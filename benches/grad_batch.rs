//! Gradient-lane bench: single-threaded `grad_fast` loop vs the
//! engine's batched gradient lane (`EngineOp::Gradient` through
//! `BatchedEngine::submit`), at n ∈ {256, 1024} over a 4-layer ×
//! 4-head problem set.
//!
//! Three variants per n:
//!   * `single`       — sequential `grad_fast` per (layer, head), fresh
//!                      FFT planner and fresh recovery every call: the
//!                      pre-engine training path;
//!   * `batched cold` — a fresh engine per iteration (pool spawn +
//!                      empty plan/basis caches): pure fan-out +
//!                      shared-plan win;
//!   * `batched warm` — a persistent engine: steady state, where the
//!                      basis cache turns the repeat (layer, head, X)
//!                      evaluations of this bench into recovery-free
//!                      `f·w` applies (`recover_probes = 0`).
//!
//! The batched lane is bit-identical to `single` (pinned by
//! `prop_batched_grad_matches_single`), so the columns are directly
//! comparable. Numbers land in EXPERIMENTS.md §PR 3.

use conv_basis::attention::batched::{BatchedEngine, EngineConfig, EngineJob};
use conv_basis::basis::RecoverConfig;
use conv_basis::gradient::batched::{FastGradConfig, GradJob};
use conv_basis::gradient::{grad_fast, AttentionLossProblem};
use conv_basis::tensor::{Matrix, Rng};
use conv_basis::util::{fmt_dur, sink, smoke, time_median, Table};
use std::sync::Arc;

const LAYERS: u32 = 4;
const HEADS: u32 = 4;
const D: usize = 8;

fn make_jobs(n: usize, cfg: &RecoverConfig) -> Vec<GradJob> {
    let mut jobs = Vec::with_capacity((LAYERS * HEADS) as usize);
    for layer in 0..LAYERS {
        for head in 0..HEADS {
            let mut rng = Rng::seeded(n as u64 * 1000 + (layer * HEADS + head) as u64);
            let problem = Arc::new(AttentionLossProblem::random_structured(n, D, &mut rng));
            // Symmetric-ish X keeps A₁XA₂ᵀ near-Toeplitz ⇒ small k.
            let x = Matrix::eye(D).scale(0.5);
            jobs.push(GradJob {
                layer,
                head,
                problem,
                x,
                cfg: FastGradConfig { recover: *cfg, use_cache: true },
            });
        }
    }
    jobs
}

fn submit_grads(engine: &BatchedEngine, jobs: &[GradJob]) -> usize {
    engine
        .submit(
            jobs.iter()
                .cloned()
                .enumerate()
                .map(|(i, j)| EngineJob::gradient(i as u64, j))
                .collect(),
        )
        .len()
}

fn main() {
    let workers = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(2);
    println!("# Batched gradient lane vs single-problem grad_fast loop");
    println!(
        "(d={D}, {LAYERS} layers × {HEADS} heads = {} jobs per step, {workers} pool workers)",
        LAYERS * HEADS
    );
    let mut table = Table::new(&[
        "n", "jobs", "single", "batched cold", "batched warm", "cold ×", "warm ×",
    ]);
    // `--smoke` (CI): one tiny n executes all three variants.
    let ns: &[usize] = if smoke() { &[48] } else { &[256, 1024] };
    for &n in ns {
        let cfg = RecoverConfig { k_max: 8, t: 2, delta: 1e-6, eps: 1e-12 };
        let jobs = make_jobs(n, &cfg);
        let n_jobs = jobs.len();
        let iters = if n >= 1024 { 3 } else { 5 };

        // Single-problem loop: the pre-engine training path.
        let t_single = time_median(iters, || {
            let mut acc = 0.0;
            for j in &jobs {
                let (g, _) = grad_fast(&j.problem, &j.x, &j.cfg.recover).unwrap();
                acc += g[(0, 0)];
            }
            acc
        });

        // Cold engine per iteration.
        let ecfg = EngineConfig { workers, cache_capacity: 2 * n_jobs };
        let t_cold = time_median(iters, || {
            let engine = BatchedEngine::new(ecfg);
            sink(submit_grads(&engine, &jobs))
        });

        // Warm engine: the warmup call fills the basis cache, timed
        // iterations evaluate the same (problem, X) set recovery-free.
        let engine = BatchedEngine::new(ecfg);
        let t_warm = time_median(iters, || sink(submit_grads(&engine, &jobs)));

        let cold_x = t_single.as_secs_f64() / t_cold.as_secs_f64();
        let warm_x = t_single.as_secs_f64() / t_warm.as_secs_f64();
        table.row(&[
            n.to_string(),
            n_jobs.to_string(),
            fmt_dur(t_single),
            fmt_dur(t_cold),
            fmt_dur(t_warm),
            format!("{cold_x:.2}×"),
            format!("{warm_x:.2}×"),
        ]);
    }
    table.print();
    println!(
        "\nshape check: cold isolates worker fan-out + shared FFT plans on the \
         d(d+2) f·w applies per job; warm adds recover-once basis reuse (a repeat \
         (layer, head, X) evaluation skips recovery entirely). The lane is \
         bit-identical to `single` — prop_batched_grad_matches_single pins it."
    );
}
