//! Theorem 4.4 table: conv-basis attention `O(knd log n)` vs exact
//! `O(n²d)` across n, k, d — wall time, recovered k, speedup, and the
//! ‖·‖∞ error against the oracle.

use conv_basis::attention::rope::rope_structured_qk;
use conv_basis::attention::{conv_attention, exact_attention, Mask};
use conv_basis::basis::RecoverConfig;
use conv_basis::tensor::{max_abs_diff, Matrix, Rng};
use conv_basis::util::{fmt_dur, smoke, time_median, Table};

fn main() {
    println!("# Theorem 4.4 — attention inference: exact vs conv-basis");
    // `--smoke` (CI) is a stronger `--quick`: tiny sizes only.
    let quick = smoke() || std::env::args().any(|a| a == "--quick");

    // Sweep n at fixed d, k budget.
    println!("\n## sweep n (d = 64, k_max = 8, structured QKᵀ)");
    let mut t1 = Table::new(&["n", "exact", "conv", "speedup", "recovered k", "max err"]);
    let ns: &[usize] = if smoke() {
        &[128]
    } else if quick {
        &[256, 512, 1024]
    } else {
        &[256, 512, 1024, 2048, 4096]
    };
    for &n in ns {
        let mut rng = Rng::seeded(n as u64);
        let d = 64;
        let (q, k) = rope_structured_qk(n, d, 3, &mut rng);
        let v = Matrix::randn(n, d, &mut rng);
        let iters = if n <= 1024 { 5 } else { 3 };
        let t_exact = time_median(iters, || exact_attention(&q, &k, &v, &Mask::causal(n)));
        let tw = 4;
        let cfg = RecoverConfig { k_max: 8, t: tw, delta: 5.0 * tw as f64 * 1e-7, eps: 1e-7 };
        let t_conv = time_median(iters, || conv_attention(&q, &k, &v, &cfg).unwrap());
        let out = conv_attention(&q, &k, &v, &cfg).unwrap();
        let exact = exact_attention(&q, &k, &v, &Mask::causal(n));
        t1.row(&[
            n.to_string(),
            fmt_dur(t_exact),
            fmt_dur(t_conv),
            format!("{:.2}×", t_exact.as_secs_f64() / t_conv.as_secs_f64()),
            out.post_basis.k().to_string(),
            format!("{:.2e}", max_abs_diff(&exact, &out.y)),
        ]);
    }
    t1.print();

    // Sweep k_max at fixed n: cost should grow ~linearly in k.
    println!("\n## sweep k (n = 2048, d = 64; k-conv synthetic target)");
    let mut t2 = Table::new(&["k", "conv time", "time/k"]);
    let n = if smoke() { 128 } else if quick { 1024 } else { 2048 };
    for &k_target in &[1usize, 2, 4, 8, 16] {
        let mut rng = Rng::seeded(900 + k_target as u64);
        let v = Matrix::randn(n, 64, &mut rng);
        // Build a synthetic k-conv post-basis directly and time the
        // apply (isolates the O(knd log n) apply from recovery).
        let mut terms = Vec::new();
        let mut m = n;
        for _ in 0..k_target {
            terms.push(conv_basis::basis::ConvBasis {
                b: rng.randn_vec(n).iter().map(|x| x.abs() + 0.1).collect(),
                m,
            });
            m = m / 2 + 1;
        }
        // Ensure strictly decreasing windows.
        let mut seen = std::collections::BTreeSet::new();
        let terms: Vec<_> = terms
            .into_iter()
            .filter(|t| seen.insert(std::cmp::Reverse(t.m)))
            .collect();
        let basis = conv_basis::basis::KConvBasis::new(n, terms);
        let mut planner = conv_basis::fft::FftPlanner::new();
        let t = time_median(5, || basis.apply_matrix(&mut planner, &v));
        t2.row(&[
            basis.k().to_string(),
            fmt_dur(t),
            fmt_dur(t / basis.k() as u32),
        ]);
    }
    t2.print();

    // Sweep d at fixed n, k.
    println!("\n## sweep d (n = 1024, k_max = 8)");
    let mut t3 = Table::new(&["d", "exact", "conv", "speedup"]);
    let ds: &[usize] = if smoke() { &[16] } else { &[16, 32, 64, 128] };
    for &d in ds {
        let n = if smoke() { 128 } else { 1024 };
        let mut rng = Rng::seeded(7000 + d as u64);
        let (q, k) = rope_structured_qk(n, d, 3, &mut rng);
        let v = Matrix::randn(n, d, &mut rng);
        let t_exact = time_median(3, || exact_attention(&q, &k, &v, &Mask::causal(n)));
        let tw = 4;
        let cfg = RecoverConfig { k_max: 8, t: tw, delta: 5.0 * tw as f64 * 1e-7, eps: 1e-7 };
        let t_conv = time_median(3, || conv_attention(&q, &k, &v, &cfg).unwrap());
        t3.row(&[
            d.to_string(),
            fmt_dur(t_exact),
            fmt_dur(t_conv),
            format!("{:.2}×", t_exact.as_secs_f64() / t_conv.as_secs_f64()),
        ]);
    }
    t3.print();
    println!(
        "\npaper shape check: conv grows ~n log n (vs n² exact), linearly in k and d; \
         speedup widens with n."
    );
}
