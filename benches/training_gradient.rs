//! Theorem 5.6 table: training forward + backward gradient — naive
//! `O(n²d)` vs tensor-trick factored (dense f) vs the conv-basis fast
//! path `O(k·n·d²·log n)`.

use conv_basis::basis::RecoverConfig;
use conv_basis::gradient::{
    fast::grad_factored_dense, grad_fast, grad_naive, loss_fast, loss_naive,
    AttentionLossProblem,
};
use conv_basis::tensor::{max_abs_diff, Matrix, Rng};
use conv_basis::util::{fmt_dur, smoke, time_median, Table};

fn main() {
    println!("# Theorem 5.6 — attention training gradient");
    // `--smoke` (CI) is a stronger `--quick`: tiny sizes only.
    let quick = smoke() || std::env::args().any(|a| a == "--quick");

    println!("\n## backward gradient, sweep n (d = 8, structured instance)");
    let mut t1 = Table::new(&[
        "n",
        "naive",
        "factored(dense f)",
        "conv-fast",
        "speedup vs naive",
        "k",
        "max err",
    ]);
    let ns: &[usize] = if smoke() {
        &[64]
    } else if quick {
        &[128, 256, 512]
    } else {
        &[128, 256, 512, 1024, 2048]
    };
    for &n in ns {
        let d = 8;
        let mut rng = Rng::seeded(n as u64);
        let p = AttentionLossProblem::random_structured(n, d, &mut rng);
        let x = Matrix::eye(d).scale(0.5); // symmetric ⇒ small conv basis
        let iters = if n <= 512 { 5 } else { 3 };
        let t_naive = time_median(iters, || grad_naive(&p, &x));
        let t_fact = time_median(iters, || grad_factored_dense(&p, &x));
        let tw = 2;
        let cfg = RecoverConfig { k_max: 8, t: tw, delta: 5.0 * tw as f64 * 1e-7, eps: 1e-7 };
        let t_fast = time_median(iters, || grad_fast(&p, &x, &cfg).unwrap());
        let (g_fast, report) = grad_fast(&p, &x, &cfg).unwrap();
        let g_naive = grad_naive(&p, &x);
        t1.row(&[
            n.to_string(),
            fmt_dur(t_naive),
            fmt_dur(t_fact),
            fmt_dur(t_fast),
            format!("{:.2}×", t_naive.as_secs_f64() / t_fast.as_secs_f64()),
            report.basis_k.to_string(),
            format!("{:.2e}", max_abs_diff(&g_naive, &g_fast)),
        ]);
    }
    t1.print();

    println!("\n## training forward, sweep n (d = 8)");
    let mut t2 = Table::new(&["n", "naive fwd", "conv fwd", "speedup", "rel loss err"]);
    for &n in ns {
        let d = 8;
        let mut rng = Rng::seeded(31 + n as u64);
        let p = AttentionLossProblem::random_structured(n, d, &mut rng);
        let x = Matrix::eye(d).scale(0.5);
        let iters = if n <= 512 { 5 } else { 3 };
        let t_naive = time_median(iters, || loss_naive(&p, &x));
        let tw = 2;
        let cfg = RecoverConfig { k_max: 8, t: tw, delta: 5.0 * tw as f64 * 1e-7, eps: 1e-7 };
        let t_fast = time_median(iters, || loss_fast(&p, &x, &cfg).unwrap());
        let l_naive = loss_naive(&p, &x);
        let l_fast = loss_fast(&p, &x, &cfg).unwrap();
        t2.row(&[
            n.to_string(),
            fmt_dur(t_naive),
            fmt_dur(t_fast),
            format!("{:.2}×", t_naive.as_secs_f64() / t_fast.as_secs_f64()),
            format!("{:.2e}", (l_naive - l_fast).abs() / l_naive.max(1e-12)),
        ]);
    }
    t2.print();

    println!("\n## backward, sweep d (n = 512): cost should scale ~d²");
    let mut t3 = Table::new(&["d", "conv-fast", "time/d²(µs)"]);
    let ds: &[usize] = if smoke() { &[4] } else { &[4, 8, 16] };
    for &d in ds {
        let n = if smoke() { 64 } else { 512 };
        let mut rng = Rng::seeded(77 + d as u64);
        let p = AttentionLossProblem::random_structured(n, d, &mut rng);
        let x = Matrix::eye(d).scale(0.5);
        let tw = 2;
        let cfg = RecoverConfig { k_max: 8, t: tw, delta: 5.0 * tw as f64 * 1e-7, eps: 1e-7 };
        let t_fast = time_median(3, || grad_fast(&p, &x, &cfg).unwrap());
        t3.row(&[
            d.to_string(),
            fmt_dur(t_fast),
            format!("{:.2}", t_fast.as_secs_f64() * 1e6 / (d * d) as f64),
        ]);
    }
    t3.print();
    println!("\npaper shape check: conv-fast beats naive for large n; growth ~n log n and ~d².");
}
