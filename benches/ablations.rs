//! Ablations of design choices called out in DESIGN.md §5:
//!  1. normalizer row-sums: closed-form prefix vs FFT apply of 1ₙ;
//!  2. continuous-row masks: segment tree (paper) vs prefix sums (ours);
//!  3. FFT plan cache: cached planner vs rebuilt per call;
//!  4. row-change deltas: analytic vs O(n) scan;
//!  5. recovery probe cost: binary search vs linear scan;
//!  6. apply_matrix: spectrum-cached pair-packed FFT (§Perf L3-1) vs
//!     per-column linear convolutions.

use conv_basis::basis::{recover_from_oracle, ConvBasis, DenseColumnOracle, KConvBasis, RecoverConfig};
use conv_basis::fft::FftPlanner;
use conv_basis::lowrank::masked;
use conv_basis::tensor::{Matrix, Rng};
use conv_basis::util::{fmt_dur, smoke, time_median, Table};

fn synthetic_basis(n: usize, k: usize, rng: &mut Rng) -> KConvBasis {
    let mut terms = Vec::new();
    let mut m = n;
    for _ in 0..k {
        terms.push(ConvBasis { b: rng.randn_vec(n).iter().map(|x| x.abs() + 0.1).collect(), m });
        if m <= 2 {
            break;
        }
        m = m / 2 + 1;
    }
    let mut seen = std::collections::BTreeSet::new();
    KConvBasis::new(
        n,
        terms.into_iter().filter(|t| seen.insert(std::cmp::Reverse(t.m))).collect(),
    )
}

fn main() {
    println!("# Ablations");
    // `--smoke` (CI): tiny sizes, just enough to execute every section.
    let ns: &[usize] = if smoke() { &[96] } else { &[512, 2048, 8192] };
    let mut rng = Rng::seeded(4242);

    println!("\n## 1. normalizer D̃: prefix-sum row_sums vs FFT·1ₙ (n sweep, k=8)");
    let mut t1 = Table::new(&["n", "prefix", "fft", "speedup"]);
    for &n in ns {
        let basis = synthetic_basis(n, 8, &mut rng);
        let ones = vec![1.0; n];
        let mut planner = FftPlanner::new();
        let t_prefix = time_median(9, || basis.row_sums());
        let t_fft = time_median(9, || basis.apply(&mut planner, &ones));
        t1.row(&[
            n.to_string(),
            fmt_dur(t_prefix),
            fmt_dur(t_fft),
            format!("{:.1}×", t_fft.as_secs_f64() / t_prefix.as_secs_f64()),
        ]);
    }
    t1.print();

    println!("\n## 2. continuous-row mask: segment tree (paper Alg 6) vs prefix sums");
    let mut t2 = Table::new(&["n", "segtree", "prefix", "segtree/prefix"]);
    for &n in ns {
        let k = 16;
        let u1 = Matrix::randn(n, k, &mut rng);
        let u2 = Matrix::randn(n, k, &mut rng);
        let v = rng.randn_vec(n);
        let s: Vec<usize> = (0..n).map(|i| i / 2).collect();
        let t: Vec<usize> = (0..n).map(|i| (i / 2 + n / 4).min(n - 1)).collect();
        let t_seg =
            time_median(7, || masked::continuous_row_multiply_segtree(&u1, &u2, &v, &s, &t));
        let t_pre =
            time_median(7, || masked::continuous_row_multiply_prefix(&u1, &u2, &v, &s, &t));
        t2.row(&[
            n.to_string(),
            fmt_dur(t_seg),
            fmt_dur(t_pre),
            format!("{:.1}×", t_seg.as_secs_f64() / t_pre.as_secs_f64()),
        ]);
    }
    t2.print();

    println!("\n## 3. FFT plan cache: shared planner vs rebuilt per apply (n=2048, k=8, 16 applies)");
    let mut t3 = Table::new(&["variant", "time"]);
    {
        let n = if smoke() { 96 } else { 2048 };
        let basis = synthetic_basis(n, 8, &mut rng);
        let x = rng.randn_vec(n);
        let mut shared = FftPlanner::new();
        let t_cached = time_median(5, || {
            let mut acc = 0.0;
            for _ in 0..16 {
                acc += basis.apply(&mut shared, &x)[n - 1];
            }
            acc
        });
        let t_cold = time_median(5, || {
            let mut acc = 0.0;
            for _ in 0..16 {
                let mut p = FftPlanner::new();
                acc += basis.apply(&mut p, &x)[n - 1];
            }
            acc
        });
        t3.row(&["cached planner".into(), fmt_dur(t_cached)]);
        t3.row(&["cold planner per apply".into(), fmt_dur(t_cold)]);
        t3.row(&[
            "cache speedup".into(),
            format!("{:.2}×", t_cold.as_secs_f64() / t_cached.as_secs_f64()),
        ]);
    }
    t3.print();

    println!("\n## 4. row-change deltas: analytic vs O(n) scan (sliding window, n sweep)");
    let mut t4 = Table::new(&["n", "analytic", "scan", "speedup"]);
    for &n in ns {
        let k = 16;
        let u1 = Matrix::randn(n, k, &mut rng);
        let u2 = Matrix::randn(n, k, &mut rng);
        let v = rng.randn_vec(n);
        let sw = conv_basis::attention::Mask::sliding_window(n, 64, 4);
        let deltas = masked::analytic_deltas(&sw).unwrap();
        let t_analytic =
            time_median(7, || masked::row_change_multiply_with_deltas(&deltas, &u1, &u2, &v));
        let t_scan = time_median(3, || masked::row_change_multiply(&sw, &u1, &u2, &v));
        t4.row(&[
            n.to_string(),
            fmt_dur(t_analytic),
            fmt_dur(t_scan),
            format!("{:.1}×", t_scan.as_secs_f64() / t_analytic.as_secs_f64()),
        ]);
    }
    t4.print();

    println!("\n## 5. recovery: binary search (Alg 3) vs linear scan of onsets (n sweep, k=4)");
    let mut t5 = Table::new(&["n", "probes (binary)", "probes (linear bound)", "saving"]);
    for &n in ns {
        let t_win = 4;
        let mut terms = Vec::new();
        let mut m = n;
        for _ in 0..4 {
            let mut b = rng.randn_vec(n);
            for x in b.iter_mut().take(t_win) {
                *x = 1.0 + rng.uniform();
            }
            for x in b.iter_mut().skip(m) {
                *x = 0.0;
            }
            terms.push(ConvBasis { b, m });
            m = m / 2 + 1;
        }
        let mut seen = std::collections::BTreeSet::new();
        let basis = KConvBasis::new(
            n,
            terms.into_iter().filter(|t| seen.insert(std::cmp::Reverse(t.m))).collect(),
        );
        let h = basis.to_dense();
        let cfg = RecoverConfig { k_max: 8, t: t_win, delta: 0.5, eps: 1e-9 };
        let (_, stats) = recover_from_oracle(&DenseColumnOracle(&h), &cfg).unwrap();
        // A linear scan would probe every column up to the last onset.
        let linear_bound = n - basis.terms().last().unwrap().m + basis.k();
        t5.row(&[
            n.to_string(),
            stats.columns_probed.to_string(),
            linear_bound.to_string(),
            format!("{:.0}×", linear_bound as f64 / stats.columns_probed as f64),
        ]);
    }
    t5.print();

    println!("\n## 6. apply_matrix: spectrum-cached pair-packed (§Perf L3-1) vs per-column");
    let mut t6 = Table::new(&["n", "d", "per-column", "spectrum+pair", "speedup"]);
    let nds: &[(usize, usize)] =
        if smoke() { &[(128, 8)] } else { &[(2048, 64), (4096, 64), (4096, 128)] };
    for &(n, d) in nds {
        let basis = synthetic_basis(n, 8, &mut rng);
        let v = Matrix::randn(n, d, &mut rng);
        let mut planner = FftPlanner::new();
        let t_old = time_median(3, || basis.apply_matrix_percolumn(&mut planner, &v));
        let t_new = time_median(3, || basis.apply_matrix(&mut planner, &v));
        t6.row(&[
            n.to_string(),
            d.to_string(),
            fmt_dur(t_old),
            fmt_dur(t_new),
            format!("{:.2}×", t_old.as_secs_f64() / t_new.as_secs_f64()),
        ]);
    }
    t6.print();
}
