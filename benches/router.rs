//! PR 9 router table: per-backend prefill cost at each n, then the
//! routed engine running a mixed per-head table over the same sizes —
//! the routed column must price like the *mix* of its resolved
//! backends (routing itself is a table lookup, not a kernel). The
//! routing decisions the policy resolved to are printed so the table
//! is self-describing in EXPERIMENTS.md.

use std::sync::Arc;

use conv_basis::attention::batched::{
    AttnJob, BatchedBackend, BatchedEngine, EngineConfig, EngineJob, HeadRoute, RouterPolicy,
};
use conv_basis::attention::rope::rope_structured_qk;
use conv_basis::attention::ExactKernel;
use conv_basis::basis::RecoverConfig;
use conv_basis::lowrank::LowRankConfig;
use conv_basis::tensor::{Matrix, Rng};
use conv_basis::util::{fmt_dur, smoke, time_median, Table};

fn prefill(e: &BatchedEngine, jobs: Vec<AttnJob>) {
    let outs = e.submit(
        jobs.into_iter().enumerate().map(|(i, j)| EngineJob::prefill(i as u64, j)).collect(),
    );
    assert!(!outs.is_empty());
}

/// One (q, k, v) per head — rope-structured so the conv routes
/// recover, mild uniform values so the low-rank route stays in its
/// accuracy envelope.
fn head_inputs(n: usize, d: usize, heads: u32) -> Vec<(Matrix, Matrix, Matrix)> {
    (0..heads)
        .map(|h| {
            let mut rng = Rng::seeded(0xBE + h as u64);
            let (q, k) = rope_structured_qk(n, d, 2, &mut rng);
            let v = Matrix::rand_uniform(n, d, 0.4, &mut rng);
            (q, k, v)
        })
        .collect()
}

fn main() {
    println!("# PR 9 — adaptive router: per-backend vs routed prefill");
    let quick = smoke() || std::env::args().any(|a| a == "--quick");
    let ns: &[usize] = if smoke() {
        &[96]
    } else if quick {
        &[256, 1024]
    } else {
        &[256, 1024, 4096]
    };
    let d = 8;
    let heads = 4u32;

    let mut table = Table::new(&["n", "exact", "strided", "conv", "lowrank", "routed(mixed)"]);
    for &n in ns {
        let inputs = head_inputs(n, d, heads);
        let iters = if n <= 1024 { 5 } else { 3 };

        let policy = Arc::new(
            RouterPolicy::new(HeadRoute::Exact)
                .set(0, 0, HeadRoute::Exact)
                .set(0, 1, HeadRoute::Strided(8))
                .set(0, 2, HeadRoute::Conv(RecoverConfig::exact(n)))
                .set(0, 3, HeadRoute::LowRank(LowRankConfig::new(2, d as f64))),
        );

        let run = |backend_for: &dyn Fn(u32) -> BatchedBackend| {
            let e = BatchedEngine::new(EngineConfig { workers: 4, cache_capacity: 4 });
            time_median(iters, || {
                let jobs: Vec<AttnJob> = inputs
                    .iter()
                    .enumerate()
                    .map(|(h, (q, k, v))| {
                        AttnJob::causal(
                            0,
                            h as u32,
                            q.clone(),
                            k.clone(),
                            v.clone(),
                            backend_for(h as u32),
                        )
                    })
                    .collect();
                prefill(&e, jobs);
            })
        };

        let t_exact = run(&|_| BatchedBackend::Exact(ExactKernel::RowStream));
        let t_strided = run(&|_| BatchedBackend::Strided(8));
        let t_conv = run(&|_| BatchedBackend::Conv(RecoverConfig::exact(n)));
        let t_lowrank = run(&|_| BatchedBackend::LowRank(LowRankConfig::new(2, d as f64)));
        let t_routed = run(&|_| BatchedBackend::Routed(Arc::clone(&policy)));

        table.row(&[
            n.to_string(),
            fmt_dur(t_exact),
            fmt_dur(t_strided),
            fmt_dur(t_conv),
            fmt_dur(t_lowrank),
            fmt_dur(t_routed),
        ]);

        // The routing decisions behind the routed column.
        let decisions: Vec<String> = policy
            .decisions()
            .map(|((layer, head), route)| format!("({layer},{head})→{route:?}"))
            .collect();
        println!("n={n} routed table: {}", decisions.join("  "));
    }
    println!();
    table.print();
}
