//! LM attention-backward bench: the dense matrix-form per-head
//! backward (the pre-PR-4 `Transformer::backward` inner loop) vs the
//! engine's LM-backward lane in exact mode (row-streamed, bit-identical
//! to dense) vs the conv-basis fast mode, at n ∈ {256, 1024, 4096}.
//!
//! Three strategies per n, all computing the same `(dQ, dK, dV)` for a
//! set of (layer, head) jobs with structured Q/K:
//!
//!   * `dense`        — materialize `Pᵀ`, `dP`, `dS` (three n×n
//!                      temporaries) and run the matrix-form backward
//!                      per head, sequentially: what
//!                      `Transformer::backward` did before the engine
//!                      routing;
//!   * `engine exact` — one `submit` of row-stream `AttnBackwardMode::Exact` jobs:
//!                      identical bits (pinned by
//!                      `tests/gradient_oracle.rs`), `O(n + n·d_h)`
//!                      scratch, pool fan-out;
//!   * `conv fast`    — one `submit` of `AttnBackwardMode::Fast` jobs on
//!                      a persistent engine (warm: repeat evaluations
//!                      are served recovery-free from the `BasisCache`):
//!                      `O(k·n·d_h²·log n)` per head.
//!
//! Numbers land in EXPERIMENTS.md §PR 4.

use conv_basis::attention::batched::{BatchedEngine, EngineConfig, EngineJob};
use conv_basis::attention::rope::rope_structured_qk;
use conv_basis::attention::ExactKernel;
use conv_basis::basis::RecoverConfig;
use conv_basis::gradient::batched::{AttnBackwardJob, AttnBackwardMode, FastGradConfig};
use conv_basis::tensor::{dot, softmax, Matrix, Rng};
use conv_basis::util::{fmt_dur, sink, smoke, time_median, Table};
use std::sync::Arc;

const DH: usize = 8;

struct HeadCase {
    q: Matrix,
    k: Matrix,
    v: Matrix,
    dout: Matrix,
    probs: Arc<Matrix>,
}

fn make_cases(n: usize, heads: usize) -> Vec<HeadCase> {
    (0..heads)
        .map(|h| {
            let mut rng = Rng::seeded(n as u64 * 100 + h as u64);
            let (q, k) = rope_structured_qk(n, DH, 3, &mut rng);
            let v = Matrix::randn(n, DH, &mut rng);
            let dout = Matrix::randn(n, DH, &mut rng);
            // The forward's softmax rows (training keeps these cached,
            // so probs construction is not part of backward cost).
            let logits = q.matmul(&k.transpose());
            let mut probs = Matrix::zeros(n, n);
            for i in 0..n {
                let row = softmax(&logits.row(i)[..=i]);
                probs.row_mut(i)[..=i].copy_from_slice(&row);
            }
            HeadCase { q, k, v, dout, probs: Arc::new(probs) }
        })
        .collect()
}

/// The pre-engine dense backward: three n×n temporaries per head.
fn dense_backward(c: &HeadCase) -> f64 {
    let n = c.q.rows();
    let dv = c.probs.transpose().matmul(&c.dout);
    let dprobs = c.dout.matmul(&c.v.transpose());
    let mut dscores = Matrix::zeros(n, n);
    for i in 0..n {
        let prow = c.probs.row(i);
        let dprow = dprobs.row(i);
        let d = dot(prow, dprow);
        let srow = dscores.row_mut(i);
        for j in 0..n {
            srow[j] = prow[j] * (dprow[j] - d);
        }
    }
    let dq = dscores.matmul(&c.k);
    let dk = dscores.transpose().matmul(&c.q);
    dq[(0, 0)] + dk[(0, 0)] + dv[(0, 0)]
}

fn submit_backward(engine: &BatchedEngine, cases: &[HeadCase], mode: &AttnBackwardMode) -> f64 {
    let jobs: Vec<EngineJob> = cases
        .iter()
        .enumerate()
        .map(|(i, c)| {
            EngineJob::attn_backward(
                i as u64,
                AttnBackwardJob {
                    layer: (i / 2) as u32,
                    head: (i % 2) as u32,
                    q: c.q.clone(),
                    k: c.k.clone(),
                    v: c.v.clone(),
                    dout: c.dout.clone(),
                    probs: Some(Arc::clone(&c.probs)),
                    basis: None,
                    mode: mode.clone(),
                },
            )
        })
        .collect();
    engine
        .submit(jobs)
        .into_iter()
        .map(|o| o.result.into_attn_backward().dq[(0, 0)])
        .sum()
}

fn main() {
    let workers = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(2);
    println!("# LM attention backward: dense vs engine-exact vs conv-fast");
    println!("(d_h={DH}, {workers} pool workers; engine exact is bit-identical to dense)");
    let mut table = Table::new(&[
        "n", "heads", "dense", "engine exact", "conv fast", "exact ×", "fast ×",
    ]);
    // `--smoke` (CI): one tiny n executes all three strategies.
    let ns: &[usize] = if smoke() { &[48] } else { &[256, 1024, 4096] };
    for &n in ns {
        // The n×n probs cache dominates memory at 4096 — halve the job
        // set there (printed, not silent).
        let heads = if n >= 4096 { 2 } else { 4 };
        let cases = make_cases(n, heads);
        let iters = if n >= 4096 { 2 } else { 5 };
        let fast_cfg = AttnBackwardMode::Fast(FastGradConfig {
            recover: RecoverConfig { k_max: 8, t: 2, delta: 1e-6, eps: 1e-12 },
            use_cache: true,
        });

        let t_dense = time_median(iters, || {
            let mut acc = 0.0;
            for c in &cases {
                acc += dense_backward(c);
            }
            acc
        });

        let engine = BatchedEngine::new(EngineConfig { workers, cache_capacity: 32 });
        let t_exact = time_median(iters, || {
            sink(submit_backward(&engine, &cases, &AttnBackwardMode::Exact(ExactKernel::RowStream)))
        });
        // Warm fast path: the first (warmup) call inside time_median
        // fills the basis cache; timed iterations are recovery-free.
        let t_fast =
            time_median(iters, || sink(submit_backward(&engine, &cases, &fast_cfg)));

        let exact_x = t_dense.as_secs_f64() / t_exact.as_secs_f64();
        let fast_x = t_dense.as_secs_f64() / t_fast.as_secs_f64();
        table.row(&[
            n.to_string(),
            heads.to_string(),
            fmt_dur(t_dense),
            fmt_dur(t_exact),
            fmt_dur(t_fast),
            format!("{exact_x:.2}×"),
            format!("{fast_x:.2}×"),
        ]);
    }
    table.print();
    println!(
        "\nshape check: dense is O(n²·d_h) flops AND O(n²) scratch per head; engine \
         exact removes the scratch and adds pool fan-out at identical bits; conv \
         fast replaces the kernel with O(k·n·d_h²·log n) basis applies (warm: \
         recovery amortized through the BasisCache). tests/gradient_oracle.rs pins \
         exact ≡ dense; fast accuracy is pinned to 1e-6 relative there."
    );
}
