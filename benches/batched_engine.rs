//! Tentpole bench: the batched multi-head conv-attention engine vs the
//! seed's single-sequence loop (one `conv_attention_strided` call per
//! (sequence, head), fresh FFT planner and fresh recovery every call).
//!
//! Three variants per (n, batch) cell:
//!   * `single`  — sequential per-job calls, the pre-engine behavior;
//!   * `cold`    — a fresh engine per iteration (pool spawn + empty
//!                 plan/basis caches): pure fan-out + shared-plan win;
//!   * `warm`    — a persistent engine: steady-state serving, where the
//!                 basis cache turns repeat (layer, head, seq_len, QK)
//!                 traffic into `O(kn + nd)` applies.
//!
//! Acceptance (ISSUE 1): batched throughput ≥ 2× single at batch 32,
//! n = 1024.

use conv_basis::attention::batched::{AttnJob, BatchedBackend, BatchedEngine, EngineConfig, EngineJob};
use conv_basis::attention::conv_attention_strided;
use conv_basis::attention::rope::rope_structured_qk;
use conv_basis::tensor::{Matrix, Rng};
use conv_basis::util::{fmt_dur, sink, smoke, time_median, Table};

/// Prefill-lane submit of a cloned job set.
fn submit_prefill(engine: &BatchedEngine, jobs: &[AttnJob]) -> usize {
    engine
        .submit(
            jobs.iter()
                .cloned()
                .enumerate()
                .map(|(i, j)| EngineJob::prefill(i as u64, j))
                .collect(),
        )
        .len()
}

const D: usize = 16;
const HEADS: usize = 2;
const K_BASES: usize = 8;

fn make_jobs(n: usize, batch: usize, seed: u64) -> Vec<AttnJob> {
    let mut jobs = Vec::with_capacity(batch * HEADS);
    for s in 0..batch {
        let mut rng = Rng::seeded(seed.wrapping_add(s as u64));
        let (q, k) = rope_structured_qk(n, D, 3, &mut rng);
        let v = Matrix::randn(n, D, &mut rng);
        for h in 0..HEADS {
            jobs.push(AttnJob::causal(
                0,
                h as u32,
                q.clone(),
                k.clone(),
                v.clone(),
                BatchedBackend::Strided(K_BASES),
            ));
        }
    }
    jobs
}

fn main() {
    let workers = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(2);
    println!("# Batched multi-head conv-attention engine vs single-sequence loop");
    println!("(d={D}, heads={HEADS}, strided k={K_BASES}, {workers} pool workers; \
              jobs = batch × heads; req/s counts jobs)");
    let mut table = Table::new(&[
        "n", "batch", "single", "batched cold", "batched warm", "cold ×", "warm ×", "warm req/s",
    ]);
    let mut accept_line = String::new();
    // `--smoke` (CI): one tiny cell per axis, enough to execute the
    // three variants end to end.
    let ns: &[usize] = if smoke() { &[64] } else { &[256, 1024, 4096] };
    let batches: &[usize] = if smoke() { &[2] } else { &[1, 8, 32] };
    for &n in ns {
        for &batch in batches {
            let jobs = make_jobs(n, batch, n as u64 * 1000 + batch as u64);
            let n_jobs = jobs.len();
            let iters = if n >= 4096 { 3 } else { 5 };

            // Single-sequence loop: fresh planner + fresh recovery per
            // call, sequential — exactly the pre-engine hot path.
            let t_single = time_median(iters, || {
                let mut acc = 0.0;
                for j in &jobs {
                    let out = conv_attention_strided(&j.q, &j.k, &j.v, K_BASES).unwrap();
                    acc += out.y[(0, 0)];
                }
                acc
            });

            // Cold engine: pool spawn + empty caches every iteration.
            let cfg = EngineConfig { workers, cache_capacity: 2 * n_jobs.max(1) };
            let t_cold = time_median(iters, || {
                let engine = BatchedEngine::new(cfg);
                sink(submit_prefill(&engine, &jobs))
            });

            // Warm engine: persistent caches (time_median's warmup call
            // fills them; timed iterations see steady state).
            let engine = BatchedEngine::new(cfg);
            let t_warm = time_median(iters, || sink(submit_prefill(&engine, &jobs)));

            let cold_x = t_single.as_secs_f64() / t_cold.as_secs_f64();
            let warm_x = t_single.as_secs_f64() / t_warm.as_secs_f64();
            table.row(&[
                n.to_string(),
                batch.to_string(),
                fmt_dur(t_single),
                fmt_dur(t_cold),
                fmt_dur(t_warm),
                format!("{cold_x:.2}×"),
                format!("{warm_x:.2}×"),
                format!("{:.1}", n_jobs as f64 / t_warm.as_secs_f64()),
            ]);
            if n == 1024 && batch == 32 {
                accept_line = format!(
                    "acceptance @ n=1024, batch=32: batched {:.2}× (cold) / {:.2}× (warm) \
                     vs the single-sequence loop (target ≥ 2×)",
                    cold_x, warm_x
                );
            }
        }
    }
    table.print();
    println!("\n{accept_line}");
    println!(
        "shape check: the cold column isolates pool fan-out + shared FFT plans; \
         the warm column adds recover-once-apply-per-V basis reuse."
    );
}
