//! Full LM training step bench (ISSUE 5): forward + backward through
//! the engine, **exact mode vs end-to-end conv mode**, at
//! n ∈ {256, 1024, 4096}.
//!
//! One "step" is what `train_lm_with_engine` pays per record per
//! optimizer step, minus the optimizer update (identical in both
//! modes): `Transformer::forward_train_batch` (training prefill jobs,
//! activations retained) → `lm_loss` → one
//! `Transformer::backward_batch_with_engine` call (LM-backward jobs).
//!
//! Two strategies per n:
//!
//!   * `exact step` — `TrainAttentionMode::Exact` +
//!     row-stream `AttnBackwardMode::Exact`: the `O(n²)` softmax forward (n×n
//!     probs retained per head) and the row-streamed exact backward —
//!     the PR-4 training path;
//!   * `conv step`  — `TrainAttentionMode::Conv` +
//!     `AttnBackwardMode::Fast`: Algorithm 1 forward recovering each
//!     (layer, head) basis once, the conv backward consuming the
//!     step-scoped handle for free (`step_basis_hits`).
//!
//! **Honesty note:** a randomly initialized transformer's QKᵀ is not
//! conv-structured, so adaptive recovery at the small budget used here
//! may *fail* and fall back to the exact kernel — the fallback /
//! recovery counters are printed next to the timings so the table
//! can't silently bench the fallback as if it were the conv path. The
//! conv win is contingent on structure (RoPE-structured heads, trained
//! attention sinks …); the kernel-level speedups on structured inputs
//! are measured in `benches/lm_backward.rs` and EXPERIMENTS.md.
//!
//! Numbers land in EXPERIMENTS.md §PR 5.

use conv_basis::attention::batched::{BatchedEngine, EngineConfig};
use conv_basis::attention::ExactKernel;
use conv_basis::basis::RecoverConfig;
use conv_basis::gradient::batched::{AttnBackwardMode, FastGradConfig};
use conv_basis::model::{ModelConfig, TrainAttentionMode, Transformer};
use conv_basis::tensor::{Matrix, Rng};
use conv_basis::util::{fmt_dur, smoke, time_median, Table};

fn step(
    m: &Transformer,
    seqs: &[Vec<usize>],
    targets: &[Vec<usize>],
    engine: &BatchedEngine,
    fwd: &TrainAttentionMode,
    bwd: &AttnBackwardMode,
) -> f64 {
    let (recs, _) = m.forward_train_batch(seqs, fwd, engine);
    let mut grads = m.zero_grads();
    let dls: Vec<Matrix> =
        recs.iter().zip(targets).map(|(r, y)| m.lm_loss(r, y, usize::MAX).1).collect();
    let batch: Vec<_> = recs.iter().zip(&dls).map(|(r, dl)| (r, dl, None)).collect();
    m.backward_batch_with_engine(&batch, &mut grads, engine, bwd);
    grads.embed[(0, 0)]
}

fn main() {
    let workers = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(2);
    println!("# Full LM training step: exact vs end-to-end conv (fwd+bwd, {workers} workers)");
    println!("(1 layer × 2 heads, d_model=16, batch=1; optimizer update excluded — identical)");
    let mut table = Table::new(&[
        "n", "exact step", "conv step", "conv ÷ exact", "recoveries", "fwd fallbacks",
        "bwd fallbacks",
    ]);
    // `--smoke` (CI): one tiny n executes both modes end to end.
    let ns: &[usize] = if smoke() { &[32] } else { &[256, 1024, 4096] };
    for &n in ns {
        // 1 layer keeps the n=4096 exact cell's retained probs at
        // 2 heads × n² × 8B ≈ 268 MB (printed config, not silent).
        let mcfg = ModelConfig {
            vocab_size: 260,
            d_model: 16,
            n_heads: 2,
            n_layers: 1,
            d_ff: 32,
            max_seq: n,
        };
        let mut rng = Rng::seeded(n as u64);
        let m = Transformer::new(&mcfg, &mut rng);
        let seqs: Vec<Vec<usize>> = vec![(0..n).map(|_| rng.below(260)).collect()];
        let targets: Vec<Vec<usize>> = vec![(0..n).map(|_| rng.below(260)).collect()];
        let iters = if n >= 4096 { 2 } else { 3 };

        let engine = BatchedEngine::new(EngineConfig { workers, cache_capacity: 16 });
        let t_exact = time_median(iters, || {
            step(
                &m,
                &seqs,
                &targets,
                &engine,
                &TrainAttentionMode::Exact,
                &AttnBackwardMode::Exact(ExactKernel::RowStream),
            )
        });

        let recover = RecoverConfig { k_max: 8, t: 2, delta: 1e-6, eps: 1e-12 };
        let fwd = TrainAttentionMode::Conv(recover);
        let bwd = AttnBackwardMode::Fast(FastGradConfig { recover, use_cache: false });
        let before = engine.metrics().snapshot();
        let t_conv = time_median(iters, || step(&m, &seqs, &targets, &engine, &fwd, &bwd));
        let after = engine.metrics().snapshot();

        table.row(&[
            n.to_string(),
            fmt_dur(t_exact),
            fmt_dur(t_conv),
            format!("{:.2}×", t_conv.as_secs_f64() / t_exact.as_secs_f64()),
            (after.step_recoveries - before.step_recoveries).to_string(),
            (after.train_fwd_fallbacks - before.train_fwd_fallbacks).to_string(),
            (after.lm_backward_fallbacks - before.lm_backward_fallbacks).to_string(),
        ]);
    }
    table.print();
    println!(
        "\nshape check: the conv step is O(k·n·d·log n) forward + O(k·n·d_h²·log n) \
         backward when recovery succeeds (recoveries column == heads × iterations, \
         fallbacks 0), vs the exact step's O(n²·d) + O(n²·d_h). Non-zero fallback \
         columns mean this random-weight instance was not conv-structured at this \
         budget and the conv cells are timing the exact fallback plus a failed \
         recovery probe — see the module docs; structured-input kernel speedups are \
         benches/lm_backward.rs's table. tests/train_conv.rs pins the correctness \
         story (single recovery per step, parity, bit-exact fallback)."
    );
}
