//! Decode-step bench: what one generated token costs per (layer, head)
//! under each serving strategy, at n ∈ {256, 1024, 4096}.
//!
//! Four variants per n (structured Q/K so the conv path is exact):
//!   * `conv step`     — `DecodeState::append_token` + `attend_last`
//!                       from a cached basis: `O(k·n + n·d)`, the
//!                       engine's `DecodeOp::Conv` path;
//!   * `exact row`     — `exact_decode_last_row` from the pre-exp
//!                       logits row: `O(n·d)`, the row-stream `DecodeOp::Exact` /
//!                       KV-cache cost (logits-row cost included);
//!   * `conv reprefill`— full `conv_attention_strided` at n+1: what a
//!                       stack without decode state pays per token,
//!                       `O(k·n·d·log n)` recovery + FFT apply;
//!   * `exact reprefill`— full `exact_attention` at n+1: the quadratic
//!                       `O(n²·d)` tax the paper exists to remove.
//!
//! The conv-step timing includes cloning the state each iteration
//! (append mutates it); the clone is `O(k·n)`, the same order as the
//! step itself, so the reported time is a conservative upper bound.
//!
//! Numbers land in EXPERIMENTS.md §PR 2.

use conv_basis::attention::decode::{exact_decode_last_row, DecodeState};
use conv_basis::attention::rope::rope_structured_qk;
use conv_basis::attention::{conv_attention_strided, exact_attention, ExactKernel, Mask};
use conv_basis::tensor::{dot, Matrix, Rng};
use conv_basis::util::{fmt_dur, sink, smoke, time_median, Table};

const D: usize = 16;
const K_BASES: usize = 8;

fn main() {
    println!("# Decode step vs full re-prefill (d={D}, strided k={K_BASES}, structured Q/K)");
    println!("(per (sequence, head); conv step includes the O(k·n) state clone)");
    let mut table = Table::new(&[
        "n",
        "conv step",
        "exact row",
        "conv reprefill",
        "exact reprefill",
        "step ÷ conv-reprefill",
        "step ÷ exact-reprefill",
    ]);
    // `--smoke` (CI): a single tiny n executes all four strategies.
    let ns: &[usize] = if smoke() { &[64] } else { &[256, 1024, 4096] };
    for &n in ns {
        let mut rng = Rng::seeded(n as u64);
        let (q_full, k_full) = rope_structured_qk(n + 1, D, 3, &mut rng);
        let q = q_full.slice(0, n, 0, D);
        let k = k_full.slice(0, n, 0, D);
        let v_full = Matrix::randn(n + 1, D, &mut rng);
        let v = v_full.slice(0, n, 0, D);

        // Prefill once: the cached basis decode grows from.
        let prefill = conv_attention_strided(&q, &k, &v, K_BASES).unwrap();
        let state0 = DecodeState::new(prefill.post_basis, prefill.d_tilde);
        let new_row: Vec<f64> =
            (0..=n).map(|j| dot(q_full.row(n), k_full.row(j))).collect();

        let iters = if n >= 4096 { 3 } else { 7 };

        let t_step = time_median(iters, || {
            let mut s = state0.clone();
            s.append_token(&new_row);
            sink(s.attend_last(&v_full))
        });
        let t_exact_row = time_median(iters, || {
            // A KV-cache stack recomputes the logits row, then the
            // weighted sum.
            let row: Vec<f64> =
                (0..=n).map(|j| dot(q_full.row(n), k_full.row(j))).collect();
            sink(exact_decode_last_row(&row, &v_full))
        });
        let t_conv_reprefill = time_median(iters, || {
            sink(conv_attention_strided(&q_full, &k_full, &v_full, K_BASES).unwrap().y)
        });
        let t_exact_reprefill = time_median(iters.min(3), || {
            sink(exact_attention(&q_full, &k_full, &v_full, &Mask::causal(n + 1)))
        });

        table.row(&[
            n.to_string(),
            fmt_dur(t_step),
            fmt_dur(t_exact_row),
            fmt_dur(t_conv_reprefill),
            fmt_dur(t_exact_reprefill),
            format!("{:.1}×", t_conv_reprefill.as_secs_f64() / t_step.as_secs_f64()),
            format!("{:.1}×", t_exact_reprefill.as_secs_f64() / t_step.as_secs_f64()),
        ]);
    }
    table.print();
    println!(
        "\nshape check: conv step and exact row grow ~linearly in n; the re-prefill \
         columns grow ~n·log n (conv) and ~n² (exact) — the decode path removes the \
         per-token re-prefill tax entirely."
    );
}
