//! L3 serving bench: throughput and latency of the coordinator over a
//! synthetic long-context trace — exact-only routing vs conv routing vs
//! conv+cache (the serving claim: conv-basis widens capacity on long
//! sequences; the basis cache amortizes recovery).

use conv_basis::coordinator::{
    run_trace, BatcherConfig, RouterConfig, Server, ServerConfig,
};
use conv_basis::attention::decode::DecodeState;
use conv_basis::attention::rope::rope_structured_qk;
use conv_basis::data::{WorkloadConfig, WorkloadTrace};
use conv_basis::tensor::{Matrix, Rng};
use conv_basis::util::{fmt_dur, smoke, time_median, Table};
use std::time::Instant;

fn run(label: &str, exact_below: usize, cache_capacity: usize, table: &mut Table) {
    let server = Server::start(ServerConfig {
        router: RouterConfig { exact_below, k_frac: 0.02, k_cap: 16, ..Default::default() },
        batcher: BatcherConfig { max_batch: 8, max_wait: std::time::Duration::from_millis(1) },
        workers: 4,
        cache_capacity,
        lowrank_degree: 2,
        gen: None,
    });
    // `--smoke` (CI): a handful of short requests, same pipeline.
    let (requests, len_buckets) =
        if smoke() { (10, [32, 64, 128, 256]) } else { (120, [256, 512, 1024, 2048]) };
    let trace = WorkloadTrace::generate(
        requests,
        &WorkloadConfig {
            rate_per_s: 1e9, // saturate: measure capacity, not arrival
            len_buckets,
            len_weights: [0.4, 0.3, 0.2, 0.1],
            d_model: 32,
        },
        99,
    );
    let t0 = Instant::now();
    let resps = run_trace(&server, &trace, 0.0);
    let wall = t0.elapsed();
    let metrics = server.shutdown();
    let s = metrics.snapshot();
    table.row(&[
        label.into(),
        format!("{:.1}", resps.len() as f64 / wall.as_secs_f64()),
        format!("{:.0}", s.e2e.p50_us),
        format!("{:.0}", s.e2e.p95_us),
        format!("{:.0}", s.e2e.p99_us),
        format!("{}h/{}m", s.cache_hits, s.cache_misses),
        s.fallbacks.to_string(),
    ]);
}

fn main() {
    println!("# Coordinator throughput — exact-only vs conv routing vs conv+cache");
    println!("(120 requests, buckets 256–2048, d=32, 4 workers, saturating arrivals)");
    let mut table = Table::new(&[
        "config",
        "req/s",
        "p50 µs",
        "p95 µs",
        "p99 µs",
        "cache",
        "fallbacks",
    ]);
    run("exact-only (exact_below=∞)", usize::MAX, 1, &mut table);
    run("conv routing, no cache", 128, 1, &mut table);
    run("conv routing + basis cache", 128, 64, &mut table);
    table.print();
    println!("\nserving shape check: conv routing beats exact-only on this long-context mix; the cache adds another step (recover once, apply many).");

    // Decode path: last-token attention with a cached basis vs the
    // exact full-row recompute — the autoregressive serving hot step.
    println!("\n# Decode (last-token) attention per step");
    println!("(kv-style = recompute only row n−1 exactly, O(nd); cached-basis = O(kn+nd) without touching K)");
    let mut t2 = Table::new(&["n", "full recompute", "kv-style exact row", "cached-basis row", "vs kv-style"]);
    let ns: &[usize] = if smoke() { &[128] } else { &[512, 2048, 8192] };
    for &n in ns {
        let d = 64;
        let mut rng = Rng::seeded(n as u64);
        let (q, k) = rope_structured_qk(n, d, 3, &mut rng);
        let v = Matrix::randn(n, d, &mut rng);
        let out = conv_basis::attention::conv_attention_strided(&q, &k, &v, 1).unwrap();
        let state = DecodeState::new(out.post_basis, out.d_tilde);
        let t_full = time_median(3, || {
            conv_basis::attention::decode::exact_attend_last(&q, &k, &v)
        });
        let t_row = time_median(9, || {
            conv_basis::attention::decode::exact_attend_last_row_only(&q, &k, &v)
        });
        let t_fast = time_median(9, || state.attend_last(&v));
        t2.row(&[
            n.to_string(),
            fmt_dur(t_full),
            fmt_dur(t_row),
            fmt_dur(t_fast),
            format!("{:.2}×", t_row.as_secs_f64() / t_fast.as_secs_f64()),
        ]);
    }
    t2.print();
}
