//! Theorem 6.5 table: masked low-rank multiply `(W ∘ U₁U₂ᵀ)·v` — one
//! row per mask family, dense-oracle baseline vs the fast kernel, across
//! n. Complexities under test: causal O(nk), row-change O(kΣB_j),
//! continuous-row O(nk log n), distinct-r O(rnk).

use conv_basis::attention::Mask;
use conv_basis::lowrank::masked;
use conv_basis::tensor::{Matrix, Rng};
use conv_basis::util::{fmt_dur, smoke, time_median, Table};

fn main() {
    println!("# Theorem 6.5 — masked low-rank attention kernels");
    // `--smoke` (CI) is a stronger `--quick`: one tiny n.
    let quick = smoke() || std::env::args().any(|a| a == "--quick");
    let k = 16;
    let ns: &[usize] = if smoke() {
        &[128]
    } else if quick {
        &[256, 512]
    } else {
        &[256, 512, 1024, 2048, 4096]
    };

    println!("\n## per-mask timing (k = {k}; dense baseline materializes W∘U₁U₂ᵀ)");
    let mut table = Table::new(&["mask", "n", "dense", "fast", "speedup"]);
    for &n in ns {
        let mut rng = Rng::seeded(n as u64);
        let u1 = Matrix::randn(n, k, &mut rng);
        let u2 = Matrix::randn(n, k, &mut rng);
        let v = rng.randn_vec(n);
        let iters = if n <= 1024 { 7 } else { 3 };

        // Causal (Alg 4).
        let causal = Mask::causal(n);
        let t_dense = time_median(iters.min(3), || masked::dense_multiply(&causal, &u1, &u2, &v));
        let t_fast = time_median(iters, || masked::causal_multiply(&u1, &u2, &v));
        table.row(&[
            "causal (Alg 4)".into(),
            n.to_string(),
            fmt_dur(t_dense),
            fmt_dur(t_fast),
            format!("{:.1}×", t_dense.as_secs_f64() / t_fast.as_secs_f64()),
        ]);

        // Row-change (Alg 5) with analytic deltas — sliding window.
        let sw = Mask::sliding_window(n, 64, 4);
        let deltas = masked::analytic_deltas(&sw).unwrap();
        let t_dense = time_median(iters.min(3), || masked::dense_multiply(&sw, &u1, &u2, &v));
        let t_fast =
            time_median(iters, || masked::row_change_multiply_with_deltas(&deltas, &u1, &u2, &v));
        table.row(&[
            "row-change (Alg 5)".into(),
            n.to_string(),
            fmt_dur(t_dense),
            fmt_dur(t_fast),
            format!("{:.1}×", t_dense.as_secs_f64() / t_fast.as_secs_f64()),
        ]);

        // Continuous rows (Alg 6, segment tree).
        let s: Vec<usize> = (0..n).map(|i| i / 2).collect();
        let t: Vec<usize> = (0..n).map(|i| (i / 2 + n / 4).min(n - 1)).collect();
        let cr = Mask::continuous_row(s.clone(), t.clone());
        let t_dense = time_median(iters.min(3), || masked::dense_multiply(&cr, &u1, &u2, &v));
        let t_fast =
            time_median(iters, || masked::continuous_row_multiply_segtree(&u1, &u2, &v, &s, &t));
        table.row(&[
            "continuous (Alg 6)".into(),
            n.to_string(),
            fmt_dur(t_dense),
            fmt_dur(t_fast),
            format!("{:.1}×", t_dense.as_secs_f64() / t_fast.as_secs_f64()),
        ]);

        // Distinct r rows (Lemma D.11), r = 3.
        let r = 3;
        let mut patterns = vec![vec![false; n]; r];
        for j in 0..n {
            patterns[0][j] = j % 2 == 0;
            patterns[1][j] = j < n / 2;
            patterns[2][j] = j % 3 != 0;
        }
        let assign: Vec<usize> = (0..n).map(|i| i % r).collect();
        let dr = Mask::distinct_rows(assign.clone(), patterns.clone());
        let t_dense = time_median(iters.min(3), || masked::dense_multiply(&dr, &u1, &u2, &v));
        let t_fast = time_median(iters, || {
            masked::distinct_rows_multiply(&u1, &u2, &v, &assign, &patterns)
        });
        table.row(&[
            "distinct-3-rows (D.11)".into(),
            n.to_string(),
            fmt_dur(t_dense),
            fmt_dur(t_fast),
            format!("{:.1}×", t_dense.as_secs_f64() / t_fast.as_secs_f64()),
        ]);
    }
    table.print();

    println!("\n## LongLora case (App. A): sliding window, B_j = O(1), O(knd) total");
    let mut t2 = Table::new(&["n", "ΣB_j", "fast time", "time/(k·ΣB_j) ns"]);
    for &n in ns {
        let mut rng = Rng::seeded(5 + n as u64);
        let u1 = Matrix::randn(n, k, &mut rng);
        let u2 = Matrix::randn(n, k, &mut rng);
        let v = rng.randn_vec(n);
        let sw = Mask::sliding_window(n, 64, 4);
        let sum_b: usize = sw.row_change_bounds().iter().sum();
        let deltas = masked::analytic_deltas(&sw).unwrap();
        let t =
            time_median(7, || masked::row_change_multiply_with_deltas(&deltas, &u1, &u2, &v));
        t2.row(&[
            n.to_string(),
            sum_b.to_string(),
            fmt_dur(t),
            format!("{:.2}", t.as_secs_f64() * 1e9 / (k * sum_b) as f64),
        ]);
    }
    t2.print();
    println!("\npaper shape check: every fast kernel beats the dense baseline, gap grows with n; time/(k·ΣB_j) roughly flat.");
}
