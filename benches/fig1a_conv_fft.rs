//! Figure 1a: naive `conv(a)·w` vs FFT, time-per-token and
//! FLOPs-per-token vs n. Reproduces both panels of the paper's
//! Figure 1a (who wins and where the crossover sits).

use conv_basis::conv::{conv_apply, conv_apply_naive};
use conv_basis::fft::{fft_conv_flops, naive_conv_flops, FftPlanner};
use conv_basis::tensor::Rng;
use conv_basis::util::{fmt_dur, smoke, time_median, Table};

fn main() {
    println!("# Figure 1a — conv(a)·w: naive O(n²) vs FFT O(n log n)");
    let mut table = Table::new(&[
        "n",
        "naive/time",
        "fft/time",
        "speedup",
        "naive time/n (µs)",
        "fft time/n (µs)",
        "naive flops/n",
        "fft flops/n",
    ]);
    let mut rng = Rng::seeded(1);
    let mut planner = FftPlanner::new();
    let ns: &[usize] =
        if smoke() { &[128, 256] } else { &[256, 512, 1024, 2048, 4096, 8192, 16384] };
    for &n in ns {
        let a = rng.randn_vec(n);
        let w = rng.randn_vec(n);
        let iters = if n <= 2048 { 21 } else { 7 };
        let t_naive = time_median(iters, || conv_apply_naive(&a, &w));
        let t_fft = time_median(iters, || conv_apply(&mut planner, &a, &w));
        table.row(&[
            n.to_string(),
            fmt_dur(t_naive),
            fmt_dur(t_fft),
            format!("{:.2}×", t_naive.as_secs_f64() / t_fft.as_secs_f64()),
            format!("{:.4}", t_naive.as_secs_f64() * 1e6 / n as f64),
            format!("{:.4}", t_fft.as_secs_f64() * 1e6 / n as f64),
            format!("{:.1}", naive_conv_flops(n) / n as f64),
            format!("{:.1}", fft_conv_flops(n) / n as f64),
        ]);
    }
    table.print();
    println!(
        "\npaper shape check: naive time/n grows ~linearly in n (O(n²) total); \
         fft time/n grows ~log n; fft wins beyond the small-n crossover."
    );
}
