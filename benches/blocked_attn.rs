//! Row-stream vs blocked exact kernels (ISSUE 10): forward, backward
//! and decode, at n ∈ {256, 1024, 4096}.
//!
//! Three lanes per n, each timing the two `ExactKernel` families on
//! identical inputs:
//!
//!   * `fwd`    — serving forward: `exact_attention` (n×n logits
//!                matmul, dense stabilized softmax, n×n probs·V) vs
//!                `blocked_attention_causal` (online-softmax tile walk
//!                over the causal prefix only: no n×n temporaries,
//!                half the logit flops, `BLOCK`-wide inner loops);
//!   * `bwd`    — the engine's LM-backward lane in
//!                `AttnBackwardMode::Exact`, row-stream vs blocked
//!                kernel, consuming the same forward probs (the
//!                blocked backward walks the causal prefix only);
//!   * `decode` — one last-row step on a length-n prefix:
//!                `exact_decode_last_row` vs `blocked_decode_last_row`
//!                (both O(n·d); expected near parity — tracked here so
//!                a regression in the shared tile walk shows up).
//!
//! `tests/blocked_kernels.rs` pins the two families to each other
//! within `blocked_rtol`; this bench only measures. Numbers land in
//! EXPERIMENTS.md §PR 10 (mirrored by `python/bench_blocked_mirror.py`
//! on toolchain-less images).

use conv_basis::attention::batched::{BatchedEngine, EngineConfig, EngineJob};
use conv_basis::attention::blocked::{
    blocked_attention_causal, blocked_decode_last_row, blocked_train_forward, causal_logits_row,
};
use conv_basis::attention::decode::exact_decode_last_row;
use conv_basis::attention::rope::rope_structured_qk;
use conv_basis::attention::{exact_attention, ExactKernel, Mask};
use conv_basis::gradient::batched::{AttnBackwardJob, AttnBackwardMode};
use conv_basis::tensor::{Matrix, Rng};
use conv_basis::util::{fmt_dur, sink, smoke, time_median, Table};
use std::sync::Arc;
use std::time::Duration;

const DH: usize = 8;
/// Decode steps per timed iteration (a single last-row step is too
/// short to time on its own).
const DECODE_STEPS: usize = 64;

fn ratio(rowstream: Duration, blocked: Duration) -> String {
    format!("{:.2}×", rowstream.as_secs_f64() / blocked.as_secs_f64())
}

fn main() {
    let workers = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(2);
    println!("# Exact kernels: row-stream vs blocked (fwd / bwd / decode)");
    println!(
        "(d_h={DH}, {workers} pool workers; both families pinned by \
         tests/blocked_kernels.rs)"
    );
    let mut table = Table::new(&["lane", "n", "row-stream", "blocked", "blocked ×"]);
    // `--smoke` (CI): one tiny n executes all three lanes.
    let ns: &[usize] = if smoke() { &[48] } else { &[256, 1024, 4096] };
    for &n in ns {
        let mut rng = Rng::seeded(n as u64);
        let (q, k) = rope_structured_qk(n, DH, 3, &mut rng);
        let v = Matrix::randn(n, DH, &mut rng);
        let dout = Matrix::randn(n, DH, &mut rng);
        let iters = if n >= 4096 { 3 } else { 7 };

        // Forward lane.
        let mask = Mask::causal(n);
        let t_rs = time_median(iters, || sink(exact_attention(&q, &k, &v, &mask)[(0, 0)]));
        let t_bl = time_median(iters, || sink(blocked_attention_causal(&q, &k, &v)[(0, 0)]));
        table.row(&[
            "fwd".to_string(),
            n.to_string(),
            fmt_dur(t_rs),
            fmt_dur(t_bl),
            ratio(t_rs, t_bl),
        ]);

        // Backward lane: both kernels consume the same forward probs
        // (training keeps these cached, so probs construction is not
        // part of backward cost).
        let (_, probs) = blocked_train_forward(&q, &k, &v);
        let probs = Arc::new(probs);
        let engine = BatchedEngine::new(EngineConfig { workers, cache_capacity: 8 });
        let backward = |kernel: ExactKernel| -> f64 {
            let job = EngineJob::attn_backward(
                0,
                AttnBackwardJob {
                    layer: 0,
                    head: 0,
                    q: q.clone(),
                    k: k.clone(),
                    v: v.clone(),
                    dout: dout.clone(),
                    probs: Some(Arc::clone(&probs)),
                    basis: None,
                    mode: AttnBackwardMode::Exact(kernel),
                },
            );
            let mut outs = engine.submit(vec![job]);
            outs.pop().unwrap().result.into_attn_backward().dq[(0, 0)]
        };
        let t_rs_b = time_median(iters, || sink(backward(ExactKernel::RowStream)));
        let t_bl_b = time_median(iters, || sink(backward(ExactKernel::Blocked)));
        table.row(&[
            "bwd".to_string(),
            n.to_string(),
            fmt_dur(t_rs_b),
            fmt_dur(t_bl_b),
            ratio(t_rs_b, t_bl_b),
        ]);

        // Decode lane: DECODE_STEPS last-row steps on the full prefix.
        let h = causal_logits_row(q.row(n - 1), &k, n);
        let t_rs_d = time_median(iters, || {
            let mut acc = 0.0;
            for _ in 0..DECODE_STEPS {
                acc += exact_decode_last_row(&h, &v)[0];
            }
            sink(acc)
        });
        let t_bl_d = time_median(iters, || {
            let mut acc = 0.0;
            for _ in 0..DECODE_STEPS {
                acc += blocked_decode_last_row(&h, &v)[0];
            }
            sink(acc)
        });
        table.row(&[
            "decode".to_string(),
            n.to_string(),
            fmt_dur(t_rs_d),
            fmt_dur(t_bl_d),
            ratio(t_rs_d, t_bl_d),
        ]);
    }
    table.print();
    println!(
        "\nshape check: row-stream fwd is O(n²·d_h) over ALL n² logits plus a dense \
         n×n probs·V; blocked fwd streams the ~n²/2 causal logits through BLOCK-wide \
         tiles with O(BLOCK + d_h) scratch per row and never materializes probs. \
         bwd: both are O(n²·d_h) flops, but the blocked kernel touches only the \
         causal prefix (half the flops) with the same two-pass row walk. decode is \
         O(n·d_h) either way (decode column = kernel-flavor parity tracking, not a \
         win)."
    );
}
