//! Figure 4 (bench-scale): relative final-layer error and classification
//! accuracy vs the number of conv bases k, on a trained mini-transformer
//! over the synthetic sentiment task. The full-scale run (n = 2048) is
//! `examples/fig4_accuracy_vs_k.rs`; this harness keeps n small so
//! `cargo bench` stays fast while preserving the curve's shape.

use conv_basis::attention::ExactKernel;
use conv_basis::data::{ByteTokenizer, SentimentDataset};
use conv_basis::model::{
    eval_classifier, train_classifier, AttentionBackend, ModelConfig, TrainConfig,
};
use conv_basis::tensor::rel_fro_error;
use conv_basis::util::{smoke, Table};

fn main() {
    println!("# Figure 4 (bench scale) — error and accuracy vs k");
    let seq = 64;
    let mcfg = ModelConfig {
        vocab_size: 260,
        d_model: 32,
        n_heads: 2,
        n_layers: 2,
        d_ff: 64,
        max_seq: seq,
    };
    // `--smoke` (CI): a few steps over a small dataset — enough to
    // execute train + the k sweep end to end.
    let (n_train, n_test, steps) = if smoke() { (24, 8, 8) } else { (160, 50, 150) };
    let ds = SentimentDataset::generate(n_train, n_test, 2024);
    let tcfg =
        TrainConfig { steps, lr: 3e-3, seq_len: seq, batch: 4, log_every: steps, seed: 3 };
    let (model, log) = train_classifier(&mcfg, &tcfg, &ds);
    println!(
        "trained {} params, loss {:.3} → {:.3}",
        model.num_params(),
        log.losses.first().unwrap().1,
        log.losses.last().unwrap().1
    );

    let tok = ByteTokenizer::new();
    // Mean relative error over a sample of test inputs.
    let sample: Vec<Vec<usize>> = ds
        .test
        .iter()
        .take(8)
        .map(|e| tok.encode_for_classification(&e.text, seq))
        .collect();
    let exact_hidden: Vec<_> = sample
        .iter()
        .map(|t| {
            model.forward(t, &AttentionBackend::Exact(ExactKernel::RowStream), false).final_hidden
        })
        .collect();
    let acc_exact =
        eval_classifier(&model, &ds.test, seq, &AttentionBackend::Exact(ExactKernel::RowStream));

    let mut table = Table::new(&["k", "rel ‖Y−Ỹ‖²_F/‖Y‖²_F", "accuracy", "exact acc"]);
    let ks: Vec<usize> = if smoke() { vec![1, 4, seq] } else { vec![1, 2, 4, 8, 16, 32, seq] };
    for k in ks {
        let backend = if k >= seq {
            AttentionBackend::ConvBasis(conv_basis::basis::RecoverConfig::exact(seq))
        } else {
            AttentionBackend::conv_with_k(k, seq)
        };
        let mut err_sum = 0.0;
        for (tokens, exact) in sample.iter().zip(&exact_hidden) {
            let rec = model.forward(tokens, &backend, false);
            err_sum += rel_fro_error(exact, &rec.final_hidden);
        }
        let acc = eval_classifier(&model, &ds.test, seq, &backend);
        table.row(&[
            k.to_string(),
            format!("{:.3e}", err_sum / sample.len() as f64),
            format!("{:.3}", acc),
            format!("{:.3}", acc_exact),
        ]);
    }
    table.print();
    println!("\npaper shape check: error falls monotonically-ish with k; accuracy approaches the exact baseline; k = n is numerically identical (k=2048 in the paper).");
}
