//! Speculative decoding bench: tokens/s, decode-lane sub-steps per
//! token, and draft acceptance rate as the draft depth γ sweeps.
//!
//! Two drafter arms, one verifier (exact, batched on the prefill lane):
//!
//! * **exact drafter** — drafts with the same exact backend the
//!   verifier uses, so every draft verifies (acceptance 1.0). This is
//!   the amortization ceiling: decode sub-steps per token fall toward
//!   (γ+1)/(γ+1) drafts per γ+1 emitted tokens plus the verify submit.
//! * **conv drafter (k=1)** — a deliberately crude single-basis conv
//!   decode path. Acceptance drops below 1, showing the draft/verify
//!   trade the scheduler navigates; the emitted stream is still exact
//!   greedy (the verifier guarantees it — see tests/speculative.rs).
//!
//! γ = 0 rows are the plain non-speculative scheduler for each backend.

use conv_basis::attention::ExactKernel;
use conv_basis::coordinator::{
    AdmissionConfig, GenConfig, GenRequest, GenStatus, Server, ServerConfig,
};
use conv_basis::model::{AttentionBackend, ModelConfig, Transformer};
use conv_basis::tensor::Rng;
use conv_basis::util::{smoke, Table};
use std::sync::Arc;
use std::time::Instant;

#[allow(clippy::too_many_arguments)]
fn run(
    model: &Arc<Transformer>,
    backend: AttentionBackend,
    label: &str,
    gamma: usize,
    n_req: usize,
    prompt_len: usize,
    max_new: usize,
    table: &mut Table,
) {
    let server = Server::start(ServerConfig {
        workers: 2,
        cache_capacity: 256,
        gen: Some(GenConfig {
            model: model.clone(),
            backend,
            max_concurrent: n_req,
            admission: AdmissionConfig::default(),
            speculate: gamma,
        }),
        ..Default::default()
    });
    let t0 = Instant::now();
    for i in 0..n_req {
        let prompt: Vec<usize> =
            (0..prompt_len).map(|j| (i * 31 + j * 7) % 255 + 1).collect();
        server.submit_generate(GenRequest::new(i as u64, prompt, max_new));
    }
    let resps = server.collect_generations(n_req);
    let wall = t0.elapsed().as_secs_f64();
    assert!(resps.iter().all(|r| r.status == GenStatus::Complete));
    let s = server.shutdown().snapshot();
    let per_step = (model.cfg.n_layers * model.cfg.n_heads) as u64;
    let steps = s.decode_steps / per_step;
    let accept = if s.spec_drafted == 0 {
        "—".to_string()
    } else {
        format!("{:.2}", s.spec_accepted as f64 / s.spec_drafted as f64)
    };
    table.row(&[
        label.into(),
        gamma.to_string(),
        format!("{:.1}", s.gen_tokens as f64 / wall),
        format!("{:.2}", steps as f64 / s.gen_tokens as f64),
        accept,
        s.spec_rounds.to_string(),
    ]);
}

fn main() {
    println!("# Speculative decoding — draft-γ sweep (exact batched verify, greedy)");
    let mut rng = Rng::seeded(11);
    let (max_seq, prompt_len, max_new, n_req) =
        if smoke() { (64, 8, 8, 2) } else { (256, 32, 48, 4) };
    println!(
        "({n_req} requests, prompt {prompt_len}, {max_new} new tokens, 2 workers; \
         decode steps/tok counts decode-lane sub-steps only — the verify submit \
         rides the prefill lane)"
    );
    let model = Arc::new(Transformer::new(&ModelConfig::tiny(max_seq), &mut rng));
    let gammas: &[usize] = if smoke() { &[0, 2] } else { &[0, 1, 2, 4, 8] };
    let mut table =
        Table::new(&["drafter", "γ", "tok/s", "decode steps/tok", "accept", "rounds"]);
    for &g in gammas {
        let exact = AttentionBackend::Exact(ExactKernel::RowStream);
        run(&model, exact, "exact", g, n_req, prompt_len, max_new, &mut table);
    }
    for &g in gammas {
        run(
            &model,
            AttentionBackend::ConvStrided(1),
            "conv k=1",
            g,
            n_req,
            prompt_len,
            max_new,
            &mut table,
        );
    }
    table.print();
    println!(
        "\nshape check: exact-drafter acceptance is 1.0 by construction; the conv \
         drafter trades acceptance for cheaper drafts, and γ = 0 is the plain loop."
    );
}
