//! Figure 1b reproduction (with the DESIGN.md substitution): the paper
//! plots one `QKᵀ` of Llama3 on an SST-2 input (n = 47) and observes
//! conv-like structure. Llama3 weights are not available offline, so we
//! show the same phenomenon on two in-repo sources:
//!
//! 1. the paper's own RoPE construction (Appendix B.5) — exactly
//!    Toeplitz, the idealized limit; and
//! 2. the attention logits of a transformer *trained in this repo* on
//!    the repetition-rich synthetic corpus — approximately conv-like,
//!    which is the regime the recovery algorithm targets.
//!
//! For each matrix we report the Toeplitz-ness spread and the exact
//! conv-basis size k, plus a coarse ASCII heatmap.

use conv_basis::attention::rope::{rope_structured_qk, toeplitz_energy_fraction, toeplitzness};
use conv_basis::attention::ExactKernel;
use conv_basis::basis::decompose_exact;
use conv_basis::model::{train_lm, AttentionBackend, ModelConfig, TrainConfig};
use conv_basis::tensor::{Matrix, Rng};

fn heat(m: &Matrix) -> String {
    let chars = [' ', '.', ':', '+', '#', '@'];
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for i in 0..m.rows() {
        for j in 0..=i {
            lo = lo.min(m[(i, j)]);
            hi = hi.max(m[(i, j)]);
        }
    }
    let mut out = String::new();
    for i in 0..m.rows() {
        for j in 0..m.cols() {
            if j > i {
                out.push(' ');
            } else {
                let t = ((m[(i, j)] - lo) / (hi - lo + 1e-12) * 5.0) as usize;
                out.push(chars[t.min(5)]);
            }
        }
        out.push('\n');
    }
    out
}

fn analyze(name: &str, h: &Matrix) {
    let spread = toeplitzness(h);
    let scale = {
        let mut mx = 0.0f64;
        for i in 0..h.rows() {
            for j in 0..=i {
                mx = mx.max(h[(i, j)].abs());
            }
        }
        mx
    };
    let k_exact = decompose_exact(&h.tril(), 1e-9).k();
    let energy = toeplitz_energy_fraction(&h.tril());
    println!("## {name}  (n = {})", h.rows());
    println!(
        "toeplitzness spread = {:.3e} (0 = perfect conv structure), max |entry| = {:.3}",
        spread, scale
    );
    println!(
        "Toeplitz energy fraction = {:.1}% (share of ‖·‖²_F captured by diagonal means); exact conv-basis k = {k_exact}",
        energy * 100.0
    );
    println!("{}", heat(h));
}

fn main() {
    let n = 47; // the paper's SST-2 token count
    println!("# Figure 1b — conv-like structure of QKᵀ\n");

    // Source 1: RoPE construction (Lemma B.25) — ideal structure.
    let mut rng = Rng::seeded(13);
    let (q, k) = rope_structured_qk(n, 64, 4, &mut rng);
    let h1 = q.matmul(&k.transpose());
    analyze("RoPE-structured QKᵀ (App. B.5 construction)", &h1);

    // Source 2: trained-model attention logits (layer 0, head 0).
    let mcfg = ModelConfig {
        vocab_size: 260,
        d_model: 32,
        n_heads: 2,
        n_layers: 2,
        d_ff: 64,
        max_seq: n,
    };
    let tcfg = TrainConfig { steps: 120, lr: 3e-3, seq_len: n, batch: 4, log_every: 60, seed: 9 };
    let (model, log) = train_lm(&mcfg, &tcfg, 12_000);
    println!(
        "trained LM: {} params, loss {:.3} → {:.3}\n",
        model.num_params(),
        log.losses.first().unwrap().1,
        log.losses.last().unwrap().1
    );
    // Extract Q,K of layer 0 head 0 on a corpus prompt.
    let prompt: Vec<usize> = "the model computes the attention matrix in almost"
        .bytes()
        .take(n)
        .map(|b| b as usize)
        .collect();
    let rec = model.forward(&prompt, &AttentionBackend::Exact(ExactKernel::RowStream), true);
    let _ = rec; // activations cached; reconstruct logits via weights:
    let dh = mcfg.d_model / mcfg.n_heads;
    // Recompute embeddings → ln1 → q,k with RoPE, as the model does.
    // (Use the public forward pieces: easiest is to re-run attention
    // internals through exact backend on the hidden states; for the
    // figure we take the first layer's rotated q,k directly.)
    let h2 = {
        // Re-derive via model weights.
        let mut x = Matrix::zeros(prompt.len(), mcfg.d_model);
        for (i, &t) in prompt.iter().enumerate() {
            x.row_mut(i).copy_from_slice(model.embed.row(t));
        }
        // RMSNorm with layer-0 gains.
        let l0 = &model.layers[0];
        let mut ln = x.clone();
        for i in 0..ln.rows() {
            let ms: f64 =
                x.row(i).iter().map(|v| v * v).sum::<f64>() / mcfg.d_model as f64;
            let r = (ms + 1e-6).sqrt();
            for j in 0..mcfg.d_model {
                ln[(i, j)] = x[(i, j)] * l0.ln1_g[j] / r;
            }
        }
        let qm = ln.matmul(&l0.wq);
        let km = ln.matmul(&l0.wk);
        let rope = conv_basis::attention::rope::Rope::new(dh, 10_000.0);
        let mut qh = Matrix::from_fn(prompt.len(), dh, |i, j| qm[(i, j)]);
        let mut kh = Matrix::from_fn(prompt.len(), dh, |i, j| km[(i, j)]);
        for i in 0..prompt.len() {
            rope.rotate_row(qh.row_mut(i), i);
            rope.rotate_row(kh.row_mut(i), i);
        }
        qh.matmul(&kh.transpose()).scale(1.0 / (dh as f64).sqrt())
    };
    analyze("trained-model layer-0 head-0 QKᵀ (synthetic corpus)", &h2);

    println!("reading: the RoPE construction is exactly Toeplitz (k = 1, 100% Toeplitz energy). The trained head is only approximately conv-like — its Toeplitz energy fraction is well above a random matrix's, which is the structure the strided recovery exploits (error shrinking with k, Figure 4).");
}
