//! Quickstart: decompose an attention matrix into its k-conv basis and
//! run Algorithm 1 against the exact oracle.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use conv_basis::prelude::*;

fn main() {
    let n = 512;
    let d = 32;
    let mut rng = Rng::seeded(7);

    // Structured Q, K (paper §B.5 RoPE construction): QKᵀ is exactly
    // Toeplitz, the clean version of the conv-like structure Figure 1b
    // shows in Llama3.
    let (q, k) = rope_structured_qk(n, d, 3, &mut rng);
    let v = Matrix::randn(n, d, &mut rng);

    // Exact attention (Definition 3.3): O(n²d).
    let exact = exact_attention(&q, &k, &v, &Mask::causal(n));

    // Conv-basis attention (Algorithm 1): recover the basis by binary
    // search (Algorithm 2/3), exp-transform it (Lemma B.16), apply via
    // FFT — O(k·n·d·log n).
    let t = 4;
    let cfg = RecoverConfig { k_max: 8, t, delta: 5.0 * t as f64 * 1e-7, eps: 1e-7 };
    let out = conv_attention(&q, &k, &v, &cfg).expect("conv attention");

    println!("n = {n}, d = {d}");
    println!("recovered k      : {}", out.post_basis.k());
    println!("recovery probes  : {} (O(k log n) column probes)", out.stats.columns_probed);
    println!("max |Y − Ỹ|      : {:.3e}", max_abs_diff(&exact, &out.y));
    println!(
        "basis memory     : {} floats (O(kn); dense A would be {} floats)",
        out.post_basis.memory_floats(),
        n * n
    );

    // The basis is reusable: apply it to a new V without re-recovery
    // (the serving layer's cache does exactly this).
    let v2 = Matrix::randn(n, d, &mut rng);
    let mut planner = FftPlanner::new();
    let y2 = conv_basis::attention::apply_cached_basis(
        &mut planner,
        &out.post_basis,
        &out.d_tilde,
        &v2,
    );
    let exact2 = exact_attention(&q, &k, &v2, &Mask::causal(n));
    println!("cached-apply err : {:.3e}", max_abs_diff(&exact2, &y2));
    println!("\nquickstart OK");
}
