//! END-TO-END DRIVER (the DESIGN.md `e2e` experiment): proves all
//! layers compose on a real small workload.
//!
//! Phase 1 — TRAIN: a decoder-only transformer (default ~1.6M params;
//! `--large` switches to the 100M-class `gpt_100m` config with reduced
//! steps — CPU-feasible but slow) on the synthetic corpus for a few
//! hundred steps, logging the loss curve.
//!
//! Phase 2 — SWAP: replace the attention operator with conv-basis
//! attention (no parameter updates — the paper's protocol) and verify
//! the perplexity penalty is negligible at modest k.
//!
//! Phase 3 — SERVE: run a batched request trace through the L3
//! coordinator (router → batcher → workers → basis cache), reporting
//! throughput and latency percentiles.
//!
//! Results are recorded in EXPERIMENTS.md §e2e.

use conv_basis::attention::ExactKernel;
use conv_basis::coordinator::{
    run_trace, BatcherConfig, RouterConfig, Server, ServerConfig,
};
use conv_basis::data::{ByteTokenizer, SyntheticCorpus, WorkloadConfig, WorkloadTrace};
use conv_basis::model::{train_lm, AttentionBackend, ModelConfig, TrainConfig};
use conv_basis::util::Table;
use std::time::Instant;

fn arg<T: std::str::FromStr>(name: &str, default: T) -> T {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let large = std::env::args().any(|a| a == "--large");
    let steps: usize = arg("--steps", if large { 20 } else { 300 });
    let seq: usize = arg("--seq", if large { 256 } else { 128 });

    // ---- Phase 1: train -------------------------------------------------
    let mcfg = if large {
        ModelConfig { max_seq: seq, ..ModelConfig::gpt_100m() }
    } else {
        ModelConfig {
            vocab_size: 260,
            d_model: 128,
            n_heads: 4,
            n_layers: 4,
            d_ff: 512,
            max_seq: seq,
        }
    };
    println!("# e2e — train / swap / serve");
    println!(
        "\n## phase 1: train ({} params, {} steps, seq {seq})",
        mcfg.approx_params(),
        steps
    );
    let tcfg = TrainConfig {
        steps,
        lr: 1e-3,
        seq_len: seq,
        batch: 4,
        log_every: (steps / 10).max(1),
        seed: 1,
    };
    let t0 = Instant::now();
    let (model, log) = train_lm(&mcfg, &tcfg, 200_000);
    println!("wall time: {:.1}s", t0.elapsed().as_secs_f64());
    println!("loss curve (step, mean loss):");
    for (step, loss) in &log.losses {
        println!("  {step:>5}  {loss:.4}");
    }
    let first = log.losses.first().unwrap().1;
    let last = log.losses.last().unwrap().1;
    assert!(last < first, "training failed to reduce loss");
    println!("loss: {first:.3} → {last:.3} ✓");

    // ---- Phase 2: swap attention ----------------------------------------
    println!("\n## phase 2: conv-basis swap (no parameter updates)");
    let tok = ByteTokenizer::new();
    let corpus = SyntheticCorpus::generate(40_000, 999); // held-out seed
    let eval_windows: Vec<_> = corpus.windows(&tok, seq).into_iter().take(8).collect();
    let mean_loss = |backend: &AttentionBackend| -> f64 {
        let mut total = 0.0;
        for (x, y) in &eval_windows {
            let rec = model.forward(x, backend, false);
            total += model.lm_loss(&rec, y, ByteTokenizer::PAD).0;
        }
        total / eval_windows.len() as f64
    };
    let mut table = Table::new(&["backend", "held-out loss", "Δ vs exact"]);
    let exact_loss = mean_loss(&AttentionBackend::Exact(ExactKernel::RowStream));
    table.row(&["exact".into(), format!("{exact_loss:.4}"), "—".into()]);
    for k in [seq / 16, seq / 4, seq] {
        let backend = if k >= seq {
            AttentionBackend::ConvBasis(conv_basis::basis::RecoverConfig::exact(seq))
        } else {
            AttentionBackend::conv_with_k(k.max(1), seq)
        };
        let l = mean_loss(&backend);
        table.row(&[
            format!("conv k={k}"),
            format!("{l:.4}"),
            format!("{:+.4}", l - exact_loss),
        ]);
    }
    table.print();

    // ---- Phase 3: serve --------------------------------------------------
    println!("\n## phase 3: serve a batched trace through the coordinator");
    let n_requests: usize = arg("--requests", 150);
    let server = Server::start(ServerConfig {
        router: RouterConfig { exact_below: 128, k_frac: 0.05, k_cap: 32, ..Default::default() },
        batcher: BatcherConfig { max_batch: 8, max_wait: std::time::Duration::from_millis(2) },
        workers: 4,
        cache_capacity: 64,
        lowrank_degree: 2,
        gen: None,
    });
    let trace = WorkloadTrace::generate(
        n_requests,
        &WorkloadConfig {
            rate_per_s: 2_000.0,
            len_buckets: [128, 256, 512, 1024],
            len_weights: [0.4, 0.3, 0.2, 0.1],
            d_model: 64,
        },
        7,
    );
    let t0 = Instant::now();
    let resps = run_trace(&server, &trace, 1.0);
    let wall = t0.elapsed();
    let metrics = server.shutdown();
    let snap = metrics.snapshot();
    println!("{}", snap.report());
    println!(
        "throughput: {:.1} req/s over {:.2}s wall ({} responses, all finite: {})",
        resps.len() as f64 / wall.as_secs_f64(),
        wall.as_secs_f64(),
        resps.len(),
        resps.iter().all(|r| r.y.is_finite()),
    );
    assert_eq!(resps.len(), n_requests);
    println!("\ne2e OK — all three layers composed (train → swap → serve).");
}
