//! Serving demo that exercises the **PJRT runtime** alongside the
//! native path: loads the AOT artifacts (`make artifacts`), serves a
//! short burst through the coordinator, then cross-checks one response
//! against the artifact execution.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_requests
//! ```

use conv_basis::attention::rope::rope_structured_qk;
use conv_basis::basis::{ConvBasis, KConvBasis};
use conv_basis::coordinator::{
    AttnRequest, BatcherConfig, Payload, RouterConfig, Server, ServerConfig,
};
use conv_basis::runtime::PjrtRuntime;
use conv_basis::tensor::{max_abs_diff, Matrix, Rng};
use std::time::Instant;

const ART_N: usize = 256;
const ART_D: usize = 32;
const ART_K: usize = 4;
const ART_MS: [usize; 4] = [256, 128, 64, 32];

fn main() {
    // --- native serving burst -------------------------------------------
    let server = Server::start(ServerConfig {
        router: RouterConfig { exact_below: 128, ..Default::default() },
        batcher: BatcherConfig::default(),
        workers: 2,
        cache_capacity: 32,
        lowrank_degree: 2,
    });
    let mut rng = Rng::seeded(55);
    let (q, k) = rope_structured_qk(ART_N, ART_D, 3, &mut rng);
    let v = Matrix::randn(ART_N, ART_D, &mut rng);
    for i in 0..8u64 {
        server.submit(AttnRequest {
            id: i,
            seq_len: ART_N,
            d_model: ART_D,
            bounded_entries: false,
            payload: Payload::Explicit { q: q.clone(), k: k.clone(), v: v.clone() },
            submitted_at: Instant::now(),
        });
    }
    let mut resps = server.collect(8);
    resps.sort_by_key(|r| r.id);
    let metrics = server.shutdown();
    println!("native burst: {}", metrics.snapshot().report());
    let native_y = &resps[0].y;
    println!("response basis k = {}", resps[0].basis_k);

    // --- PJRT cross-check --------------------------------------------------
    if !conv_basis::runtime::pjrt_available() {
        println!("built without the `pjrt` feature — skipping the PJRT cross-check");
        return;
    }
    let artifact = std::path::Path::new("artifacts/conv_attention.hlo.txt");
    if !artifact.exists() {
        println!("artifacts not built — run `make artifacts` for the PJRT cross-check");
        return;
    }
    let mut rt = PjrtRuntime::cpu().expect("PJRT CPU client");
    println!("PJRT platform: {}", rt.platform());
    let model = rt.load(artifact).expect("load conv_attention artifact");

    // Recover the basis natively, pack into the artifact's fixed bank.
    let t = 4;
    let cfg = conv_basis::basis::RecoverConfig {
        k_max: ART_K,
        t,
        delta: 5.0 * t as f64 * 1e-7,
        eps: 1e-7,
    };
    let out = conv_basis::attention::conv_attention(&q, &k, &v, &cfg).expect("conv attention");
    let mut bases = Matrix::zeros(ART_K, ART_N);
    for term in out.post_basis.terms() {
        if let Some(slot) = ART_MS.iter().position(|&m| m == term.m) {
            for (j, &x) in term.b.iter().enumerate() {
                bases[(slot, j)] = x;
            }
        }
    }
    // Sanity: the packed bank composes to the same operator.
    let packed = KConvBasis::new(
        ART_N,
        ART_MS
            .iter()
            .enumerate()
            .map(|(r, &m)| ConvBasis { b: bases.row(r).to_vec(), m })
            .collect(),
    );
    assert_eq!(packed.n(), ART_N);

    let y_pjrt = &model
        .run(&[(&bases, (ART_K, ART_N)), (&v, (ART_N, ART_D))], &[(ART_N, ART_D)])
        .expect("execute artifact")[0];
    let err = max_abs_diff(y_pjrt, native_y);
    println!("PJRT vs native coordinator output: max err = {err:.3e} (f32 artifact)");
    assert!(err < 1e-3);
    println!("serve_requests OK");
}
