//! Serving demo, end to end:
//!
//! 1. **Generation requests** (prompt in, tokens out) through the
//!    coordinator's decode scheduler: batched prefill seeds per-head
//!    decode states from the basis cache, then every generated token is
//!    one decode-lane `BatchedEngine::submit` per layer — no per-token
//!    re-prefill. The decode metrics line shows seed hits and drift
//!    re-recoveries.
//! 2. A **native attention burst** through the router/batcher path.
//! 3. The **PJRT cross-check** against the AOT artifacts, when built
//!    with `--features pjrt` (`make artifacts` first).
//!
//! ```bash
//! cargo run --release --example serve_requests
//! ```

use conv_basis::attention::rope::rope_structured_qk;
use conv_basis::basis::{ConvBasis, KConvBasis};
use conv_basis::coordinator::{
    AdmissionConfig, AttnRequest, BatcherConfig, GenConfig, GenRequest, GenSink, Payload,
    RouterConfig, Server, ServerConfig,
};
use conv_basis::data::ByteTokenizer;
use conv_basis::model::{AttentionBackend, ModelConfig, Transformer};
use conv_basis::runtime::PjrtRuntime;
use conv_basis::tensor::{max_abs_diff, Matrix, Rng};
use std::sync::Arc;
use std::time::Instant;

const ART_N: usize = 256;
const ART_D: usize = 32;
const ART_K: usize = 4;
const ART_MS: [usize; 4] = [256, 128, 64, 32];

fn main() {
    // --- generation through the decode path -----------------------------
    let mut rng = Rng::seeded(7);
    let model = Arc::new(Transformer::new(&ModelConfig::tiny(96), &mut rng));
    let gen_server = Server::start(ServerConfig {
        gen: Some(GenConfig {
            model: model.clone(),
            // Conv decode: cached-basis steps, drift-tracked.
            backend: AttentionBackend::ConvStrided(4),
            max_concurrent: 4,
            admission: AdmissionConfig::default(),
            speculate: 0,
        }),
        cache_capacity: 512,
        ..Default::default()
    });
    let tok = ByteTokenizer::new();
    let prompts = ["the conv basis ", "attention is ", "fast decode "];
    // The first prompt streams: its sink fires on every decode step.
    let streamed = Arc::new(std::sync::Mutex::new(Vec::new()));
    let sink_tokens = streamed.clone();
    let sink = GenSink::new(move |ev| {
        if let conv_basis::coordinator::GenEvent::Token { token, .. } = ev {
            sink_tokens.lock().unwrap().push(*token);
        }
    });
    for (i, p) in prompts.iter().enumerate() {
        let mut req = GenRequest::new(i as u64, tok.encode(p), 24);
        if i == 0 {
            req = req.with_stream(sink.clone());
        }
        gen_server.submit_generate(req);
    }
    // Streamed requests answer through their sink, channel ones through
    // collect_generations — so collect only the two unstreamed prompts.
    let mut gens = gen_server.collect_generations(prompts.len() - 1);
    gens.sort_by_key(|g| g.id);
    let streamed = streamed.lock().unwrap();
    // The model is untrained — the continuations are noise; the point
    // is the serving path: prompt in, N tokens out, decode-priced.
    println!(
        "prompt {:?} → {} streamed tokens: {:?}",
        prompts[0],
        streamed.len(),
        tok.decode(&streamed),
    );
    for (p, g) in prompts[1..].iter().zip(&gens) {
        println!(
            "prompt {:?} → {} tokens in {} decode steps: {:?}",
            p,
            g.tokens.len(),
            g.decode_steps,
            tok.decode(&g.tokens),
        );
    }
    let gen_metrics = gen_server.shutdown();
    println!("generation: {}", gen_metrics.snapshot().decode_report());

    // --- native serving burst -------------------------------------------
    let server = Server::start(ServerConfig {
        router: RouterConfig { exact_below: 128, ..Default::default() },
        batcher: BatcherConfig::default(),
        workers: 2,
        cache_capacity: 32,
        lowrank_degree: 2,
        gen: None,
    });
    let mut rng = Rng::seeded(55);
    let (q, k) = rope_structured_qk(ART_N, ART_D, 3, &mut rng);
    let v = Matrix::randn(ART_N, ART_D, &mut rng);
    for i in 0..8u64 {
        server.submit(AttnRequest {
            id: i,
            seq_len: ART_N,
            d_model: ART_D,
            bounded_entries: false,
            backend: None,
            payload: Payload::Explicit { q: q.clone(), k: k.clone(), v: v.clone() },
            submitted_at: Instant::now(),
        });
    }
    let mut resps = server.collect(8);
    resps.sort_by_key(|r| r.id);
    let metrics = server.shutdown();
    println!("native burst: {}", metrics.snapshot().report());
    let native_y = &resps[0].y;
    println!("response basis k = {}", resps[0].basis_k);

    // --- PJRT cross-check --------------------------------------------------
    if !conv_basis::runtime::pjrt_available() {
        println!("built without the `pjrt` feature — skipping the PJRT cross-check");
        return;
    }
    let artifact = std::path::Path::new("artifacts/conv_attention.hlo.txt");
    if !artifact.exists() {
        println!("artifacts not built — run `make artifacts` for the PJRT cross-check");
        return;
    }
    let mut rt = PjrtRuntime::cpu().expect("PJRT CPU client");
    println!("PJRT platform: {}", rt.platform());
    let model = rt.load(artifact).expect("load conv_attention artifact");

    // Recover the basis natively, pack into the artifact's fixed bank.
    let t = 4;
    let cfg = conv_basis::basis::RecoverConfig {
        k_max: ART_K,
        t,
        delta: 5.0 * t as f64 * 1e-7,
        eps: 1e-7,
    };
    let out = conv_basis::attention::conv_attention(&q, &k, &v, &cfg).expect("conv attention");
    let mut bases = Matrix::zeros(ART_K, ART_N);
    for term in out.post_basis.terms() {
        if let Some(slot) = ART_MS.iter().position(|&m| m == term.m) {
            for (j, &x) in term.b.iter().enumerate() {
                bases[(slot, j)] = x;
            }
        }
    }
    // Sanity: the packed bank composes to the same operator.
    let packed = KConvBasis::new(
        ART_N,
        ART_MS
            .iter()
            .enumerate()
            .map(|(r, &m)| ConvBasis { b: bases.row(r).to_vec(), m })
            .collect(),
    );
    assert_eq!(packed.n(), ART_N);

    let y_pjrt = &model
        .run(&[(&bases, (ART_K, ART_N)), (&v, (ART_N, ART_D))], &[(ART_N, ART_D)])
        .expect("execute artifact")[0];
    let err = max_abs_diff(y_pjrt, native_y);
    println!("PJRT vs native coordinator output: max err = {err:.3e} (f32 artifact)");
    assert!(err < 1e-3);
    println!("serve_requests OK");
}
