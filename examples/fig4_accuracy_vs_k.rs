//! Figure 4 reproduction (full protocol, repo-scale substitution):
//!
//! Paper: Llama3-8B-Instruct, IMDB, context 2048, conv attention with
//! varying k; metrics = relative final-layer error ‖Y−Ỹ‖²_F/‖Y‖²_F and
//! classification accuracy over 5 groups × 200 samples.
//!
//! Here: a transformer trained in-repo on the synthetic sentiment task
//! (DESIGN.md substitution log), context `--seq` (default 256; pass
//! `--seq 2048 --groups 5 --per-group 200` for the paper's exact sizes —
//! hours on CPU), conv attention with k ∈ {n/16 … n}; same two metrics,
//! averaged over groups with the paper's 5-group protocol.

use conv_basis::attention::ExactKernel;
use conv_basis::data::{ByteTokenizer, SentimentDataset};
use conv_basis::model::{
    eval_classifier, train_classifier, AttentionBackend, ModelConfig, TrainConfig,
};
use conv_basis::tensor::rel_fro_error;
use conv_basis::util::Table;

fn arg<T: std::str::FromStr>(name: &str, default: T) -> T {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let seq: usize = arg("--seq", 128);
    let groups: usize = arg("--groups", 5);
    let per_group: usize = arg("--per-group", 20);
    let steps: usize = arg("--steps", 400);

    println!("# Figure 4 — relative error and accuracy vs number of conv bases k");
    println!("(context n = {seq}, {groups} groups × {per_group} samples; paper: n = 2048, 5 × 200 on IMDB/Llama3-8B — substitution documented in DESIGN.md; pass --seq 2048 --groups 5 --per-group 200 for paper-scale)\n");

    let mcfg = ModelConfig {
        vocab_size: 260,
        d_model: 64,
        n_heads: 4,
        n_layers: 3,
        d_ff: 128,
        max_seq: seq,
    };
    let n_test = groups * per_group;
    let ds = SentimentDataset::generate(300, n_test, 77);
    let tcfg = TrainConfig { steps, lr: 3e-3, seq_len: seq, batch: 4, log_every: 50, seed: 42 };
    let (model, log) = train_classifier(&mcfg, &tcfg, &ds);
    println!(
        "trained model: {} params; train loss {:.3} → {:.3}",
        model.num_params(),
        log.losses.first().unwrap().1,
        log.losses.last().unwrap().1
    );
    let acc_exact =
        eval_classifier(&model, &ds.test, seq, &AttentionBackend::Exact(ExactKernel::RowStream));
    println!("exact-attention accuracy: {acc_exact:.3}\n");

    let tok = ByteTokenizer::new();
    // Error sample: first example of each group.
    let err_samples: Vec<Vec<usize>> = ds
        .test_groups(groups)
        .iter()
        .map(|g| tok.encode_for_classification(&g[0].text, seq))
        .collect();
    let exact_hidden: Vec<_> = err_samples
        .iter()
        .map(|t| {
            model.forward(t, &AttentionBackend::Exact(ExactKernel::RowStream), false).final_hidden
        })
        .collect();

    let ks: Vec<usize> =
        [seq / 16, seq / 8, seq / 4, seq / 2, seq].iter().cloned().filter(|&k| k >= 1).collect();
    let mut table =
        Table::new(&["k", "rel ‖Y−Ỹ‖²_F/‖Y‖²_F", "acc mean", "acc std", "Δacc vs exact"]);
    for &k in &ks {
        let backend = if k >= seq {
            // k = n reproduces the exact output (the paper's k = 2048
            // baseline point).
            AttentionBackend::ConvBasis(conv_basis::basis::RecoverConfig::exact(seq))
        } else {
            AttentionBackend::conv_with_k(k, seq)
        };
        let mut err_sum = 0.0;
        for (tokens, exact) in err_samples.iter().zip(&exact_hidden) {
            let rec = model.forward(tokens, &backend, false);
            err_sum += rel_fro_error(exact, &rec.final_hidden);
        }
        let rel_err = err_sum / err_samples.len() as f64;
        // Per-group accuracy (the paper's averaging protocol).
        let accs: Vec<f64> = ds
            .test_groups(groups)
            .iter()
            .map(|g| eval_classifier(&model, g, seq, &backend))
            .collect();
        let mean = accs.iter().sum::<f64>() / accs.len() as f64;
        let var =
            accs.iter().map(|a| (a - mean) * (a - mean)).sum::<f64>() / accs.len() as f64;
        table.row(&[
            k.to_string(),
            format!("{:.3e}", rel_err),
            format!("{:.3}", mean),
            format!("{:.3}", var.sqrt()),
            format!("{:+.3}", mean - acc_exact),
        ]);
    }
    table.print();
    println!("\nreading (paper's Figure 4 shape): relative error falls rapidly with k; accuracy reaches the exact baseline well before k = n — the accuracy/efficiency trade-off the paper reports.");
}
