//! Figure 1a reproduction: average time and FLOPs of `conv(a)·w`,
//! naive vs FFT, over 100 runs per n — the paper's exact protocol
//! (theirs used NumPy on CPU; ours is the Rust substrate).

use conv_basis::conv::{conv_apply, conv_apply_naive};
use conv_basis::fft::{fft_conv_flops, naive_conv_flops, FftPlanner};
use conv_basis::tensor::Rng;
use conv_basis::util::Table;
use std::time::Instant;

fn main() {
    println!("# Figure 1a — conv(a)·w, naive vs FFT (100-run averages)");
    let quick = std::env::args().any(|a| a == "--quick");
    let ns: &[usize] = if quick {
        &[128, 256, 512, 1024, 2048]
    } else {
        &[128, 256, 512, 1024, 2048, 4096, 8192, 16384]
    };
    let runs = 100; // the paper's reported averaging
    let mut rng = Rng::seeded(11);
    let mut planner = FftPlanner::new();

    let mut table = Table::new(&[
        "n",
        "naive time/n (µs)",
        "fft time/n (µs)",
        "naive FLOPs/n",
        "fft FLOPs/n",
    ]);
    for &n in ns {
        let a = rng.randn_vec(n);
        let w = rng.randn_vec(n);
        let reps = if n > 4096 { runs / 10 } else { runs };

        let t0 = Instant::now();
        for _ in 0..reps {
            conv_basis::util::sink(conv_apply_naive(&a, &w));
        }
        let naive_avg = t0.elapsed().as_secs_f64() / reps as f64;

        let t1 = Instant::now();
        for _ in 0..runs {
            conv_basis::util::sink(conv_apply(&mut planner, &a, &w));
        }
        let fft_avg = t1.elapsed().as_secs_f64() / runs as f64;

        table.row(&[
            n.to_string(),
            format!("{:.4}", naive_avg * 1e6 / n as f64),
            format!("{:.4}", fft_avg * 1e6 / n as f64),
            format!("{:.1}", naive_conv_flops(n) / n as f64),
            format!("{:.1}", fft_conv_flops(n) / n as f64),
        ]);
    }
    table.print();
    println!(
        "\nreading: time/n and FLOPs/n grow linearly for naive (O(n²) total) and \
         ~logarithmically for FFT (O(n log n)) — the Figure 1a panels."
    );
}
