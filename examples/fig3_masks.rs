//! Figure 3 reproduction: the paper's 16×16 mask gallery — row change
//! by amortized constant (Definition 6.1), continuous row
//! (Definition 6.2), distinct 3 rows (Definition 6.4) — rendered as
//! ASCII (█ = 1, · = 0), plus the quantities Theorem 6.5's complexity
//! claims depend on (ΣB_j, interval widths, r).

use conv_basis::attention::figure3_masks;

fn main() {
    println!("# Figure 3 — mask gallery (16×16; █ = attend, · = masked)\n");
    for (name, mask) in figure3_masks() {
        println!("## {name}");
        print!("{}", mask.render());
        let bounds = mask.row_change_bounds();
        let sum_b: usize = bounds.iter().sum();
        let max_b = bounds.iter().max().copied().unwrap_or(0);
        println!(
            "nnz = {}, ΣB_j = {sum_b}, max B_j = {max_b}, lower-triangular = {}\n",
            mask.nnz(),
            mask.is_lower_triangular(),
        );
    }
    println!(
        "reading: left mask has amortized-constant row change (Theorem 6.5 → O(kd·ΣB_j)); \
         middle is continuous rows (→ segment tree, O(knd log n)); \
         right has 3 distinct row patterns (→ O(rnd))."
    );
}
