//! Concurrency facade — the single import point for lock, channel and
//! thread primitives in every concurrency-bearing module
//! (`runtime/pool`, `coordinator/{server, admission, net, metrics,
//! cache}`, `fft/planner`).
//!
//! Normally these names resolve to `std::sync` / `std::thread`. Under
//! `--cfg loom` they resolve to the `loom` package instead, so the
//! whole library can be model-checked by `tests/loom_models.rs`
//! without any per-module `#[cfg]` noise (the tokio wiring pattern;
//! the offline image resolves `loom` to `rust/loom-stub`, see that
//! crate's docs for what the stub weakens). The repo-invariant lint
//! (`cargo run --bin lint`, rule `sync-facade`) rejects raw
//! `std::sync` / `std::thread` paths in the facade-scoped modules so
//! the migration cannot silently regress.
//!
//! The facade also centralizes the mutex-poisoning policy via
//! [`lock`] / [`wait`]: serving-layer mutexes guard counters,
//! registries and channel receivers whose invariants are
//! per-operation, so a panic in one holder must not cascade into every
//! later request returning `PoisonError` — recover the guard and keep
//! serving. Code that *wants* poisoning to propagate should call
//! `.lock()` directly and justify the `unwrap`/`expect` to the lint.

#[cfg(not(loom))]
pub use std::sync::atomic;
#[cfg(not(loom))]
pub use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard};
#[cfg(not(loom))]
pub use std::thread;

#[cfg(loom)]
pub use loom::sync::atomic;
#[cfg(loom)]
pub use loom::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard};
#[cfg(loom)]
pub use loom::thread;

/// Acquire `m`, recovering the guard if a previous holder panicked
/// (see the module docs for why the serving layer recovers rather
/// than propagates poisoning).
pub fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Block on `cv`, releasing `g` while parked; recovers from poisoning
/// like [`lock`].
pub fn wait<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(|e| e.into_inner())
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::{lock, Arc, Mutex};

    #[test]
    fn lock_recovers_from_poison() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the mutex");
        })
        .join();
        assert!(m.is_poisoned());
        assert_eq!(*lock(&m), 7, "guard recovered with state intact");
    }
}
