//! Algorithm 2 (`Recover`) and Algorithm 3 (binary `Search`).
//!
//! The algorithms never materialize `H̃ = M ∘ (QKᵀ)`; they probe single
//! columns through a [`ColumnOracle`] (`H̃_j = M_j ∘ (Q·(Kᵀ)_j)`,
//! Lemma B.15, `O(nd)` per probe). Total work: `O(k·log n)` probes →
//! `O(k·n·d·log n)` (Lemma B.20's running-time claim).

use super::{ConvBasis, KConvBasis};
use crate::attention::Mask;
use crate::tensor::Matrix;
use std::cell::Cell;

/// Lazy access to columns of `H̃ = M ∘ (QKᵀ)`.
pub trait ColumnOracle {
    /// Sequence length `n`.
    fn n(&self) -> usize;
    /// Column `j` (0-indexed), as a length-n vector with masked entries
    /// zeroed.
    fn column(&self, j: usize) -> Vec<f64>;
}

/// The production oracle: `H̃_j = M_j ∘ (Q · (Kᵀ)_j)` (Lemma B.15).
pub struct QkColumnOracle<'a> {
    q: &'a Matrix,
    k: &'a Matrix,
    mask: &'a Mask,
    probes: Cell<usize>,
}

impl<'a> QkColumnOracle<'a> {
    pub fn new(q: &'a Matrix, k: &'a Matrix, mask: &'a Mask) -> Self {
        assert_eq!(q.rows(), k.rows(), "Q and K must share n");
        assert_eq!(q.cols(), k.cols(), "Q and K must share d");
        assert_eq!(mask.n(), q.rows(), "mask size must equal n");
        QkColumnOracle { q, k, mask, probes: Cell::new(0) }
    }

    /// Number of O(nd) column probes issued (observability).
    pub fn probes(&self) -> usize {
        self.probes.get()
    }
}

impl ColumnOracle for QkColumnOracle<'_> {
    fn n(&self) -> usize {
        self.q.rows()
    }

    fn column(&self, j: usize) -> Vec<f64> {
        self.probes.set(self.probes.get() + 1);
        let kj = self.k.row(j);
        let n = self.n();
        let mut col = vec![0.0; n];
        // §Perf (EXPERIMENTS.md §Perf L3-2): the causal fast path skips
        // the masked prefix entirely (no per-row branch), turning the
        // probe into a contiguous GEMV over rows j..n.
        if matches!(self.mask.kind(), crate::attention::MaskKind::Causal) {
            for (i, slot) in col.iter_mut().enumerate().skip(j) {
                *slot = crate::tensor::dot(self.q.row(i), kj);
            }
        } else {
            for (i, slot) in col.iter_mut().enumerate() {
                // Fused mask+dot: masked entries skip the GEMV row.
                if self.mask.entry(i, j) {
                    *slot = crate::tensor::dot(self.q.row(i), kj);
                }
            }
        }
        col
    }
}

/// Test oracle over a dense, already-masked matrix.
pub struct DenseColumnOracle<'a>(pub &'a Matrix);

impl ColumnOracle for DenseColumnOracle<'_> {
    fn n(&self) -> usize {
        self.0.rows()
    }

    fn column(&self, j: usize) -> Vec<f64> {
        self.0.col(j)
    }
}

/// Hyper-parameters of Algorithms 1–3 (`k, T, δ, ε` in the paper).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RecoverConfig {
    /// Maximum number of bases to recover (`k`).
    pub k_max: usize,
    /// Probe window length (`T`).
    pub t: usize,
    /// Non-degeneracy threshold (`δ`, Definition 4.1).
    pub delta: f64,
    /// Noise level (`ε`, Definition 4.2; requires `ε ≤ δ/(5T)` for the
    /// binary-search separation argument).
    pub eps: f64,
}

impl RecoverConfig {
    /// Exact-recovery configuration (Corollary 4.5: `k=n, T=1, δ=ε=0`).
    /// With `δ = 0` every column qualifies, so every column is peeled
    /// exactly — `O(n²d)` worst case, zero error.
    pub fn exact(n: usize) -> Self {
        RecoverConfig { k_max: n, t: 1, delta: 0.0, eps: 0.0 }
    }

    /// The Definition 4.2 admissibility condition `ε ≤ δ / (5T)`.
    pub fn is_admissible(&self) -> bool {
        self.t >= 1 && self.eps <= self.delta / (5.0 * self.t as f64)
    }

    /// The binary-search acceptance threshold `δ − 2Tε` (Algorithm 3
    /// line 8).
    pub fn threshold(&self) -> f64 {
        self.delta - 2.0 * self.t as f64 * self.eps
    }
}

/// Recovery failure modes.
#[derive(Clone, Debug, PartialEq)]
pub enum RecoverError {
    /// `T` must satisfy `1 ≤ T ≤ n`.
    BadWindow { t: usize, n: usize },
    /// `k_max` must be ≥ 1.
    ZeroK,
    /// `ε > δ/(5T)`: the separation argument of Lemma B.19 fails and the
    /// binary search may mis-locate onsets.
    Inadmissible { delta: f64, eps: f64, t: usize },
}

impl std::fmt::Display for RecoverError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RecoverError::BadWindow { t, n } => {
                write!(f, "window T={t} out of range for n={n}")
            }
            RecoverError::ZeroK => write!(f, "k_max must be at least 1"),
            RecoverError::Inadmissible { delta, eps, t } => write!(
                f,
                "inadmissible config: eps={eps} > delta/(5T) = {}",
                delta / (5.0 * *t as f64)
            ),
        }
    }
}

impl std::error::Error for RecoverError {}

/// Observability counters for a recovery run.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RecoverStats {
    /// Columns probed (each probe is O(nd) through [`QkColumnOracle`]).
    pub columns_probed: usize,
    /// Bases found (`≤ k_max`).
    pub bases_found: usize,
    /// Binary-search iterations across all bases.
    pub search_steps: usize,
}

/// Algorithm 2: recover the (pre-softmax) k-conv basis of `H̃` through a
/// column oracle. Returns the basis (windows strictly decreasing) and
/// run statistics.
pub fn recover_from_oracle<O: ColumnOracle>(
    oracle: &O,
    cfg: &RecoverConfig,
) -> Result<(KConvBasis, RecoverStats), RecoverError> {
    let n = oracle.n();
    if cfg.t < 1 || cfg.t > n {
        return Err(RecoverError::BadWindow { t: cfg.t, n });
    }
    if cfg.k_max == 0 {
        return Err(RecoverError::ZeroK);
    }
    if !cfg.is_admissible() {
        return Err(RecoverError::Inadmissible { delta: cfg.delta, eps: cfg.eps, t: cfg.t });
    }

    let mut stats = RecoverStats::default();
    let threshold = cfg.threshold();
    let t_win = cfg.t;
    let hi = n - t_win; // largest probe-able onset column (0-indexed)

    // α_j = ‖(H̃_j)_{j:j+T−1} − v‖₁ ≥ δ − 2Tε ⇔ a basis onset is at or
    // before column j (Lemma B.19 Part 2).
    let probe = |j: usize, v: &[f64], stats: &mut RecoverStats| -> bool {
        stats.columns_probed += 1;
        let col = oracle.column(j);
        let mut alpha = 0.0;
        for i in 0..t_win {
            alpha += (col[j + i] - v[i]).abs();
        }
        alpha >= threshold
    };

    let mut v = vec![0.0; t_win]; // Σ (b'_r)_{1:T}
    let mut u = vec![0.0; n]; // Σ b'_r
    let mut terms: Vec<ConvBasis> = Vec::new();
    let mut lo = 0usize;

    while terms.len() < cfg.k_max && lo <= hi {
        // Algorithm 3: binary search for the smallest qualifying column.
        let (mut a, mut b) = (lo, hi);
        while a < b {
            stats.search_steps += 1;
            let mid = (a + b) / 2;
            if probe(mid, &v, &mut stats) {
                b = mid;
            } else {
                a = mid + 1;
            }
        }
        if !probe(a, &v, &mut stats) {
            break; // no further basis (Theorem 4.3 flexibility: fewer than k_max)
        }
        let s = a;
        let m = n - s;
        // Algorithm 2 lines 7–8: peel the basis vector off column s.
        let col = oracle.column(s);
        stats.columns_probed += 1;
        let mut bvec = vec![0.0; n];
        for i in 0..m {
            bvec[i] = col[s + i] - u[i];
        }
        for i in 0..t_win {
            v[i] += bvec[i];
        }
        for (ui, bi) in u.iter_mut().zip(&bvec) {
            *ui += bi;
        }
        terms.push(ConvBasis { b: bvec, m });
        stats.bases_found += 1;
        lo = s + 1;
    }

    Ok((KConvBasis::new(n, terms), stats))
}


/// Non-adaptive **strided** recovery: peel the basis at `k` uniformly
/// spaced onset columns `j_r = ⌊r·n/k⌋` (windows `m_r = n − j_r`).
///
/// Theorem 4.3 guarantees *some* `(k, T, δ, ε)` makes the adaptive
/// search exact, but real attention matrices are only approximately
/// conv-structured and give no usable δ-gap; the paper's Section 7
/// protocol ("incrementally increase the number of conv basis k",
/// k = n reproducing the exact output) corresponds to this uniform
/// schedule. Cost: `k` column probes, `O(k·n·d)` — no binary search.
pub fn recover_strided<O: ColumnOracle>(oracle: &O, k: usize) -> (KConvBasis, RecoverStats) {
    let n = oracle.n();
    let k = k.clamp(1, n);
    let mut stats = RecoverStats::default();
    let mut u = vec![0.0; n];
    let mut terms: Vec<ConvBasis> = Vec::with_capacity(k);
    let mut prev_onset = usize::MAX;
    for r in 0..k {
        let s = r * n / k;
        if s == prev_onset {
            continue; // duplicate onset when k ∤ n
        }
        prev_onset = s;
        let col = oracle.column(s);
        stats.columns_probed += 1;
        let m = n - s;
        let mut b = vec![0.0; n];
        let mut nonzero = false;
        for i in 0..m {
            b[i] = col[s + i] - u[i];
            nonzero |= b[i] != 0.0;
        }
        for (ui, bi) in u.iter_mut().zip(&b) {
            *ui += bi;
        }
        if nonzero || r == 0 {
            terms.push(ConvBasis { b, m });
            stats.bases_found += 1;
        }
    }
    (KConvBasis::new(n, terms), stats)
}

/// Convenience wrapper: recover from `Q`, `K` and a mask.
pub fn recover(
    q: &Matrix,
    k: &Matrix,
    mask: &Mask,
    cfg: &RecoverConfig,
) -> Result<(KConvBasis, RecoverStats), RecoverError> {
    let oracle = QkColumnOracle::new(q, k, mask);
    recover_from_oracle(&oracle, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{max_abs_diff, Rng};

    /// Build a non-degenerate basis: each b has |b[0..T]| entries ≥ δ of
    /// one sign, so partial sums can't cancel (Definition 4.1).
    fn nondegenerate_basis(n: usize, ms: &[usize], t: usize, rng: &mut Rng) -> KConvBasis {
        let terms = ms
            .iter()
            .map(|&m| {
                let mut b = rng.randn_vec(n);
                for x in b.iter_mut().take(t) {
                    *x = 1.0 + rng.uniform(); // all positive in the window
                }
                for x in b.iter_mut().skip(m) {
                    *x = 0.0;
                }
                ConvBasis { b, m }
            })
            .collect();
        KConvBasis::new(n, terms)
    }

    #[test]
    fn recovers_clean_basis_exactly() {
        let mut rng = Rng::seeded(81);
        let n = 48;
        let ms = [48usize, 30, 12, 5];
        let t = 4;
        let basis = nondegenerate_basis(n, &ms, t, &mut rng);
        let h = basis.to_dense();
        let oracle = DenseColumnOracle(&h);
        let cfg = RecoverConfig { k_max: 8, t, delta: 0.5, eps: 1e-9 };
        let (rec, stats) = recover_from_oracle(&oracle, &cfg).unwrap();
        assert_eq!(rec.k(), 4);
        assert_eq!(stats.bases_found, 4);
        let ms_rec: Vec<usize> = rec.terms().iter().map(|x| x.m).collect();
        assert_eq!(ms_rec, ms.to_vec());
        assert!(max_abs_diff(&rec.to_dense(), &h) < 1e-9);
    }

    #[test]
    fn recovery_is_sublinear_in_probes() {
        let mut rng = Rng::seeded(82);
        let n = 512;
        let ms = [512usize, 200, 77];
        let t = 4;
        let basis = nondegenerate_basis(n, &ms, t, &mut rng);
        let h = basis.to_dense();
        let oracle = DenseColumnOracle(&h);
        let cfg = RecoverConfig { k_max: 4, t, delta: 0.5, eps: 1e-9 };
        let (rec, stats) = recover_from_oracle(&oracle, &cfg).unwrap();
        assert_eq!(rec.k(), 3);
        // O(k log n) probes, not O(n): generous bound 4·k·(log2 n + 2).
        let bound = 4 * 4 * ((n as f64).log2() as usize + 2);
        assert!(
            stats.columns_probed < bound,
            "probed {} ≥ bound {}",
            stats.columns_probed,
            bound
        );
    }

    #[test]
    fn tolerates_bounded_noise() {
        // Lemma B.19 parts 3–4: with ‖R‖∞ ≤ ε, recovered partial sums are
        // within Tε (window) / ε (pointwise).
        let mut rng = Rng::seeded(83);
        let n = 64;
        let t = 4;
        let ms = [64usize, 40, 13];
        let basis = nondegenerate_basis(n, &ms, t, &mut rng);
        let mut h = basis.to_dense();
        let eps = 1e-3;
        // Add lower-triangular noise bounded by eps.
        for i in 0..n {
            for j in 0..=i {
                h[(i, j)] += (rng.uniform() * 2.0 - 1.0) * eps;
            }
        }
        let oracle = DenseColumnOracle(&h);
        let delta = 1.0;
        let cfg = RecoverConfig { k_max: 4, t, delta, eps };
        assert!(cfg.is_admissible());
        let (rec, _) = recover_from_oracle(&oracle, &cfg).unwrap();
        assert_eq!(rec.k(), 3);
        let ms_rec: Vec<usize> = rec.terms().iter().map(|x| x.m).collect();
        assert_eq!(ms_rec, ms.to_vec());
        // Part 4 invariant: |Σ b'_r − Σ b_r| ≤ ε pointwise, so the
        // composed matrices differ by ≤ 2ε (H̃ vs H ≤ ε, H̃ vs H' ≤ ε).
        assert!(max_abs_diff(&rec.to_dense(), &basis.to_dense()) <= 2.0 * eps + 1e-12);
    }

    #[test]
    fn stops_when_no_more_bases() {
        let mut rng = Rng::seeded(84);
        let n = 32;
        let t = 2;
        let basis = nondegenerate_basis(n, &[32], t, &mut rng);
        let h = basis.to_dense();
        let oracle = DenseColumnOracle(&h);
        let cfg = RecoverConfig { k_max: 10, t, delta: 0.5, eps: 0.0 };
        let (rec, _) = recover_from_oracle(&oracle, &cfg).unwrap();
        assert_eq!(rec.k(), 1);
    }

    #[test]
    fn zero_matrix_recovers_empty() {
        let h = Matrix::zeros(16, 16);
        let oracle = DenseColumnOracle(&h);
        let cfg = RecoverConfig { k_max: 4, t: 2, delta: 0.5, eps: 0.0 };
        let (rec, _) = recover_from_oracle(&oracle, &cfg).unwrap();
        assert_eq!(rec.k(), 0);
    }

    #[test]
    fn config_validation() {
        let h = Matrix::zeros(8, 8);
        let oracle = DenseColumnOracle(&h);
        let bad_t = RecoverConfig { k_max: 1, t: 0, delta: 1.0, eps: 0.0 };
        assert!(matches!(
            recover_from_oracle(&oracle, &bad_t),
            Err(RecoverError::BadWindow { .. })
        ));
        let bad_k = RecoverConfig { k_max: 0, t: 1, delta: 1.0, eps: 0.0 };
        assert!(matches!(recover_from_oracle(&oracle, &bad_k), Err(RecoverError::ZeroK)));
        let bad_eps = RecoverConfig { k_max: 1, t: 2, delta: 1.0, eps: 0.5 };
        assert!(matches!(
            recover_from_oracle(&oracle, &bad_eps),
            Err(RecoverError::Inadmissible { .. })
        ));
    }

    #[test]
    fn qk_oracle_matches_dense() {
        let mut rng = Rng::seeded(85);
        let n = 20;
        let d = 6;
        let q = Matrix::randn(n, d, &mut rng);
        let k = Matrix::randn(n, d, &mut rng);
        let mask = Mask::causal(n);
        let dense = mask.apply(&q.matmul(&k.transpose()));
        let oracle = QkColumnOracle::new(&q, &k, &mask);
        for j in [0usize, 5, 19] {
            let col = oracle.column(j);
            for i in 0..n {
                assert!((col[i] - dense[(i, j)]).abs() < 1e-10);
            }
        }
        assert_eq!(oracle.probes(), 3);
    }

    #[test]
    fn exact_config_recovers_any_lower_triangular() {
        // Corollary 4.5: k=n, T=1, δ→0, ε=0 recovers exactly.
        let mut rng = Rng::seeded(86);
        let n = 24;
        let h = Matrix::randn(n, n, &mut rng).tril();
        let oracle = DenseColumnOracle(&h);
        let cfg = RecoverConfig::exact(n);
        let (rec, _) = recover_from_oracle(&oracle, &cfg).unwrap();
        assert!(max_abs_diff(&rec.to_dense(), &h) < 1e-9);
    }
}
