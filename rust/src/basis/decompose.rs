//! Exact k-conv decomposition — the constructive proof of Lemma 3.12.
//!
//! Peel columns left to right: at column `j` (0-indexed) the residual
//! (after subtracting the already-extracted bases) restricted to rows
//! `j..n` is either zero — column `j` follows the diagonal pattern set by
//! earlier columns, no new basis — or non-zero, in which case it *is* the
//! next basis vector, with window `m = n − j`. The number of non-zero
//! residual columns is exactly the paper's unique `k`.

use super::{ConvBasis, KConvBasis};
use crate::tensor::Matrix;

/// Decompose a lower-triangular matrix into its exact k-conv basis.
///
/// `tol` treats |residual| ≤ tol as zero (pass `0.0` for the literal
/// lemma; floating-point inputs want something like `1e-12`).
///
/// Panics if `h` is not square. Upper-triangular entries are ignored
/// (the decomposition only represents the lower triangle — callers
/// should pass a lower-triangular matrix; `debug_assert`ed).
pub fn decompose_exact(h: &Matrix, tol: f64) -> KConvBasis {
    let n = h.rows();
    assert_eq!(h.cols(), n, "decompose_exact requires a square matrix");
    #[cfg(debug_assertions)]
    for i in 0..n {
        for j in i + 1..n {
            debug_assert!(
                h[(i, j)].abs() <= tol.max(0.0),
                "decompose_exact expects a lower-triangular matrix"
            );
        }
    }

    let mut terms: Vec<ConvBasis> = Vec::new();
    // cum[t] = Σ over extracted bases of b[t] — the value the existing
    // bases predict for diagonal offset t at the current column.
    let mut cum = vec![0.0; n];
    for j in 0..n {
        // Residual of column j, rows j..n, against the prediction.
        let mut best: f64 = 0.0;
        for i in j..n {
            best = best.max((h[(i, j)] - cum[i - j]).abs());
        }
        if best <= tol {
            continue;
        }
        let mut b = vec![0.0; n];
        let m = n - j;
        for i in j..n {
            b[i - j] = h[(i, j)] - cum[i - j];
        }
        for t in 0..m {
            cum[t] += b[t];
        }
        terms.push(ConvBasis { b, m });
    }
    KConvBasis::new(n, terms)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{max_abs_diff, Rng};

    #[test]
    fn roundtrip_random_basis() {
        let mut rng = Rng::seeded(71);
        let n = 24;
        let ms = [24usize, 15, 8, 3];
        let terms: Vec<ConvBasis> = ms
            .iter()
            .map(|&m| {
                let mut b = rng.randn_vec(n);
                // Zero the ignored tail so equality is exact.
                for t in b.iter_mut().skip(m) {
                    *t = 0.0;
                }
                ConvBasis { b, m }
            })
            .collect();
        let basis = KConvBasis::new(n, terms);
        let h = basis.to_dense();
        let rec = decompose_exact(&h, 1e-10);
        assert_eq!(rec.k(), 4, "minimal k recovered");
        assert!(max_abs_diff(&rec.to_dense(), &h) < 1e-9);
        // And the windows match.
        let ms_rec: Vec<usize> = rec.terms().iter().map(|t| t.m).collect();
        assert_eq!(ms_rec, ms.to_vec());
    }

    #[test]
    fn pure_conv_matrix_is_1_conv() {
        let mut rng = Rng::seeded(72);
        let n = 16;
        let a = rng.randn_vec(n);
        let h = crate::conv::ConvMatrix::new(a).to_dense();
        let rec = decompose_exact(&h, 1e-12);
        assert_eq!(rec.k(), 1);
    }

    #[test]
    fn all_ones_lower_triangular_is_1_conv() {
        // The footnote-1 example: all-ones lower triangle has k = 1.
        let n = 12;
        let h = Matrix::ones(n, n).tril();
        let rec = decompose_exact(&h, 0.0);
        assert_eq!(rec.k(), 1);
        assert!(max_abs_diff(&rec.to_dense(), &h) < 1e-12);
    }

    #[test]
    fn generic_lower_triangular_is_n_conv() {
        // A generic lower-triangular matrix needs k = n.
        let mut rng = Rng::seeded(73);
        let n = 10;
        let h = Matrix::randn(n, n, &mut rng).tril();
        let rec = decompose_exact(&h, 1e-12);
        assert_eq!(rec.k(), n);
        assert!(max_abs_diff(&rec.to_dense(), &h) < 1e-9);
    }

    #[test]
    fn zero_matrix_is_0_conv() {
        // (Lemma 3.12 excludes the zero matrix; we return k = 0.)
        let rec = decompose_exact(&Matrix::zeros(5, 5), 0.0);
        assert_eq!(rec.k(), 0);
    }

    #[test]
    fn k_is_minimal_for_figure2_structure() {
        // Figure 2: 3 bases with onsets at columns 0, 2, 4 of a 6×6.
        let n = 6;
        let t1 = ConvBasis { b: vec![1.0, 1.0, 1.0, 1.0, 1.0, 1.0], m: 6 };
        let t2 = ConvBasis { b: vec![2.0, 2.0, 2.0, 2.0, 0.0, 0.0], m: 4 };
        let t3 = ConvBasis { b: vec![3.0, 3.0, 0.0, 0.0, 0.0, 0.0], m: 2 };
        let h = KConvBasis::new(n, vec![t1, t2, t3]).to_dense();
        let rec = decompose_exact(&h, 0.0);
        assert_eq!(rec.k(), 3);
    }
}
