//! The k-conv basis system (§3.2, §4, Appendix B).
//!
//! * [`KConvBasis`] — `H = Σ_{r∈[k]} conv(b_r, m_r)` with
//!   `n ≥ m_1 > m_2 > … > m_k ≥ 1` (Definition 3.11).
//! * [`decompose_exact`] — the constructive proof of Lemma 3.12: any
//!   non-zero lower-triangular matrix has a unique k-conv basis.
//! * [`exp_transform`] — Lemma B.16: turn the pre-softmax basis of
//!   `H = M ∘ (QKᵀ)` into the post-`exp` basis of `M ∘ exp(QKᵀ)` via
//!   the telescoping identity.
//! * [`recover`] (in [`recover`](self::recover)) — Algorithm 2 + the
//!   binary search of Algorithm 3.

mod decompose;
mod recover_impl;

pub use decompose::decompose_exact;
pub use recover_impl::{
    recover, recover_from_oracle, recover_strided, ColumnOracle, DenseColumnOracle,
    QkColumnOracle, RecoverConfig, RecoverError, RecoverStats,
};

use crate::conv::{sub_conv_apply_into, sub_conv_transpose_apply_into};
use crate::fft::FftPlanner;
use crate::tensor::{exp_vec, sub_vec, Matrix};

/// One basis element: the pair `(b, m)` defining `conv(b, m)`.
#[derive(Clone, Debug, PartialEq)]
pub struct ConvBasis {
    /// Defining vector `b ∈ Rⁿ` (entries beyond `m` are ignored by the
    /// sub-convolution but kept so bases compose with plain vector adds).
    pub b: Vec<f64>,
    /// Window size `m ∈ [1, n]`.
    pub m: usize,
}

/// A k-conv basis: `Σ_r conv(b_r, m_r)` with strictly decreasing `m_r`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct KConvBasis {
    terms: Vec<ConvBasis>,
    n: usize,
}

impl KConvBasis {
    /// Build from terms; validates Definition 3.11's ordering constraint
    /// `n ≥ m_1 > m_2 > … > m_k ≥ 1`.
    pub fn new(n: usize, terms: Vec<ConvBasis>) -> Self {
        for t in &terms {
            assert_eq!(t.b.len(), n, "basis vector length must equal n");
            assert!(t.m >= 1 && t.m <= n, "m out of range");
        }
        for w in terms.windows(2) {
            assert!(w[0].m > w[1].m, "window sizes must be strictly decreasing");
        }
        KConvBasis { terms, n }
    }

    pub fn empty(n: usize) -> Self {
        KConvBasis { terms: Vec::new(), n }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of basis elements `k`.
    #[inline]
    pub fn k(&self) -> usize {
        self.terms.len()
    }

    #[inline]
    pub fn terms(&self) -> &[ConvBasis] {
        &self.terms
    }

    /// Memory footprint in floats — the Appendix A claim (`O(kn)`).
    pub fn memory_floats(&self) -> usize {
        self.terms.iter().map(|t| t.b.len()).sum()
    }

    /// Entry `(i, j)` of the composed matrix (0-indexed; oracle use).
    pub fn entry(&self, i: usize, j: usize) -> f64 {
        if i < j {
            return 0.0;
        }
        let n = self.n;
        let mut s = 0.0;
        for t in &self.terms {
            if j >= n - t.m {
                s += t.b[i - j];
            } else {
                // Terms are sorted by decreasing m: once one misses, all
                // later (smaller-m) terms miss too.
                break;
            }
        }
        s
    }

    /// Dense composition (tests/oracles only — O(n²)).
    pub fn to_dense(&self) -> Matrix {
        Matrix::from_fn(self.n, self.n, |i, j| self.entry(i, j))
    }

    /// `(Σ_r conv(b_r, m_r)) · x` via FFT — `O(k n log n)` (Claim 3.10).
    pub fn apply(&self, planner: &mut FftPlanner, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.n);
        let mut out = vec![0.0; self.n];
        for t in &self.terms {
            sub_conv_apply_into(planner, &t.b, t.m, x, &mut out);
        }
        out
    }

    /// `(Σ_r conv(b_r, m_r))ᵀ · x` via FFT — the **transpose** apply,
    /// same `O(k n log n)` cost and plan lengths as [`Self::apply`] (a
    /// transposed sub-convolution is a reversed-window correlation; see
    /// [`sub_conv_transpose_apply_into`]). This is what keeps the LM
    /// attention backward almost-linear: `dV = fᵀ·dout` and the `dK`
    /// chain apply the transposed operator through the same basis.
    pub fn apply_transpose(&self, planner: &mut FftPlanner, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.n);
        let mut out = vec![0.0; self.n];
        for t in &self.terms {
            sub_conv_transpose_apply_into(planner, &t.b, t.m, x, &mut out);
        }
        out
    }

    /// Row sums `(Σ_r conv(b_r, m_r)) · 1_n` in closed form: row `n−m+i`
    /// of `conv(b, m)·1` is the prefix sum `Σ_{j ≤ i} b_j`.
    ///
    /// `O(k n)` — strictly cheaper than the FFT route Algorithm 1 line 3
    /// describes; used for the normalizer `D̃`. (§Perf: “rowsums via
    /// prefix sums”.)
    pub fn row_sums(&self) -> Vec<f64> {
        let n = self.n;
        let mut out = vec![0.0; n];
        for t in &self.terms {
            let off = n - t.m;
            let mut prefix = 0.0;
            for i in 0..t.m {
                prefix += t.b[i];
                out[off + i] += prefix;
            }
        }
        out
    }

    /// Apply to each column of a matrix: `(Σ_r conv(b_r,m_r)) · V`,
    /// `O(k·d·n log n)` — the Algorithm 1 line 4 workhorse.
    ///
    /// §Perf (EXPERIMENTS.md §Perf L3-1): per basis term the kernel
    /// spectrum is transformed **once** ([`KernelSpectrum`]) and two
    /// real columns of V share each complex transform, cutting the
    /// transform count per basis from `2d` to `d + 1` vs the naive
    /// per-column `linear_convolution` loop (kept as
    /// [`Self::apply_matrix_percolumn`] for the ablation bench).
    pub fn apply_matrix(&self, planner: &mut FftPlanner, v: &Matrix) -> Matrix {
        assert_eq!(v.rows(), self.n);
        let n = self.n;
        let d = v.cols();
        let mut out = Matrix::zeros(n, d);
        // Column cache: extracting columns once, not per basis.
        let cols: Vec<Vec<f64>> = (0..d).map(|j| v.col(j)).collect();
        let mut ycol = vec![vec![0.0; n]; d];
        let mut scratch: Vec<crate::fft::Complex> = Vec::new();
        let mut y1 = vec![0.0; n];
        let mut y2 = vec![0.0; n];
        for t in &self.terms {
            let m = t.m;
            let off = n - m;
            let spec = crate::fft::KernelSpectrum::new(planner, &t.b[..m], m);
            scratch.resize(spec.fft_len(), crate::fft::Complex::zero());
            let mut j = 0;
            while j + 1 < d {
                spec.conv_pair_into(
                    &cols[j][off..],
                    &cols[j + 1][off..],
                    &mut scratch[..spec.fft_len()],
                    &mut y1[..m],
                    &mut y2[..m],
                );
                for i in 0..m {
                    ycol[j][off + i] += y1[i];
                    ycol[j + 1][off + i] += y2[i];
                }
                j += 2;
            }
            if j < d {
                let y = spec.conv_one(&cols[j][off..], m);
                for i in 0..m {
                    ycol[j][off + i] += y[i];
                }
            }
        }
        for (j, y) in ycol.iter().enumerate() {
            out.set_col(j, y);
        }
        out
    }

    /// Pre-§Perf per-column apply (ablation baseline; see
    /// `benches/ablations.rs` §6).
    pub fn apply_matrix_percolumn(&self, planner: &mut FftPlanner, v: &Matrix) -> Matrix {
        assert_eq!(v.rows(), self.n);
        let d = v.cols();
        let mut out = Matrix::zeros(self.n, d);
        for j in 0..d {
            let col = v.col(j);
            let y = self.apply(planner, &col);
            out.set_col(j, &y);
        }
        out
    }
}

/// Lemma B.16 (+ the `m₁ = n` completion): convert the k-conv basis of
/// the **pre-softmax** matrix `H = M ∘ (QKᵀ)` into a basis of
/// `M ∘ exp(H)`.
///
/// `b̃_1 = exp(b_1)` and `b̃_r = exp(Σ_{l≤r} b_l) − exp(Σ_{l≤r−1} b_l)`
/// for `r ≥ 2` — a telescoping sum, so positions covered by bases
/// `1..ℓ` get exactly `exp(H_{ij})`.
///
/// The lemma implicitly assumes `m₁ = n` (every masked position is
/// covered by the first basis). When the recovered basis has `m₁ < n`
/// the uncovered positions of `M ∘ exp(H)` equal `exp(0) = 1`, so we
/// *complete* the basis with a prepended zero term of window `n`, whose
/// transformed vector is `exp(0)·1 = 1_n`. Pass `complete = false` to get
/// the literal lemma statement.
pub fn exp_transform(basis: &KConvBasis, complete: bool) -> KConvBasis {
    let n = basis.n();
    let mut pre: Vec<ConvBasis> = Vec::with_capacity(basis.k() + 1);
    if complete && basis.terms().first().map(|t| t.m < n).unwrap_or(true) {
        pre.push(ConvBasis { b: vec![0.0; n], m: n });
    }
    pre.extend(basis.terms().iter().cloned());

    let mut out = Vec::with_capacity(pre.len());
    let mut cum = vec![0.0; n];
    for (r, t) in pre.iter().enumerate() {
        let prev_exp = if r == 0 { None } else { Some(exp_vec(&cum)) };
        for (c, b) in cum.iter_mut().zip(&t.b) {
            *c += b;
        }
        let cur_exp = exp_vec(&cum);
        let b_tilde = match prev_exp {
            None => cur_exp, // b̃₁ = exp(b₁)
            Some(prev) => sub_vec(&cur_exp, &prev),
        };
        out.push(ConvBasis { b: b_tilde, m: t.m });
    }
    KConvBasis::new(n, out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::Mask;
    use crate::tensor::{max_abs_diff, Rng};

    fn random_basis(n: usize, ms: &[usize], rng: &mut Rng) -> KConvBasis {
        let terms = ms
            .iter()
            .map(|&m| ConvBasis { b: rng.randn_vec(n), m })
            .collect();
        KConvBasis::new(n, terms)
    }

    #[test]
    fn entry_matches_dense() {
        let mut rng = Rng::seeded(61);
        let basis = random_basis(16, &[16, 9, 3], &mut rng);
        let d = basis.to_dense();
        for i in 0..16 {
            for j in 0..16 {
                assert_eq!(basis.entry(i, j), d[(i, j)]);
            }
        }
    }

    #[test]
    fn figure_2_three_conv_composition() {
        // The Figure 2 structure: red = basis 1 everywhere it reaches,
        // purple = basis 1 + basis 2, dark green = all three.
        let n = 6;
        let b1 = ConvBasis { b: vec![1.0; n], m: 6 }; // red
        let b2 = ConvBasis { b: vec![10.0; n], m: 4 }; // blue
        let b3 = ConvBasis { b: vec![100.0; n], m: 2 }; // green
        let h = KConvBasis::new(n, vec![b1, b2, b3]).to_dense();
        assert_eq!(h[(0, 0)], 1.0); // red-only region (cols 0..2)
        assert_eq!(h[(3, 2)], 11.0); // red+blue region (cols 2..4)
        assert_eq!(h[(5, 4)], 111.0); // all three (cols 4..)
        assert_eq!(h[(0, 5)], 0.0); // upper triangle
    }

    #[test]
    fn apply_matches_dense_matvec() {
        let mut p = FftPlanner::new();
        let mut rng = Rng::seeded(62);
        let basis = random_basis(31, &[31, 17, 5, 2], &mut rng);
        let x = rng.randn_vec(31);
        let fast = basis.apply(&mut p, &x);
        let dense = basis.to_dense().matvec(&x);
        for (u, v) in fast.iter().zip(&dense) {
            assert!((u - v).abs() < 1e-8);
        }
    }

    #[test]
    fn apply_transpose_matches_dense_transpose_matvec() {
        let mut p = FftPlanner::new();
        let mut rng = Rng::seeded(68);
        let basis = random_basis(29, &[29, 13, 4], &mut rng);
        let x = rng.randn_vec(29);
        let fast = basis.apply_transpose(&mut p, &x);
        let dense = basis.to_dense().transpose().matvec(&x);
        for (u, v) in fast.iter().zip(&dense) {
            assert!((u - v).abs() < 1e-8);
        }
    }

    #[test]
    fn row_sums_match_apply_ones() {
        let mut p = FftPlanner::new();
        let mut rng = Rng::seeded(63);
        let basis = random_basis(24, &[20, 10, 1], &mut rng);
        let ones = vec![1.0; 24];
        let via_fft = basis.apply(&mut p, &ones);
        let closed = basis.row_sums();
        for (u, v) in via_fft.iter().zip(&closed) {
            assert!((u - v).abs() < 1e-8);
        }
    }

    #[test]
    fn apply_matrix_matches_dense() {
        let mut p = FftPlanner::new();
        let mut rng = Rng::seeded(64);
        let basis = random_basis(20, &[20, 7], &mut rng);
        let v = Matrix::randn(20, 5, &mut rng);
        let fast = basis.apply_matrix(&mut p, &v);
        let dense = basis.to_dense().matmul(&v);
        assert!(max_abs_diff(&fast, &dense) < 1e-8);
    }

    #[test]
    fn exp_transform_full_window() {
        // m1 = n: literal Lemma B.16.
        let mut rng = Rng::seeded(65);
        let n = 12;
        let basis = random_basis(n, &[12, 6, 2], &mut rng);
        let h = basis.to_dense();
        let transformed = exp_transform(&basis, true);
        assert_eq!(transformed.k(), 3); // no completion term needed
        let want = Mask::causal(n).apply(&h.map(f64::exp));
        let got = transformed.to_dense();
        assert!(max_abs_diff(&want, &got) < 1e-10);
    }

    #[test]
    fn exp_transform_completion_when_m1_lt_n() {
        let mut rng = Rng::seeded(66);
        let n = 10;
        let basis = random_basis(n, &[6, 3], &mut rng);
        let h = basis.to_dense();
        let transformed = exp_transform(&basis, true);
        assert_eq!(transformed.k(), 3); // zero-basis prepended
        let want = Mask::causal(n).apply(&h.map(f64::exp));
        let got = transformed.to_dense();
        assert!(max_abs_diff(&want, &got) < 1e-10);
    }

    #[test]
    fn memory_is_kn() {
        let mut rng = Rng::seeded(67);
        let basis = random_basis(64, &[64, 32, 16], &mut rng);
        assert_eq!(basis.memory_floats(), 3 * 64);
    }

    #[test]
    #[should_panic(expected = "strictly decreasing")]
    fn rejects_non_decreasing_windows() {
        let n = 4;
        let t1 = ConvBasis { b: vec![0.0; n], m: 2 };
        let t2 = ConvBasis { b: vec![0.0; n], m: 2 };
        let _ = KConvBasis::new(n, vec![t1, t2]);
    }
}
