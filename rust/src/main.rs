//! `conv-basis` CLI: the leader entrypoint.
//!
//! Subcommands (hand-rolled parsing — no vendored CLI crate on this
//! image):
//!
//! ```text
//! conv-basis serve   [--requests N] [--rate R] [--workers W] [--exact-below N]
//! conv-basis bench   [--n N] [--k K] [--d D]        one-shot conv-vs-exact timing
//! conv-basis masks                                  render the Figure 3 gallery
//! conv-basis verify  [--artifact PATH]              load an AOT artifact on PJRT
//! ```

use conv_basis::attention::rope::rope_structured_qk;
use conv_basis::attention::{conv_attention, exact_attention, figure3_masks, Mask};
use conv_basis::basis::RecoverConfig;
use conv_basis::coordinator::{run_trace, BatcherConfig, RouterConfig, Server, ServerConfig};
use conv_basis::data::{WorkloadConfig, WorkloadTrace};
use conv_basis::tensor::{max_abs_diff, Matrix, Rng};
use std::time::Instant;

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

fn flag_num<T: std::str::FromStr>(args: &[String], name: &str, default: T) -> T {
    flag(args, name).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("serve") => cmd_serve(&args[1..]),
        Some("bench") => cmd_bench(&args[1..]),
        Some("masks") => cmd_masks(),
        Some("verify") => cmd_verify(&args[1..]),
        _ => {
            eprintln!(
                "usage: conv-basis <serve|bench|masks|verify> [flags]\n\
                 see `rust/src/main.rs` header for flags"
            );
            std::process::exit(2);
        }
    }
}

fn cmd_serve(args: &[String]) {
    let n_requests: usize = flag_num(args, "--requests", 200);
    let rate: f64 = flag_num(args, "--rate", 500.0);
    let workers: usize = flag_num(args, "--workers", 4);
    let exact_below: usize = flag_num(args, "--exact-below", 128);

    let server = Server::start(ServerConfig {
        router: RouterConfig { exact_below, ..Default::default() },
        batcher: BatcherConfig::default(),
        workers,
        cache_capacity: 128,
        lowrank_degree: 2,
        gen: None,
    });
    let trace = WorkloadTrace::generate(
        n_requests,
        &WorkloadConfig { rate_per_s: rate, ..Default::default() },
        42,
    );
    println!("serving {n_requests} requests at {rate}/s across {workers} workers…");
    let t0 = Instant::now();
    let resps = run_trace(&server, &trace, 1.0);
    let wall = t0.elapsed();
    let metrics = server.shutdown();
    let snap = metrics.snapshot();
    println!("{}", snap.report());
    println!(
        "throughput: {:.1} req/s (wall {:.2}s, {} responses)",
        resps.len() as f64 / wall.as_secs_f64(),
        wall.as_secs_f64(),
        resps.len()
    );
}

fn cmd_bench(args: &[String]) {
    let n: usize = flag_num(args, "--n", 2048);
    let k: usize = flag_num(args, "--k", 8);
    let d: usize = flag_num(args, "--d", 64);
    let mut rng = Rng::seeded(7);
    let (q, kk) = rope_structured_qk(n, d, 3.min(d / 2).max(1), &mut rng);
    let v = Matrix::randn(n, d, &mut rng);

    let t0 = Instant::now();
    let exact = exact_attention(&q, &kk, &v, &Mask::causal(n));
    let t_exact = t0.elapsed();

    let t_w = 4.min(n);
    let cfg = RecoverConfig { k_max: k, t: t_w, delta: 5.0 * t_w as f64 * 1e-7, eps: 1e-7 };
    let t1 = Instant::now();
    let out = conv_attention(&q, &kk, &v, &cfg).expect("conv attention");
    let t_conv = t1.elapsed();

    println!(
        "n={n} d={d} k_max={k} | exact {:?} | conv {:?} (recovered k={}) | speedup {:.2}× | max err {:.2e}",
        t_exact,
        t_conv,
        out.post_basis.k(),
        t_exact.as_secs_f64() / t_conv.as_secs_f64(),
        max_abs_diff(&exact, &out.y),
    );
}

fn cmd_masks() {
    for (name, mask) in figure3_masks() {
        println!("## {name}\n{}", mask.render());
    }
}

fn cmd_verify(args: &[String]) {
    let path = flag(args, "--artifact")
        .unwrap_or_else(|| "artifacts/conv_attention.hlo.txt".to_string());
    match conv_basis::runtime::PjrtRuntime::cpu() {
        Ok(mut rt) => {
            println!("PJRT platform: {}", rt.platform());
            match rt.load(std::path::Path::new(&path)) {
                Ok(model) => println!("loaded + compiled {}", model.name),
                Err(e) => {
                    eprintln!("{e}");
                    std::process::exit(1);
                }
            }
        }
        Err(e) => {
            eprintln!("PJRT unavailable: {e}");
            std::process::exit(1);
        }
    }
}
