//! Repo-invariant lint engine — the static half of the determinism
//! contract (see ARCHITECTURE.md §"Determinism invariants & static
//! analysis"). `cargo run --bin lint` drives this over `rust/src`; CI
//! runs it before the test step so a violation fails the build before
//! any test burns time.
//!
//! This is a *text/syntax-level* pass, not a type-checked one — the
//! image vendors no syn/rustc libraries, and the forbidden patterns
//! are all textual by design (that is what makes them reviewable in a
//! diff). Three pieces of real parsing keep it honest:
//!
//! * **String/comment stripping** ([`strip_code`]): rule patterns are
//!   matched against a copy of the source whose string literals
//!   (including raw strings and char literals) and comments are
//!   blanked — a doc comment *mentioning* `HashMap`, or a test
//!   fixture's `r#"{"op":…}"#` payload, can never trip a rule.
//! * **`#[cfg(test)]` masking** ([`test_mask`]): items under a
//!   `#[cfg(test)]` attribute are exempt, tracked by brace balance so
//!   a mid-file test helper (e.g. the one inside
//!   `coordinator/metrics.rs`) masks exactly its own item, not the
//!   rest of the file.
//! * **An allowlist** ([`parse_allowlist`], `rust/lint.allow`): every
//!   audited exception is a visible, greppable line with a rationale —
//!   and [`lint_tree`] reports entries that no longer match anything,
//!   so stale exemptions rot loudly.
//!
//! The rules themselves ([`RULES`]) encode the invariants the dynamic
//! suites pin by sampling:
//!
//! | rule id | forbids | where |
//! |---|---|---|
//! | `hash-iter` | any `HashMap`/`HashSet` (hasher-ordered iteration is one `.iter()` away) | deterministic modules |
//! | `wall-clock` | `Instant::now` / `SystemTime` (results keyed on time) | kernel modules + the worker pool |
//! | `metrics-unbounded-push` | `.push(` without a reservoir-cap guard | `coordinator/metrics.rs` |
//! | `request-path-unwrap` | `.unwrap()` on per-connection request paths | `coordinator/net.rs`, `coordinator/server.rs` |
//! | `sync-facade` | raw `std::sync` / `std::thread` bypassing `crate::sync` | the facade-scoped modules |
//!
//! `lintpass.rs`, `sync.rs` and `bin/` are outside every scope by
//! construction (they define the facade and the patterns).

use std::fmt;
use std::path::{Path, PathBuf};

/// One rule violation, anchored to a file and 1-based line.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Violation {
    /// Stable rule id (see [`RULES`]).
    pub rule: &'static str,
    /// Path relative to the linted root, `/`-separated.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// The offending source line, trimmed (allowlist substrings match
    /// against this).
    pub excerpt: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} {}:{} {}", self.rule, self.file, self.line, self.excerpt)
    }
}

/// Rule ids with one-line rationales (`lint --help` prints these).
pub const RULES: &[(&str, &str)] = &[
    (
        "hash-iter",
        "HashMap/HashSet in a deterministic module: hasher-ordered iteration breaks \
         bit-identity; use BTreeMap/BTreeSet or allowlist a lookup-only use",
    ),
    (
        "wall-clock",
        "Instant::now/SystemTime in a kernel module: results must be pure functions of \
         inputs, never of time",
    ),
    (
        "metrics-unbounded-push",
        "unguarded .push( under the metrics mutex: latency series must stay bounded by \
         LATENCY_RESERVOIR_CAP",
    ),
    (
        "request-path-unwrap",
        ".unwrap() on a per-connection request path: a malformed frame must produce an \
         error event, not a dead thread",
    ),
    (
        "sync-facade",
        "raw std::sync/std::thread in a facade-scoped module: import crate::sync so \
         --cfg loom can swap the primitives",
    ),
];

/// Modules whose lock/thread primitives must come from `crate::sync`.
const FACADE_FILES: &[&str] = &[
    "runtime/pool.rs",
    "coordinator/server.rs",
    "coordinator/admission.rs",
    "coordinator/net.rs",
    "coordinator/metrics.rs",
    "coordinator/cache.rs",
    "fft/planner.rs",
];

/// Deterministic fan-out / result-assembly scope for `hash-iter`.
const HASH_SCOPE_DIRS: &[&str] = &[
    "attention/",
    "basis/",
    "conv/",
    "coordinator/",
    "fft/",
    "gradient/",
    "lowrank/",
    "model/",
    "runtime/",
    "tensor/",
];

/// Kernel scope for `wall-clock` (the coordinator is a serving layer —
/// deadline batching and latency metrics legitimately read the clock).
const CLOCK_SCOPE_DIRS: &[&str] =
    &["attention/", "basis/", "conv/", "fft/", "gradient/", "lowrank/", "model/", "tensor/"];
const CLOCK_SCOPE_FILES: &[&str] = &["runtime/pool.rs"];

/// Per-connection request-path scope for `request-path-unwrap`.
/// `.expect("invariant")` stays legal as the audited form.
const UNWRAP_FILES: &[&str] = &["coordinator/net.rs", "coordinator/server.rs"];

const METRICS_FILE: &str = "coordinator/metrics.rs";
/// A `.push(` within this many lines after the cap token is guarded.
const METRICS_GUARD_WINDOW: usize = 2;

/// Blank out comments, string/char literals (including raw strings)
/// with spaces, preserving line structure, so rule patterns never
/// match prose or payload text.
pub fn strip_code(src: &str) -> String {
    let b = src.as_bytes();
    let mut out = vec![0u8; 0];
    let mut i = 0;
    let n = b.len();
    let blank = |out: &mut Vec<u8>, seg: &[u8]| {
        out.extend(seg.iter().map(|&c| if c == b'\n' { b'\n' } else { b' ' }));
    };
    while i < n {
        // Line comment.
        if b[i] == b'/' && i + 1 < n && b[i + 1] == b'/' {
            let j = src[i..].find('\n').map(|k| i + k).unwrap_or(n);
            blank(&mut out, &b[i..j]);
            i = j;
            continue;
        }
        // Block comment (nesting tracked — Rust block comments nest).
        if b[i] == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let mut depth = 1;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if b[j] == b'/' && j + 1 < n && b[j + 1] == b'*' {
                    depth += 1;
                    j += 2;
                } else if b[j] == b'*' && j + 1 < n && b[j + 1] == b'/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            blank(&mut out, &b[i..j]);
            i = j;
            continue;
        }
        // Raw string r"…" / r#"…"# (also br"…").
        if b[i] == b'r' && i + 1 < n && (b[i + 1] == b'"' || b[i + 1] == b'#') {
            let mut hashes = 0;
            while i + 1 + hashes < n && b[i + 1 + hashes] == b'#' {
                hashes += 1;
            }
            if i + 1 + hashes < n && b[i + 1 + hashes] == b'"' {
                let close: String = format!("\"{}", "#".repeat(hashes));
                let start = i + 2 + hashes;
                let j = src[start..].find(&close).map(|k| start + k + close.len()).unwrap_or(n);
                blank(&mut out, &b[i..j]);
                i = j;
                continue;
            }
        }
        // Plain string literal with escapes.
        if b[i] == b'"' {
            let mut j = i + 1;
            while j < n {
                if b[j] == b'\\' {
                    j = (j + 2).min(n);
                } else if b[j] == b'"' {
                    j += 1;
                    break;
                } else {
                    j += 1;
                }
            }
            blank(&mut out, &b[i..j]);
            i = j;
            continue;
        }
        // Char literal — only when it closes ('a', '\n', '\u{1f600}');
        // lifetimes ('a in generics) never close with a quote.
        if b[i] == b'\'' {
            let rest = &src[i + 1..];
            let lit_len = if let Some(r) = rest.strip_prefix('\\') {
                // Escape: the char after the backslash is consumed
                // unconditionally (it may itself be a quote, as in
                // '\''), then scan to the closing quote.
                r.get(1..).and_then(|t| t.find('\'')).map(|k| k + 4)
            } else {
                let mut ch = rest.chars();
                match (ch.next(), ch.next()) {
                    (Some(c0), Some('\'')) => Some(1 + c0.len_utf8() + 1),
                    _ => None,
                }
            };
            if let Some(l) = lit_len {
                blank(&mut out, &b[i..(i + l).min(n)]);
                i = (i + l).min(n);
                continue;
            }
        }
        out.push(b[i]);
        i += 1;
    }
    String::from_utf8(out).expect("blanking is ascii-space substitution on utf8 boundaries")
}

/// Per-line mask: `true` where the line belongs to a `#[cfg(test)]`
/// item. Brace-tracked from the attribute so a mid-file test helper
/// masks exactly its own item (attribute → first `{` → matching `}`,
/// or the first `;` for braceless items).
pub fn test_mask(stripped_lines: &[&str]) -> Vec<bool> {
    let mut mask = vec![false; stripped_lines.len()];
    let mut i = 0;
    while i < stripped_lines.len() {
        let l = stripped_lines[i];
        if !(l.contains("#[cfg(test)]") || l.contains("#[cfg(all(test")) {
            i += 1;
            continue;
        }
        let mut depth: i64 = 0;
        let mut opened = false;
        let mut j = i;
        while j < stripped_lines.len() {
            mask[j] = true;
            for c in stripped_lines[j].chars() {
                match c {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => depth -= 1,
                    _ => {}
                }
            }
            if opened && depth <= 0 {
                break;
            }
            if !opened && stripped_lines[j].contains(';') {
                break;
            }
            j += 1;
        }
        i = j + 1;
    }
    mask
}

/// One audited exception from the allowlist file.
#[derive(Clone, Debug)]
pub struct AllowEntry {
    pub rule: String,
    pub file: String,
    /// Substring the violating line must contain, or `"*"` to exempt
    /// the whole (rule, file) pair.
    pub substring: String,
    pub note: String,
}

impl AllowEntry {
    fn matches(&self, v: &Violation) -> bool {
        self.rule == v.rule
            && self.file == v.file
            && (self.substring == "*" || v.excerpt.contains(&self.substring))
    }
}

/// Parse the `rule | file | substring-or-* | note` allowlist format
/// (`#` comments and blank lines skipped). Every entry must carry a
/// non-empty note — an exception without a rationale is an error.
pub fn parse_allowlist(text: &str) -> Result<Vec<AllowEntry>, String> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let parts: Vec<&str> = line.splitn(4, '|').map(str::trim).collect();
        let [rule, file, substring, note] = parts[..] else {
            return Err(format!("lint.allow:{}: want `rule | file | substring | note`", i + 1));
        };
        if !RULES.iter().any(|(id, _)| *id == rule) {
            return Err(format!("lint.allow:{}: unknown rule id `{rule}`", i + 1));
        }
        if note.is_empty() {
            return Err(format!("lint.allow:{}: an exception needs a rationale note", i + 1));
        }
        out.push(AllowEntry {
            rule: rule.to_string(),
            file: file.to_string(),
            substring: substring.to_string(),
            note: note.to_string(),
        });
    }
    Ok(out)
}

fn in_dirs(rel: &str, dirs: &[&str]) -> bool {
    dirs.iter().any(|d| rel.starts_with(d))
}

/// Lint one file's source. `rel` is the `/`-separated path relative to
/// the linted root (scopes key off it). Returns raw violations — the
/// allowlist is applied by [`lint_tree`].
pub fn lint_source(rel: &str, src: &str) -> Vec<Violation> {
    let stripped = strip_code(src);
    let stripped_lines: Vec<&str> = stripped.lines().collect();
    let raw_lines: Vec<&str> = src.lines().collect();
    let mask = test_mask(&stripped_lines);
    let mut out = Vec::new();
    let mut push = |rule: &'static str, idx: usize| {
        out.push(Violation {
            rule,
            file: rel.to_string(),
            line: idx + 1,
            excerpt: raw_lines.get(idx).unwrap_or(&"").trim().to_string(),
        });
    };

    let hash_scope = in_dirs(rel, HASH_SCOPE_DIRS);
    let clock_scope = in_dirs(rel, CLOCK_SCOPE_DIRS) || CLOCK_SCOPE_FILES.contains(&rel);
    let facade_scope = FACADE_FILES.contains(&rel);
    let unwrap_scope = UNWRAP_FILES.contains(&rel);
    let metrics_scope = rel == METRICS_FILE;

    for (idx, line) in stripped_lines.iter().enumerate() {
        if mask[idx] {
            continue;
        }
        if hash_scope && (line.contains("HashMap") || line.contains("HashSet")) {
            push("hash-iter", idx);
        }
        if clock_scope && (line.contains("Instant::now") || line.contains("SystemTime")) {
            push("wall-clock", idx);
        }
        if metrics_scope && line.contains(".push(") {
            let lo = idx.saturating_sub(METRICS_GUARD_WINDOW);
            let guarded = (lo..=idx).any(|k| stripped_lines[k].contains("LATENCY_RESERVOIR_CAP"));
            if !guarded {
                push("metrics-unbounded-push", idx);
            }
        }
        if unwrap_scope && line.contains(".unwrap()") {
            push("request-path-unwrap", idx);
        }
        if facade_scope && (line.contains("std::sync") || line.contains("std::thread")) {
            push("sync-facade", idx);
        }
    }
    out
}

/// A whole-tree lint run.
#[derive(Debug, Default)]
pub struct Report {
    /// Violations that survived the allowlist, sorted (file, line, rule).
    pub violations: Vec<Violation>,
    /// Allowlist entries (by index into the parsed list) that matched
    /// nothing — stale exemptions the caller should surface.
    pub unused_allow: Vec<usize>,
    /// `.rs` files scanned.
    pub files_scanned: usize,
}

impl Report {
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty()
    }
}

/// Recursively collect `.rs` files under `root`, lint each, apply the
/// allowlist. Traversal is sorted, so output order is deterministic.
pub fn lint_tree(root: &Path, allow: &[AllowEntry]) -> std::io::Result<Report> {
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    files.sort();
    let mut report = Report::default();
    let mut allow_used = vec![false; allow.len()];
    for path in &files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let src = std::fs::read_to_string(path)?;
        report.files_scanned += 1;
        for v in lint_source(&rel, &src) {
            let mut allowed = false;
            for (i, a) in allow.iter().enumerate() {
                if a.matches(&v) {
                    allow_used[i] = true;
                    allowed = true;
                }
            }
            if !allowed {
                report.violations.push(v);
            }
        }
    }
    report.violations.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    report.unused_allow = allow_used
        .iter()
        .enumerate()
        .filter_map(|(i, &used)| if used { None } else { Some(i) })
        .collect();
    Ok(report)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Parse `// lint-expect: rule-id@LINE` markers out of a fixture file
/// (markers live in comments, so the stripped pass never sees them).
/// `// lint-expect: none` declares an intentionally clean fixture.
pub fn parse_expectations(src: &str) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    for line in src.lines() {
        let Some(rest) = line.trim().strip_prefix("// lint-expect:") else { continue };
        let rest = rest.trim();
        if rest == "none" {
            continue;
        }
        if let Some((rule, ln)) = rest.split_once('@') {
            if let Ok(ln) = ln.trim().parse::<usize>() {
                out.push((rule.trim().to_string(), ln));
            }
        }
    }
    out.sort();
    out
}

/// Run the fixture self-test: every fixture under `fixtures_root` must
/// produce exactly its `// lint-expect:` markers (no allowlist).
/// Returns human-readable mismatch descriptions; empty = pass.
pub fn self_test(fixtures_root: &Path) -> std::io::Result<Vec<String>> {
    let mut files = Vec::new();
    collect_rs(fixtures_root, &mut files)?;
    files.sort();
    let mut failures = Vec::new();
    if files.is_empty() {
        failures.push(format!("no fixtures found under {}", fixtures_root.display()));
    }
    for path in &files {
        let rel = path
            .strip_prefix(fixtures_root)
            .unwrap_or(path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let src = std::fs::read_to_string(path)?;
        let want = parse_expectations(&src);
        let mut got: Vec<(String, usize)> =
            lint_source(&rel, &src).into_iter().map(|v| (v.rule.to_string(), v.line)).collect();
        got.sort();
        if got != want {
            failures.push(format!("{rel}: expected {want:?}, lint found {got:?}"));
        }
    }
    Ok(failures)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stripping_blanks_strings_and_comments() {
        let src = "let a = \"HashMap in a string\"; // HashMap in a comment\nlet b = r#\"Instant::now in raw\"#;\n/* HashMap\nacross lines */ let c = 1;\n";
        let out = strip_code(src);
        assert!(!out.contains("HashMap"));
        assert!(!out.contains("Instant::now"));
        assert!(out.contains("let a ="));
        assert!(out.contains("let c = 1;"));
        assert_eq!(out.matches('\n').count(), src.matches('\n').count());
    }

    #[test]
    fn stripping_keeps_lifetimes_and_char_literals_apart() {
        let src = "fn f<'a>(x: &'a str) -> char { 'x' }\nlet esc = '\\n';";
        let out = strip_code(src);
        assert!(out.contains("fn f<'a>(x: &'a str)"), "lifetimes survive: {out}");
        assert!(!out.contains("'x'"), "char literal blanked: {out}");
        assert!(!out.contains("\\n';"), "escaped char blanked: {out}");
    }

    #[test]
    fn test_mask_covers_exactly_the_test_item() {
        // A mid-file #[cfg(test)] helper (the coordinator/metrics.rs
        // shape) must mask its own item and nothing after it.
        let src = "fn a() {\n    let x = 1;\n}\n#[cfg(test)]\nfn helper() {\n    let m = HashMap::new();\n}\nfn b() {\n    let y = 2;\n}\n";
        let stripped = strip_code(src);
        let lines: Vec<&str> = stripped.lines().collect();
        let mask = test_mask(&lines);
        assert_eq!(
            mask,
            vec![false, false, false, true, true, true, true, false, false, false]
        );
    }

    #[test]
    fn rules_fire_and_scope() {
        let src = "use std::collections::HashMap;\n";
        assert_eq!(lint_source("conv/x.rs", src).len(), 1);
        assert_eq!(lint_source("conv/x.rs", src)[0].rule, "hash-iter");
        // data/ and util/ are outside the deterministic scope.
        assert!(lint_source("data/x.rs", src).is_empty());
        // bin/, sync.rs, lintpass.rs sit outside every scope.
        assert!(lint_source("bin/lint.rs", src).is_empty());
        assert!(lint_source("sync.rs", "use std::sync::Mutex;\n").is_empty());
    }

    #[test]
    fn metrics_push_guard_window() {
        let guarded = "if self.samples.len() < LATENCY_RESERVOIR_CAP {\n    self.samples.push(x);\n}\n";
        assert!(lint_source("coordinator/metrics.rs", guarded).is_empty());
        let unguarded = "fn record(&mut self) {\n    self.samples.push(1.0);\n}\n";
        let v = lint_source("coordinator/metrics.rs", unguarded);
        assert_eq!(v.len(), 1);
        assert_eq!((v[0].rule, v[0].line), ("metrics-unbounded-push", 2));
        // The same push outside metrics.rs is fine.
        assert!(lint_source("coordinator/server.rs", unguarded).is_empty());
    }

    #[test]
    fn unwrap_rule_spares_expect_and_unwrap_or() {
        let src = "let a = x.unwrap();\nlet b = y.expect(\"invariant\");\nlet c = z.unwrap_or(0);\n";
        let v = lint_source("coordinator/net.rs", src);
        assert_eq!(v.len(), 1);
        assert_eq!((v[0].rule, v[0].line), ("request-path-unwrap", 1));
    }

    #[test]
    fn allowlist_parses_matches_and_rejects_garbage() {
        let allow = parse_allowlist(
            "# comment\n\nhash-iter | coordinator/net.rs | HashMap | lookup-only maps\n",
        )
        .expect("valid allowlist");
        assert_eq!(allow.len(), 1);
        let v = Violation {
            rule: "hash-iter",
            file: "coordinator/net.rs".into(),
            line: 3,
            excerpt: "use std::collections::HashMap;".into(),
        };
        assert!(allow[0].matches(&v));
        let other = Violation { file: "coordinator/cache.rs".into(), ..v.clone() };
        assert!(!allow[0].matches(&other));
        assert!(parse_allowlist("bogus-rule | f.rs | * | note").is_err());
        assert!(parse_allowlist("hash-iter | f.rs | *").is_err(), "missing note field");
        assert!(parse_allowlist("hash-iter | f.rs | * | ").is_err(), "empty note");
    }

    #[test]
    fn expectations_parse() {
        let src = "// lint-expect: hash-iter@6\n// lint-expect: wall-clock@9\ncode();\n";
        assert_eq!(
            parse_expectations(src),
            vec![("hash-iter".to_string(), 6), ("wall-clock".to_string(), 9)]
        );
        assert!(parse_expectations("// lint-expect: none\n").is_empty());
    }
}
