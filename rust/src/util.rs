//! Benchmark utilities: the offline image vendors no criterion, so the
//! `benches/` harnesses are plain `main()`s built on these helpers.
//! Timing discipline: warmup, then median of N runs (medians are robust
//! to scheduler noise on shared CPU).

use std::time::{Duration, Instant};

/// Median-of-`iters` wall time of `f`, with one warmup call.
/// A `black_box`-style sink prevents the optimizer from eliding work.
pub fn time_median<T>(iters: usize, mut f: impl FnMut() -> T) -> Duration {
    assert!(iters >= 1);
    let _ = sink(f()); // warmup
    let mut samples: Vec<Duration> = (0..iters)
        .map(|_| {
            let t0 = Instant::now();
            let out = f();
            let dt = t0.elapsed();
            let _ = sink(out);
            dt
        })
        .collect();
    samples.sort();
    samples[samples.len() / 2]
}

/// Opaque sink (std::hint::black_box wrapper, kept here so benches don't
/// import core hints everywhere).
#[inline]
pub fn sink<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// True when the bench binary was invoked with `--smoke`: CI runs every
/// bench target in this mode (`cargo bench --bench <name> -- --smoke`,
/// tiny sizes) so a *panicking* bench fails the build —
/// `cargo bench --no-run` only catches ones that stop compiling.
/// Full-size tables stay manual.
pub fn smoke() -> bool {
    std::env::args().any(|a| a == "--smoke")
}

/// Seconds as a human-readable string with 3 significant digits.
pub fn fmt_dur(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.3}µs", s * 1e6)
    }
}

/// Simple fixed-width table printer for bench output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let line = |cells: &[String]| {
            cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", line(&self.headers));
        println!("{}", widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  "));
        for row in &self.rows {
            println!("{}", line(row));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_median_measures_something() {
        let d = time_median(3, || {
            let mut s = 0u64;
            for i in 0..10_000 {
                s = s.wrapping_add(i);
            }
            s
        });
        assert!(d.as_nanos() > 0);
    }

    #[test]
    fn fmt_dur_units() {
        assert!(fmt_dur(Duration::from_secs(2)).ends_with('s'));
        assert!(fmt_dur(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_micros(7)).ends_with("µs"));
    }

    #[test]
    fn table_prints() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.print();
    }
}
