//! Repo-invariant lint driver — see `src/lintpass.rs` for the engine
//! and rule rationales, ARCHITECTURE.md §"Determinism invariants &
//! static analysis" for the contract it enforces.
//!
//! ```text
//! cargo run --release --bin lint                 # lint rust/src with rust/lint.allow
//! cargo run --release --bin lint -- --self-test  # fixtures must reproduce their markers
//! cargo run --release --bin lint -- --root DIR --allow FILE
//! ```
//!
//! Exit codes: 0 clean, 1 violations (or self-test mismatch), 2 usage
//! or I/O error. CI runs `--self-test` then the tree pass *before* the
//! test step, so a determinism regression fails fast.

use conv_basis::lintpass::{self, RULES};
use std::path::PathBuf;
use std::process::ExitCode;

fn usage() -> ExitCode {
    eprintln!("usage: lint [--root DIR] [--allow FILE] [--self-test]");
    eprintln!("rules:");
    for (id, why) in RULES {
        eprintln!("  {id:<24} {why}");
    }
    ExitCode::from(2)
}

fn main() -> ExitCode {
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let mut root = manifest.join("src");
    let mut allow_path = manifest.join("lint.allow");
    let mut self_test = false;

    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => match args.next() {
                Some(d) => root = PathBuf::from(d),
                None => return usage(),
            },
            "--allow" => match args.next() {
                Some(f) => allow_path = PathBuf::from(f),
                None => return usage(),
            },
            "--self-test" => self_test = true,
            "--help" | "-h" => {
                let _ = usage();
                return ExitCode::SUCCESS;
            }
            _ => return usage(),
        }
    }

    if self_test {
        let fixtures = manifest.join("lint-fixtures");
        return match lintpass::self_test(&fixtures) {
            Ok(failures) if failures.is_empty() => {
                println!("lint self-test: all fixtures reproduce their lint-expect markers");
                ExitCode::SUCCESS
            }
            Ok(failures) => {
                for f in &failures {
                    eprintln!("lint self-test FAIL: {f}");
                }
                ExitCode::from(1)
            }
            Err(e) => {
                eprintln!("lint self-test: io error: {e}");
                ExitCode::from(2)
            }
        };
    }

    let allow = if allow_path.exists() {
        let text = match std::fs::read_to_string(&allow_path) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("lint: cannot read {}: {e}", allow_path.display());
                return ExitCode::from(2);
            }
        };
        match lintpass::parse_allowlist(&text) {
            Ok(a) => a,
            Err(e) => {
                eprintln!("lint: {e}");
                return ExitCode::from(2);
            }
        }
    } else {
        Vec::new()
    };

    let report = match lintpass::lint_tree(&root, &allow) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("lint: io error walking {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };

    for &i in &report.unused_allow {
        let a = &allow[i];
        eprintln!(
            "lint: warning: unused allowlist entry `{} | {} | {}` — remove it from {}",
            a.rule,
            a.file,
            a.substring,
            allow_path.display()
        );
    }
    if report.is_clean() {
        println!(
            "lint: {} files clean ({} allowlisted exception{})",
            report.files_scanned,
            allow.len(),
            if allow.len() == 1 { "" } else { "s" }
        );
        ExitCode::SUCCESS
    } else {
        for v in &report.violations {
            println!("{v}");
        }
        eprintln!(
            "lint: {} violation{} in {} files — fix, or add an audited `rule | file | substring | note` line to {}",
            report.violations.len(),
            if report.violations.len() == 1 { "" } else { "s" },
            report.files_scanned,
            allow_path.display()
        );
        ExitCode::from(1)
    }
}
