//! Closed-loop load generator for the TCP serving door.
//!
//! Spins an in-process [`NetServer`] on an ephemeral port, then drives
//! it the way a fleet of clients would: per cell of the sweep,
//! `batch` connections each run a closed loop of generation requests
//! (send one, stream its tokens to the terminal event, send the next)
//! over real sockets — measuring time-to-first-token and end-to-end
//! latency off the wire, not in-process.
//!
//! Sweep: batch × prompt_len × decode_len × γ (speculative-decoding
//! depth; γ = 0 is the plain decode loop). Results land in
//! `BENCH_PR7.json` (repo root; `--out <path>` overrides) with schema
//! `bench_pr7/v1`; each cell carries the server-side draft acceptance
//! rate for its γ next to the wire-side latency percentiles:
//!
//! ```text
//! {"schema":"bench_pr7/v1","source":"rust-loadgen","smoke":false,
//!  "cells":[{"batch":4,"prompt_len":64,"decode_len":32,"gamma":2,
//!            "requests":12,"tokens":384,"wall_s":1.2,
//!            "tokens_per_s":320.0,"accept_rate":0.87,
//!            "ttft_p50_us":900.0,"e2e_p50_us":..,"e2e_p95_us":..,
//!            "shed":0}, ...]}
//! ```
//!
//! `--smoke` (CI) shrinks the grid to seconds. Shed (busy) responses
//! are counted, never retried — the cell reports them so a saturated
//! configuration is visible instead of silently under-counting.

use conv_basis::coordinator::{AdmissionConfig, GenConfig, NetConfig, NetServer, ServerConfig};
use conv_basis::model::{AttentionBackend, ModelConfig, Transformer};
use conv_basis::tensor::Rng;
use conv_basis::util::{smoke, Table};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::Instant;

struct Cell {
    batch: usize,
    prompt_len: usize,
    decode_len: usize,
    gamma: usize,
    requests: usize,
    tokens: usize,
    wall_s: f64,
    ttft_p50_us: f64,
    e2e_p50_us: f64,
    e2e_p95_us: f64,
    /// Server-side speculative acceptance rate (0.0 when γ = 0).
    accept_rate: f64,
    shed: usize,
}

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    sorted[((q * (sorted.len() - 1) as f64).round() as usize).min(sorted.len() - 1)]
}

/// One client connection's closed loop: `iters` generations, streamed.
/// Returns (ttft_us, e2e_us) per completed request, tokens seen, sheds.
fn client_loop(
    addr: SocketAddr,
    conn_id: usize,
    prompt_len: usize,
    decode_len: usize,
    iters: usize,
) -> std::io::Result<(Vec<(f64, f64)>, usize, usize)> {
    let stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true)?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut lats = Vec::with_capacity(iters);
    let mut tokens = 0usize;
    let mut shed = 0usize;
    let prompt: Vec<String> =
        (0..prompt_len).map(|j| (((conn_id * 131 + j * 17) % 255) + 1).to_string()).collect();
    let prompt = prompt.join(",");
    let mut line = String::new();
    for i in 0..iters {
        let t0 = Instant::now();
        writeln!(
            writer,
            "{{\"op\":\"generate\",\"id\":{i},\"prompt\":[{prompt}],\"max_new_tokens\":{decode_len}}}"
        )?;
        let mut ttft: Option<f64> = None;
        loop {
            line.clear();
            if reader.read_line(&mut line)? == 0 {
                return Ok((lats, tokens, shed)); // server went away
            }
            if line.contains("\"ev\":\"token\"") {
                tokens += 1;
                ttft.get_or_insert_with(|| t0.elapsed().as_secs_f64() * 1e6);
            } else if line.contains("\"ev\":\"done\"") {
                lats.push((ttft.unwrap_or(0.0), t0.elapsed().as_secs_f64() * 1e6));
                break;
            } else if line.contains("\"ev\":\"busy\"") {
                shed += 1;
                break;
            } else if line.contains("\"ev\":\"rejected\"") || line.contains("\"ev\":\"error\"") {
                panic!("loadgen sent an invalid request: {line}");
            }
        }
    }
    Ok((lats, tokens, shed))
}

fn run_cell(
    batch: usize,
    prompt_len: usize,
    decode_len: usize,
    gamma: usize,
    iters: usize,
) -> Cell {
    // Fresh server per cell: no cache warmth bleeding across cells.
    let max_seq = (prompt_len + decode_len + 8).next_power_of_two();
    let mut rng = Rng::seeded(6);
    let model = Arc::new(Transformer::new(&ModelConfig::tiny(max_seq), &mut rng));
    let net = NetServer::start(
        ServerConfig {
            workers: 2,
            gen: Some(GenConfig {
                model,
                backend: AttentionBackend::ConvStrided(4),
                max_concurrent: 16,
                admission: AdmissionConfig::default(),
                speculate: gamma,
            }),
            ..Default::default()
        },
        NetConfig::default(),
    )
    .expect("bind loadgen server");
    let addr = net.addr();

    let t0 = Instant::now();
    let mut joins = Vec::new();
    for c in 0..batch {
        joins.push(std::thread::spawn(move || {
            client_loop(addr, c, prompt_len, decode_len, iters).expect("client io")
        }));
    }
    let mut lats: Vec<(f64, f64)> = Vec::new();
    let mut tokens = 0;
    let mut shed = 0;
    for j in joins {
        let (l, t, s) = j.join().expect("client thread");
        lats.extend(l);
        tokens += t;
        shed += s;
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let snap = net.shutdown().snapshot();
    let accept_rate = if snap.spec_drafted == 0 {
        0.0
    } else {
        snap.spec_accepted as f64 / snap.spec_drafted as f64
    };

    let mut ttft: Vec<f64> = lats.iter().map(|l| l.0).collect();
    let mut e2e: Vec<f64> = lats.iter().map(|l| l.1).collect();
    ttft.sort_by(|a, b| a.partial_cmp(b).unwrap());
    e2e.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Cell {
        batch,
        prompt_len,
        decode_len,
        gamma,
        requests: lats.len(),
        tokens,
        wall_s,
        ttft_p50_us: percentile(&ttft, 0.5),
        e2e_p50_us: percentile(&e2e, 0.5),
        e2e_p95_us: percentile(&e2e, 0.95),
        accept_rate,
        shed,
    }
}

fn arg_value(name: &str) -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

fn main() {
    let smoke = smoke();
    let out = arg_value("--out").unwrap_or_else(|| "BENCH_PR7.json".to_string());
    type Grid<'a> = (&'a [usize], &'a [usize], &'a [usize], &'a [usize], usize);
    let (batches, prompts, decodes, gammas, iters): Grid = if smoke {
        (&[1, 2], &[8, 16], &[4], &[0, 2], 2)
    } else {
        (&[1, 4, 8], &[16, 64, 256], &[8, 32], &[0, 4], 3)
    };

    println!("# Closed-loop TCP load sweep (conv-strided decode, streaming, γ sweep)");
    let mut table = Table::new(&[
        "batch", "prompt", "decode", "γ", "req", "tok/s", "accept", "ttft p50 µs", "e2e p50 µs",
        "e2e p95 µs", "shed",
    ]);
    let mut cells = Vec::new();
    for &b in batches {
        for &p in prompts {
            for &d in decodes {
                for &g in gammas {
                    let cell = run_cell(b, p, d, g, iters);
                    table.row(&[
                        b.to_string(),
                        p.to_string(),
                        d.to_string(),
                        g.to_string(),
                        cell.requests.to_string(),
                        format!("{:.1}", cell.tokens as f64 / cell.wall_s),
                        format!("{:.2}", cell.accept_rate),
                        format!("{:.0}", cell.ttft_p50_us),
                        format!("{:.0}", cell.e2e_p50_us),
                        format!("{:.0}", cell.e2e_p95_us),
                        cell.shed.to_string(),
                    ]);
                    cells.push(cell);
                }
            }
        }
    }
    table.print();

    let cells_json: Vec<String> = cells
        .iter()
        .map(|c| {
            format!(
                "{{\"batch\":{},\"prompt_len\":{},\"decode_len\":{},\"gamma\":{},\"requests\":{},\
                 \"tokens\":{},\"wall_s\":{:.6},\"tokens_per_s\":{:.3},\"accept_rate\":{:.4},\
                 \"ttft_p50_us\":{:.1},\"e2e_p50_us\":{:.1},\"e2e_p95_us\":{:.1},\"shed\":{}}}",
                c.batch,
                c.prompt_len,
                c.decode_len,
                c.gamma,
                c.requests,
                c.tokens,
                c.wall_s,
                c.tokens as f64 / c.wall_s,
                c.accept_rate,
                c.ttft_p50_us,
                c.e2e_p50_us,
                c.e2e_p95_us,
                c.shed,
            )
        })
        .collect();
    let json = format!(
        "{{\"schema\":\"bench_pr7/v1\",\"source\":\"rust-loadgen\",\"smoke\":{},\"cells\":[{}]}}\n",
        smoke,
        cells_json.join(",")
    );
    std::fs::write(&out, json).expect("write bench json");
    println!("wrote {out}");
}
