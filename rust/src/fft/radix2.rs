//! Iterative radix-2 Cooley–Tukey FFT with precomputed bit-reversal and
//! twiddle tables. Power-of-two lengths only; [`super::bluestein`]
//! handles the rest.

use super::Complex;

/// Precomputed radix-2 plan for a fixed power-of-two length.
#[derive(Debug)]
pub struct Radix2Plan {
    n: usize,
    /// Bit-reversal permutation.
    rev: Vec<u32>,
    /// Forward twiddles, one flat table: for stage with half-size `h`,
    /// twiddles `e^{-2πi k / (2h)}`, `k < h`, stored consecutively.
    twiddles: Vec<Complex>,
    /// Offsets into `twiddles` per stage.
    stage_offsets: Vec<usize>,
}

impl Radix2Plan {
    pub fn new(n: usize) -> Self {
        assert!(n.is_power_of_two(), "Radix2Plan requires a power of two, got {n}");
        let bits = n.trailing_zeros();
        let mut rev = vec![0u32; n];
        for i in 0..n {
            rev[i] = (i as u32).reverse_bits() >> (32 - bits.max(1));
        }
        if n == 1 {
            rev[0] = 0;
        }
        let mut twiddles = Vec::new();
        let mut stage_offsets = Vec::new();
        let mut h = 1;
        while h < n {
            stage_offsets.push(twiddles.len());
            for k in 0..h {
                let theta = -std::f64::consts::PI * k as f64 / h as f64;
                twiddles.push(Complex::cis(theta));
            }
            h *= 2;
        }
        Radix2Plan { n, rev, twiddles, stage_offsets }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.n
    }

    /// In-place forward transform (DFT with `e^{-2πi}` convention).
    pub fn forward(&self, x: &mut [Complex]) {
        self.transform(x, false);
    }

    /// In-place inverse transform (includes the 1/n normalization).
    pub fn inverse(&self, x: &mut [Complex]) {
        self.transform(x, true);
        let scale = 1.0 / self.n as f64;
        for v in x.iter_mut() {
            *v = *v * scale;
        }
    }

    fn transform(&self, x: &mut [Complex], inverse: bool) {
        let n = self.n;
        assert_eq!(x.len(), n, "buffer length mismatch");
        if n == 1 {
            return;
        }
        // Bit-reversal permutation.
        for i in 0..n {
            let j = self.rev[i] as usize;
            if i < j {
                x.swap(i, j);
            }
        }
        // Butterflies.
        let mut h = 1;
        let mut stage = 0;
        while h < n {
            let tw = &self.twiddles[self.stage_offsets[stage]..self.stage_offsets[stage] + h];
            let mut base = 0;
            while base < n {
                for k in 0..h {
                    let w = if inverse { tw[k].conj() } else { tw[k] };
                    let a = x[base + k];
                    let b = x[base + k + h] * w;
                    x[base + k] = a + b;
                    x[base + k + h] = a - b;
                }
                base += 2 * h;
            }
            h *= 2;
            stage += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::dft_naive;

    #[test]
    fn matches_naive_dft() {
        let mut rng = crate::tensor::Rng::seeded(21);
        for &n in &[1usize, 2, 4, 8, 64, 256] {
            let x: Vec<Complex> =
                (0..n).map(|_| Complex::new(rng.randn(), rng.randn())).collect();
            let want = dft_naive(&x, false);
            let plan = Radix2Plan::new(n);
            let mut got = x.clone();
            plan.forward(&mut got);
            for (a, b) in want.iter().zip(&got) {
                assert!((a.re - b.re).abs() < 1e-7, "n={n}");
                assert!((a.im - b.im).abs() < 1e-7, "n={n}");
            }
        }
    }

    #[test]
    fn roundtrip() {
        let mut rng = crate::tensor::Rng::seeded(22);
        let n = 128;
        let x: Vec<Complex> = (0..n).map(|_| Complex::new(rng.randn(), rng.randn())).collect();
        let plan = Radix2Plan::new(n);
        let mut y = x.clone();
        plan.forward(&mut y);
        plan.inverse(&mut y);
        for (a, b) in x.iter().zip(&y) {
            assert!((a.re - b.re).abs() < 1e-10);
            assert!((a.im - b.im).abs() < 1e-10);
        }
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn rejects_non_pow2() {
        let _ = Radix2Plan::new(12);
    }
}
