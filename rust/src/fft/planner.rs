//! Plan cache. The conv-attention hot loop applies thousands of
//! same-length transforms; building twiddle/bit-reversal tables every
//! call would dominate. `FftPlanner` hands out `Arc`-shared plans.

use super::bluestein::BluesteinPlan;
use super::radix2::Radix2Plan;
use super::Complex;
use crate::sync::{lock, Arc, Mutex};
use std::collections::BTreeMap;

/// A length-specific FFT (radix-2 when possible, Bluestein otherwise).
#[derive(Debug, Clone)]
pub enum Fft {
    Radix2(Arc<Radix2Plan>),
    Bluestein(Arc<BluesteinPlan>),
}

impl Fft {
    pub fn len(&self) -> usize {
        match self {
            Fft::Radix2(p) => p.len(),
            Fft::Bluestein(p) => p.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn forward(&self, x: &mut [Complex]) {
        match self {
            Fft::Radix2(p) => p.forward(x),
            Fft::Bluestein(p) => p.forward(x),
        }
    }

    pub fn inverse(&self, x: &mut [Complex]) {
        match self {
            Fft::Radix2(p) => p.inverse(x),
            Fft::Bluestein(p) => p.inverse(x),
        }
    }
}

fn build_plan(n: usize) -> Fft {
    if n.is_power_of_two() {
        Fft::Radix2(Arc::new(Radix2Plan::new(n)))
    } else {
        Fft::Bluestein(Arc::new(BluesteinPlan::new(n)))
    }
}

/// Thread-safe plan cache shared across the batched engine's workers:
/// one twiddle/bit-reversal table set per length for the whole engine,
/// built once under a short lock and handed out as cheap `Arc`-backed
/// [`Fft`] clones (plans are immutable after construction).
#[derive(Debug, Default)]
pub struct SharedFftPlanner {
    plans: Mutex<BTreeMap<usize, Fft>>,
}

impl SharedFftPlanner {
    pub fn new() -> Self {
        SharedFftPlanner::default()
    }

    /// Get (or build) a plan for length `n`. Plans are built *outside*
    /// the lock so a slow table build (Bluestein is `O(n log n)`) never
    /// blocks workers that only need an already-cached plan; a rare
    /// racing duplicate build is discarded (plans are pure functions of
    /// `n`, so whichever insert wins is numerically identical).
    pub fn plan(&self, n: usize) -> Fft {
        if let Some(f) = lock(&self.plans).get(&n) {
            return f.clone();
        }
        let built = build_plan(n);
        let mut g = lock(&self.plans);
        g.entry(n).or_insert(built).clone()
    }

    /// Number of cached plans (observability for the engine metrics).
    pub fn cached_plans(&self) -> usize {
        lock(&self.plans).len()
    }
}

/// Caches one plan per requested length. Optionally backed by a
/// [`SharedFftPlanner`]: misses then go through the shared cache (plans
/// built once per engine, reused by every worker) while the local map
/// keeps repeat lookups lock-free.
#[derive(Debug, Default)]
pub struct FftPlanner {
    plans: BTreeMap<usize, Fft>,
    shared: Option<Arc<SharedFftPlanner>>,
}

impl FftPlanner {
    pub fn new() -> Self {
        FftPlanner { plans: BTreeMap::new(), shared: None }
    }

    /// A planner whose cache misses are served by `shared`.
    pub fn with_shared(shared: Arc<SharedFftPlanner>) -> Self {
        FftPlanner { plans: BTreeMap::new(), shared: Some(shared) }
    }

    /// Get (or build) a plan for length `n`.
    pub fn plan(&mut self, n: usize) -> Fft {
        if let Some(f) = self.plans.get(&n) {
            return f.clone();
        }
        let fft = match &self.shared {
            Some(s) => s.plan(n),
            None => build_plan(n),
        };
        self.plans.insert(n, fft.clone());
        fft
    }

    /// Number of cached plans (observability for the coordinator metrics).
    pub fn cached_plans(&self) -> usize {
        self.plans.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn planner_caches() {
        let mut p = FftPlanner::new();
        let _ = p.plan(16);
        let _ = p.plan(16);
        let _ = p.plan(12);
        assert_eq!(p.cached_plans(), 2);
    }

    #[test]
    fn planner_picks_backend() {
        let mut p = FftPlanner::new();
        assert!(matches!(p.plan(64), Fft::Radix2(_)));
        assert!(matches!(p.plan(63), Fft::Bluestein(_)));
    }

    #[test]
    fn shared_planner_backs_local_planners() {
        let shared = Arc::new(SharedFftPlanner::new());
        let mut a = FftPlanner::with_shared(shared.clone());
        let mut b = FftPlanner::with_shared(shared.clone());
        let fa = a.plan(32);
        let fb = b.plan(32);
        // Both locals hold the same shared plan instance.
        match (&fa, &fb) {
            (Fft::Radix2(x), Fft::Radix2(y)) => assert!(Arc::ptr_eq(x, y)),
            _ => panic!("expected radix-2 plans"),
        }
        assert_eq!(shared.cached_plans(), 1);
        let _ = a.plan(24);
        assert_eq!(shared.cached_plans(), 2);
        assert_eq!(a.cached_plans(), 2);
        assert_eq!(b.cached_plans(), 1);
    }
}
