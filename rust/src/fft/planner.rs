//! Plan cache. The conv-attention hot loop applies thousands of
//! same-length transforms; building twiddle/bit-reversal tables every
//! call would dominate. `FftPlanner` hands out `Arc`-shared plans.

use super::bluestein::BluesteinPlan;
use super::radix2::Radix2Plan;
use super::Complex;
use std::collections::HashMap;
use std::sync::Arc;

/// A length-specific FFT (radix-2 when possible, Bluestein otherwise).
#[derive(Debug, Clone)]
pub enum Fft {
    Radix2(Arc<Radix2Plan>),
    Bluestein(Arc<BluesteinPlan>),
}

impl Fft {
    pub fn len(&self) -> usize {
        match self {
            Fft::Radix2(p) => p.len(),
            Fft::Bluestein(p) => p.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn forward(&self, x: &mut [Complex]) {
        match self {
            Fft::Radix2(p) => p.forward(x),
            Fft::Bluestein(p) => p.forward(x),
        }
    }

    pub fn inverse(&self, x: &mut [Complex]) {
        match self {
            Fft::Radix2(p) => p.inverse(x),
            Fft::Bluestein(p) => p.inverse(x),
        }
    }
}

/// Caches one plan per requested length.
#[derive(Debug, Default)]
pub struct FftPlanner {
    plans: HashMap<usize, Fft>,
}

impl FftPlanner {
    pub fn new() -> Self {
        FftPlanner { plans: HashMap::new() }
    }

    /// Get (or build) a plan for length `n`.
    pub fn plan(&mut self, n: usize) -> Fft {
        self.plans
            .entry(n)
            .or_insert_with(|| {
                if n.is_power_of_two() {
                    Fft::Radix2(Arc::new(Radix2Plan::new(n)))
                } else {
                    Fft::Bluestein(Arc::new(BluesteinPlan::new(n)))
                }
            })
            .clone()
    }

    /// Number of cached plans (observability for the coordinator metrics).
    pub fn cached_plans(&self) -> usize {
        self.plans.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn planner_caches() {
        let mut p = FftPlanner::new();
        let _ = p.plan(16);
        let _ = p.plan(16);
        let _ = p.plan(12);
        assert_eq!(p.cached_plans(), 2);
    }

    #[test]
    fn planner_picks_backend() {
        let mut p = FftPlanner::new();
        assert!(matches!(p.plan(64), Fft::Radix2(_)));
        assert!(matches!(p.plan(63), Fft::Bluestein(_)));
    }
}
