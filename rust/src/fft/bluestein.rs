//! Bluestein (chirp-z) transform: DFT of arbitrary length `n` via a
//! circular convolution of length `≥ 2n−1` rounded up to a power of two.
//!
//! Needed because sub-convolution windows `m` (Definition 3.9) are
//! arbitrary integers: the recovery algorithm produces whatever `m_i`
//! the binary search finds.

use super::radix2::Radix2Plan;
use super::Complex;
use std::sync::Arc;

/// Precomputed Bluestein plan for a fixed (arbitrary) length.
#[derive(Debug)]
pub struct BluesteinPlan {
    n: usize,
    m: usize,
    inner: Arc<Radix2Plan>,
    /// Chirp `w_j = e^{-iπ j² / n}` for `j < n`.
    chirp: Vec<Complex>,
    /// FFT of the padded conjugate-chirp kernel (precomputed).
    kernel_fft: Vec<Complex>,
}

impl BluesteinPlan {
    pub fn new(n: usize) -> Self {
        assert!(n >= 1);
        let m = (2 * n - 1).next_power_of_two();
        let inner = Arc::new(Radix2Plan::new(m));
        // j² mod 2n to keep the angle argument bounded (avoids precision
        // loss for large n).
        let two_n = 2 * n as u64;
        let chirp: Vec<Complex> = (0..n)
            .map(|j| {
                let jsq = (j as u64 * j as u64) % two_n;
                Complex::cis(-std::f64::consts::PI * jsq as f64 / n as f64)
            })
            .collect();
        let mut kernel = vec![Complex::zero(); m];
        kernel[0] = chirp[0].conj();
        for j in 1..n {
            let c = chirp[j].conj();
            kernel[j] = c;
            kernel[m - j] = c;
        }
        inner.forward(&mut kernel);
        BluesteinPlan { n, m, inner, chirp, kernel_fft: kernel }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.n
    }

    /// Forward DFT, in place over a length-n buffer.
    pub fn forward(&self, x: &mut [Complex]) {
        assert_eq!(x.len(), self.n);
        let mut buf = vec![Complex::zero(); self.m];
        for j in 0..self.n {
            buf[j] = x[j] * self.chirp[j];
        }
        self.inner.forward(&mut buf);
        for (b, k) in buf.iter_mut().zip(&self.kernel_fft) {
            *b = *b * *k;
        }
        self.inner.inverse(&mut buf);
        for j in 0..self.n {
            x[j] = buf[j] * self.chirp[j];
        }
    }

    /// Inverse DFT (with 1/n normalization): conjugate trick.
    pub fn inverse(&self, x: &mut [Complex]) {
        for v in x.iter_mut() {
            *v = v.conj();
        }
        self.forward(x);
        let scale = 1.0 / self.n as f64;
        for v in x.iter_mut() {
            *v = v.conj() * scale;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::dft_naive;

    #[test]
    fn matches_naive_dft_arbitrary_lengths() {
        let mut rng = crate::tensor::Rng::seeded(31);
        for &n in &[1usize, 2, 3, 5, 7, 12, 47, 100, 257] {
            let x: Vec<Complex> =
                (0..n).map(|_| Complex::new(rng.randn(), rng.randn())).collect();
            let want = dft_naive(&x, false);
            let plan = BluesteinPlan::new(n);
            let mut got = x.clone();
            plan.forward(&mut got);
            for (a, b) in want.iter().zip(&got) {
                assert!((a.re - b.re).abs() < 1e-6, "n={n}: {} vs {}", a.re, b.re);
                assert!((a.im - b.im).abs() < 1e-6, "n={n}");
            }
        }
    }

    #[test]
    fn roundtrip_prime_length() {
        let mut rng = crate::tensor::Rng::seeded(32);
        let n = 101;
        let x: Vec<Complex> = (0..n).map(|_| Complex::new(rng.randn(), rng.randn())).collect();
        let plan = BluesteinPlan::new(n);
        let mut y = x.clone();
        plan.forward(&mut y);
        plan.inverse(&mut y);
        for (a, b) in x.iter().zip(&y) {
            assert!((a.re - b.re).abs() < 1e-8);
            assert!((a.im - b.im).abs() < 1e-8);
        }
    }
}
