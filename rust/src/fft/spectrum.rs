//! Spectrum-cached convolution — the §Perf optimization for the k-conv
//! apply (`EXPERIMENTS.md §Perf L3-1`).
//!
//! `apply_matrix` convolves the *same* basis vector against d columns of
//! V. The generic `linear_convolution` packs (a, x) into one transform,
//! which re-transforms `a` every call. Here we:
//!
//! 1. transform the (zero-padded) basis vector **once** per basis,
//! 2. pack **two real columns** per complex forward transform
//!    (`z = x₁ + i·x₂`; the kernel spectrum is from a real sequence, so
//!    by linearity `IFFT(A·Z) = y₁ + i·y₂` exactly),
//!
//! cutting transform count per basis from `2d` to `d + 1`.

use super::{Complex, Fft, FftPlanner};

/// Precomputed spectrum of a real convolution kernel at a fixed FFT size.
#[derive(Clone, Debug)]
pub struct KernelSpectrum {
    /// FFT of the zero-padded kernel.
    spec: Vec<Complex>,
    /// Kernel length (m of the sub-convolution).
    kernel_len: usize,
    fft: Fft,
}

impl KernelSpectrum {
    /// Build for kernel `a` and signal length `sig_len` (the linear
    /// convolution needs `a.len() + sig_len − 1` coefficients).
    pub fn new(planner: &mut FftPlanner, a: &[f64], sig_len: usize) -> Self {
        let out_len = a.len() + sig_len - 1;
        let n = out_len.next_power_of_two();
        let fft = planner.plan(n);
        let mut spec = vec![Complex::zero(); n];
        for (i, &v) in a.iter().enumerate() {
            spec[i].re = v;
        }
        fft.forward(&mut spec);
        KernelSpectrum { spec, kernel_len: a.len(), fft }
    }

    #[inline]
    pub fn fft_len(&self) -> usize {
        self.spec.len()
    }

    /// Convolve one real signal: returns the first `take` coefficients
    /// of `a * x`.
    pub fn conv_one(&self, x: &[f64], take: usize) -> Vec<f64> {
        let n = self.fft_len();
        debug_assert!(self.kernel_len + x.len() - 1 <= n);
        let mut z = vec![Complex::zero(); n];
        for (i, &v) in x.iter().enumerate() {
            z[i].re = v;
        }
        self.fft.forward(&mut z);
        for (zi, ai) in z.iter_mut().zip(&self.spec) {
            *zi = *zi * *ai;
        }
        self.fft.inverse(&mut z);
        z.into_iter().take(take).map(|c| c.re).collect()
    }

    /// Convolve two real signals with ONE forward + ONE inverse
    /// transform (two-for-one packing). Returns the first `take`
    /// coefficients of `a * x₁` and `a * x₂`.
    pub fn conv_pair(&self, x1: &[f64], x2: &[f64], take: usize) -> (Vec<f64>, Vec<f64>) {
        let mut scratch = vec![Complex::zero(); self.fft_len()];
        let mut y1 = vec![0.0; take];
        let mut y2 = vec![0.0; take];
        self.conv_pair_into(x1, x2, &mut scratch, &mut y1, &mut y2);
        (y1, y2)
    }

    /// Allocation-free pair convolution: caller supplies the complex
    /// scratch (length [`Self::fft_len`]) and output slices (§Perf L3-3:
    /// the hot loop reuses one scratch across all column pairs).
    pub fn conv_pair_into(
        &self,
        x1: &[f64],
        x2: &[f64],
        scratch: &mut [Complex],
        y1: &mut [f64],
        y2: &mut [f64],
    ) {
        debug_assert_eq!(x1.len(), x2.len());
        debug_assert_eq!(y1.len(), y2.len());
        let n = self.fft_len();
        assert_eq!(scratch.len(), n);
        for (i, s) in scratch.iter_mut().enumerate() {
            *s = if i < x1.len() { Complex::new(x1[i], x2[i]) } else { Complex::zero() };
        }
        self.fft.forward(scratch);
        for (zi, ai) in scratch.iter_mut().zip(&self.spec) {
            *zi = *zi * *ai;
        }
        self.fft.inverse(scratch);
        for (i, c) in scratch.iter().take(y1.len()).enumerate() {
            y1[i] = c.re;
            y2[i] = c.im;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::linear_convolution;
    use crate::tensor::Rng;

    #[test]
    fn conv_one_matches_linear_convolution() {
        let mut p = FftPlanner::new();
        let mut rng = Rng::seeded(401);
        for &(la, lx) in &[(8usize, 8usize), (16, 5), (33, 33)] {
            let a = rng.randn_vec(la);
            let x = rng.randn_vec(lx);
            let want = linear_convolution(&mut p, &a, &x);
            let spec = KernelSpectrum::new(&mut p, &a, lx);
            let got = spec.conv_one(&x, want.len());
            for (u, v) in got.iter().zip(&want) {
                assert!((u - v).abs() < 1e-8);
            }
        }
    }

    #[test]
    fn conv_pair_matches_two_singles() {
        let mut p = FftPlanner::new();
        let mut rng = Rng::seeded(402);
        let a = rng.randn_vec(24);
        let x1 = rng.randn_vec(24);
        let x2 = rng.randn_vec(24);
        let spec = KernelSpectrum::new(&mut p, &a, 24);
        let take = 24;
        let (y1, y2) = spec.conv_pair(&x1, &x2, take);
        let w1 = spec.conv_one(&x1, take);
        let w2 = spec.conv_one(&x2, take);
        for i in 0..take {
            assert!((y1[i] - w1[i]).abs() < 1e-8);
            assert!((y2[i] - w2[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn spectrum_is_reusable() {
        let mut p = FftPlanner::new();
        let mut rng = Rng::seeded(403);
        let a = rng.randn_vec(16);
        let spec = KernelSpectrum::new(&mut p, &a, 16);
        let x = rng.randn_vec(16);
        let y1 = spec.conv_one(&x, 16);
        let y2 = spec.conv_one(&x, 16);
        assert_eq!(y1, y2);
    }
}
