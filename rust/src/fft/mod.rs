//! From-scratch FFT substrate.
//!
//! Claim 3.7 / 3.10 and Fact B.8 of the paper reduce `conv(a)·x` and
//! `conv(a, m)·x` to circular convolutions, i.e. to FFTs. We implement:
//!
//! * an iterative radix-2 Cooley–Tukey transform with precomputed
//!   bit-reversal and twiddle tables ([`radix2`]),
//! * a Bluestein (chirp-z) fallback so *any* length is supported
//!   ([`bluestein`]) — sub-convolutions have arbitrary sizes `m`,
//! * a [`FftPlanner`] that caches plans per length: the serving hot loop
//!   applies the same-length transform thousands of times.
//!
//! Real-input convolutions pack two real sequences into one complex
//! transform (`linear_convolution` below), halving transform count — one
//! of the §Perf optimizations recorded in EXPERIMENTS.md.

mod bluestein;
mod planner;
mod radix2;
mod spectrum;

pub use planner::{Fft, FftPlanner, SharedFftPlanner};
pub use spectrum::KernelSpectrum;

/// Minimal complex number (we avoid a `num-complex` dependency).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Complex {
    pub re: f64,
    pub im: f64,
}

impl Complex {
    #[inline]
    pub const fn new(re: f64, im: f64) -> Self {
        Complex { re, im }
    }

    #[inline]
    pub const fn zero() -> Self {
        Complex { re: 0.0, im: 0.0 }
    }

    #[inline]
    pub fn conj(self) -> Self {
        Complex { re: self.re, im: -self.im }
    }

    /// `e^{iθ}`.
    #[inline]
    pub fn cis(theta: f64) -> Self {
        Complex { re: theta.cos(), im: theta.sin() }
    }

    #[inline]
    pub fn norm_sqr(self) -> f64 {
        self.re * self.re + self.im * self.im
    }
}

impl std::ops::Add for Complex {
    type Output = Complex;
    #[inline]
    fn add(self, o: Complex) -> Complex {
        Complex { re: self.re + o.re, im: self.im + o.im }
    }
}

impl std::ops::Sub for Complex {
    type Output = Complex;
    #[inline]
    fn sub(self, o: Complex) -> Complex {
        Complex { re: self.re - o.re, im: self.im - o.im }
    }
}

impl std::ops::Mul for Complex {
    type Output = Complex;
    #[inline]
    fn mul(self, o: Complex) -> Complex {
        Complex {
            re: self.re * o.re - self.im * o.im,
            im: self.re * o.im + self.im * o.re,
        }
    }
}

impl std::ops::Mul<f64> for Complex {
    type Output = Complex;
    #[inline]
    fn mul(self, s: f64) -> Complex {
        Complex { re: self.re * s, im: self.im * s }
    }
}

/// Next power of two ≥ `n`.
#[inline]
pub fn next_pow2(n: usize) -> usize {
    n.next_power_of_two()
}

/// Naive O(n²) DFT — the correctness oracle for the fast transforms.
pub fn dft_naive(x: &[Complex], inverse: bool) -> Vec<Complex> {
    let n = x.len();
    let sign = if inverse { 1.0 } else { -1.0 };
    let mut out = vec![Complex::zero(); n];
    for (k, o) in out.iter_mut().enumerate() {
        let mut acc = Complex::zero();
        for (j, &xj) in x.iter().enumerate() {
            let theta = sign * 2.0 * std::f64::consts::PI * (k * j) as f64 / n as f64;
            acc = acc + xj * Complex::cis(theta);
        }
        *o = if inverse { acc * (1.0 / n as f64) } else { acc };
    }
    out
}

/// Linear convolution of two real sequences via one complex FFT
/// (packing trick: `z = a + i·b`, unpack via conjugate symmetry).
///
/// Returns `a.len() + b.len() - 1` coefficients:
/// `out[t] = Σ_{i+j=t} a[i]·b[j]`.
pub fn linear_convolution(planner: &mut FftPlanner, a: &[f64], b: &[f64]) -> Vec<f64> {
    if a.is_empty() || b.is_empty() {
        return vec![];
    }
    let out_len = a.len() + b.len() - 1;
    let n = next_pow2(out_len);
    let fft = planner.plan(n);

    // Pack a into the real part, b into the imaginary part.
    let mut z = vec![Complex::zero(); n];
    for (i, &ai) in a.iter().enumerate() {
        z[i].re = ai;
    }
    for (i, &bi) in b.iter().enumerate() {
        z[i].im = bi;
    }
    fft.forward(&mut z);

    // With Z = FFT(a + i b):  A[k] = (Z[k] + conj(Z[n-k]))/2,
    //                          B[k] = (Z[k] - conj(Z[n-k]))/(2i).
    // We need C[k] = A[k]·B[k]; compute in place.
    let mut c = vec![Complex::zero(); n];
    for k in 0..n {
        let zk = z[k];
        let znk = z[(n - k) % n].conj();
        let ak = (zk + znk) * 0.5;
        // B[k] = (Z[k] − conj(Z[n−k])) / (2i) = −(i/2)·(Z[k] − conj(Z[n−k]))
        let diff = zk - znk;
        let bk = Complex::new(diff.im * 0.5, -diff.re * 0.5);
        c[k] = ak * bk;
    }
    fft.inverse(&mut c);
    c.truncate(out_len);
    c.into_iter().map(|v| v.re).collect()
}

/// Circular convolution of two real length-n sequences (Fact B.8:
/// `Circ(a)·x = F⁻¹ diag(F a) F x`).
pub fn circular_convolution(planner: &mut FftPlanner, a: &[f64], x: &[f64]) -> Vec<f64> {
    assert_eq!(a.len(), x.len());
    let n = a.len();
    let lin = linear_convolution(planner, a, x);
    // Fold the tail back (indices ≥ n wrap modulo n).
    let mut out = vec![0.0; n];
    for (t, &v) in lin.iter().enumerate() {
        out[t % n] += v;
    }
    out
}

/// FLOP estimate for an FFT-based length-n linear convolution
/// (3 transforms of size 2n, 5·N·log₂N flops each, plus pointwise
/// products). Used by the Figure 1a FLOP series.
pub fn fft_conv_flops(n: usize) -> f64 {
    let padded = next_pow2(2 * n) as f64;
    3.0 * 5.0 * padded * padded.log2() + 6.0 * padded
}

/// FLOP count of a naive length-n convolution-matrix multiply
/// (`conv(a)·x`: n(n+1)/2 multiply-adds).
pub fn naive_conv_flops(n: usize) -> f64 {
    (n as f64) * (n as f64 + 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_close(a: &[f64], b: &[f64], tol: f64) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert!((x - y).abs() < tol, "{x} vs {y}");
        }
    }

    #[test]
    fn linear_convolution_small() {
        let mut p = FftPlanner::new();
        // (1 + 2x)(3 + 4x) = 3 + 10x + 8x^2
        let out = linear_convolution(&mut p, &[1.0, 2.0], &[3.0, 4.0]);
        assert_close(&out, &[3.0, 10.0, 8.0], 1e-9);
    }

    #[test]
    fn linear_convolution_matches_naive() {
        let mut p = FftPlanner::new();
        let mut rng = crate::tensor::Rng::seeded(11);
        for &(la, lb) in &[(1, 1), (5, 3), (17, 17), (64, 10), (100, 100)] {
            let a = rng.randn_vec(la);
            let b = rng.randn_vec(lb);
            let fast = linear_convolution(&mut p, &a, &b);
            let mut naive = vec![0.0; la + lb - 1];
            for i in 0..la {
                for j in 0..lb {
                    naive[i + j] += a[i] * b[j];
                }
            }
            assert_close(&fast, &naive, 1e-8);
        }
    }

    #[test]
    fn circular_convolution_matches_matrix() {
        let mut p = FftPlanner::new();
        let mut rng = crate::tensor::Rng::seeded(12);
        let n = 13;
        let a = rng.randn_vec(n);
        let x = rng.randn_vec(n);
        let fast = circular_convolution(&mut p, &a, &x);
        // Circ(a)[i][j] = a[(i - j) mod n]
        let mut naive = vec![0.0; n];
        for i in 0..n {
            for j in 0..n {
                naive[i] += a[(i + n - j) % n] * x[j];
            }
        }
        assert_close(&fast, &naive, 1e-9);
    }

    #[test]
    fn dft_naive_roundtrip() {
        let x: Vec<Complex> =
            (0..8).map(|i| Complex::new(i as f64, (i as f64).cos())).collect();
        let f = dft_naive(&x, false);
        let back = dft_naive(&f, true);
        for (a, b) in x.iter().zip(&back) {
            assert!((a.re - b.re).abs() < 1e-9 && (a.im - b.im).abs() < 1e-9);
        }
    }

    #[test]
    fn empty_convolution() {
        let mut p = FftPlanner::new();
        assert!(linear_convolution(&mut p, &[], &[1.0]).is_empty());
    }

    #[test]
    fn flop_models_ordering() {
        // FFT flops should beat naive flops for large n.
        assert!(fft_conv_flops(8192) < naive_conv_flops(8192));
        // ... and lose for tiny n.
        assert!(fft_conv_flops(8) > naive_conv_flops(8));
    }
}
