//! Training loops: language modeling on the synthetic corpus and
//! sentiment classification (the Figure 4 model).

use super::backend::AttentionBackend;
use super::optim::Adam;
use super::transformer::{ModelConfig, Transformer};
use crate::data::{ByteTokenizer, SentimentDataset, SyntheticCorpus};
use crate::tensor::Rng;

/// Training hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr: f64,
    pub seq_len: usize,
    /// Gradient accumulation: sequences per optimizer step.
    pub batch: usize,
    pub log_every: usize,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { steps: 200, lr: 3e-3, seq_len: 64, batch: 4, log_every: 20, seed: 0 }
    }
}

/// Per-step training telemetry.
#[derive(Clone, Debug, Default)]
pub struct TrainLog {
    /// (step, mean loss) pairs at `log_every` cadence.
    pub losses: Vec<(usize, f64)>,
    pub final_loss: f64,
}

/// Train a language model on the synthetic corpus. Returns the trained
/// model and the loss curve (the e2e deliverable's loss log).
pub fn train_lm(model_cfg: &ModelConfig, cfg: &TrainConfig, corpus_bytes: usize) -> (Transformer, TrainLog) {
    let mut rng = Rng::seeded(cfg.seed);
    let mut model = Transformer::new(model_cfg, &mut rng);
    let mut opt = Adam::new(cfg.lr);
    let tok = ByteTokenizer::new();
    let corpus = SyntheticCorpus::generate(corpus_bytes, cfg.seed.wrapping_add(1));
    let windows = corpus.windows(&tok, cfg.seq_len);
    assert!(!windows.is_empty(), "corpus too small for seq_len");

    let mut log = TrainLog::default();
    let mut running = 0.0;
    let mut running_n = 0usize;
    for step in 0..cfg.steps {
        let mut grads = model.zero_grads();
        let mut batch_loss = 0.0;
        for b in 0..cfg.batch {
            let (x, y) = &windows[(step * cfg.batch + b) % windows.len()];
            let rec = model.forward(x, &AttentionBackend::Exact, true);
            let (loss, dlogits) = model.lm_loss(&rec, y, ByteTokenizer::PAD);
            batch_loss += loss;
            model.backward(&rec, &dlogits, None, &mut grads);
        }
        scale_grads(&mut grads, 1.0 / cfg.batch as f64);
        opt.step(&mut model, &grads);
        batch_loss /= cfg.batch as f64;
        running += batch_loss;
        running_n += 1;
        if (step + 1) % cfg.log_every == 0 || step + 1 == cfg.steps {
            log.losses.push((step + 1, running / running_n as f64));
            running = 0.0;
            running_n = 0;
        }
        log.final_loss = batch_loss;
    }
    (model, log)
}

/// Train the sentiment classifier (LM-style init, classification loss
/// only — enough signal for the synthetic task).
pub fn train_classifier(
    model_cfg: &ModelConfig,
    cfg: &TrainConfig,
    dataset: &SentimentDataset,
) -> (Transformer, TrainLog) {
    let mut rng = Rng::seeded(cfg.seed);
    let mut model = Transformer::new(model_cfg, &mut rng);
    let mut opt = Adam::new(cfg.lr);
    let tok = ByteTokenizer::new();
    let mut log = TrainLog::default();
    let mut running = 0.0;
    let mut running_n = 0usize;
    for step in 0..cfg.steps {
        let mut grads = model.zero_grads();
        let mut batch_loss = 0.0;
        for b in 0..cfg.batch {
            let ex = &dataset.train[(step * cfg.batch + b) % dataset.train.len()];
            let tokens = tok.encode_for_classification(&ex.text, cfg.seq_len);
            let rec = model.forward(&tokens, &AttentionBackend::Exact, true);
            let (loss, _, dcls) = model.cls_loss(&rec, ex.label);
            batch_loss += loss;
            let zero = crate::tensor::Matrix::zeros(tokens.len(), model_cfg.vocab_size);
            model.backward(&rec, &zero, Some(dcls), &mut grads);
        }
        scale_grads(&mut grads, 1.0 / cfg.batch as f64);
        opt.step(&mut model, &grads);
        batch_loss /= cfg.batch as f64;
        running += batch_loss;
        running_n += 1;
        if (step + 1) % cfg.log_every == 0 || step + 1 == cfg.steps {
            log.losses.push((step + 1, running / running_n as f64));
            running = 0.0;
            running_n = 0;
        }
        log.final_loss = batch_loss;
    }
    (model, log)
}

/// Evaluate classification accuracy under the given attention backend.
pub fn eval_classifier(
    model: &Transformer,
    dataset: &[crate::data::SentimentExample],
    seq_len: usize,
    backend: &AttentionBackend,
) -> f64 {
    let tok = ByteTokenizer::new();
    let mut correct = 0usize;
    for ex in dataset {
        let tokens = tok.encode_for_classification(&ex.text, seq_len);
        let rec = model.forward(&tokens, backend, false);
        let logits = model.classify(&rec);
        let pred = logits[1] > logits[0];
        if pred == ex.label {
            correct += 1;
        }
    }
    correct as f64 / dataset.len().max(1) as f64
}

fn scale_grads(g: &mut super::transformer::Gradients, s: f64) {
    for x in g.embed.data_mut() {
        *x *= s;
    }
    for x in g.head.data_mut() {
        *x *= s;
    }
    for x in g.cls_head.data_mut() {
        *x *= s;
    }
    for x in g.lnf_g.iter_mut() {
        *x *= s;
    }
    for l in &mut g.layers {
        for m in [&mut l.wq, &mut l.wk, &mut l.wv, &mut l.wo, &mut l.w1, &mut l.w2] {
            for x in m.data_mut() {
                *x *= s;
            }
        }
        for x in l.ln1_g.iter_mut().chain(l.ln2_g.iter_mut()) {
            *x *= s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lm_training_loss_decreases() {
        let mcfg = ModelConfig {
            vocab_size: 260,
            d_model: 16,
            n_heads: 2,
            n_layers: 1,
            d_ff: 32,
            max_seq: 32,
        };
        let tcfg = TrainConfig { steps: 40, lr: 3e-3, seq_len: 32, batch: 2, log_every: 10, seed: 3 };
        let (_, log) = train_lm(&mcfg, &tcfg, 4000);
        let first = log.losses.first().unwrap().1;
        let last = log.losses.last().unwrap().1;
        assert!(last < first, "loss {first} → {last}");
    }

    #[test]
    fn classifier_beats_chance_quickly() {
        let mcfg = ModelConfig {
            vocab_size: 260,
            d_model: 16,
            n_heads: 2,
            n_layers: 1,
            d_ff: 32,
            max_seq: 48,
        };
        let ds = SentimentDataset::generate(64, 32, 9);
        let tcfg =
            TrainConfig { steps: 60, lr: 3e-3, seq_len: 48, batch: 4, log_every: 20, seed: 4 };
        let (model, _) = train_classifier(&mcfg, &tcfg, &ds);
        let acc = eval_classifier(&model, &ds.test, 48, &AttentionBackend::Exact);
        assert!(acc > 0.6, "accuracy = {acc}");
    }
}
