//! Training loops: language modeling on the synthetic corpus,
//! sentiment classification (the Figure 4 model), and **batched
//! attention-head training** through the engine's gradient lane
//! ([`train_attention_heads`]): every (layer, head) Definition 5.1
//! gradient of a step is one `GradJob` in one
//! [`BatchedEngine::submit`] call, sharing the engine's FFT plans and
//! recovered-basis cache — the Theorem 5.6 training path, finally
//! pooled like the forward paths.
//!
//! The full-transformer loops ([`train_lm`] / [`train_classifier`])
//! route **both halves of every optimizer step** the same way: the
//! forward through one prefill-lane submit of training jobs per layer
//! (`Transformer::forward_train_batch`, exact or conv per
//! [`TrainAttentionMode`]) and the backward through the LM-backward
//! lane (`Transformer::backward_batch_with_engine` — one submit per
//! layer spanning all (sequence, head) pairs). In conv mode the two
//! halves share one basis recovery per (record, layer, head) per step
//! — the forward recovers, the backward consumes the step-scoped
//! handle — so training runs end-to-end in almost linear time with
//! **no `n×n` matrix anywhere** and zero writes to the serving
//! `BasisCache`.
//!
//! [`BatchedEngine::submit`]: crate::attention::batched::BatchedEngine::submit

use super::backend::AttentionBackend;
use super::optim::Adam;
use super::transformer::{ForwardRecord, ModelConfig, Transformer};
use crate::attention::batched::{BatchedEngine, EngineConfig, EngineJob};
use crate::attention::ExactKernel;
use crate::basis::RecoverConfig;
use crate::data::{ByteTokenizer, SentimentDataset, SyntheticCorpus};
use crate::gradient::batched::{AttnBackwardMode, FastGradConfig, GradJob};
use crate::gradient::AttentionLossProblem;
use crate::tensor::{Matrix, Rng};
use std::sync::Arc;

/// Which attention operator the **training forward** runs — the knob
/// that makes training end-to-end conv-capable (the paper's Theorem 5.6
/// / arXiv:2408.13233 claim: forward *and* backward in almost linear
/// time, through one shared low-complexity structure).
///
/// * [`Exact`](TrainAttentionMode::Exact) — the `O(n²)` softmax kernel;
///   softmax rows are retained for the backward (the PR-4 behavior).
/// * [`Conv`](TrainAttentionMode::Conv) — Algorithm 1 with the given
///   recovery budget: each (record, layer, head) operator basis is
///   recovered **once per optimizer step** by the forward and consumed
///   for free by the conv backward (the step-scoped handle — see
///   `Transformer::forward_train_batch`), so no basis is recovered
///   twice in a step and nothing is written to the serving
///   `BasisCache` shards. Requires the [`AttnBackwardMode::Fast`]
///   backward: the conv forward never materializes the softmax rows
///   the exact backward needs (fallback heads still carry them, which
///   is what keeps a failed recovery bit-equal to exact training).
#[derive(Clone, Copy, Debug)]
pub enum TrainAttentionMode {
    /// Exact `O(n²)` training forward.
    Exact,
    /// Conv-basis training forward with this recovery budget, sharing
    /// each recovered basis with the backward.
    Conv(RecoverConfig),
}

/// Training hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr: f64,
    pub seq_len: usize,
    /// Gradient accumulation: sequences per optimizer step.
    pub batch: usize,
    pub log_every: usize,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { steps: 200, lr: 3e-3, seq_len: 64, batch: 4, log_every: 20, seed: 0 }
    }
}

/// Per-step training telemetry.
#[derive(Clone, Debug, Default)]
pub struct TrainLog {
    /// (step, mean loss) pairs at `log_every` cadence.
    pub losses: Vec<(usize, f64)>,
    pub final_loss: f64,
    /// Per optimizer step: conv-forward jobs whose recovery fell back
    /// to the exact kernel that step (all zeros in
    /// [`TrainAttentionMode::Exact`] and when every recovery succeeds).
    /// A fallback degrades cost, never the curve — the fallback kernel
    /// is bit-equal to the exact forward — so this is the lever for
    /// mid-curve alarms: a structural break in the weights shows up
    /// here steps before it would show in the loss.
    pub step_fwd_fallbacks: Vec<usize>,
}

/// Train a language model on the synthetic corpus. Returns the trained
/// model and the loss curve (the e2e deliverable's loss log).
///
/// Routes the whole step through a private [`BatchedEngine`] in
/// [`TrainAttentionMode::Exact`] / row-stream [`AttnBackwardMode::Exact`] —
/// bit-identical weights to the pre-engine dense loop (see
/// [`train_lm_with_engine`] to share an engine or select the conv-basis
/// forward/backward).
pub fn train_lm(
    model_cfg: &ModelConfig,
    cfg: &TrainConfig,
    corpus_bytes: usize,
) -> (Transformer, TrainLog) {
    let engine = BatchedEngine::new(EngineConfig::default());
    train_lm_with_engine(
        model_cfg,
        cfg,
        corpus_bytes,
        &engine,
        &TrainAttentionMode::Exact,
        &AttnBackwardMode::Exact(ExactKernel::RowStream),
    )
}

/// [`train_lm`] over a caller-owned engine: each optimizer step runs
/// **one [`Transformer::forward_train_batch`] call** (every (record,
/// head) attention of a layer in one prefill-lane submit of training
/// jobs, activations retained), then **one
/// [`Transformer::backward_batch_with_engine`] call** — every
/// (sequence, layer, head) attention backward of the step flows
/// through the engine's LM-backward lane, one submit per layer
/// spanning the whole micro-batch.
///
/// `fwd` selects the training-forward operator; `bwd` the backward
/// kernel. The end-to-end conv configuration is
/// `(TrainAttentionMode::Conv(cfg), AttnBackwardMode::Fast(..))`: the
/// forward recovers each (record, layer, head) basis once per step and
/// the backward consumes the shared handle — no double recovery, no
/// serving-cache writes (`tests/train_conv.rs` pins both with engine
/// counters). A conv forward with the exact backward is rejected: the
/// conv path never materializes the softmax rows the exact kernel
/// needs. A fast `bwd`'s `use_cache` is forced off inside the loop —
/// weights change every step, so caching each step's operator basis
/// could only evict live serving entries from a shared engine (same
/// policy as [`train_attention_heads`]).
///
/// Memory note: batching per layer means the whole micro-batch's
/// forward activations (incl. per-head softmax rows in exact mode) are
/// live at once — peak activation memory scales with `cfg.batch`,
/// where the old per-record dense loop peaked at one record. Shrink
/// `batch` (trading submit width) if that matters at long `seq_len`.
/// Conv mode replaces each head's `n×n` softmax rows with its `O(k·n)`
/// basis handle — the training-forward memory win.
pub fn train_lm_with_engine(
    model_cfg: &ModelConfig,
    cfg: &TrainConfig,
    corpus_bytes: usize,
    engine: &BatchedEngine,
    fwd: &TrainAttentionMode,
    bwd: &AttnBackwardMode,
) -> (Transformer, TrainLog) {
    let bwd = &no_dead_cache_writes(bwd);
    assert_conv_modes_compatible(fwd, bwd);
    let mut rng = Rng::seeded(cfg.seed);
    let mut model = Transformer::new(model_cfg, &mut rng);
    let mut opt = Adam::new(cfg.lr);
    let tok = ByteTokenizer::new();
    let corpus = SyntheticCorpus::generate(corpus_bytes, cfg.seed.wrapping_add(1));
    let windows = corpus.windows(&tok, cfg.seq_len);
    assert!(!windows.is_empty(), "corpus too small for seq_len");

    let mut log = TrainLog::default();
    let mut running = 0.0;
    let mut running_n = 0usize;
    for step in 0..cfg.steps {
        let mut grads = model.zero_grads();
        let mut batch_loss = 0.0;
        // Forward the whole micro-batch in one engine-routed call
        // (retaining activations + per-head backward artifacts), then
        // backward it in one engine-routed call per layer.
        let mut seqs: Vec<Vec<usize>> = Vec::with_capacity(cfg.batch);
        let mut targets: Vec<&Vec<usize>> = Vec::with_capacity(cfg.batch);
        for b in 0..cfg.batch {
            let (x, y) = &windows[(step * cfg.batch + b) % windows.len()];
            seqs.push(x.clone());
            targets.push(y);
        }
        let (recs, fwd_fallbacks) = model.forward_train_batch(&seqs, fwd, engine);
        let mut dls: Vec<Matrix> = Vec::with_capacity(cfg.batch);
        for (rec, y) in recs.iter().zip(&targets) {
            let (loss, dlogits) = model.lm_loss(rec, y.as_slice(), ByteTokenizer::PAD);
            batch_loss += loss;
            dls.push(dlogits);
        }
        let batch: Vec<(&ForwardRecord, &Matrix, Option<[f64; 2]>)> =
            recs.iter().zip(&dls).map(|(r, dl)| (r, dl, None)).collect();
        model.backward_batch_with_engine(&batch, &mut grads, engine, bwd);
        drop(batch);
        scale_grads(&mut grads, 1.0 / cfg.batch as f64);
        opt.step(&mut model, &grads);
        batch_loss /= cfg.batch as f64;
        running += batch_loss;
        running_n += 1;
        if (step + 1) % cfg.log_every == 0 || step + 1 == cfg.steps {
            log.losses.push((step + 1, running / running_n as f64));
            running = 0.0;
            running_n = 0;
        }
        log.final_loss = batch_loss;
        log.step_fwd_fallbacks.push(fwd_fallbacks);
    }
    (model, log)
}

/// Train the sentiment classifier (LM-style init, classification loss
/// only — enough signal for the synthetic task). Backward is
/// engine-routed exactly like [`train_lm`].
pub fn train_classifier(
    model_cfg: &ModelConfig,
    cfg: &TrainConfig,
    dataset: &SentimentDataset,
) -> (Transformer, TrainLog) {
    let engine = BatchedEngine::new(EngineConfig::default());
    train_classifier_with_engine(
        model_cfg,
        cfg,
        dataset,
        &engine,
        &TrainAttentionMode::Exact,
        &AttnBackwardMode::Exact(ExactKernel::RowStream),
    )
}

/// [`train_classifier`] over a caller-owned engine — see
/// [`train_lm_with_engine`] for the mode knobs and the
/// batching/bit-identity contract (and the forced `use_cache: false` /
/// peak-memory notes).
pub fn train_classifier_with_engine(
    model_cfg: &ModelConfig,
    cfg: &TrainConfig,
    dataset: &SentimentDataset,
    engine: &BatchedEngine,
    fwd: &TrainAttentionMode,
    bwd: &AttnBackwardMode,
) -> (Transformer, TrainLog) {
    let bwd = &no_dead_cache_writes(bwd);
    assert_conv_modes_compatible(fwd, bwd);
    let mut rng = Rng::seeded(cfg.seed);
    let mut model = Transformer::new(model_cfg, &mut rng);
    let mut opt = Adam::new(cfg.lr);
    let tok = ByteTokenizer::new();
    let mut log = TrainLog::default();
    let mut running = 0.0;
    let mut running_n = 0usize;
    for step in 0..cfg.steps {
        let mut grads = model.zero_grads();
        let mut batch_loss = 0.0;
        let mut seqs: Vec<Vec<usize>> = Vec::with_capacity(cfg.batch);
        let mut labels: Vec<bool> = Vec::with_capacity(cfg.batch);
        for b in 0..cfg.batch {
            let ex = &dataset.train[(step * cfg.batch + b) % dataset.train.len()];
            seqs.push(tok.encode_for_classification(&ex.text, cfg.seq_len));
            labels.push(ex.label);
        }
        let (recs, fwd_fallbacks) = model.forward_train_batch(&seqs, fwd, engine);
        let mut items: Vec<(Matrix, [f64; 2])> = Vec::with_capacity(cfg.batch);
        for (rec, (tokens, &label)) in recs.iter().zip(seqs.iter().zip(&labels)) {
            let (loss, _, dcls) = model.cls_loss(rec, label);
            batch_loss += loss;
            let zero = crate::tensor::Matrix::zeros(tokens.len(), model_cfg.vocab_size);
            items.push((zero, dcls));
        }
        let batch: Vec<(&ForwardRecord, &Matrix, Option<[f64; 2]>)> = recs
            .iter()
            .zip(&items)
            .map(|(r, (zero, dcls))| (r, zero, Some(*dcls)))
            .collect();
        model.backward_batch_with_engine(&batch, &mut grads, engine, bwd);
        drop(batch);
        log.step_fwd_fallbacks.push(fwd_fallbacks);
        scale_grads(&mut grads, 1.0 / cfg.batch as f64);
        opt.step(&mut model, &grads);
        batch_loss /= cfg.batch as f64;
        running += batch_loss;
        running_n += 1;
        if (step + 1) % cfg.log_every == 0 || step + 1 == cfg.steps {
            log.losses.push((step + 1, running / running_n as f64));
            running = 0.0;
            running_n = 0;
        }
        log.final_loss = batch_loss;
    }
    (model, log)
}

/// Evaluate classification accuracy under the given attention backend.
pub fn eval_classifier(
    model: &Transformer,
    dataset: &[crate::data::SentimentExample],
    seq_len: usize,
    backend: &AttentionBackend,
) -> f64 {
    let tok = ByteTokenizer::new();
    let mut correct = 0usize;
    for ex in dataset {
        let tokens = tok.encode_for_classification(&ex.text, seq_len);
        let rec = model.forward(&tokens, backend, false);
        let logits = model.classify(&rec);
        let pred = logits[1] > logits[0];
        if pred == ex.label {
            correct += 1;
        }
    }
    correct as f64 / dataset.len().max(1) as f64
}

/// One attention head's Definition 5.1 training instance, addressed by
/// its (layer, head) slot (the engine cache key / shard coordinates).
#[derive(Clone, Debug)]
pub struct HeadProblem {
    pub layer: u32,
    pub head: u32,
    pub problem: AttentionLossProblem,
}

/// Hyper-parameters for [`train_attention_heads`].
#[derive(Clone, Copy, Debug)]
pub struct HeadTrainConfig {
    /// Gradient-descent steps.
    pub steps: usize,
    /// Fixed learning rate (the per-problem Armijo solver lives in
    /// `gradient::optimize`; batched training trades line search for
    /// one engine call per step).
    pub lr: f64,
    /// Fast-gradient configuration shared by every head. `use_cache`
    /// is forced off inside the loop: GD evaluates each `X` once, so
    /// caching its operator basis could only evict live serving
    /// entries (per-evaluation cache reuse remains available to
    /// direct `GradJob` submitters).
    pub grad: FastGradConfig,
}

/// Per-head training trace from [`train_attention_heads`]: the final
/// `X` and the loss at every step (read off the gradient jobs'
/// residuals — no separate forward passes).
#[derive(Clone, Debug)]
pub struct HeadTrainResult {
    pub layer: u32,
    pub head: u32,
    pub x: Matrix,
    pub losses: Vec<f64>,
    /// Gradient jobs that fell back to the dense oracle.
    pub fallbacks: usize,
}

/// Gradient-descent over a set of attention-head problems with **all
/// (layer, head) gradients of each step evaluated in one
/// [`BatchedEngine::submit`] call** — the engine fans the `GradJob`s
/// over its worker pool exactly like prefill/decode work, so
/// multi-head training parallelizes without per-head threads, and the
/// per-job losses come back for free from the backward residual.
///
/// Starting point is `X = 0` per head (the Definition 5.1 convention).
/// Results are deterministic for any engine worker count: gradient
/// jobs are pure and the engine orders results by input index.
///
/// [`BatchedEngine::submit`]: crate::attention::batched::BatchedEngine::submit
pub fn train_attention_heads(
    heads: &[HeadProblem],
    engine: &BatchedEngine,
    cfg: &HeadTrainConfig,
) -> Vec<HeadTrainResult> {
    let mut results: Vec<HeadTrainResult> = heads
        .iter()
        .map(|h| HeadTrainResult {
            layer: h.layer,
            head: h.head,
            x: Matrix::zeros(h.problem.d(), h.problem.d()),
            losses: Vec::with_capacity(cfg.steps),
            fallbacks: 0,
        })
        .collect();
    // One deep copy per head for the whole run; each step's jobs then
    // share the problem data by Arc (it is immutable across steps).
    let problems: Vec<Arc<AttentionLossProblem>> =
        heads.iter().map(|h| Arc::new(h.problem.clone())).collect();
    // GD never revisits an X, so every cache write here would be a
    // dead entry whose only effect is evicting live serving bases from
    // the shared (layer, head) shard — keep training out of the cache.
    let grad_cfg = FastGradConfig { use_cache: false, ..cfg.grad };
    for _ in 0..cfg.steps {
        let jobs: Vec<EngineJob> = heads
            .iter()
            .zip(&results)
            .zip(&problems)
            .enumerate()
            .map(|(i, ((h, r), p))| {
                EngineJob::gradient(
                    i as u64,
                    GradJob {
                        layer: h.layer,
                        head: h.head,
                        problem: Arc::clone(p),
                        x: r.x.clone(),
                        cfg: grad_cfg,
                    },
                )
            })
            .collect();
        // The one door: every head's backward in a single engine call.
        let outs = engine.submit(jobs);
        for (r, out) in results.iter_mut().zip(outs) {
            let g = out.result.into_gradient();
            r.losses.push(g.loss);
            r.fallbacks += g.fell_back as usize;
            r.x.axpy_mat(-cfg.lr, &g.grad);
        }
    }
    results
}

/// The conv training forward never materializes softmax rows, and the
/// exact backward kernel consumes nothing else — reject the broken
/// combination up front instead of panicking per job mid-curve.
/// (Conv-forward fallback heads *do* retain probs, which is what keeps
/// a failed recovery bit-equal to exact training under the Fast
/// backward's dense fallback — but an all-exact backward would still
/// die on the first head that recovered successfully.)
fn assert_conv_modes_compatible(fwd: &TrainAttentionMode, bwd: &AttnBackwardMode) {
    if matches!(fwd, TrainAttentionMode::Conv(_)) {
        assert!(
            matches!(bwd, AttnBackwardMode::Fast(_)),
            "TrainAttentionMode::Conv requires AttnBackwardMode::Fast: the conv forward \
             shares its recovered basis with the conv backward and never materializes \
             the softmax rows the exact backward kernel needs"
        );
    }
}

/// Training never revisits a (Q, K) — weights change every optimizer
/// step — so a fast backward's basis-cache writes are dead entries
/// whose only effect is evicting live serving bases from a shared
/// engine's (layer, head) shards. Force `use_cache` off (the
/// [`train_attention_heads`] policy, applied to the LM loops).
fn no_dead_cache_writes(mode: &AttnBackwardMode) -> AttnBackwardMode {
    match mode {
        AttnBackwardMode::Exact(kernel) => AttnBackwardMode::Exact(*kernel),
        AttnBackwardMode::Fast(cfg) => {
            AttnBackwardMode::Fast(FastGradConfig { use_cache: false, ..*cfg })
        }
    }
}

fn scale_grads(g: &mut super::transformer::Gradients, s: f64) {
    for x in g.embed.data_mut() {
        *x *= s;
    }
    for x in g.head.data_mut() {
        *x *= s;
    }
    for x in g.cls_head.data_mut() {
        *x *= s;
    }
    for x in g.lnf_g.iter_mut() {
        *x *= s;
    }
    for l in &mut g.layers {
        for m in [&mut l.wq, &mut l.wk, &mut l.wv, &mut l.wo, &mut l.w1, &mut l.w2] {
            for x in m.data_mut() {
                *x *= s;
            }
        }
        for x in l.ln1_g.iter_mut().chain(l.ln2_g.iter_mut()) {
            *x *= s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lm_training_loss_decreases() {
        let mcfg = ModelConfig {
            vocab_size: 260,
            d_model: 16,
            n_heads: 2,
            n_layers: 1,
            d_ff: 32,
            max_seq: 32,
        };
        let tcfg = TrainConfig { steps: 40, lr: 3e-3, seq_len: 32, batch: 2, log_every: 10, seed: 3 };
        let (_, log) = train_lm(&mcfg, &tcfg, 4000);
        let first = log.losses.first().unwrap().1;
        let last = log.losses.last().unwrap().1;
        assert!(last < first, "loss {first} → {last}");
    }

    #[test]
    fn attention_heads_train_through_one_submit_per_step() {
        use crate::attention::batched::{BatchedEngine, EngineConfig};
        let n = 16;
        let steps = 15;
        let mut rng = Rng::seeded(21);
        let heads: Vec<HeadProblem> = (0..2u32)
            .flat_map(|layer| (0..2u32).map(move |head| (layer, head)))
            .map(|(layer, head)| HeadProblem {
                layer,
                head,
                problem: AttentionLossProblem::random_structured(n, 3, &mut rng),
            })
            .collect();
        let engine = BatchedEngine::new(EngineConfig { workers: 2, cache_capacity: 64 });
        let cfg = HeadTrainConfig { steps, lr: 0.5, grad: FastGradConfig::exact(n) };
        let results = train_attention_heads(&heads, &engine, &cfg);
        assert_eq!(results.len(), 4);
        for r in &results {
            assert_eq!(r.losses.len(), steps);
            assert_eq!(r.fallbacks, 0);
            let (first, last) = (r.losses[0], *r.losses.last().unwrap());
            assert!(
                last < first,
                "head ({}, {}) loss did not decrease: {first} → {last}",
                r.layer,
                r.head
            );
        }
        // The tentpole claim: one engine call per training step, all
        // (layer, head) gradients inside it.
        let snap = engine.metrics().snapshot();
        assert_eq!(snap.grad_calls, steps as u64);
        assert_eq!(snap.submit_calls, steps as u64);
        assert_eq!(snap.grad_jobs, (steps * heads.len()) as u64);
    }

    #[test]
    fn train_lm_routes_backward_through_engine_lane() {
        use crate::attention::batched::{BatchedEngine, EngineConfig};
        let mcfg = ModelConfig {
            vocab_size: 260,
            d_model: 16,
            n_heads: 2,
            n_layers: 2,
            d_ff: 32,
            max_seq: 16,
        };
        let tcfg = TrainConfig { steps: 3, lr: 3e-3, seq_len: 16, batch: 2, log_every: 1, seed: 7 };
        let engine = BatchedEngine::new(EngineConfig { workers: 2, cache_capacity: 16 });
        let (_, log) = train_lm_with_engine(
            &mcfg,
            &tcfg,
            2000,
            &engine,
            &TrainAttentionMode::Exact,
            &AttnBackwardMode::Exact(ExactKernel::RowStream),
        );
        assert!(log.final_loss.is_finite());
        assert_eq!(log.step_fwd_fallbacks, vec![0; tcfg.steps]);
        let snap = engine.metrics().snapshot();
        // One submit per layer per step, each carrying every
        // (sequence, head) job of the micro-batch.
        assert_eq!(snap.lm_backward_calls, (tcfg.steps * mcfg.n_layers) as u64);
        assert_eq!(
            snap.lm_backward_jobs,
            (tcfg.steps * tcfg.batch * mcfg.n_layers * mcfg.n_heads) as u64
        );
        assert_eq!(snap.lm_backward_fallbacks, 0, "exact mode never falls back");
        // The forward now rides the engine too: one prefill-lane submit
        // per layer per step (exact training jobs, so no conv counters).
        assert_eq!(snap.batched_calls, (tcfg.steps * mcfg.n_layers) as u64);
        assert_eq!(
            snap.batched_jobs,
            (tcfg.steps * tcfg.batch * mcfg.n_layers * mcfg.n_heads) as u64
        );
        assert_eq!(snap.train_fwd_conv_calls, 0);
    }

    #[test]
    fn classifier_beats_chance_quickly() {
        let mcfg = ModelConfig {
            vocab_size: 260,
            d_model: 16,
            n_heads: 2,
            n_layers: 1,
            d_ff: 32,
            max_seq: 48,
        };
        let ds = SentimentDataset::generate(64, 32, 9);
        let tcfg =
            TrainConfig { steps: 60, lr: 3e-3, seq_len: 48, batch: 4, log_every: 20, seed: 4 };
        let (model, _) = train_classifier(&mcfg, &tcfg, &ds);
        let acc =
            eval_classifier(&model, &ds.test, 48, &AttentionBackend::Exact(ExactKernel::RowStream));
        assert!(acc > 0.6, "accuracy = {acc}");
    }
}
