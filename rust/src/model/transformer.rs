//! Decoder-only transformer with manual forward/backward.
//!
//! Architecture: token embedding → L × [RMSNorm → multi-head causal
//! RoPE attention → residual; RMSNorm → GELU MLP → residual] →
//! RMSNorm → LM head (+ a 2-way classifier head on the last position
//! for the sentiment task).

use super::backend::AttentionBackend;
use super::train::TrainAttentionMode;
use crate::attention::batched::{
    AttnJob, BatchedBackend, BatchedEngine, DecodeJob, DecodeOp, DecodeOutput, EngineJob,
    JobOutput,
};
use crate::attention::rope::Rope;
use crate::attention::ExactKernel;
use crate::coordinator::{Metrics, StepBasis};
use crate::gradient::batched::{AttnBackwardJob, AttnBackwardMode};
use crate::tensor::{Matrix, Rng};
use std::sync::Arc;

/// Fan a prefill-only batch through the engine's unified door and
/// unwrap the lane (the model layer's jobs are index-keyed; results
/// are input-ordered by contract).
fn submit_prefill(engine: &BatchedEngine, jobs: Vec<AttnJob>) -> Vec<JobOutput> {
    engine
        .submit(jobs.into_iter().enumerate().map(|(i, j)| EngineJob::prefill(i as u64, j)).collect())
        .into_iter()
        .map(|o| o.result.into_prefill())
        .collect()
}

/// Model hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct ModelConfig {
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub max_seq: usize,
}

impl ModelConfig {
    /// A test-sized model.
    pub fn tiny(max_seq: usize) -> Self {
        ModelConfig { vocab_size: 260, d_model: 32, n_heads: 2, n_layers: 2, d_ff: 64, max_seq }
    }

    /// The Figure 4 evaluation model (~1M params — trainable on CPU in
    /// seconds, long enough sequences to exercise the conv path).
    pub fn fig4(max_seq: usize) -> Self {
        ModelConfig { vocab_size: 260, d_model: 64, n_heads: 4, n_layers: 4, d_ff: 256, max_seq }
    }

    /// A 100M-class GPT configuration (e2e example; steps scaled down on
    /// CPU — see EXPERIMENTS.md).
    pub fn gpt_100m() -> Self {
        ModelConfig {
            vocab_size: 260,
            d_model: 768,
            n_heads: 12,
            n_layers: 14,
            d_ff: 3072,
            max_seq: 1024,
        }
    }

    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Approximate parameter count.
    pub fn approx_params(&self) -> usize {
        let attn = 4 * self.d_model * self.d_model;
        let mlp = 2 * self.d_model * self.d_ff;
        let norms = 2 * self.d_model;
        self.vocab_size * self.d_model * 2
            + self.n_layers * (attn + mlp + norms)
            + self.d_model
            + 2 * self.d_model
    }
}

/// One transformer layer's parameters.
#[derive(Clone, Debug)]
pub struct LayerParams {
    pub ln1_g: Vec<f64>,
    pub wq: Matrix,
    pub wk: Matrix,
    pub wv: Matrix,
    pub wo: Matrix,
    pub ln2_g: Vec<f64>,
    pub w1: Matrix,
    pub w2: Matrix,
}

/// Full parameter set.
#[derive(Clone, Debug)]
pub struct Transformer {
    pub cfg: ModelConfig,
    pub embed: Matrix, // vocab × d_model
    pub layers: Vec<LayerParams>,
    pub lnf_g: Vec<f64>,
    pub head: Matrix, // d_model × vocab
    pub cls_head: Matrix, // d_model × 2
    rope: Rope,
}

/// Per-layer forward cache (needed for backward).
struct LayerCache {
    x_in: Matrix,
    ln1_out: Matrix,
    ln1_rms: Vec<f64>,
    q_rot: Matrix,
    k_rot: Matrix,
    v: Matrix,
    /// Per head, n×n softmax rows — `Some` on the exact training
    /// forward and on conv heads whose recovery fell back (the exact
    /// backward and the fast backward's dense fallback consume them);
    /// `None` on conv heads, which carry [`Self::bases`] instead.
    /// `Arc`-shared so the engine-routed backward's jobs borrow them
    /// without copying.
    probs: Vec<Option<Arc<Matrix>>>,
    /// Per head, the **step-scoped conv basis handle** the conv
    /// training forward recovered (`None` on the exact path and on
    /// fallback heads). The backward's Fast jobs consume it instead of
    /// re-recovering from raw (Q, K) — this field *is* the step's
    /// basis store: populated once per (record, layer, head) per
    /// optimizer step, dropped with the record when the step ends.
    bases: Vec<Option<StepBasis>>,
    attn_concat: Matrix,
    x_mid: Matrix,
    ln2_out: Matrix,
    ln2_rms: Vec<f64>,
    ff_pre: Matrix, // before gelu
    ff_act: Matrix, // after gelu
}

/// Forward record returned for observation / backward.
pub struct ForwardRecord {
    /// Final hidden states after the last RMSNorm (n × d_model).
    pub final_hidden: Matrix,
    /// LM logits (n × vocab).
    pub logits: Matrix,
    caches: Option<Vec<LayerCache>>,
    lnf_rms: Vec<f64>,
    lnf_in: Matrix,
    tokens: Vec<usize>,
}

/// Gradients, mirroring the parameter structure.
#[derive(Clone, Debug)]
pub struct Gradients {
    pub embed: Matrix,
    pub layers: Vec<LayerParams>,
    pub lnf_g: Vec<f64>,
    pub head: Matrix,
    pub cls_head: Matrix,
}

/// Per-(layer) KV cache of one decode session; grows one row per step.
struct LayerKv {
    /// Post-RoPE key rows (`n × d_model`).
    k_rot: Matrix,
    /// Value rows (`n × d_model`).
    v: Matrix,
    /// Post-RoPE *unscaled* query rows — retained only for conv decode
    /// (drift re-recovery probes the full Q); empty (0-row) otherwise.
    q_rot: Matrix,
    /// Per-head conv decode state (`None` for exact decode).
    states: Vec<Option<crate::attention::decode::DecodeState>>,
}

/// Autoregressive decode state of one in-flight sequence: the tokens
/// so far, per-layer KV caches, and per-(layer, head) conv decode
/// states. Created by [`Transformer::prefill_batch`]; grown one token
/// per [`Transformer::decode_step`].
pub struct DecodeSession {
    /// Caller-assigned id (the serving layer uses the request id).
    pub id: u64,
    tokens: Vec<usize>,
    op: DecodeOp,
    layers: Vec<LayerKv>,
}

impl DecodeSession {
    /// Tokens consumed so far (prompt + fed generations).
    pub fn tokens(&self) -> &[usize] {
        &self.tokens
    }

    /// Bytes resident in this session: per-layer KV caches (K, V and —
    /// for conv decode — Q rows) plus per-head conv decode states, plus
    /// the token buffer. This is what the serving layer's
    /// `decode_resident_bytes` gauge sums over live sessions.
    pub fn resident_bytes(&self) -> usize {
        let mut floats = 0usize;
        for l in &self.layers {
            floats += l.k_rot.rows() * l.k_rot.cols()
                + l.v.rows() * l.v.cols()
                + l.q_rot.rows() * l.q_rot.cols();
            for s in l.states.iter().flatten() {
                floats += s.memory_floats();
            }
        }
        floats * std::mem::size_of::<f64>() + self.tokens.len() * std::mem::size_of::<usize>()
    }

    /// Release this session's memory from the `decode_resident_bytes`
    /// gauge. Call exactly once when a session leaves service (the
    /// generation scheduler does this on retirement); the session's
    /// bytes were added by `Transformer::prefill_batch` and grown by
    /// `Transformer::decode_step`.
    pub fn retire(&self, metrics: &Metrics) {
        Metrics::sub(&metrics.decode_resident_bytes, self.resident_bytes() as u64);
    }

    /// Current sequence length.
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// The decode operator this session runs under.
    pub fn op(&self) -> &DecodeOp {
        &self.op
    }
}

const RMS_EPS: f64 = 1e-6;

/// One row of `row · m` with **exactly** [`Matrix::matmul`]'s i-k-j
/// accumulation order (including its skip on exact zeros), so a decode
/// step's row arithmetic is bit-identical to the full-matrix forward.
fn row_matmul(row: &[f64], m: &Matrix) -> Vec<f64> {
    assert_eq!(row.len(), m.rows());
    let n = m.cols();
    let mut out = vec![0.0; n];
    for (k, &aik) in row.iter().enumerate() {
        if aik == 0.0 {
            continue;
        }
        let b_row = m.row(k);
        for (j, slot) in out.iter_mut().enumerate() {
            *slot += aik * b_row[j];
        }
    }
    out
}

/// One row of RMSNorm with exactly [`rmsnorm_fwd`]'s float-op order.
fn rmsnorm_row(row: &[f64], g: &[f64]) -> Vec<f64> {
    let d = row.len();
    let ms: f64 = row.iter().map(|v| v * v).sum::<f64>() / d as f64;
    let r = (ms + RMS_EPS).sqrt();
    row.iter().zip(g).map(|(&x, &gj)| x * gj / r).collect()
}

fn rmsnorm_fwd(x: &Matrix, g: &[f64]) -> (Matrix, Vec<f64>) {
    let (n, d) = x.shape();
    let mut out = Matrix::zeros(n, d);
    let mut rms = Vec::with_capacity(n);
    for i in 0..n {
        let row = x.row(i);
        let ms: f64 = row.iter().map(|v| v * v).sum::<f64>() / d as f64;
        let r = (ms + RMS_EPS).sqrt();
        rms.push(r);
        let orow = out.row_mut(i);
        for j in 0..d {
            orow[j] = row[j] * g[j] / r;
        }
    }
    (out, rms)
}

/// Backward through RMSNorm: returns (dx, dg contribution added).
fn rmsnorm_bwd(x: &Matrix, g: &[f64], rms: &[f64], dy: &Matrix, dg: &mut [f64]) -> Matrix {
    let (n, d) = x.shape();
    let mut dx = Matrix::zeros(n, d);
    for i in 0..n {
        let r = rms[i];
        let xr = x.row(i);
        let dyr = dy.row(i);
        // dg_j += dy_j * x_j / r
        for j in 0..d {
            dg[j] += dyr[j] * xr[j] / r;
        }
        // dx = (g∘dy)/r − x·Σ(x∘g∘dy)/(d·r³)
        let s: f64 = (0..d).map(|j| xr[j] * g[j] * dyr[j]).sum();
        let coef = s / (d as f64 * r * r * r);
        let dxr = dx.row_mut(i);
        for j in 0..d {
            dxr[j] = g[j] * dyr[j] / r - xr[j] * coef;
        }
    }
    dx
}

fn gelu(x: f64) -> f64 {
    // tanh approximation.
    const C: f64 = 0.7978845608028654; // sqrt(2/π)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

fn gelu_grad(x: f64) -> f64 {
    const C: f64 = 0.7978845608028654;
    let u = C * (x + 0.044715 * x * x * x);
    let t = u.tanh();
    let sech2 = 1.0 - t * t;
    0.5 * (1.0 + t) + 0.5 * x * sech2 * C * (1.0 + 3.0 * 0.044715 * x * x)
}

/// The post-attention half of one layer: Wo projection, attention
/// residual, RMSNorm, GELU MLP, MLP residual. Returns every
/// intermediate `(x_mid, ln2_out, ln2_rms, ff_pre, ff_act, x_out)` —
/// training callers retain them all for the backward; inference
/// callers keep only `x_out`. One body for every forward flavor, so
/// their float-op order cannot drift apart.
fn layer_tail(
    layer: &LayerParams,
    x_in: &Matrix,
    attn_concat: &Matrix,
) -> (Matrix, Matrix, Vec<f64>, Matrix, Matrix, Matrix) {
    let attn_out = attn_concat.matmul(&layer.wo);
    let x_mid = x_in.add(&attn_out);
    let (ln2_out, ln2_rms) = rmsnorm_fwd(&x_mid, &layer.ln2_g);
    let ff_pre = ln2_out.matmul(&layer.w1);
    let ff_act = ff_pre.map(gelu);
    let ff_out = ff_act.matmul(&layer.w2);
    let x_out = x_mid.add(&ff_out);
    (x_mid, ln2_out, ln2_rms, ff_pre, ff_act, x_out)
}

impl Transformer {
    /// The pre-attention half of one layer for one record: RMSNorm →
    /// Q/K/V projections → per-head RoPE rotation. Returns
    /// `(ln1_out, ln1_rms, q_rot, k_rot, v)`. Every forward flavor
    /// (per-record training, inference-batched, prefill, engine-routed
    /// training) runs this one body — the bit-identity contracts in
    /// `tests/{decode,gradient_oracle,train_conv}.rs` lean on the
    /// flavors never drifting apart in float-op order.
    fn layer_qkv(
        &self,
        x: &Matrix,
        layer: &LayerParams,
    ) -> (Matrix, Vec<f64>, Matrix, Matrix, Matrix) {
        let n = x.rows();
        let nh = self.cfg.n_heads;
        let dh = self.cfg.head_dim();
        let (ln1_out, ln1_rms) = rmsnorm_fwd(x, &layer.ln1_g);
        let q = ln1_out.matmul(&layer.wq);
        let k = ln1_out.matmul(&layer.wk);
        let v = ln1_out.matmul(&layer.wv);
        let mut q_rot = q;
        let mut k_rot = k;
        for h in 0..nh {
            for i in 0..n {
                let qs = &mut q_rot.row_mut(i)[h * dh..(h + 1) * dh];
                self.rope.rotate_row(qs, i);
            }
            for i in 0..n {
                let ks = &mut k_rot.row_mut(i)[h * dh..(h + 1) * dh];
                self.rope.rotate_row(ks, i);
            }
        }
        (ln1_out, ln1_rms, q_rot, k_rot, v)
    }

    /// One head's `(Q·scale, K, V)` blocks from the full-width rotated
    /// matrices — exactly the per-head extraction the engine jobs (and
    /// the engine-routed backward's job construction) perform.
    fn head_blocks(
        &self,
        q_rot: &Matrix,
        k_rot: &Matrix,
        v: &Matrix,
        h: usize,
    ) -> (Matrix, Matrix, Matrix) {
        let n = q_rot.rows();
        let dh = self.cfg.head_dim();
        let scale = 1.0 / (dh as f64).sqrt();
        (
            Matrix::from_fn(n, dh, |i, j| q_rot[(i, h * dh + j)] * scale),
            Matrix::from_fn(n, dh, |i, j| k_rot[(i, h * dh + j)]),
            Matrix::from_fn(n, dh, |i, j| v[(i, h * dh + j)]),
        )
    }

    /// Initialize with scaled-normal weights (deterministic from `rng`).
    pub fn new(cfg: &ModelConfig, rng: &mut Rng) -> Self {
        let d = cfg.d_model;
        let std_attn = 1.0 / (d as f64).sqrt();
        let std_ff = 1.0 / (cfg.d_ff as f64).sqrt();
        let layers = (0..cfg.n_layers)
            .map(|_| LayerParams {
                ln1_g: vec![1.0; d],
                wq: Matrix::randn(d, d, rng).scale(std_attn),
                wk: Matrix::randn(d, d, rng).scale(std_attn),
                wv: Matrix::randn(d, d, rng).scale(std_attn),
                wo: Matrix::randn(d, d, rng).scale(std_attn / (2.0 * cfg.n_layers as f64).sqrt()),
                ln2_g: vec![1.0; d],
                w1: Matrix::randn(d, cfg.d_ff, rng).scale(std_attn),
                w2: Matrix::randn(cfg.d_ff, d, rng)
                    .scale(std_ff / (2.0 * cfg.n_layers as f64).sqrt()),
            })
            .collect();
        Transformer {
            cfg: *cfg,
            embed: Matrix::randn(cfg.vocab_size, d, rng).scale(0.02),
            layers,
            lnf_g: vec![1.0; d],
            head: Matrix::randn(d, cfg.vocab_size, rng).scale(std_attn),
            cls_head: Matrix::randn(d, 2, rng).scale(std_attn),
            rope: Rope::new(cfg.d_model / cfg.n_heads, 10_000.0),
        }
    }

    pub fn num_params(&self) -> usize {
        let mut n = self.embed.rows() * self.embed.cols()
            + self.head.rows() * self.head.cols()
            + self.cls_head.rows() * self.cls_head.cols()
            + self.lnf_g.len();
        for l in &self.layers {
            n += l.ln1_g.len()
                + l.ln2_g.len()
                + l.wq.rows() * l.wq.cols() * 4
                + l.w1.rows() * l.w1.cols()
                + l.w2.rows() * l.w2.cols();
        }
        n
    }

    /// Zero-shaped gradient holder.
    pub fn zero_grads(&self) -> Gradients {
        Gradients {
            embed: Matrix::zeros(self.embed.rows(), self.embed.cols()),
            layers: self
                .layers
                .iter()
                .map(|l| LayerParams {
                    ln1_g: vec![0.0; l.ln1_g.len()],
                    wq: Matrix::zeros(l.wq.rows(), l.wq.cols()),
                    wk: Matrix::zeros(l.wk.rows(), l.wk.cols()),
                    wv: Matrix::zeros(l.wv.rows(), l.wv.cols()),
                    wo: Matrix::zeros(l.wo.rows(), l.wo.cols()),
                    ln2_g: vec![0.0; l.ln2_g.len()],
                    w1: Matrix::zeros(l.w1.rows(), l.w1.cols()),
                    w2: Matrix::zeros(l.w2.rows(), l.w2.cols()),
                })
                .collect(),
            lnf_g: vec![0.0; self.lnf_g.len()],
            head: Matrix::zeros(self.head.rows(), self.head.cols()),
            cls_head: Matrix::zeros(self.cls_head.rows(), self.cls_head.cols()),
        }
    }

    /// Forward pass. `backend` selects the attention operator (training
    /// must use `Exact`; approximate backends are inference-only).
    /// `keep_cache` retains activations for [`Self::backward`].
    pub fn forward(
        &self,
        tokens: &[usize],
        backend: &AttentionBackend,
        keep_cache: bool,
    ) -> ForwardRecord {
        let n = tokens.len();
        assert!(n <= self.cfg.max_seq, "sequence too long");
        let d = self.cfg.d_model;
        let nh = self.cfg.n_heads;
        let dh = self.cfg.head_dim();

        let mut x = Matrix::zeros(n, d);
        for (i, &t) in tokens.iter().enumerate() {
            x.row_mut(i).copy_from_slice(self.embed.row(t));
        }

        let mut caches: Vec<LayerCache> = Vec::new();
        for layer in &self.layers {
            let x_in = x.clone();
            let (ln1_out, ln1_rms, q_rot, k_rot, v) = self.layer_qkv(&x, layer);
            // Per-head attention through the selected backend.
            let mut attn_concat = Matrix::zeros(n, d);
            let mut probs_cache: Vec<Option<Arc<Matrix>>> = Vec::new();
            for h in 0..nh {
                let (qh, kh, vh) = self.head_blocks(&q_rot, &k_rot, &v, h);
                let (out_h, probs) = backend.attend(&qh, &kh, &vh, keep_cache);
                for i in 0..n {
                    for j in 0..dh {
                        attn_concat[(i, h * dh + j)] = out_h[(i, j)];
                    }
                }
                if keep_cache {
                    probs_cache.push(Some(Arc::new(probs.expect("exact backend caches probs"))));
                }
            }
            let (x_mid, ln2_out, ln2_rms, ff_pre, ff_act, x_out) =
                layer_tail(layer, &x_in, &attn_concat);
            x = x_out;

            if keep_cache {
                caches.push(LayerCache {
                    x_in,
                    ln1_out,
                    ln1_rms,
                    q_rot,
                    k_rot,
                    v,
                    probs: probs_cache,
                    bases: vec![None; nh],
                    attn_concat,
                    x_mid,
                    ln2_out,
                    ln2_rms,
                    ff_pre,
                    ff_act,
                });
            }
        }
        let lnf_in = x.clone();
        let (final_hidden, lnf_rms) = rmsnorm_fwd(&x, &self.lnf_g);
        let logits = final_hidden.matmul(&self.head);
        ForwardRecord {
            final_hidden,
            logits,
            caches: if keep_cache { Some(caches) } else { None },
            lnf_rms,
            lnf_in,
            tokens: tokens.to_vec(),
        }
    }

    /// Batched inference forward: run a batch of sequences through the
    /// model with all (sequence, head) attention jobs of each layer
    /// fanned out as **one** [`BatchedEngine`] call per layer — the
    /// engine shares FFT plans and recovered bases across the whole
    /// batch and runs jobs on its worker pool with deterministic
    /// ordering. No activation caches are kept (inference only;
    /// training stays on [`Self::forward`] with the exact backend).
    ///
    /// Output is identical to calling [`Self::forward`] per sequence:
    /// the engine applies the same per-head operator (see
    /// `AttentionBackend::to_batched`), only batched and in parallel.
    pub fn forward_batch(
        &self,
        seqs: &[Vec<usize>],
        backend: &AttentionBackend,
        engine: &BatchedEngine,
    ) -> Vec<ForwardRecord> {
        let d = self.cfg.d_model;
        let nh = self.cfg.n_heads;
        let dh = self.cfg.head_dim();
        let spec = backend.to_batched();

        let mut xs: Vec<Matrix> = seqs
            .iter()
            .map(|tokens| {
                let n = tokens.len();
                assert!(n <= self.cfg.max_seq, "sequence too long");
                let mut x = Matrix::zeros(n, d);
                for (i, &t) in tokens.iter().enumerate() {
                    x.row_mut(i).copy_from_slice(self.embed.row(t));
                }
                x
            })
            .collect();

        for (li, layer) in self.layers.iter().enumerate() {
            // Gather: every (sequence, head) attention job of this layer.
            let mut jobs = Vec::with_capacity(seqs.len() * nh);
            for x in &xs {
                let (_, _, q_rot, k_rot, v) = self.layer_qkv(x, layer);
                for h in 0..nh {
                    let (qh, kh, vh) = self.head_blocks(&q_rot, &k_rot, &v, h);
                    jobs.push(AttnJob::causal(li as u32, h as u32, qh, kh, vh, spec.clone()));
                }
            }
            let outs = submit_prefill(engine, jobs);
            // Scatter: finish the layer per sequence.
            for (s, x) in xs.iter_mut().enumerate() {
                let n = x.rows();
                let mut attn_concat = Matrix::zeros(n, d);
                for h in 0..nh {
                    let out_h = &outs[s * nh + h].y;
                    for i in 0..n {
                        for j in 0..dh {
                            attn_concat[(i, h * dh + j)] = out_h[(i, j)];
                        }
                    }
                }
                let (_, _, _, _, _, x_out) = layer_tail(layer, x, &attn_concat);
                *x = x_out;
            }
        }

        xs.into_iter()
            .zip(seqs)
            .map(|(x, tokens)| {
                let lnf_in = x.clone();
                let (final_hidden, lnf_rms) = rmsnorm_fwd(&x, &self.lnf_g);
                let logits = final_hidden.matmul(&self.head);
                ForwardRecord {
                    final_hidden,
                    logits,
                    caches: None,
                    lnf_rms,
                    lnf_in,
                    tokens: tokens.clone(),
                }
            })
            .collect()
    }

    /// Engine-routed **training forward** for a micro-batch: every
    /// (record, head) attention of a layer fans out as one prefill-lane
    /// submit of *training* jobs ([`AttnJob::for_training`]) — the
    /// mirror of [`Self::backward_batch_with_engine`] on the way in —
    /// while retaining the full activation caches the backward needs.
    /// Returns the forward records plus the number of conv jobs whose
    /// recovery fell back to the exact kernel (the per-step fallback
    /// count the training loops log).
    ///
    /// The `mode` knob selects the attention operator:
    ///
    /// * [`TrainAttentionMode::Exact`] — the `O(n²)` softmax kernel;
    ///   per record **bit-identical** to
    ///   `forward(tokens, &AttentionBackend::Exact(kernel), true)` (the jobs
    ///   run the same training-softmax helper, and all non-attention
    ///   arithmetic is record-local in the same float-op order). The
    ///   softmax rows land in the cache for the exact backward.
    /// * [`TrainAttentionMode::Conv`] — Algorithm 1: each (record,
    ///   layer, head) recovers its conv basis **once**, output within
    ///   recovery tolerance of exact, and the basis rides the cache as
    ///   a step-scoped handle ([`StepBasis`]) that the Fast backward
    ///   consumes for free — forward and backward share one recovery
    ///   per step, with **zero writes to the serving `BasisCache`**
    ///   (training jobs never touch it). A head whose recovery fails
    ///   falls back to the exact kernel bit-exactly (probs retained, so
    ///   the backward's dense fallback keeps the whole step bit-equal
    ///   to exact-mode training), counted in
    ///   `Metrics::train_fwd_fallbacks`.
    ///
    /// Results are bit-identical for any engine worker count: training
    /// jobs are pure and the engine orders results by input index.
    pub fn forward_train_batch(
        &self,
        seqs: &[Vec<usize>],
        mode: &TrainAttentionMode,
        engine: &BatchedEngine,
    ) -> (Vec<ForwardRecord>, usize) {
        let d = self.cfg.d_model;
        let nh = self.cfg.n_heads;
        let dh = self.cfg.head_dim();
        let backend = match mode {
            TrainAttentionMode::Exact => BatchedBackend::Exact(ExactKernel::RowStream),
            TrainAttentionMode::Conv(cfg) => BatchedBackend::Conv(*cfg),
        };

        let mut xs: Vec<Matrix> = seqs
            .iter()
            .map(|tokens| {
                let n = tokens.len();
                assert!(n <= self.cfg.max_seq, "sequence too long");
                let mut x = Matrix::zeros(n, d);
                for (i, &t) in tokens.iter().enumerate() {
                    x.row_mut(i).copy_from_slice(self.embed.row(t));
                }
                x
            })
            .collect();
        let mut caches: Vec<Vec<LayerCache>> =
            seqs.iter().map(|_| Vec::with_capacity(self.layers.len())).collect();
        let mut fallbacks = 0usize;

        for (li, layer) in self.layers.iter().enumerate() {
            // Gather: the shared pre-attention half (`layer_qkv` — the
            // same body every forward flavor runs), retained for the
            // caches.
            struct Pre {
                x_in: Matrix,
                ln1_out: Matrix,
                ln1_rms: Vec<f64>,
                q_rot: Matrix,
                k_rot: Matrix,
                v: Matrix,
            }
            let mut jobs = Vec::with_capacity(seqs.len() * nh);
            let mut pres: Vec<Pre> = Vec::with_capacity(seqs.len());
            for x in &xs {
                let x_in = x.clone();
                let (ln1_out, ln1_rms, q_rot, k_rot, v) = self.layer_qkv(x, layer);
                for h in 0..nh {
                    let (qh, kh, vh) = self.head_blocks(&q_rot, &k_rot, &v, h);
                    jobs.push(
                        AttnJob::causal(li as u32, h as u32, qh, kh, vh, backend.clone())
                            .for_training(),
                    );
                }
                pres.push(Pre { x_in, ln1_out, ln1_rms, q_rot, k_rot, v });
            }
            let outs = submit_prefill(engine, jobs);
            // Scatter: finish the layer per record, stashing each
            // head's backward artifact (probs or basis handle).
            for ((s, x), pre) in xs.iter_mut().enumerate().zip(pres) {
                let n = x.rows();
                let mut attn_concat = Matrix::zeros(n, d);
                let mut probs_cache: Vec<Option<Arc<Matrix>>> = Vec::with_capacity(nh);
                let mut bases_cache: Vec<Option<StepBasis>> = Vec::with_capacity(nh);
                for h in 0..nh {
                    let out = &outs[s * nh + h];
                    for i in 0..n {
                        for j in 0..dh {
                            attn_concat[(i, h * dh + j)] = out.y[(i, j)];
                        }
                    }
                    fallbacks += out.fell_back as usize;
                    probs_cache.push(out.probs.clone());
                    bases_cache.push(out.basis.clone());
                }
                let (x_mid, ln2_out, ln2_rms, ff_pre, ff_act, x_out) =
                    layer_tail(layer, &pre.x_in, &attn_concat);
                *x = x_out;
                caches[s].push(LayerCache {
                    x_in: pre.x_in,
                    ln1_out: pre.ln1_out,
                    ln1_rms: pre.ln1_rms,
                    q_rot: pre.q_rot,
                    k_rot: pre.k_rot,
                    v: pre.v,
                    probs: probs_cache,
                    bases: bases_cache,
                    attn_concat,
                    x_mid,
                    ln2_out,
                    ln2_rms,
                    ff_pre,
                    ff_act,
                });
            }
        }

        let records = xs
            .into_iter()
            .zip(seqs)
            .zip(caches)
            .map(|((x, tokens), cache)| {
                let lnf_in = x.clone();
                let (final_hidden, lnf_rms) = rmsnorm_fwd(&x, &self.lnf_g);
                let logits = final_hidden.matmul(&self.head);
                ForwardRecord {
                    final_hidden,
                    logits,
                    caches: Some(cache),
                    lnf_rms,
                    lnf_in,
                    tokens: tokens.clone(),
                }
            })
            .collect();
        (records, fallbacks)
    }

    /// Prefill a batch of prompts for autoregressive decoding: run the
    /// batched-engine forward (one prefill-lane `submit` per layer,
    /// exactly like [`Self::forward_batch`]) while **retaining** per-layer KV
    /// caches, and — for conv backends — seed every (layer, head)
    /// [`DecodeState`](crate::attention::decode::DecodeState) straight
    /// from the engine's `BasisCache` (the prefill jobs just recovered
    /// and cached those bases, so seeding is a cache hit, counted in
    /// `Metrics::decode_seed_hits`).
    ///
    /// Returns, per prompt, the [`DecodeSession`] plus the last
    /// position's LM logits (what the first sampled token comes from).
    /// The logits are bit-identical to [`Self::forward`]'s last row
    /// under the same backend.
    pub fn prefill_batch(
        &self,
        seqs: &[Vec<usize>],
        backend: &AttentionBackend,
        engine: &BatchedEngine,
    ) -> Vec<(DecodeSession, Vec<f64>)> {
        let d = self.cfg.d_model;
        let nh = self.cfg.n_heads;
        let dh = self.cfg.head_dim();
        let scale = 1.0 / (dh as f64).sqrt();
        let spec = backend.to_batched();
        let op = backend.to_decode();
        let conv = matches!(op, DecodeOp::Conv { .. });
        // Routed backends decode through the exact last-row kernel (see
        // `AttentionBackend::to_decode`); account for every low-rank
        // table slot that pin overrides for these decode-bound sessions.
        if let AttentionBackend::Routed(policy) = backend {
            let pins = policy.lowrank_route_count(self.layers.len() as u32, nh as u32)
                * seqs.len() as u64;
            if pins > 0 {
                Metrics::add(&engine.metrics().router_decode_pins, pins);
            }
        }

        let mut xs: Vec<Matrix> = seqs
            .iter()
            .map(|tokens| {
                assert!(!tokens.is_empty(), "cannot prefill an empty prompt");
                let n = tokens.len();
                assert!(n <= self.cfg.max_seq, "sequence too long");
                let mut x = Matrix::zeros(n, d);
                for (i, &t) in tokens.iter().enumerate() {
                    x.row_mut(i).copy_from_slice(self.embed.row(t));
                }
                x
            })
            .collect();
        let mut sessions: Vec<DecodeSession> = seqs
            .iter()
            .map(|tokens| DecodeSession {
                id: 0,
                tokens: tokens.clone(),
                op: op.clone(),
                layers: Vec::with_capacity(self.layers.len()),
            })
            .collect();

        for (li, layer) in self.layers.iter().enumerate() {
            // Gather: identical math to `forward_batch` (one shared
            // `layer_qkv` body), plus KV-cache retention per session.
            let mut jobs = Vec::with_capacity(seqs.len() * nh);
            for (s, x) in xs.iter().enumerate() {
                let (_, _, q_rot, k_rot, v) = self.layer_qkv(x, layer);
                for h in 0..nh {
                    let (qh, kh, vh) = self.head_blocks(&q_rot, &k_rot, &v, h);
                    jobs.push(AttnJob::causal(li as u32, h as u32, qh, kh, vh, spec.clone()));
                }
                sessions[s].layers.push(LayerKv {
                    k_rot,
                    v,
                    q_rot: if conv { q_rot } else { Matrix::zeros(0, d) },
                    states: (0..nh).map(|_| None).collect(),
                });
            }
            let outs = submit_prefill(engine, jobs);
            // Seed conv decode states from the bases the jobs above
            // just recovered and cached.
            if let DecodeOp::Conv { k_bases, .. } = &op {
                for s in 0..seqs.len() {
                    for h in 0..nh {
                        let (qh, kh) = {
                            let kv = &sessions[s].layers[li];
                            let n = kv.k_rot.rows();
                            (
                                Matrix::from_fn(n, dh, |i, j| kv.q_rot[(i, h * dh + j)] * scale),
                                Matrix::from_fn(n, dh, |i, j| kv.k_rot[(i, h * dh + j)]),
                            )
                        };
                        let (state, _hit) =
                            engine.seed_decode(li as u32, h as u32, &qh, &kh, *k_bases);
                        sessions[s].layers[li].states[h] = Some(state);
                    }
                }
            }
            // Scatter: finish the layer per sequence.
            for (s, x) in xs.iter_mut().enumerate() {
                let n = x.rows();
                let mut attn_concat = Matrix::zeros(n, d);
                for h in 0..nh {
                    let out_h = &outs[s * nh + h].y;
                    for i in 0..n {
                        for j in 0..dh {
                            attn_concat[(i, h * dh + j)] = out_h[(i, j)];
                        }
                    }
                }
                let (_, _, _, _, _, x_out) = layer_tail(layer, x, &attn_concat);
                *x = x_out;
            }
        }

        // KV-cache memory accounting: the new sessions are now live.
        let resident: usize = sessions.iter().map(|s| s.resident_bytes()).sum();
        Metrics::add(&engine.metrics().decode_resident_bytes, resident as u64);

        xs.into_iter()
            .zip(sessions)
            .map(|(x, sess)| {
                let n = x.rows();
                let (final_hidden, _) = rmsnorm_fwd(&x, &self.lnf_g);
                let logits = final_hidden.matmul(&self.head);
                let last = logits.row(n - 1).to_vec();
                (sess, last)
            })
            .collect()
    }

    /// Prefill a single prompt (see [`Self::prefill_batch`]).
    pub fn prefill(
        &self,
        tokens: &[usize],
        backend: &AttentionBackend,
        engine: &BatchedEngine,
    ) -> (DecodeSession, Vec<f64>) {
        let seqs = [tokens.to_vec()];
        self.prefill_batch(&seqs, backend, engine).pop().expect("one prompt in, one session out")
    }

    /// One autoregressive decode step for a batch of in-flight
    /// sessions: feed `next_tokens[i]` to `sessions[i]`, run every
    /// (session, head) attention as **one [`BatchedEngine::submit`]
    /// call of decode jobs per layer** — no per-token re-prefill
    /// anywhere — and return each session's next-token LM logits.
    ///
    /// All non-attention arithmetic is row-local and replicates the
    /// full forward's float-op order exactly (see the private
    /// `row_matmul` / `rmsnorm_row` helpers), so with the exact
    /// backend the returned logits
    /// **bit-match** `forward(&tokens_so_far)` at the grown length —
    /// the `tests/decode.rs` property pins this for thread counts
    /// 1/2/8. Conv sessions grow their cached bases in `O(k·n + n·d)`
    /// per (layer, head) and re-recover on drift (counters in the
    /// engine's `Metrics`).
    pub fn decode_step(
        &self,
        sessions: &mut [DecodeSession],
        next_tokens: &[usize],
        engine: &BatchedEngine,
    ) -> Vec<Vec<f64>> {
        self.decode_step_with_jobs(sessions, next_tokens, engine, Vec::new()).0
    }

    /// [`Self::decode_step`] with **extra prefill jobs merged into the
    /// first layer's engine submit** — the continuous-batching hook the
    /// server's generation scheduler uses to let non-generation
    /// attention arrivals ride an in-flight decode step instead of
    /// waiting for the next batcher flush. Returns the decode logits
    /// plus the extra jobs' outputs (in the order given).
    ///
    /// Merging never changes decode results: every engine job is pure
    /// and results are input-indexed, so the logits are bit-identical
    /// to a plain [`Self::decode_step`] call with the same sessions.
    pub fn decode_step_with_jobs(
        &self,
        sessions: &mut [DecodeSession],
        next_tokens: &[usize],
        engine: &BatchedEngine,
        mut extra: Vec<AttnJob>,
    ) -> (Vec<Vec<f64>>, Vec<JobOutput>) {
        assert_eq!(sessions.len(), next_tokens.len());
        if sessions.is_empty() {
            if extra.is_empty() {
                return (Vec::new(), Vec::new());
            }
            return (Vec::new(), submit_prefill(engine, extra));
        }
        let resident_before: usize = sessions.iter().map(|s| s.resident_bytes()).sum();
        let mut extra_outs: Vec<JobOutput> = Vec::new();
        let d = self.cfg.d_model;
        let nh = self.cfg.n_heads;
        let dh = self.cfg.head_dim();
        let scale = 1.0 / (dh as f64).sqrt();

        // The new token's hidden row per session.
        let mut xs: Vec<Vec<f64>> = sessions
            .iter()
            .zip(next_tokens)
            .map(|(sess, &t)| {
                assert!(sess.len() < self.cfg.max_seq, "sequence at max_seq");
                assert!(t < self.cfg.vocab_size, "token out of vocab");
                self.embed.row(t).to_vec()
            })
            .collect();
        let positions: Vec<usize> = sessions.iter().map(|s| s.len()).collect();

        for li in 0..self.layers.len() {
            let layer = &self.layers[li];
            // Gather: one DecodeJob per (session, head).
            let mut jobs = Vec::with_capacity(sessions.len() * nh);
            for (si, sess) in sessions.iter_mut().enumerate() {
                let conv = matches!(sess.op, DecodeOp::Conv { .. });
                let ln1 = rmsnorm_row(&xs[si], &layer.ln1_g);
                let mut q = row_matmul(&ln1, &layer.wq);
                let mut k = row_matmul(&ln1, &layer.wk);
                let v = row_matmul(&ln1, &layer.wv);
                let pos = positions[si];
                for h in 0..nh {
                    self.rope.rotate_row(&mut q[h * dh..(h + 1) * dh], pos);
                    self.rope.rotate_row(&mut k[h * dh..(h + 1) * dh], pos);
                }
                let kv = &mut sess.layers[li];
                kv.k_rot.push_row(&k);
                kv.v.push_row(&v);
                if conv {
                    kv.q_rot.push_row(&q);
                }
                let n1 = kv.k_rot.rows();
                for h in 0..nh {
                    // Pre-exp logits row of the new token against the
                    // grown prefix, in matmul's accumulation order.
                    let mut new_row = vec![0.0; n1];
                    for (c, &qraw) in q[h * dh..(h + 1) * dh].iter().enumerate() {
                        let qc = qraw * scale;
                        if qc == 0.0 {
                            continue;
                        }
                        for (i, slot) in new_row.iter_mut().enumerate() {
                            *slot += qc * kv.k_rot[(i, h * dh + c)];
                        }
                    }
                    let vh = Matrix::from_fn(n1, dh, |i, j| kv.v[(i, h * dh + j)]);
                    let (qm, km, state) = if conv {
                        (
                            Some(Matrix::from_fn(n1, dh, |i, j| {
                                kv.q_rot[(i, h * dh + j)] * scale
                            })),
                            Some(Matrix::from_fn(n1, dh, |i, j| kv.k_rot[(i, h * dh + j)])),
                            kv.states[h].take(),
                        )
                    } else {
                        (None, None, None)
                    };
                    jobs.push(DecodeJob {
                        layer: li as u32,
                        head: h as u32,
                        state,
                        new_row,
                        v: vh,
                        q: qm,
                        k: km,
                        op: sess.op.clone(),
                    });
                }
            }
            // One unified submit per layer: all (session, head) decode
            // jobs, plus — on the first layer only — any merged
            // prefill riders.
            let n_decode = jobs.len();
            let mut engine_jobs: Vec<EngineJob> = jobs
                .into_iter()
                .enumerate()
                .map(|(i, j)| EngineJob::decode(i as u64, j))
                .collect();
            if li == 0 && !extra.is_empty() {
                engine_jobs.extend(
                    extra
                        .drain(..)
                        .enumerate()
                        .map(|(i, j)| EngineJob::prefill((n_decode + i) as u64, j)),
                );
            }
            let mut all_outs = engine.submit(engine_jobs);
            if all_outs.len() > n_decode {
                extra_outs = all_outs
                    .split_off(n_decode)
                    .into_iter()
                    .map(|o| o.result.into_prefill())
                    .collect();
            }
            let mut outs: Vec<DecodeOutput> =
                all_outs.into_iter().map(|o| o.result.into_decode()).collect();
            // Scatter: finish the layer per session, hand states back.
            for (si, sess) in sessions.iter_mut().enumerate() {
                let mut attn_row = vec![0.0; d];
                for h in 0..nh {
                    let out = &mut outs[si * nh + h];
                    attn_row[h * dh..(h + 1) * dh].copy_from_slice(&out.y_last);
                    sess.layers[li].states[h] = out.state.take();
                }
                let attn_out = row_matmul(&attn_row, &layer.wo);
                let x_mid: Vec<f64> = xs[si].iter().zip(&attn_out).map(|(a, b)| a + b).collect();
                let ln2 = rmsnorm_row(&x_mid, &layer.ln2_g);
                let ff_pre = row_matmul(&ln2, &layer.w1);
                let ff_act: Vec<f64> = ff_pre.iter().map(|&x| gelu(x)).collect();
                let ff_out = row_matmul(&ff_act, &layer.w2);
                xs[si] = x_mid.iter().zip(&ff_out).map(|(a, b)| a + b).collect();
            }
        }
        for (sess, &t) in sessions.iter_mut().zip(next_tokens) {
            sess.tokens.push(t);
        }
        // KV growth accounting (signed: a drift re-recovery may swap a
        // state for a smaller basis).
        let resident_after: usize = sessions.iter().map(|s| s.resident_bytes()).sum();
        let delta = resident_after as i64 - resident_before as i64;
        let gauge = &engine.metrics().decode_resident_bytes;
        if delta >= 0 {
            Metrics::add(gauge, delta as u64);
        } else {
            Metrics::sub(gauge, (-delta) as u64);
        }
        let logits = xs
            .into_iter()
            .map(|x| {
                let hid = rmsnorm_row(&x, &self.lnf_g);
                row_matmul(&hid, &self.head)
            })
            .collect();
        (logits, extra_outs)
    }

    /// Roll a decode session back to its length-`n` prefix — the
    /// speculative decoder's rollback path: drafted KV rows are dropped
    /// when the exact verifier rejects a suffix. Tokens, per-layer K/V
    /// (and conv Q) rows, and per-head conv decode states all truncate
    /// in place; a conv state whose windows cannot shrink that far
    /// (drift re-recovery replaced it mid-draft) is re-seeded from the
    /// truncated K/Q through the engine's basis cache instead. The
    /// `decode_resident_bytes` gauge absorbs the signed size change,
    /// mirroring [`Self::decode_step`]'s accounting.
    ///
    /// Exact sessions roll back bitwise: their per-step attention reads
    /// only K/V rows, and rows `0..n` are untouched bytes (row-major
    /// truncation), so a truncated session decodes exactly like one
    /// that never drafted. Conv states grown purely by `append_token`
    /// also roll back bitwise ([`DecodeState::truncate_to`]); only the
    /// re-seed fallback may differ, and the speculative scheduler's
    /// exact verification makes the emitted stream independent of the
    /// draft state either way.
    ///
    /// [`DecodeState::truncate_to`]: crate::attention::decode::DecodeState::truncate_to
    pub fn truncate_session(&self, sess: &mut DecodeSession, n: usize, engine: &BatchedEngine) {
        assert!(n >= 1 && n <= sess.len(), "truncate_session out of range");
        if n == sess.len() {
            return;
        }
        let resident_before = sess.resident_bytes();
        let nh = self.cfg.n_heads;
        let dh = self.cfg.head_dim();
        let scale = 1.0 / (dh as f64).sqrt();
        let op = sess.op.clone();
        let conv = matches!(op, DecodeOp::Conv { .. });
        sess.tokens.truncate(n);
        for (li, kv) in sess.layers.iter_mut().enumerate() {
            kv.k_rot.truncate_rows(n);
            kv.v.truncate_rows(n);
            if conv {
                kv.q_rot.truncate_rows(n);
            }
            for h in 0..nh {
                let Some(mut state) = kv.states[h].take() else { continue };
                if state.truncate_to(n) {
                    kv.states[h] = Some(state);
                } else if let DecodeOp::Conv { k_bases, .. } = &op {
                    // Window underflow: rebuild from the truncated
                    // prefix (a cache hit when this prefix's basis was
                    // recovered before).
                    let qh = Matrix::from_fn(n, dh, |i, j| kv.q_rot[(i, h * dh + j)] * scale);
                    let kh = Matrix::from_fn(n, dh, |i, j| kv.k_rot[(i, h * dh + j)]);
                    let (state, _hit) =
                        engine.seed_decode(li as u32, h as u32, &qh, &kh, *k_bases);
                    kv.states[h] = Some(state);
                }
            }
        }
        // Signed gauge delta, like decode_step: a re-seeded basis can
        // be larger than the truncated state it replaces.
        let resident_after = sess.resident_bytes();
        let gauge = &engine.metrics().decode_resident_bytes;
        if resident_after >= resident_before {
            Metrics::add(gauge, (resident_after - resident_before) as u64);
        } else {
            Metrics::sub(gauge, (resident_before - resident_after) as u64);
        }
    }

    /// Classification logits from the last position's hidden state.
    pub fn classify(&self, record: &ForwardRecord) -> [f64; 2] {
        let n = record.final_hidden.rows();
        let h = record.final_hidden.row(n - 1);
        let out = self.cls_head.transpose().matvec(h);
        [out[0], out[1]]
    }

    /// LM cross-entropy over positions whose target ≠ `ignore`; returns
    /// (mean loss, d_logits) for backward.
    pub fn lm_loss(
        &self,
        record: &ForwardRecord,
        targets: &[usize],
        ignore: usize,
    ) -> (f64, Matrix) {
        let (n, v) = record.logits.shape();
        assert_eq!(targets.len(), n);
        let mut dlogits = Matrix::zeros(n, v);
        let mut total = 0.0;
        let mut count = 0usize;
        for i in 0..n {
            if targets[i] == ignore {
                continue;
            }
            count += 1;
            let probs = crate::tensor::softmax(record.logits.row(i));
            total -= probs[targets[i]].max(1e-300).ln();
            let drow = dlogits.row_mut(i);
            drow.copy_from_slice(&probs);
            drow[targets[i]] -= 1.0;
        }
        let c = count.max(1) as f64;
        for x in dlogits.data_mut() {
            *x /= c;
        }
        (total / c, dlogits)
    }

    /// Classification cross-entropy on the last position; returns
    /// (loss, probability of the true class, d_cls_logits).
    pub fn cls_loss(&self, record: &ForwardRecord, label: bool) -> (f64, f64, [f64; 2]) {
        let logits = self.classify(record);
        let probs = crate::tensor::softmax(&logits);
        let idx = label as usize;
        let loss = -probs[idx].max(1e-300).ln();
        let mut d = [probs[0], probs[1]];
        d[idx] -= 1.0;
        (loss, probs[idx], d)
    }

    /// Backward from LM-loss logit gradients (and optionally a
    /// classification gradient on the last position). Accumulates into
    /// `grads`.
    ///
    /// This is the **dense oracle**: the per-head attention backward
    /// materializes `n×n` temporaries in matrix form. The training
    /// loops route through [`Self::backward_with_engine`] instead,
    /// which executes the identical math as engine jobs (bit-identical
    /// in exact mode — `tests/gradient_oracle.rs` pins it — without
    /// the `n×n` allocations); this form is kept as the comparison
    /// oracle and for engine-free callers.
    pub fn backward(
        &self,
        record: &ForwardRecord,
        dlogits: &Matrix,
        dcls: Option<[f64; 2]>,
        grads: &mut Gradients,
    ) {
        let caches = record.caches.as_ref().expect("forward(keep_cache=true) required");
        let n = record.logits.rows();
        let d = self.cfg.d_model;
        let nh = self.cfg.n_heads;
        let dh = self.cfg.head_dim();
        let scale = 1.0 / (dh as f64).sqrt();

        // Head: logits = final_hidden · head.
        grads.head.axpy_mat(1.0, &record.final_hidden.transpose().matmul(dlogits));
        let mut dfinal = dlogits.matmul(&self.head.transpose());
        if let Some(dc) = dcls {
            // cls logits = cls_headᵀ · h_last.
            let h_last = record.final_hidden.row(n - 1);
            for c in 0..2 {
                for j in 0..d {
                    grads.cls_head[(j, c)] += dc[c] * h_last[j];
                }
            }
            let drow = dfinal.row_mut(n - 1);
            for j in 0..d {
                drow[j] += dc[0] * self.cls_head[(j, 0)] + dc[1] * self.cls_head[(j, 1)];
            }
        }
        // Final RMSNorm.
        let mut dx = rmsnorm_bwd(&record.lnf_in, &self.lnf_g, &record.lnf_rms, &dfinal, &mut grads.lnf_g);

        // Layers in reverse.
        for (li, layer) in self.layers.iter().enumerate().rev() {
            let cache = &caches[li];
            let g = &mut grads.layers[li];

            // x = x_mid + ff_act·w2  (ff path)
            let dff_out = dx.clone();
            let dff_act = dff_out.matmul(&layer.w2.transpose());
            g.w2.axpy_mat(1.0, &cache.ff_act.transpose().matmul(&dff_out));
            let dff_pre = Matrix::from_fn(n, self.cfg.d_ff, |i, j| {
                dff_act[(i, j)] * gelu_grad(cache.ff_pre[(i, j)])
            });
            g.w1.axpy_mat(1.0, &cache.ln2_out.transpose().matmul(&dff_pre));
            let dln2_out = dff_pre.matmul(&layer.w1.transpose());
            let dx_mid_from_ff =
                rmsnorm_bwd(&cache.x_mid, &layer.ln2_g, &cache.ln2_rms, &dln2_out, &mut g.ln2_g);
            let mut dx_mid = dx; // residual
            dx_mid.axpy_mat(1.0, &dx_mid_from_ff);

            // x_mid = x_in + attn_concat·wo
            let dattn_out = dx_mid.clone();
            g.wo.axpy_mat(1.0, &cache.attn_concat.transpose().matmul(&dattn_out));
            let dattn_concat = dattn_out.matmul(&layer.wo.transpose());

            // Per-head attention backward.
            let mut dq_rot = Matrix::zeros(n, d);
            let mut dk_rot = Matrix::zeros(n, d);
            let mut dv_full = Matrix::zeros(n, d);
            for h in 0..nh {
                let probs = cache.probs[h]
                    .as_ref()
                    .expect("the dense backward requires the exact forward's probs");
                let dout_h = Matrix::from_fn(n, dh, |i, j| dattn_concat[(i, h * dh + j)]);
                let vh = Matrix::from_fn(n, dh, |i, j| cache.v[(i, h * dh + j)]);
                // dV_h = probsᵀ · dout
                let dvh = probs.transpose().matmul(&dout_h);
                // dProbs = dout · V_hᵀ
                let dprobs = dout_h.matmul(&vh.transpose());
                // dScores = probs ∘ (dprobs − rowdot)
                let mut dscores = Matrix::zeros(n, n);
                for i in 0..n {
                    let prow = probs.row(i);
                    let dprow = dprobs.row(i);
                    let dot: f64 = crate::tensor::dot(prow, dprow);
                    let srow = dscores.row_mut(i);
                    for j in 0..n {
                        srow[j] = prow[j] * (dprow[j] - dot);
                    }
                }
                // scores = (q_h·scale)·k_hᵀ  (scale folded into q at fwd)
                let qh_scaled =
                    Matrix::from_fn(n, dh, |i, j| cache.q_rot[(i, h * dh + j)] * scale);
                let kh = Matrix::from_fn(n, dh, |i, j| cache.k_rot[(i, h * dh + j)]);
                let dqh_scaled = dscores.matmul(&kh);
                let dkh = dscores.transpose().matmul(&qh_scaled);
                for i in 0..n {
                    for j in 0..dh {
                        dq_rot[(i, h * dh + j)] += dqh_scaled[(i, j)] * scale;
                        dk_rot[(i, h * dh + j)] += dkh[(i, j)];
                        dv_full[(i, h * dh + j)] += dvh[(i, j)];
                    }
                }
            }
            // RoPE backward: inverse rotation (orthogonal).
            let inv_rope = &self.rope;
            let mut dq = dq_rot;
            let mut dk = dk_rot;
            for h in 0..nh {
                for i in 0..n {
                    let qs = &mut dq.row_mut(i)[h * dh..(h + 1) * dh];
                    rotate_inverse(inv_rope, qs, i);
                    let ks = &mut dk.row_mut(i)[h * dh..(h + 1) * dh];
                    rotate_inverse(inv_rope, ks, i);
                }
            }
            // q = ln1_out·wq etc.
            g.wq.axpy_mat(1.0, &cache.ln1_out.transpose().matmul(&dq));
            g.wk.axpy_mat(1.0, &cache.ln1_out.transpose().matmul(&dk));
            g.wv.axpy_mat(1.0, &cache.ln1_out.transpose().matmul(&dv_full));
            let mut dln1_out = dq.matmul(&layer.wq.transpose());
            dln1_out.axpy_mat(1.0, &dk.matmul(&layer.wk.transpose()));
            dln1_out.axpy_mat(1.0, &dv_full.matmul(&layer.wv.transpose()));
            let dx_in_from_attn =
                rmsnorm_bwd(&cache.x_in, &layer.ln1_g, &cache.ln1_rms, &dln1_out, &mut g.ln1_g);
            let mut dx_in = dx_mid; // residual
            dx_in.axpy_mat(1.0, &dx_in_from_attn);
            dx = dx_in;
        }

        // Embedding scatter.
        for (i, &t) in record.tokens.iter().enumerate() {
            let drow = dx.row(i);
            for j in 0..d {
                grads.embed[(t, j)] += drow[j];
            }
        }
    }

    /// [`Self::backward`] with the per-head attention backward routed
    /// through the engine's LM-backward lane
    /// ([`EngineOp::AttnBackward`](crate::attention::batched::EngineOp))
    /// — one job per head, one `submit` per layer. See
    /// [`Self::backward_batch_with_engine`] for the batched form (and
    /// the bit-identity contract).
    pub fn backward_with_engine(
        &self,
        record: &ForwardRecord,
        dlogits: &Matrix,
        dcls: Option<[f64; 2]>,
        grads: &mut Gradients,
        engine: &BatchedEngine,
        mode: &AttnBackwardMode,
    ) {
        self.backward_batch_with_engine(&[(record, dlogits, dcls)], grads, engine, mode);
    }

    /// Backward for a micro-batch of forward records through the
    /// engine: all non-attention chain arithmetic stays inline (it is
    /// `O(n·d²)` and layer-sequential), while every (sequence, head)
    /// attention backward of a layer fans out as **one
    /// [`BatchedEngine::submit`] of `AttnBackwardJob`s** — the last
    /// dense `O(n²)`-memory training path, converted to the one-door
    /// architecture. Layers are inherently sequential in a backward
    /// pass (layer `ℓ`'s upstream gradient depends on `ℓ+1`'s output),
    /// so per-layer submits spanning the whole micro-batch are the
    /// widest possible batching.
    ///
    /// With row-stream [`AttnBackwardMode::Exact`] the accumulated `grads` are
    /// **bit-identical** to calling the dense [`Self::backward`] per
    /// record in order, for any engine worker count: the streamed
    /// kernel replays the dense float-op order per output element, jobs
    /// are pure, results are input-ordered, and every parameter's
    /// accumulation chain visits records in the same order as the
    /// sequential dense loop (`tests/gradient_oracle.rs` pins 1/2/8).
    /// Unlike the dense oracle it allocates no `n×n` matrix — the
    /// jobs borrow the forward's softmax rows (`Arc`) and stream them.
    ///
    /// [`AttnBackwardMode::Fast`] swaps the per-head kernel for the
    /// conv-basis path (`O(k·n·d_h²·log n)` per head), within recovery
    /// tolerance of exact; recovery failures fall back densely and are
    /// counted in `grad_fallbacks`/`lm_backward_fallbacks`.
    pub fn backward_batch_with_engine(
        &self,
        batch: &[(&ForwardRecord, &Matrix, Option<[f64; 2]>)],
        grads: &mut Gradients,
        engine: &BatchedEngine,
        mode: &AttnBackwardMode,
    ) {
        let d = self.cfg.d_model;
        let nh = self.cfg.n_heads;
        let dh = self.cfg.head_dim();
        let scale = 1.0 / (dh as f64).sqrt();

        // Output head(s) + final RMSNorm, per record in order (the
        // same per-parameter accumulation order as sequential dense
        // backwards).
        let mut dxs: Vec<Matrix> = Vec::with_capacity(batch.len());
        for (record, dlogits, dcls) in batch {
            record.caches.as_ref().expect("forward(keep_cache=true) required");
            let n = record.logits.rows();
            grads.head.axpy_mat(1.0, &record.final_hidden.transpose().matmul(dlogits));
            let mut dfinal = dlogits.matmul(&self.head.transpose());
            if let Some(dc) = dcls {
                let h_last = record.final_hidden.row(n - 1);
                for c in 0..2 {
                    for j in 0..d {
                        grads.cls_head[(j, c)] += dc[c] * h_last[j];
                    }
                }
                let drow = dfinal.row_mut(n - 1);
                for j in 0..d {
                    drow[j] += dc[0] * self.cls_head[(j, 0)] + dc[1] * self.cls_head[(j, 1)];
                }
            }
            dxs.push(rmsnorm_bwd(
                &record.lnf_in,
                &self.lnf_g,
                &record.lnf_rms,
                &dfinal,
                &mut grads.lnf_g,
            ));
        }

        // Layers in reverse; one engine submit per layer covering every
        // (record, head) attention backward.
        for (li, layer) in self.layers.iter().enumerate().rev() {
            let mut jobs: Vec<EngineJob> = Vec::with_capacity(batch.len() * nh);
            let mut dx_mids: Vec<Matrix> = Vec::with_capacity(batch.len());
            for (bi, (record, _, _)) in batch.iter().enumerate() {
                let cache = &record.caches.as_ref().unwrap()[li];
                let g = &mut grads.layers[li];
                let n = cache.x_in.rows();
                let dx = &dxs[bi];

                // x = x_mid + ff_act·w2  (ff path)
                let dff_out = dx.clone();
                let dff_act = dff_out.matmul(&layer.w2.transpose());
                g.w2.axpy_mat(1.0, &cache.ff_act.transpose().matmul(&dff_out));
                let dff_pre = Matrix::from_fn(n, self.cfg.d_ff, |i, j| {
                    dff_act[(i, j)] * gelu_grad(cache.ff_pre[(i, j)])
                });
                g.w1.axpy_mat(1.0, &cache.ln2_out.transpose().matmul(&dff_pre));
                let dln2_out = dff_pre.matmul(&layer.w1.transpose());
                let dx_mid_from_ff = rmsnorm_bwd(
                    &cache.x_mid,
                    &layer.ln2_g,
                    &cache.ln2_rms,
                    &dln2_out,
                    &mut g.ln2_g,
                );
                let mut dx_mid = dx.clone(); // residual
                dx_mid.axpy_mat(1.0, &dx_mid_from_ff);

                // x_mid = x_in + attn_concat·wo
                let dattn_out = dx_mid.clone();
                g.wo.axpy_mat(1.0, &cache.attn_concat.transpose().matmul(&dattn_out));
                let dattn_concat = dattn_out.matmul(&layer.wo.transpose());

                // Gather: one LM-backward job per head. Inputs are the
                // identical extractions the dense loop and the forward
                // jobs perform (`head_blocks`), so exact mode
                // reproduces the dense bits and the fast mode's cache
                // keys collide with the forward's.
                for h in 0..nh {
                    let dout_h = Matrix::from_fn(n, dh, |i, j| dattn_concat[(i, h * dh + j)]);
                    let (qh, kh, vh) =
                        self.head_blocks(&cache.q_rot, &cache.k_rot, &cache.v, h);
                    // The forward's per-head artifact rides the job:
                    // probs (exact / conv-fallback heads) for the exact
                    // kernel and the dense fallback, the step-scoped
                    // basis handle (conv heads) for the fast kernel —
                    // the forward→backward handoff that makes conv
                    // training recover each operator once per step.
                    jobs.push(EngineJob::attn_backward(
                        (bi * nh + h) as u64,
                        AttnBackwardJob {
                            layer: li as u32,
                            head: h as u32,
                            q: qh,
                            k: kh,
                            v: vh,
                            dout: dout_h,
                            probs: cache.probs[h].clone(),
                            basis: cache.bases[h].clone(),
                            mode: mode.clone(),
                        },
                    ));
                }
                dx_mids.push(dx_mid);
            }

            // The one door: all (record, head) attention backwards of
            // this layer in a single engine call.
            let mut outs = engine.submit(jobs).into_iter();

            // Scatter: finish the layer per record, in order.
            for (bi, (record, _, _)) in batch.iter().enumerate() {
                let cache = &record.caches.as_ref().unwrap()[li];
                let g = &mut grads.layers[li];
                let n = cache.x_in.rows();
                let mut dq_rot = Matrix::zeros(n, d);
                let mut dk_rot = Matrix::zeros(n, d);
                let mut dv_full = Matrix::zeros(n, d);
                for h in 0..nh {
                    let out = outs
                        .next()
                        .expect("one output per job")
                        .result
                        .into_attn_backward();
                    for i in 0..n {
                        for j in 0..dh {
                            dq_rot[(i, h * dh + j)] += out.dq[(i, j)] * scale;
                            dk_rot[(i, h * dh + j)] += out.dk[(i, j)];
                            dv_full[(i, h * dh + j)] += out.dv[(i, j)];
                        }
                    }
                }
                // RoPE backward: inverse rotation (orthogonal).
                let mut dq = dq_rot;
                let mut dk = dk_rot;
                for h in 0..nh {
                    for i in 0..n {
                        let qs = &mut dq.row_mut(i)[h * dh..(h + 1) * dh];
                        rotate_inverse(&self.rope, qs, i);
                        let ks = &mut dk.row_mut(i)[h * dh..(h + 1) * dh];
                        rotate_inverse(&self.rope, ks, i);
                    }
                }
                // q = ln1_out·wq etc.
                g.wq.axpy_mat(1.0, &cache.ln1_out.transpose().matmul(&dq));
                g.wk.axpy_mat(1.0, &cache.ln1_out.transpose().matmul(&dk));
                g.wv.axpy_mat(1.0, &cache.ln1_out.transpose().matmul(&dv_full));
                let mut dln1_out = dq.matmul(&layer.wq.transpose());
                dln1_out.axpy_mat(1.0, &dk.matmul(&layer.wk.transpose()));
                dln1_out.axpy_mat(1.0, &dv_full.matmul(&layer.wv.transpose()));
                let dx_in_from_attn = rmsnorm_bwd(
                    &cache.x_in,
                    &layer.ln1_g,
                    &cache.ln1_rms,
                    &dln1_out,
                    &mut g.ln1_g,
                );
                let mut dx_in = std::mem::replace(&mut dx_mids[bi], Matrix::zeros(0, 0));
                dx_in.axpy_mat(1.0, &dx_in_from_attn);
                dxs[bi] = dx_in;
            }
        }

        // Embedding scatter, per record in order.
        for (bi, (record, _, _)) in batch.iter().enumerate() {
            for (i, &t) in record.tokens.iter().enumerate() {
                let drow = dxs[bi].row(i);
                for j in 0..d {
                    grads.embed[(t, j)] += drow[j];
                }
            }
        }
    }
}

/// Inverse RoPE rotation (rotate by −pos): transpose of the forward
/// rotation, used for the gradient.
fn rotate_inverse(_rope: &Rope, row: &mut [f64], pos: usize) {
    // Forward rotates by +θ·pos per plane; the Jacobian is the rotation
    // itself, so the gradient rotates by −θ·pos. We re-use the forward
    // machinery by negating the pairs' angle via conjugation:
    // rot(-θ): (a, b) → (a c + b s, −a s + b c). Implemented directly.
    let d = row.len();
    debug_assert!(d % 2 == 0);
    // Reconstruct the frequencies the same way Rope::new does.
    for k in 0..d / 2 {
        let f = 10_000f64.powf(-2.0 * k as f64 / d as f64);
        let theta = pos as f64 * f;
        let (s, c) = theta.sin_cos();
        let (a, b) = (row[2 * k], row[2 * k + 1]);
        row[2 * k] = a * c + b * s;
        row[2 * k + 1] = -a * s + b * c;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::max_abs_diff;

    fn tiny_model(seed: u64) -> Transformer {
        let mut rng = Rng::seeded(seed);
        let cfg = ModelConfig {
            vocab_size: 16,
            d_model: 8,
            n_heads: 2,
            n_layers: 2,
            d_ff: 16,
            max_seq: 16,
        };
        Transformer::new(&cfg, &mut rng)
    }

    #[test]
    fn forward_shapes() {
        let m = tiny_model(201);
        let rec =
            m.forward(&[1, 2, 3, 4, 5], &AttentionBackend::Exact(ExactKernel::RowStream), false);
        assert_eq!(rec.logits.shape(), (5, 16));
        assert_eq!(rec.final_hidden.shape(), (5, 8));
        assert!(rec.logits.is_finite());
    }

    #[test]
    fn rotate_inverse_is_inverse() {
        let rope = Rope::new(8, 10_000.0);
        let mut rng = Rng::seeded(202);
        let orig = rng.randn_vec(8);
        let mut row = orig.clone();
        rope.rotate_row(&mut row, 13);
        rotate_inverse(&rope, &mut row, 13);
        for (a, b) in row.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn lm_loss_decreases_on_overfit_step() {
        let m = tiny_model(203);
        let tokens = [1usize, 2, 3, 4, 5, 6];
        let targets = [2usize, 3, 4, 5, 6, 7];
        let rec = m.forward(&tokens, &AttentionBackend::Exact(ExactKernel::RowStream), true);
        let (loss0, dlogits) = m.lm_loss(&rec, &targets, usize::MAX);
        let mut grads = m.zero_grads();
        m.backward(&rec, &dlogits, None, &mut grads);
        // SGD step.
        let mut m2 = m.clone();
        let lr = 0.5;
        m2.embed.axpy_mat(-lr, &grads.embed);
        m2.head.axpy_mat(-lr, &grads.head);
        for (l, gl) in m2.layers.iter_mut().zip(&grads.layers) {
            l.wq.axpy_mat(-lr, &gl.wq);
            l.wk.axpy_mat(-lr, &gl.wk);
            l.wv.axpy_mat(-lr, &gl.wv);
            l.wo.axpy_mat(-lr, &gl.wo);
            l.w1.axpy_mat(-lr, &gl.w1);
            l.w2.axpy_mat(-lr, &gl.w2);
        }
        let rec2 = m2.forward(&tokens, &AttentionBackend::Exact(ExactKernel::RowStream), false);
        let (loss1, _) = m2.lm_loss(&rec2, &targets, usize::MAX);
        assert!(loss1 < loss0, "{loss1} !< {loss0}");
    }

    #[test]
    fn gradients_match_finite_differences() {
        // Spot-check several parameters end-to-end.
        let m = tiny_model(204);
        let tokens = [3usize, 1, 4, 1, 5];
        let targets = [1usize, 4, 1, 5, 9];
        let rec = m.forward(&tokens, &AttentionBackend::Exact(ExactKernel::RowStream), true);
        let (_, dlogits) = m.lm_loss(&rec, &targets, usize::MAX);
        let mut grads = m.zero_grads();
        m.backward(&rec, &dlogits, None, &mut grads);

        let eps = 1e-5;
        let loss_with = |m: &Transformer| {
            let r = m.forward(&tokens, &AttentionBackend::Exact(ExactKernel::RowStream), false);
            m.lm_loss(&r, &targets, usize::MAX).0
        };
        // wq of layer 0, a few entries.
        for &(i, j) in &[(0usize, 0usize), (3, 5), (7, 2)] {
            let mut mp = m.clone();
            mp.layers[0].wq[(i, j)] += eps;
            let mut mm = m.clone();
            mm.layers[0].wq[(i, j)] -= eps;
            let fd = (loss_with(&mp) - loss_with(&mm)) / (2.0 * eps);
            let an = grads.layers[0].wq[(i, j)];
            assert!((fd - an).abs() < 1e-5, "wq({i},{j}): fd={fd} an={an}");
        }
        // ln1_g of layer 1.
        for &j in &[0usize, 4] {
            let mut mp = m.clone();
            mp.layers[1].ln1_g[j] += eps;
            let mut mm = m.clone();
            mm.layers[1].ln1_g[j] -= eps;
            let fd = (loss_with(&mp) - loss_with(&mm)) / (2.0 * eps);
            let an = grads.layers[1].ln1_g[j];
            assert!((fd - an).abs() < 1e-5, "ln1_g({j}): fd={fd} an={an}");
        }
        // Embedding of token 1 (appears twice).
        for &j in &[0usize, 7] {
            let mut mp = m.clone();
            mp.embed[(1, j)] += eps;
            let mut mm = m.clone();
            mm.embed[(1, j)] -= eps;
            let fd = (loss_with(&mp) - loss_with(&mm)) / (2.0 * eps);
            let an = grads.embed[(1, j)];
            assert!((fd - an).abs() < 1e-4 * (1.0 + an.abs()), "embed(1,{j}): fd={fd} an={an}");
        }
        // w2 of layer 0.
        let mut mp = m.clone();
        mp.layers[0].w2[(5, 3)] += eps;
        let mut mm = m.clone();
        mm.layers[0].w2[(5, 3)] -= eps;
        let fd = (loss_with(&mp) - loss_with(&mm)) / (2.0 * eps);
        let an = grads.layers[0].w2[(5, 3)];
        assert!((fd - an).abs() < 1e-5, "w2: fd={fd} an={an}");
        // Final norm gain + head.
        let mut mp = m.clone();
        mp.lnf_g[2] += eps;
        let mut mm = m.clone();
        mm.lnf_g[2] -= eps;
        let fd = (loss_with(&mp) - loss_with(&mm)) / (2.0 * eps);
        assert!((fd - grads.lnf_g[2]).abs() < 1e-5);
    }

    #[test]
    fn cls_gradient_matches_finite_differences() {
        let m = tiny_model(205);
        let tokens = [2usize, 7, 1, 9];
        let label = true;
        let rec = m.forward(&tokens, &AttentionBackend::Exact(ExactKernel::RowStream), true);
        let (_, _, dcls) = m.cls_loss(&rec, label);
        let mut grads = m.zero_grads();
        let zero_dlogits = Matrix::zeros(4, 16);
        m.backward(&rec, &zero_dlogits, Some(dcls), &mut grads);

        let eps = 1e-5;
        let loss_with = |m: &Transformer| {
            let r = m.forward(&tokens, &AttentionBackend::Exact(ExactKernel::RowStream), false);
            m.cls_loss(&r, label).0
        };
        let mut mp = m.clone();
        mp.cls_head[(3, 1)] += eps;
        let mut mm = m.clone();
        mm.cls_head[(3, 1)] -= eps;
        let fd = (loss_with(&mp) - loss_with(&mm)) / (2.0 * eps);
        assert!((fd - grads.cls_head[(3, 1)]).abs() < 1e-6);
        // And a weight upstream of the pooled position.
        let mut mp = m.clone();
        mp.layers[0].wv[(2, 2)] += eps;
        let mut mm = m.clone();
        mm.layers[0].wv[(2, 2)] -= eps;
        let fd = (loss_with(&mp) - loss_with(&mm)) / (2.0 * eps);
        let an = grads.layers[0].wv[(2, 2)];
        assert!((fd - an).abs() < 1e-5, "fd={fd} an={an}");
    }

    #[test]
    fn deterministic_forward() {
        let m = tiny_model(206);
        let a = m.forward(&[1, 2, 3], &AttentionBackend::Exact(ExactKernel::RowStream), false);
        let b = m.forward(&[1, 2, 3], &AttentionBackend::Exact(ExactKernel::RowStream), false);
        assert!(max_abs_diff(&a.logits, &b.logits) == 0.0);
    }

    #[test]
    fn prefill_logits_bitmatch_forward() {
        use crate::attention::batched::{BatchedEngine, EngineConfig};
        let m = tiny_model(208);
        let engine = BatchedEngine::new(EngineConfig { workers: 2, cache_capacity: 32 });
        for backend in
            [AttentionBackend::Exact(ExactKernel::RowStream), AttentionBackend::ConvStrided(4)]
        {
            let prompt = vec![1usize, 2, 3, 4, 5];
            let (sess, last) = m.prefill(&prompt, &backend, &engine);
            assert_eq!(sess.len(), prompt.len());
            let want = m.forward(&prompt, &backend, false);
            assert_eq!(
                last,
                want.logits.row(prompt.len() - 1).to_vec(),
                "prefill logits must be bit-identical to forward"
            );
        }
    }

    #[test]
    fn decode_steps_bitmatch_full_forward() {
        // T exact decode steps from a length-n prefill must reproduce a
        // fresh length-(n+t) forward bit-for-bit at every step.
        use crate::attention::batched::{BatchedEngine, EngineConfig};
        let m = tiny_model(209);
        let engine = BatchedEngine::new(EngineConfig { workers: 2, cache_capacity: 32 });
        let prompt = vec![3usize, 1, 4, 1];
        let feed = [5usize, 9, 2, 6];
        let (mut sess, _) =
            m.prefill(&prompt, &AttentionBackend::Exact(ExactKernel::RowStream), &engine);
        let mut toks = prompt.clone();
        for &t in &feed {
            let logits = m.decode_step(std::slice::from_mut(&mut sess), &[t], &engine);
            toks.push(t);
            let want = m.forward(&toks, &AttentionBackend::Exact(ExactKernel::RowStream), false);
            assert_eq!(
                logits[0],
                want.logits.row(toks.len() - 1).to_vec(),
                "decode step must bit-match full re-prefill at n={}",
                toks.len()
            );
        }
        assert_eq!(sess.tokens(), &toks[..]);
    }

    #[test]
    fn conv_decode_steps_are_finite_and_seeded() {
        use crate::attention::batched::{BatchedEngine, EngineConfig};
        let m = tiny_model(210);
        let engine = BatchedEngine::new(EngineConfig { workers: 2, cache_capacity: 64 });
        let backend = AttentionBackend::ConvStrided(4);
        let (mut sess, last) = m.prefill(&[1, 2, 3, 4, 5, 6], &backend, &engine);
        assert!(last.iter().all(|x| x.is_finite()));
        // Prefill seeded every (layer, head) straight from the cache.
        let snap = engine.metrics().snapshot();
        assert_eq!(snap.decode_seed_hits + snap.decode_seed_misses, 4, "2 layers × 2 heads");
        assert_eq!(snap.decode_seed_hits, 4, "strided prefill must have cached all bases");
        let logits = m.decode_step(std::slice::from_mut(&mut sess), &[7], &engine);
        assert!(logits[0].iter().all(|x| x.is_finite()));
        let snap = engine.metrics().snapshot();
        assert_eq!(snap.decode_steps, 4, "2 layers × 2 heads");
    }

    #[test]
    fn decode_step_with_jobs_merges_prefill_without_changing_decode() {
        // A decode step with prefill riders must give bit-identical
        // logits to a plain decode step, and the riders' outputs must
        // bit-match standalone execution.
        use crate::attention::batched::{BatchedBackend, BatchedEngine, EngineConfig};
        use crate::attention::{exact_attention, Mask};
        let m = tiny_model(212);
        let prompt = vec![2usize, 4, 6, 8, 10];
        let engine_a = BatchedEngine::new(EngineConfig { workers: 2, cache_capacity: 32 });
        let engine_b = BatchedEngine::new(EngineConfig { workers: 2, cache_capacity: 32 });
        let exact = AttentionBackend::Exact(ExactKernel::RowStream);
        let (mut sess_a, _) = m.prefill(&prompt, &exact, &engine_a);
        let (mut sess_b, _) = m.prefill(&prompt, &exact, &engine_b);

        let mut rng = Rng::seeded(213);
        let (n, d) = (12, 4);
        let riders: Vec<crate::attention::batched::AttnJob> = (0..3)
            .map(|h| {
                let q = Matrix::randn(n, d, &mut rng).scale(0.3);
                let k = Matrix::randn(n, d, &mut rng).scale(0.3);
                let v = Matrix::randn(n, d, &mut rng);
                crate::attention::batched::AttnJob::causal(
                    9,
                    h,
                    q,
                    k,
                    v,
                    BatchedBackend::Exact(ExactKernel::RowStream),
                )
            })
            .collect();
        let want_riders: Vec<Matrix> = riders
            .iter()
            .map(|j| exact_attention(&j.q, &j.k, &j.v, &Mask::causal(n)))
            .collect();

        let plain = m.decode_step(std::slice::from_mut(&mut sess_a), &[3], &engine_a);
        let (merged, rider_outs) = m.decode_step_with_jobs(
            std::slice::from_mut(&mut sess_b),
            &[3],
            &engine_b,
            riders,
        );
        assert_eq!(plain, merged, "riders must not change decode logits");
        assert_eq!(rider_outs.len(), 3);
        for (out, want) in rider_outs.iter().zip(&want_riders) {
            assert_eq!(max_abs_diff(&out.y, want), 0.0, "rider output must be exact");
        }
        // And with no sessions at all, extra jobs still execute.
        let (none, outs) =
            m.decode_step_with_jobs(&mut [], &[], &engine_a, vec![]);
        assert!(none.is_empty() && outs.is_empty());
    }

    #[test]
    fn forward_batch_matches_per_sequence_forward() {
        use crate::attention::batched::{BatchedEngine, EngineConfig};
        let m = tiny_model(207);
        let engine = BatchedEngine::new(EngineConfig { workers: 2, cache_capacity: 32 });
        let seqs: Vec<Vec<usize>> =
            vec![vec![1, 2, 3, 4, 5, 6], vec![7, 8, 9], vec![2, 4, 6, 8, 10, 12, 14, 1]];
        for backend in
            [AttentionBackend::Exact(ExactKernel::RowStream), AttentionBackend::ConvStrided(4)]
        {
            let singles: Vec<_> =
                seqs.iter().map(|s| m.forward(s, &backend, false)).collect();
            let batched = m.forward_batch(&seqs, &backend, &engine);
            assert_eq!(batched.len(), seqs.len());
            for (b, s) in batched.iter().zip(&singles) {
                assert_eq!(
                    max_abs_diff(&b.logits, &s.logits),
                    0.0,
                    "batched forward must be bit-identical to the per-sequence path"
                );
            }
        }
    }
}
