//! Pluggable attention operator — the Section 7 experiment switch.
//!
//! The conv-basis and low-rank backends are *inference-time drop-ins*:
//! they replace the attention operator of an already-trained model with
//! no parameter updates, exactly the paper's protocol.

use crate::attention::batched::{BatchedBackend, DecodeOp, RouterPolicy};
use crate::attention::blocked::{blocked_attention_causal, blocked_train_forward};
use crate::attention::{conv_attention, exact_attention, ExactKernel, Mask};
use crate::basis::RecoverConfig;
use crate::lowrank::{LowRankAttention, LowRankConfig};
use crate::tensor::Matrix;
use std::sync::Arc;

/// Which operator computes `softmax(QKᵀ)·V` per head.
#[derive(Clone, Debug)]
pub enum AttentionBackend {
    /// Exact `O(n²d)` attention (training + baseline), served by the
    /// selected [`ExactKernel`] family — the row-streamed oracle or
    /// the blocked streaming-softmax kernels. Decode pins to the same
    /// flavor (see [`Self::to_decode`]).
    Exact(ExactKernel),
    /// Algorithm 1 with the adaptive binary-search recovery
    /// (Algorithms 2–3). Falls back to exact on recovery failure
    /// (degenerate normalizer etc.) — the serving layer records
    /// fallbacks in its metrics.
    ConvBasis(RecoverConfig),
    /// Algorithm 1 with strided (non-adaptive) recovery at k uniform
    /// onsets — the Section 7 protocol knob. k = n is exact.
    ConvStrided(usize),
    /// Theorem 6.5: masked low-rank approximation.
    LowRank(LowRankConfig),
    /// Per-(layer, head) adaptive routing: the policy resolves each
    /// head to exact / conv(k) / low-rank inside the engine
    /// ([`BatchedBackend::Routed`]). Engine-path only — this variant
    /// requires the (layer, head) identity that `forward_batch` /
    /// `prefill_batch` carry, so the single-head [`Self::attend`]
    /// rejects it. Decode is pinned to the exact last-row kernel:
    /// a low-rank route cannot seed a
    /// [`DecodeState`](crate::attention::decode::DecodeState), and
    /// pinning **all** routed heads to exact decode keeps the decode
    /// plan independent of the policy table (conv seeding under a
    /// mixed table would hit or miss per head, breaking the seed-hit
    /// invariants `tests/decode.rs` pins).
    Routed(Arc<RouterPolicy>),
}

impl AttentionBackend {
    /// A conv backend whose basis count is the paper's x-axis in
    /// Figure 4 (strided onsets: accuracy grows monotonically with k on
    /// real attention matrices; k = n reproduces exact attention).
    pub fn conv_with_k(k: usize, n: usize) -> Self {
        let _ = n;
        AttentionBackend::ConvStrided(k.max(1))
    }

    /// The engine-side job spec with semantics identical to
    /// [`Self::attend`]: per-head `Q` arrives pre-scaled by `1/√d_h`, so
    /// the low-rank path pins `scale = 1` exactly as `attend` does.
    /// Used by `Transformer::forward_batch` to route all heads of a
    /// forward pass through one `BatchedEngine` call per layer.
    pub fn to_batched(&self) -> BatchedBackend {
        match self {
            AttentionBackend::Exact(kernel) => BatchedBackend::Exact(*kernel),
            AttentionBackend::ConvBasis(cfg) => BatchedBackend::Conv(*cfg),
            AttentionBackend::ConvStrided(k) => BatchedBackend::Strided(*k),
            AttentionBackend::LowRank(cfg) => {
                BatchedBackend::LowRank(LowRankConfig::new(cfg.degree, 1.0))
            }
            AttentionBackend::Routed(policy) => BatchedBackend::Routed(Arc::clone(policy)),
        }
    }

    /// The decode-time operator matching this backend, used by
    /// `Transformer::decode_step` to drive one-token-at-a-time serving
    /// through the engine:
    ///
    /// * `Exact` and `LowRank` decode through the exact last-row kernel
    ///   (`O(n·d_h)` per step — the KV-cache cost; low-rank has no
    ///   incremental form, and the exact row is both cheaper than its
    ///   feature construction and bit-stable);
    /// * the conv backends decode through a cached-basis
    ///   [`DecodeState`](crate::attention::decode::DecodeState) in
    ///   `O(k·n + n·d_h)`, seeded from the prefill's `BasisCache` entry
    ///   and re-recovered on drift. `ConvBasis` maps its `k_max` onto
    ///   the strided decode schedule (adaptive recovery has no
    ///   incremental analogue; the strided schedule is the serving
    ///   protocol).
    pub fn to_decode(&self) -> DecodeOp {
        match self {
            // Exact decode inherits the prefill's kernel flavor: the
            // decode-bitmatches-prefill contract only holds within one
            // ExactKernel family, so mixing flavors across prefill and
            // decode would break the bit pins in tests/decode.rs and
            // tests/blocked_kernels.rs.
            AttentionBackend::Exact(kernel) => DecodeOp::Exact(*kernel),
            // Routed/low-rank decode pins to the row-stream exact row:
            // low-rank routes cannot seed a DecodeState, and a
            // policy-independent decode plan keeps the seed-hit
            // invariants intact (see the variant docs).
            // `Transformer::prefill_batch` counts the pinned low-rank
            // slots in `Metrics::router_decode_pins`.
            AttentionBackend::LowRank(_) | AttentionBackend::Routed(_) => {
                DecodeOp::Exact(ExactKernel::RowStream)
            }
            AttentionBackend::ConvBasis(cfg) => DecodeOp::conv(cfg.k_max),
            AttentionBackend::ConvStrided(k) => DecodeOp::conv(*k),
        }
    }

    /// Compute one head: inputs are pre-scaled `Q` (×1/√d_h), `K`, `V`.
    /// Returns the output and, when `keep_probs` (training), the dense
    /// attention probabilities (only the exact backend supports that).
    pub fn attend(
        &self,
        q: &Matrix,
        k: &Matrix,
        v: &Matrix,
        keep_probs: bool,
    ) -> (Matrix, Option<Matrix>) {
        let n = q.rows();
        let mask = Mask::causal(n);
        match self {
            AttentionBackend::Exact(ExactKernel::RowStream) => {
                if keep_probs {
                    // The one source of truth for training-forward
                    // softmax rows: the LM-backward fallback replays
                    // the same helper, so its "bit-identical to exact
                    // mode" contract can't drift out of sync.
                    let probs = crate::gradient::batched::dense_causal_probs(q, k);
                    (probs.matmul(v), Some(probs))
                } else {
                    (exact_attention(q, k, v, &mask), None)
                }
            }
            AttentionBackend::Exact(ExactKernel::Blocked) => {
                if keep_probs {
                    let (y, probs) = blocked_train_forward(q, k, v);
                    (y, Some(probs))
                } else {
                    (blocked_attention_causal(q, k, v), None)
                }
            }
            AttentionBackend::ConvBasis(cfg) => {
                assert!(!keep_probs, "approximate backends are inference-only");
                match conv_attention(q, k, v, cfg) {
                    Ok(out) => (out.y, None),
                    Err(_) => (exact_attention(q, k, v, &mask), None),
                }
            }
            AttentionBackend::ConvStrided(kb) => {
                assert!(!keep_probs, "approximate backends are inference-only");
                match crate::attention::conv_attention_strided(q, k, v, *kb) {
                    Ok(out) => (out.y, None),
                    Err(_) => (exact_attention(q, k, v, &mask), None),
                }
            }
            AttentionBackend::LowRank(cfg) => {
                assert!(!keep_probs, "approximate backends are inference-only");
                // LowRankAttention expects unscaled logits divided by
                // `cfg.scale`; our q is pre-scaled, so scale = 1.
                let lr = LowRankAttention::new(q, k, mask, &LowRankConfig::new(cfg.degree, 1.0));
                (lr.forward(v), None)
            }
            AttentionBackend::Routed(_) => panic!(
                "Routed attention requires the engine path (forward_batch / prefill_batch): \
                 per-head routing needs the (layer, head) identity attend() does not carry"
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{max_abs_diff, Rng};

    #[test]
    fn exact_paths_agree() {
        let mut rng = Rng::seeded(211);
        let (n, d) = (10, 4);
        let q = Matrix::randn(n, d, &mut rng).scale(0.5);
        let k = Matrix::randn(n, d, &mut rng).scale(0.5);
        let v = Matrix::randn(n, d, &mut rng);
        let b = AttentionBackend::Exact(ExactKernel::RowStream);
        let (y1, p) = b.attend(&q, &k, &v, true);
        let (y2, _) = b.attend(&q, &k, &v, false);
        assert!(max_abs_diff(&y1, &y2) < 1e-10);
        let probs = p.unwrap();
        for i in 0..n {
            let s: f64 = probs.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-10);
        }
    }

    #[test]
    fn conv_backend_with_full_k_matches_exact() {
        let mut rng = Rng::seeded(212);
        let (n, d) = (16, 4);
        let q = Matrix::randn(n, d, &mut rng).scale(0.4);
        let k = Matrix::randn(n, d, &mut rng).scale(0.4);
        let v = Matrix::randn(n, d, &mut rng);
        let exact = AttentionBackend::Exact(ExactKernel::RowStream).attend(&q, &k, &v, false).0;
        let conv = AttentionBackend::ConvBasis(RecoverConfig::exact(n))
            .attend(&q, &k, &v, false)
            .0;
        assert!(max_abs_diff(&exact, &conv) < 1e-8);
    }

    #[test]
    fn lowrank_backend_close_for_bounded_inputs() {
        let mut rng = Rng::seeded(213);
        let (n, d) = (14, 3);
        let q = Matrix::rand_uniform(n, d, 0.5, &mut rng);
        let k = Matrix::rand_uniform(n, d, 0.5, &mut rng);
        let v = Matrix::randn(n, d, &mut rng);
        let exact = AttentionBackend::Exact(ExactKernel::RowStream).attend(&q, &k, &v, false).0;
        let lr = AttentionBackend::LowRank(LowRankConfig::new(6, 1.0))
            .attend(&q, &k, &v, false)
            .0;
        assert!(max_abs_diff(&exact, &lr) < 1e-3);
    }

    #[test]
    fn routed_backend_maps_to_engine_and_pins_decode_to_exact() {
        use crate::attention::batched::HeadRoute;
        let policy = Arc::new(RouterPolicy::new(HeadRoute::Strided(4)));
        let b = AttentionBackend::Routed(policy);
        assert!(matches!(b.to_batched(), BatchedBackend::Routed(_)));
        assert!(
            matches!(b.to_decode(), DecodeOp::Exact(ExactKernel::RowStream)),
            "routed decode is pinned to the row-stream exact last-row kernel"
        );
    }

    #[test]
    #[should_panic(expected = "Routed attention requires the engine path")]
    fn routed_backend_rejects_single_head_attend() {
        use crate::attention::batched::HeadRoute;
        let mut rng = Rng::seeded(215);
        let q = Matrix::randn(8, 4, &mut rng);
        let k = Matrix::randn(8, 4, &mut rng);
        let v = Matrix::randn(8, 4, &mut rng);
        let b = AttentionBackend::Routed(Arc::new(RouterPolicy::new(HeadRoute::Exact)));
        let _ = b.attend(&q, &k, &v, false);
    }

    #[test]
    fn conv_backend_falls_back_gracefully() {
        // Pathological inputs (huge logits) can break recovery; the
        // backend must still return finite output.
        let mut rng = Rng::seeded(214);
        let (n, d) = (12, 4);
        let q = Matrix::randn(n, d, &mut rng).scale(10.0);
        let k = Matrix::randn(n, d, &mut rng).scale(10.0);
        let v = Matrix::randn(n, d, &mut rng);
        let b = AttentionBackend::conv_with_k(2, n);
        let (y, _) = b.attend(&q, &k, &v, false);
        assert!(y.is_finite());
    }
}
