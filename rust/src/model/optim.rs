//! Adam optimizer over the transformer's parameter tree.

use super::transformer::{Gradients, Transformer};
use crate::tensor::Matrix;

/// Adam with bias correction (Kingma & Ba), acting on the full
/// parameter tree of a [`Transformer`].
pub struct Adam {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    t: u64,
    m: Option<Gradients>,
    v: Option<Gradients>,
}

impl Adam {
    pub fn new(lr: f64) -> Self {
        Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8, t: 0, m: None, v: None }
    }

    /// One update step (consumes the gradient values; model mutated in
    /// place).
    pub fn step(&mut self, model: &mut Transformer, grads: &Gradients) {
        if self.m.is_none() {
            self.m = Some(model.zero_grads());
            self.v = Some(model.zero_grads());
        }
        self.t += 1;
        let t = self.t;
        let (b1, b2, eps, lr) = (self.beta1, self.beta2, self.eps, self.lr);
        let bc1 = 1.0 - b1.powi(t as i32);
        let bc2 = 1.0 - b2.powi(t as i32);
        let m = self.m.as_mut().unwrap();
        let v = self.v.as_mut().unwrap();

        let update_mat = |p: &mut Matrix, g: &Matrix, m: &mut Matrix, v: &mut Matrix| {
            for i in 0..p.data().len() {
                let gi = g.data()[i];
                let mi = b1 * m.data()[i] + (1.0 - b1) * gi;
                let vi = b2 * v.data()[i] + (1.0 - b2) * gi * gi;
                m.data_mut()[i] = mi;
                v.data_mut()[i] = vi;
                let mhat = mi / bc1;
                let vhat = vi / bc2;
                p.data_mut()[i] -= lr * mhat / (vhat.sqrt() + eps);
            }
        };
        let update_vec = |p: &mut [f64], g: &[f64], m: &mut [f64], v: &mut [f64]| {
            for i in 0..p.len() {
                let gi = g[i];
                m[i] = b1 * m[i] + (1.0 - b1) * gi;
                v[i] = b2 * v[i] + (1.0 - b2) * gi * gi;
                let mhat = m[i] / bc1;
                let vhat = v[i] / bc2;
                p[i] -= lr * mhat / (vhat.sqrt() + eps);
            }
        };

        update_mat(&mut model.embed, &grads.embed, &mut m.embed, &mut v.embed);
        update_mat(&mut model.head, &grads.head, &mut m.head, &mut v.head);
        update_mat(&mut model.cls_head, &grads.cls_head, &mut m.cls_head, &mut v.cls_head);
        update_vec(&mut model.lnf_g, &grads.lnf_g, &mut m.lnf_g, &mut v.lnf_g);
        for li in 0..model.layers.len() {
            let lp = &mut model.layers[li];
            let lg = &grads.layers[li];
            let lm = &mut m.layers[li];
            let lv = &mut v.layers[li];
            update_mat(&mut lp.wq, &lg.wq, &mut lm.wq, &mut lv.wq);
            update_mat(&mut lp.wk, &lg.wk, &mut lm.wk, &mut lv.wk);
            update_mat(&mut lp.wv, &lg.wv, &mut lm.wv, &mut lv.wv);
            update_mat(&mut lp.wo, &lg.wo, &mut lm.wo, &mut lv.wo);
            update_mat(&mut lp.w1, &lg.w1, &mut lm.w1, &mut lv.w1);
            update_mat(&mut lp.w2, &lg.w2, &mut lm.w2, &mut lv.w2);
            update_vec(&mut lp.ln1_g, &lg.ln1_g, &mut lm.ln1_g, &mut lv.ln1_g);
            update_vec(&mut lp.ln2_g, &lg.ln2_g, &mut lm.ln2_g, &mut lv.ln2_g);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::ExactKernel;
    use crate::model::{AttentionBackend, ModelConfig};
    use crate::tensor::Rng;

    #[test]
    fn adam_reduces_loss_faster_than_nothing() {
        let mut rng = Rng::seeded(221);
        let cfg = ModelConfig {
            vocab_size: 16,
            d_model: 8,
            n_heads: 2,
            n_layers: 1,
            d_ff: 16,
            max_seq: 8,
        };
        let mut model = Transformer::new(&cfg, &mut rng);
        let mut opt = Adam::new(1e-2);
        let tokens = [1usize, 2, 3, 4, 5, 6];
        let targets = [2usize, 3, 4, 5, 6, 7];
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..30 {
            let rec =
                model.forward(&tokens, &AttentionBackend::Exact(ExactKernel::RowStream), true);
            let (loss, dlogits) = model.lm_loss(&rec, &targets, usize::MAX);
            if first.is_none() {
                first = Some(loss);
            }
            last = loss;
            let mut grads = model.zero_grads();
            model.backward(&rec, &dlogits, None, &mut grads);
            opt.step(&mut model, &grads);
        }
        assert!(last < first.unwrap() * 0.5, "{last} vs {first:?}");
    }
}
