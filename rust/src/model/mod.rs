//! A small decoder-only transformer with **pluggable attention
//! backends** — the Figure 4 / end-to-end experiment substrate.
//!
//! The training path uses exact attention with full manual backprop
//! (this crate has no autograd dependency); the inference path swaps the
//! attention operator per [`AttentionBackend`]:
//!
//! * `Exact` — the `O(n²d)` oracle (Definition 3.3),
//! * `ConvBasis` — Algorithm 1 (`O(knd log n)`, Theorem 4.4),
//! * `LowRank` — Theorem 6.5's masked low-rank path.
//!
//! This is exactly the paper's Section 7 protocol: train/obtain a model
//! with standard attention, then replace the attention mechanism at
//! inference with the conv approximation for varying k — **no parameter
//! updates**.
//!
//! For serving, the model also exposes the autoregressive decode path:
//! [`Transformer::prefill_batch`] builds a [`DecodeSession`] (KV caches
//! + per-head conv decode states seeded from the engine's basis cache)
//! and [`Transformer::decode_step`] advances a batch of sessions one
//! token per call through decode-lane `BatchedEngine::submit` calls —
//! no per-token re-prefill. `decode_step_with_jobs` additionally lets
//! prefill jobs ride a decode step's submit (the server's
//! continuous-batching merge lane), and live sessions report their KV
//! memory through `Metrics::decode_resident_bytes`
//! ([`DecodeSession::resident_bytes`] / [`DecodeSession::retire`]).
//!
//! For training, [`train_attention_heads`] steps every (layer, head)
//! Definition 5.1 problem with **one gradient-lane submit per step**,
//! and the full LM/classifier step is engine-routed end to end:
//! [`Transformer::forward_train_batch`] runs the training forward
//! through prefill-lane training jobs (exact or conv-basis per
//! [`TrainAttentionMode`]) and
//! [`Transformer::backward_batch_with_engine`] fans every (sequence,
//! head) attention backward of a layer through the engine's
//! LM-backward lane (exact mode bit-matches the dense oracle with no
//! `n×n` allocation; fast mode runs the conv-basis backward, consuming
//! the forward's step-scoped basis handle in conv training so each
//! operator is recovered exactly once per step).

mod backend;
mod optim;
mod train;
mod transformer;

pub use backend::AttentionBackend;
pub use optim::Adam;
pub use train::{
    eval_classifier, train_attention_heads, train_classifier, train_classifier_with_engine,
    train_lm, train_lm_with_engine, HeadProblem, HeadTrainConfig, HeadTrainResult,
    TrainAttentionMode, TrainConfig, TrainLog,
};
pub use transformer::{DecodeSession, ForwardRecord, Gradients, ModelConfig, Transformer};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn param_count_scales() {
        let small = ModelConfig::tiny(64);
        let big = ModelConfig { n_layers: 4, ..small };
        let mut rng = Rng::seeded(1);
        let m1 = Transformer::new(&small, &mut rng);
        let m2 = Transformer::new(&big, &mut rng);
        assert!(m2.num_params() > m1.num_params());
    }

    #[test]
    fn hundred_m_config_exists() {
        // The e2e example's "100M-class" configuration (run with reduced
        // steps on CPU; see EXPERIMENTS.md e2e).
        let cfg = ModelConfig::gpt_100m();
        let params = cfg.approx_params();
        assert!(params > 80_000_000 && params < 150_000_000, "params = {params}");
    }
}
