//! Dense oracles for the attention loss: `O(n²d)` analytic gradient and
//! finite differences. These anchor the correctness of [`super::fast`].

use super::AttentionLossProblem;
use crate::tensor::Matrix;

/// `f(x) = D(X)⁻¹ (M ∘ exp(A₁XA₂ᵀ))` — dense (Definition C.2 rows).
pub fn f_dense(p: &AttentionLossProblem, x: &Matrix) -> Matrix {
    let n = p.n();
    let logits = p.a1.matmul(x).matmul(&p.a2.transpose());
    let u = Matrix::from_fn(n, n, |i, j| {
        if p.mask.entry(i, j) {
            logits[(i, j)].exp()
        } else {
            0.0
        }
    });
    let d = u.row_sums();
    let inv: Vec<f64> = d.iter().map(|&v| 1.0 / v).collect();
    u.scale_rows(&inv)
}

/// Dense loss `L(X)` (Definition 5.1).
pub fn loss_naive(p: &AttentionLossProblem, x: &Matrix) -> f64 {
    let f = f_dense(p, x);
    let h = p.h();
    let c = f.matmul(&h).sub(&p.e);
    0.5 * c.data().iter().map(|v| v * v).sum::<f64>()
}

/// Dense analytic gradient: `∇L = A₁ᵀ p(x) A₂` with
/// `p_j = (diag(f_j) − f_j f_jᵀ) q_j`, `q = c hᵀ` (Lemma C.9).
pub fn grad_naive(p: &AttentionLossProblem, x: &Matrix) -> Matrix {
    let n = p.n();
    let f = f_dense(p, x);
    let h = p.h();
    let c = f.matmul(&h).sub(&p.e); // n×d
    let q = c.matmul(&h.transpose()); // n×n (dense oracle: fine)
    // p rows: diag(f_j) q_j − ⟨f_j, q_j⟩ f_j.
    let mut pmat = Matrix::zeros(n, n);
    for j in 0..n {
        let fj = f.row(j);
        let qj = q.row(j);
        let r: f64 = crate::tensor::dot(fj, qj);
        let prow = pmat.row_mut(j);
        for l in 0..n {
            prow[l] = fj[l] * qj[l] - r * fj[l];
        }
    }
    p.a1.transpose().matmul(&pmat).matmul(&p.a2)
}

/// Central finite differences — the ground-truth gradient.
pub fn grad_finite_diff(p: &AttentionLossProblem, x: &Matrix, h: f64) -> Matrix {
    let d = x.rows();
    let mut g = Matrix::zeros(d, d);
    for i in 0..d {
        for j in 0..d {
            let mut xp = x.clone();
            xp[(i, j)] += h;
            let mut xm = x.clone();
            xm[(i, j)] -= h;
            g[(i, j)] = (loss_naive(p, &xp) - loss_naive(p, &xm)) / (2.0 * h);
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::Mask;
    use crate::tensor::Rng;

    #[test]
    fn f_rows_sum_to_one_on_support() {
        let mut rng = Rng::seeded(161);
        let p = AttentionLossProblem::random_structured(10, 3, &mut rng);
        let x = Matrix::randn(3, 3, &mut rng).scale(0.5);
        let f = f_dense(&p, &x);
        for i in 0..10 {
            let s: f64 = f.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn loss_is_zero_when_e_matches() {
        let mut rng = Rng::seeded(162);
        let mut p = AttentionLossProblem::random_structured(8, 3, &mut rng);
        let x = Matrix::randn(3, 3, &mut rng).scale(0.3);
        let f = f_dense(&p, &x);
        p.e = f.matmul(&p.h());
        assert!(loss_naive(&p, &x).abs() < 1e-18);
        // And the gradient at the optimum is ~0.
        let g = grad_naive(&p, &x);
        assert!(crate::tensor::linf_norm_mat(&g) < 1e-12);
    }

    #[test]
    fn masked_positions_do_not_affect_gradient() {
        // Changing K rows that the mask hides from row 0 must not change
        // row-0's contribution — sanity on mask handling.
        let mut rng = Rng::seeded(163);
        let n = 6;
        let d = 2;
        let a = Matrix::randn(n, d, &mut rng);
        let p = AttentionLossProblem::new(
            a.clone(),
            a.clone(),
            a,
            Matrix::eye(d),
            Matrix::zeros(n, d),
            Mask::causal(n),
        );
        let x = Matrix::eye(d).scale(0.5);
        let f = f_dense(&p, &x);
        // Row 0 attends only to itself under the causal mask.
        assert!((f[(0, 0)] - 1.0).abs() < 1e-12);
        for j in 1..n {
            assert_eq!(f[(0, j)], 0.0);
        }
    }
}
