//! The engine-side gradient lanes: batched Definition 5.1 backward
//! passes ([`GradJob`]) **and per-head LM attention backwards**
//! ([`AttnBackwardJob`]) through [`BatchedEngine::submit`].
//!
//! The paper's efficiency claim is symmetric — attention *inference*
//! and the training *gradient* both run in almost linear time through
//! the same recovered conv basis (Theorem 5.6 / C.17) — but until this
//! lane existed only the forward paths enjoyed the engine's worker
//! pool, shared FFT plans, and `BasisCache`. A [`GradJob`] wraps one
//! attention-loss problem (one (layer, head) in multi-head training)
//! plus a [`FastGradConfig`]; the engine fans a batch of them over the
//! same pool as prefill/decode work, with the same input-order
//! determinism.
//!
//! **What the engine shares with this lane:**
//!
//! * the [`SharedFftPlanner`] — the gradient's `f·w` applies reuse the
//!   engine-wide plan tables;
//! * the [`BasisCache`] — the operator `f = D̃⁻¹ (M ∘ exp(A₁XA₂ᵀ))` is
//!   keyed exactly like a prefill `BatchedBackend::Conv` job over
//!   `Q = A₁X`, `K = A₂` (same content fingerprint, same
//!   recovery-schedule tag), so a causal-mask gradient job reuses a
//!   basis the forward pass recovered — and vice versa. Non-causal
//!   masks skip the cache: the prefill path stores a
//!   mask-complement-corrected basis there which the gradient pipeline
//!   does not use, and sharing would break bit-equality with
//!   [`grad_fast`](super::grad_fast).
//!
//! **Determinism.** A batched gradient is bit-identical to
//! single-problem [`grad_fast`](super::grad_fast): recovery is a pure
//! function of (Q, K, mask, config), FFT plans are pure functions of
//! the transform length, and a cache hit replays a byte-identical
//! basis. `tests/properties.rs` pins this for worker counts 1/2/8.
//!
//! **Fallback.** When recovery fails or the normalizer degenerates, the
//! job is served by the dense [`grad_naive`](super::grad_naive) oracle
//! (`O(n²d)`), flagged `fell_back` and counted in
//! `Metrics::grad_fallbacks` — mirroring the prefill lane's
//! exact-attention fallback.
//!
//! **LM backward.** [`AttnBackwardJob`] is the d(Q,K,V)-producing
//! sibling: one (sequence, layer, head) of a transformer LM backward,
//! executed either [`AttnBackwardMode::Exact`] (row-streamed dense
//! softmax backward, bit-matching the pre-engine
//! `Transformer::backward` float-op order with `O(n + n·d_h)` scratch)
//! or [`AttnBackwardMode::Fast`] (conv-basis, `O(k·n·d_h²·log n)`,
//! sharing the prefill `Conv` cache namespace so a conv forward's
//! recovered basis makes the backward recovery-free). In **conv
//! training** the job instead carries the forward's step-scoped basis
//! handle directly ([`AttnBackwardJob::basis`], a [`StepBasis`]): the
//! backward consumes it without re-recovering *and* without touching
//! the serving cache — one recovery per (record, layer, head) per
//! optimizer step, counted in `Metrics::step_basis_hits`. The model
//! layer fans all (sequence, head) jobs of a layer through one submit
//! (`Transformer::backward_batch_with_engine`); `train_lm` /
//! `train_classifier` ride it by default.
//!
//! [`BatchedEngine::submit`]: crate::attention::batched::BatchedEngine::submit
//! [`BatchedEngine`]: crate::attention::batched::BatchedEngine
//! [`BatchedBackend::Conv`]: crate::attention::batched::BatchedBackend

use super::fast::{attn_backward_core, grad_core, FOperator, FastGradientReport};
use super::naive::{grad_naive, loss_naive};
use super::AttentionLossProblem;
use crate::attention::batched::{conv_fingerprint, recover_cfg_tag};
use crate::attention::blocked::attn_backward_blocked;
use crate::attention::{ExactKernel, Mask, MaskKind};
use crate::basis::RecoverConfig;
use crate::coordinator::{BasisCache, CacheKey, CachedBasis, Metrics, StepBasis};
use crate::fft::{FftPlanner, SharedFftPlanner};
use crate::tensor::Matrix;
use std::sync::Arc;

/// Configuration of one fast-gradient evaluation.
#[derive(Clone, Copy, Debug)]
pub struct FastGradConfig {
    /// Recovery budget for the conv basis of `M ∘ (A₁XA₂ᵀ)`.
    pub recover: RecoverConfig,
    /// Consult/populate the engine's `BasisCache` (causal masks only;
    /// non-causal jobs always recover fresh). On by default — a repeat
    /// evaluation at the same `X`, or a gradient following a forward
    /// that already recovered this operator, then skips recovery.
    pub use_cache: bool,
}

impl FastGradConfig {
    pub fn new(recover: RecoverConfig) -> Self {
        FastGradConfig { recover, use_cache: true }
    }

    /// Exact recovery at sequence length `n` (the oracle-grade config
    /// the property tests use).
    pub fn exact(n: usize) -> Self {
        Self::new(RecoverConfig::exact(n))
    }
}

/// One (layer, head) unit of gradient work: evaluate
/// `∇_X L(X)` for an [`AttentionLossProblem`] at the point `x`.
#[derive(Clone, Debug)]
pub struct GradJob {
    /// Layer index (cache key component).
    pub layer: u32,
    /// Head index within the layer (cache key component).
    pub head: u32,
    /// The Definition 5.1 instance (for self-attention training,
    /// `A₁ = A₂ = A₃ =` the head's input block — Remark 5.2).
    /// `Arc`-shared: the problem data is immutable across a training
    /// run, so re-submitting it every GD step (as
    /// `model::train_attention_heads` does) costs a pointer clone, not
    /// a copy of the `n×d` matrices.
    pub problem: Arc<AttentionLossProblem>,
    /// The point `X ∈ R^{d×d}` the gradient is taken at.
    pub x: Matrix,
    pub cfg: FastGradConfig,
}

/// Result of one gradient job.
#[derive(Clone, Debug)]
pub struct GradOutput {
    /// `∇_X L` (`d×d`).
    pub grad: Matrix,
    /// `L(X)` at the evaluation point (from the backward's residual —
    /// no separate forward pass).
    pub loss: f64,
    /// Complexity/observability report (`basis_k`, probe and apply
    /// counts, loss).
    pub report: FastGradientReport,
    /// Whether the `f`-operator basis came from the engine's cache.
    pub cache_hit: bool,
    /// Whether the fast path failed and the dense `grad_naive` oracle
    /// served this job.
    pub fell_back: bool,
    /// Wall time this job spent executing on its worker.
    pub exec: std::time::Duration,
}

/// Execute one gradient job (called by the engine's workers from
/// `BatchedEngine::submit`).
pub(crate) fn execute_grad_job(
    job: GradJob,
    planner: &Arc<SharedFftPlanner>,
    cache: &BasisCache,
    metrics: &Metrics,
    model_id: u64,
) -> GradOutput {
    let t0 = std::time::Instant::now();
    let mut out = execute_grad_job_inner(job, planner, cache, metrics, model_id);
    out.exec = t0.elapsed();
    metrics.record_grad(out.exec);
    out
}

fn execute_grad_job_inner(
    job: GradJob,
    planner: &Arc<SharedFftPlanner>,
    cache: &BasisCache,
    metrics: &Metrics,
    model_id: u64,
) -> GradOutput {
    let GradJob { layer, head, problem: p, x, cfg } = job;
    let n = p.n();
    // Q = A₁X — needed for both the cache fingerprint and recovery.
    let q = p.a1.matmul(&x);
    // Cache only causal-mask operators: a non-causal prefill entry
    // carries a mask-complement correction the gradient pipeline does
    // not apply, so the namespaces must not mix (see module docs).
    let key = if cfg.use_cache && matches!(p.mask.kind(), MaskKind::Causal) {
        Some(CacheKey {
            model_id,
            layer,
            head,
            seq_len: n,
            qk_fingerprint: conv_fingerprint(&q, &p.a2, &p.mask) ^ recover_cfg_tag(&cfg.recover),
        })
    } else {
        None
    };
    if let Some(key) = &key {
        if let Some(hit) = cache.get(key) {
            // Cached entries are guaranteed sound (positive finite D̃ —
            // both writers below and the prefill path check), so this
            // reconstruction cannot fail.
            let local = FftPlanner::with_shared(Arc::clone(planner));
            if let Ok((mut f_op, mut report)) = FOperator::from_cached(hit, local) {
                Metrics::incr(&metrics.cache_hits);
                Metrics::incr(&metrics.grad_cache_hits);
                let (grad, loss) = grad_core(&p, &mut f_op);
                report.f_applies = f_op.applies();
                report.loss = loss;
                return GradOutput {
                    grad,
                    loss,
                    report,
                    cache_hit: true,
                    fell_back: false,
                    exec: std::time::Duration::ZERO,
                };
            }
        }
        Metrics::incr(&metrics.cache_misses);
        Metrics::incr(&metrics.grad_cache_misses);
    }
    let local = FftPlanner::with_shared(Arc::clone(planner));
    match FOperator::build_from_q(&q, &p, &cfg.recover, local) {
        Ok((mut f_op, mut report)) => {
            if let Some(key) = key {
                let (basis, d_tilde) = f_op.cacheable_parts();
                // Same soundness guard as the decode seeding path: only
                // finite, positive normalizers may be served to future
                // cache hits.
                if d_tilde.iter().all(|&v| v > 0.0 && v.is_finite()) {
                    cache.put(
                        key,
                        CachedBasis { post_basis: basis.clone(), d_tilde: d_tilde.to_vec() },
                    );
                }
            }
            let (grad, loss) = grad_core(&p, &mut f_op);
            report.f_applies = f_op.applies();
            report.loss = loss;
            GradOutput {
                grad,
                loss,
                report,
                cache_hit: false,
                fell_back: false,
                exec: std::time::Duration::ZERO,
            }
        }
        Err(_) => {
            // Recovery failed (degenerate normalizer / no usable
            // structure): the dense analytic oracle is total.
            Metrics::incr(&metrics.grad_fallbacks);
            let loss = loss_naive(&p, &x);
            GradOutput {
                grad: grad_naive(&p, &x),
                loss,
                // basis_k/probes/applies are genuinely 0 (no basis was
                // used), but the loss invariant — report.loss == L(X)
                // — must hold on every path.
                report: FastGradientReport { loss, ..Default::default() },
                cache_hit: false,
                fell_back: true,
                exec: std::time::Duration::ZERO,
            }
        }
    }
}

/// How an [`AttnBackwardJob`] computes its `(dQ, dK, dV)`.
#[derive(Clone, Debug)]
pub enum AttnBackwardMode {
    /// Replay the dense softmax backward with **exactly** the float-op
    /// order of the pre-engine `Transformer::backward` per-head loop —
    /// bit-identical to that dense oracle (pinned by
    /// `tests/gradient_oracle.rs`), `O(n²·d_h)`, but row-streamed:
    /// `O(n + n·d_h)` scratch instead of three `n×n` temporaries.
    /// Requires [`AttnBackwardJob::probs`]. The training default.
    /// The [`ExactKernel`] picks the family: `RowStream` is the dense
    /// oracle above; `Blocked` streams each row's causal prefix in
    /// column tiles (half the flops, within the blocked family's
    /// documented tolerance of the oracle).
    Exact(ExactKernel),
    /// Conv-basis fast path through the `f`-operator of
    /// `gradient::fast`: `O(k·n·d_h²·log n)`, within recovery
    /// tolerance of exact.
    /// Consults/populates the engine's `BasisCache` under the **same
    /// key as an equivalent `Conv` prefill job** over this (Q, K), so
    /// backward recovery is free right after a conv forward. Falls
    /// back to the dense exact kernel on recovery failure (counted in
    /// both `grad_fallbacks` and `lm_backward_fallbacks`).
    Fast(FastGradConfig),
}

/// One (sequence, layer, head) unit of LM-backward work: given the
/// head's forward tensors and the upstream gradient `dout` w.r.t. the
/// head's attention output, produce `(dQ, dK, dV)` — the
/// d(Q,K,V)-producing sibling of the Definition 5.1 [`GradJob`], riding
/// the same engine lane (`EngineOp::AttnBackward`).
#[derive(Clone, Debug)]
pub struct AttnBackwardJob {
    /// Layer index (cache key component for the fast path).
    pub layer: u32,
    /// Head index within the layer (cache key component).
    pub head: u32,
    /// Pre-scaled per-head query block (`n × d_h`, `1/√d_h` folded in —
    /// exactly as prefill jobs carry it, which is what makes the fast
    /// path's cache key collide with the forward's).
    pub q: Matrix,
    /// Per-head key block (`n × d_h`).
    pub k: Matrix,
    /// Per-head value block (`n × d_h`).
    pub v: Matrix,
    /// Upstream gradient w.r.t. this head's attention output
    /// (`n × d_h`).
    pub dout: Matrix,
    /// The forward's softmax rows (`Arc`-shared with the forward's
    /// activation cache — no copy). Required by
    /// [`AttnBackwardMode::Exact`]; the fast path only reads it on its
    /// dense fallback (recomputing probs from (Q, K) when absent).
    pub probs: Option<Arc<Matrix>>,
    /// The **step-scoped basis handle** the conv training forward
    /// recovered for this (record, layer, head) — when present, a
    /// [`AttnBackwardMode::Fast`] job rebuilds its `f`-operator from it
    /// directly (`Metrics::step_basis_hits`) instead of re-recovering
    /// from raw (Q, K) or consulting the serving `BasisCache`: one
    /// recovery per step, shared forward→backward, zero serving-shard
    /// traffic. `None` outside conv training (the PR-4 behavior).
    pub basis: Option<StepBasis>,
    pub mode: AttnBackwardMode,
}

/// Result of one LM-backward job. All three gradients are w.r.t. the
/// job's inputs (`dq` w.r.t. the *pre-scaled* q — the model layer
/// applies the `1/√d_h` chain factor when scattering, exactly like the
/// dense path did).
#[derive(Clone, Debug)]
pub struct AttnBackwardOutput {
    pub dq: Matrix,
    pub dk: Matrix,
    pub dv: Matrix,
    /// Basis size the fast path used (0 for exact / fallback).
    pub basis_k: usize,
    /// Whether the fast path's `f`-operator came from the `BasisCache`.
    pub cache_hit: bool,
    /// Whether the fast path failed recovery and the dense exact kernel
    /// served this job.
    pub fell_back: bool,
    /// Wall time this job spent executing on its worker.
    pub exec: std::time::Duration,
}

/// Execute one LM-backward job (called by the engine's workers from
/// `BatchedEngine::submit`).
pub(crate) fn execute_attn_backward_job(
    job: AttnBackwardJob,
    planner: &Arc<SharedFftPlanner>,
    cache: &BasisCache,
    metrics: &Metrics,
    model_id: u64,
) -> AttnBackwardOutput {
    let t0 = std::time::Instant::now();
    let mut out = execute_attn_backward_inner(job, planner, cache, metrics, model_id);
    out.exec = t0.elapsed();
    metrics.record_lm_backward(out.exec);
    out
}

fn execute_attn_backward_inner(
    job: AttnBackwardJob,
    planner: &Arc<SharedFftPlanner>,
    cache: &BasisCache,
    metrics: &Metrics,
    model_id: u64,
) -> AttnBackwardOutput {
    let AttnBackwardJob { layer, head, q, k, v, dout, probs, basis, mode } = job;
    let cfg = match mode {
        AttnBackwardMode::Exact(kernel) => {
            let probs = probs.expect("exact attention backward requires the forward's probs");
            let (dq, dk, dv) = match kernel {
                ExactKernel::RowStream => attn_backward_exact(&probs, &q, &k, &v, &dout),
                ExactKernel::Blocked => attn_backward_blocked(&probs, &q, &k, &v, &dout),
            };
            return AttnBackwardOutput {
                dq,
                dk,
                dv,
                basis_k: 0,
                cache_hit: false,
                fell_back: false,
                exec: std::time::Duration::ZERO,
            };
        }
        AttnBackwardMode::Fast(cfg) => cfg,
    };
    // Step-scoped handle: the conv training forward already recovered
    // this operator this step — consume it and skip recovery AND the
    // serving cache entirely (the forward→backward half of "recover
    // once per (record, layer, head) per step").
    if let Some(handle) = &basis {
        let local = FftPlanner::with_shared(Arc::clone(planner));
        // Hand the operator the handle itself (`Arc` clone) — zero
        // copies of the O(k·n) basis floats per backward job.
        if let Ok((mut f_op, report)) = FOperator::from_cached(Arc::clone(handle), local) {
            Metrics::incr(&metrics.step_basis_hits);
            let (dq, dk, dv) = attn_backward_core(&mut f_op, &q, &k, &v, &dout);
            return AttnBackwardOutput {
                dq,
                dk,
                dv,
                basis_k: report.basis_k,
                cache_hit: true,
                fell_back: false,
                exec: std::time::Duration::ZERO,
            };
        }
        // A degenerate handle never comes from the training forward
        // (it checks soundness before handing one over); a hostile
        // direct submitter falls through to the self-recovery path.
    }
    if !cfg.use_cache && basis.is_none() {
        // A cache-less fast backward with no forward handle: the
        // training loops land here when the forward ran exact or its
        // recovery fell back — the step-scoped store had nothing for
        // this head.
        Metrics::incr(&metrics.step_basis_misses);
    }
    // Fast path. LM heads are always causal, so the cache namespace is
    // exactly the prefill `Conv` namespace over the same (Q, K).
    let n = q.rows();
    let mask = Mask::causal(n);
    let key = if cfg.use_cache {
        Some(CacheKey {
            model_id,
            layer,
            head,
            seq_len: n,
            qk_fingerprint: conv_fingerprint(&q, &k, &mask) ^ recover_cfg_tag(&cfg.recover),
        })
    } else {
        None
    };
    if let Some(key) = &key {
        if let Some(hit) = cache.get(key) {
            let local = FftPlanner::with_shared(Arc::clone(planner));
            if let Ok((mut f_op, report)) = FOperator::from_cached(hit, local) {
                Metrics::incr(&metrics.cache_hits);
                Metrics::incr(&metrics.lm_backward_cache_hits);
                let (dq, dk, dv) = attn_backward_core(&mut f_op, &q, &k, &v, &dout);
                return AttnBackwardOutput {
                    dq,
                    dk,
                    dv,
                    basis_k: report.basis_k,
                    cache_hit: true,
                    fell_back: false,
                    exec: std::time::Duration::ZERO,
                };
            }
        }
        Metrics::incr(&metrics.cache_misses);
        Metrics::incr(&metrics.lm_backward_cache_misses);
    }
    let local = FftPlanner::with_shared(Arc::clone(planner));
    match FOperator::build_qk(&q, &k, &mask, &cfg.recover, local) {
        Ok((mut f_op, report)) => {
            if let Some(key) = key {
                let (basis, d_tilde) = f_op.cacheable_parts();
                // Same soundness guard as every other cache writer:
                // only finite, positive normalizers may be served to
                // future hits.
                if d_tilde.iter().all(|&x| x > 0.0 && x.is_finite()) {
                    cache.put(
                        key,
                        CachedBasis { post_basis: basis.clone(), d_tilde: d_tilde.to_vec() },
                    );
                }
            }
            let (dq, dk, dv) = attn_backward_core(&mut f_op, &q, &k, &v, &dout);
            AttnBackwardOutput {
                dq,
                dk,
                dv,
                basis_k: report.basis_k,
                cache_hit: false,
                fell_back: false,
                exec: std::time::Duration::ZERO,
            }
        }
        Err(_) => {
            // Recovery failed: the dense exact kernel is total. Counted
            // in the gradient lane's shared fallback counter (what
            // training dashboards alarm on) *and* the lane-local one.
            Metrics::incr(&metrics.grad_fallbacks);
            Metrics::incr(&metrics.lm_backward_fallbacks);
            let probs = probs.unwrap_or_else(|| Arc::new(dense_causal_probs(&q, &k)));
            let (dq, dk, dv) = attn_backward_exact(&probs, &q, &k, &v, &dout);
            AttnBackwardOutput {
                dq,
                dk,
                dv,
                basis_k: 0,
                cache_hit: false,
                fell_back: true,
                exec: std::time::Duration::ZERO,
            }
        }
    }
}

/// Dense causal softmax rows from the pre-scaled per-head (Q, K), with
/// exactly the float-op order of the exact backend's training forward
/// (`AttentionBackend::attend` with `keep_probs`) — so a fast-path
/// fallback that had to recompute probs is still bit-identical to
/// [`AttnBackwardMode::Exact`] on the same inputs.
pub(crate) fn dense_causal_probs(q: &Matrix, k: &Matrix) -> Matrix {
    let n = q.rows();
    let logits = q.matmul(&k.transpose());
    let mut probs = Matrix::zeros(n, n);
    for i in 0..n {
        let row = crate::tensor::softmax(&logits.row(i)[..=i]);
        probs.row_mut(i)[..=i].copy_from_slice(&row);
    }
    probs
}

/// One row of `row · m` with exactly `Matrix::matmul`'s k-ascending
/// accumulation order — including its skip on exact zeros — written
/// into `out` (zeroed first). The float-op-order contract that makes
/// [`attn_backward_exact`] bit-identical to the matrix-form backward.
fn row_matmul_into(row: &[f64], m: &Matrix, out: &mut [f64]) {
    debug_assert_eq!(row.len(), m.rows());
    debug_assert_eq!(out.len(), m.cols());
    out.fill(0.0);
    for (kidx, &aik) in row.iter().enumerate() {
        if aik == 0.0 {
            continue;
        }
        let b_row = m.row(kidx);
        for (j, slot) in out.iter_mut().enumerate() {
            *slot += aik * b_row[j];
        }
    }
}

/// The dense per-head softmax-attention backward, **row-streamed**:
///
/// ```text
/// dV = Pᵀ·dout
/// dP = dout·Vᵀ
/// dS = P ∘ (dP − rowdot(P, dP))
/// dQ = dS·K,   dK = dSᵀ·Q
/// ```
///
/// Bit-identical to the matrix form above (the pre-engine
/// `Transformer::backward` per-head loop): every output element's
/// accumulation chain replays `Matrix::matmul`'s k-ascending order with
/// the same zero skips — the streamed outer loop over rows `i` is
/// matmul's `k` loop for the transposed products and its row loop for
/// the direct ones. But the scratch is `O(n + n·d_h)` (one `dP` row,
/// one `dS` row, `Vᵀ`) instead of three `n×n` temporaries — the last
/// `O(n²)`-memory allocation of the training backward, gone.
pub(crate) fn attn_backward_exact(
    probs: &Matrix,
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    dout: &Matrix,
) -> (Matrix, Matrix, Matrix) {
    let n = probs.rows();
    let dh = q.cols();
    let mut dq = Matrix::zeros(n, dh);
    let mut dk = Matrix::zeros(n, dh);
    let mut dv = Matrix::zeros(n, dh);
    // Vᵀ (d_h × n) so dP rows replay matmul(dout, Vᵀ) rows verbatim.
    let vt = v.transpose();
    let mut dprow = vec![0.0; n];
    let mut dsrow = vec![0.0; n];
    for i in 0..n {
        let prow = probs.row(i);
        let dorow = dout.row(i);
        // dV[j] += P[i][j]·dout[i] — replays Pᵀ·dout's k-loop (k = i
        // ascending per output element, skip on exact zero).
        for (j, &pij) in prow.iter().enumerate() {
            if pij == 0.0 {
                continue;
            }
            for (slot, &d) in dv.row_mut(j).iter_mut().zip(dorow) {
                *slot += pij * d;
            }
        }
        // dP row i = dout_i · Vᵀ, then the softmax-Jacobian row.
        row_matmul_into(dorow, &vt, &mut dprow);
        let dot = crate::tensor::dot(prow, &dprow);
        for j in 0..n {
            dsrow[j] = prow[j] * (dprow[j] - dot);
        }
        // dQ row i = dS_i · K.
        row_matmul_into(&dsrow, k, dq.row_mut(i));
        // dK[j] += dS[i][j]·q[i] — replays dSᵀ·Q's k-loop.
        let qrow = q.row(i);
        for (j, &sij) in dsrow.iter().enumerate() {
            if sij == 0.0 {
                continue;
            }
            for (slot, &qv) in dk.row_mut(j).iter_mut().zip(qrow) {
                *slot += sij * qv;
            }
        }
    }
    (dq, dk, dv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::batched::{
        AttnJob, BatchedBackend, BatchedEngine, EngineConfig, EngineJob,
    };
    use crate::gradient::grad_fast;
    use crate::tensor::{max_abs_diff, Rng};

    fn engine(workers: usize) -> BatchedEngine {
        BatchedEngine::new(EngineConfig { workers, cache_capacity: 64 })
    }

    fn grad_jobs(seed: u64, count: u32) -> Vec<GradJob> {
        let mut rng = Rng::seeded(seed);
        (0..count)
            .map(|i| {
                let n = 12 + 4 * i as usize;
                let d = 3;
                let problem = Arc::new(AttentionLossProblem::random_structured(n, d, &mut rng));
                let x = Matrix::randn(d, d, &mut rng).scale(0.3);
                GradJob { layer: i, head: 0, problem, x, cfg: FastGradConfig::exact(n) }
            })
            .collect()
    }

    fn submit_grads(e: &BatchedEngine, jobs: Vec<GradJob>) -> Vec<GradOutput> {
        e.submit(jobs.into_iter().enumerate().map(|(i, j)| EngineJob::gradient(i as u64, j)).collect())
            .into_iter()
            .map(|o| o.result.into_gradient())
            .collect()
    }

    #[test]
    fn batched_grad_bitmatches_grad_fast() {
        let e = engine(2);
        let jobs = grad_jobs(900, 4);
        let singles: Vec<(Matrix, f64)> = jobs
            .iter()
            .map(|j| {
                let (g, r) = grad_fast(&j.problem, &j.x, &j.cfg.recover).unwrap();
                (g, r.loss)
            })
            .collect();
        let outs = submit_grads(&e, jobs);
        for (out, (g, loss)) in outs.iter().zip(&singles) {
            assert!(!out.fell_back);
            assert!(!out.cache_hit, "fresh engine: first evaluation recovers");
            assert_eq!(max_abs_diff(&out.grad, g), 0.0, "batched grad must bit-match grad_fast");
            assert_eq!(out.loss, *loss);
        }
        let snap = e.metrics().snapshot();
        assert_eq!(snap.grad_calls, 1);
        assert_eq!(snap.grad_jobs, 4);
        assert_eq!(snap.grad_fallbacks, 0);
        assert_eq!(snap.grad.count, 4, "per-job latency recorded");
    }

    #[test]
    fn repeat_evaluation_hits_basis_cache() {
        // Same (problem, X) twice: the second submit reuses the cached
        // operator basis — zero recovery probes — and stays bitwise
        // identical.
        let e = engine(2);
        let first = submit_grads(&e, grad_jobs(901, 3));
        let second = submit_grads(&e, grad_jobs(901, 3));
        for (a, b) in first.iter().zip(&second) {
            assert!(b.cache_hit, "second evaluation must hit the cache");
            assert_eq!(b.report.recover_probes, 0);
            assert_eq!(max_abs_diff(&a.grad, &b.grad), 0.0, "cache hit must be bit-identical");
            assert_eq!(a.loss, b.loss);
        }
        let snap = e.metrics().snapshot();
        assert!(snap.cache_hits >= 3);
        assert_eq!(snap.grad_cache_hits, 3, "lane-local hit accounting");
        assert_eq!(snap.grad_cache_misses, 3, "first evaluation recovered fresh");
    }

    #[test]
    fn gradient_reuses_basis_a_prefill_conv_job_recovered() {
        // Forward then backward over the same operator content: the
        // prefill `Conv` job and the gradient job share a cache key by
        // construction, so training's backward starts recovery-free.
        let mut rng = Rng::seeded(902);
        let (n, d) = (20, 3);
        let problem = Arc::new(AttentionLossProblem::random_structured(n, d, &mut rng));
        let x = Matrix::eye(d);
        let cfg = FastGradConfig::exact(n);
        let e = engine(2);
        // Prefill with Q = A₁X, K = A₂ under the same recovery config.
        let q = problem.a1.matmul(&x);
        let v = Matrix::randn(n, d, &mut rng);
        let pre = e.submit(vec![EngineJob::prefill(
            0,
            AttnJob {
                layer: 7,
                head: 1,
                q,
                k: problem.a2.clone(),
                v,
                mask: Some(problem.mask.clone()),
                backend: BatchedBackend::Conv(cfg.recover),
                training: false,
            },
        )]);
        assert!(!pre[0].result.clone().into_prefill().fell_back);
        let outs = submit_grads(
            &e,
            vec![GradJob { layer: 7, head: 1, problem: Arc::clone(&problem), x: x.clone(), cfg }],
        );
        assert!(outs[0].cache_hit, "gradient must reuse the forward's recovered basis");
        let (want, _) = grad_fast(&problem, &x, &cfg.recover).unwrap();
        assert_eq!(max_abs_diff(&outs[0].grad, &want), 0.0);
    }

    #[test]
    fn attn_backward_exact_streams_bit_identical_to_matrix_form() {
        // The row-streamed kernel vs the literal matrix-form backward
        // (what `Transformer::backward` materializes densely).
        let mut rng = Rng::seeded(910);
        let (n, dh) = (24, 4);
        let q = Matrix::randn(n, dh, &mut rng).scale(0.3);
        let k = Matrix::randn(n, dh, &mut rng).scale(0.3);
        let v = Matrix::randn(n, dh, &mut rng);
        let dout = Matrix::randn(n, dh, &mut rng);
        let probs = dense_causal_probs(&q, &k);
        let (dq, dk, dv) = attn_backward_exact(&probs, &q, &k, &v, &dout);

        let dv_want = probs.transpose().matmul(&dout);
        let dprobs = dout.matmul(&v.transpose());
        let mut dscores = Matrix::zeros(n, n);
        for i in 0..n {
            let dot = crate::tensor::dot(probs.row(i), dprobs.row(i));
            for j in 0..n {
                dscores[(i, j)] = probs[(i, j)] * (dprobs[(i, j)] - dot);
            }
        }
        let dq_want = dscores.matmul(&k);
        let dk_want = dscores.transpose().matmul(&q);
        assert_eq!(max_abs_diff(&dv, &dv_want), 0.0, "dv must be bit-identical");
        assert_eq!(max_abs_diff(&dq, &dq_want), 0.0, "dq must be bit-identical");
        assert_eq!(max_abs_diff(&dk, &dk_want), 0.0, "dk must be bit-identical");
    }

    fn backward_job(seed: u64, mode: AttnBackwardMode) -> AttnBackwardJob {
        let mut rng = Rng::seeded(seed);
        let (n, dh) = (20, 3);
        let q = Matrix::randn(n, dh, &mut rng).scale(0.3);
        let k = Matrix::randn(n, dh, &mut rng).scale(0.3);
        let probs = Arc::new(dense_causal_probs(&q, &k));
        AttnBackwardJob {
            layer: 0,
            head: 0,
            q,
            k,
            v: Matrix::randn(n, dh, &mut rng),
            dout: Matrix::randn(n, dh, &mut rng),
            probs: Some(probs),
            basis: None,
            mode,
        }
    }

    fn submit_backward(e: &BatchedEngine, job: AttnBackwardJob) -> AttnBackwardOutput {
        e.submit(vec![EngineJob::attn_backward(0, job)])
            .pop()
            .unwrap()
            .result
            .into_attn_backward()
    }

    #[test]
    fn fast_attn_backward_close_to_exact() {
        // Exact-config recovery ⇒ the conv f-operator is the softmax
        // matrix to FFT rounding, so the fast backward tracks the exact
        // one to ~1e-8.
        let e = engine(2);
        let exact =
            submit_backward(&e, backward_job(911, AttnBackwardMode::Exact(ExactKernel::RowStream)));
        let fast = submit_backward(
            &e,
            backward_job(911, AttnBackwardMode::Fast(FastGradConfig::exact(20))),
        );
        assert!(!fast.fell_back);
        assert!(fast.basis_k >= 1);
        for (got, want, name) in [
            (&fast.dq, &exact.dq, "dq"),
            (&fast.dk, &exact.dk, "dk"),
            (&fast.dv, &exact.dv, "dv"),
        ] {
            let err = max_abs_diff(got, want);
            assert!(err < 1e-8, "{name} err = {err}");
        }
    }

    #[test]
    fn fast_attn_backward_reuses_prefill_conv_basis() {
        // A conv prefill over the same pre-scaled (Q, K) caches the
        // operator basis; the fast LM backward must hit it — "forward
        // recovers, backward reuses" across the forward/backward
        // boundary of a *transformer* head, not just Definition 5.1.
        let e = engine(2);
        let job = backward_job(912, AttnBackwardMode::Fast(FastGradConfig::exact(20)));
        let pre = e.submit(vec![EngineJob::prefill(
            0,
            AttnJob::causal(
                0,
                0,
                job.q.clone(),
                job.k.clone(),
                job.v.clone(),
                BatchedBackend::Conv(RecoverConfig::exact(20)),
            ),
        )]);
        assert!(!pre[0].result.clone().into_prefill().fell_back);
        let out = submit_backward(&e, job);
        assert!(out.cache_hit, "backward must reuse the forward's recovered basis");
        assert_eq!(e.metrics().snapshot().lm_backward_cache_hits, 1);
    }

    #[test]
    fn fast_attn_backward_consumes_step_basis_handle() {
        // A conv *training* forward returns its basis as a step-scoped
        // handle; a Fast backward carrying that handle must (a) produce
        // bits identical to self-recovery over the same content — the
        // handle is the same basis — (b) tick step_basis_hits, and
        // (c) generate zero serving-cache traffic.
        let e = engine(2);
        let job = backward_job(
            914,
            AttnBackwardMode::Fast(FastGradConfig {
                recover: RecoverConfig::exact(20),
                use_cache: false,
            }),
        );
        // Self-recovered reference (cache-less: no forward ran).
        let want = submit_backward(&e, job.clone());
        assert!(!want.fell_back);
        assert_eq!(e.metrics().snapshot().step_basis_misses, 1, "no handle, cache-less");
        // Training forward over the same (Q, K) hands back the basis.
        let fwd = e.submit(vec![EngineJob::prefill(
            0,
            AttnJob::causal(
                0,
                0,
                job.q.clone(),
                job.k.clone(),
                job.v.clone(),
                BatchedBackend::Conv(RecoverConfig::exact(20)),
            )
            .for_training(),
        )]);
        let fwd = fwd[0].result.clone().into_prefill();
        let handle = fwd.basis.expect("conv training forward returns its basis");
        let mut with_handle = job;
        with_handle.basis = Some(handle);
        let got = submit_backward(&e, with_handle);
        assert!(got.cache_hit, "handle consumption reports as a (step) cache hit");
        assert_eq!(max_abs_diff(&got.dq, &want.dq), 0.0);
        assert_eq!(max_abs_diff(&got.dk, &want.dk), 0.0);
        assert_eq!(max_abs_diff(&got.dv, &want.dv), 0.0);
        let snap = e.metrics().snapshot();
        assert_eq!(snap.step_basis_hits, 1);
        assert_eq!(snap.step_recoveries, 1, "the forward recovered once");
        assert_eq!(snap.step_basis_misses, 1, "only the reference run missed");
        assert_eq!(
            (snap.cache_hits, snap.cache_misses),
            (0, 0),
            "conv training never touches the serving BasisCache"
        );
        assert_eq!(e.cache().stats(), (0, 0, 0), "zero writes to the serving shards");
    }

    #[test]
    fn fast_attn_backward_fallback_is_dense_exact_and_counted() {
        // Zero recovery budget fails deterministically: the job must be
        // served by the dense kernel (bit-identical to exact mode,
        // since the fallback reuses the forward's probs) and flagged in
        // BOTH grad_fallbacks and lm_backward_fallbacks.
        let e = engine(1);
        let bad = FastGradConfig {
            recover: RecoverConfig { k_max: 0, t: 1, delta: 1.0, eps: 0.0 },
            use_cache: false,
        };
        let exact =
            submit_backward(&e, backward_job(913, AttnBackwardMode::Exact(ExactKernel::RowStream)));
        let fb = submit_backward(&e, backward_job(913, AttnBackwardMode::Fast(bad)));
        assert!(fb.fell_back);
        assert_eq!(max_abs_diff(&fb.dq, &exact.dq), 0.0);
        assert_eq!(max_abs_diff(&fb.dk, &exact.dk), 0.0);
        assert_eq!(max_abs_diff(&fb.dv, &exact.dv), 0.0);
        let snap = e.metrics().snapshot();
        assert_eq!(snap.lm_backward_fallbacks, 1);
        assert_eq!(snap.grad_fallbacks, 1, "shared gradient-lane alarm counter");
    }

    #[test]
    fn failed_recovery_falls_back_to_dense_oracle() {
        // A zero recovery budget fails deterministically; the lane must
        // serve the dense gradient instead of erroring, and flag it.
        let mut rng = Rng::seeded(903);
        let (n, d) = (12, 3);
        let problem = Arc::new(AttentionLossProblem::random_structured(n, d, &mut rng));
        let x = Matrix::randn(d, d, &mut rng).scale(0.3);
        let cfg = FastGradConfig {
            recover: RecoverConfig { k_max: 0, t: 1, delta: 1.0, eps: 0.0 },
            use_cache: true,
        };
        let e = engine(1);
        let outs = submit_grads(
            &e,
            vec![GradJob { layer: 0, head: 0, problem: Arc::clone(&problem), x: x.clone(), cfg }],
        );
        assert!(outs[0].fell_back);
        assert!(!outs[0].cache_hit);
        let want = grad_naive(&problem, &x);
        assert_eq!(max_abs_diff(&outs[0].grad, &want), 0.0);
        assert_eq!(outs[0].loss, loss_naive(&problem, &x));
        assert_eq!(outs[0].report.loss, outs[0].loss, "report.loss holds on the fallback path");
        assert!(outs[0].grad.is_finite());
        assert_eq!(e.metrics().snapshot().grad_fallbacks, 1);
    }
}
