//! The engine-side gradient lane: batched Definition 5.1 backward
//! passes through [`BatchedEngine::submit`].
//!
//! The paper's efficiency claim is symmetric — attention *inference*
//! and the training *gradient* both run in almost linear time through
//! the same recovered conv basis (Theorem 5.6 / C.17) — but until this
//! lane existed only the forward paths enjoyed the engine's worker
//! pool, shared FFT plans, and `BasisCache`. A [`GradJob`] wraps one
//! attention-loss problem (one (layer, head) in multi-head training)
//! plus a [`FastGradConfig`]; the engine fans a batch of them over the
//! same pool as prefill/decode work, with the same input-order
//! determinism.
//!
//! **What the engine shares with this lane:**
//!
//! * the [`SharedFftPlanner`] — the gradient's `f·w` applies reuse the
//!   engine-wide plan tables;
//! * the [`BasisCache`] — the operator `f = D̃⁻¹ (M ∘ exp(A₁XA₂ᵀ))` is
//!   keyed exactly like a prefill `BatchedBackend::Conv` job over
//!   `Q = A₁X`, `K = A₂` (same content fingerprint, same
//!   recovery-schedule tag), so a causal-mask gradient job reuses a
//!   basis the forward pass recovered — and vice versa. Non-causal
//!   masks skip the cache: the prefill path stores a
//!   mask-complement-corrected basis there which the gradient pipeline
//!   does not use, and sharing would break bit-equality with
//!   [`grad_fast`](super::grad_fast).
//!
//! **Determinism.** A batched gradient is bit-identical to
//! single-problem [`grad_fast`](super::grad_fast): recovery is a pure
//! function of (Q, K, mask, config), FFT plans are pure functions of
//! the transform length, and a cache hit replays a byte-identical
//! basis. `tests/properties.rs` pins this for worker counts 1/2/8.
//!
//! **Fallback.** When recovery fails or the normalizer degenerates, the
//! job is served by the dense [`grad_naive`](super::grad_naive) oracle
//! (`O(n²d)`), flagged `fell_back` and counted in
//! `Metrics::grad_fallbacks` — mirroring the prefill lane's
//! exact-attention fallback.
//!
//! [`BatchedEngine::submit`]: crate::attention::batched::BatchedEngine::submit
//! [`BatchedEngine`]: crate::attention::batched::BatchedEngine
//! [`BatchedBackend::Conv`]: crate::attention::batched::BatchedBackend

use super::fast::{grad_core, FOperator, FastGradientReport};
use super::naive::{grad_naive, loss_naive};
use super::AttentionLossProblem;
use crate::attention::batched::{conv_fingerprint, recover_cfg_tag};
use crate::attention::MaskKind;
use crate::basis::RecoverConfig;
use crate::coordinator::{BasisCache, CacheKey, CachedBasis, Metrics};
use crate::fft::{FftPlanner, SharedFftPlanner};
use crate::tensor::Matrix;
use std::sync::Arc;

/// Configuration of one fast-gradient evaluation.
#[derive(Clone, Copy, Debug)]
pub struct FastGradConfig {
    /// Recovery budget for the conv basis of `M ∘ (A₁XA₂ᵀ)`.
    pub recover: RecoverConfig,
    /// Consult/populate the engine's `BasisCache` (causal masks only;
    /// non-causal jobs always recover fresh). On by default — a repeat
    /// evaluation at the same `X`, or a gradient following a forward
    /// that already recovered this operator, then skips recovery.
    pub use_cache: bool,
}

impl FastGradConfig {
    pub fn new(recover: RecoverConfig) -> Self {
        FastGradConfig { recover, use_cache: true }
    }

    /// Exact recovery at sequence length `n` (the oracle-grade config
    /// the property tests use).
    pub fn exact(n: usize) -> Self {
        Self::new(RecoverConfig::exact(n))
    }
}

/// One (layer, head) unit of gradient work: evaluate
/// `∇_X L(X)` for an [`AttentionLossProblem`] at the point `x`.
#[derive(Clone, Debug)]
pub struct GradJob {
    /// Layer index (cache key component).
    pub layer: u32,
    /// Head index within the layer (cache key component).
    pub head: u32,
    /// The Definition 5.1 instance (for self-attention training,
    /// `A₁ = A₂ = A₃ =` the head's input block — Remark 5.2).
    /// `Arc`-shared: the problem data is immutable across a training
    /// run, so re-submitting it every GD step (as
    /// `model::train_attention_heads` does) costs a pointer clone, not
    /// a copy of the `n×d` matrices.
    pub problem: Arc<AttentionLossProblem>,
    /// The point `X ∈ R^{d×d}` the gradient is taken at.
    pub x: Matrix,
    pub cfg: FastGradConfig,
}

/// Result of one gradient job.
#[derive(Clone, Debug)]
pub struct GradOutput {
    /// `∇_X L` (`d×d`).
    pub grad: Matrix,
    /// `L(X)` at the evaluation point (from the backward's residual —
    /// no separate forward pass).
    pub loss: f64,
    /// Complexity/observability report (`basis_k`, probe and apply
    /// counts, loss).
    pub report: FastGradientReport,
    /// Whether the `f`-operator basis came from the engine's cache.
    pub cache_hit: bool,
    /// Whether the fast path failed and the dense `grad_naive` oracle
    /// served this job.
    pub fell_back: bool,
    /// Wall time this job spent executing on its worker.
    pub exec: std::time::Duration,
}

/// Execute one gradient job (called by the engine's workers from
/// `BatchedEngine::submit`).
pub(crate) fn execute_grad_job(
    job: GradJob,
    planner: &Arc<SharedFftPlanner>,
    cache: &BasisCache,
    metrics: &Metrics,
    model_id: u64,
) -> GradOutput {
    let t0 = std::time::Instant::now();
    let mut out = execute_grad_job_inner(job, planner, cache, metrics, model_id);
    out.exec = t0.elapsed();
    metrics.record_grad(out.exec);
    out
}

fn execute_grad_job_inner(
    job: GradJob,
    planner: &Arc<SharedFftPlanner>,
    cache: &BasisCache,
    metrics: &Metrics,
    model_id: u64,
) -> GradOutput {
    let GradJob { layer, head, problem: p, x, cfg } = job;
    let n = p.n();
    // Q = A₁X — needed for both the cache fingerprint and recovery.
    let q = p.a1.matmul(&x);
    // Cache only causal-mask operators: a non-causal prefill entry
    // carries a mask-complement correction the gradient pipeline does
    // not apply, so the namespaces must not mix (see module docs).
    let key = if cfg.use_cache && matches!(p.mask.kind(), MaskKind::Causal) {
        Some(CacheKey {
            model_id,
            layer,
            head,
            seq_len: n,
            qk_fingerprint: conv_fingerprint(&q, &p.a2, &p.mask) ^ recover_cfg_tag(&cfg.recover),
        })
    } else {
        None
    };
    if let Some(key) = &key {
        if let Some(hit) = cache.get(key) {
            // Cached entries are guaranteed sound (positive finite D̃ —
            // both writers below and the prefill path check), so this
            // reconstruction cannot fail.
            let local = FftPlanner::with_shared(Arc::clone(planner));
            if let Ok((mut f_op, mut report)) = FOperator::from_cached(hit.post_basis, hit.d_tilde, local)
            {
                Metrics::incr(&metrics.cache_hits);
                Metrics::incr(&metrics.grad_cache_hits);
                let (grad, loss) = grad_core(&p, &mut f_op);
                report.f_applies = f_op.applies();
                report.loss = loss;
                return GradOutput {
                    grad,
                    loss,
                    report,
                    cache_hit: true,
                    fell_back: false,
                    exec: std::time::Duration::ZERO,
                };
            }
        }
        Metrics::incr(&metrics.cache_misses);
        Metrics::incr(&metrics.grad_cache_misses);
    }
    let local = FftPlanner::with_shared(Arc::clone(planner));
    match FOperator::build_from_q(&q, &p, &cfg.recover, local) {
        Ok((mut f_op, mut report)) => {
            if let Some(key) = key {
                let (basis, d_tilde) = f_op.cacheable_parts();
                // Same soundness guard as the decode seeding path: only
                // finite, positive normalizers may be served to future
                // cache hits.
                if d_tilde.iter().all(|&v| v > 0.0 && v.is_finite()) {
                    cache.put(
                        key,
                        CachedBasis { post_basis: basis.clone(), d_tilde: d_tilde.to_vec() },
                    );
                }
            }
            let (grad, loss) = grad_core(&p, &mut f_op);
            report.f_applies = f_op.applies();
            report.loss = loss;
            GradOutput {
                grad,
                loss,
                report,
                cache_hit: false,
                fell_back: false,
                exec: std::time::Duration::ZERO,
            }
        }
        Err(_) => {
            // Recovery failed (degenerate normalizer / no usable
            // structure): the dense analytic oracle is total.
            Metrics::incr(&metrics.grad_fallbacks);
            let loss = loss_naive(&p, &x);
            GradOutput {
                grad: grad_naive(&p, &x),
                loss,
                // basis_k/probes/applies are genuinely 0 (no basis was
                // used), but the loss invariant — report.loss == L(X)
                // — must hold on every path.
                report: FastGradientReport { loss, ..Default::default() },
                cache_hit: false,
                fell_back: true,
                exec: std::time::Duration::ZERO,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::batched::{
        AttnJob, BatchedBackend, BatchedEngine, EngineConfig, EngineJob,
    };
    use crate::gradient::grad_fast;
    use crate::tensor::{max_abs_diff, Rng};

    fn engine(workers: usize) -> BatchedEngine {
        BatchedEngine::new(EngineConfig { workers, cache_capacity: 64 })
    }

    fn grad_jobs(seed: u64, count: u32) -> Vec<GradJob> {
        let mut rng = Rng::seeded(seed);
        (0..count)
            .map(|i| {
                let n = 12 + 4 * i as usize;
                let d = 3;
                let problem = Arc::new(AttentionLossProblem::random_structured(n, d, &mut rng));
                let x = Matrix::randn(d, d, &mut rng).scale(0.3);
                GradJob { layer: i, head: 0, problem, x, cfg: FastGradConfig::exact(n) }
            })
            .collect()
    }

    fn submit_grads(e: &BatchedEngine, jobs: Vec<GradJob>) -> Vec<GradOutput> {
        e.submit(jobs.into_iter().enumerate().map(|(i, j)| EngineJob::gradient(i as u64, j)).collect())
            .into_iter()
            .map(|o| o.result.into_gradient())
            .collect()
    }

    #[test]
    fn batched_grad_bitmatches_grad_fast() {
        let e = engine(2);
        let jobs = grad_jobs(900, 4);
        let singles: Vec<(Matrix, f64)> = jobs
            .iter()
            .map(|j| {
                let (g, r) = grad_fast(&j.problem, &j.x, &j.cfg.recover).unwrap();
                (g, r.loss)
            })
            .collect();
        let outs = submit_grads(&e, jobs);
        for (out, (g, loss)) in outs.iter().zip(&singles) {
            assert!(!out.fell_back);
            assert!(!out.cache_hit, "fresh engine: first evaluation recovers");
            assert_eq!(max_abs_diff(&out.grad, g), 0.0, "batched grad must bit-match grad_fast");
            assert_eq!(out.loss, *loss);
        }
        let snap = e.metrics().snapshot();
        assert_eq!(snap.grad_calls, 1);
        assert_eq!(snap.grad_jobs, 4);
        assert_eq!(snap.grad_fallbacks, 0);
        assert_eq!(snap.grad.count, 4, "per-job latency recorded");
    }

    #[test]
    fn repeat_evaluation_hits_basis_cache() {
        // Same (problem, X) twice: the second submit reuses the cached
        // operator basis — zero recovery probes — and stays bitwise
        // identical.
        let e = engine(2);
        let first = submit_grads(&e, grad_jobs(901, 3));
        let second = submit_grads(&e, grad_jobs(901, 3));
        for (a, b) in first.iter().zip(&second) {
            assert!(b.cache_hit, "second evaluation must hit the cache");
            assert_eq!(b.report.recover_probes, 0);
            assert_eq!(max_abs_diff(&a.grad, &b.grad), 0.0, "cache hit must be bit-identical");
            assert_eq!(a.loss, b.loss);
        }
        let snap = e.metrics().snapshot();
        assert!(snap.cache_hits >= 3);
        assert_eq!(snap.grad_cache_hits, 3, "lane-local hit accounting");
        assert_eq!(snap.grad_cache_misses, 3, "first evaluation recovered fresh");
    }

    #[test]
    fn gradient_reuses_basis_a_prefill_conv_job_recovered() {
        // Forward then backward over the same operator content: the
        // prefill `Conv` job and the gradient job share a cache key by
        // construction, so training's backward starts recovery-free.
        let mut rng = Rng::seeded(902);
        let (n, d) = (20, 3);
        let problem = Arc::new(AttentionLossProblem::random_structured(n, d, &mut rng));
        let x = Matrix::eye(d);
        let cfg = FastGradConfig::exact(n);
        let e = engine(2);
        // Prefill with Q = A₁X, K = A₂ under the same recovery config.
        let q = problem.a1.matmul(&x);
        let v = Matrix::randn(n, d, &mut rng);
        let pre = e.submit(vec![EngineJob::prefill(
            0,
            AttnJob {
                layer: 7,
                head: 1,
                q,
                k: problem.a2.clone(),
                v,
                mask: Some(problem.mask.clone()),
                backend: BatchedBackend::Conv(cfg.recover),
            },
        )]);
        assert!(!pre[0].result.clone().into_prefill().fell_back);
        let outs = submit_grads(
            &e,
            vec![GradJob { layer: 7, head: 1, problem: Arc::clone(&problem), x: x.clone(), cfg }],
        );
        assert!(outs[0].cache_hit, "gradient must reuse the forward's recovered basis");
        let (want, _) = grad_fast(&problem, &x, &cfg.recover).unwrap();
        assert_eq!(max_abs_diff(&outs[0].grad, &want), 0.0);
    }

    #[test]
    fn failed_recovery_falls_back_to_dense_oracle() {
        // A zero recovery budget fails deterministically; the lane must
        // serve the dense gradient instead of erroring, and flag it.
        let mut rng = Rng::seeded(903);
        let (n, d) = (12, 3);
        let problem = Arc::new(AttentionLossProblem::random_structured(n, d, &mut rng));
        let x = Matrix::randn(d, d, &mut rng).scale(0.3);
        let cfg = FastGradConfig {
            recover: RecoverConfig { k_max: 0, t: 1, delta: 1.0, eps: 0.0 },
            use_cache: true,
        };
        let e = engine(1);
        let outs = submit_grads(
            &e,
            vec![GradJob { layer: 0, head: 0, problem: Arc::clone(&problem), x: x.clone(), cfg }],
        );
        assert!(outs[0].fell_back);
        assert!(!outs[0].cache_hit);
        let want = grad_naive(&problem, &x);
        assert_eq!(max_abs_diff(&outs[0].grad, &want), 0.0);
        assert_eq!(outs[0].loss, loss_naive(&problem, &x));
        assert_eq!(outs[0].report.loss, outs[0].loss, "report.loss holds on the fallback path");
        assert!(outs[0].grad.is_finite());
        assert_eq!(e.metrics().snapshot().grad_fallbacks, 1);
    }
}
