//! The fast gradient path (Theorem 5.6 / Theorem C.17):
//! `O(k·n·d²·log n)` backward, `O(k·n·d·log n + T_mat(n,d,d))` forward.
//!
//! Everything flows through one primitive: `f(x)·w` where
//! `f = D⁻¹·(M ∘ exp(A₁XA₂ᵀ))` is applied via the recovered k-conv
//! basis (Lemma C.10). `q(x)` stays in rank-d factored form
//! `q = c·hᵀ` (Lemma C.12); the Hadamard `p₁ = f ∘ q` multiplies
//! through the diag-sandwich `Σ_i diag(c_i) f diag(h_i)` (Lemma C.13);
//! `p₂ = diag(r)·f` with `r_j = ⟨f_j, q_j⟩` computed off the factored
//! form (Lemmas C.14–C.15).

use super::naive::f_dense;
use super::AttentionLossProblem;
use crate::attention::{AttentionError, Mask};
use crate::basis::{exp_transform, recover, KConvBasis, RecoverConfig};
use crate::coordinator::CachedBasis;
use crate::fft::FftPlanner;
use crate::tensor::Matrix;
use std::sync::Arc;

/// Run report for observability / complexity accounting.
#[derive(Clone, Copy, Debug, Default)]
pub struct FastGradientReport {
    /// Recovered basis size `k`.
    pub basis_k: usize,
    /// Column probes used by recovery (0 when the basis came from a
    /// cache).
    pub recover_probes: usize,
    /// Number of `f·w` basis applications performed.
    pub f_applies: usize,
    /// The Definition 5.1 objective `L(X)` at this point — computed for
    /// free from the residual `c = f·h − E` the backward pass already
    /// materializes (so batched training reads per-head losses without
    /// a second forward).
    pub loss: f64,
}

/// The conv-backed normalized-attention operator `f(x)·w`.
///
/// `pub(crate)` so the engine's batched gradient lane
/// ([`crate::gradient::batched`]) can build it from a cached basis and
/// a shared FFT planner while this module keeps the single-problem
/// entry points.
pub(crate) struct FOperator {
    hold: BasisHold,
    d_inv: Vec<f64>,
    planner: FftPlanner,
    applies: usize,
}

/// How the operator owns its `(post_basis, d̃)` pair.
///
/// A fresh recovery owns its basis outright; a cache hit or a
/// step-scoped training handle holds the **shared** resident entry
/// (`Arc<CachedBasis>`) — zero copies of the `O(k·n)` basis floats per
/// backward job, the serving cache and every consumer reading one
/// allocation. Both variants are immutable after construction, so the
/// apply paths are identical.
enum BasisHold {
    Owned(CachedBasis),
    Shared(Arc<CachedBasis>),
}

impl BasisHold {
    fn post_basis(&self) -> &KConvBasis {
        match self {
            BasisHold::Owned(c) => &c.post_basis,
            BasisHold::Shared(c) => &c.post_basis,
        }
    }

    fn d_tilde(&self) -> &[f64] {
        match self {
            BasisHold::Owned(c) => &c.d_tilde,
            BasisHold::Shared(c) => &c.d_tilde,
        }
    }
}

impl FOperator {
    /// Build from the problem: recover the basis of `M ∘ (A₁XA₂ᵀ)` using
    /// `Q = A₁X`, `K = A₂` (so `QKᵀ = A₁XA₂ᵀ`), exp-transform, and take
    /// row sums as the normalizer.
    pub(crate) fn build(
        p: &AttentionLossProblem,
        x: &Matrix,
        cfg: &RecoverConfig,
    ) -> Result<(Self, FastGradientReport), AttentionError> {
        let q = p.a1.matmul(x);
        Self::build_from_q(&q, p, cfg, FftPlanner::new())
    }

    /// [`Self::build`] with a precomputed `Q = A₁X` and a caller-owned
    /// planner (the batched lane fingerprints `Q` for its cache key, so
    /// it already paid the `T_mat(n,d,d)`, and threads the engine's
    /// shared plan cache through). Bit-identical to [`Self::build`]:
    /// FFT plans are pure functions of the transform length.
    pub(crate) fn build_from_q(
        q: &Matrix,
        p: &AttentionLossProblem,
        cfg: &RecoverConfig,
        planner: FftPlanner,
    ) -> Result<(Self, FastGradientReport), AttentionError> {
        Self::build_qk(q, &p.a2, &p.mask, cfg, planner)
    }

    /// Build the normalized operator `f = D̃⁻¹(M ∘ exp(QKᵀ))` straight
    /// from a (Q, K, mask) triple — no [`AttentionLossProblem`]
    /// required. This is the LM-backward entry: a transformer head's
    /// softmax matrix *is* this operator over the head's pre-scaled
    /// (Q, K), so the attention backward reuses the whole recovery /
    /// cache / apply stack of the Definition 5.1 pipeline.
    pub(crate) fn build_qk(
        q: &Matrix,
        k: &Matrix,
        mask: &Mask,
        cfg: &RecoverConfig,
        planner: FftPlanner,
    ) -> Result<(Self, FastGradientReport), AttentionError> {
        let (pre, stats) = recover(q, k, mask, cfg)?;
        let post = exp_transform(&pre, true);
        let d = post.row_sums();
        for (row, &val) in d.iter().enumerate() {
            if !(val > 0.0) {
                return Err(AttentionError::DegenerateNormalizer { row, value: val });
            }
        }
        let report = FastGradientReport {
            basis_k: post.k(),
            recover_probes: stats.columns_probed,
            f_applies: 0,
            loss: 0.0,
        };
        let d_inv = d.iter().map(|&v| 1.0 / v).collect();
        let hold = BasisHold::Owned(CachedBasis { post_basis: post, d_tilde: d });
        Ok((FOperator { hold, d_inv, planner, applies: 0 }, report))
    }

    /// Rebuild the operator from a **shared** cached `(post_basis, d̃)`
    /// entry — what a prefill job or an earlier gradient job left in
    /// the engine's `BasisCache`, or the step-scoped handle a conv
    /// training forward handed over. Skips recovery entirely and holds
    /// the `Arc` itself (no copy of the `O(k·n)` basis floats); the
    /// normalizer inverse is recomputed with the same float ops as
    /// [`Self::build_from_q`], so a cache hit is bit-identical to a
    /// fresh recovery of identical content.
    pub(crate) fn from_cached(
        cached: Arc<CachedBasis>,
        planner: FftPlanner,
    ) -> Result<(Self, FastGradientReport), AttentionError> {
        for (row, &val) in cached.d_tilde.iter().enumerate() {
            if !(val > 0.0) {
                return Err(AttentionError::DegenerateNormalizer { row, value: val });
            }
        }
        let report = FastGradientReport {
            basis_k: cached.post_basis.k(),
            recover_probes: 0,
            f_applies: 0,
            loss: 0.0,
        };
        let d_inv = cached.d_tilde.iter().map(|&v| 1.0 / v).collect();
        Ok((FOperator { hold: BasisHold::Shared(cached), d_inv, planner, applies: 0 }, report))
    }

    /// The cacheable halves: (post-exp basis, normalizer diagonal `D̃`).
    pub(crate) fn cacheable_parts(&self) -> (&KConvBasis, &[f64]) {
        (self.hold.post_basis(), self.hold.d_tilde())
    }

    /// `f·w` applications performed so far.
    pub(crate) fn applies(&self) -> usize {
        self.applies
    }

    /// `f·w` — one k-conv FFT apply plus a diagonal scale:
    /// `O(k·n·log n)` (Lemma C.10).
    fn apply(&mut self, w: &[f64]) -> Vec<f64> {
        self.applies += 1;
        let mut y = self.hold.post_basis().apply(&mut self.planner, w);
        for (yi, di) in y.iter_mut().zip(&self.d_inv) {
            *yi *= di;
        }
        y
    }

    /// `fᵀ·w = Bᵀ·(D̃⁻¹ ∘ w)` — the transposed operator through the
    /// same conv basis, `O(k·n·log n)` per apply (the diagonal
    /// normalizer moves to the *input* side under transposition).
    /// Counted in [`Self::applies`].
    fn apply_transpose(&mut self, w: &[f64]) -> Vec<f64> {
        self.applies += 1;
        let scaled: Vec<f64> = w.iter().zip(&self.d_inv).map(|(x, di)| x * di).collect();
        self.hold.post_basis().apply_transpose(&mut self.planner, &scaled)
    }

    /// `f·W` column-wise.
    fn apply_matrix(&mut self, w: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(w.rows(), w.cols());
        for c in 0..w.cols() {
            let y = self.apply(&w.col(c));
            out.set_col(c, &y);
        }
        out
    }
}

/// Fast training **forward**: `L(X)` in `O(knd log n + T_mat(n,d,d))`
/// (Theorem 5.6 forward clause).
pub fn loss_fast(
    p: &AttentionLossProblem,
    x: &Matrix,
    cfg: &RecoverConfig,
) -> Result<f64, AttentionError> {
    let (mut f_op, _) = FOperator::build(p, x, cfg)?;
    let h = p.h();
    let c = f_op.apply_matrix(&h).sub(&p.e);
    Ok(0.5 * c.data().iter().map(|v| v * v).sum::<f64>())
}

/// Fast **backward**: `∇L = A₁ᵀ p(x) A₂` in `O(k·n·d²·log n)`
/// (Theorem C.17). Returns the `d×d` gradient and a run report.
pub fn grad_fast(
    p: &AttentionLossProblem,
    x: &Matrix,
    cfg: &RecoverConfig,
) -> Result<(Matrix, FastGradientReport), AttentionError> {
    let (mut f_op, mut report) = FOperator::build(p, x, cfg)?;
    let (g, loss) = grad_core(p, &mut f_op);
    report.f_applies = f_op.applies;
    report.loss = loss;
    Ok((g, report))
}

/// The backward body, generic over how the `f`-operator was obtained
/// (fresh recovery or a cache hit): the tensor-trick pipeline of
/// Lemmas C.10–C.16. Returns `(∇L, L(X))` — the loss falls out of the
/// residual `c` for free.
pub(crate) fn grad_core(p: &AttentionLossProblem, f_op: &mut FOperator) -> (Matrix, f64) {
    let n = p.n();
    let d = p.d();

    // h(y) = A₃Y — T_mat(n,d,d) (Lemma C.10 part 2).
    let h = p.h();
    // c = f·h − E — d basis applies (Lemma C.11).
    let fh = f_op.apply_matrix(&h);
    let c = fh.sub(&p.e);
    let loss = 0.5 * c.data().iter().map(|v| v * v).sum::<f64>();
    // q = c·hᵀ, kept factored (Lemma C.12): U_a = c, U_b = h.

    // r_j = ⟨f_j, q_j⟩ = ⟨(f·h)_j, c_j⟩ (Lemma C.14, using q = c hᵀ ⇒
    // f·qᵀ = (f·h)·cᵀ whose diagonal is r).
    let r: Vec<f64> = (0..n)
        .map(|j| crate::tensor::dot(fh.row(j), c.row(j)))
        .collect();

    // p·A₂, one column at a time: p·w = p₁·w − p₂·w with
    //   p₁·w = Σ_{i<d} c_{:,i} ∘ (f·(h_{:,i} ∘ w))   (Lemma C.13)
    //   p₂·w = r ∘ (f·w)                              (Lemma C.15)
    let mut pa2 = Matrix::zeros(n, d);
    let mut scratch = vec![0.0; n];
    for col in 0..d {
        let w = p.a2.col(col);
        let mut acc = vec![0.0; n];
        for i in 0..d {
            // h_{:,i} ∘ w
            for (row, s) in scratch.iter_mut().enumerate() {
                *s = h[(row, i)] * w[row];
            }
            let fw = f_op.apply(&scratch);
            for row in 0..n {
                acc[row] += c[(row, i)] * fw[row];
            }
        }
        let fw = f_op.apply(&w);
        for row in 0..n {
            acc[row] -= r[row] * fw[row];
        }
        pa2.set_col(col, &acc);
    }

    // ∇L = A₁ᵀ (p·A₂) — T_mat(d,n,d) (Lemma C.16).
    (p.a1.transpose().matmul(&pa2), loss)
}

/// Conv-basis **LM attention backward** for one head: given the
/// operator `f = softmax(QKᵀ)` (causal, as an [`FOperator`]) and the
/// upstream gradient `dout` w.r.t. the head's output `Y = f·V`, return
/// `(dQ, dK, dV)` in `O(k·n·d_h²·log n)` — the per-layer gradient chain
/// of "Multi-Layer Transformers Gradient Can be Approximated in Almost
/// Linear Time" instantiated on our conv basis.
///
/// Derivation (P = f, S = pre-softmax scores):
///
/// ```text
/// dV = Pᵀ·dout                                       (d_h fᵀ-applies)
/// dS = P ∘ (dout·Vᵀ) − diag(r)·P,   r_i = ⟨dout_i, Y_i⟩
/// dQ = dS·K = Σ_c dout_c ∘ f·(V_c ∘ K_col) − r ∘ (f·K_col)
/// dK = dSᵀ·Q = Σ_c V_c ∘ fᵀ·(dout_c ∘ Q_col) − fᵀ·(r ∘ Q_col)
/// ```
///
/// The rank-`d_h` Hadamard products multiply through the diag-sandwich
/// identity (Lemma C.13), exactly like the Definition 5.1 pipeline; the
/// softmax-Jacobian row dots collapse to `r = rowdot(dout, f·V)` — the
/// forward output the backward recomputes in `d_h` applies — so no
/// `n×n` matrix is ever materialized. The transposed applies go through
/// [`KConvBasis::apply_transpose`] (same cost, same FFT plan lengths).
pub(crate) fn attn_backward_core(
    f_op: &mut FOperator,
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    dout: &Matrix,
) -> (Matrix, Matrix, Matrix) {
    let n = q.rows();
    let dh = q.cols();
    // Y = f·V — recovers the forward output; r_i = ⟨dout_i, Y_i⟩ is the
    // softmax-Jacobian row-dot term.
    let y = f_op.apply_matrix(v);
    let r: Vec<f64> = (0..n).map(|i| crate::tensor::dot(dout.row(i), y.row(i))).collect();

    // dV = fᵀ·dout, column-wise.
    let mut dv = Matrix::zeros(n, dh);
    for c in 0..dh {
        let col = dout.col(c);
        dv.set_col(c, &f_op.apply_transpose(&col));
    }

    let mut scratch = vec![0.0; n];
    // dQ (w.r.t. the pre-scaled Q the operator was built from).
    let mut dq = Matrix::zeros(n, dh);
    for col in 0..dh {
        let kcol = k.col(col);
        let mut acc = vec![0.0; n];
        for c in 0..dh {
            for (row, s) in scratch.iter_mut().enumerate() {
                *s = v[(row, c)] * kcol[row];
            }
            let fw = f_op.apply(&scratch);
            for row in 0..n {
                acc[row] += dout[(row, c)] * fw[row];
            }
        }
        let fk = f_op.apply(&kcol);
        for row in 0..n {
            acc[row] -= r[row] * fk[row];
        }
        dq.set_col(col, &acc);
    }

    // dK — the transposed chain.
    let mut dk = Matrix::zeros(n, dh);
    for col in 0..dh {
        let qcol = q.col(col);
        let mut acc = vec![0.0; n];
        for c in 0..dh {
            for (row, s) in scratch.iter_mut().enumerate() {
                *s = dout[(row, c)] * qcol[row];
            }
            let ftw = f_op.apply_transpose(&scratch);
            for row in 0..n {
                acc[row] += v[(row, c)] * ftw[row];
            }
        }
        for (row, s) in scratch.iter_mut().enumerate() {
            *s = r[row] * qcol[row];
        }
        let ftr = f_op.apply_transpose(&scratch);
        for row in 0..n {
            acc[row] -= ftr[row];
        }
        dk.set_col(col, &acc);
    }

    (dq, dk, dv)
}

/// Dense-f variant of the fast pipeline (ablation: same factored-q /
/// diag-sandwich structure but `f·w` via the materialized matrix,
/// `O(n²)` per apply). Lets the benches separate the conv speedup from
/// the tensor-trick speedup.
pub fn grad_factored_dense(p: &AttentionLossProblem, x: &Matrix) -> Matrix {
    let n = p.n();
    let d = p.d();
    let f = f_dense(p, x);
    let h = p.h();
    let fh = f.matmul(&h);
    let c = fh.sub(&p.e);
    let r: Vec<f64> = (0..n)
        .map(|j| crate::tensor::dot(fh.row(j), c.row(j)))
        .collect();
    let mut pa2 = Matrix::zeros(n, d);
    let mut scratch = vec![0.0; n];
    for col in 0..d {
        let w = p.a2.col(col);
        let mut acc = vec![0.0; n];
        for i in 0..d {
            for (row, s) in scratch.iter_mut().enumerate() {
                *s = h[(row, i)] * w[row];
            }
            let fw = f.matvec(&scratch);
            for row in 0..n {
                acc[row] += c[(row, i)] * fw[row];
            }
        }
        let fw = f.matvec(&w);
        for row in 0..n {
            acc[row] -= r[row] * fw[row];
        }
        pa2.set_col(col, &acc);
    }
    p.a1.transpose().matmul(&pa2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{max_abs_diff, Rng};

    #[test]
    fn factored_dense_matches_naive() {
        let mut rng = Rng::seeded(171);
        let p = AttentionLossProblem::random_structured(14, 3, &mut rng);
        let x = Matrix::randn(3, 3, &mut rng).scale(0.4);
        let want = super::super::naive::grad_naive(&p, &x);
        let got = grad_factored_dense(&p, &x);
        assert!(max_abs_diff(&want, &got) < 1e-9);
    }

    #[test]
    fn f_operator_matches_dense_f() {
        let mut rng = Rng::seeded(172);
        let p = AttentionLossProblem::random_structured(18, 4, &mut rng);
        let x = Matrix::eye(4).scale(0.3);
        let cfg = RecoverConfig::exact(18);
        let (mut f_op, _) = FOperator::build(&p, &x, &cfg).unwrap();
        let f = f_dense(&p, &x);
        let w = rng.randn_vec(18);
        let fast = f_op.apply(&w);
        let dense = f.matvec(&w);
        for (a, b) in fast.iter().zip(&dense) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn report_loss_matches_loss_fast() {
        let mut rng = Rng::seeded(174);
        let p = AttentionLossProblem::random_structured(16, 3, &mut rng);
        let x = Matrix::randn(3, 3, &mut rng).scale(0.2);
        let cfg = RecoverConfig::exact(16);
        let (_, report) = grad_fast(&p, &x, &cfg).unwrap();
        let l = loss_fast(&p, &x, &cfg).unwrap();
        assert_eq!(report.loss, l, "the backward's residual is the forward's loss");
    }

    #[test]
    fn from_cached_operator_is_bit_identical() {
        let mut rng = Rng::seeded(175);
        let p = AttentionLossProblem::random_structured(18, 3, &mut rng);
        let x = Matrix::randn(3, 3, &mut rng).scale(0.3);
        let cfg = RecoverConfig::exact(18);
        let (mut fresh, _) = FOperator::build(&p, &x, &cfg).unwrap();
        let (basis, d_tilde) = fresh.cacheable_parts();
        let shared =
            Arc::new(CachedBasis { post_basis: basis.clone(), d_tilde: d_tilde.to_vec() });
        let (mut cached, _) = FOperator::from_cached(shared, FftPlanner::new()).unwrap();
        let (g_fresh, l_fresh) = grad_core(&p, &mut fresh);
        let (g_cached, l_cached) = grad_core(&p, &mut cached);
        assert_eq!(max_abs_diff(&g_fresh, &g_cached), 0.0);
        assert_eq!(l_fresh, l_cached);
    }

    #[test]
    fn attn_backward_core_matches_dense_softmax_backward() {
        // Dense oracle: P = row-normalized masked exp(QKᵀ), then the
        // textbook matrix-form softmax-attention backward.
        let mut rng = Rng::seeded(176);
        let (n, dh) = (18, 3);
        let q = Matrix::randn(n, dh, &mut rng).scale(0.3);
        let k = Matrix::randn(n, dh, &mut rng).scale(0.3);
        let v = Matrix::randn(n, dh, &mut rng);
        let dout = Matrix::randn(n, dh, &mut rng);
        let mask = Mask::causal(n);
        let cfg = RecoverConfig::exact(n);
        let (mut f_op, _) =
            FOperator::build_qk(&q, &k, &mask, &cfg, FftPlanner::new()).unwrap();
        let (dq, dk, dv) = attn_backward_core(&mut f_op, &q, &k, &v, &dout);

        let scores = q.matmul(&k.transpose());
        let mut p = Matrix::zeros(n, n);
        for i in 0..n {
            let mut s = 0.0;
            for j in 0..=i {
                p[(i, j)] = scores[(i, j)].exp();
                s += p[(i, j)];
            }
            for j in 0..=i {
                p[(i, j)] /= s;
            }
        }
        let dv_want = p.transpose().matmul(&dout);
        let dprobs = dout.matmul(&v.transpose());
        let mut ds = Matrix::zeros(n, n);
        for i in 0..n {
            let dot = crate::tensor::dot(p.row(i), dprobs.row(i));
            for j in 0..n {
                ds[(i, j)] = p[(i, j)] * (dprobs[(i, j)] - dot);
            }
        }
        let dq_want = ds.matmul(&k);
        let dk_want = ds.transpose().matmul(&q);
        assert!(max_abs_diff(&dv, &dv_want) < 1e-8, "dv err {}", max_abs_diff(&dv, &dv_want));
        assert!(max_abs_diff(&dq, &dq_want) < 1e-8, "dq err {}", max_abs_diff(&dq, &dq_want));
        assert!(max_abs_diff(&dk, &dk_want) < 1e-8, "dk err {}", max_abs_diff(&dk, &dk_want));
    }

    #[test]
    fn report_counts_applies() {
        let mut rng = Rng::seeded(173);
        let p = AttentionLossProblem::random_structured(12, 3, &mut rng);
        let x = Matrix::eye(3);
        let cfg = RecoverConfig::exact(12);
        let (_, report) = grad_fast(&p, &x, &cfg).unwrap();
        // d applies for f·h, plus per output column (d): d Hadamard
        // applies + 1 plain apply ⇒ d + d·(d+1).
        let d = 3;
        assert_eq!(report.f_applies, d + d * (d + 1));
    }
}
