//! Attention-loss gradient (Section 5, Appendix C).
//!
//! Definition 5.1: given `A₁, A₂, A₃, E ∈ R^{n×d}`, `Y ∈ R^{d×d}` and the
//! causal mask `M`, minimize over `X ∈ R^{d×d}`
//!
//! ```text
//! L(X) = 0.5 · ‖ D(X)⁻¹ (M ∘ exp(A₁XA₂ᵀ)) A₃Y − E ‖²_F ,
//! D(X) = diag((M ∘ exp(A₁XA₂ᵀ))·1).
//! ```
//!
//! The gradient (Lemma C.9, via the tensor trick Fact E.9) is
//! `dL/dx = vec(A₁ᵀ p(x) A₂)` with
//! `p(x)_{j} = (diag(f_j) − f_j f_jᵀ) q_j`, `f = D⁻¹·(M∘exp(A₁XA₂ᵀ))`,
//! `q = c·h(y)ᵀ`, `c = f·h(y) − E`, `h(y) = A₃Y`.
//!
//! Three implementations, in decreasing cost:
//! * [`naive::grad_finite_diff`] — finite differences (oracle of oracles);
//! * [`naive::grad_naive`] — dense analytic, `O(n²d)`;
//! * [`fast::grad_fast`] — the paper's `O(k·n·d²·log n)` path: `f·w`
//!   through the k-conv basis (Theorem 4.4), `q` kept rank-d factored
//!   (Lemma C.12), `p₁` via the diag-sandwich identity (Lemma C.13),
//!   `p₂ = diag(r)·f` (Lemmas C.14–C.15).
//!
//! Batched execution: [`batched::GradJob`] wraps one problem for the
//! engine's unified [`submit`] door — all (layer, head) gradients of a
//! training step fan over the worker pool in one call, sharing the
//! engine's FFT plans and recovered-basis cache (bit-identical to
//! per-problem [`grad_fast`]; see `tests/properties.rs`).
//!
//! [`submit`]: crate::attention::batched::BatchedEngine::submit
//!
//! Note: Definition C.7 in the paper writes `p = p₁ + p₂` while defining
//! `p₂ := f fᵀ q`; the softmax Jacobian (and the finite-difference
//! oracle) require `p = p₁ − p₂`. We implement the minus and verify it
//! against finite differences in the tests.

pub mod batched;
pub mod fast;
pub mod naive;
pub mod optimize;

pub use batched::{
    AttnBackwardJob, AttnBackwardMode, AttnBackwardOutput, FastGradConfig, GradJob, GradOutput,
};
pub use fast::{grad_fast, loss_fast, FastGradientReport};
pub use naive::{grad_finite_diff, grad_naive, loss_naive};
pub use optimize::{solve, SolveTrace, SolverConfig};

use crate::attention::Mask;
use crate::tensor::Matrix;

/// The attention-optimization instance of Definition 5.1.
#[derive(Clone, Debug)]
pub struct AttentionLossProblem {
    pub a1: Matrix,
    pub a2: Matrix,
    pub a3: Matrix,
    /// `Y ∈ R^{d×d}` (plays the role of `W_V` — Remark 5.2).
    pub y: Matrix,
    /// Target `E ∈ R^{n×d}`.
    pub e: Matrix,
    pub mask: Mask,
}

impl AttentionLossProblem {
    pub fn new(a1: Matrix, a2: Matrix, a3: Matrix, y: Matrix, e: Matrix, mask: Mask) -> Self {
        let (n, d) = a1.shape();
        assert_eq!(a2.shape(), (n, d));
        assert_eq!(a3.shape(), (n, d));
        assert_eq!(y.shape(), (d, d));
        assert_eq!(e.shape(), (n, d));
        assert_eq!(mask.n(), n);
        AttentionLossProblem { a1, a2, a3, y, e, mask }
    }

    pub fn n(&self) -> usize {
        self.a1.rows()
    }

    pub fn d(&self) -> usize {
        self.a1.cols()
    }

    /// `h(y) = A₃·Y` (Definition C.3) — `T_mat(n,d,d)`.
    pub fn h(&self) -> Matrix {
        self.a3.matmul(&self.y)
    }

    /// A random self-attention-shaped instance (Remark 5.2): `A₁ = A₂ =
    /// A₃ = X_input`, with structured rows so the conv basis is small.
    pub fn random_structured(n: usize, d: usize, rng: &mut crate::tensor::Rng) -> Self {
        let (x_in, _) = crate::attention::rope::rope_structured_qk(n, d, (d / 2).min(3), rng);
        let y = Matrix::randn(d, d, rng).scale(1.0 / (d as f64).sqrt());
        let e = Matrix::randn(n, d, rng).scale(0.1);
        AttentionLossProblem::new(
            x_in.clone(),
            x_in.clone(),
            x_in,
            y,
            e,
            Mask::causal(n),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{max_abs_diff, Matrix, Rng};

    #[test]
    fn problem_shapes() {
        let mut rng = Rng::seeded(151);
        let p = AttentionLossProblem::random_structured(16, 4, &mut rng);
        assert_eq!(p.n(), 16);
        assert_eq!(p.d(), 4);
        assert_eq!(p.h().shape(), (16, 4));
    }

    #[test]
    fn naive_grad_matches_finite_diff() {
        let mut rng = Rng::seeded(152);
        let p = AttentionLossProblem::random_structured(12, 3, &mut rng);
        let x = Matrix::randn(3, 3, &mut rng).scale(0.3);
        let g_analytic = grad_naive(&p, &x);
        let g_fd = grad_finite_diff(&p, &x, 1e-5);
        let err = max_abs_diff(&g_analytic, &g_fd);
        assert!(err < 1e-6, "err = {err}");
    }

    #[test]
    fn fast_grad_matches_naive_exact_config() {
        let mut rng = Rng::seeded(153);
        let p = AttentionLossProblem::random_structured(20, 4, &mut rng);
        let x = Matrix::randn(4, 4, &mut rng).scale(0.25);
        let g_naive = grad_naive(&p, &x);
        let cfg = crate::basis::RecoverConfig::exact(20);
        let (g_fast, report) = grad_fast(&p, &x, &cfg).unwrap();
        let err = max_abs_diff(&g_naive, &g_fast);
        assert!(err < 1e-7, "err = {err}");
        assert!(report.basis_k >= 1);
    }

    #[test]
    fn fast_grad_small_k_on_structured_instance() {
        // Structured A₁=A₂ ⇒ A₁XA₂ᵀ is near-Toeplitz for symmetric X ⇒
        // small recovered k (validates the “conv+low-rank simultaneously”
        // claim of Remark 5.7 on a favourable instance).
        let mut rng = Rng::seeded(154);
        let p = AttentionLossProblem::random_structured(32, 4, &mut rng);
        // Symmetric PSD-ish X = I keeps A₁XA₂ᵀ = A₁A₂ᵀ Toeplitz.
        let x = Matrix::eye(4);
        let cfg = crate::basis::RecoverConfig { k_max: 8, t: 2, delta: 1e-6, eps: 1e-12 };
        let (g_fast, report) = grad_fast(&p, &x, &cfg).unwrap();
        assert!(report.basis_k <= 2, "k = {}", report.basis_k);
        let g_naive = grad_naive(&p, &x);
        let err = max_abs_diff(&g_naive, &g_fast);
        assert!(err < 1e-6, "err = {err}");
    }

    #[test]
    fn loss_fast_matches_naive() {
        let mut rng = Rng::seeded(155);
        let p = AttentionLossProblem::random_structured(24, 4, &mut rng);
        let x = Matrix::randn(4, 4, &mut rng).scale(0.2);
        let l_naive = loss_naive(&p, &x);
        let cfg = crate::basis::RecoverConfig::exact(24);
        let l_fast = loss_fast(&p, &x, &cfg).unwrap();
        assert!((l_naive - l_fast).abs() < 1e-8 * l_naive.max(1.0));
    }

    #[test]
    fn gradient_descent_reduces_loss() {
        // End-to-end sanity: a few GD steps with the fast gradient
        // reduce the Definition 5.1 objective.
        let mut rng = Rng::seeded(156);
        let p = AttentionLossProblem::random_structured(16, 3, &mut rng);
        let mut x = Matrix::zeros(3, 3);
        let cfg = crate::basis::RecoverConfig::exact(16);
        let mut losses = Vec::new();
        for _ in 0..40 {
            losses.push(loss_naive(&p, &x));
            let (g, _) = grad_fast(&p, &x, &cfg).unwrap();
            x.axpy_mat(-2.0, &g);
        }
        let first = losses[0];
        let last = *losses.last().unwrap();
        assert!(last < first * 0.99, "loss did not decrease: {first} → {last}");
        // And the trajectory is monotone non-increasing up to noise.
        assert!(losses.windows(2).all(|w| w[1] <= w[0] + 1e-9));
    }
}
