//! Fixed worker pool for the batched attention engine.
//!
//! Dispatcher-style (cf. the rplay dispatcher pattern): a bounded set of
//! `std::thread` workers drain one shared job queue; callers fan work
//! out with [`WorkerPool::map`] and get results back in **input order**
//! regardless of which worker finished first — the determinism contract
//! the batched engine's tests pin down (thread counts 1/2/8 must give
//! bit-identical outputs, which holds because jobs are pure and ordering
//! is restored by index).
//!
//! Plain std threads + mpsc: the workload is CPU-bound attention math
//! and this image vendors no async runtime or rayon.
//!
//! # Worked example
//!
//! ```
//! use conv_basis::runtime::pool::WorkerPool;
//!
//! let pool = WorkerPool::new(4);
//! // `map` blocks until every item is done and restores input order,
//! // whatever order the workers finished in.
//! let out = pool.map((0..16u64).collect(), |idx, x| (idx as u64) + x * 10);
//! assert_eq!(out[3], 3 + 30);
//! // Identical inputs on any pool size give identical outputs — the
//! // invariant `tests/properties.rs` pins for the attention engine.
//! let again = WorkerPool::new(1).map((0..16u64).collect(), |idx, x| (idx as u64) + x * 10);
//! assert_eq!(out, again);
//! ```
//!
//! # Invariants callers rely on
//!
//! * **Input-order results**: `map(items, f)[i] == f(i, items[i])`.
//! * **Purity is the caller's contract**: `f` must not read mutable
//!   shared state keyed on timing or worker identity, or the
//!   bit-determinism guarantee above evaporates.
//! * **No nested maps**: a job must not call `map` on the same pool
//!   (all workers may be busy running callers — deadlock).
//! * **Panic containment**: a panicking job panics the *caller* of
//!   `map`, not the worker thread; the pool stays fully operational
//!   for subsequent maps (see `workers_survive_panicking_jobs`).

use crate::sync::{lock, mpsc, thread, Arc, Mutex};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed pool of worker threads executing boxed jobs.
pub struct WorkerPool {
    /// Mutex-wrapped so the pool is `Sync` (shared via `Arc` by the
    /// coordinator's server workers) on every toolchain vintage.
    tx: Option<Mutex<mpsc::Sender<Job>>>,
    handles: Vec<thread::JoinHandle<()>>,
    workers: usize,
}

impl WorkerPool {
    /// Spawn `workers` threads (clamped to at least 1).
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..workers)
            .map(|_| {
                let rx = Arc::clone(&rx);
                thread::spawn(move || loop {
                    // Hold the receiver lock only for the dequeue, not
                    // while running the job.
                    let job = { lock(&rx).recv() };
                    match job {
                        // Contain panicking jobs: the worker must
                        // survive (a shared engine would otherwise lose
                        // a thread forever per bad job). The panic
                        // resurfaces in the caller's `map` when the
                        // job's result never arrives.
                        Ok(job) => {
                            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                        }
                        Err(_) => break, // queue closed: pool dropped
                    }
                })
            })
            .collect();
        WorkerPool { tx: Some(Mutex::new(tx)), handles, workers }
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.workers
    }

    fn submit(&self, job: Job) {
        lock(self.tx.as_ref().expect("pool running")).send(job).expect("worker threads alive");
    }

    /// Run `f` over every item on the pool and return the results in
    /// input order. Blocks the calling thread until all items finish.
    ///
    /// Must not be called from inside a pool job (the caller would wait
    /// on workers that may all be occupied by callers).
    pub fn map<I, O, F>(&self, items: Vec<I>, f: F) -> Vec<O>
    where
        I: Send + 'static,
        O: Send + 'static,
        F: Fn(usize, I) -> O + Send + Sync + 'static,
    {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        let f = Arc::new(f);
        let (otx, orx) = mpsc::channel::<(usize, O)>();
        for (i, item) in items.into_iter().enumerate() {
            let otx = otx.clone();
            let f = Arc::clone(&f);
            self.submit(Box::new(move || {
                let _ = otx.send((i, f(i, item)));
            }));
        }
        drop(otx);
        let mut slots: Vec<Option<O>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            // A send is only missing if a job panicked; surface that as
            // a panic here rather than hanging.
            let (i, o) = orx.recv().expect("a pool job panicked before returning its result");
            slots[i] = Some(o);
        }
        slots.into_iter().map(|s| s.expect("result index delivered exactly once")).collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the queue; workers drain and exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_input_order() {
        let pool = WorkerPool::new(4);
        let items: Vec<usize> = (0..64).collect();
        let out = pool.map(items, |_, x| {
            // Stagger completion so arrival order differs from input order.
            if x % 3 == 0 {
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
            x * 2
        });
        assert_eq!(out, (0..64).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn map_results_independent_of_worker_count() {
        let items: Vec<u64> = (0..40).collect();
        let f = |_: usize, x: u64| x.wrapping_mul(0x9E3779B97F4A7C15);
        let a = WorkerPool::new(1).map(items.clone(), f);
        let b = WorkerPool::new(2).map(items.clone(), f);
        let c = WorkerPool::new(8).map(items, f);
        assert_eq!(a, b);
        assert_eq!(b, c);
    }

    #[test]
    fn empty_map_is_empty() {
        let pool = WorkerPool::new(2);
        let out: Vec<u32> = pool.map(Vec::<u32>::new(), |_, x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn concurrent_maps_do_not_interleave_results() {
        let pool = Arc::new(WorkerPool::new(3));
        let mut joins = Vec::new();
        for t in 0..4u64 {
            let pool = pool.clone();
            joins.push(std::thread::spawn(move || {
                let items: Vec<u64> = (0..20).map(|i| t * 1000 + i).collect();
                let out = pool.map(items.clone(), |_, x| x + 1);
                assert_eq!(out, items.iter().map(|x| x + 1).collect::<Vec<_>>());
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
    }

    #[test]
    fn drop_joins_workers() {
        let pool = WorkerPool::new(2);
        let _ = pool.map(vec![1, 2, 3], |_, x| x);
        drop(pool); // must not hang
    }

    #[test]
    fn workers_survive_panicking_jobs() {
        let pool = WorkerPool::new(2);
        // A map containing a panicking job panics the caller...
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.map(vec![0u32, 1, 2], |_, x| {
                if x == 1 {
                    panic!("bad job");
                }
                x
            })
        }));
        assert!(result.is_err());
        // ...but the pool keeps all its workers and serves later maps.
        let out = pool.map(vec![10u32, 20, 30, 40], |_, x| x + 1);
        assert_eq!(out, vec![11, 21, 31, 41]);
    }
}
