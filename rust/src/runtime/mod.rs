//! Execution runtime: the batched engine's [`pool`] of worker threads,
//! plus the (feature-gated) PJRT client that loads the AOT artifacts
//! produced by `python/compile/aot.py` (HLO **text** — see
//! /opt/xla-example/README: serialized protos from jax ≥ 0.5 are
//! rejected by xla_extension 0.5.1) and executes them on the CPU PJRT
//! client from the Rust hot path.
//!
//! The PJRT path needs the external `xla` crate, which the offline build
//! image cannot vendor through the registry; it is therefore behind the
//! `pjrt` cargo feature. The feature build compiles against the in-tree
//! `xla` **API-surface stub** (`rust/xla-stub`, an optional path
//! dependency whose entry points fail at runtime) — CI checks it with
//! `cargo check --features pjrt` so this module cannot rot; point the
//! path dependency at a vendored real crate to actually execute. The
//! default (feature-off) build ships a runtime stub with the identical
//! API whose constructors return [`RuntimeError::Unavailable`], so every
//! caller — the CLI `verify` subcommand, `examples/serve_requests.rs`,
//! the integration tests — compiles unchanged and degrades gracefully.
//!
//! Python never runs at request time: `make artifacts` is the only
//! python invocation, and it is a no-op when artifacts are fresh.

pub mod pool;

/// Runtime errors (wraps the xla crate's error type when `pjrt` is on).
#[derive(Debug)]
pub enum RuntimeError {
    Xla(String),
    Io(String),
    Shape(String),
    /// The crate was built without the `pjrt` feature.
    Unavailable(String),
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RuntimeError::Xla(e) => write!(f, "xla error: {e}"),
            RuntimeError::Io(e) => write!(f, "io error: {e}"),
            RuntimeError::Shape(e) => write!(f, "shape error: {e}"),
            RuntimeError::Unavailable(e) => write!(f, "pjrt unavailable: {e}"),
        }
    }
}

impl std::error::Error for RuntimeError {}

/// Whether this build carries a real PJRT client (the `pjrt` feature).
pub fn pjrt_available() -> bool {
    cfg!(feature = "pjrt")
}

/// Default artifact directory (repo-root relative).
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("CONV_BASIS_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}

#[cfg(feature = "pjrt")]
mod pjrt {
    use super::RuntimeError;
    use crate::tensor::Matrix;
    use std::path::Path;

    /// A compiled artifact ready to execute.
    pub struct CompiledModel {
        exe: xla::PjRtLoadedExecutable,
        /// Human-readable identity (artifact path).
        pub name: String,
    }

    impl From<xla::Error> for RuntimeError {
        fn from(e: xla::Error) -> Self {
            RuntimeError::Xla(e.to_string())
        }
    }

    /// PJRT CPU client wrapper. One per process; compiled executables are
    /// cached by artifact path.
    ///
    /// `Rc`, not `Arc`: the xla crate's executables are neither `Send` nor
    /// `Sync`, so a runtime is owned by one thread (the coordinator gives
    /// each worker that needs PJRT its own runtime).
    pub struct PjrtRuntime {
        client: xla::PjRtClient,
        compiled: std::collections::BTreeMap<String, std::rc::Rc<CompiledModel>>,
    }

    impl PjrtRuntime {
        /// Create the CPU client.
        pub fn cpu() -> Result<Self, RuntimeError> {
            Ok(PjrtRuntime { client: xla::PjRtClient::cpu()?, compiled: Default::default() })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile an HLO-text artifact (cached).
        pub fn load(&mut self, path: &Path) -> Result<std::rc::Rc<CompiledModel>, RuntimeError> {
            let key = path.display().to_string();
            if let Some(m) = self.compiled.get(&key) {
                return Ok(m.clone());
            }
            if !path.exists() {
                return Err(RuntimeError::Io(format!(
                    "artifact {key} not found — run `make artifacts` first"
                )));
            }
            let proto = xla::HloModuleProto::from_text_file(&key)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            let model = std::rc::Rc::new(CompiledModel { exe, name: key.clone() });
            self.compiled.insert(key, model.clone());
            Ok(model)
        }
    }

    impl CompiledModel {
        /// Execute with f32 matrix inputs; returns the tuple of f32 matrix
        /// outputs (shapes supplied by the caller — HLO text carries them,
        /// but the xla crate's literal API is easiest with explicit dims).
        pub fn run(
            &self,
            inputs: &[(&Matrix, (usize, usize))],
            out_shapes: &[(usize, usize)],
        ) -> Result<Vec<Matrix>, RuntimeError> {
            let mut literals = Vec::with_capacity(inputs.len());
            for (m, (r, c)) in inputs {
                if m.shape() != (*r, *c) {
                    return Err(RuntimeError::Shape(format!(
                        "input shape {:?} != declared {:?}",
                        m.shape(),
                        (r, c)
                    )));
                }
                let lit = xla::Literal::vec1(&m.to_f32())
                    .reshape(&[*r as i64, *c as i64])?;
                literals.push(lit);
            }
            let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
            // aot.py lowers with return_tuple=True.
            let mut result = result;
            let tuple = result.decompose_tuple()?;
            if tuple.len() != out_shapes.len() {
                return Err(RuntimeError::Shape(format!(
                    "artifact returned {} outputs, caller expected {}",
                    tuple.len(),
                    out_shapes.len()
                )));
            }
            let mut out = Vec::with_capacity(tuple.len());
            for (lit, (r, c)) in tuple.into_iter().zip(out_shapes) {
                let v = lit.to_vec::<f32>()?;
                if v.len() != r * c {
                    return Err(RuntimeError::Shape(format!(
                        "output has {} elements, expected {}×{}",
                        v.len(),
                        r,
                        c
                    )));
                }
                out.push(Matrix::from_f32(*r, *c, &v));
            }
            Ok(out)
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn cpu_client_starts() {
            let rt = PjrtRuntime::cpu().expect("PJRT CPU client");
            assert!(!rt.platform().is_empty());
        }

        #[test]
        fn missing_artifact_is_io_error() {
            let mut rt = PjrtRuntime::cpu().unwrap();
            let err = rt.load(Path::new("/nonexistent/foo.hlo.txt")).err().unwrap();
            assert!(matches!(err, RuntimeError::Io(_)));
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt::{CompiledModel, PjrtRuntime};

#[cfg(not(feature = "pjrt"))]
mod pjrt_stub {
    use super::RuntimeError;
    use crate::tensor::Matrix;
    use std::path::Path;

    const MSG: &str =
        "built without the `pjrt` feature — rebuild with `--features pjrt` and a vendored `xla` crate";

    /// Stub compiled artifact (API-compatible with the `pjrt` build).
    pub struct CompiledModel {
        /// Human-readable identity (artifact path).
        pub name: String,
    }

    /// Stub PJRT client: construction reports [`RuntimeError::Unavailable`].
    pub struct PjrtRuntime {
        _private: (),
    }

    impl PjrtRuntime {
        pub fn cpu() -> Result<Self, RuntimeError> {
            Err(RuntimeError::Unavailable(MSG.into()))
        }

        pub fn platform(&self) -> String {
            "unavailable".into()
        }

        pub fn load(&mut self, _path: &Path) -> Result<std::rc::Rc<CompiledModel>, RuntimeError> {
            Err(RuntimeError::Unavailable(MSG.into()))
        }
    }

    impl CompiledModel {
        pub fn run(
            &self,
            _inputs: &[(&Matrix, (usize, usize))],
            _out_shapes: &[(usize, usize)],
        ) -> Result<Vec<Matrix>, RuntimeError> {
            Err(RuntimeError::Unavailable(MSG.into()))
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn stub_reports_unavailable() {
            assert!(!super::super::pjrt_available());
            let err = PjrtRuntime::cpu().err().unwrap();
            assert!(matches!(err, RuntimeError::Unavailable(_)));
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use pjrt_stub::{CompiledModel, PjrtRuntime};
