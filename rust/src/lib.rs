//! # conv-basis
//!
//! Production-grade reproduction of *“Conv-Basis: A New Paradigm for
//! Efficient Attention Inference and Gradient Computation in
//! Transformers”* (EMNLP 2025 Findings).
//!
//! The library decomposes the (masked, pre-softmax) attention matrix
//! `H = M ∘ (QKᵀ)` into a sum of **sub-convolution matrices**
//! `H = Σ_{r∈[k]} conv(b_r, m_r)` (a *k-conv basis*, Definition 3.11 of
//! the paper), recovers that basis from `Q, K` alone with `O(k·n·d·log n)`
//! work via binary search (Algorithms 2–3), and then evaluates attention
//! `Y = D⁻¹·(M ∘ exp(QKᵀ))·V` through FFTs in `O(k·n·d·log n)` instead of
//! the quadratic `O(n²·d)` (Algorithm 1, Theorem 4.4). The same machinery
//! accelerates the training gradient (Theorem 5.6) and extends the
//! low-rank attention approximation of [AS23] to masked attention
//! (Theorem 6.5).
//!
//! ## Crate layout
//!
//! * [`tensor`] — dense row-major matrix/vector micro-BLAS (the substrate
//!   everything else is written against; no external linear algebra).
//! * [`fft`] — from-scratch complex FFT (iterative radix-2 Cooley–Tukey +
//!   Bluestein for arbitrary lengths) and a plan cache.
//! * [`conv`] — structured matrices: `conv(a)`, sub-convolution
//!   `conv(a, m)`, Toeplitz, circulant; FFT-backed multiplies.
//! * [`basis`] — the k-conv basis type, exact decomposition
//!   (Lemma 3.12), the `Recover` algorithm (Algorithm 2) with binary
//!   search (Algorithm 3), and the exp-transform (Lemma B.16).
//! * [`attention`] — exact attention oracle, conv-basis attention
//!   (Algorithm 1), masks (causal / LongLora / continuous-row /
//!   distinct-r / row-change), RoPE, the full (non-causal)
//!   self-attention split of Appendix A, the **batched engine**
//!   ([`attention::batched`]) whose single typed
//!   [`submit`](attention::batched::BatchedEngine::submit) door fans
//!   prefill, decode, gradient *and* LM-backward jobs over one worker
//!   pool, and the **incremental decode path** ([`attention::decode`])
//!   that attends one appended token in `O(k·n + n·d)` from a cached
//!   basis.
//! * [`lowrank`] — the [AS23] `(ε,k)`-approximation via polynomial
//!   features and the mask-aware multiplies of Appendix D
//!   (prefix-sum, support-delta, segment-tree, distinct-r).
//! * [`gradient`] — attention-loss gradient (Definition 5.1): dense
//!   oracle, finite differences, the fast conv+low-rank path of
//!   Appendix C, and the engine's batched lanes
//!   ([`gradient::batched`]): every (layer, head) Definition 5.1
//!   gradient of a training step in one `submit` call, plus the
//!   per-head LM attention backward
//!   ([`gradient::batched::AttnBackwardJob`] — exact mode bit-matches
//!   the dense backward with no `n×n` scratch; fast mode runs the
//!   conv-basis backward through [`basis`]' transpose apply).
//! * [`model`] — a small decoder-only transformer with a pluggable
//!   attention backend, Adam, and a training loop (used by the Figure 4
//!   and end-to-end experiments).
//! * [`data`] — byte-level tokenizer, synthetic corpora, the synthetic
//!   sentiment task standing in for IMDB, and serving workload traces.
//! * [`coordinator`] — the L3 serving layer: request router, dynamic
//!   batcher, per-model conv-basis cache, scheduler and metrics.
//! * [`runtime`] — the worker [`runtime::pool`] behind the batched
//!   engine, plus the (feature-gated) PJRT CPU client loading the AOT
//!   artifacts produced by `python/compile/aot.py` (HLO text).
//! * [`sync`] — the std/loom facade every concurrency-bearing module
//!   imports its primitives through (`RUSTFLAGS="--cfg loom"` flips it
//!   to the in-tree loom stub for `tests/loom_models.rs`), including
//!   the poison-recovering `lock`/`wait` helpers.
//! * [`lintpass`] — the repo-invariant determinism lint engine
//!   (`cargo run --bin lint`; rules, allowlist, fixture self-test) —
//!   see `ARCHITECTURE.md` §"Determinism invariants & static
//!   analysis".
//!
//! ## Architecture
//!
//! The full request flow — prefill, decode *and* gradient — is
//! documented in `ARCHITECTURE.md` at the repository root; the short
//! version: everything reaches one door,
//! [`attention::batched::BatchedEngine::submit`], as a typed
//! [`attention::batched::EngineJob`].
//!
//! * **Prefill / one-shot attention**: requests → `Router` →
//!   `DynamicBatcher` → server workers → one prefill-lane `submit` per
//!   batch. Every (sequence, head) pair is one
//!   [`attention::batched::AttnJob`]; jobs are pure, so results are
//!   bit-identical for any worker count. *Recover once, apply per V*
//!   happens engine-wide through the shared lock-striped
//!   [`coordinator::BasisCache`]. A request can pin its backend over
//!   the wire (`"backend":"exact"|"conv"|"lowrank"`), and the model
//!   layer can route **per (layer, head)** through
//!   [`attention::batched::BatchedBackend::Routed`] — a deterministic
//!   [`attention::batched::RouterPolicy`] table (explicit or built
//!   from measured [`coordinator::HeadProfile`]s) resolved inside job
//!   execution, so routed outputs stay bit-identical to direct
//!   backends for any worker count (`tests/router.rs`).
//! * **Autoregressive decode**: generation requests
//!   ([`coordinator::GenRequest`]) → the server's decode scheduler →
//!   `model::Transformer::prefill_batch` (seeds per-head
//!   [`attention::decode::DecodeState`]s from the basis cache) → one
//!   decode-lane `submit` per layer per generated token — `O(k·n + n·d)`
//!   per (layer, head) step, never a re-prefill, with drift-triggered
//!   re-recovery and live-session KV bytes surfaced in
//!   [`coordinator::Metrics`]. The scheduler's merge lane lets flushed
//!   attention batches ride an in-flight decode submit (continuous
//!   batching across op kinds).
//! * **Training gradients**: [`gradient::batched::GradJob`]s — one per
//!   (layer, head) Definition 5.1 problem — fan through the gradient
//!   lane in one `submit` per step (`model::train_attention_heads`),
//!   bit-identical to single-problem [`gradient::grad_fast`] and
//!   sharing recovered bases with the forward paths.
//! * **Full LM training step**: `model::train_lm`/`train_classifier`
//!   route both halves through the engine —
//!   `Transformer::forward_train_batch` submits *training-flavored*
//!   prefill jobs (exact or conv per [`model::TrainAttentionMode`]),
//!   then `Transformer::backward_batch_with_engine` fans every
//!   (sequence, layer, head) attention backward as
//!   [`gradient::batched::AttnBackwardJob`]s — one submit per layer
//!   over the whole micro-batch, bit-identical to the dense backward
//!   oracle in exact mode (`tests/gradient_oracle.rs`). In conv mode
//!   forward and backward share one basis recovery per (record, layer,
//!   head) per step — the forward's step-scoped handle
//!   ([`coordinator::StepBasis`]) rides the backward job, the serving
//!   `BasisCache` shards see zero training traffic, and the whole step
//!   is almost-linear end to end (`tests/train_conv.rs`).
//!
//! `examples/serve_requests.rs` drives both paths end-to-end (prompt
//! in, tokens out, metrics report); `benches/decode_step.rs` prices a
//! decode step against full re-prefill (numbers in `EXPERIMENTS.md`).
//!
//! ## Verifying
//!
//! Tier-1 verification is a single line from `rust/`:
//!
//! ```bash
//! cargo build --release && cargo test -q
//! ```
//!
//! The static-analysis layer runs alongside: `cargo run --bin lint`
//! (determinism lint, CI runs it before the tests) and
//! `RUSTFLAGS="--cfg loom" cargo test --release --test loom_models`
//! (scheduler protocol models; CI job `loom`), with ThreadSanitizer
//! and Miri lanes in CI.
//!
//! Benches (plain `main()` harnesses) run with
//! `cargo bench --bench batched_engine`,
//! `cargo bench --bench decode_step`, etc.; record their tables in
//! `EXPERIMENTS.md` per PR. The PJRT integration tests self-skip
//! unless artifacts exist and the `pjrt` feature is on. Docs are kept
//! warning-free by CI (`cargo doc --no-deps` with `-D warnings` plus
//! the doctest suite).

pub mod attention;
pub mod basis;
pub mod conv;
pub mod coordinator;
pub mod data;
pub mod fft;
pub mod gradient;
pub mod lintpass;
pub mod lowrank;
pub mod model;
pub mod runtime;
pub mod sync;
pub mod tensor;
pub mod util;

/// Convenience re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::attention::batched::{
        AttnJob, BatchedBackend, BatchedEngine, DecodeJob, DecodeOp, DecodeOutput, EngineConfig,
        EngineJob, EngineOp, EngineOutput, EngineResult, HeadRoute, JobOutput, ProfilePolicyConfig,
        RouterPolicy,
    };
    pub use crate::attention::decode::DecodeState;
    pub use crate::gradient::batched::{FastGradConfig, GradJob, GradOutput};
    pub use crate::model::{
        AttentionBackend, DecodeSession, ModelConfig, TrainAttentionMode, Transformer,
    };
    pub use crate::attention::rope::{rope_structured_qk, Rope};
    pub use crate::attention::{
        conv_attention, exact_attention, exact_attention_unmasked, ConvAttentionOutput, Mask,
    };
    pub use crate::basis::{
        exp_transform, recover, ConvBasis, KConvBasis, RecoverConfig, RecoverError,
    };
    pub use crate::conv::{conv_apply, conv_apply_naive, sub_conv_apply, ConvMatrix, SubConvMatrix};
    pub use crate::fft::FftPlanner;
    pub use crate::lowrank::{LowRankAttention, LowRankConfig};
    pub use crate::tensor::{max_abs_diff, Matrix, Rng, Vector};
}

#[cfg(test)]
mod lib_tests {
    #[test]
    fn prelude_compiles() {
        use crate::prelude::*;
        let m = Matrix::zeros(2, 2);
        assert_eq!(m.rows(), 2);
    }
}
