//! Matrix / vector norms as used throughout the paper (§3 Notations):
//! entry-wise ℓ₁, ℓ∞ and Frobenius.

use super::Matrix;

/// `‖v‖₁ = Σ|vᵢ|`.
pub fn l1_norm_vec(v: &[f64]) -> f64 {
    v.iter().map(|x| x.abs()).sum()
}

/// `‖v‖∞ = max |vᵢ|`.
pub fn linf_norm_vec(v: &[f64]) -> f64 {
    v.iter().fold(0.0, |m, x| m.max(x.abs()))
}

/// Entry-wise `‖A‖₁ = Σᵢⱼ |Aᵢⱼ|` (paper §3, *not* the operator 1-norm).
pub fn l1_norm_mat(a: &Matrix) -> f64 {
    l1_norm_vec(a.data())
}

/// Entry-wise `‖A‖∞ = maxᵢⱼ |Aᵢⱼ|`.
pub fn linf_norm_mat(a: &Matrix) -> f64 {
    linf_norm_vec(a.data())
}

/// Frobenius norm.
pub fn fro_norm(a: &Matrix) -> f64 {
    a.data().iter().map(|x| x * x).sum::<f64>().sqrt()
}

/// `maxᵢⱼ |Aᵢⱼ − Bᵢⱼ|` — the error metric of Theorem 4.4.
pub fn max_abs_diff(a: &Matrix, b: &Matrix) -> f64 {
    assert_eq!(a.shape(), b.shape(), "max_abs_diff shape mismatch");
    a.data()
        .iter()
        .zip(b.data())
        .fold(0.0, |m, (x, y)| m.max((x - y).abs()))
}

/// Relative Frobenius error `‖A − B‖²_F / ‖A‖²_F` — the Figure 4 metric.
pub fn rel_fro_error(reference: &Matrix, approx: &Matrix) -> f64 {
    assert_eq!(reference.shape(), approx.shape());
    let num: f64 = reference
        .data()
        .iter()
        .zip(approx.data())
        .map(|(x, y)| (x - y) * (x - y))
        .sum();
    let den: f64 = reference.data().iter().map(|x| x * x).sum();
    num / den
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norms_basic() {
        let a = Matrix::from_vec(2, 2, vec![1.0, -2.0, 3.0, -4.0]);
        assert_eq!(l1_norm_mat(&a), 10.0);
        assert_eq!(linf_norm_mat(&a), 4.0);
        assert!((fro_norm(&a) - (30f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn max_abs_diff_basic() {
        let a = Matrix::from_vec(1, 3, vec![1.0, 2.0, 3.0]);
        let b = Matrix::from_vec(1, 3, vec![1.0, 2.5, 2.0]);
        assert_eq!(max_abs_diff(&a, &b), 1.0);
    }

    #[test]
    fn rel_fro_zero_for_equal() {
        let a = Matrix::from_vec(1, 2, vec![3.0, 4.0]);
        assert_eq!(rel_fro_error(&a, &a.clone()), 0.0);
    }
}
