//! Deterministic xoshiro256** PRNG.
//!
//! No external `rand` dependency: every experiment in EXPERIMENTS.md must
//! be exactly reproducible from a seed, across platforms.

/// xoshiro256** generator with a splitmix64 seeder.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal from the Box–Muller pair.
    spare: Option<f64>,
}

impl Rng {
    /// Seeded construction (splitmix64-expanded).
    pub fn seeded(seed: u64) -> Self {
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()], spare: None }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn randn(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        // Avoid u == 0 for the log.
        let u = loop {
            let u = self.uniform();
            if u > 1e-300 {
                break u;
            }
        };
        let v = self.uniform();
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * v;
        self.spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Random normal vector of length `n`.
    pub fn randn_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.randn()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::seeded(42);
        let mut b = Rng::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seeded(1);
        let mut b = Rng::seeded(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut rng = Rng::seeded(3);
        for _ in 0..1000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn randn_moments() {
        let mut rng = Rng::seeded(4);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.randn()).collect();
        let mean: f64 = xs.iter().sum::<f64>() / n as f64;
        let var: f64 = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::seeded(5);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
