//! Row-major dense `f64` matrix with a blocked, thread-parallel matmul
//! (std::thread scoped threads — this image vendors no rayon).

use super::rng::Rng;

/// Dense row-major matrix of `f64`.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// All-zero `rows × cols` matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// All-one matrix.
    pub fn ones(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![1.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a closure `f(row, col)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    /// Build from a flat row-major vector.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    /// Standard-normal entries (deterministic given the RNG state).
    pub fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        let data = (0..rows * cols).map(|_| rng.randn()).collect();
        Matrix { rows, cols, data }
    }

    /// Uniform `[-a, a)` entries.
    pub fn rand_uniform(rows: usize, cols: usize, a: f64, rng: &mut Rng) -> Self {
        let data = (0..rows * cols).map(|_| (rng.uniform() * 2.0 - 1.0) * a).collect();
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Borrow row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Copy column `j` out.
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Set column `j` from a slice.
    pub fn set_col(&mut self, j: usize, v: &[f64]) {
        assert_eq!(v.len(), self.rows);
        for i in 0..self.rows {
            self[(i, j)] = v[i];
        }
    }

    /// Flat row-major data.
    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on large matrices.
        const B: usize = 32;
        for i0 in (0..self.rows).step_by(B) {
            for j0 in (0..self.cols).step_by(B) {
                for i in i0..(i0 + B).min(self.rows) {
                    for j in j0..(j0 + B).min(self.cols) {
                        t[(j, i)] = self[(i, j)];
                    }
                }
            }
        }
        t
    }

    /// Matrix product `self * other`.
    ///
    /// i-k-j loop order (streams rows of `other`, auto-vectorizes the
    /// inner j loop). Rows are split across scoped std threads once the
    /// work is large enough to amortize thread spawn.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, kk, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(m, n);
        let work = m * kk * n;

        #[inline]
        fn row_block(a: &Matrix, b: &Matrix, rows: std::ops::Range<usize>, out: &mut [f64]) {
            let n = b.cols;
            for (ri, i) in rows.enumerate() {
                let a_row = a.row(i);
                let out_row = &mut out[ri * n..(ri + 1) * n];
                for (k, &aik) in a_row.iter().enumerate() {
                    if aik == 0.0 {
                        continue;
                    }
                    let b_row = b.row(k);
                    for j in 0..n {
                        out_row[j] += aik * b_row[j];
                    }
                }
            }
        }

        let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        if work < 96 * 96 * 96 || threads == 1 || m < 2 * threads {
            // Serial path: small matmuls dominate the unit tests; thread
            // spawn would cost more than the multiply.
            row_block(self, other, 0..m, &mut out.data);
            return out;
        }
        let chunk_rows = m.div_ceil(threads);
        std::thread::scope(|scope| {
            let mut rest: &mut [f64] = &mut out.data;
            let mut start = 0usize;
            while start < m {
                let end = (start + chunk_rows).min(m);
                let (head, tail) = rest.split_at_mut((end - start) * n);
                rest = tail;
                let range = start..end;
                scope.spawn(move || row_block(self, other, range, head));
                start = end;
            }
        });
        out
    }

    /// `self * v` for a vector `v`.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len(), "matvec shape mismatch");
        (0..self.rows).map(|i| super::dot(self.row(i), v)).collect()
    }

    /// `selfᵀ * v`.
    pub fn matvec_t(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.rows, v.len(), "matvec_t shape mismatch");
        let mut out = vec![0.0; self.cols];
        for i in 0..self.rows {
            super::axpy(v[i], self.row(i), &mut out);
        }
        out
    }

    /// Element-wise map.
    pub fn map(&self, f: impl Fn(f64) -> f64 + Sync) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Element-wise (Hadamard) product.
    pub fn hadamard(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a * b).collect(),
        }
    }

    /// `self + other`.
    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect(),
        }
    }

    /// `self - other`.
    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect(),
        }
    }

    /// `self * s` for a scalar.
    pub fn scale(&self, s: f64) -> Matrix {
        self.map(|x| x * s)
    }

    /// In-place `self += alpha * other`.
    pub fn axpy_mat(&mut self, alpha: f64, other: &Matrix) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Row sums (`A · 1_n`).
    pub fn row_sums(&self) -> Vec<f64> {
        (0..self.rows).map(|i| self.row(i).iter().sum()).collect()
    }

    /// Scale each row `i` by `s[i]` (i.e. `diag(s) · A`).
    pub fn scale_rows(&self, s: &[f64]) -> Matrix {
        assert_eq!(s.len(), self.rows);
        let mut out = self.clone();
        for i in 0..self.rows {
            for x in out.row_mut(i) {
                *x *= s[i];
            }
        }
        out
    }

    /// Scale each column `j` by `s[j]` (i.e. `A · diag(s)`).
    pub fn scale_cols(&self, s: &[f64]) -> Matrix {
        assert_eq!(s.len(), self.cols);
        let mut out = self.clone();
        for i in 0..self.rows {
            let row = out.row_mut(i);
            for (j, x) in row.iter_mut().enumerate() {
                *x *= s[j];
            }
        }
        out
    }

    /// Append one row (the decode-path KV caches grow one token per
    /// step; row-major storage makes this a plain `Vec` extend).
    pub fn push_row(&mut self, row: &[f64]) {
        assert_eq!(row.len(), self.cols, "push_row width mismatch");
        self.data.extend_from_slice(row);
        self.rows += 1;
    }

    /// Drop every row past `n` (the exact inverse of [`Self::push_row`]
    /// for the dropped rows; the speculative-decode rollback truncates
    /// KV caches with this). Row-major storage makes it a plain `Vec`
    /// truncate — the surviving rows are untouched bytes.
    pub fn truncate_rows(&mut self, n: usize) {
        assert!(n <= self.rows, "truncate_rows past end");
        self.data.truncate(n * self.cols);
        self.rows = n;
    }

    /// Extract a contiguous sub-matrix (rows `r0..r1`, cols `c0..c1`).
    pub fn slice(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Matrix {
        assert!(r1 <= self.rows && c1 <= self.cols && r0 <= r1 && c0 <= c1);
        Matrix::from_fn(r1 - r0, c1 - c0, |i, j| self[(r0 + i, c0 + j)])
    }

    /// Whether all entries are finite.
    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }

    /// Lower-triangular part (inclusive of diagonal); the rest zeroed.
    pub fn tril(&self) -> Matrix {
        Matrix::from_fn(self.rows, self.cols, |i, j| if i >= j { self[(i, j)] } else { 0.0 })
    }

    /// Strictly upper-triangular part.
    pub fn triu_strict(&self) -> Matrix {
        Matrix::from_fn(self.rows, self.cols, |i, j| if i < j { self[(i, j)] } else { 0.0 })
    }

    /// Convert to `f32` (PJRT interop).
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }

    /// Build from `f32` data (PJRT interop).
    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Matrix {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data: data.iter().map(|&x| x as f64).collect() }
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::seeded(1);
        let a = Matrix::randn(5, 7, &mut rng);
        let i = Matrix::eye(7);
        let prod = a.matmul(&i);
        assert_eq!(prod, a);
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::seeded(2);
        let a = Matrix::randn(9, 11, &mut rng);
        let b = Matrix::randn(11, 6, &mut rng);
        let c = a.matmul(&b);
        for i in 0..9 {
            for j in 0..6 {
                let mut s = 0.0;
                for k in 0..11 {
                    s += a[(i, k)] * b[(k, j)];
                }
                assert!((c[(i, j)] - s).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn matmul_parallel_path_matches_serial() {
        let mut rng = Rng::seeded(3);
        // Force the parallel branch (work >= 64^3).
        let a = Matrix::randn(80, 70, &mut rng);
        let b = Matrix::randn(70, 90, &mut rng);
        let c = a.matmul(&b);
        // Check a few entries against a naive computation.
        for &(i, j) in &[(0, 0), (79, 89), (40, 45), (13, 77)] {
            let mut s = 0.0;
            for k in 0..70 {
                s += a[(i, k)] * b[(k, j)];
            }
            assert!((c[(i, j)] - s).abs() < 1e-9);
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::seeded(4);
        let a = Matrix::randn(33, 47, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Rng::seeded(5);
        let a = Matrix::randn(6, 4, &mut rng);
        let v = vec![1.0, -2.0, 3.0, 0.5];
        let vm = Matrix::from_vec(4, 1, v.clone());
        let via_matmul = a.matmul(&vm);
        let via_matvec = a.matvec(&v);
        for i in 0..6 {
            assert!((via_matmul[(i, 0)] - via_matvec[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn matvec_t_matches_transpose() {
        let mut rng = Rng::seeded(6);
        let a = Matrix::randn(6, 4, &mut rng);
        let v = vec![1.0, -1.0, 2.0, 0.25, 3.0, -0.5];
        let direct = a.matvec_t(&v);
        let via_t = a.transpose().matvec(&v);
        for (x, y) in direct.iter().zip(&via_t) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn row_sums_and_scaling() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(a.row_sums(), vec![3.0, 7.0]);
        let scaled = a.scale_rows(&[2.0, 0.5]);
        assert_eq!(scaled.data(), &[2.0, 4.0, 1.5, 2.0]);
        let cscaled = a.scale_cols(&[10.0, 1.0]);
        assert_eq!(cscaled.data(), &[10.0, 2.0, 30.0, 4.0]);
    }

    #[test]
    fn tril_triu_partition() {
        let mut rng = Rng::seeded(7);
        let a = Matrix::randn(8, 8, &mut rng);
        let recon = a.tril().add(&a.triu_strict());
        assert_eq!(recon, a);
    }

    #[test]
    fn push_row_grows() {
        let mut m = Matrix::zeros(0, 3);
        m.push_row(&[1.0, 2.0, 3.0]);
        m.push_row(&[4.0, 5.0, 6.0]);
        assert_eq!(m.shape(), (2, 3));
        assert_eq!(m.row(1), &[4.0, 5.0, 6.0]);
    }

    #[test]
    fn truncate_rows_inverts_push_row() {
        let mut rng = Rng::seeded(8);
        let base = Matrix::randn(5, 3, &mut rng);
        let mut grown = base.clone();
        grown.push_row(&[1.0, 2.0, 3.0]);
        grown.push_row(&[4.0, 5.0, 6.0]);
        grown.truncate_rows(5);
        assert_eq!(grown, base, "truncate must be bitwise push_row inverse");
        grown.truncate_rows(0);
        assert_eq!(grown.shape(), (0, 3));
    }

    #[test]
    fn slice_extracts() {
        let a = Matrix::from_fn(4, 4, |i, j| (i * 4 + j) as f64);
        let s = a.slice(1, 3, 2, 4);
        assert_eq!(s.data(), &[6.0, 7.0, 10.0, 11.0]);
    }

    #[test]
    fn f32_roundtrip() {
        let a = Matrix::from_vec(1, 3, vec![1.5, -2.25, 0.0]);
        let f = a.to_f32();
        let back = Matrix::from_f32(1, 3, &f);
        assert_eq!(back, a);
    }
}
