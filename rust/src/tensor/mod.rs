//! Dense row-major matrix / vector micro-BLAS.
//!
//! Everything in this crate is written against this module — there is no
//! external linear-algebra dependency. The core scalar type is `f64`
//! (the recovery algorithm subtracts accumulated basis vectors, so we
//! keep full precision in the algorithm core); the PJRT interop layer in
//! [`crate::runtime`] converts to/from `f32` at the boundary.

mod matrix;
mod norms;
mod rng;

pub use matrix::Matrix;
pub use norms::{
    fro_norm, l1_norm_mat, l1_norm_vec, linf_norm_mat, linf_norm_vec, max_abs_diff, rel_fro_error,
};
pub use rng::Rng;

/// A dense vector. We use plain `Vec<f64>` with free functions rather
/// than a newtype: the algorithms index heavily and the paper's notation
/// maps naturally onto slices.
pub type Vector = Vec<f64>;

/// Dot product of two equal-length slices.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulation: measurably faster than the naive loop
    // on the recovery hot path and deterministic across runs.
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for j in chunks * 4..n {
        s += a[j] * b[j];
    }
    s
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * xi;
    }
}

/// Element-wise `exp` of a slice into a new vector.
#[inline]
pub fn exp_vec(x: &[f64]) -> Vector {
    x.iter().map(|v| v.exp()).collect()
}

/// Element-wise difference `a - b`.
#[inline]
pub fn sub_vec(a: &[f64], b: &[f64]) -> Vector {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b.iter()).map(|(x, y)| x - y).collect()
}

/// Element-wise sum `a + b`.
#[inline]
pub fn add_vec(a: &[f64], b: &[f64]) -> Vector {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b.iter()).map(|(x, y)| x + y).collect()
}

/// Softmax over a slice (numerically stabilized).
pub fn softmax(x: &[f64]) -> Vector {
    let m = x.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let e: Vector = x.iter().map(|v| (v - m).exp()).collect();
    let s: f64 = e.iter().sum();
    e.into_iter().map(|v| v / s).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f64> = (0..13).map(|i| i as f64 * 0.5).collect();
        let b: Vec<f64> = (0..13).map(|i| (i as f64).sin()).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-12);
    }

    #[test]
    fn axpy_works() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 24.0, 36.0]);
    }

    #[test]
    fn softmax_sums_to_one() {
        let s = softmax(&[1.0, 2.0, 3.0, -100.0]);
        let total: f64 = s.iter().sum();
        assert!((total - 1.0).abs() < 1e-12);
        assert!(s[2] > s[1] && s[1] > s[0]);
    }

    #[test]
    fn softmax_handles_large_values() {
        let s = softmax(&[1000.0, 1000.0]);
        assert!((s[0] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn exp_sub_add_vec() {
        let a = vec![0.0, 1.0];
        let e = exp_vec(&a);
        assert!((e[1] - std::f64::consts::E).abs() < 1e-12);
        assert_eq!(sub_vec(&[3.0], &[1.0]), vec![2.0]);
        assert_eq!(add_vec(&[3.0], &[1.0]), vec![4.0]);
    }
}
