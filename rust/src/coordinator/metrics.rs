//! Lock-free serving metrics: counters on atomics, latency samples in
//! bounded per-series reservoirs behind mutexes (recording is off the
//! execution hot loop).
//!
//! # Bounded latency memory
//!
//! Each latency series is a **reservoir** of at most
//! [`LATENCY_RESERVOIR_CAP`] samples (Algorithm R: once full, the
//! `i`-th observation replaces a uniformly random resident slot with
//! probability `cap/i`). A long-lived server therefore holds `O(1)`
//! latency memory per series regardless of request count, and
//! `snapshot()`'s percentile sort is `O(cap·log cap)`, not
//! `O(total·log total)`. `count`, `mean_us` and `max_us` stay **exact**
//! (running total/sum/max); the percentiles are estimates over the
//! uniform sample once `count > cap` — unbiased, and below the cap the
//! reservoir is the full series, so small-run tests see exact values.
//! Replacement uses a fixed-seed xorshift so identical recording
//! sequences produce identical snapshots (determinism contract).

use crate::sync::atomic::{AtomicU64, Ordering};
use crate::sync::{lock, Mutex};
use std::collections::BTreeMap;
use std::time::Duration;

/// Max resident samples per latency series (see module docs).
pub const LATENCY_RESERVOIR_CAP: usize = 4096;

/// EMA smoothing for [`HeadProfile::err_ema`] — a pinned constant
/// (never tuned at runtime), part of the router determinism contract.
pub const HEAD_ERR_EMA_ALPHA: f64 = 0.125;

/// Quantum for the order-independent recovery-error aggregate: errors
/// are accumulated as integer multiples of `1e-9` (saturating), so the
/// per-head mean is identical regardless of the order concurrent
/// workers recorded observations in — integer addition commutes where
/// float addition does not. `RouterPolicy::from_profile` thresholds
/// against this mean, never the (order-sensitive) EMA.
pub const HEAD_ERR_QUANTUM: f64 = 1e-9;

/// Which operator family served a (layer, head) prefill job — the
/// profile's latency buckets (and the router's decision counters) key
/// on this.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RouteKind {
    Exact,
    Conv,
    LowRank,
}

/// Measured per-(layer, head) serving profile: the inputs a
/// profile-driven `RouterPolicy` thresholds against, plus
/// observability extras.
///
/// Determinism note: routing decisions may depend only on the
/// **order-independent** aggregates — `fallback_rate()` (integer
/// counters) and `mean_recovery_err()` (integer-quantized sum) — so a
/// profile fed by any worker count yields the same decision table.
/// `err_ema` (sequential EMA) and the per-backend latency totals are
/// observability views: the EMA depends on observation order and the
/// latencies on wall clock, so neither may feed a routing decision
/// (the PR-8 lint forbids wall-clock in kernel paths for exactly this
/// reason).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct HeadProfile {
    /// Serving prefill jobs observed for this head.
    pub jobs: u64,
    /// Jobs whose conv path fell back to exact.
    pub fallbacks: u64,
    /// Recovery-error EMA (α = [`HEAD_ERR_EMA_ALPHA`]) — dashboard
    /// view; order-sensitive, never a decision input.
    pub err_ema: f64,
    /// Recovery-error sum in [`HEAD_ERR_QUANTUM`] units (saturating) —
    /// the order-independent aggregate decisions use.
    pub err_quanta: u64,
    /// Recovery-error observations recorded.
    pub err_samples: u64,
    /// Per-backend wall-time totals (ns) and job counts — latency
    /// observability only (see the determinism note above).
    pub exact_ns: u64,
    pub exact_jobs: u64,
    pub conv_ns: u64,
    pub conv_jobs: u64,
    pub lowrank_ns: u64,
    pub lowrank_jobs: u64,
}

impl HeadProfile {
    /// Fraction of this head's jobs whose conv recovery fell back to
    /// exact (0.0 when nothing ran). Order-independent.
    pub fn fallback_rate(&self) -> f64 {
        if self.jobs == 0 {
            0.0
        } else {
            self.fallbacks as f64 / self.jobs as f64
        }
    }

    /// Mean recovery error over the recorded observations, from the
    /// integer-quantized sum (order-independent; resolution
    /// [`HEAD_ERR_QUANTUM`]). 0.0 when no observation was recorded.
    pub fn mean_recovery_err(&self) -> f64 {
        if self.err_samples == 0 {
            0.0
        } else {
            (self.err_quanta as f64 * HEAD_ERR_QUANTUM) / self.err_samples as f64
        }
    }

    /// Mean execution wall time (µs) for one backend bucket
    /// (observability only).
    pub fn mean_exec_us(&self, kind: RouteKind) -> f64 {
        let (ns, jobs) = match kind {
            RouteKind::Exact => (self.exact_ns, self.exact_jobs),
            RouteKind::Conv => (self.conv_ns, self.conv_jobs),
            RouteKind::LowRank => (self.lowrank_ns, self.lowrank_jobs),
        };
        if jobs == 0 {
            0.0
        } else {
            ns as f64 / jobs as f64 / 1e3
        }
    }
}

/// Latency summary (microseconds).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LatencyStats {
    pub count: usize,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub max_us: f64,
}

/// Bounded latency series: Algorithm R reservoir with exact running
/// count/sum/max and a deterministic (fixed-seed xorshift64*)
/// replacement stream.
#[derive(Debug)]
struct Reservoir {
    /// Total observations ever recorded (exact).
    seen: u64,
    /// Running sum of every observation (exact mean).
    sum: f64,
    /// Running max of every observation (exact).
    max: f64,
    /// The resident sample, `len() ≤ LATENCY_RESERVOIR_CAP`.
    samples: Vec<f64>,
    /// xorshift64* state — fixed seed, so two identically-fed
    /// reservoirs hold identical samples.
    rng: u64,
}

impl Default for Reservoir {
    fn default() -> Self {
        Reservoir { seen: 0, sum: 0.0, max: 0.0, samples: Vec::new(), rng: 0x9e3779b97f4a7c15 }
    }
}

impl Reservoir {
    fn record(&mut self, x: f64) {
        self.seen += 1;
        self.sum += x;
        if x > self.max {
            self.max = x;
        }
        if self.samples.len() < LATENCY_RESERVOIR_CAP {
            self.samples.push(x);
        } else {
            // Algorithm R: keep x with probability cap/seen, in a
            // uniformly random resident slot.
            self.rng ^= self.rng << 13;
            self.rng ^= self.rng >> 7;
            self.rng ^= self.rng << 17;
            let j = (self.rng.wrapping_mul(0x2545f4914f6cdd1d) % self.seen) as usize;
            if j < LATENCY_RESERVOIR_CAP {
                self.samples[j] = x;
            }
        }
    }

    fn summarize(&self) -> LatencyStats {
        if self.seen == 0 {
            return LatencyStats::default();
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.total_cmp(b));
        let resident = s.len();
        let pick = |q: f64| s[((q * (resident - 1) as f64).round() as usize).min(resident - 1)];
        LatencyStats {
            count: self.seen as usize,
            mean_us: self.sum / self.seen as f64,
            p50_us: pick(0.50),
            p95_us: pick(0.95),
            p99_us: pick(0.99),
            max_us: self.max,
        }
    }
}

/// Shared metrics sink.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests_submitted: AtomicU64,
    pub requests_completed: AtomicU64,
    pub batches_executed: AtomicU64,
    pub conv_requests: AtomicU64,
    pub exact_requests: AtomicU64,
    pub lowrank_requests: AtomicU64,
    pub fallbacks: AtomicU64,
    pub cache_hits: AtomicU64,
    pub cache_misses: AtomicU64,
    /// Unified-door engine calls (one per [`BatchedEngine::submit`]).
    ///
    /// [`BatchedEngine::submit`]: crate::attention::batched::BatchedEngine::submit
    pub submit_calls: AtomicU64,
    /// Engine calls that carried ≥ 1 prefill job (one per `submit`
    /// with a prefill lane).
    pub batched_calls: AtomicU64,
    /// Total (sequence, head) prefill jobs executed by the engine.
    pub batched_jobs: AtomicU64,
    /// Engine calls that carried ≥ 1 decode job.
    pub decode_calls: AtomicU64,
    /// Total (sequence, layer, head) decode jobs executed.
    pub decode_steps: AtomicU64,
    /// Decode states seeded straight from a `BasisCache` hit (the
    /// prefill recovered the basis; decode reuses it for free).
    pub decode_seed_hits: AtomicU64,
    /// Decode states that had to recover a basis at seed time.
    pub decode_seed_misses: AtomicU64,
    /// Drift-triggered basis re-recoveries during decode.
    pub decode_rerecoveries: AtomicU64,
    /// Conv decode jobs that fell back to the exact last-row kernel
    /// (degenerate normalizer after growth/re-recovery).
    pub decode_fallbacks: AtomicU64,
    /// Engine calls that carried ≥ 1 gradient job.
    pub grad_calls: AtomicU64,
    /// Total gradient jobs executed by the engine.
    pub grad_jobs: AtomicU64,
    /// Gradient jobs whose fast path failed (recovery error or
    /// degenerate normalizer) and were served by the dense
    /// `grad_naive` oracle instead.
    pub grad_fallbacks: AtomicU64,
    /// Gradient jobs whose `f`-operator basis came from the shared
    /// `BasisCache` (also counted in the engine-wide `cache_hits`;
    /// these lane-local counters keep the training dashboard honest
    /// when one engine serves inference and training together).
    pub grad_cache_hits: AtomicU64,
    /// Gradient jobs that recovered their operator fresh.
    pub grad_cache_misses: AtomicU64,
    /// Engine calls that carried ≥ 1 LM-backward (`AttnBackwardJob`)
    /// job — `Transformer::backward_batch_with_engine` issues one per
    /// layer per backward pass.
    pub lm_backward_calls: AtomicU64,
    /// Total (sequence, layer, head) LM-backward jobs executed.
    pub lm_backward_jobs: AtomicU64,
    /// Fast-path LM-backward jobs whose recovery failed and that were
    /// served by the dense exact kernel instead (also counted in
    /// `grad_fallbacks` — the gradient lane's shared alarm counter —
    /// so "recovery failed during training" is one number to watch).
    pub lm_backward_fallbacks: AtomicU64,
    /// Fast-path LM-backward jobs whose `f`-operator basis came from
    /// the shared `BasisCache` (the forward's conv prefill recovered
    /// it; backward reuses it for free).
    pub lm_backward_cache_hits: AtomicU64,
    /// Fast-path LM-backward jobs that recovered their operator fresh.
    pub lm_backward_cache_misses: AtomicU64,
    /// Engine submits that carried ≥ 1 conv-backend **training-forward**
    /// prefill job (`Transformer::forward_train_batch` in
    /// `TrainAttentionMode::Conv` issues one per layer per optimizer
    /// step, spanning the whole micro-batch).
    pub train_fwd_conv_calls: AtomicU64,
    /// Total conv-backend training-forward prefill jobs executed.
    pub train_fwd_conv_jobs: AtomicU64,
    /// Training-forward conv jobs whose recovery failed and that were
    /// served by the exact kernel instead — **bit-equal** to the exact
    /// training forward (the fallback replays the training softmax
    /// helper), so a fallback degrades cost, never the curve. Also
    /// counted in the engine-wide `fallbacks`.
    pub train_fwd_fallbacks: AtomicU64,
    /// Fresh basis recoveries performed by training-forward conv jobs —
    /// the *recoveries-per-step* number. Conv training recovers each
    /// (record, layer, head) operator exactly **once** per optimizer
    /// step (the backward consumes the forward's handle instead of
    /// re-recovering), so over a step this advances by
    /// `batch × layers × heads` minus fallbacks, never 2×.
    pub step_recoveries: AtomicU64,
    /// Fast LM-backward jobs served by a **step-scoped basis handle**
    /// the training forward recovered (`AttnBackwardJob::basis`) — the
    /// forward→backward handoff: one recovery, two consumers, zero
    /// serving-cache traffic.
    pub step_basis_hits: AtomicU64,
    /// Cache-less fast LM-backward jobs that had **no** forward handle
    /// to consume (the forward ran exact, or its recovery fell back) and
    /// had to build their operator themselves.
    pub step_basis_misses: AtomicU64,
    /// Generation requests admitted by the server's decode scheduler.
    pub gen_requests: AtomicU64,
    /// Generation requests completed (response sent). Rejected requests
    /// are **not** counted here — see `gen_rejected`.
    pub gen_completed: AtomicU64,
    /// Generation requests rejected at the door (empty prompt or prompt
    /// ≥ `max_seq`). Kept out of `gen_completed` and the `gen_e2e`
    /// latency series so completion throughput and latency percentiles
    /// describe real generations only.
    pub gen_rejected: AtomicU64,
    /// Tokens emitted across all generation requests.
    pub gen_tokens: AtomicU64,
    /// Generation requests cancelled (queued or in-flight) before
    /// completion. Kept out of `gen_completed` and the `gen_e2e`
    /// latency series, like rejections — a cancelled generation is not
    /// a served one.
    pub gen_cancelled: AtomicU64,
    /// Speculation rounds executed: one per in-flight sequence per
    /// draft→verify→accept cycle. Every round emits at least one token
    /// (the verifier's bonus token), so `gen_tokens` advances by ≥
    /// `spec_rounds` across the speculative path — the no-livelock
    /// invariant `tests/speculative.rs` pins.
    pub spec_rounds: AtomicU64,
    /// Tokens drafted through the cheap decode path by speculation
    /// rounds (γ_eff per round — the clamped per-round draft length).
    pub spec_drafted: AtomicU64,
    /// Drafted tokens the exact verifier accepted. The acceptance rate
    /// `spec_accepted / spec_drafted` is the speculation dashboard's
    /// headline number: 1.0 means every drafted token was emitted
    /// for free, 0.0 means the draft model never agreed with the
    /// verifier (the output is bit-exact either way — only throughput
    /// rides on this).
    pub spec_accepted: AtomicU64,
    /// Requests the admission queue refused because it was full (the
    /// caller got an explicit busy response, never a silent drop).
    pub shed_requests: AtomicU64,
    /// Gauge: generation requests currently waiting in the admission
    /// queue (raised on enqueue, lowered on admit/shed-drain).
    pub queue_depth: AtomicU64,
    /// Non-generation attention requests served by the generation
    /// scheduler's lane (merged into a decode submit or executed
    /// standalone between decode steps) instead of a server worker.
    pub gen_lane_attn_requests: AtomicU64,
    /// Subset of `gen_lane_attn_requests` that rode an in-flight decode
    /// step's engine submit (true continuous batching across op kinds).
    pub merged_attn_requests: AtomicU64,
    /// Gauge: bytes resident in live `DecodeSession` KV caches + conv
    /// decode states. Raised by `Transformer::{prefill_batch,
    /// decode_step}`, lowered by `DecodeSession::retire`.
    pub decode_resident_bytes: AtomicU64,
    /// Prefill jobs that entered the engine with the `Routed` backend
    /// (the per-(layer, head) policy mode). Each also lands in exactly
    /// one of the `router_*_routes` decision counters below, plus the
    /// per-backend request counter of whatever operator actually ran.
    pub routed_jobs: AtomicU64,
    /// Routed jobs resolved to the exact operator.
    pub router_exact_routes: AtomicU64,
    /// Routed jobs resolved to a conv operator (adaptive or strided).
    pub router_conv_routes: AtomicU64,
    /// Routed jobs resolved to the low-rank operator.
    pub router_lowrank_routes: AtomicU64,
    /// Low-rank routes refused at job time because the feature rank
    /// `C(d+g, g)` was ≥ the sequence length (low-rank is a strict
    /// loss there) — rerouted to the policy's conv fallback. Counted
    /// *in addition to* the decision counter of the fallback route.
    pub router_rank_refusals: AtomicU64,
    /// Low-rank-preferring (layer, head) routes pinned to the exact
    /// kernel for a decode-bound session: low-rank cannot seed a
    /// `DecodeState` (no conv structure to append to), so
    /// `AttentionBackend::Routed` decodes exact and counts each pinned
    /// (session, layer, head) here. The decode seed-hit invariants
    /// survive routing because of exactly this pin.
    pub router_decode_pins: AtomicU64,
    queue_lat: Mutex<Reservoir>,
    exec_lat: Mutex<Reservoir>,
    e2e_lat: Mutex<Reservoir>,
    decode_lat: Mutex<Reservoir>,
    gen_lat: Mutex<Reservoir>,
    grad_lat: Mutex<Reservoir>,
    lm_backward_lat: Mutex<Reservoir>,
    /// Per-(layer, head) serving aggregation ([`HeadProfile`]) — the
    /// measured inputs a profile-driven `RouterPolicy` is built from.
    /// A `BTreeMap` (not a `HashMap`): iteration order is part of the
    /// determinism contract — `RouterPolicy::from_profile` walks it to
    /// build the decision table, and the hash-iter lint forbids
    /// nondeterministic-iteration maps on decision-feeding paths.
    head_profiles: Mutex<BTreeMap<(u32, u32), HeadProfile>>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    #[inline]
    pub fn incr(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(counter: &AtomicU64, n: u64) {
        counter.fetch_add(n, Ordering::Relaxed);
    }

    /// Lower a gauge (e.g. `decode_resident_bytes` on session retire).
    #[inline]
    pub fn sub(counter: &AtomicU64, n: u64) {
        counter.fetch_sub(n, Ordering::Relaxed);
    }

    pub fn record_queue(&self, d: Duration) {
        lock(&self.queue_lat).record(d.as_secs_f64() * 1e6);
    }

    pub fn record_exec(&self, d: Duration) {
        lock(&self.exec_lat).record(d.as_secs_f64() * 1e6);
    }

    pub fn record_e2e(&self, d: Duration) {
        lock(&self.e2e_lat).record(d.as_secs_f64() * 1e6);
    }

    /// Per-job decode-step execution time (kept separate from the
    /// prefill `exec` series so the two latency regimes don't mix).
    pub fn record_decode(&self, d: Duration) {
        lock(&self.decode_lat).record(d.as_secs_f64() * 1e6);
    }

    /// Whole-generation end-to-end time (submit → response, all
    /// tokens). Its own series for the same reason: one multi-token
    /// generation is orders of magnitude above one attention request,
    /// and mixing them would corrupt the e2e percentiles.
    pub fn record_gen_e2e(&self, d: Duration) {
        lock(&self.gen_lat).record(d.as_secs_f64() * 1e6);
    }

    /// Per-job gradient execution time (its own series — one gradient
    /// job is `O(k·n·d²·log n)`, far above a prefill job, and mixing
    /// the regimes would corrupt the exec percentiles).
    pub fn record_grad(&self, d: Duration) {
        lock(&self.grad_lat).record(d.as_secs_f64() * 1e6);
    }

    /// Per-job LM-backward execution time (its own series — an
    /// attention backward is a different cost regime from both a
    /// prefill job and a Definition 5.1 gradient job).
    pub fn record_lm_backward(&self, d: Duration) {
        lock(&self.lm_backward_lat).record(d.as_secs_f64() * 1e6);
    }

    /// Record one serving prefill job into its (layer, head) profile:
    /// which operator family served it, whether the conv path fell
    /// back, and its worker wall time (latency observability only —
    /// see the [`HeadProfile`] determinism note). The engine calls
    /// this once per serving prefill job.
    pub fn record_head_job(
        &self,
        layer: u32,
        head: u32,
        kind: RouteKind,
        fell_back: bool,
        exec: Duration,
    ) {
        let ns = u64::try_from(exec.as_nanos()).unwrap_or(u64::MAX);
        let mut map = lock(&self.head_profiles);
        let p = map.entry((layer, head)).or_default();
        p.jobs += 1;
        if fell_back {
            p.fallbacks += 1;
        }
        match kind {
            RouteKind::Exact => {
                p.exact_jobs += 1;
                p.exact_ns = p.exact_ns.saturating_add(ns);
            }
            RouteKind::Conv => {
                p.conv_jobs += 1;
                p.conv_ns = p.conv_ns.saturating_add(ns);
            }
            RouteKind::LowRank => {
                p.lowrank_jobs += 1;
                p.lowrank_ns = p.lowrank_ns.saturating_add(ns);
            }
        }
    }

    /// Record one measured recovery error for a (layer, head) — the
    /// calibration feed: true recovery error needs the exact oracle
    /// next to the approximation, so a profiling pass (run both, diff)
    /// records it here; the serving hot path never computes it. Both
    /// aggregates advance: the EMA (dashboard) and the
    /// order-independent quantized sum (what
    /// `RouterPolicy::from_profile` thresholds against).
    pub fn record_head_recovery_err(&self, layer: u32, head: u32, err: f64) {
        let err = err.max(0.0);
        let mut map = lock(&self.head_profiles);
        let p = map.entry((layer, head)).or_default();
        p.err_ema = if p.err_samples == 0 {
            err
        } else {
            HEAD_ERR_EMA_ALPHA * err + (1.0 - HEAD_ERR_EMA_ALPHA) * p.err_ema
        };
        let quanta = (err / HEAD_ERR_QUANTUM).round();
        let quanta = if quanta >= u64::MAX as f64 { u64::MAX } else { quanta as u64 };
        p.err_quanta = p.err_quanta.saturating_add(quanta);
        p.err_samples += 1;
    }

    /// Point-in-time copy of every (layer, head) profile, in
    /// deterministic (layer, head) order.
    pub fn head_profiles(&self) -> BTreeMap<(u32, u32), HeadProfile> {
        lock(&self.head_profiles).clone()
    }

    /// Resident sample count of the e2e series (reservoir bound proof
    /// for tests; the exact observation count lives in the snapshot).
    #[cfg(test)]
    fn e2e_resident_samples(&self) -> usize {
        lock(&self.e2e_lat).samples.len()
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            requests_submitted: self.requests_submitted.load(Ordering::Relaxed),
            requests_completed: self.requests_completed.load(Ordering::Relaxed),
            batches_executed: self.batches_executed.load(Ordering::Relaxed),
            conv_requests: self.conv_requests.load(Ordering::Relaxed),
            exact_requests: self.exact_requests.load(Ordering::Relaxed),
            lowrank_requests: self.lowrank_requests.load(Ordering::Relaxed),
            fallbacks: self.fallbacks.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.cache_misses.load(Ordering::Relaxed),
            submit_calls: self.submit_calls.load(Ordering::Relaxed),
            batched_calls: self.batched_calls.load(Ordering::Relaxed),
            batched_jobs: self.batched_jobs.load(Ordering::Relaxed),
            decode_calls: self.decode_calls.load(Ordering::Relaxed),
            decode_steps: self.decode_steps.load(Ordering::Relaxed),
            decode_seed_hits: self.decode_seed_hits.load(Ordering::Relaxed),
            decode_seed_misses: self.decode_seed_misses.load(Ordering::Relaxed),
            decode_rerecoveries: self.decode_rerecoveries.load(Ordering::Relaxed),
            decode_fallbacks: self.decode_fallbacks.load(Ordering::Relaxed),
            grad_calls: self.grad_calls.load(Ordering::Relaxed),
            grad_jobs: self.grad_jobs.load(Ordering::Relaxed),
            grad_fallbacks: self.grad_fallbacks.load(Ordering::Relaxed),
            grad_cache_hits: self.grad_cache_hits.load(Ordering::Relaxed),
            grad_cache_misses: self.grad_cache_misses.load(Ordering::Relaxed),
            lm_backward_calls: self.lm_backward_calls.load(Ordering::Relaxed),
            lm_backward_jobs: self.lm_backward_jobs.load(Ordering::Relaxed),
            lm_backward_fallbacks: self.lm_backward_fallbacks.load(Ordering::Relaxed),
            lm_backward_cache_hits: self.lm_backward_cache_hits.load(Ordering::Relaxed),
            lm_backward_cache_misses: self.lm_backward_cache_misses.load(Ordering::Relaxed),
            train_fwd_conv_calls: self.train_fwd_conv_calls.load(Ordering::Relaxed),
            train_fwd_conv_jobs: self.train_fwd_conv_jobs.load(Ordering::Relaxed),
            train_fwd_fallbacks: self.train_fwd_fallbacks.load(Ordering::Relaxed),
            step_recoveries: self.step_recoveries.load(Ordering::Relaxed),
            step_basis_hits: self.step_basis_hits.load(Ordering::Relaxed),
            step_basis_misses: self.step_basis_misses.load(Ordering::Relaxed),
            gen_requests: self.gen_requests.load(Ordering::Relaxed),
            gen_completed: self.gen_completed.load(Ordering::Relaxed),
            gen_rejected: self.gen_rejected.load(Ordering::Relaxed),
            gen_tokens: self.gen_tokens.load(Ordering::Relaxed),
            gen_cancelled: self.gen_cancelled.load(Ordering::Relaxed),
            spec_rounds: self.spec_rounds.load(Ordering::Relaxed),
            spec_drafted: self.spec_drafted.load(Ordering::Relaxed),
            spec_accepted: self.spec_accepted.load(Ordering::Relaxed),
            shed_requests: self.shed_requests.load(Ordering::Relaxed),
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            gen_lane_attn_requests: self.gen_lane_attn_requests.load(Ordering::Relaxed),
            merged_attn_requests: self.merged_attn_requests.load(Ordering::Relaxed),
            decode_resident_bytes: self.decode_resident_bytes.load(Ordering::Relaxed),
            routed_jobs: self.routed_jobs.load(Ordering::Relaxed),
            router_exact_routes: self.router_exact_routes.load(Ordering::Relaxed),
            router_conv_routes: self.router_conv_routes.load(Ordering::Relaxed),
            router_lowrank_routes: self.router_lowrank_routes.load(Ordering::Relaxed),
            router_rank_refusals: self.router_rank_refusals.load(Ordering::Relaxed),
            router_decode_pins: self.router_decode_pins.load(Ordering::Relaxed),
            queue: lock(&self.queue_lat).summarize(),
            exec: lock(&self.exec_lat).summarize(),
            e2e: lock(&self.e2e_lat).summarize(),
            decode: lock(&self.decode_lat).summarize(),
            gen_e2e: lock(&self.gen_lat).summarize(),
            grad: lock(&self.grad_lat).summarize(),
            lm_backward: lock(&self.lm_backward_lat).summarize(),
        }
    }
}

/// Point-in-time metrics view.
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    pub requests_submitted: u64,
    pub requests_completed: u64,
    pub batches_executed: u64,
    pub conv_requests: u64,
    pub exact_requests: u64,
    pub lowrank_requests: u64,
    pub fallbacks: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub submit_calls: u64,
    pub batched_calls: u64,
    pub batched_jobs: u64,
    pub decode_calls: u64,
    pub decode_steps: u64,
    pub decode_seed_hits: u64,
    pub decode_seed_misses: u64,
    pub decode_rerecoveries: u64,
    pub decode_fallbacks: u64,
    pub grad_calls: u64,
    pub grad_jobs: u64,
    pub grad_fallbacks: u64,
    pub grad_cache_hits: u64,
    pub grad_cache_misses: u64,
    pub lm_backward_calls: u64,
    pub lm_backward_jobs: u64,
    pub lm_backward_fallbacks: u64,
    pub lm_backward_cache_hits: u64,
    pub lm_backward_cache_misses: u64,
    pub train_fwd_conv_calls: u64,
    pub train_fwd_conv_jobs: u64,
    pub train_fwd_fallbacks: u64,
    pub step_recoveries: u64,
    pub step_basis_hits: u64,
    pub step_basis_misses: u64,
    pub gen_requests: u64,
    pub gen_completed: u64,
    pub gen_rejected: u64,
    pub gen_tokens: u64,
    pub gen_cancelled: u64,
    pub spec_rounds: u64,
    pub spec_drafted: u64,
    pub spec_accepted: u64,
    pub shed_requests: u64,
    pub queue_depth: u64,
    pub gen_lane_attn_requests: u64,
    pub merged_attn_requests: u64,
    pub decode_resident_bytes: u64,
    pub routed_jobs: u64,
    pub router_exact_routes: u64,
    pub router_conv_routes: u64,
    pub router_lowrank_routes: u64,
    pub router_rank_refusals: u64,
    pub router_decode_pins: u64,
    pub queue: LatencyStats,
    pub exec: LatencyStats,
    pub e2e: LatencyStats,
    pub decode: LatencyStats,
    pub gen_e2e: LatencyStats,
    pub grad: LatencyStats,
    pub lm_backward: LatencyStats,
}

impl MetricsSnapshot {
    /// Render a compact report (used by the serve example and benches).
    pub fn report(&self) -> String {
        format!(
            "requests: {} submitted / {} completed | batches: {} | \
             backends: conv={} exact={} lowrank={} fallbacks={} | \
             cache: {}h/{}m | engine: {} calls/{} jobs | \
             e2e p50={:.0}µs p95={:.0}µs p99={:.0}µs max={:.0}µs | \
             exec mean={:.0}µs | queue mean={:.0}µs",
            self.requests_submitted,
            self.requests_completed,
            self.batches_executed,
            self.conv_requests,
            self.exact_requests,
            self.lowrank_requests,
            self.fallbacks,
            self.cache_hits,
            self.cache_misses,
            self.batched_calls,
            self.batched_jobs,
            self.e2e.p50_us,
            self.e2e.p95_us,
            self.e2e.p99_us,
            self.e2e.max_us,
            self.exec.mean_us,
            self.queue.mean_us,
        )
    }

    /// Render the decode/generation counters (the autoregressive path's
    /// dashboard line — seed hits say how often prefill bases were
    /// reused, re-recoveries how often drift forced a fresh recovery).
    pub fn decode_report(&self) -> String {
        format!(
            "generation: {} requests / {} completed / {} rejected / {} cancelled / {} tokens | \
             admission: {} shed, {} queued | \
             decode: {} calls/{} steps | seeds: {}h/{}m | \
             drift re-recoveries: {} | fallbacks: {} | \
             kv resident: {} B | merged attn: {} (lane {}) | \
             step exec mean={:.0}µs p95={:.0}µs | gen e2e p50={:.0}µs p95={:.0}µs",
            self.gen_requests,
            self.gen_completed,
            self.gen_rejected,
            self.gen_cancelled,
            self.gen_tokens,
            self.shed_requests,
            self.queue_depth,
            self.decode_calls,
            self.decode_steps,
            self.decode_seed_hits,
            self.decode_seed_misses,
            self.decode_rerecoveries,
            self.decode_fallbacks,
            self.decode_resident_bytes,
            self.merged_attn_requests,
            self.gen_lane_attn_requests,
            self.decode.mean_us,
            self.decode.p95_us,
            self.gen_e2e.p50_us,
            self.gen_e2e.p95_us,
        )
    }

    /// Drafted-token acceptance rate of the speculative decoder
    /// (`spec_accepted / spec_drafted`; 0.0 before any drafting). The
    /// emitted stream is bit-exact at every rate — this number prices
    /// the draft model, it never prices correctness.
    pub fn spec_acceptance_rate(&self) -> f64 {
        if self.spec_drafted == 0 {
            0.0
        } else {
            self.spec_accepted as f64 / self.spec_drafted as f64
        }
    }

    /// Render the speculative-decoding counters (the draft/verify
    /// dashboard line): rounds, drafted and accepted tokens, and the
    /// acceptance rate that prices the draft model.
    pub fn spec_report(&self) -> String {
        format!(
            "speculation: {} rounds | drafted: {} | accepted: {} | acceptance rate: {:.3}",
            self.spec_rounds,
            self.spec_drafted,
            self.spec_accepted,
            self.spec_acceptance_rate(),
        )
    }

    /// Render the gradient-lane counters (the training dashboard
    /// line; the cache numbers are each lane's own, not the engine-wide
    /// totals a co-located serving workload would drown them in).
    pub fn grad_report(&self) -> String {
        format!(
            "gradient: {} calls/{} jobs | fallbacks: {} | cache: {}h/{}m | \
             job exec mean={:.0}µs p95={:.0}µs | \
             lm-backward: {} calls/{} jobs | fallbacks: {} | cache: {}h/{}m | \
             job exec mean={:.0}µs p95={:.0}µs",
            self.grad_calls,
            self.grad_jobs,
            self.grad_fallbacks,
            self.grad_cache_hits,
            self.grad_cache_misses,
            self.grad.mean_us,
            self.grad.p95_us,
            self.lm_backward_calls,
            self.lm_backward_jobs,
            self.lm_backward_fallbacks,
            self.lm_backward_cache_hits,
            self.lm_backward_cache_misses,
            self.lm_backward.mean_us,
            self.lm_backward.p95_us,
        )
    }

    /// Render the end-to-end conv-training counters (the
    /// forward→backward basis-sharing dashboard line): how many
    /// training-forward conv submits/jobs ran, how often recovery fell
    /// back to the exact kernel, and the single-recovery invariant —
    /// `step_recoveries` fresh recoveries, each consumed once by a
    /// backward (`step_basis_hits`); `step_basis_misses` counts
    /// backward jobs that had no handle to consume.
    pub fn train_report(&self) -> String {
        format!(
            "train-fwd conv: {} calls/{} jobs | fallbacks: {} | \
             step basis: {} recoveries, {}h/{}m",
            self.train_fwd_conv_calls,
            self.train_fwd_conv_jobs,
            self.train_fwd_fallbacks,
            self.step_recoveries,
            self.step_basis_hits,
            self.step_basis_misses,
        )
    }

    /// Render the per-(layer, head) router counters (the adaptive
    /// approximation dashboard line): how many prefill jobs went
    /// through the `Routed` mode, how the decisions split across the
    /// three operator families, and the two refusal guards — rank
    /// refusals (low-rank rerouted to conv because `C(d+g,g) ≥ n`) and
    /// decode pins (low-rank heads pinned to exact for decode-bound
    /// sessions). Deterministic routing means two identical runs
    /// render identical lines — `tests/router.rs` asserts exactly
    /// that.
    pub fn router_report(&self) -> String {
        format!(
            "router: {} routed jobs | routes: exact={} conv={} lowrank={} | \
             rank refusals: {} | decode pins: {}",
            self.routed_jobs,
            self.router_exact_routes,
            self.router_conv_routes,
            self.router_lowrank_routes,
            self.router_rank_refusals,
            self.router_decode_pins,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.record_e2e(Duration::from_micros(i));
        }
        let s = m.snapshot();
        assert_eq!(s.e2e.count, 100);
        assert!(s.e2e.p50_us <= s.e2e.p95_us);
        assert!(s.e2e.p95_us <= s.e2e.p99_us);
        assert!(s.e2e.p99_us <= s.e2e.max_us);
        assert_eq!(s.e2e.max_us, 100.0);
    }

    #[test]
    fn empty_latency_is_zero() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!(s.e2e, LatencyStats::default());
    }

    #[test]
    fn counters_relaxed() {
        let m = Metrics::new();
        Metrics::incr(&m.requests_submitted);
        Metrics::incr(&m.requests_submitted);
        assert_eq!(m.snapshot().requests_submitted, 2);
    }

    #[test]
    fn report_renders() {
        let m = Metrics::new();
        Metrics::incr(&m.conv_requests);
        let r = m.snapshot().report();
        assert!(r.contains("conv=1"));
    }

    #[test]
    fn gauge_add_sub_roundtrips() {
        let m = Metrics::new();
        Metrics::add(&m.decode_resident_bytes, 4096);
        Metrics::add(&m.decode_resident_bytes, 1024);
        Metrics::sub(&m.decode_resident_bytes, 4096);
        assert_eq!(m.snapshot().decode_resident_bytes, 1024);
        Metrics::sub(&m.decode_resident_bytes, 1024);
        assert_eq!(m.snapshot().decode_resident_bytes, 0);
    }

    #[test]
    fn grad_report_renders() {
        let m = Metrics::new();
        Metrics::incr(&m.grad_calls);
        Metrics::add(&m.grad_jobs, 8);
        m.record_grad(Duration::from_micros(25));
        let s = m.snapshot();
        assert_eq!(s.grad.count, 1);
        let r = s.grad_report();
        assert!(r.contains("1 calls/8 jobs"));
    }

    #[test]
    fn lm_backward_counters_and_report() {
        let m = Metrics::new();
        Metrics::incr(&m.lm_backward_calls);
        Metrics::add(&m.lm_backward_jobs, 4);
        Metrics::incr(&m.lm_backward_fallbacks);
        m.record_lm_backward(Duration::from_micros(12));
        let s = m.snapshot();
        assert_eq!((s.lm_backward_calls, s.lm_backward_jobs, s.lm_backward_fallbacks), (1, 4, 1));
        assert_eq!(s.lm_backward.count, 1);
        let r = s.grad_report();
        assert!(r.contains("lm-backward: 1 calls/4 jobs"));
    }

    #[test]
    fn train_counters_and_report() {
        let m = Metrics::new();
        Metrics::incr(&m.train_fwd_conv_calls);
        Metrics::add(&m.train_fwd_conv_jobs, 4);
        Metrics::add(&m.step_recoveries, 4);
        Metrics::add(&m.step_basis_hits, 4);
        Metrics::incr(&m.step_basis_misses);
        let s = m.snapshot();
        assert_eq!((s.train_fwd_conv_calls, s.train_fwd_conv_jobs), (1, 4));
        assert_eq!((s.step_recoveries, s.step_basis_hits, s.step_basis_misses), (4, 4, 1));
        assert_eq!(s.train_fwd_fallbacks, 0);
        let r = s.train_report();
        assert!(r.contains("1 calls/4 jobs"));
        assert!(r.contains("4 recoveries, 4h/1m"));
    }

    #[test]
    fn decode_report_renders() {
        let m = Metrics::new();
        Metrics::incr(&m.gen_requests);
        Metrics::incr(&m.decode_seed_hits);
        m.record_decode(Duration::from_micros(10));
        let s = m.snapshot();
        assert_eq!(s.decode.count, 1);
        let r = s.decode_report();
        assert!(r.contains("1 requests"));
        assert!(r.contains("seeds: 1h/0m"));
    }

    #[test]
    fn spec_counters_and_report() {
        let m = Metrics::new();
        Metrics::add(&m.spec_rounds, 3);
        Metrics::add(&m.spec_drafted, 12);
        Metrics::add(&m.spec_accepted, 9);
        Metrics::incr(&m.gen_cancelled);
        let s = m.snapshot();
        assert_eq!((s.spec_rounds, s.spec_drafted, s.spec_accepted), (3, 12, 9));
        assert_eq!(s.gen_cancelled, 1);
        assert!((s.spec_acceptance_rate() - 0.75).abs() < 1e-12);
        let r = s.spec_report();
        assert!(r.contains("3 rounds"));
        assert!(r.contains("acceptance rate: 0.750"));
        assert!(s.decode_report().contains("1 cancelled"));
    }

    #[test]
    fn spec_rate_is_zero_before_drafting() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.spec_acceptance_rate(), 0.0);
    }

    #[test]
    fn router_counters_and_report() {
        let m = Metrics::new();
        Metrics::add(&m.routed_jobs, 6);
        Metrics::add(&m.router_exact_routes, 2);
        Metrics::add(&m.router_conv_routes, 3);
        Metrics::incr(&m.router_lowrank_routes);
        Metrics::incr(&m.router_rank_refusals);
        Metrics::add(&m.router_decode_pins, 2);
        let s = m.snapshot();
        assert_eq!(s.routed_jobs, 6);
        assert_eq!(
            (s.router_exact_routes, s.router_conv_routes, s.router_lowrank_routes),
            (2, 3, 1)
        );
        assert_eq!((s.router_rank_refusals, s.router_decode_pins), (1, 2));
        let r = s.router_report();
        assert!(r.contains("6 routed jobs"));
        assert!(r.contains("exact=2 conv=3 lowrank=1"));
        assert!(r.contains("rank refusals: 1"));
        assert!(r.contains("decode pins: 2"));
    }

    #[test]
    fn head_profile_aggregates() {
        let m = Metrics::new();
        m.record_head_job(0, 1, RouteKind::Conv, false, Duration::from_micros(10));
        m.record_head_job(0, 1, RouteKind::Conv, true, Duration::from_micros(30));
        m.record_head_job(0, 1, RouteKind::Exact, false, Duration::from_micros(50));
        m.record_head_recovery_err(0, 1, 1e-3);
        m.record_head_recovery_err(0, 1, 3e-3);
        let profiles = m.head_profiles();
        let p = &profiles[&(0, 1)];
        assert_eq!((p.jobs, p.fallbacks), (3, 1));
        assert_eq!((p.conv_jobs, p.exact_jobs, p.lowrank_jobs), (2, 1, 0));
        assert!((p.fallback_rate() - 1.0 / 3.0).abs() < 1e-12);
        assert!((p.mean_recovery_err() - 2e-3).abs() < 1e-9);
        assert!(p.err_ema > 0.0);
        assert!((p.mean_exec_us(RouteKind::Conv) - 20.0).abs() < 1e-6);
        assert!((p.mean_exec_us(RouteKind::Exact) - 50.0).abs() < 1e-6);
        assert_eq!(p.mean_exec_us(RouteKind::LowRank), 0.0);
        // Untouched heads do not materialize.
        assert!(!profiles.contains_key(&(0, 0)));
    }

    // The decision-feeding error aggregate must be order-independent:
    // two profiles fed the same observations in different orders agree
    // exactly on `mean_recovery_err` (integer quanta commute), even
    // though the EMA — dashboard only — may differ.
    #[test]
    fn head_profile_mean_err_is_order_independent() {
        let errs = [1e-3, 5e-4, 7e-3, 2e-6, 9e-4];
        let (a, b) = (Metrics::new(), Metrics::new());
        for &e in &errs {
            a.record_head_recovery_err(0, 0, e);
        }
        for &e in errs.iter().rev() {
            b.record_head_recovery_err(0, 0, e);
        }
        let (pa, pb) = (a.head_profiles()[&(0, 0)].clone(), b.head_profiles()[&(0, 0)].clone());
        assert_eq!(pa.err_quanta, pb.err_quanta);
        assert_eq!(pa.mean_recovery_err(), pb.mean_recovery_err());
    }

    #[test]
    fn admission_counters_render() {
        let m = Metrics::new();
        Metrics::incr(&m.gen_rejected);
        Metrics::add(&m.shed_requests, 3);
        Metrics::add(&m.queue_depth, 2);
        let s = m.snapshot();
        assert_eq!((s.gen_rejected, s.shed_requests, s.queue_depth), (1, 3, 2));
        let r = s.decode_report();
        assert!(r.contains("1 rejected"));
        assert!(r.contains("admission: 3 shed, 2 queued"));
    }

    // Regression (unbounded latency memory): pre-reservoir, every
    // `record_*` pushed onto an ever-growing Vec, so a long-lived
    // server leaked a float per request forever. The reservoir must
    // hold at most LATENCY_RESERVOIR_CAP residents no matter how many
    // observations arrive, while count/mean/max stay exact.
    #[test]
    fn reservoir_bounds_resident_samples() {
        let m = Metrics::new();
        let total = 3 * LATENCY_RESERVOIR_CAP;
        for i in 1..=total {
            m.record_e2e(Duration::from_micros(i as u64));
        }
        assert_eq!(m.e2e_resident_samples(), LATENCY_RESERVOIR_CAP);
        let s = m.snapshot();
        assert_eq!(s.e2e.count, total);
        assert_eq!(s.e2e.max_us, total as f64);
        let exact_mean = (total + 1) as f64 / 2.0;
        assert!((s.e2e.mean_us - exact_mean).abs() < 1e-6 * exact_mean);
    }

    #[test]
    fn reservoir_percentiles_stay_sane_past_cap() {
        // Uniform ramp 1..=3·cap: the sampled percentiles should land
        // within a few percent of the true quantiles.
        let m = Metrics::new();
        let total = 3 * LATENCY_RESERVOIR_CAP;
        for i in 1..=total {
            m.record_e2e(Duration::from_micros(i as u64));
        }
        let s = m.snapshot();
        let tol = 0.10 * total as f64;
        assert!((s.e2e.p50_us - 0.50 * total as f64).abs() < tol, "p50={}", s.e2e.p50_us);
        assert!((s.e2e.p95_us - 0.95 * total as f64).abs() < tol, "p95={}", s.e2e.p95_us);
        assert!(s.e2e.p50_us <= s.e2e.p95_us && s.e2e.p95_us <= s.e2e.p99_us);
        assert!(s.e2e.p99_us <= s.e2e.max_us);
    }

    #[test]
    fn reservoir_replacement_is_deterministic() {
        let feed = |m: &Metrics| {
            for i in 1..=(2 * LATENCY_RESERVOIR_CAP) {
                m.record_e2e(Duration::from_micros((i % 977 + 1) as u64));
            }
        };
        let (a, b) = (Metrics::new(), Metrics::new());
        feed(&a);
        feed(&b);
        let (sa, sb) = (a.snapshot(), b.snapshot());
        assert_eq!(sa.e2e, sb.e2e, "identically-fed reservoirs must summarize identically");
    }
}
