//! The network door: a TCP front-end over [`Server`].
//!
//! Plain `std::net` — this image vendors no async runtime and no JSON
//! crate, so the framing is hand-rolled: **one flat JSON object per
//! newline-terminated line**, both directions. The parser handles
//! exactly that shape (unsigned integer fields, one flat array of
//! unsigned integers, no string escapes, no nesting) — it is a wire
//! format for this server, not a general JSON implementation.
//!
//! ## Requests (client → server)
//!
//! ```text
//! {"op":"generate","id":1,"prompt":[1,2,3],"max_new_tokens":8}
//! {"op":"generate","id":3,"prompt":[4,5],"max_new_tokens":8,"speculate":4}
//! {"op":"attn","id":2,"seq_len":128,"d_model":8,"seed":7}
//! {"op":"attn","id":4,"seq_len":128,"d_model":8,"seed":7,"backend":"exact"}
//! {"op":"cancel","id":1}
//! ```
//!
//! `backend` is optional on `attn`: `"exact"`, `"conv"` or
//! `"lowrank"` pins that one request past the server-side router
//! (any other value is rejected with an `error` line); omitting it
//! keeps the routed default. `speculate` is optional: it overrides
//! the server's speculative decoding depth γ for that one request
//! (`0` opts out). `cancel`
//! drops a previously submitted generation by its client id — queued
//! or in flight — freeing its decode session; tokens already streamed
//! stand and the terminal line is `cancelled`. Cancelling a finished
//! (or unknown) id is a no-op: the earlier terminal line stands.
//!
//! Attention requests are trace-style: the payload is synthesized from
//! `seed` server-side (same [`Payload::Synthetic`] path the bench
//! traces use) — explicit tensors stay on the in-process API.
//!
//! ## Responses (server → client)
//!
//! Generation **streams**: one `token` line per decode step the moment
//! the scheduler produces it, then a terminal line:
//!
//! ```text
//! {"ev":"token","id":1,"index":0,"token":17}
//! {"ev":"done","id":1,"prompt_len":3,"decode_steps":7,"tokens":[17,...]}
//! {"ev":"rejected","id":1}            (invalid prompt)
//! {"ev":"busy","id":1}                (admission queue full — retry)
//! {"ev":"cancelled","id":1}           (dropped by {"op":"cancel",...})
//! {"ev":"attn","id":2,"backend":"conv","basis_k":4,"y_fp":"1a2b..."}
//! {"ev":"error","msg":"..."}          (unparseable request line)
//! ```
//!
//! `y_fp` is the FNV-1a [`fingerprint`] of the output matrix — enough
//! for a client to assert bit-identity against an in-process oracle
//! without shipping `n × d` floats through the wire format.
//!
//! `id`s are client-scoped: each connection may number its requests
//! 0,1,2,… — the front-end rewrites them onto a server-global id space
//! and maps responses back before writing.

#![cfg_attr(not(test), deny(clippy::unwrap_used))]

use super::cache::fingerprint;
use super::metrics::Metrics;
use super::router::Backend;
use super::server::{AttnRequest, GenEvent, GenRequest, GenSink, Payload, Server, ServerConfig};
use crate::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::sync::{lock, mpsc, thread, Arc, Mutex};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::time::{Duration, Instant};

/// Network front-end configuration.
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// Bind address, e.g. `"127.0.0.1:0"` (port 0 = ephemeral; read
    /// the bound port back from [`NetServer::addr`]).
    pub addr: String,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig { addr: "127.0.0.1:0".to_string() }
    }
}

/// How often the accept loop re-checks the shutdown flag while no
/// connection is arriving (the listener is non-blocking).
const ACCEPT_POLL: Duration = Duration::from_millis(5);

type SharedStream = Arc<Mutex<TcpStream>>;

/// Routes one submitted attention request's response back to its
/// connection: internal id → (client id, connection writer).
type AttnRoutes = Arc<Mutex<HashMap<u64, (u64, SharedStream)>>>;

/// A running TCP front-end wrapping a [`Server`].
pub struct NetServer {
    server: Arc<Server>,
    addr: SocketAddr,
    running: Arc<AtomicBool>,
    accept_thread: Option<thread::JoinHandle<()>>,
    pump_thread: Option<thread::JoinHandle<()>>,
    pump_stop: mpsc::Sender<()>,
    /// Writer halves of every accepted connection (for shutdown).
    conns: Arc<Mutex<Vec<SharedStream>>>,
    /// Reader threads (joined on shutdown).
    readers: Arc<Mutex<Vec<thread::JoinHandle<()>>>>,
}

impl NetServer {
    /// Start the server and bind the listener.
    pub fn start(server_cfg: ServerConfig, net_cfg: NetConfig) -> std::io::Result<NetServer> {
        let server = Arc::new(Server::start(server_cfg));
        let listener = TcpListener::bind(&net_cfg.addr)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;

        let running = Arc::new(AtomicBool::new(true));
        let conns: Arc<Mutex<Vec<SharedStream>>> = Arc::new(Mutex::new(Vec::new()));
        let readers: Arc<Mutex<Vec<thread::JoinHandle<()>>>> =
            Arc::new(Mutex::new(Vec::new()));
        let routes: AttnRoutes = Arc::new(Mutex::new(HashMap::new()));
        let next_id = Arc::new(AtomicU64::new(1));

        // Response pump: drains the server's attention responses and
        // routes each back to the connection that submitted it.
        let (pump_stop, pump_stop_rx) = mpsc::channel::<()>();
        let pump_thread = {
            let server = server.clone();
            let routes = routes.clone();
            Some(thread::spawn(move || loop {
                if let Some(resp) = server.recv_attn_timeout(Duration::from_millis(20)) {
                    let dest = lock(&routes).remove(&resp.id);
                    if let Some((client_id, writer)) = dest {
                        let backend = match resp.backend {
                            Backend::Exact => "exact",
                            Backend::ConvBasis => "conv",
                            Backend::LowRank => "lowrank",
                        };
                        write_line(
                            &writer,
                            &format!(
                                "{{\"ev\":\"attn\",\"id\":{},\"backend\":\"{}\",\"basis_k\":{},\"y_fp\":\"{:016x}\"}}",
                                client_id,
                                backend,
                                resp.basis_k,
                                fingerprint(resp.y.data()),
                            ),
                        );
                    }
                } else if pump_stop_rx.try_recv().is_ok() {
                    break;
                }
            }))
        };

        // Accept loop: non-blocking accept + shutdown-flag poll; one
        // reader thread per connection.
        let accept_thread = {
            let server = server.clone();
            let running = running.clone();
            let conns = conns.clone();
            let readers = readers.clone();
            Some(thread::spawn(move || {
                while running.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            let writer: SharedStream = match stream.try_clone() {
                                Ok(w) => Arc::new(Mutex::new(w)),
                                Err(_) => continue,
                            };
                            lock(&conns).push(writer.clone());
                            let server = server.clone();
                            let routes = routes.clone();
                            let next_id = next_id.clone();
                            let handle = thread::spawn(move || {
                                serve_connection(stream, writer, &server, &routes, &next_id);
                            });
                            lock(&readers).push(handle);
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            thread::sleep(ACCEPT_POLL);
                        }
                        Err(_) => break,
                    }
                }
            }))
        };

        Ok(NetServer { server, addr, running, accept_thread, pump_thread, pump_stop, conns, readers })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The wrapped server's metrics.
    pub fn metrics(&self) -> Arc<Metrics> {
        self.server.metrics.clone()
    }

    /// Graceful shutdown: stop accepting, close every connection
    /// (in-flight generations keep decoding — their streamed writes to
    /// dead sockets are discarded), drain the server, join all
    /// threads. Safe to call mid-stream.
    pub fn shutdown(mut self) -> Arc<Metrics> {
        self.running.store(false, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        // Closing the sockets unblocks every reader's `read_line`.
        for conn in lock(&self.conns).drain(..) {
            if let Ok(s) = conn.lock() {
                let _ = s.shutdown(Shutdown::Both);
            }
        }
        let reader_handles: Vec<_> = lock(&self.readers).drain(..).collect();
        for r in reader_handles {
            let _ = r.join();
        }
        // No clients remain: stop the pump, then drain the server.
        let _ = self.pump_stop.send(());
        if let Some(t) = self.pump_thread.take() {
            let _ = t.join();
        }
        let server = Arc::try_unwrap(self.server)
            .unwrap_or_else(|_| panic!("net server threads must release the server on shutdown"));
        server.shutdown()
    }
}

/// One connection's read loop: parse request lines, rewrite ids into
/// the server-global space, submit. Exits on EOF / socket close.
fn serve_connection(
    stream: TcpStream,
    writer: SharedStream,
    server: &Server,
    routes: &AttnRoutes,
    next_id: &AtomicU64,
) {
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    // Client id → internal id for this connection's generations, so
    // `cancel` lines can address them (latest submission wins when a
    // client reuses an id). Connection-scoped: one reader thread owns
    // it, no lock needed.
    let mut gen_ids: HashMap<u64, u64> = HashMap::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) | Err(_) => break, // EOF or dead socket
            Ok(_) => {}
        }
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        match json_str(line, "op") {
            Some("generate") => {
                let (Some(client_id), Some(prompt), Some(max_new)) = (
                    json_u64(line, "id"),
                    json_usize_array(line, "prompt"),
                    json_u64(line, "max_new_tokens"),
                ) else {
                    write_error(&writer, "generate needs id, prompt, max_new_tokens");
                    continue;
                };
                let internal = next_id.fetch_add(1, Ordering::Relaxed);
                gen_ids.insert(client_id, internal);
                let sink_writer = writer.clone();
                let sink = GenSink::new(move |ev| {
                    // Map the server-global id back to the client's.
                    let msg = match ev {
                        GenEvent::Token { index, token, .. } => format!(
                            "{{\"ev\":\"token\",\"id\":{client_id},\"index\":{index},\"token\":{token}}}"
                        ),
                        GenEvent::Done { prompt_len, tokens, decode_steps, .. } => format!(
                            "{{\"ev\":\"done\",\"id\":{client_id},\"prompt_len\":{prompt_len},\"decode_steps\":{decode_steps},\"tokens\":[{}]}}",
                            join_usizes(tokens),
                        ),
                        GenEvent::Rejected { .. } => {
                            format!("{{\"ev\":\"rejected\",\"id\":{client_id}}}")
                        }
                        GenEvent::Busy { .. } => {
                            format!("{{\"ev\":\"busy\",\"id\":{client_id}}}")
                        }
                        GenEvent::Cancelled { .. } => {
                            format!("{{\"ev\":\"cancelled\",\"id\":{client_id}}}")
                        }
                    };
                    write_line(&sink_writer, &msg);
                });
                let mut req = GenRequest::new(internal, prompt, max_new as usize).with_stream(sink);
                if let Some(gamma) = json_u64(line, "speculate") {
                    req = req.with_speculate(gamma as usize);
                }
                server.submit_generate(req);
            }
            Some("cancel") => {
                let Some(client_id) = json_u64(line, "id") else {
                    write_error(&writer, "cancel needs id");
                    continue;
                };
                match gen_ids.get(&client_id) {
                    Some(&internal) => server.cancel_generate(internal),
                    None => write_error(&writer, "cancel: unknown id"),
                }
            }
            Some("attn") => {
                let (Some(client_id), Some(seq_len), Some(d_model), Some(seed)) = (
                    json_u64(line, "id"),
                    json_u64(line, "seq_len"),
                    json_u64(line, "d_model"),
                    json_u64(line, "seed"),
                ) else {
                    write_error(&writer, "attn needs id, seq_len, d_model, seed");
                    continue;
                };
                // Optional per-request backend override; anything else
                // (or no field) defers to the server-side router.
                let backend = match json_str(line, "backend") {
                    Some("exact") => Some(Backend::Exact),
                    Some("conv") => Some(Backend::ConvBasis),
                    Some("lowrank") => Some(Backend::LowRank),
                    Some(_) => {
                        write_error(&writer, "backend must be exact|conv|lowrank");
                        continue;
                    }
                    None => None,
                };
                let internal = next_id.fetch_add(1, Ordering::Relaxed);
                lock(routes).insert(internal, (client_id, writer.clone()));
                server.submit(AttnRequest {
                    id: internal,
                    seq_len: seq_len as usize,
                    d_model: d_model as usize,
                    bounded_entries: false,
                    backend,
                    payload: Payload::Synthetic { seed },
                    submitted_at: Instant::now(),
                });
            }
            _ => write_error(&writer, "unknown op (want generate|attn|cancel)"),
        }
    }
}

/// Write one whole line under the connection mutex (lines from the
/// pump, the streaming sinks, and the reader never interleave). Errors
/// are discarded: a dead client just stops receiving.
fn write_line(writer: &SharedStream, line: &str) {
    let mut s = lock(writer);
    let _ = s.write_all(line.as_bytes());
    let _ = s.write_all(b"\n");
    let _ = s.flush();
}

fn write_error(writer: &SharedStream, msg: &str) {
    write_line(writer, &format!("{{\"ev\":\"error\",\"msg\":\"{msg}\"}}"));
}

fn join_usizes(xs: &[usize]) -> String {
    xs.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(",")
}

/// Extract an unsigned integer field from a flat JSON line.
fn json_u64(line: &str, key: &str) -> Option<u64> {
    let rest = field(line, key)?;
    let end = rest.find(|c: char| !c.is_ascii_digit()).unwrap_or(rest.len());
    if end == 0 {
        return None;
    }
    rest[..end].parse().ok()
}

/// Extract a string field (no escape handling — wire format only).
fn json_str<'a>(line: &'a str, key: &str) -> Option<&'a str> {
    let rest = field(line, key)?.strip_prefix('"')?;
    let end = rest.find('"')?;
    Some(&rest[..end])
}

/// Extract a flat array of unsigned integers.
fn json_usize_array(line: &str, key: &str) -> Option<Vec<usize>> {
    let rest = field(line, key)?.strip_prefix('[')?;
    let body = &rest[..rest.find(']')?];
    if body.trim().is_empty() {
        return Some(Vec::new());
    }
    body.split(',').map(|t| t.trim().parse::<usize>().ok()).collect()
}

/// Position just past `"key":` in a flat JSON line.
fn field<'a>(line: &'a str, key: &str) -> Option<&'a str> {
    let pat = format!("\"{key}\":");
    let i = line.find(&pat)? + pat.len();
    Some(line[i..].trim_start())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_flat_json_fields() {
        let line = r#"{"op":"generate","id":7,"prompt":[1, 2,3],"max_new_tokens":8}"#;
        assert_eq!(json_str(line, "op"), Some("generate"));
        assert_eq!(json_u64(line, "id"), Some(7));
        assert_eq!(json_usize_array(line, "prompt"), Some(vec![1, 2, 3]));
        assert_eq!(json_u64(line, "max_new_tokens"), Some(8));
        assert_eq!(json_u64(line, "missing"), None);
        assert_eq!(json_usize_array(r#"{"prompt":[]}"#, "prompt"), Some(vec![]));
        assert_eq!(json_usize_array(r#"{"prompt":[1,x]}"#, "prompt"), None);
    }

    #[test]
    fn renders_token_arrays() {
        assert_eq!(join_usizes(&[1, 22, 3]), "1,22,3");
        assert_eq!(join_usizes(&[]), "");
    }

    #[test]
    fn parses_optional_backend_knob() {
        let pinned = r#"{"op":"attn","id":2,"seq_len":64,"d_model":8,"seed":7,"backend":"exact"}"#;
        assert_eq!(json_str(pinned, "backend"), Some("exact"));
        let routed = r#"{"op":"attn","id":2,"seq_len":64,"d_model":8,"seed":7}"#;
        assert_eq!(json_str(routed, "backend"), None);
    }
}
