//! L3 serving coordinator — the systems layer wrapping the paper's
//! algorithms, shaped like an attention-inference service (the paper's
//! motivating workload is long-context LLM inference):
//!
//! * [`Router`] — per-request backend policy: exact attention for short
//!   sequences (quadratic is cheap there — Figure 1a's crossover),
//!   conv-basis for long ones, low-rank when the request asks for it.
//! * [`DynamicBatcher`] — groups requests by sequence-length bucket and
//!   flushes on size or deadline, so workers amortize FFT plans and
//!   basis recovery across a batch.
//! * [`BasisCache`] — recovered conv bases keyed by (model, layer, Q/K
//!   fingerprint): *recover once, apply per request* — the serving-side
//!   realization of Algorithm 1's split between Recover and the FFT
//!   apply.
//! * [`Server`] — worker threads draining the batch queue (std::thread
//!   + mpsc; this image vendors no async runtime, and the workload is
//!   CPU-bound anyway). With a [`GenConfig`] it also runs the
//!   generation scheduler: [`GenRequest`] (prompt → N tokens) served
//!   by interleaving batched prefill of new arrivals with one engine
//!   decode step per loop for every in-flight sequence — autoregressive
//!   serving with no per-token re-prefill. With `speculate: γ > 0` each
//!   round instead drafts γ tokens through the cheap decode path and
//!   verifies them (plus one bonus position) in a single exact
//!   prefill-lane submit — the emitted stream stays bit-identical to
//!   exact greedy decoding while decode-lane work per token drops by
//!   the acceptance rate. In-flight requests can be dropped via
//!   `Server::cancel_generate` (wire: `{"op":"cancel","id":…}`).
//! * [`AdmissionQueue`] — token-budget admission control for the
//!   generation lane ([`AdmissionConfig`]: per-wave prefill budget,
//!   whole-batch total-token budget, waiting/served ratio) with
//!   bounded queueing, explicit load shedding, and the condvar the
//!   event-driven scheduler parks on.
//! * [`NetServer`] — the TCP front-end: newline-delimited JSON-ish
//!   framing over `std::net`, per-connection reader threads, token
//!   streaming per decode step ([`GenSink`]/[`GenEvent`] under the
//!   hood). No new dependencies — the framing is hand-rolled.
//! * [`Metrics`] — lock-free counters + bounded-reservoir latency
//!   recording, including the decode path (`decode_seed_hits`,
//!   `decode_rerecoveries`, …) and the admission door (`gen_rejected`,
//!   `shed_requests`, `queue_depth`).
//!
//! The runtime is deliberately deterministic given a trace and a seed —
//! every number in EXPERIMENTS.md §coordinator is reproducible. See
//! `ARCHITECTURE.md` at the repo root for the full request flow.

mod admission;
mod batcher;
mod cache;
mod metrics;
mod net;
mod router;
mod server;

pub use admission::{AdmissionConfig, AdmissionQueue, Wake};
pub use batcher::{Batch, BatcherConfig, DynamicBatcher};
pub use cache::{fingerprint, shard_of, BasisCache, CacheKey, CachedBasis, StepBasis, N_SHARDS};
pub use metrics::{
    HeadProfile, LatencyStats, Metrics, MetricsSnapshot, RouteKind, HEAD_ERR_EMA_ALPHA,
    HEAD_ERR_QUANTUM, LATENCY_RESERVOIR_CAP,
};
pub use net::{NetConfig, NetServer};
pub use router::{Backend, Router, RouterConfig};
pub use server::{
    run_trace, AttnRequest, AttnResponse, GenConfig, GenEvent, GenRequest, GenResponse, GenSink,
    GenStatus, Payload, Server, ServerConfig,
};
