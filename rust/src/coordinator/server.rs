//! The serving loop: dispatcher thread (router + batcher) feeding
//! worker threads over mpsc channels; workers execute **whole batches**
//! through the shared [`BatchedEngine`] (one `attend_batch` call per
//! batch — the dynamic batcher's groups finally reach the attention
//! layer as batches, not loops of singles). Plain std threads — the
//! workload is CPU-bound attention math, so an async runtime would only
//! add scheduling noise (and this image vendors none).

use super::batcher::{Batch, BatcherConfig, DynamicBatcher};
use super::cache::BasisCache;
use super::metrics::Metrics;
use super::router::{Backend, Router, RouterConfig};
use crate::attention::batched::{AttnJob, BatchedBackend, BatchedEngine};
use crate::attention::rope::rope_structured_qk;
use crate::lowrank::LowRankConfig;
use crate::tensor::{Matrix, Rng};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

/// Request payload: explicit tensors, or a synthetic structured
/// workload generated from a seed (trace-driven benching).
#[derive(Clone, Debug)]
pub enum Payload {
    Synthetic { seed: u64 },
    Explicit { q: Matrix, k: Matrix, v: Matrix },
}

/// One attention request.
#[derive(Clone, Debug)]
pub struct AttnRequest {
    pub id: u64,
    pub seq_len: usize,
    pub d_model: usize,
    /// Router hint: entries known bounded (enables low-rank).
    pub bounded_entries: bool,
    pub payload: Payload,
    pub submitted_at: Instant,
}

/// Completed response.
#[derive(Debug)]
pub struct AttnResponse {
    pub id: u64,
    pub y: Matrix,
    pub backend: Backend,
    /// Basis size used (0 for exact / low-rank).
    pub basis_k: usize,
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub router: RouterConfig,
    pub batcher: BatcherConfig,
    pub workers: usize,
    pub cache_capacity: usize,
    /// Low-rank degree when the router picks LowRank.
    pub lowrank_degree: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            router: RouterConfig::default(),
            batcher: BatcherConfig::default(),
            workers: 2,
            cache_capacity: 64,
            lowrank_degree: 2,
        }
    }
}

enum DispatchMsg {
    Request(AttnRequest),
    Shutdown,
}

/// The coordinator server.
pub struct Server {
    dispatch_tx: mpsc::Sender<DispatchMsg>,
    resp_rx: mpsc::Receiver<AttnResponse>,
    pub metrics: Arc<Metrics>,
    pub cache: Arc<BasisCache>,
    /// The shared batched attention engine all workers execute through.
    pub engine: Arc<BatchedEngine>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    running: Arc<AtomicBool>,
}

impl Server {
    /// Start dispatcher + worker threads.
    pub fn start(cfg: ServerConfig) -> Self {
        let metrics = Arc::new(Metrics::new());
        let cache = Arc::new(BasisCache::new(cfg.cache_capacity));
        let running = Arc::new(AtomicBool::new(true));
        let (dispatch_tx, dispatch_rx) = mpsc::channel::<DispatchMsg>();
        let (batch_tx, batch_rx) = mpsc::channel::<Batch>();
        let batch_rx = Arc::new(std::sync::Mutex::new(batch_rx));
        let (resp_tx, resp_rx) = mpsc::channel::<AttnResponse>();

        // Dispatcher: route + batch.
        let router = Router::new(cfg.router);
        let bcfg = cfg.batcher;
        let running_d = running.clone();
        let metrics_d = metrics.clone();
        let dispatcher = std::thread::spawn(move || {
            let mut batcher = DynamicBatcher::new(bcfg);
            loop {
                let timeout = batcher.next_deadline().unwrap_or(bcfg.max_wait);
                match dispatch_rx.recv_timeout(timeout) {
                    Ok(DispatchMsg::Request(req)) => {
                        Metrics::incr(&metrics_d.requests_submitted);
                        let backend = router.route(req.seq_len, req.bounded_entries);
                        let bucket = router.bucket(req.seq_len);
                        if let Some(batch) = batcher.push(backend, bucket, req) {
                            let _ = batch_tx.send(batch);
                        }
                    }
                    Ok(DispatchMsg::Shutdown) => {
                        for b in batcher.flush(true) {
                            let _ = batch_tx.send(b);
                        }
                        break;
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        for b in batcher.flush(false) {
                            let _ = batch_tx.send(b);
                        }
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
                if !running_d.load(Ordering::Relaxed) {
                    for b in batcher.flush(true) {
                        let _ = batch_tx.send(b);
                    }
                    break;
                }
            }
        });

        // The shared batched engine: one FFT plan cache and one basis
        // cache for the whole server, a fixed pool of compute threads.
        let engine = Arc::new(BatchedEngine::with_shared(
            cfg.workers.max(1),
            cache.clone(),
            metrics.clone(),
        ));

        // Workers: drain the batch queue and execute each batch as ONE
        // engine call (all requests of the batch fan out across the
        // engine pool together).
        let mut workers = Vec::new();
        for _ in 0..cfg.workers.max(1) {
            let rx = batch_rx.clone();
            let tx = resp_tx.clone();
            let metrics_w = metrics.clone();
            let router_w = Router::new(cfg.router);
            let engine_w = engine.clone();
            let lowrank_degree = cfg.lowrank_degree;
            workers.push(std::thread::spawn(move || loop {
                let batch = {
                    let guard = rx.lock().unwrap();
                    guard.recv()
                };
                let Ok(batch) = batch else { break };
                let t0 = Instant::now();
                let n_reqs = batch.requests.len();
                if n_reqs == 0 {
                    continue;
                }
                let mut jobs = Vec::with_capacity(n_reqs);
                let mut meta = Vec::with_capacity(n_reqs);
                for req in batch.requests {
                    metrics_w.record_queue(t0.duration_since(req.submitted_at));
                    let (q, k, v) = match req.payload {
                        Payload::Explicit { q, k, v } => (q, k, v),
                        Payload::Synthetic { seed } => synthesize(req.seq_len, req.d_model, seed),
                    };
                    let spec = match batch.backend {
                        Backend::Exact => BatchedBackend::Exact,
                        Backend::ConvBasis => BatchedBackend::Strided(router_w.k_budget(q.rows())),
                        Backend::LowRank => BatchedBackend::LowRank(LowRankConfig::new(
                            lowrank_degree,
                            q.cols() as f64,
                        )),
                    };
                    jobs.push(AttnJob::causal(0, 0, q, k, v, spec));
                    meta.push((req.id, req.submitted_at));
                }
                let outs = engine_w.attend_batch(jobs);
                for ((id, submitted_at), out) in meta.into_iter().zip(outs) {
                    // Per-job wall time from the engine: exec latency
                    // percentiles stay per-request under batching.
                    metrics_w.record_exec(out.exec);
                    metrics_w.record_e2e(submitted_at.elapsed());
                    Metrics::incr(&metrics_w.requests_completed);
                    let backend = if out.fell_back { Backend::Exact } else { batch.backend };
                    let _ = tx.send(AttnResponse { id, y: out.y, backend, basis_k: out.basis_k });
                }
                Metrics::incr(&metrics_w.batches_executed);
            }));
        }
        drop(resp_tx);

        Server {
            dispatch_tx,
            resp_rx,
            metrics,
            cache,
            engine,
            dispatcher: Some(dispatcher),
            workers,
            running,
        }
    }

    /// Submit a request (non-blocking).
    pub fn submit(&self, req: AttnRequest) {
        let _ = self.dispatch_tx.send(DispatchMsg::Request(req));
    }

    /// Collect `n` responses (blocking).
    pub fn collect(&self, n: usize) -> Vec<AttnResponse> {
        (0..n).filter_map(|_| self.resp_rx.recv().ok()).collect()
    }

    /// Graceful shutdown: flush, join.
    pub fn shutdown(mut self) -> Arc<Metrics> {
        self.running.store(false, Ordering::Relaxed);
        let _ = self.dispatch_tx.send(DispatchMsg::Shutdown);
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        // Workers exit when the batch channel closes (dispatcher gone).
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.metrics.clone()
    }
}

fn synthesize(seq_len: usize, d_model: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
    let mut rng = Rng::seeded(seed);
    let freqs = (d_model / 2).min(4).max(1);
    let (q, k) = rope_structured_qk(seq_len, d_model, freqs, &mut rng);
    let v = Matrix::randn(seq_len, d_model, &mut rng);
    (q, k, v)
}

/// Drive a whole workload trace through a server, honouring arrival
/// times scaled by `time_scale` (0 = as fast as possible). Returns
/// responses sorted by id.
pub fn run_trace(
    server: &Server,
    trace: &crate::data::WorkloadTrace,
    time_scale: f64,
) -> Vec<AttnResponse> {
    let t0 = Instant::now();
    for r in &trace.requests {
        if time_scale > 0.0 {
            let due = std::time::Duration::from_micros((r.arrival_us as f64 * time_scale) as u64);
            let elapsed = t0.elapsed();
            if due > elapsed {
                std::thread::sleep(due - elapsed);
            }
        }
        server.submit(AttnRequest {
            id: r.id,
            seq_len: r.seq_len,
            d_model: r.d_model,
            bounded_entries: false,
            payload: Payload::Synthetic { seed: r.id % 16 }, // repeats → cache hits
            submitted_at: Instant::now(),
        });
    }
    let mut out = server.collect(trace.requests.len());
    out.sort_by_key(|r| r.id);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::{exact_attention, Mask};
    use crate::data::{WorkloadConfig, WorkloadTrace};

    fn small_server() -> Server {
        Server::start(ServerConfig {
            router: RouterConfig { exact_below: 64, ..Default::default() },
            batcher: BatcherConfig { max_batch: 4, max_wait: std::time::Duration::from_millis(1) },
            workers: 2,
            cache_capacity: 16,
            lowrank_degree: 2,
        })
    }

    #[test]
    fn serves_explicit_request_exactly() {
        let server = small_server();
        let mut rng = Rng::seeded(231);
        let (n, d) = (32, 8);
        let q = Matrix::randn(n, d, &mut rng).scale(0.3);
        let k = Matrix::randn(n, d, &mut rng).scale(0.3);
        let v = Matrix::randn(n, d, &mut rng);
        let want = exact_attention(&q, &k, &v, &Mask::causal(n));
        server.submit(AttnRequest {
            id: 7,
            seq_len: n,
            d_model: d,
            bounded_entries: false,
            payload: Payload::Explicit { q, k, v },
            submitted_at: Instant::now(),
        });
        let resps = server.collect(1);
        assert_eq!(resps[0].id, 7);
        assert_eq!(resps[0].backend, Backend::Exact);
        assert!(crate::tensor::max_abs_diff(&resps[0].y, &want) < 1e-10);
        server.shutdown();
    }

    #[test]
    fn all_trace_requests_complete_once() {
        let server = small_server();
        let cfg = WorkloadConfig {
            rate_per_s: 10_000.0,
            len_buckets: [32, 48, 96, 128],
            len_weights: [0.4, 0.3, 0.2, 0.1],
            d_model: 8,
        };
        let trace = WorkloadTrace::generate(40, &cfg, 5);
        let resps = run_trace(&server, &trace, 0.0);
        assert_eq!(resps.len(), 40);
        let ids: Vec<u64> = resps.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..40).collect::<Vec<_>>());
        let m = server.shutdown();
        let s = m.snapshot();
        assert_eq!(s.requests_completed, 40);
        assert_eq!(s.requests_submitted, 40);
    }

    #[test]
    fn conv_path_hits_cache_on_repeats() {
        let server = small_server();
        // Same synthetic seed ⇒ same (Q,K) fingerprint ⇒ cache hits.
        for i in 0..6u64 {
            server.submit(AttnRequest {
                id: i,
                seq_len: 96, // ≥ exact_below ⇒ conv
                d_model: 8,
                bounded_entries: false,
                payload: Payload::Synthetic { seed: 1 },
                submitted_at: Instant::now(),
            });
        }
        let resps = server.collect(6);
        assert_eq!(resps.len(), 6);
        let m = server.shutdown();
        let s = m.snapshot();
        assert!(s.cache_hits >= 1, "cache hits = {}", s.cache_hits);
        assert!(s.conv_requests == 6);
    }

    #[test]
    fn conv_and_exact_agree_on_structured_payloads() {
        let server = small_server();
        let (q, k, v) = synthesize(128, 8, 3);
        let want = exact_attention(&q, &k, &v, &Mask::causal(128));
        server.submit(AttnRequest {
            id: 0,
            seq_len: 128,
            d_model: 8,
            bounded_entries: false,
            payload: Payload::Explicit { q, k, v },
            submitted_at: Instant::now(),
        });
        let resp = &server.collect(1)[0];
        assert_eq!(resp.backend, Backend::ConvBasis);
        assert!(resp.basis_k >= 1);
        let err = crate::tensor::max_abs_diff(&resp.y, &want);
        assert!(err < 1e-6, "err = {err}");
        server.shutdown();
    }

    #[test]
    fn shutdown_flushes_pending() {
        let server = Server::start(ServerConfig {
            batcher: BatcherConfig {
                max_batch: 1000, // never fills
                max_wait: std::time::Duration::from_secs(3600),
            },
            ..Default::default()
        });
        server.submit(AttnRequest {
            id: 1,
            seq_len: 32,
            d_model: 8,
            bounded_entries: false,
            payload: Payload::Synthetic { seed: 0 },
            submitted_at: Instant::now(),
        });
        // The batch can never fill and the deadline is an hour away —
        // only the shutdown flush can complete this request.
        std::thread::sleep(std::time::Duration::from_millis(20));
        let m = server.shutdown();
        let s = m.snapshot();
        assert_eq!(s.requests_completed, 1);
    }
}
