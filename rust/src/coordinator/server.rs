//! The serving loop: dispatcher thread (router + batcher) feeding
//! worker threads over mpsc channels; workers execute **whole batches**
//! through the shared [`BatchedEngine`]'s unified `submit` door (one
//! prefill-lane call per batch — the dynamic batcher's groups finally
//! reach the attention layer as batches, not loops of singles). Plain
//! std threads — the workload is CPU-bound attention math, so an async
//! runtime would only add scheduling noise (and this image vendors
//! none).
//!
//! With a [`GenConfig`] the server additionally runs a **generation
//! scheduler** thread for autoregressive requests ([`GenRequest`]:
//! prompt in, N tokens out). The scheduler keeps a set of in-flight
//! [`DecodeSession`]s and loops: admit new arrivals (batched prefill
//! through the engine), run **one decode step for every in-flight
//! sequence** (one decode-lane submit per layer via
//! `Transformer::decode_step`), retire finished sequences. New
//! arrivals therefore merge into the running decode loop after at most
//! one step. Every generated token costs `O(k·n + n·d)` (conv) or
//! `O(n·d)` (exact) per head, never a re-prefill; seed hits, drift
//! re-recoveries, per-step latency and live-session KV bytes
//! (`decode_resident_bytes`) land in [`Metrics`].
//!
//! **Continuous batching across op kinds.** The scheduler also drains
//! the dispatcher's flushed attention batches: while decoding it
//! converts them to prefill jobs and merges them into the *same*
//! engine submit as the decode step
//! (`Transformer::decode_step_with_jobs` — counted in
//! `merged_attn_requests`); while idle it executes them standalone.
//! Non-generation arrivals therefore stop waiting for a worker when
//! the decode loop already has the engine hot. With `workers: 0` (and
//! `gen` set) the scheduler's lane is the *only* attention executor —
//! the fully unified single-door configuration.
//!
//! **Admission and streaming.** Generation arrivals are validated at
//! the door (empty / over-`max_seq` prompts are rejected immediately —
//! counted in `gen_rejected`, never against concurrency or the
//! completion metrics) and then pass a token-budget admission queue
//! ([`AdmissionQueue`], policy in [`AdmissionConfig`]): a prefill wave
//! is admitted only when its Σ prompt tokens fit the prefill budget,
//! the whole batch fits the total-token budget, and pausing the
//! running batch pays for itself (`waiting_served_ratio`, with
//! `max_waiting_steps` as the starvation valve). A full queue sheds
//! with an explicit busy response (`shed_requests`). Requests carrying
//! a [`GenSink`] stream every token as a [`GenEvent`] the step it
//! decodes — the TCP front-end ([`super::net`]) rides this. The
//! scheduler is event-driven: idle it parks on the queue's condvar,
//! and the dispatcher *kicks* it whenever it flushes attention batches
//! (no timer polling anywhere in the loop).

#![cfg_attr(not(test), deny(clippy::unwrap_used))]

use super::admission::{AdmissionConfig, AdmissionQueue, Wake};
use super::batcher::{Batch, BatcherConfig, DynamicBatcher};
use super::cache::BasisCache;
use super::metrics::Metrics;
use super::router::{Backend, Router, RouterConfig};
use crate::attention::batched::{AttnJob, BatchedBackend, BatchedEngine, EngineJob, JobOutput};
use crate::attention::rope::rope_structured_qk;
use crate::attention::ExactKernel;
use crate::lowrank::LowRankConfig;
use crate::model::{AttentionBackend, DecodeSession, Transformer};
use crate::tensor::{Matrix, Rng};
use crate::sync::atomic::{AtomicBool, Ordering};
use crate::sync::{lock, mpsc, thread, Arc, Mutex};
use std::collections::BTreeSet;
use std::time::{Duration, Instant};

/// Request payload: explicit tensors, or a synthetic structured
/// workload generated from a seed (trace-driven benching).
#[derive(Clone, Debug)]
pub enum Payload {
    Synthetic { seed: u64 },
    Explicit { q: Matrix, k: Matrix, v: Matrix },
}

/// One attention request.
#[derive(Clone, Debug)]
pub struct AttnRequest {
    pub id: u64,
    pub seq_len: usize,
    pub d_model: usize,
    /// Router hint: entries known bounded (enables low-rank).
    pub bounded_entries: bool,
    /// Explicit backend override (wire knob `"backend"`): `Some` pins
    /// the request to that backend, `None` lets the request-level
    /// [`Router`] decide from `seq_len`/`bounded_entries`. The ROADMAP
    /// carried slice — clients that know their workload (an eval
    /// harness pinning exact, a long-context batch pinning conv) skip
    /// the policy.
    pub backend: Option<Backend>,
    pub payload: Payload,
    pub submitted_at: Instant,
}

/// Completed response.
#[derive(Debug)]
pub struct AttnResponse {
    pub id: u64,
    pub y: Matrix,
    pub backend: Backend,
    /// Basis size used (0 for exact / low-rank).
    pub basis_k: usize,
}

/// Autoregressive-generation configuration: which model decodes, with
/// which attention backend, and how many sequences may be in flight at
/// once (arrivals beyond that wait in the channel).
#[derive(Clone)]
pub struct GenConfig {
    pub model: Arc<Transformer>,
    /// Attention backend for prefill *and* decode (conv backends
    /// decode through cached bases, exact through the KV-cache row).
    pub backend: AttentionBackend,
    /// Max concurrently decoding sequences (≥ 1).
    pub max_concurrent: usize,
    /// Token-budget admission policy for the waiting line.
    pub admission: AdmissionConfig,
    /// Speculative decoding: tokens drafted through the serving decode
    /// path per round, then verified (plus one bonus position) in a
    /// single exact prefill-lane engine submit; the longest accepted
    /// prefix is kept. `0` disables speculation — the scheduler then
    /// runs the plain one-token-per-step decode loop, the exact same
    /// code path (counter-asserted by `tests/speculative.rs`). Greedy
    /// argmax + exact verification make the emitted stream bit-identical
    /// to non-speculative greedy decoding under the **exact** backend
    /// for every γ; under conv backends speculation *upgrades* the
    /// stream to the exact-greedy oracle (exactness rests on the
    /// verifier, not the drafter). Per-request override:
    /// [`GenRequest::with_speculate`].
    pub speculate: usize,
}

impl std::fmt::Debug for GenConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("GenConfig")
            .field("backend", &self.backend)
            .field("max_concurrent", &self.max_concurrent)
            .field("admission", &self.admission)
            .field("speculate", &self.speculate)
            .field("model_params", &self.model.num_params())
            .finish()
    }
}

/// Per-request streaming sink: invoked on the scheduler thread for
/// every [`GenEvent`] of one generation, in order. Keep it cheap — a
/// slow sink stalls every in-flight sequence's decode step.
#[derive(Clone)]
pub struct GenSink(Arc<dyn Fn(&GenEvent) + Send + Sync>);

impl GenSink {
    pub fn new(f: impl Fn(&GenEvent) + Send + Sync + 'static) -> Self {
        GenSink(Arc::new(f))
    }

    pub fn emit(&self, ev: &GenEvent) {
        (self.0)(ev)
    }
}

impl std::fmt::Debug for GenSink {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("GenSink(..)")
    }
}

/// One streamed generation event. Every request ends in exactly one
/// terminal event (`Done`, `Rejected`, `Busy`, or `Cancelled`);
/// `Token` events precede the terminal with consecutive `index`es
/// from 0.
#[derive(Clone, Debug)]
pub enum GenEvent {
    /// One generated token, emitted the step it decodes.
    Token { id: u64, index: usize, token: usize },
    /// Terminal: generation complete (tokens repeats the full stream).
    Done { id: u64, prompt_len: usize, tokens: Vec<usize>, decode_steps: usize },
    /// Terminal: invalid prompt (empty or over `max_seq`).
    Rejected { id: u64 },
    /// Terminal: admission queue full — retry later.
    Busy { id: u64 },
    /// Terminal: dropped by [`Server::cancel_generate`] — tokens
    /// already streamed stand, nothing follows.
    Cancelled { id: u64 },
}

/// One generation request: a prompt and a token budget.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<usize>,
    /// Tokens to generate (greedy argmax decoding — deterministic).
    pub max_new_tokens: usize,
    pub submitted_at: Instant,
    /// Streaming sink. When set, tokens are emitted as they decode and
    /// the terminal event **replaces** the channel response —
    /// [`Server::collect_generations`] never sees sinked requests.
    pub stream: Option<GenSink>,
    /// Per-request speculation override: `Some(γ)` drafts γ tokens per
    /// round regardless of [`GenConfig::speculate`]; `None` inherits
    /// the server default. `Some(0)` opts a single request out.
    pub speculate: Option<usize>,
}

impl GenRequest {
    pub fn new(id: u64, prompt: Vec<usize>, max_new_tokens: usize) -> Self {
        GenRequest {
            id,
            prompt,
            max_new_tokens,
            submitted_at: Instant::now(),
            stream: None,
            speculate: None,
        }
    }

    pub fn with_stream(mut self, sink: GenSink) -> Self {
        self.stream = Some(sink);
        self
    }

    pub fn with_speculate(mut self, gamma: usize) -> Self {
        self.speculate = Some(gamma);
        self
    }
}

/// How a generation request ended.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GenStatus {
    /// Decoded to its token budget (or the model's `max_seq`).
    Complete,
    /// Invalid prompt (empty or over `max_seq`) — rejected at the
    /// door, excluded from completion/latency metrics.
    Rejected,
    /// Shed by the admission queue (queue full) — retry later.
    Busy,
    /// Dropped by [`Server::cancel_generate`] before completing;
    /// `tokens` holds whatever was generated before the drop.
    Cancelled,
}

/// Completed generation.
#[derive(Clone, Debug)]
pub struct GenResponse {
    pub id: u64,
    pub prompt_len: usize,
    pub status: GenStatus,
    /// Generated tokens (length ≤ `max_new_tokens`; shorter only when
    /// the model's `max_seq` cut generation off or the request was
    /// cancelled mid-flight, empty on `Rejected` and `Busy`).
    pub tokens: Vec<usize>,
    /// Decode steps this sequence ran through the engine (prefill not
    /// counted: the first token comes from the prefill logits).
    pub decode_steps: usize,
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub router: RouterConfig,
    pub batcher: BatcherConfig,
    /// Attention worker threads. Clamped to ≥ 1 — except that `0` with
    /// `gen` set spawns **no** worker threads: every attention batch is
    /// then served by the generation scheduler's merge lane (merged
    /// into decode submits while sequences are in flight, standalone
    /// otherwise).
    pub workers: usize,
    pub cache_capacity: usize,
    /// Low-rank degree when the router picks LowRank.
    pub lowrank_degree: usize,
    /// Enable the generation scheduler (None = attention-only server).
    pub gen: Option<GenConfig>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            router: RouterConfig::default(),
            batcher: BatcherConfig::default(),
            workers: 2,
            cache_capacity: 64,
            lowrank_degree: 2,
            gen: None,
        }
    }
}

enum DispatchMsg {
    Request(AttnRequest),
    Shutdown,
}

/// The coordinator server. `Sync`: the submit side is lock-free mpsc
/// and the response receivers sit behind mutexes, so one `Server` can
/// be shared across connection-handler threads (the TCP front-end
/// does exactly that).
pub struct Server {
    dispatch_tx: mpsc::Sender<DispatchMsg>,
    resp_rx: Mutex<mpsc::Receiver<AttnResponse>>,
    pub metrics: Arc<Metrics>,
    pub cache: Arc<BasisCache>,
    /// The shared batched attention engine all workers execute through.
    pub engine: Arc<BatchedEngine>,
    dispatcher: Option<thread::JoinHandle<()>>,
    workers: Vec<thread::JoinHandle<()>>,
    gen_queue: Option<Arc<AdmissionQueue>>,
    gen_resp_tx: Option<mpsc::Sender<GenResponse>>,
    gen_resp_rx: Option<Mutex<mpsc::Receiver<GenResponse>>>,
    gen_scheduler: Option<thread::JoinHandle<()>>,
    /// Cancellation requests for in-flight generations; the scheduler
    /// sweeps this set once per round (queued requests are cancelled
    /// directly in the admission queue, never through here).
    gen_cancel: Option<Arc<Mutex<BTreeSet<u64>>>>,
    /// The generation model's `max_seq` (door validation bound).
    gen_max_seq: usize,
    /// The generation model's vocabulary size (door validation bound:
    /// an out-of-vocab prompt token would panic the embedding lookup
    /// deep inside the scheduler thread, so it is rejected here).
    gen_vocab: usize,
    running: Arc<AtomicBool>,
}

impl Server {
    /// Start dispatcher + worker threads.
    pub fn start(cfg: ServerConfig) -> Self {
        let metrics = Arc::new(Metrics::new());
        let cache = Arc::new(BasisCache::new(cfg.cache_capacity));
        let running = Arc::new(AtomicBool::new(true));
        let (dispatch_tx, dispatch_rx) = mpsc::channel::<DispatchMsg>();
        let (batch_tx, batch_rx) = mpsc::channel::<Batch>();
        let batch_rx = Arc::new(Mutex::new(batch_rx));
        let (resp_tx, resp_rx) = mpsc::channel::<AttnResponse>();

        // The generation admission queue is created before the
        // dispatcher so the dispatcher can kick it whenever batches
        // are flushed (event-driven wakeup for the scheduler's lane).
        let gen_queue: Option<Arc<AdmissionQueue>> =
            cfg.gen.as_ref().map(|g| Arc::new(AdmissionQueue::new(g.admission, metrics.clone())));

        // Dispatcher: route + batch.
        let router = Router::new(cfg.router);
        let bcfg = cfg.batcher;
        let running_d = running.clone();
        let metrics_d = metrics.clone();
        let queue_d = gen_queue.clone();
        let dispatcher = thread::spawn(move || {
            let mut batcher = DynamicBatcher::new(bcfg);
            let kick = |n: usize| {
                if n > 0 {
                    if let Some(q) = &queue_d {
                        q.kick();
                    }
                }
            };
            loop {
                let timeout = batcher.next_deadline().unwrap_or(bcfg.max_wait);
                match dispatch_rx.recv_timeout(timeout) {
                    Ok(DispatchMsg::Request(req)) => {
                        kick(handle_request(&mut batcher, &router, &metrics_d, req, &batch_tx));
                    }
                    Ok(DispatchMsg::Shutdown) => {
                        kick(send_batches(batcher.flush(true), &batch_tx));
                        break;
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        kick(send_batches(batcher.flush(false), &batch_tx));
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
                if !running_d.load(Ordering::Relaxed) {
                    kick(send_batches(batcher.flush(true), &batch_tx));
                    break;
                }
            }
        });

        // The shared batched engine: one FFT plan cache and one basis
        // cache for the whole server, a fixed pool of compute threads.
        let engine = Arc::new(BatchedEngine::with_shared(
            cfg.workers.max(1),
            cache.clone(),
            metrics.clone(),
        ));

        // Workers: drain the batch queue and execute each batch as ONE
        // engine call (all requests of the batch fan out across the
        // engine pool together). `workers: 0` with a generation
        // scheduler spawns none — the scheduler's lane serves
        // attention batches instead.
        let worker_count =
            if cfg.workers == 0 && cfg.gen.is_some() { 0 } else { cfg.workers.max(1) };
        let mut workers = Vec::new();
        for _ in 0..worker_count {
            let rx = batch_rx.clone();
            let tx = resp_tx.clone();
            let metrics_w = metrics.clone();
            let router_w = Router::new(cfg.router);
            let engine_w = engine.clone();
            let lowrank_degree = cfg.lowrank_degree;
            workers.push(thread::spawn(move || loop {
                let batch = { lock(&rx).recv() };
                let Ok(batch) = batch else { break };
                execute_attn_batch(batch, &router_w, lowrank_degree, &engine_w, &metrics_w, &tx);
            }));
        }

        // Generation scheduler: in-flight decode sessions stepped in
        // lockstep through the engine, interleaved with batched prefill
        // of new arrivals — and, via the merge lane, with flushed
        // attention batches.
        let gen_max_seq = cfg.gen.as_ref().map(|g| g.model.cfg.max_seq).unwrap_or(0);
        let gen_vocab = cfg.gen.as_ref().map(|g| g.model.cfg.vocab_size).unwrap_or(0);
        let (gen_resp_tx, gen_resp_rx, gen_scheduler, gen_cancel) = match cfg.gen {
            Some(gen_cfg) => {
                let (rtx, rrx) = mpsc::channel::<GenResponse>();
                let engine_g = engine.clone();
                let metrics_g = metrics.clone();
                let queue_g =
                    gen_queue.clone().expect("queue was created above whenever cfg.gen is set");
                let cancel = Arc::new(Mutex::new(BTreeSet::new()));
                let cancel_g = cancel.clone();
                let lane = GenLane {
                    batch_rx: batch_rx.clone(),
                    attn_tx: resp_tx.clone(),
                    router: Router::new(cfg.router),
                    lowrank_degree: cfg.lowrank_degree,
                };
                let rtx_sched = rtx.clone();
                let handle = thread::spawn(move || {
                    generation_loop(
                        gen_cfg, &queue_g, rtx_sched, &engine_g, &metrics_g, lane, &cancel_g,
                    );
                });
                (Some(rtx), Some(Mutex::new(rrx)), Some(handle), Some(cancel))
            }
            None => (None, None, None, None),
        };
        drop(resp_tx);

        Server {
            dispatch_tx,
            resp_rx: Mutex::new(resp_rx),
            metrics,
            cache,
            engine,
            dispatcher: Some(dispatcher),
            workers,
            gen_queue,
            gen_resp_tx,
            gen_resp_rx,
            gen_scheduler,
            gen_cancel,
            gen_max_seq,
            gen_vocab,
            running,
        }
    }

    /// Submit a request (non-blocking).
    pub fn submit(&self, req: AttnRequest) {
        let _ = self.dispatch_tx.send(DispatchMsg::Request(req));
    }

    /// Collect `n` responses (blocking).
    pub fn collect(&self, n: usize) -> Vec<AttnResponse> {
        let rx = lock(&self.resp_rx);
        (0..n).filter_map(|_| rx.recv().ok()).collect()
    }

    /// Receive one attention response, waiting at most `timeout` (the
    /// network front-end's response pump).
    pub fn recv_attn_timeout(&self, timeout: Duration) -> Option<AttnResponse> {
        lock(&self.resp_rx).recv_timeout(timeout).ok()
    }

    /// Submit a generation request (non-blocking). Invalid prompts
    /// (empty, longer than the model's `max_seq`, or containing an
    /// out-of-vocab token id — which would otherwise panic the
    /// embedding lookup inside the scheduler thread) are rejected at
    /// the door and a full admission queue sheds with
    /// busy — in both cases the terminal answer (channel response, or
    /// event for sinked requests) is produced here, immediately; the
    /// request never occupies a concurrency slot and never touches the
    /// completion or latency metrics. Panics if the server was started
    /// without a [`GenConfig`].
    pub fn submit_generate(&self, req: GenRequest) {
        let queue = self.gen_queue.as_ref().expect("ServerConfig.gen required for generation");
        Metrics::incr(&self.metrics.gen_requests);
        if req.prompt.is_empty()
            || req.prompt.len() > self.gen_max_seq
            || req.prompt.iter().any(|&t| t >= self.gen_vocab)
        {
            Metrics::incr(&self.metrics.gen_rejected);
            self.answer_terminal(&req, GenStatus::Rejected);
            return;
        }
        if let Err(req) = queue.submit(req) {
            // Shed (queue full): explicit busy, never a silent drop.
            // `shed_requests` was counted by the queue.
            self.answer_terminal(&req, GenStatus::Busy);
        }
    }

    /// Best-effort cancellation of a generation request. Still queued:
    /// it is removed from the admission line and answered terminally
    /// (`Cancelled`) right here. In flight: the scheduler's per-round
    /// sweep retires its [`DecodeSession`] (the `decode_resident_bytes`
    /// gauge drops) and emits the terminal `Cancelled` event — tokens
    /// already streamed stand. Already finished (or unknown id): no-op,
    /// the terminal `Done` stands — every request ends in exactly one
    /// terminal event either way. Cancelled requests never count as
    /// completed and never touch the gen-e2e latency series; they are
    /// counted in `gen_cancelled`. Panics if the server was started
    /// without a [`GenConfig`].
    pub fn cancel_generate(&self, id: u64) {
        let queue = self.gen_queue.as_ref().expect("ServerConfig.gen required for generation");
        if let Some(req) = queue.cancel(id) {
            Metrics::incr(&self.metrics.gen_cancelled);
            match &req.stream {
                Some(sink) => sink.emit(&GenEvent::Cancelled { id: req.id }),
                None => {
                    if let Some(tx) = &self.gen_resp_tx {
                        let _ = tx.send(GenResponse {
                            id: req.id,
                            prompt_len: req.prompt.len(),
                            status: GenStatus::Cancelled,
                            tokens: Vec::new(),
                            decode_steps: 0,
                        });
                    }
                }
            }
            return;
        }
        // Not queued: either in flight or already finished. Park the id
        // for the scheduler's sweep; a kick wakes an idle scheduler so
        // stale ids don't linger in the set.
        if let Some(cancel) = &self.gen_cancel {
            lock(cancel).insert(id);
            queue.kick();
        }
    }

    /// Deliver a door-side terminal answer (rejected / busy).
    fn answer_terminal(&self, req: &GenRequest, status: GenStatus) {
        match (&req.stream, status) {
            (Some(sink), GenStatus::Rejected) => sink.emit(&GenEvent::Rejected { id: req.id }),
            (Some(sink), _) => sink.emit(&GenEvent::Busy { id: req.id }),
            (None, status) => {
                if let Some(tx) = &self.gen_resp_tx {
                    let _ = tx.send(GenResponse {
                        id: req.id,
                        prompt_len: req.prompt.len(),
                        status,
                        tokens: Vec::new(),
                        decode_steps: 0,
                    });
                }
            }
        }
    }

    /// Collect `n` completed generations (blocking). Sinked requests
    /// answer through their [`GenSink`] and never appear here. Panics
    /// if the server was started without a [`GenConfig`].
    pub fn collect_generations(&self, n: usize) -> Vec<GenResponse> {
        let rx = self.gen_resp_rx.as_ref().expect("ServerConfig.gen required for generation");
        let rx = lock(rx);
        (0..n).filter_map(|_| rx.recv().ok()).collect()
    }

    /// Graceful shutdown: flush, finish in-flight generations, join.
    pub fn shutdown(self) -> Arc<Metrics> {
        let metrics = self.metrics.clone();
        drop(self); // Drop does the actual teardown (idempotent).
        metrics
    }
}

impl Drop for Server {
    /// Graceful teardown (also the body of [`Server::shutdown`]):
    /// flush pending batches, let the scheduler drain queued and
    /// in-flight generations, join every thread. Safe to run on an
    /// already-shut-down server — all steps are idempotent.
    fn drop(&mut self) {
        self.running.store(false, Ordering::Relaxed);
        let _ = self.dispatch_tx.send(DispatchMsg::Shutdown);
        if let Some(q) = &self.gen_queue {
            q.shutdown();
        }
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        // Workers exit when the batch channel closes (dispatcher gone).
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // The scheduler drains its in-flight sequences before exiting.
        if let Some(g) = self.gen_scheduler.take() {
            let _ = g.join();
        }
    }
}

/// Route, batch, and flush one request; returns the number of batches
/// sent downstream. Flushing **here** — after every push — is the fix
/// for the dispatcher's flush-starvation bug: the old loop flushed due
/// groups only when `recv_timeout` timed out, so a steady request
/// stream (which never lets the recv time out) held a due batch in
/// another bucket hostage until the stream stopped. Now a due group is
/// emitted as soon as any request arrives past its deadline.
fn handle_request(
    batcher: &mut DynamicBatcher,
    router: &Router,
    metrics: &Metrics,
    req: AttnRequest,
    batch_tx: &mpsc::Sender<Batch>,
) -> usize {
    Metrics::incr(&metrics.requests_submitted);
    // The wire knob wins over the policy: an explicit `backend` pins
    // the request; otherwise the request-level router decides.
    let backend = req.backend.unwrap_or_else(|| router.route(req.seq_len, req.bounded_entries));
    let bucket = router.bucket(req.seq_len);
    let mut sent = 0;
    if let Some(batch) = batcher.push(backend, bucket, req) {
        let _ = batch_tx.send(batch);
        sent += 1;
    }
    sent + send_batches(batcher.flush(false), batch_tx)
}

fn send_batches(batches: Vec<Batch>, batch_tx: &mpsc::Sender<Batch>) -> usize {
    let n = batches.len();
    for b in batches {
        let _ = batch_tx.send(b);
    }
    n
}

/// Convert one flushed batch into engine prefill jobs plus the
/// response metadata, recording queue latency. Shared by the worker
/// threads and the generation scheduler's merge lane — both must
/// produce bit-identical jobs for a given batch.
fn batch_to_jobs(
    batch: Batch,
    router: &Router,
    lowrank_degree: usize,
    metrics: &Metrics,
) -> (Vec<AttnJob>, Vec<(u64, Instant)>, Backend) {
    let t0 = Instant::now();
    let n_reqs = batch.requests.len();
    let mut jobs = Vec::with_capacity(n_reqs);
    let mut meta = Vec::with_capacity(n_reqs);
    for req in batch.requests {
        metrics.record_queue(t0.duration_since(req.submitted_at));
        let (q, k, v) = match req.payload {
            Payload::Explicit { q, k, v } => (q, k, v),
            Payload::Synthetic { seed } => synthesize(req.seq_len, req.d_model, seed),
        };
        let spec = match batch.backend {
            Backend::Exact => BatchedBackend::Exact(ExactKernel::RowStream),
            Backend::ConvBasis => BatchedBackend::Strided(router.k_budget(q.rows())),
            Backend::LowRank => {
                BatchedBackend::LowRank(LowRankConfig::new(lowrank_degree, q.cols() as f64))
            }
        };
        jobs.push(AttnJob::causal(0, 0, q, k, v, spec));
        meta.push((req.id, req.submitted_at));
    }
    (jobs, meta, batch.backend)
}

/// Deliver one executed batch's outputs: per-request latency metrics,
/// completion counters, responses.
fn deliver_attn_outputs(
    outs: Vec<JobOutput>,
    meta: Vec<(u64, Instant)>,
    backend: Backend,
    metrics: &Metrics,
    tx: &mpsc::Sender<AttnResponse>,
) {
    for ((id, submitted_at), out) in meta.into_iter().zip(outs) {
        // Per-job wall time from the engine: exec latency percentiles
        // stay per-request under batching.
        metrics.record_exec(out.exec);
        metrics.record_e2e(submitted_at.elapsed());
        Metrics::incr(&metrics.requests_completed);
        let b = if out.fell_back { Backend::Exact } else { backend };
        let _ = tx.send(AttnResponse { id, y: out.y, backend: b, basis_k: out.basis_k });
    }
    Metrics::incr(&metrics.batches_executed);
}

/// Execute one batch standalone as a prefill-lane submit (worker
/// threads, and the generation scheduler when no decode is in flight).
fn execute_attn_batch(
    batch: Batch,
    router: &Router,
    lowrank_degree: usize,
    engine: &BatchedEngine,
    metrics: &Metrics,
    tx: &mpsc::Sender<AttnResponse>,
) {
    if batch.requests.is_empty() {
        return;
    }
    let (jobs, meta, backend) = batch_to_jobs(batch, router, lowrank_degree, metrics);
    let outs: Vec<JobOutput> = engine
        .submit(jobs.into_iter().enumerate().map(|(i, j)| EngineJob::prefill(i as u64, j)).collect())
        .into_iter()
        .map(|o| o.result.into_prefill())
        .collect();
    deliver_attn_outputs(outs, meta, backend, metrics, tx);
}

/// The generation scheduler's handle on the attention path: where to
/// drain flushed batches from, how to convert them (router policy),
/// and where their responses go.
struct GenLane {
    batch_rx: Arc<Mutex<mpsc::Receiver<Batch>>>,
    attn_tx: mpsc::Sender<AttnResponse>,
    router: Router,
    lowrank_degree: usize,
}

impl GenLane {
    /// Non-blocking drain of every currently flushed batch. Uses
    /// `try_lock`: an attention worker parks *holding* the receiver
    /// mutex while it waits for traffic, so a blocking lock here would
    /// stall the decode loop — and a held lock means a worker is
    /// already covering the queue. With `workers: 0` the lock is
    /// always free and this lane sees every batch.
    fn drain_pending(&self) -> Vec<Batch> {
        let mut out = Vec::new();
        if let Ok(rx) = self.batch_rx.try_lock() {
            while let Ok(b) = rx.try_recv() {
                out.push(b);
            }
        }
        out
    }
}

/// One in-flight generation, tracked next to its [`DecodeSession`]
/// (parallel vectors: `Transformer::decode_step` wants the sessions as
/// one contiguous `&mut [DecodeSession]`).
struct GenFlight {
    id: u64,
    prompt_len: usize,
    max_new: usize,
    generated: Vec<usize>,
    decode_steps: usize,
    submitted_at: Instant,
    stream: Option<GenSink>,
    /// Configured speculation depth γ for this request (server default
    /// unless overridden per request). Clamped per round to the token
    /// budget and `max_seq` room — see the γ_eff computation.
    speculate: usize,
}

impl GenFlight {
    /// Record one generated token (+ stream it when sinked).
    fn push_token(&mut self, token: usize, metrics: &Metrics) {
        if let Some(sink) = &self.stream {
            sink.emit(&GenEvent::Token { id: self.id, index: self.generated.len(), token });
        }
        self.generated.push(token);
        Metrics::incr(&metrics.gen_tokens);
    }
}

fn argmax(xs: &[f64]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// The generation scheduler body: admit (token-budget policy) →
/// prefill (batched) → one decode step for all in-flight sessions
/// (merging any flushed attention batches into the same engine
/// submit) → retire finished; repeat. Idle, it parks on the admission
/// queue's condvar — arrivals, dispatcher kicks (flushed attention
/// batches), and shutdown wake it. On shutdown it drains the waiting
/// line, decodes the remaining sequences to completion, and drains
/// the attention queue (flush semantics, like the worker path).
fn generation_loop(
    cfg: GenConfig,
    queue: &AdmissionQueue,
    resp_tx: mpsc::Sender<GenResponse>,
    engine: &BatchedEngine,
    metrics: &Metrics,
    lane: GenLane,
    cancel: &Mutex<BTreeSet<u64>>,
) {
    let model = cfg.model;
    let backend = cfg.backend;
    let max_concurrent = cfg.max_concurrent.max(1);
    let max_seq = model.cfg.max_seq;
    let mut sessions: Vec<DecodeSession> = Vec::new();
    let mut flights: Vec<GenFlight> = Vec::new();
    let mut kick_seen = 0u64;
    let mut steps_since_admit = 0usize;

    let respond = |flight: &GenFlight, resp_tx: &mpsc::Sender<GenResponse>| {
        Metrics::incr(&metrics.gen_completed);
        metrics.record_gen_e2e(flight.submitted_at.elapsed());
        match &flight.stream {
            Some(sink) => sink.emit(&GenEvent::Done {
                id: flight.id,
                prompt_len: flight.prompt_len,
                tokens: flight.generated.clone(),
                decode_steps: flight.decode_steps,
            }),
            None => {
                let _ = resp_tx.send(GenResponse {
                    id: flight.id,
                    prompt_len: flight.prompt_len,
                    status: GenStatus::Complete,
                    tokens: flight.generated.clone(),
                    decode_steps: flight.decode_steps,
                });
            }
        }
    };

    loop {
        // Idle: park until there is work (no timer polling). A kick
        // with no generation work means the dispatcher flushed
        // attention batches — serve them standalone (this lane is the
        // only executor when workers: 0; with workers the try_lock in
        // drain_pending defers to them).
        if sessions.is_empty() {
            match queue.wait_for_work(&mut kick_seen) {
                Wake::Shutdown => break,
                Wake::Work => {}
            }
            for batch in lane.drain_pending() {
                Metrics::add(&metrics.gen_lane_attn_requests, batch.requests.len() as u64);
                execute_attn_batch(
                    batch,
                    &lane.router,
                    lane.lowrank_degree,
                    engine,
                    metrics,
                    &lane.attn_tx,
                );
            }
        }

        // Admission: the token-budget policy decides how many waiting
        // requests join this wave (prompts were validated at the door,
        // so every admitted request prefills cleanly).
        let running_tokens: usize = sessions.iter().map(|s| s.len()).sum::<usize>()
            + flights.iter().map(|f| f.max_new.saturating_sub(f.generated.len())).sum::<usize>();
        let slots = max_concurrent.saturating_sub(sessions.len());
        let arrivals = queue.admit(sessions.len(), running_tokens, steps_since_admit, slots);

        if !arrivals.is_empty() {
            steps_since_admit = 0;
            // Batch-prefill the wave through the engine (one
            // prefill-lane submit per layer for ALL arrivals together).
            let prompts: Vec<Vec<usize>> = arrivals.iter().map(|r| r.prompt.clone()).collect();
            let prefilled = model.prefill_batch(&prompts, &backend, engine);
            for (r, (mut sess, last_logits)) in arrivals.into_iter().zip(prefilled) {
                sess.id = r.id;
                let mut flight = GenFlight {
                    id: r.id,
                    prompt_len: r.prompt.len(),
                    max_new: r.max_new_tokens,
                    generated: Vec::new(),
                    decode_steps: 0,
                    submitted_at: r.submitted_at,
                    stream: r.stream,
                    speculate: r.speculate.unwrap_or(cfg.speculate),
                };
                if flight.max_new >= 1 {
                    // The first token falls out of the prefill
                    // logits — no decode step needed for it.
                    flight.push_token(argmax(&last_logits), metrics);
                }
                if flight.generated.len() >= flight.max_new || sess.len() >= max_seq {
                    // Done straight out of prefill: release the KV
                    // bytes the prefill just accounted.
                    sess.retire(metrics);
                    respond(&flight, &resp_tx);
                } else {
                    sessions.push(sess);
                    flights.push(flight);
                }
            }
        }

        // Cancellation sweep: drop every in-flight sequence whose id
        // was parked by `Server::cancel_generate`. The whole set drains
        // each round — ids that match no flight belong to requests that
        // already finished (their terminal `Done` stands; cancel-after-
        // done is a no-op, preserving exactly-one-terminal-event).
        {
            let mut pending = lock(cancel);
            if !pending.is_empty() {
                for i in (0..flights.len()).rev() {
                    if !pending.remove(&flights[i].id) {
                        continue;
                    }
                    Metrics::incr(&metrics.gen_cancelled);
                    sessions[i].retire(metrics);
                    let f = &flights[i];
                    match &f.stream {
                        Some(sink) => sink.emit(&GenEvent::Cancelled { id: f.id }),
                        None => {
                            let _ = resp_tx.send(GenResponse {
                                id: f.id,
                                prompt_len: f.prompt_len,
                                status: GenStatus::Cancelled,
                                tokens: f.generated.clone(),
                                decode_steps: f.decode_steps,
                            });
                        }
                    }
                    flights.swap_remove(i);
                    sessions.swap_remove(i);
                }
                pending.clear();
            }
        }

        if sessions.is_empty() {
            continue;
        }

        // Merge lane: any attention batches the dispatcher has flushed
        // ride this decode step's engine submit instead of waiting for
        // a worker. Jobs are pure, so riders never change decode bits.
        let mut rider_jobs: Vec<AttnJob> = Vec::new();
        let mut rider_meta: Vec<(Vec<(u64, Instant)>, Backend, usize)> = Vec::new();
        for batch in lane.drain_pending() {
            let n_reqs = batch.requests.len();
            if n_reqs == 0 {
                continue;
            }
            Metrics::add(&metrics.gen_lane_attn_requests, n_reqs as u64);
            Metrics::add(&metrics.merged_attn_requests, n_reqs as u64);
            let (jobs, meta, b) = batch_to_jobs(batch, &lane.router, lane.lowrank_degree, metrics);
            rider_jobs.extend(jobs);
            rider_meta.push((meta, b, n_reqs));
        }

        steps_since_admit += 1;

        // Per-flight draft depth this round: the configured γ clamped
        // so the round's emissions stay within the token budget
        // (accepted + bonus ≤ remaining) and the γ_eff + 1 appended KV
        // rows stay within `max_seq`. Both clamp terms are ≥ 1 for an
        // in-flight sequence, so γ_eff is well defined (possibly 0).
        let gammas: Vec<usize> = flights
            .iter()
            .zip(&sessions)
            .map(|(f, s)| {
                let remaining = f.max_new - f.generated.len();
                let room = max_seq - s.len();
                f.speculate.min(remaining - 1).min(room - 1)
            })
            .collect();

        if gammas.iter().all(|&g| g == 0) {
            // γ = 0 everywhere: the identity — this arm is the plain
            // pre-speculation scheduler step, bit for bit and counter
            // for counter (no draft, no verify, no spec_* increments).
            //
            // One decode step for every in-flight sequence: feed each
            // its latest generated token, get the next token's logits.
            let next: Vec<usize> = flights
                .iter()
                .map(|f| *f.generated.last().expect("prefill seeded every flight with a token"))
                .collect();
            let (logits, rider_outs) =
                model.decode_step_with_jobs(&mut sessions, &next, engine, rider_jobs);
            // Deliver rider responses batch by batch (input order holds).
            let mut rest = rider_outs.into_iter();
            for (meta, b, n_reqs) in rider_meta {
                let outs: Vec<JobOutput> = rest.by_ref().take(n_reqs).collect();
                deliver_attn_outputs(outs, meta, b, metrics, &lane.attn_tx);
            }
            for i in (0..flights.len()).rev() {
                let f = &mut flights[i];
                f.decode_steps += 1;
                f.push_token(argmax(&logits[i]), metrics);
                if f.generated.len() >= f.max_new || sessions[i].len() >= max_seq {
                    sessions[i].retire(metrics);
                    respond(&flights[i], &resp_tx);
                    flights.swap_remove(i);
                    sessions.swap_remove(i);
                }
            }
        } else {
            // Speculative round: draft γ_eff tokens per flight through
            // the cheap serving decode path, then verify every drafted
            // position plus one bonus in a SINGLE exact prefill-lane
            // forward over all speculating sessions, keep each flight's
            // longest accepted prefix. Greedy + exact verify ⇒ the
            // emitted stream is the exact-greedy oracle's, token for
            // token, regardless of what the drafter produced.
            //
            // Sort the parallel vectors by γ_eff descending so every
            // draft sub-step's active set is a prefix of the batch
            // (order is not load-bearing: retirement uses swap_remove
            // and events are per-flight).
            let mut order: Vec<usize> = (0..flights.len()).collect();
            order.sort_by_key(|&i| std::cmp::Reverse(gammas[i]));
            let mut old_sessions: Vec<Option<DecodeSession>> =
                sessions.drain(..).map(Some).collect();
            let mut old_flights: Vec<Option<GenFlight>> = flights.drain(..).map(Some).collect();
            let mut gam: Vec<usize> = Vec::with_capacity(order.len());
            for &i in &order {
                sessions.push(old_sessions[i].take().expect("order permutes each index once"));
                flights.push(old_flights[i].take().expect("order permutes each index once"));
                gam.push(gammas[i]);
            }
            let gmax = gam[0];

            // Draft: γ_eff + 1 decode sub-steps per speculating flight.
            // Sub-step 0 feeds the still-unfed latest token (exactly
            // like a plain step); its logits are draft d_1. Sub-step t
            // feeds d_t; for t < γ_eff the logits are d_{t+1}, and the
            // final sub-step's logits are discarded — it exists only to
            // append d_γ's KV row so the verifier sees every drafted
            // position. γ_eff = 0 flights ride sub-step 0 as their
            // plain decode step and take its logits directly. Riders
            // attach to sub-step 0 only.
            let mut drafts: Vec<Vec<usize>> = vec![Vec::new(); flights.len()];
            let mut riders = Some((rider_jobs, rider_meta));
            for t in 0..=gmax {
                // Active prefix: flights still inside their own γ_eff+1
                // draft sub-steps (gam is sorted descending).
                let m = gam.iter().take_while(|&&g| g >= t).count();
                if m == 0 {
                    break;
                }
                let next: Vec<usize> = (0..m)
                    .map(|i| {
                        if t == 0 {
                            *flights[i]
                                .generated
                                .last()
                                .expect("prefill seeded every flight with a token")
                        } else {
                            *drafts[i].last().expect("sub-step t > 0 pushed a draft for i < m")
                        }
                    })
                    .collect();
                let (rj, rm) = match riders.take() {
                    Some((jobs, meta)) => (jobs, meta),
                    None => (Vec::new(), Vec::new()),
                };
                let (logits, rider_outs) =
                    model.decode_step_with_jobs(&mut sessions[..m], &next, engine, rj);
                let mut rest = rider_outs.into_iter();
                for (meta, b, n_reqs) in rm {
                    let outs: Vec<JobOutput> = rest.by_ref().take(n_reqs).collect();
                    deliver_attn_outputs(outs, meta, b, metrics, &lane.attn_tx);
                }
                for i in 0..m {
                    flights[i].decode_steps += 1;
                    if gam[i] == 0 {
                        flights[i].push_token(argmax(&logits[i]), metrics);
                    } else if t < gam[i] {
                        drafts[i].push(argmax(&logits[i]));
                    }
                }
            }

            // Verify: one exact batched forward over every speculating
            // session (one prefill-lane submit per layer for ALL of
            // them). Row i of an exact causal forward is bit-identical
            // to the last row of the length-i+1 prefix's forward (rows
            // are causally independent), so rows base..base+γ are
            // exactly the greedy oracle's logits at each drafted
            // position plus the bonus.
            let spec_n = gam.iter().take_while(|&&g| g > 0).count();
            let seqs: Vec<Vec<usize>> =
                sessions[..spec_n].iter().map(|s| s.tokens().to_vec()).collect();
            let exact = AttentionBackend::Exact(ExactKernel::RowStream);
            let recs = model.forward_batch(&seqs, &exact, engine);
            for (i, rec) in recs.iter().enumerate() {
                let g = gam[i];
                let n_total = sessions[i].len();
                // Session length before this round was base + 1; the
                // verified positions start at the row that predicts the
                // first draft.
                let base = n_total - g - 1;
                let mut accepted = 0;
                while accepted < g
                    && argmax(rec.logits.row(base + accepted)) == drafts[i][accepted]
                {
                    accepted += 1;
                }
                let bonus = argmax(rec.logits.row(base + accepted));
                // Rollback: drop the rejected drafts' KV rows. Always a
                // pure truncation — drafting only ever appends, so the
                // "every resident row was fed" invariant is restored
                // exactly (full acceptance truncates nothing).
                model.truncate_session(&mut sessions[i], base + 1 + accepted, engine);
                Metrics::incr(&metrics.spec_rounds);
                Metrics::add(&metrics.spec_drafted, g as u64);
                Metrics::add(&metrics.spec_accepted, accepted as u64);
                for t in 0..accepted {
                    flights[i].push_token(drafts[i][t], metrics);
                }
                // The bonus token is free: the verifier's logits at the
                // last accepted position are the oracle's next-token
                // distribution. It also guarantees ≥ 1 emission per
                // round — no livelock even when every draft rejects.
                flights[i].push_token(bonus, metrics);
            }

            for i in (0..flights.len()).rev() {
                if flights[i].generated.len() >= flights[i].max_new
                    || sessions[i].len() >= max_seq
                {
                    sessions[i].retire(metrics);
                    respond(&flights[i], &resp_tx);
                    flights.swap_remove(i);
                    sessions.swap_remove(i);
                }
            }
        }
    }

    // Shutdown drain: serve whatever the dispatcher still flushes until
    // it closes the queue. With worker threads present they compete for
    // the same receiver — either executor is correct; with workers: 0
    // this is the only path that honours flush semantics.
    loop {
        let batch = { lock(&lane.batch_rx).recv() };
        match batch {
            Ok(batch) => {
                Metrics::add(&metrics.gen_lane_attn_requests, batch.requests.len() as u64);
                execute_attn_batch(
                    batch,
                    &lane.router,
                    lane.lowrank_degree,
                    engine,
                    metrics,
                    &lane.attn_tx,
                );
            }
            Err(_) => break,
        }
    }
}

fn synthesize(seq_len: usize, d_model: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
    let mut rng = Rng::seeded(seed);
    let freqs = (d_model / 2).min(4).max(1);
    let (q, k) = rope_structured_qk(seq_len, d_model, freqs, &mut rng);
    let v = Matrix::randn(seq_len, d_model, &mut rng);
    (q, k, v)
}

/// Drive a whole workload trace through a server, honouring arrival
/// times scaled by `time_scale` (0 = as fast as possible). Returns
/// responses sorted by id.
pub fn run_trace(
    server: &Server,
    trace: &crate::data::WorkloadTrace,
    time_scale: f64,
) -> Vec<AttnResponse> {
    let t0 = Instant::now();
    for r in &trace.requests {
        if time_scale > 0.0 {
            let due = std::time::Duration::from_micros((r.arrival_us as f64 * time_scale) as u64);
            let elapsed = t0.elapsed();
            if due > elapsed {
                thread::sleep(due - elapsed);
            }
        }
        server.submit(AttnRequest {
            id: r.id,
            seq_len: r.seq_len,
            d_model: r.d_model,
            bounded_entries: false,
            backend: None,
            payload: Payload::Synthetic { seed: r.id % 16 }, // repeats → cache hits
            submitted_at: Instant::now(),
        });
    }
    let mut out = server.collect(trace.requests.len());
    out.sort_by_key(|r| r.id);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::{exact_attention, Mask};
    use crate::data::{WorkloadConfig, WorkloadTrace};

    fn small_server() -> Server {
        Server::start(ServerConfig {
            router: RouterConfig { exact_below: 64, ..Default::default() },
            batcher: BatcherConfig { max_batch: 4, max_wait: std::time::Duration::from_millis(1) },
            workers: 2,
            cache_capacity: 16,
            lowrank_degree: 2,
            gen: None,
        })
    }

    fn gen_server(backend: AttentionBackend, model: Arc<Transformer>) -> Server {
        spec_server(backend, model, 0)
    }

    fn spec_server(backend: AttentionBackend, model: Arc<Transformer>, speculate: usize) -> Server {
        Server::start(ServerConfig {
            gen: Some(GenConfig {
                model,
                backend,
                max_concurrent: 4,
                admission: AdmissionConfig::default(),
                speculate,
            }),
            cache_capacity: 256,
            ..Default::default()
        })
    }

    fn req(id: u64, n: usize) -> AttnRequest {
        AttnRequest {
            id,
            seq_len: n,
            d_model: 8,
            bounded_entries: false,
            backend: None,
            payload: Payload::Synthetic { seed: id },
            submitted_at: Instant::now(),
        }
    }

    fn tiny_model(seed: u64) -> Arc<Transformer> {
        let mut rng = Rng::seeded(seed);
        Arc::new(Transformer::new(&crate::model::ModelConfig::tiny(64), &mut rng))
    }

    /// Greedy-generation oracle: full re-prefill per token through
    /// `Transformer::forward` (what the decode path must reproduce).
    fn generate_by_reprefill(
        model: &Transformer,
        prompt: &[usize],
        max_new: usize,
        backend: &AttentionBackend,
    ) -> Vec<usize> {
        let mut toks = prompt.to_vec();
        let mut out = Vec::new();
        for _ in 0..max_new {
            let rec = model.forward(&toks, backend, false);
            let row = rec.logits.row(toks.len() - 1);
            let mut best = 0;
            for (i, &x) in row.iter().enumerate() {
                if x > row[best] {
                    best = i;
                }
            }
            out.push(best);
            if toks.len() == model.cfg.max_seq {
                break;
            }
            toks.push(best);
        }
        out
    }

    #[test]
    fn serves_explicit_request_exactly() {
        let server = small_server();
        let mut rng = Rng::seeded(231);
        let (n, d) = (32, 8);
        let q = Matrix::randn(n, d, &mut rng).scale(0.3);
        let k = Matrix::randn(n, d, &mut rng).scale(0.3);
        let v = Matrix::randn(n, d, &mut rng);
        let want = exact_attention(&q, &k, &v, &Mask::causal(n));
        server.submit(AttnRequest {
            id: 7,
            seq_len: n,
            d_model: d,
            bounded_entries: false,
            backend: None,
            payload: Payload::Explicit { q, k, v },
            submitted_at: Instant::now(),
        });
        let resps = server.collect(1);
        assert_eq!(resps[0].id, 7);
        assert_eq!(resps[0].backend, Backend::Exact);
        assert!(crate::tensor::max_abs_diff(&resps[0].y, &want) < 1e-10);
        server.shutdown();
    }

    #[test]
    fn all_trace_requests_complete_once() {
        let server = small_server();
        let cfg = WorkloadConfig {
            rate_per_s: 10_000.0,
            len_buckets: [32, 48, 96, 128],
            len_weights: [0.4, 0.3, 0.2, 0.1],
            d_model: 8,
        };
        let trace = WorkloadTrace::generate(40, &cfg, 5);
        let resps = run_trace(&server, &trace, 0.0);
        assert_eq!(resps.len(), 40);
        let ids: Vec<u64> = resps.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..40).collect::<Vec<_>>());
        let m = server.shutdown();
        let s = m.snapshot();
        assert_eq!(s.requests_completed, 40);
        assert_eq!(s.requests_submitted, 40);
    }

    #[test]
    fn conv_path_hits_cache_on_repeats() {
        let server = small_server();
        // Same synthetic seed ⇒ same (Q,K) fingerprint ⇒ cache hits.
        for i in 0..6u64 {
            server.submit(AttnRequest {
                id: i,
                seq_len: 96, // ≥ exact_below ⇒ conv
                d_model: 8,
                bounded_entries: false,
                backend: None,
                payload: Payload::Synthetic { seed: 1 },
                submitted_at: Instant::now(),
            });
        }
        let resps = server.collect(6);
        assert_eq!(resps.len(), 6);
        let m = server.shutdown();
        let s = m.snapshot();
        assert!(s.cache_hits >= 1, "cache hits = {}", s.cache_hits);
        assert!(s.conv_requests == 6);
    }

    #[test]
    fn explicit_backend_overrides_the_router() {
        let server = small_server();
        let (q, k, v) = synthesize(128, 8, 3);
        let want = exact_attention(&q, &k, &v, &Mask::causal(128));
        // 128 ≥ exact_below would route to conv; the override pins exact.
        server.submit(AttnRequest {
            id: 0,
            seq_len: 128,
            d_model: 8,
            bounded_entries: false,
            backend: Some(Backend::Exact),
            payload: Payload::Explicit { q, k, v },
            submitted_at: Instant::now(),
        });
        let resp = &server.collect(1)[0];
        assert_eq!(resp.backend, Backend::Exact);
        assert_eq!(resp.basis_k, 0);
        assert_eq!(crate::tensor::max_abs_diff(&resp.y, &want), 0.0, "exact path, exact bits");
        let s = server.shutdown().snapshot();
        assert_eq!(s.exact_requests, 1);
        assert_eq!(s.conv_requests, 0, "the router never saw this request");
    }

    #[test]
    fn conv_and_exact_agree_on_structured_payloads() {
        let server = small_server();
        let (q, k, v) = synthesize(128, 8, 3);
        let want = exact_attention(&q, &k, &v, &Mask::causal(128));
        server.submit(AttnRequest {
            id: 0,
            seq_len: 128,
            d_model: 8,
            bounded_entries: false,
            backend: None,
            payload: Payload::Explicit { q, k, v },
            submitted_at: Instant::now(),
        });
        let resp = &server.collect(1)[0];
        assert_eq!(resp.backend, Backend::ConvBasis);
        assert!(resp.basis_k >= 1);
        let err = crate::tensor::max_abs_diff(&resp.y, &want);
        assert!(err < 1e-6, "err = {err}");
        server.shutdown();
    }

    #[test]
    fn generation_matches_reprefill_oracle_without_reprefilling() {
        // The server must produce exactly the tokens a per-token
        // re-prefill loop produces (exact decode bit-matches prefill),
        // while the metrics prove it never re-prefilled.
        let model = tiny_model(41);
        let server = gen_server(AttentionBackend::Exact(ExactKernel::RowStream), model.clone());
        let prompts: [&[usize]; 3] = [&[1, 2, 3, 4], &[9, 8, 7], &[5, 5, 5, 5, 5, 5]];
        let max_new = 6;
        for (i, p) in prompts.iter().enumerate() {
            server.submit_generate(GenRequest::new(i as u64, p.to_vec(), max_new));
        }
        let mut resps = server.collect_generations(prompts.len());
        resps.sort_by_key(|r| r.id);
        let metrics = server.shutdown();
        for (i, p) in prompts.iter().enumerate() {
            let exact = AttentionBackend::Exact(ExactKernel::RowStream);
            let want = generate_by_reprefill(&model, p, max_new, &exact);
            assert_eq!(resps[i].tokens, want, "prompt {i}");
            assert_eq!(resps[i].prompt_len, p.len());
            assert_eq!(resps[i].decode_steps, max_new - 1);
        }
        let s = metrics.snapshot();
        assert_eq!(s.gen_requests, 3);
        assert_eq!(s.gen_completed, 3);
        assert_eq!(s.gen_tokens, 3 * max_new as u64);
        // Decode really went through the engine's decode path…
        let n_layers = model.cfg.n_layers as u64;
        let n_heads = model.cfg.n_heads as u64;
        assert_eq!(s.decode_steps, 3 * (max_new as u64 - 1) * n_layers * n_heads);
        // …and prefill cost was paid at most once per admission wave
        // per layer (≤ 3 waves × layers calls), not once per token.
        assert!(
            s.batched_calls <= 3 * n_layers,
            "per-token re-prefill detected: {} prefill-lane submits",
            s.batched_calls
        );
    }

    #[test]
    fn conv_generation_decodes_through_cached_bases() {
        let model = tiny_model(42);
        let server = gen_server(AttentionBackend::ConvStrided(4), model.clone());
        server.submit_generate(GenRequest::new(0, vec![1, 2, 3, 4, 5, 6, 7, 8], 5));
        let resps = server.collect_generations(1);
        assert_eq!(resps[0].tokens.len(), 5);
        let s = server.shutdown().snapshot();
        let per_step = (model.cfg.n_layers * model.cfg.n_heads) as u64;
        // Prefill seeded every (layer, head) state from the cache the
        // prefill jobs had just filled — zero extra recoveries.
        assert_eq!(s.decode_seed_hits, per_step, "seeding must hit the prefill's bases");
        assert_eq!(s.decode_seed_misses, 0);
        assert_eq!(s.decode_steps, 4 * per_step);
        assert_eq!(s.gen_tokens, 5);
    }

    #[test]
    fn zero_workers_serves_attention_through_gen_lane() {
        // workers: 0 + gen spawns no attention workers: every attention
        // batch must flow through the generation scheduler's lane —
        // merged into a decode submit while sequences are in flight,
        // standalone otherwise — and the responses must stay exact.
        let model = tiny_model(45);
        let server = Server::start(ServerConfig {
            router: RouterConfig { exact_below: 64, ..Default::default() },
            batcher: BatcherConfig {
                max_batch: 1, // flush every request immediately
                max_wait: std::time::Duration::from_millis(1),
            },
            workers: 0,
            cache_capacity: 16,
            lowrank_degree: 2,
            gen: Some(GenConfig {
                model: model.clone(),
                backend: AttentionBackend::Exact(ExactKernel::RowStream),
                max_concurrent: 2,
                admission: AdmissionConfig::default(),
                speculate: 0,
            }),
        });
        // A long-ish generation keeps the decode loop hot while the
        // attention requests arrive.
        server.submit_generate(GenRequest::new(99, vec![1, 2, 3], 12));
        let mut rng = Rng::seeded(451);
        let (n, d) = (24, 8);
        let mut oracles = Vec::new();
        for i in 0..4u64 {
            let q = Matrix::randn(n, d, &mut rng).scale(0.3);
            let k = Matrix::randn(n, d, &mut rng).scale(0.3);
            let v = Matrix::randn(n, d, &mut rng);
            oracles.push(exact_attention(&q, &k, &v, &Mask::causal(n)));
            server.submit(AttnRequest {
                id: i,
                seq_len: n,
                d_model: d,
                bounded_entries: false,
                backend: None,
                payload: Payload::Explicit { q, k, v },
                submitted_at: Instant::now(),
            });
        }
        let mut resps = server.collect(4);
        resps.sort_by_key(|r| r.id);
        for (resp, want) in resps.iter().zip(&oracles) {
            assert_eq!(resp.backend, Backend::Exact);
            assert!(crate::tensor::max_abs_diff(&resp.y, want) < 1e-10);
        }
        let gens = server.collect_generations(1);
        assert_eq!(gens[0].tokens.len(), 12);
        let s = server.shutdown().snapshot();
        assert_eq!(s.requests_completed, 4);
        assert_eq!(
            s.gen_lane_attn_requests, 4,
            "with zero workers every attention request must ride the gen lane \
             (merged: {})",
            s.merged_attn_requests
        );
        // All sessions retired ⇒ the KV gauge must return to zero.
        assert_eq!(s.decode_resident_bytes, 0);
    }

    #[test]
    fn generation_truncates_at_max_seq_and_rejects_invalid() {
        let model = tiny_model(43);
        let max_seq = model.cfg.max_seq; // 64
        let server = gen_server(AttentionBackend::Exact(ExactKernel::RowStream), model.clone());
        // Asks for more tokens than max_seq leaves room for.
        let prompt: Vec<usize> = (0..60).map(|i| (i % 11) + 1).collect();
        server.submit_generate(GenRequest::new(0, prompt.clone(), 50));
        // Empty and over-long prompts are rejected whole.
        server.submit_generate(GenRequest::new(1, vec![], 4));
        server.submit_generate(GenRequest::new(2, vec![1; max_seq + 1], 4));
        let mut resps = server.collect_generations(3);
        resps.sort_by_key(|r| r.id);
        server.shutdown();
        // 60-token prompt: 1 prefill token + (64−60) steps = 5 tokens.
        assert_eq!(resps[0].tokens.len(), max_seq - prompt.len() + 1);
        assert_eq!(resps[0].status, GenStatus::Complete);
        assert!(resps[1].tokens.is_empty());
        assert!(resps[2].tokens.is_empty());
        assert_eq!(resps[1].status, GenStatus::Rejected);
        assert_eq!(resps[2].status, GenStatus::Rejected);
    }

    #[test]
    fn rejections_stay_out_of_completion_metrics() {
        // Regression: rejected generations used to flow through the
        // same respond path as completions, inflating `gen_completed`
        // and the gen-e2e latency series, and they occupied admission
        // slots until their (empty) response was built. Now they are
        // refused at the door: `gen_rejected` counts them, everything
        // else stays clean.
        let model = tiny_model(46);
        let server = gen_server(AttentionBackend::Exact(ExactKernel::RowStream), model);
        server.submit_generate(GenRequest::new(0, vec![1, 2, 3], 4));
        server.submit_generate(GenRequest::new(1, vec![], 4)); // reject: empty
        server.submit_generate(GenRequest::new(2, vec![1; 65], 4)); // reject: > max_seq
        server.submit_generate(GenRequest::new(3, vec![4, 5], 4));
        server.submit_generate(GenRequest::new(4, vec![], 4)); // reject: empty
        let mut resps = server.collect_generations(5);
        resps.sort_by_key(|r| r.id);
        let s = server.shutdown().snapshot();
        assert_eq!(s.gen_requests, 5);
        assert_eq!(s.gen_completed, 2, "only real generations count as completed");
        assert_eq!(s.gen_rejected, 3);
        assert_eq!(s.gen_e2e.count, 2, "rejections must not pollute the latency series");
        assert_eq!(s.gen_tokens, 2 * 4);
        for r in &resps {
            match r.id {
                1 | 2 | 4 => {
                    assert_eq!(r.status, GenStatus::Rejected);
                    assert!(r.tokens.is_empty());
                }
                _ => {
                    assert_eq!(r.status, GenStatus::Complete);
                    assert_eq!(r.tokens.len(), 4);
                }
            }
        }
    }

    #[test]
    fn out_of_vocab_prompt_is_rejected_not_panicked() {
        // Regression: a prompt token ≥ vocab_size passed the old door
        // validation (length-only) and panicked the embedding lookup
        // inside the scheduler thread — a wire-reachable crash via
        // {"op":"generate","prompt":[999999],...}. The door now rejects
        // it and the scheduler keeps serving valid requests.
        let model = tiny_model(47);
        let vocab = model.cfg.vocab_size;
        let server = gen_server(AttentionBackend::Exact(ExactKernel::RowStream), model);
        server.submit_generate(GenRequest::new(0, vec![1, 2, 3], 4));
        server.submit_generate(GenRequest::new(1, vec![1, vocab, 2], 4)); // reject
        server.submit_generate(GenRequest::new(2, vec![999_999], 4)); // reject
        server.submit_generate(GenRequest::new(3, vec![vocab - 1], 4)); // max valid id
        let mut resps = server.collect_generations(4);
        resps.sort_by_key(|r| r.id);
        let s = server.shutdown().snapshot();
        assert_eq!(s.gen_requests, 4);
        assert_eq!(s.gen_completed, 2, "scheduler survived and served the valid requests");
        assert_eq!(s.gen_rejected, 2);
        for r in &resps {
            match r.id {
                1 | 2 => assert_eq!(r.status, GenStatus::Rejected),
                _ => {
                    assert_eq!(r.status, GenStatus::Complete);
                    assert_eq!(r.tokens.len(), 4);
                }
            }
        }
    }

    #[test]
    fn streaming_sink_receives_tokens_then_done() {
        let model = tiny_model(47);
        let server = gen_server(AttentionBackend::Exact(ExactKernel::RowStream), model.clone());
        let events: Arc<Mutex<Vec<GenEvent>>> = Arc::new(Mutex::new(Vec::new()));
        let ev = events.clone();
        let sink = GenSink::new(move |e| ev.lock().unwrap().push(e.clone()));
        server.submit_generate(GenRequest::new(5, vec![1, 2, 3], 6).with_stream(sink));
        // Sinked requests answer through events, not the channel —
        // shutdown drains the scheduler first.
        let s = server.shutdown().snapshot();
        assert_eq!(s.gen_completed, 1);
        let evs = events.lock().unwrap();
        let toks: Vec<(usize, usize)> = evs
            .iter()
            .filter_map(|e| match e {
                GenEvent::Token { index, token, .. } => Some((*index, *token)),
                _ => None,
            })
            .collect();
        let exact = AttentionBackend::Exact(ExactKernel::RowStream);
        let want = generate_by_reprefill(&model, &[1, 2, 3], 6, &exact);
        assert_eq!(toks.iter().map(|t| t.0).collect::<Vec<_>>(), (0..6).collect::<Vec<_>>());
        assert_eq!(toks.iter().map(|t| t.1).collect::<Vec<_>>(), want);
        match evs.last().unwrap() {
            GenEvent::Done { id, tokens, .. } => {
                assert_eq!(*id, 5);
                assert_eq!(tokens, &want, "Done must repeat the streamed tokens");
            }
            other => panic!("expected terminal Done, got {other:?}"),
        }
    }

    #[test]
    fn speculative_generation_matches_oracle_with_fewer_decode_submits() {
        // Exact backend: exact decode bit-matches re-prefill, so every
        // draft is accepted and each round emits γ_eff + 1 tokens. The
        // stream must equal the plain greedy oracle's while the decode
        // lane runs strictly fewer steps than tokens generated.
        let model = tiny_model(51);
        let server = spec_server(AttentionBackend::Exact(ExactKernel::RowStream), model.clone(), 3);
        let prompts: [&[usize]; 2] = [&[1, 2, 3, 4], &[9, 8, 7]];
        let max_new = 9;
        for (i, p) in prompts.iter().enumerate() {
            server.submit_generate(GenRequest::new(i as u64, p.to_vec(), max_new));
        }
        let mut resps = server.collect_generations(prompts.len());
        resps.sort_by_key(|r| r.id);
        let s = server.shutdown().snapshot();
        for (i, p) in prompts.iter().enumerate() {
            let exact = AttentionBackend::Exact(ExactKernel::RowStream);
            let want = generate_by_reprefill(&model, p, max_new, &exact);
            assert_eq!(resps[i].tokens, want, "prompt {i}");
        }
        assert_eq!(s.gen_tokens, (prompts.len() * max_new) as u64);
        assert!(s.spec_rounds >= 1, "γ = 3 must speculate");
        assert_eq!(s.spec_accepted, s.spec_drafted, "exact drafts always verify");
        // Emission accounting: prefill emits one token per request,
        // every speculative round emits accepted + 1 (the bonus).
        assert_eq!(s.gen_tokens, prompts.len() as u64 + s.spec_accepted + s.spec_rounds);
        let per_step = (model.cfg.n_layers * model.cfg.n_heads) as u64;
        assert!(
            s.decode_steps / per_step < s.gen_tokens,
            "speculation must amortise: {} decode sub-steps for {} tokens",
            s.decode_steps / per_step,
            s.gen_tokens
        );
    }

    #[test]
    fn cancel_drops_queued_and_inflight_generations() {
        let model = tiny_model(50);
        let server = Server::start(ServerConfig {
            gen: Some(GenConfig {
                model,
                backend: AttentionBackend::Exact(ExactKernel::RowStream),
                max_concurrent: 1, // forces the second request to queue
                admission: AdmissionConfig::default(),
                speculate: 0,
            }),
            ..Default::default()
        });
        // Request 7 streams through a sink that parks the scheduler
        // after the first token, giving this thread a deterministic
        // window to issue cancellations.
        let events: Arc<Mutex<Vec<GenEvent>>> = Arc::new(Mutex::new(Vec::new()));
        let gate = Arc::new((Mutex::new(false), std::sync::Condvar::new()));
        let started = Arc::new((Mutex::new(false), std::sync::Condvar::new()));
        let (ev, g, st) = (events.clone(), gate.clone(), started.clone());
        let sink = GenSink::new(move |e| {
            ev.lock().unwrap().push(e.clone());
            if matches!(e, GenEvent::Token { index: 0, .. }) {
                *st.0.lock().unwrap() = true;
                st.1.notify_all();
                let mut open = g.0.lock().unwrap();
                while !*open {
                    open = g.1.wait(open).unwrap();
                }
            }
        });
        server.submit_generate(GenRequest::new(7, vec![1, 2, 3], 30).with_stream(sink));
        {
            let mut s = started.0.lock().unwrap();
            while !*s {
                s = started.1.wait(s).unwrap();
            }
        }
        // Request 8 cannot be admitted while 7 holds the only slot:
        // cancelling it takes the queued path and answers immediately.
        server.submit_generate(GenRequest::new(8, vec![4, 5, 6], 30));
        server.cancel_generate(8);
        let resp = server.collect_generations(1);
        assert_eq!(resp[0].id, 8);
        assert_eq!(resp[0].status, GenStatus::Cancelled);
        assert!(resp[0].tokens.is_empty());
        // Cancel in-flight 7 (plus an unknown id — must be a no-op),
        // then release the scheduler; the next round's sweep retires it
        // with a terminal Cancelled, never a Done.
        server.cancel_generate(7);
        server.cancel_generate(999);
        *gate.0.lock().unwrap() = true;
        gate.1.notify_all();
        let s = server.shutdown().snapshot();
        assert_eq!(s.gen_cancelled, 2);
        assert_eq!(s.gen_completed, 0, "cancelled requests are not completions");
        assert_eq!(s.gen_e2e.count, 0, "cancellations must not pollute latency");
        assert_eq!(s.decode_resident_bytes, 0, "cancellation must free the session KV");
        assert_eq!(s.queue_depth, 0);
        let evs = events.lock().unwrap();
        assert!(
            matches!(evs.last().unwrap(), GenEvent::Cancelled { id: 7 }),
            "terminal must be Cancelled, got {:?}",
            evs.last().unwrap()
        );
        assert!(evs.iter().all(|e| !matches!(e, GenEvent::Done { .. })));
        assert_eq!(
            evs.iter().filter(|e| matches!(e, GenEvent::Cancelled { .. })).count(),
            1,
            "exactly one terminal event"
        );
    }

    #[test]
    fn token_budget_admission_serves_all_requests_in_waves() {
        // Tight budgets force multiple admission waves; every request
        // must still complete and the queue gauge must drain to zero.
        let model = tiny_model(48);
        let server = Server::start(ServerConfig {
            gen: Some(GenConfig {
                model,
                backend: AttentionBackend::Exact(ExactKernel::RowStream),
                max_concurrent: 8,
                admission: AdmissionConfig {
                    max_batch_prefill_tokens: 8,
                    max_batch_total_tokens: 24,
                    waiting_served_ratio: 1.0,
                    max_waiting_steps: 1,
                    max_queue: 64,
                },
                speculate: 0,
            }),
            cache_capacity: 64,
            ..Default::default()
        });
        for i in 0..10u64 {
            server.submit_generate(GenRequest::new(i, vec![1, 2, 3, 4], 4));
        }
        let resps = server.collect_generations(10);
        assert_eq!(resps.len(), 10);
        assert!(resps.iter().all(|r| r.status == GenStatus::Complete && r.tokens.len() == 4));
        let s = server.shutdown().snapshot();
        assert_eq!(s.gen_completed, 10);
        assert_eq!(s.shed_requests, 0);
        assert_eq!(s.queue_depth, 0, "admission gauge must drain to zero");
    }

    #[test]
    fn full_admission_queue_sheds_with_busy() {
        let model = tiny_model(49);
        let server = Server::start(ServerConfig {
            gen: Some(GenConfig {
                model,
                backend: AttentionBackend::Exact(ExactKernel::RowStream),
                max_concurrent: 1,
                admission: AdmissionConfig { max_queue: 1, ..Default::default() },
                speculate: 0,
            }),
            ..Default::default()
        });
        // Burst far past queue + concurrency: some must shed, every id
        // must still get exactly one (terminal) response.
        let n = 8u64;
        for i in 0..n {
            server.submit_generate(GenRequest::new(i, vec![1, 2, 3], 8));
        }
        let resps = server.collect_generations(n as usize);
        let busy = resps.iter().filter(|r| r.status == GenStatus::Busy).count() as u64;
        let done = resps.iter().filter(|r| r.status == GenStatus::Complete).count() as u64;
        assert_eq!(busy + done, n, "every request answered, none silently dropped");
        let s = server.shutdown().snapshot();
        assert!(s.shed_requests >= 1, "burst of {n} through a 1-deep queue must shed");
        assert_eq!(s.shed_requests, busy);
        assert_eq!(s.gen_completed, done);
    }

    #[test]
    fn shutdown_finishes_inflight_generations() {
        // Immediate shutdown after submitting: the scheduler must
        // drain every queued request to completion before exiting
        // (flush semantics, mirroring the attention path).
        let model = tiny_model(44);
        let server = gen_server(AttentionBackend::Exact(ExactKernel::RowStream), model);
        for i in 0..5u64 {
            server.submit_generate(GenRequest::new(i, vec![1, 2, 3], 8));
        }
        let s = server.shutdown().snapshot();
        assert_eq!(s.gen_completed, 5);
        assert_eq!(s.gen_tokens, 5 * 8);
    }

    #[test]
    fn dispatcher_flushes_due_groups_on_push() {
        // Regression (flush starvation): the old dispatcher flushed due
        // groups only in the `recv_timeout` Timeout arm, so a steady
        // request stream — which never lets the recv time out — starved
        // a lone due batch in another bucket indefinitely. The
        // per-request body must emit due groups on every push.
        let router = Router::new(RouterConfig { exact_below: 64, ..Default::default() });
        let metrics = Metrics::new();
        let mut batcher = DynamicBatcher::new(BatcherConfig {
            max_batch: 64, // never fills on this traffic
            max_wait: Duration::from_millis(5),
        });
        let (tx, rx) = mpsc::channel();
        // Lone conv-bucket request (seq 96)…
        handle_request(&mut batcher, &router, &metrics, req(1000, 96), &tx);
        // …then a steady exact-bucket stream (seq 32), each arrival
        // well inside its own deadline, running past the lone
        // request's max_wait.
        for i in 0..5 {
            std::thread::sleep(Duration::from_millis(2));
            handle_request(&mut batcher, &router, &metrics, req(i, 32), &tx);
        }
        let batches: Vec<Batch> = rx.try_iter().collect();
        assert!(
            batches.iter().any(|b| b.requests.iter().any(|r| r.id == 1000)),
            "due conv-bucket batch was starved by the exact-bucket stream"
        );
    }

    #[test]
    fn steady_stream_does_not_starve_other_bucket() {
        // Server-level version of the starvation regression: a lone
        // conv-bucket request under a continuous exact-bucket stream
        // must complete within its max_wait (plus slack), not when the
        // stream stops.
        let server = Server::start(ServerConfig {
            router: RouterConfig { exact_below: 64, ..Default::default() },
            batcher: BatcherConfig {
                max_batch: 1000, // never fills: only flushing can emit
                max_wait: Duration::from_millis(3),
            },
            workers: 2,
            cache_capacity: 16,
            lowrank_degree: 2,
            gen: None,
        });
        let t0 = Instant::now();
        server.submit(req(9999, 96)); // lone conv-bucket request
        let stream_for = Duration::from_millis(60);
        let mut streamed = 0u64;
        let mut lone_done_at: Option<Duration> = None;
        while t0.elapsed() < stream_for {
            server.submit(req(streamed, 32));
            streamed += 1;
            std::thread::sleep(Duration::from_micros(200));
            while let Ok(r) = server.resp_rx.lock().unwrap().try_recv() {
                if r.id == 9999 && lone_done_at.is_none() {
                    lone_done_at = Some(t0.elapsed());
                }
            }
        }
        let done_at = match lone_done_at {
            Some(d) => d,
            None => {
                // Starved case: it only completes after the stream.
                loop {
                    let r = server.collect(1);
                    if r.is_empty() || r[0].id == 9999 {
                        break t0.elapsed();
                    }
                }
            }
        };
        assert!(
            done_at < Duration::from_millis(30),
            "lone bucket starved: served after {done_at:?} under a {stream_for:?} stream"
        );
        server.shutdown();
    }

    #[test]
    fn shutdown_flushes_pending() {
        let server = Server::start(ServerConfig {
            batcher: BatcherConfig {
                max_batch: 1000, // never fills
                max_wait: std::time::Duration::from_secs(3600),
            },
            ..Default::default()
        });
        server.submit(AttnRequest {
            id: 1,
            seq_len: 32,
            d_model: 8,
            bounded_entries: false,
            backend: None,
            payload: Payload::Synthetic { seed: 0 },
            submitted_at: Instant::now(),
        });
        // The batch can never fill and the deadline is an hour away —
        // only the shutdown flush can complete this request.
        std::thread::sleep(std::time::Duration::from_millis(20));
        let m = server.shutdown();
        let s = m.snapshot();
        assert_eq!(s.requests_completed, 1);
    }
}
