//! Dynamic batcher: groups requests by (backend, sequence bucket) and
//! flushes on batch-size or deadline — the vLLM-style continuous
//! batching loop, scoped to attention calls.

use super::router::Backend;
use super::server::AttnRequest;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// A flushed batch: same backend, same bucket.
#[derive(Debug)]
pub struct Batch {
    pub backend: Backend,
    pub bucket: usize,
    pub requests: Vec<AttnRequest>,
    /// When the oldest member entered the batcher.
    pub opened_at: Instant,
}

struct Pending {
    requests: Vec<AttnRequest>,
    opened_at: Instant,
}

/// Accumulates requests; emits batches.
pub struct DynamicBatcher {
    cfg: BatcherConfig,
    pending: BTreeMap<(Backend, usize), Pending>,
}

impl DynamicBatcher {
    pub fn new(cfg: BatcherConfig) -> Self {
        DynamicBatcher { cfg, pending: BTreeMap::new() }
    }

    /// Add a request; returns a batch if this push filled one.
    pub fn push(&mut self, backend: Backend, bucket: usize, req: AttnRequest) -> Option<Batch> {
        let now = Instant::now();
        let entry = self
            .pending
            .entry((backend, bucket))
            .or_insert_with(|| Pending { requests: Vec::new(), opened_at: now });
        entry.requests.push(req);
        if entry.requests.len() >= self.cfg.max_batch {
            let p = self.pending.remove(&(backend, bucket)).expect("entry inserted above");
            Some(Batch { backend, bucket, requests: p.requests, opened_at: p.opened_at })
        } else {
            None
        }
    }

    /// Flush every group whose deadline has passed (or all, when
    /// `force`). Emission order is (backend, bucket)-sorted — the
    /// pending map is a `BTreeMap` precisely so flush order (and hence
    /// dispatch order under equal deadlines) never depends on hasher
    /// state.
    pub fn flush(&mut self, force: bool) -> Vec<Batch> {
        let now = Instant::now();
        let mut out = Vec::new();
        let keys: Vec<(Backend, usize)> = self.pending.keys().cloned().collect();
        for key in keys {
            let due = {
                let p = &self.pending[&key];
                force || now.duration_since(p.opened_at) >= self.cfg.max_wait
            };
            if due {
                let p = self.pending.remove(&key).expect("key came from this map");
                if !p.requests.is_empty() {
                    out.push(Batch {
                        backend: key.0,
                        bucket: key.1,
                        requests: p.requests,
                        opened_at: p.opened_at,
                    });
                }
            }
        }
        out
    }

    /// Time until the earliest pending deadline (for the dispatch loop's
    /// park timeout).
    pub fn next_deadline(&self) -> Option<Duration> {
        let now = Instant::now();
        self.pending
            .values()
            .map(|p| {
                let elapsed = now.duration_since(p.opened_at);
                self.cfg.max_wait.saturating_sub(elapsed)
            })
            .min()
    }

    pub fn pending_len(&self) -> usize {
        self.pending.values().map(|p| p.requests.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::server::Payload;

    fn req(id: u64, n: usize) -> AttnRequest {
        AttnRequest {
            id,
            seq_len: n,
            d_model: 8,
            bounded_entries: false,
            backend: None,
            payload: Payload::Synthetic { seed: id },
            submitted_at: Instant::now(),
        }
    }

    #[test]
    fn fills_batch_at_max() {
        let mut b = DynamicBatcher::new(BatcherConfig { max_batch: 3, ..Default::default() });
        assert!(b.push(Backend::Exact, 128, req(1, 100)).is_none());
        assert!(b.push(Backend::Exact, 128, req(2, 100)).is_none());
        let batch = b.push(Backend::Exact, 128, req(3, 100)).unwrap();
        assert_eq!(batch.requests.len(), 3);
        assert_eq!(b.pending_len(), 0);
    }

    #[test]
    fn separates_buckets_and_backends() {
        let mut b = DynamicBatcher::new(BatcherConfig { max_batch: 2, ..Default::default() });
        assert!(b.push(Backend::Exact, 128, req(1, 100)).is_none());
        assert!(b.push(Backend::ConvBasis, 128, req(2, 100)).is_none());
        assert!(b.push(Backend::Exact, 256, req(3, 200)).is_none());
        assert_eq!(b.pending_len(), 3);
        let batch = b.push(Backend::Exact, 128, req(4, 100)).unwrap();
        assert_eq!(batch.bucket, 128);
        assert_eq!(batch.backend, Backend::Exact);
    }

    #[test]
    fn deadline_flush() {
        let mut b = DynamicBatcher::new(BatcherConfig {
            max_batch: 100,
            max_wait: Duration::from_millis(1),
        });
        b.push(Backend::ConvBasis, 512, req(1, 500));
        std::thread::sleep(Duration::from_millis(3));
        let batches = b.flush(false);
        assert_eq!(batches.len(), 1);
    }

    #[test]
    fn force_flush_empties() {
        let mut b = DynamicBatcher::new(BatcherConfig::default());
        b.push(Backend::Exact, 128, req(1, 100));
        b.push(Backend::ConvBasis, 256, req(2, 200));
        let batches = b.flush(true);
        assert_eq!(batches.len(), 2);
        assert_eq!(b.pending_len(), 0);
    }

    #[test]
    fn no_request_dropped_or_duplicated() {
        // Property: every pushed id appears in exactly one emitted batch.
        let mut b = DynamicBatcher::new(BatcherConfig { max_batch: 4, ..Default::default() });
        let mut emitted = Vec::new();
        for id in 0..37u64 {
            let bucket = if id % 3 == 0 { 128 } else { 256 };
            let backend = if id % 2 == 0 { Backend::Exact } else { Backend::ConvBasis };
            if let Some(batch) = b.push(backend, bucket, req(id, bucket - 1)) {
                emitted.extend(batch.requests.iter().map(|r| r.id));
            }
        }
        for batch in b.flush(true) {
            emitted.extend(batch.requests.iter().map(|r| r.id));
        }
        emitted.sort();
        assert_eq!(emitted, (0..37).collect::<Vec<_>>());
    }
}
