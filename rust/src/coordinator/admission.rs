//! Token-budget admission control for the generation scheduler.
//!
//! The queue sits between [`Server::submit_generate`] (and the TCP
//! front-end) and the generation scheduler thread. It does three jobs:
//!
//! 1. **Bounded queueing with load shedding.** Submissions past
//!    `max_queue` are refused (`shed_requests`) so the caller can send
//!    an explicit busy response — the server never silently drops a
//!    request and never lets the waiting line grow without bound.
//! 2. **Token-budget admission** (the policy trio popularized by
//!    text-generation-inference): a prefill wave is admitted only when
//!    its Σ prompt tokens fit `max_batch_prefill_tokens` and the whole
//!    batch — tokens already resident plus tokens every sequence may
//!    still decode — fits `max_batch_total_tokens`. A prefill wave
//!    pauses every running sequence for a step, so admission into a
//!    *running* batch additionally waits for `waiting ≥ ceil(ratio ×
//!    running)` (`waiting_served_ratio`), with `max_waiting_steps`
//!    decode steps as the starvation valve: the ratio can defer a
//!    wave, never deny it.
//! 3. **Event-driven wakeup.** The scheduler parks on the queue's
//!    condvar when idle; arrivals, shutdown, and dispatcher *kicks*
//!    (attention batches were flushed — the scheduler's lane may be
//!    their only executor) all wake it. This replaces the old
//!    fixed-interval idle poll: zero wakeups when nothing happens,
//!    immediate wakeup when something does.
//!
//! [`Server::submit_generate`]: super::Server::submit_generate

use super::metrics::Metrics;
use super::server::GenRequest;
use crate::sync::{lock, wait, Arc, Condvar, Mutex};
use std::collections::VecDeque;

/// Admission policy for the generation scheduler (see module docs).
#[derive(Clone, Copy, Debug)]
pub struct AdmissionConfig {
    /// Max Σ prompt tokens admitted in one prefill wave.
    pub max_batch_prefill_tokens: usize,
    /// Max Σ (resident + still-to-decode) tokens across the running
    /// batch plus a candidate wave.
    pub max_batch_total_tokens: usize,
    /// Admit into a running batch only when `waiting ≥ ceil(ratio ×
    /// running)` — the prefill pause must pay for itself.
    pub waiting_served_ratio: f64,
    /// …unless the queue head has already waited this many decode
    /// steps (starvation valve; `0` disables the ratio gate).
    pub max_waiting_steps: usize,
    /// Queue bound: submissions past this are shed with an explicit
    /// busy response.
    pub max_queue: usize,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            max_batch_prefill_tokens: 4096,
            max_batch_total_tokens: 16384,
            waiting_served_ratio: 1.2,
            max_waiting_steps: 4,
            max_queue: 256,
        }
    }
}

/// Why [`AdmissionQueue::wait_for_work`] woke.
///
/// Public (like `wait_for_work`/`admit`) so the loom models in
/// `tests/loom_models.rs` and the stable shutdown-race twin in
/// `tests/shutdown_race.rs` can drive the scheduler protocol directly;
/// production callers are the generation scheduler only.
#[derive(Debug, PartialEq, Eq)]
pub enum Wake {
    /// Waiting requests and/or a dispatcher kick — there is work.
    Work,
    /// Shutdown requested and the waiting line is drained.
    Shutdown,
}

struct QueueInner {
    waiting: VecDeque<GenRequest>,
    shutting: bool,
    /// Dispatcher kick counter. A counter (not a flag) so a kick that
    /// lands while the scheduler is mid-decode is seen on its next
    /// wait — no missed wakeups.
    kicks: u64,
}

/// Condvar-fronted admission queue (see module docs).
pub struct AdmissionQueue {
    inner: Mutex<QueueInner>,
    cv: Condvar,
    cfg: AdmissionConfig,
    metrics: Arc<Metrics>,
}

impl AdmissionQueue {
    pub fn new(cfg: AdmissionConfig, metrics: Arc<Metrics>) -> Self {
        AdmissionQueue {
            inner: Mutex::new(QueueInner { waiting: VecDeque::new(), shutting: false, kicks: 0 }),
            cv: Condvar::new(),
            cfg,
            metrics,
        }
    }

    /// Enqueue a request, or shed it (`Err(req)` hands it back so the
    /// caller can answer busy). Counts `shed_requests` and maintains
    /// the `queue_depth` gauge.
    pub fn submit(&self, req: GenRequest) -> Result<(), GenRequest> {
        let mut g = lock(&self.inner);
        if g.shutting || g.waiting.len() >= self.cfg.max_queue {
            Metrics::incr(&self.metrics.shed_requests);
            return Err(req);
        }
        g.waiting.push_back(req);
        Metrics::add(&self.metrics.queue_depth, 1);
        self.cv.notify_all();
        Ok(())
    }

    /// Dispatcher ping: attention batches were flushed; wake the
    /// scheduler in case its lane is their executor.
    pub fn kick(&self) {
        let mut g = lock(&self.inner);
        g.kicks += 1;
        self.cv.notify_all();
    }

    /// Stop accepting new work and wake every waiter. Requests already
    /// queued still drain ([`Self::wait_for_work`] only reports
    /// [`Wake::Shutdown`] once the line is empty).
    pub fn shutdown(&self) {
        let mut g = lock(&self.inner);
        g.shutting = true;
        self.cv.notify_all();
    }

    /// Park until there is work (arrivals or an unseen kick) or until
    /// shutdown with a drained queue. `kick_seen` is the caller's kick
    /// cursor; it advances past any kick this call consumes.
    pub fn wait_for_work(&self, kick_seen: &mut u64) -> Wake {
        let mut g = lock(&self.inner);
        loop {
            if g.kicks != *kick_seen {
                *kick_seen = g.kicks;
                return Wake::Work;
            }
            if !g.waiting.is_empty() {
                return Wake::Work;
            }
            if g.shutting {
                return Wake::Shutdown;
            }
            g = wait(&self.cv, g);
        }
    }

    /// Remove a **queued** request by id (the cancellation door's first
    /// stop). Returns the request so the caller can emit its terminal
    /// cancelled answer; `None` means the id is not waiting here — it
    /// was already admitted (cancel it in flight), finished, or never
    /// existed. Maintains the `queue_depth` gauge like `admit`.
    pub fn cancel(&self, id: u64) -> Option<GenRequest> {
        let mut g = lock(&self.inner);
        let pos = g.waiting.iter().position(|r| r.id == id)?;
        let req = g.waiting.remove(pos).expect("position came from this queue");
        Metrics::sub(&self.metrics.queue_depth, 1);
        Some(req)
    }

    /// Pop the wave of requests the policy admits right now (possibly
    /// empty). `running`/`running_tokens` describe the in-flight batch
    /// (count, Σ resident + still-to-decode tokens), `steps_since_admit`
    /// the decode steps since the last admitted wave, `slots` the free
    /// concurrency. When nothing is running the head request is always
    /// admitted — an oversized request degrades to a batch of one
    /// instead of deadlocking the queue.
    pub fn admit(
        &self,
        running: usize,
        running_tokens: usize,
        steps_since_admit: usize,
        slots: usize,
    ) -> Vec<GenRequest> {
        let mut g = lock(&self.inner);
        if g.waiting.is_empty() || slots == 0 {
            return Vec::new();
        }
        if running > 0 && !g.shutting && steps_since_admit < self.cfg.max_waiting_steps {
            let need = (self.cfg.waiting_served_ratio * running as f64).ceil() as usize;
            if g.waiting.len() < need {
                return Vec::new();
            }
        }
        let mut out = Vec::new();
        let mut prefill = 0usize;
        let mut total = running_tokens;
        while out.len() < slots {
            let Some(front) = g.waiting.front() else { break };
            let p = front.prompt.len();
            let budget = p + front.max_new_tokens;
            if running > 0 || !out.is_empty() {
                if prefill + p > self.cfg.max_batch_prefill_tokens {
                    break;
                }
                if total + budget > self.cfg.max_batch_total_tokens {
                    break;
                }
            }
            prefill += p;
            total += budget;
            out.push(g.waiting.pop_front().expect("front() was Some"));
        }
        Metrics::sub(&self.metrics.queue_depth, out.len() as u64);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn queue(cfg: AdmissionConfig) -> (AdmissionQueue, Arc<Metrics>) {
        let m = Arc::new(Metrics::new());
        (AdmissionQueue::new(cfg, m.clone()), m)
    }

    fn req(id: u64, prompt_len: usize, max_new: usize) -> GenRequest {
        GenRequest::new(id, vec![1; prompt_len], max_new)
    }

    #[test]
    fn sheds_when_full_and_tracks_depth() {
        let (q, m) = queue(AdmissionConfig { max_queue: 2, ..Default::default() });
        assert!(q.submit(req(0, 4, 4)).is_ok());
        assert!(q.submit(req(1, 4, 4)).is_ok());
        let back = q.submit(req(2, 4, 4));
        assert_eq!(back.unwrap_err().id, 2, "shed hands the request back");
        let s = m.snapshot();
        assert_eq!((s.shed_requests, s.queue_depth), (1, 2));
        let wave = q.admit(0, 0, 0, 8);
        assert_eq!(wave.len(), 2);
        assert_eq!(m.snapshot().queue_depth, 0);
    }

    #[test]
    fn prefill_budget_caps_the_wave() {
        let cfg = AdmissionConfig {
            max_batch_prefill_tokens: 8,
            max_batch_total_tokens: 1000,
            ..Default::default()
        };
        let (q, _m) = queue(cfg);
        for i in 0..5 {
            q.submit(req(i, 4, 4)).unwrap();
        }
        // 4 + 4 = 8 fits; a third prompt would blow the prefill budget.
        assert_eq!(q.admit(0, 0, 0, 8).len(), 2);
    }

    #[test]
    fn total_budget_counts_running_tokens() {
        let cfg = AdmissionConfig {
            max_batch_prefill_tokens: 1000,
            max_batch_total_tokens: 20,
            waiting_served_ratio: 0.0,
            ..Default::default()
        };
        let (q, _m) = queue(cfg);
        q.submit(req(0, 4, 4)).unwrap();
        q.submit(req(1, 4, 4)).unwrap();
        // 14 running tokens + one 8-token candidate = 22 > 20: with a
        // running batch, nothing is force-admitted.
        assert!(q.admit(2, 14, 0, 8).is_empty());
        // 4 running tokens: one candidate fits (12), two would be 20 —
        // exactly the cap, so both go.
        assert_eq!(q.admit(2, 4, 0, 8).len(), 2);
    }

    #[test]
    fn oversized_request_admits_alone_when_idle() {
        let cfg = AdmissionConfig {
            max_batch_prefill_tokens: 8,
            max_batch_total_tokens: 8,
            ..Default::default()
        };
        let (q, _m) = queue(cfg);
        q.submit(req(0, 100, 10)).unwrap();
        q.submit(req(1, 4, 4)).unwrap();
        // Head exceeds every budget but nothing is running: admit it
        // alone rather than deadlock. The next request must wait.
        let wave = q.admit(0, 0, 0, 8);
        assert_eq!(wave.len(), 1);
        assert_eq!(wave[0].id, 0);
    }

    #[test]
    fn ratio_defers_then_waiting_steps_force() {
        let cfg = AdmissionConfig { waiting_served_ratio: 1.2, max_waiting_steps: 4, ..Default::default() };
        let (q, _m) = queue(cfg);
        q.submit(req(0, 4, 4)).unwrap();
        // 4 running, 1 waiting < ceil(1.2 × 4) = 5: deferred…
        assert!(q.admit(4, 32, 0, 8).is_empty());
        assert!(q.admit(4, 32, 3, 8).is_empty());
        // …until the head has waited max_waiting_steps decode steps.
        assert_eq!(q.admit(4, 32, 4, 8).len(), 1);
    }

    #[test]
    fn cancel_removes_queued_request_and_lowers_depth() {
        let (q, m) = queue(AdmissionConfig::default());
        q.submit(req(0, 4, 4)).unwrap();
        q.submit(req(1, 4, 4)).unwrap();
        q.submit(req(2, 4, 4)).unwrap();
        let got = q.cancel(1).expect("queued request cancels");
        assert_eq!(got.id, 1);
        assert_eq!(m.snapshot().queue_depth, 2);
        // Unknown ids (and double cancels) are a miss, not a panic.
        assert!(q.cancel(1).is_none());
        assert!(q.cancel(99).is_none());
        // The survivors admit in FIFO order with the hole closed.
        let wave = q.admit(0, 0, 0, 8);
        assert_eq!(wave.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2]);
        assert_eq!(m.snapshot().queue_depth, 0);
    }

    #[test]
    fn kick_wakes_exactly_once_then_shutdown() {
        let (q, _m) = queue(AdmissionConfig::default());
        q.kick();
        let mut seen = 0u64;
        assert!(matches!(q.wait_for_work(&mut seen), Wake::Work));
        assert_eq!(seen, 1, "the kick cursor advances");
        q.shutdown();
        assert!(matches!(q.wait_for_work(&mut seen), Wake::Shutdown));
        // Post-shutdown submissions shed.
        assert!(q.submit(req(9, 4, 4)).is_err());
    }
}
