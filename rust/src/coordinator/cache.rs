//! Conv-basis cache: *recover once, apply many*.
//!
//! The expensive half of Algorithm 1 is Recover (`O(knd log n)` probe
//! work); the apply is cheap per V. In decode-style serving the same
//! (layer, prefix) pair recurs, so the coordinator caches the
//! exp-transformed basis and its normalizer, keyed by a fingerprint of
//! (model id, layer, Q/K content hash).

use crate::basis::KConvBasis;
use std::collections::HashMap;
use std::sync::Mutex;

/// Cache key: (model, layer, head, seq_len) plus a content fingerprint
/// of (Q, K) — the batched engine's *recover once per (layer, head,
/// seq_len)* reuse unit; the fingerprint guards against collisions when
/// the same slot sees different content.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct CacheKey {
    pub model_id: u64,
    pub layer: u32,
    /// Attention head within the layer (0 for single-head callers).
    pub head: u32,
    /// Sequence length the basis was recovered at.
    pub seq_len: usize,
    pub qk_fingerprint: u64,
}

/// FNV-1a over the f64 bit patterns — cheap, deterministic fingerprint.
pub fn fingerprint(data: &[f64]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &x in data {
        for b in x.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

#[derive(Clone, Debug)]
pub struct CachedBasis {
    pub post_basis: KConvBasis,
    pub d_tilde: Vec<f64>,
}

/// Bounded LRU (timestamp-based eviction; sizes are small — the value
/// payload is `O(kn)` floats, the Appendix A memory claim).
pub struct BasisCache {
    inner: Mutex<Inner>,
    capacity: usize,
}

struct Inner {
    map: HashMap<CacheKey, (CachedBasis, u64)>,
    clock: u64,
    hits: u64,
    misses: u64,
}

impl BasisCache {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1);
        BasisCache {
            inner: Mutex::new(Inner { map: HashMap::new(), clock: 0, hits: 0, misses: 0 }),
            capacity,
        }
    }

    pub fn get(&self, key: &CacheKey) -> Option<CachedBasis> {
        let mut g = self.inner.lock().unwrap();
        g.clock += 1;
        let clock = g.clock;
        match g.map.get_mut(key) {
            Some((v, stamp)) => {
                *stamp = clock;
                let out = v.clone();
                g.hits += 1;
                Some(out)
            }
            None => {
                g.misses += 1;
                None
            }
        }
    }

    pub fn put(&self, key: CacheKey, value: CachedBasis) {
        let mut g = self.inner.lock().unwrap();
        g.clock += 1;
        let clock = g.clock;
        if g.map.len() >= self.capacity && !g.map.contains_key(&key) {
            // Evict the least-recently used entry.
            if let Some(victim) = g
                .map
                .iter()
                .min_by_key(|(_, (_, stamp))| *stamp)
                .map(|(k, _)| k.clone())
            {
                g.map.remove(&victim);
            }
        }
        g.map.insert(key, (value, clock));
    }

    /// (hits, misses, len).
    pub fn stats(&self) -> (u64, u64, usize) {
        let g = self.inner.lock().unwrap();
        (g.hits, g.misses, g.map.len())
    }

    /// Approximate resident floats (memory accounting: `Σ k·n + n`).
    pub fn resident_floats(&self) -> usize {
        let g = self.inner.lock().unwrap();
        g.map
            .values()
            .map(|(v, _)| v.post_basis.memory_floats() + v.d_tilde.len())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::basis::{ConvBasis, KConvBasis};

    fn dummy_basis(n: usize) -> CachedBasis {
        CachedBasis {
            post_basis: KConvBasis::new(n, vec![ConvBasis { b: vec![1.0; n], m: n }]),
            d_tilde: vec![1.0; n],
        }
    }

    fn key(i: u64) -> CacheKey {
        CacheKey { model_id: 1, layer: 0, head: 0, seq_len: 8, qk_fingerprint: i }
    }

    #[test]
    fn hit_after_put() {
        let c = BasisCache::new(4);
        assert!(c.get(&key(1)).is_none());
        c.put(key(1), dummy_basis(8));
        assert!(c.get(&key(1)).is_some());
        let (hits, misses, len) = c.stats();
        assert_eq!((hits, misses, len), (1, 1, 1));
    }

    #[test]
    fn lru_evicts_oldest() {
        let c = BasisCache::new(2);
        c.put(key(1), dummy_basis(4));
        c.put(key(2), dummy_basis(4));
        let _ = c.get(&key(1)); // refresh 1
        c.put(key(3), dummy_basis(4)); // evicts 2
        assert!(c.get(&key(1)).is_some());
        assert!(c.get(&key(2)).is_none());
        assert!(c.get(&key(3)).is_some());
    }

    #[test]
    fn fingerprint_distinguishes_content() {
        let a = fingerprint(&[1.0, 2.0, 3.0]);
        let b = fingerprint(&[1.0, 2.0, 3.0000001]);
        let c = fingerprint(&[1.0, 2.0, 3.0]);
        assert_eq!(a, c);
        assert_ne!(a, b);
    }

    #[test]
    fn memory_accounting() {
        let c = BasisCache::new(4);
        c.put(key(1), dummy_basis(16));
        assert_eq!(c.resident_floats(), 16 + 16);
    }

    #[test]
    fn concurrent_access_is_safe() {
        let c = std::sync::Arc::new(BasisCache::new(8));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..50 {
                    c.put(key(t * 100 + i % 5), dummy_basis(4));
                    let _ = c.get(&key(t * 100 + i % 5));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let (hits, _, len) = c.stats();
        assert!(hits > 0);
        assert!(len <= 8);
    }
}
