//! Conv-basis cache: *recover once, apply many* — lock-striped.
//!
//! The expensive half of Algorithm 1 is Recover (`O(knd log n)` probe
//! work); the apply is cheap per V. In decode-style serving the same
//! (layer, prefix) pair recurs, so the coordinator caches the
//! exp-transformed basis and its normalizer, keyed by a fingerprint of
//! (model id, layer, Q/K content hash).
//!
//! # Lock striping
//!
//! One global mutex serialized every worker of the batched engine on
//! the cache, even when they touched unrelated heads. The cache is now
//! split into [`N_SHARDS`] independently locked partitions; a key's
//! shard is a pure function of its **(layer, head)** slot
//! ([`shard_of`]), so
//!
//! * all entries of one (layer, head) — every seq_len, every content
//!   fingerprint — share a shard, preserving the old single-mutex
//!   semantics (LRU order, capacity) *within* a slot, while
//! * different heads hash to different stripes and stop contending.
//!
//! `capacity` is enforced **per shard**. Hit/miss/len accounting
//! aggregates across shards ([`BasisCache::stats`]), so callers observe
//! one logical cache.

use crate::basis::KConvBasis;
use crate::sync::{lock, Arc, Mutex};
use std::collections::BTreeMap;

/// Number of lock stripes. Eight covers the worker counts this crate's
/// determinism tests pin (1/2/8) without making per-shard LRU state
/// degenerate for small capacities.
pub const N_SHARDS: usize = 8;

/// Cache key: (model, layer, head, seq_len) plus a content fingerprint
/// of (Q, K) — the batched engine's *recover once per (layer, head,
/// seq_len)* reuse unit; the fingerprint guards against collisions when
/// the same slot sees different content.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct CacheKey {
    pub model_id: u64,
    pub layer: u32,
    /// Attention head within the layer (0 for single-head callers).
    pub head: u32,
    /// Sequence length the basis was recovered at.
    pub seq_len: usize,
    pub qk_fingerprint: u64,
}

/// The stripe a key lives in — a pure function of (layer, head), so
/// every entry of one attention head shares a lock and distinct heads
/// spread across stripes.
pub fn shard_of(key: &CacheKey) -> usize {
    (key.layer as usize).wrapping_mul(31).wrapping_add(key.head as usize) % N_SHARDS
}

/// FNV-1a over the f64 bit patterns — cheap, deterministic fingerprint.
pub fn fingerprint(data: &[f64]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &x in data {
        for b in x.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

#[derive(Clone, Debug)]
pub struct CachedBasis {
    pub post_basis: KConvBasis,
    pub d_tilde: Vec<f64>,
}

/// A **step-scoped** basis handle: the conv training forward recovers
/// each (record, layer, head) operator exactly once per optimizer step
/// and hands the backward this shared reference, so no basis is ever
/// recovered twice within a step and *nothing* is written to the
/// serving [`BasisCache`] shards (training bases die with the step —
/// weights change before they could ever be reused, so a shard write
/// could only evict live serving entries).
///
/// Step scoping is ownership, not a mutable store: the handle lives in
/// the forward record's activation cache
/// (`model::Transformer`'s per-layer cache), rides the
/// `AttnBackwardJob` that consumes it
/// (`Metrics::step_basis_hits`), and is dropped with the records when
/// the step ends — no eviction policy, no lock, no interaction with
/// serving traffic.
pub type StepBasis = crate::sync::Arc<CachedBasis>;

/// Bounded LRU (timestamp-based eviction; sizes are small — the value
/// payload is `O(kn)` floats, the Appendix A memory claim), striped
/// into [`N_SHARDS`] independently locked partitions keyed by
/// (layer, head).
pub struct BasisCache {
    shards: Vec<Mutex<Inner>>,
    /// Max entries **per shard** (entries of one (layer, head) always
    /// share a shard, so this is the per-slot working-set bound).
    capacity: usize,
}

#[derive(Default)]
struct Inner {
    /// Values are `Arc`-shared: a hit hands the caller a reference to
    /// the resident entry (O(1)), never a deep copy of the `O(k·n)`
    /// basis floats. Entries are immutable once inserted, so sharing
    /// is sound; eviction only drops the shard's reference.
    map: BTreeMap<CacheKey, (Arc<CachedBasis>, u64)>,
    clock: u64,
    hits: u64,
    misses: u64,
}

impl BasisCache {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1);
        BasisCache {
            shards: (0..N_SHARDS).map(|_| Mutex::new(Inner::default())).collect(),
            capacity,
        }
    }

    /// Look up an entry. A hit returns a shared handle to the resident
    /// basis — an `Arc` clone, **not** a deep copy of the `O(k·n)`
    /// payload — so consumers (prefill applies, gradient
    /// `FOperator::from_cached`, decode seeding) read through the
    /// cache's own allocation.
    pub fn get(&self, key: &CacheKey) -> Option<Arc<CachedBasis>> {
        let mut g = lock(&self.shards[shard_of(key)]);
        g.clock += 1;
        let clock = g.clock;
        match g.map.get_mut(key) {
            Some((v, stamp)) => {
                *stamp = clock;
                let out = Arc::clone(v);
                g.hits += 1;
                Some(out)
            }
            None => {
                g.misses += 1;
                None
            }
        }
    }

    pub fn put(&self, key: CacheKey, value: CachedBasis) {
        let value = Arc::new(value);
        let mut g = lock(&self.shards[shard_of(&key)]);
        g.clock += 1;
        let clock = g.clock;
        if g.map.len() >= self.capacity && !g.map.contains_key(&key) {
            // Evict the least-recently used entry of this shard.
            // BTreeMap iteration is key-ordered, so the victim choice
            // is deterministic even if stamps ever tied.
            if let Some(victim) = g
                .map
                .iter()
                .min_by_key(|(_, (_, stamp))| *stamp)
                .map(|(k, _)| k.clone())
            {
                g.map.remove(&victim);
            }
        }
        g.map.insert(key, (value, clock));
    }

    /// (hits, misses, len), aggregated across every shard.
    pub fn stats(&self) -> (u64, u64, usize) {
        let mut agg = (0u64, 0u64, 0usize);
        for s in &self.shards {
            let g = lock(s);
            agg.0 += g.hits;
            agg.1 += g.misses;
            agg.2 += g.map.len();
        }
        agg
    }

    /// Entries currently resident in one shard (observability / tests).
    pub fn shard_len(&self, shard: usize) -> usize {
        lock(&self.shards[shard]).map.len()
    }

    /// Approximate resident floats (memory accounting: `Σ k·n + n`),
    /// aggregated across every shard.
    pub fn resident_floats(&self) -> usize {
        self.shards
            .iter()
            .map(|s| {
                let g = lock(s);
                g.map
                    .values()
                    .map(|(v, _)| v.post_basis.memory_floats() + v.d_tilde.len())
                    .sum::<usize>()
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::basis::{ConvBasis, KConvBasis};

    fn dummy_basis(n: usize) -> CachedBasis {
        CachedBasis {
            post_basis: KConvBasis::new(n, vec![ConvBasis { b: vec![1.0; n], m: n }]),
            d_tilde: vec![1.0; n],
        }
    }

    fn key(i: u64) -> CacheKey {
        CacheKey { model_id: 1, layer: 0, head: 0, seq_len: 8, qk_fingerprint: i }
    }

    fn slot_key(layer: u32, head: u32, i: u64) -> CacheKey {
        CacheKey { model_id: 1, layer, head, seq_len: 8, qk_fingerprint: i }
    }

    #[test]
    fn hit_after_put() {
        let c = BasisCache::new(4);
        assert!(c.get(&key(1)).is_none());
        c.put(key(1), dummy_basis(8));
        assert!(c.get(&key(1)).is_some());
        let (hits, misses, len) = c.stats();
        assert_eq!((hits, misses, len), (1, 1, 1));
    }

    #[test]
    fn lru_evicts_oldest() {
        let c = BasisCache::new(2);
        c.put(key(1), dummy_basis(4));
        c.put(key(2), dummy_basis(4));
        let _ = c.get(&key(1)); // refresh 1
        c.put(key(3), dummy_basis(4)); // evicts 2
        assert!(c.get(&key(1)).is_some());
        assert!(c.get(&key(2)).is_none());
        assert!(c.get(&key(3)).is_some());
    }

    #[test]
    fn hits_share_one_allocation() {
        // Two hits on the same key must hand back the SAME resident
        // basis (Arc identity), not deep copies — the zero-copy
        // contract consumers like `FOperator::from_cached` rely on.
        let c = BasisCache::new(4);
        c.put(key(1), dummy_basis(8));
        let a = c.get(&key(1)).unwrap();
        let b = c.get(&key(1)).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "cache hits must share the resident allocation");
    }

    #[test]
    fn fingerprint_distinguishes_content() {
        let a = fingerprint(&[1.0, 2.0, 3.0]);
        let b = fingerprint(&[1.0, 2.0, 3.0000001]);
        let c = fingerprint(&[1.0, 2.0, 3.0]);
        assert_eq!(a, c);
        assert_ne!(a, b);
    }

    #[test]
    fn memory_accounting() {
        let c = BasisCache::new(4);
        c.put(key(1), dummy_basis(16));
        assert_eq!(c.resident_floats(), 16 + 16);
    }

    #[test]
    fn keys_spread_across_shards() {
        // Eight consecutive layers at head 0 must not all collapse into
        // one stripe (the whole point of striping).
        let mut seen = std::collections::HashSet::new();
        for layer in 0..8u32 {
            seen.insert(shard_of(&slot_key(layer, 0, 0)));
        }
        assert!(seen.len() >= 4, "layers landed on {} shard(s)", seen.len());
        // And every (seq_len, fingerprint) variant of one slot stays on
        // that slot's shard.
        let base = shard_of(&slot_key(3, 1, 0));
        for i in 0..16u64 {
            let mut k = slot_key(3, 1, i);
            k.seq_len = 8 + i as usize;
            assert_eq!(shard_of(&k), base, "same (layer, head) must share a shard");
        }
    }

    #[test]
    fn cross_shard_hit_accounting_aggregates() {
        // Entries for distinct (layer, head) slots live in distinct
        // shards; stats() must still report one logical cache.
        let c = BasisCache::new(4);
        let slots: Vec<CacheKey> =
            (0..6u32).map(|layer| slot_key(layer, layer % 2, layer as u64)).collect();
        let distinct: std::collections::HashSet<usize> = slots.iter().map(shard_of).collect();
        assert!(distinct.len() >= 2, "test must span shards, got {distinct:?}");
        for k in &slots {
            assert!(c.get(k).is_none()); // one miss each
            c.put(k.clone(), dummy_basis(4));
        }
        for _ in 0..2 {
            for k in &slots {
                assert!(c.get(k).is_some()); // two hits each
            }
        }
        let (hits, misses, len) = c.stats();
        assert_eq!(hits, 2 * slots.len() as u64);
        assert_eq!(misses, slots.len() as u64);
        assert_eq!(len, slots.len());
        // Per-shard occupancy sums to the logical len.
        let by_shard: usize = (0..N_SHARDS).map(|s| c.shard_len(s)).sum();
        assert_eq!(by_shard, len);
    }

    #[test]
    fn eviction_is_per_shard() {
        // Filling one slot far past capacity must not evict another
        // slot's entries (they live on a different stripe).
        let a = slot_key(0, 0, 999);
        let b_layer = (1..8u32)
            .find(|&l| shard_of(&slot_key(l, 0, 0)) != shard_of(&a))
            .expect("some layer maps to a different shard");
        let c = BasisCache::new(2);
        c.put(a.clone(), dummy_basis(4));
        for i in 0..8u64 {
            c.put(slot_key(b_layer, 0, i), dummy_basis(4));
        }
        assert!(c.get(&a).is_some(), "cross-shard churn must not evict slot A");
        assert_eq!(c.shard_len(shard_of(&slot_key(b_layer, 0, 0))), 2);
    }

    #[test]
    fn concurrent_access_is_safe() {
        let c = std::sync::Arc::new(BasisCache::new(8));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..50 {
                    c.put(key(t * 100 + i % 5), dummy_basis(4));
                    let _ = c.get(&key(t * 100 + i % 5));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let (hits, _, len) = c.stats();
        assert!(hits > 0);
        assert!(len <= 8);
    }
}
