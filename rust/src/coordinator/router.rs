//! Request router: picks the attention backend per request.
//!
//! Policy follows the paper's complexity analysis: exact `O(n²d)` wins
//! below the FFT crossover; conv-basis `O(knd log n)` wins beyond it;
//! low-rank is selected for masks/workloads where Theorem 6.5's kernels
//! apply. Thresholds are configurable and benchable (ablations bench).

/// The backend chosen for a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Backend {
    Exact,
    ConvBasis,
    LowRank,
}

/// Routing policy configuration.
#[derive(Clone, Copy, Debug)]
pub struct RouterConfig {
    /// Sequences shorter than this go to the exact backend.
    pub exact_below: usize,
    /// Sequences at least this long *and* flagged bounded-entry go to
    /// low-rank; everything else long goes to conv-basis.
    pub lowrank_min: usize,
    /// Conv recovery budget as a fraction of n (k_max = ceil(frac·n)),
    /// clamped to [1, k_cap].
    pub k_frac: f64,
    pub k_cap: usize,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig { exact_below: 128, lowrank_min: usize::MAX, k_frac: 0.05, k_cap: 64 }
    }
}

/// Stateless router (cheap to share across workers).
#[derive(Clone, Debug, Default)]
pub struct Router {
    cfg: RouterConfig,
}

impl Router {
    pub fn new(cfg: RouterConfig) -> Self {
        Router { cfg }
    }

    /// Route a request by sequence length and entry-boundedness hint.
    pub fn route(&self, seq_len: usize, bounded_entries: bool) -> Backend {
        if seq_len < self.cfg.exact_below {
            Backend::Exact
        } else if bounded_entries && seq_len >= self.cfg.lowrank_min {
            Backend::LowRank
        } else {
            Backend::ConvBasis
        }
    }

    /// Conv recovery budget for a sequence length.
    pub fn k_budget(&self, seq_len: usize) -> usize {
        ((self.cfg.k_frac * seq_len as f64).ceil() as usize).clamp(1, self.cfg.k_cap)
    }

    /// Sequence-length bucket (power-of-two rounding) — the batching key.
    pub fn bucket(&self, seq_len: usize) -> usize {
        seq_len.next_power_of_two()
    }

    pub fn config(&self) -> &RouterConfig {
        &self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn short_sequences_go_exact() {
        let r = Router::new(RouterConfig::default());
        assert_eq!(r.route(64, false), Backend::Exact);
        assert_eq!(r.route(127, true), Backend::Exact);
    }

    #[test]
    fn long_sequences_go_conv() {
        let r = Router::new(RouterConfig::default());
        assert_eq!(r.route(2048, false), Backend::ConvBasis);
    }

    #[test]
    fn lowrank_when_configured_and_bounded() {
        let cfg = RouterConfig { lowrank_min: 512, ..Default::default() };
        let r = Router::new(cfg);
        assert_eq!(r.route(1024, true), Backend::LowRank);
        assert_eq!(r.route(1024, false), Backend::ConvBasis);
        assert_eq!(r.route(256, true), Backend::ConvBasis);
    }

    #[test]
    fn k_budget_clamped() {
        let r = Router::new(RouterConfig { k_frac: 0.05, k_cap: 64, ..Default::default() });
        assert_eq!(r.k_budget(100), 5);
        assert_eq!(r.k_budget(10_000), 64);
        assert_eq!(r.k_budget(1), 1);
    }

    #[test]
    fn buckets_are_pow2() {
        let r = Router::new(RouterConfig::default());
        assert_eq!(r.bucket(100), 128);
        assert_eq!(r.bucket(128), 128);
        assert_eq!(r.bucket(129), 256);
    }
}
