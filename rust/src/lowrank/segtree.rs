//! Segment tree over k-dimensional vectors — the data structure of
//! Algorithm 6 (continuous-row masks, Lemma D.9).
//!
//! Stores `b_i = (U₂ᵀ)_i · v_i ∈ Rᵏ` at the leaves; a range query
//! `Σ_{i ∈ [s, t]} b_i` touches `O(log n)` nodes, each contributing a
//! k-vector add → `O(k log n)` per row, `O(nk log n)` total.

/// Segment tree of k-vectors with range-sum queries.
#[derive(Clone, Debug)]
pub struct VecSegTree {
    n: usize,
    k: usize,
    /// 1-indexed flat binary tree: node i has children 2i, 2i+1; leaves
    /// occupy `size .. size + n`. Each node stores k contiguous floats.
    nodes: Vec<f64>,
    size: usize,
}

impl VecSegTree {
    /// Build from `n` leaves, each a k-vector produced by `leaf(i)`.
    pub fn build(n: usize, k: usize, mut leaf: impl FnMut(usize, &mut [f64])) -> Self {
        assert!(n >= 1 && k >= 1);
        let size = n.next_power_of_two();
        let mut nodes = vec![0.0; 2 * size * k];
        for i in 0..n {
            leaf(i, &mut nodes[(size + i) * k..(size + i + 1) * k]);
        }
        for node in (1..size).rev() {
            let (parents, children) = nodes.split_at_mut(2 * node * k);
            let parent = &mut parents[node * k..(node + 1) * k];
            let left = &children[..k];
            let right = &children[k..2 * k];
            for j in 0..k {
                parent[j] = left[j] + right[j];
            }
        }
        VecSegTree { n, k, nodes, size }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    /// `out += Σ_{i ∈ [lo, hi]} leaf_i` (inclusive bounds).
    ///
    /// Counts node visits in `visits` when provided (complexity
    /// accounting for the Theorem 6.5 bench).
    pub fn range_sum_into(&self, lo: usize, hi: usize, out: &mut [f64]) -> usize {
        assert!(lo <= hi && hi < self.n);
        assert_eq!(out.len(), self.k);
        let mut visits = 0usize;
        let (mut l, mut r) = (lo + self.size, hi + self.size + 1);
        while l < r {
            if l & 1 == 1 {
                self.add_node(l, out);
                visits += 1;
                l += 1;
            }
            if r & 1 == 1 {
                r -= 1;
                self.add_node(r, out);
                visits += 1;
            }
            l >>= 1;
            r >>= 1;
        }
        visits
    }

    #[inline]
    fn add_node(&self, node: usize, out: &mut [f64]) {
        let base = node * self.k;
        for j in 0..self.k {
            out[j] += self.nodes[base + j];
        }
    }

    /// Point update: overwrite leaf `i` and repair ancestors —
    /// `O(k log n)`. (Beyond the paper: lets the serving layer refresh
    /// one token's contribution without a rebuild.)
    pub fn update_leaf(&mut self, i: usize, values: &[f64]) {
        assert!(i < self.n);
        assert_eq!(values.len(), self.k);
        let mut node = self.size + i;
        self.nodes[node * self.k..(node + 1) * self.k].copy_from_slice(values);
        node >>= 1;
        while node >= 1 {
            for j in 0..self.k {
                self.nodes[node * self.k + j] = self.nodes[2 * node * self.k + j]
                    + self.nodes[(2 * node + 1) * self.k + j];
            }
            if node == 1 {
                break;
            }
            node >>= 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn range_sums_match_naive() {
        let mut rng = Rng::seeded(131);
        let (n, k) = (37, 4);
        let leaves: Vec<Vec<f64>> = (0..n).map(|_| rng.randn_vec(k)).collect();
        let tree = VecSegTree::build(n, k, |i, out| out.copy_from_slice(&leaves[i]));
        for &(lo, hi) in &[(0usize, 0usize), (0, 36), (5, 20), (36, 36), (17, 18)] {
            let mut got = vec![0.0; k];
            tree.range_sum_into(lo, hi, &mut got);
            let mut want = vec![0.0; k];
            for leaf in leaves.iter().take(hi + 1).skip(lo) {
                for j in 0..k {
                    want[j] += leaf[j];
                }
            }
            for j in 0..k {
                assert!((got[j] - want[j]).abs() < 1e-10, "[{lo},{hi}] dim {j}");
            }
        }
    }

    #[test]
    fn query_touches_log_nodes() {
        let (n, k) = (1024, 2);
        let tree = VecSegTree::build(n, k, |i, out| out[0] = i as f64);
        let mut buf = vec![0.0; k];
        let visits = tree.range_sum_into(3, 1000, &mut buf);
        assert!(visits <= 2 * 11, "visits = {visits}"); // 2·log2(1024) + slack
    }

    #[test]
    fn update_leaf_propagates() {
        let (n, k) = (10, 3);
        let mut tree = VecSegTree::build(n, k, |_, out| out.fill(1.0));
        tree.update_leaf(4, &[5.0, 6.0, 7.0]);
        let mut got = vec![0.0; k];
        tree.range_sum_into(0, 9, &mut got);
        assert_eq!(got, vec![9.0 + 5.0, 9.0 + 6.0, 9.0 + 7.0]);
    }

    #[test]
    fn single_leaf_tree() {
        let tree = VecSegTree::build(1, 2, |_, out| out.copy_from_slice(&[3.0, 4.0]));
        let mut got = vec![0.0; 2];
        tree.range_sum_into(0, 0, &mut got);
        assert_eq!(got, vec![3.0, 4.0]);
    }
}
