//! Low-rank attention approximation with masks (Section 6 / Appendix D).
//!
//! [AS23] approximate `H = exp(QKᵀ/d)` by `U₁U₂ᵀ` with
//! `U₁, U₂ ∈ R^{n×k}` (an `(ε,k)`-approximation, Definition D.1) — but
//! only without a mask. The paper's Theorem 6.5 extends it: for a mask
//! `W`, compute `Ỹ = D̃⁻¹ (W ∘ U₁U₂ᵀ) V` where each mask family admits a
//! fast `(W ∘ U₁U₂ᵀ)·v` kernel:
//!
//! | mask | algorithm | time |
//! |---|---|---|
//! | causal (Def 3.2) | Alg 4, prefix sums | `O(nk)` |
//! | row-change `B_j` (Def 6.1) | Alg 5, support deltas | `O(k ΣB_j)` |
//! | continuous rows (Def 6.2) | Alg 6, segment tree | `O(nk log n)` |
//! | distinct r rows/cols (Defs 6.3/6.4) | Lemmas D.10–D.12 | `O(rnk)` |
//!
//! `U₁, U₂` come from truncated-Taylor polynomial features (the
//! constructive core of [AS23]'s Lemma 3.4 = Lemma D.2 here).

pub mod masked;
pub mod segtree;

use crate::attention::{Mask, MaskKind};
use crate::tensor::Matrix;

/// Configuration of the polynomial-feature approximation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LowRankConfig {
    /// Taylor truncation degree `g`; feature rank is `C(d+g, g)`.
    pub degree: usize,
    /// Logit scaling: approximates `exp(QKᵀ / scale)`. The paper (and
    /// [AS23]) use `scale = d`.
    pub scale: f64,
}

impl LowRankConfig {
    pub fn new(degree: usize, scale: f64) -> Self {
        assert!(scale > 0.0);
        LowRankConfig { degree, scale }
    }

    /// Feature rank `k = C(d+g, g)` for hidden dim `d`.
    ///
    /// Saturates at `usize::MAX` instead of silently wrapping when the
    /// binomial overflows (large `d`/`g` pairs overflow even `u128`
    /// intermediates). A saturated rank is still correct for every
    /// comparison the callers make — "is low-rank even worth it here"
    /// is `rank < n`, and `usize::MAX` loses that comparison for any
    /// real sequence length, so the router refuses the route instead
    /// of allocating a wrapped-tiny feature matrix.
    pub fn rank(&self, d: usize) -> usize {
        binomial(d + self.degree, self.degree)
    }
}

/// `C(n, k)`, saturating at `usize::MAX` on overflow. Computed as the
/// exact integer recurrence `C(n, i+1) = C(n, i)·(n−i)/(i+1)` so the
/// running value is always the true binomial (never a truncated
/// quotient) and the only failure mode is the checked multiply.
fn binomial(n: usize, k: usize) -> usize {
    let k = k.min(n - k);
    let mut c: u128 = 1;
    for i in 0..k {
        // c = C(n, i) here, so c·(n−i) is divisible by (i+1).
        match c.checked_mul((n - i) as u128) {
            Some(t) => c = t / (i as u128 + 1),
            None => return usize::MAX,
        }
    }
    usize::try_from(c).unwrap_or(usize::MAX)
}

/// The `(ε,k)`-approximation `exp(QKᵀ/scale) ≈ U₁U₂ᵀ`.
#[derive(Clone, Debug)]
pub struct LowRankFactors {
    pub u1: Matrix,
    pub u2: Matrix,
}

/// Build polynomial features: `φ(x)` has one coordinate per multiset
/// `α` of size `t ≤ g` over `[d]`, with value
/// `sqrt(C(t,α) / (t!·scaleᵗ)) · x^α`, so that
/// `φ(q)·φ(k) = Σ_{t≤g} (q·k)ᵗ / (t!·scaleᵗ) ≈ exp(q·k/scale)`.
pub fn poly_features(x: &Matrix, cfg: &LowRankConfig) -> Matrix {
    let (n, d) = x.shape();
    let g = cfg.degree;
    // Enumerate multisets over [d] of each size t ≤ g, as non-decreasing
    // index tuples, along with the scaled multinomial coefficient.
    let mut coords: Vec<(Vec<usize>, f64)> = Vec::new();
    let mut stack: Vec<usize> = Vec::new();
    enumerate_multisets(d, g, &mut stack, &mut coords, cfg.scale);
    let k = coords.len();
    debug_assert_eq!(k, cfg.rank(d));

    let mut out = Matrix::zeros(n, k);
    for i in 0..n {
        let row = x.row(i);
        for (c, (idx, coeff)) in coords.iter().enumerate() {
            let mut v = *coeff;
            for &j in idx {
                v *= row[j];
            }
            out[(i, c)] = v;
        }
    }
    out
}

fn enumerate_multisets(
    d: usize,
    g: usize,
    stack: &mut Vec<usize>,
    coords: &mut Vec<(Vec<usize>, f64)>,
    scale: f64,
) {
    // Record the current multiset (including the empty one).
    let t = stack.len();
    // multinomial C(t, α) = t! / ∏ α_j!
    let mut fact_t = 1.0;
    for i in 1..=t {
        fact_t *= i as f64;
    }
    let mut denom = 1.0;
    let mut run = 1;
    for w in 1..stack.len() {
        if stack[w] == stack[w - 1] {
            run += 1;
            denom *= run as f64;
        } else {
            run = 1;
        }
    }
    let multinomial = fact_t / denom;
    let coeff = (multinomial / (fact_t * scale.powi(t as i32))).sqrt();
    coords.push((stack.clone(), coeff));

    if t == g {
        return;
    }
    let start = stack.last().copied().unwrap_or(0);
    for j in start..d {
        stack.push(j);
        enumerate_multisets(d, g, stack, coords, scale);
        stack.pop();
    }
}

/// Build the factors for given `Q, K` (Lemma D.2 constructive step).
pub fn build_factors(q: &Matrix, k: &Matrix, cfg: &LowRankConfig) -> LowRankFactors {
    LowRankFactors { u1: poly_features(q, cfg), u2: poly_features(k, cfg) }
}

/// Masked low-rank attention (Theorem 6.5):
/// `Ỹ = D̃⁻¹ (W ∘ U₁U₂ᵀ) V`, with the per-mask fast kernels.
#[derive(Clone, Debug)]
pub struct LowRankAttention {
    factors: LowRankFactors,
    mask: Mask,
}

impl LowRankAttention {
    pub fn new(q: &Matrix, k: &Matrix, mask: Mask, cfg: &LowRankConfig) -> Self {
        assert_eq!(q.rows(), mask.n());
        LowRankAttention { factors: build_factors(q, k, cfg), mask }
    }

    pub fn from_factors(factors: LowRankFactors, mask: Mask) -> Self {
        LowRankAttention { factors, mask }
    }

    pub fn factors(&self) -> &LowRankFactors {
        &self.factors
    }

    pub fn mask(&self) -> &Mask {
        &self.mask
    }

    /// `(W ∘ U₁U₂ᵀ)·v` through the mask-specific kernel.
    pub fn masked_multiply(&self, v: &[f64]) -> Vec<f64> {
        let f = &self.factors;
        match self.mask.kind() {
            MaskKind::Causal => masked::causal_multiply(&f.u1, &f.u2, v),
            MaskKind::SlidingWindow { .. } => {
                masked::row_change_multiply(&self.mask, &f.u1, &f.u2, v)
            }
            MaskKind::ContinuousRow { s, t } => {
                masked::continuous_row_multiply_segtree(&f.u1, &f.u2, v, s, t)
            }
            MaskKind::DistinctRows { assign, patterns } => {
                masked::distinct_rows_multiply(&f.u1, &f.u2, v, assign, patterns)
            }
            MaskKind::DistinctCols { assign, patterns } => {
                masked::distinct_cols_multiply(&f.u1, &f.u2, v, assign, patterns)
            }
            MaskKind::Dense(_) => masked::row_change_multiply(&self.mask, &f.u1, &f.u2, v),
        }
    }

    /// Full attention output: `Ỹ = D̃⁻¹ (W∘U₁U₂ᵀ) V` (Lemma D.3: one
    /// extra multiply by `1_n` yields the normalizer in `O(t + n)`).
    pub fn forward(&self, v: &Matrix) -> Matrix {
        let n = self.mask.n();
        assert_eq!(v.rows(), n);
        let ones = vec![1.0; n];
        let d_tilde = self.masked_multiply(&ones);
        let mut out = Matrix::zeros(n, v.cols());
        for c in 0..v.cols() {
            let col = v.col(c);
            let y = self.masked_multiply(&col);
            out.set_col(c, &y);
        }
        let inv: Vec<f64> = d_tilde.iter().map(|&x| 1.0 / x).collect();
        out.scale_rows(&inv)
    }
}

/// Exact masked-softmax reference with the [AS23] `1/scale` logit
/// convention (`A = W ∘ exp(QKᵀ/scale)`) — the oracle Theorem 6.5's
/// `4ε‖V‖∞` bound compares against.
pub fn exact_scaled_attention(
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    mask: &Mask,
    scale: f64,
) -> Matrix {
    let n = q.rows();
    let logits = q.matmul(&k.transpose());
    let a = Matrix::from_fn(n, n, |i, j| {
        if mask.entry(i, j) {
            (logits[(i, j)] / scale).exp()
        } else {
            0.0
        }
    });
    let d = a.row_sums();
    let av = a.matmul(v);
    let inv: Vec<f64> = d.iter().map(|&x| 1.0 / x).collect();
    av.scale_rows(&inv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{max_abs_diff, Rng};

    #[test]
    fn rank_formula() {
        let cfg = LowRankConfig::new(2, 4.0);
        // C(4+2, 2) = 15
        assert_eq!(cfg.rank(4), 15);
        let cfg3 = LowRankConfig::new(3, 8.0);
        assert_eq!(cfg3.rank(8), binomial(11, 3));
    }

    #[test]
    fn rank_saturates_instead_of_wrapping() {
        // In range: C(60, 30) still fits u64 exactly.
        assert_eq!(binomial(60, 30), 118_264_581_564_861_424);
        // Past the boundary: C(70, 35) ≈ 1.12e20 > u64::MAX — the old
        // unchecked `as usize` cast wrapped this to a small number.
        assert_eq!(binomial(70, 35), usize::MAX);
        assert_eq!(LowRankConfig::new(35, 1.0).rank(35), usize::MAX);
        // Deep overflow (the u128 intermediate itself overflows).
        assert_eq!(binomial(200, 100), usize::MAX);
        // Saturation is monotone: a saturated rank always loses the
        // router's `rank < n` comparison.
        assert!(LowRankConfig::new(35, 1.0).rank(35) >= 4096);
    }

    #[test]
    fn features_inner_product_is_truncated_taylor() {
        let mut rng = Rng::seeded(121);
        let d = 3;
        let cfg = LowRankConfig::new(4, d as f64);
        let q = Matrix::randn(1, d, &mut rng).scale(0.5);
        let k = Matrix::randn(1, d, &mut rng).scale(0.5);
        let fq = poly_features(&q, &cfg);
        let fk = poly_features(&k, &cfg);
        let got = crate::tensor::dot(fq.row(0), fk.row(0));
        let x = crate::tensor::dot(q.row(0), k.row(0)) / d as f64;
        let mut want = 0.0;
        let mut term = 1.0;
        for t in 0..=4 {
            if t > 0 {
                term *= x / t as f64;
            }
            want += term;
        }
        assert!((got - want).abs() < 1e-10, "{got} vs {want}");
    }

    #[test]
    fn factors_approximate_exp_for_bounded_entries() {
        let mut rng = Rng::seeded(122);
        let (n, d) = (16, 4);
        let q = Matrix::rand_uniform(n, d, 0.8, &mut rng);
        let k = Matrix::rand_uniform(n, d, 0.8, &mut rng);
        let cfg = LowRankConfig::new(6, d as f64);
        let f = build_factors(&q, &k, &cfg);
        let approx = f.u1.matmul(&f.u2.transpose());
        let exact = q.matmul(&k.transpose()).map(|x| (x / d as f64).exp());
        // Relative entrywise error (Definition D.1 form).
        for i in 0..n {
            for j in 0..n {
                let rel = (approx[(i, j)] - exact[(i, j)]).abs() / exact[(i, j)];
                assert!(rel < 1e-4, "rel err {rel} at ({i},{j})");
            }
        }
    }

    #[test]
    fn forward_matches_oracle_within_taylor_error() {
        let mut rng = Rng::seeded(123);
        let (n, d) = (24, 3);
        let q = Matrix::rand_uniform(n, d, 1.0, &mut rng);
        let k = Matrix::rand_uniform(n, d, 1.0, &mut rng);
        let v = Matrix::randn(n, d, &mut rng);
        let cfg = LowRankConfig::new(5, d as f64);
        let mask = Mask::causal(n);
        let lr = LowRankAttention::new(&q, &k, mask.clone(), &cfg);
        let approx = lr.forward(&v);
        let exact = exact_scaled_attention(&q, &k, &v, &mask, d as f64);
        let err = max_abs_diff(&exact, &approx);
        assert!(err < 1e-3 * crate::tensor::linf_norm_mat(&v), "err = {err}");
    }

    #[test]
    fn forward_all_mask_kinds_match_dense_oracle() {
        let mut rng = Rng::seeded(124);
        let (n, d) = (18, 3);
        let q = Matrix::rand_uniform(n, d, 0.7, &mut rng);
        let k = Matrix::rand_uniform(n, d, 0.7, &mut rng);
        let v = Matrix::randn(n, d, &mut rng);
        let cfg = LowRankConfig::new(4, d as f64);

        let mut patterns = vec![vec![false; n]; 3];
        for j in 0..n {
            patterns[0][j] = j % 2 == 0;
            patterns[1][j] = j < n / 2;
            patterns[2][j] = j > 2;
        }
        let assign: Vec<usize> = (0..n).map(|i| i % 3).collect();
        let masks = vec![
            Mask::causal(n),
            Mask::sliding_window(n, 5, 1),
            Mask::continuous_row(
                (0..n).map(|i| i / 2).collect(),
                (0..n).map(|i| (i / 2 + n / 2).min(n - 1)).collect(),
            ),
            Mask::distinct_rows(assign.clone(), patterns.clone()),
            Mask::distinct_cols(assign, patterns),
        ];
        for mask in masks {
            let lr = LowRankAttention::new(&q, &k, mask.clone(), &cfg);
            let fast = lr.forward(&v);
            // Dense oracle using the same factors (isolates the masked
            // multiply from the Taylor error).
            let f = lr.factors();
            let a = mask.apply(&f.u1.matmul(&f.u2.transpose()));
            let dsum = a.row_sums();
            let av = a.matmul(&v);
            let inv: Vec<f64> = dsum.iter().map(|&x| 1.0 / x).collect();
            let want = av.scale_rows(&inv);
            let err = max_abs_diff(&want, &fast);
            assert!(err < 1e-9, "mask {:?}: err = {err}", mask.kind());
        }
    }
}
