//! Mask-aware low-rank multiplies: `(W ∘ U₁U₂ᵀ)·v` for each mask family
//! (Appendix D.3–D.6). All kernels share the Lemma D.5 identity
//! `Y_j = ⟨(U₁ᵀ)_j, Σ_{l ∈ S_j} (U₂ᵀ)_l v_l⟩` — they differ only in how
//! the per-row support sums `c_j` are maintained.

use super::segtree::VecSegTree;
use crate::attention::Mask;
use crate::tensor::{dot, Matrix};

/// Dense oracle (tests / ablation baseline): materialize `W ∘ U₁U₂ᵀ`.
pub fn dense_multiply(mask: &Mask, u1: &Matrix, u2: &Matrix, v: &[f64]) -> Vec<f64> {
    let a = mask.apply(&u1.matmul(&u2.transpose()));
    a.matvec(v)
}

/// Algorithm 4 (causal mask, Lemma D.6): running prefix sum
/// `c_j = Σ_{l ≤ j} (U₂ᵀ)_l v_l` — `O(nk)`.
pub fn causal_multiply(u1: &Matrix, u2: &Matrix, v: &[f64]) -> Vec<f64> {
    let (n, k) = u2.shape();
    assert_eq!(u1.shape(), (n, k));
    assert_eq!(v.len(), n);
    let mut c = vec![0.0; k];
    let mut y = Vec::with_capacity(n);
    for j in 0..n {
        let row = u2.row(j);
        let vj = v[j];
        for (ci, &ui) in c.iter_mut().zip(row) {
            *ci += ui * vj;
        }
        y.push(dot(u1.row(j), &c));
    }
    y
}

/// Algorithm 5 (row-change-by-amortized-constant mask, Lemma D.8):
/// maintain `c_j` by applying the support deltas
/// `Q⁺_j = S_j \ S_{j−1}`, `Q⁻_j = S_{j−1} \ S_j` — `O(k·ΣB_j)`.
///
/// The deltas come from [`Mask::entry`] row scans here (`O(n)` per row
/// to *find* the delta, `O(k·B_j)` to apply it); masks that know their
/// deltas analytically should pre-compute them and call
/// [`row_change_multiply_with_deltas`].
pub fn row_change_multiply(mask: &Mask, u1: &Matrix, u2: &Matrix, v: &[f64]) -> Vec<f64> {
    let n = mask.n();
    let mut deltas: Vec<(Vec<usize>, Vec<usize>)> = Vec::with_capacity(n);
    let mut prev = vec![false; n];
    for i in 0..n {
        let mut add = Vec::new();
        let mut del = Vec::new();
        for j in 0..n {
            let cur = mask.entry(i, j);
            if cur && !prev[j] {
                add.push(j);
            } else if !cur && prev[j] {
                del.push(j);
            }
            prev[j] = cur;
        }
        deltas.push((add, del));
    }
    row_change_multiply_with_deltas(&deltas, u1, u2, v)
}

/// Algorithm 5 core, with the support deltas supplied by the caller.
pub fn row_change_multiply_with_deltas(
    deltas: &[(Vec<usize>, Vec<usize>)],
    u1: &Matrix,
    u2: &Matrix,
    v: &[f64],
) -> Vec<f64> {
    let (n, k) = u2.shape();
    assert_eq!(deltas.len(), n);
    let mut c = vec![0.0; k];
    let mut y = Vec::with_capacity(n);
    for (j, (add, del)) in deltas.iter().enumerate() {
        for &i in add {
            let row = u2.row(i);
            let vi = v[i];
            for (ci, &ui) in c.iter_mut().zip(row) {
                *ci += ui * vi;
            }
        }
        for &i in del {
            let row = u2.row(i);
            let vi = v[i];
            for (ci, &ui) in c.iter_mut().zip(row) {
                *ci -= ui * vi;
            }
        }
        y.push(dot(u1.row(j), &c));
    }
    y
}

/// Analytic support deltas for the structured masks (sliding-window /
/// causal) — `O(B_j)` per row instead of the `O(n)` scan.
pub fn analytic_deltas(mask: &Mask) -> Option<Vec<(Vec<usize>, Vec<usize>)>> {
    use crate::attention::MaskKind;
    let n = mask.n();
    match mask.kind() {
        MaskKind::Causal => Some((0..n).map(|i| (vec![i], vec![])).collect()),
        MaskKind::SlidingWindow { w, sink } => Some(
            (0..n)
                .map(|i| {
                    let add = vec![i];
                    let mut del = Vec::new();
                    // Row i keeps {j: i−j < w} ∪ {j < sink}; leaving row
                    // i−1 → i drops column i−w if it is ≥ sink.
                    if i >= *w && i - *w >= *sink {
                        del.push(i - *w);
                    }
                    (add, del)
                })
                .collect(),
        ),
        _ => None,
    }
}

/// Algorithm 6 (continuous-row mask, Lemma D.9): segment tree over
/// `b_i = (U₂ᵀ)_i v_i`, range query per row — `O(nk log n)`.
pub fn continuous_row_multiply_segtree(
    u1: &Matrix,
    u2: &Matrix,
    v: &[f64],
    s: &[usize],
    t: &[usize],
) -> Vec<f64> {
    let (n, k) = u2.shape();
    let tree = VecSegTree::build(n, k, |i, out| {
        let row = u2.row(i);
        let vi = v[i];
        for (o, &ui) in out.iter_mut().zip(row) {
            *o = ui * vi;
        }
    });
    let mut y = Vec::with_capacity(n);
    let mut c = vec![0.0; k];
    for i in 0..n {
        c.fill(0.0);
        tree.range_sum_into(s[i], t[i], &mut c);
        y.push(dot(u1.row(i), &c));
    }
    y
}

/// Ablation: continuous-row masks via plain prefix sums —
/// `c_{[s,t]} = P_{t+1} − P_s`, `O(nk)` and strictly less work than the
/// segment tree the paper prescribes (DESIGN.md §5; benched in
/// `benches/ablations.rs`).
pub fn continuous_row_multiply_prefix(
    u1: &Matrix,
    u2: &Matrix,
    v: &[f64],
    s: &[usize],
    t: &[usize],
) -> Vec<f64> {
    let (n, k) = u2.shape();
    // P[i] = Σ_{l < i} b_l, flat (n+1)×k.
    let mut prefix = vec![0.0; (n + 1) * k];
    for i in 0..n {
        let row = u2.row(i);
        let vi = v[i];
        let (lo, hi) = prefix.split_at_mut((i + 1) * k);
        let prev = &lo[i * k..];
        let cur = &mut hi[..k];
        for j in 0..k {
            cur[j] = prev[j] + row[j] * vi;
        }
    }
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let lo = &prefix[s[i] * k..(s[i] + 1) * k];
        let hi = &prefix[(t[i] + 1) * k..(t[i] + 2) * k];
        let mut acc = 0.0;
        let u_row = u1.row(i);
        for j in 0..k {
            acc += u_row[j] * (hi[j] - lo[j]);
        }
        y.push(acc);
    }
    y
}

/// Lemma D.10 (distinct-r **columns** mask):
/// `Y = Σ_j diag(W_{*,h(j)}) U₁ (U₂ᵀ)_{*,S_j} v_{S_j}` — `O(rnk)`.
pub fn distinct_cols_multiply(
    u1: &Matrix,
    u2: &Matrix,
    v: &[f64],
    assign: &[usize],
    patterns: &[Vec<bool>],
) -> Vec<f64> {
    let (n, k) = u2.shape();
    let r = patterns.len();
    // Group sums w_g = Σ_{i ∈ S_g} (U₂ᵀ)_i v_i.
    let mut group_sums = vec![0.0; r * k];
    for i in 0..n {
        let g = assign[i];
        let row = u2.row(i);
        let vi = v[i];
        let gs = &mut group_sums[g * k..(g + 1) * k];
        for (s, &ui) in gs.iter_mut().zip(row) {
            *s += ui * vi;
        }
    }
    let mut y = vec![0.0; n];
    for g in 0..r {
        let gs = &group_sums[g * k..(g + 1) * k];
        // The column pattern for group g: patterns[g][i] describes
        // column entries (i.e. W[i][j] for j ∈ S_g equals patterns[g][i]).
        for i in 0..n {
            if patterns[g][i] {
                y[i] += dot(u1.row(i), gs);
            }
        }
    }
    y
}

/// Lemma D.11 (distinct-r **rows** mask):
/// `Y = Σ_j diag(e_{S_j}) U₁ U₂ᵀ diag(W_{h(j),*}) v` — `O(rnk)`.
pub fn distinct_rows_multiply(
    u1: &Matrix,
    u2: &Matrix,
    v: &[f64],
    assign: &[usize],
    patterns: &[Vec<bool>],
) -> Vec<f64> {
    let (n, k) = u2.shape();
    let r = patterns.len();
    // For each group pattern, w_g = U₂ᵀ (pattern ∘ v).
    let mut group_w = vec![0.0; r * k];
    for (g, pat) in patterns.iter().enumerate() {
        let w = &mut group_w[g * k..(g + 1) * k];
        for i in 0..n {
            if pat[i] {
                let row = u2.row(i);
                let vi = v[i];
                for (s, &ui) in w.iter_mut().zip(row) {
                    *s += ui * vi;
                }
            }
        }
    }
    let mut y = vec![0.0; n];
    for i in 0..n {
        let g = assign[i];
        y[i] = dot(u1.row(i), &group_w[g * k..(g + 1) * k]);
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn setup(n: usize, k: usize, seed: u64) -> (Matrix, Matrix, Vec<f64>) {
        let mut rng = Rng::seeded(seed);
        let u1 = Matrix::randn(n, k, &mut rng);
        let u2 = Matrix::randn(n, k, &mut rng);
        let v = rng.randn_vec(n);
        (u1, u2, v)
    }

    fn assert_close(a: &[f64], b: &[f64]) {
        for (x, y) in a.iter().zip(b) {
            assert!((x - y).abs() < 1e-9, "{x} vs {y}");
        }
    }

    #[test]
    fn causal_matches_dense() {
        let (u1, u2, v) = setup(23, 5, 141);
        let mask = Mask::causal(23);
        assert_close(&causal_multiply(&u1, &u2, &v), &dense_multiply(&mask, &u1, &u2, &v));
    }

    #[test]
    fn row_change_matches_dense_all_masks() {
        let n = 20;
        let (u1, u2, v) = setup(n, 4, 142);
        for mask in [
            Mask::causal(n),
            Mask::sliding_window(n, 4, 2),
            Mask::continuous_row(
                (0..n).map(|i| i / 3).collect(),
                (0..n).map(|i| (i / 3 + 5).min(n - 1)).collect(),
            ),
        ] {
            assert_close(
                &row_change_multiply(&mask, &u1, &u2, &v),
                &dense_multiply(&mask, &u1, &u2, &v),
            );
        }
    }

    #[test]
    fn analytic_deltas_match_scanned() {
        let n = 24;
        let (u1, u2, v) = setup(n, 3, 143);
        for mask in [Mask::causal(n), Mask::sliding_window(n, 5, 2)] {
            let deltas = analytic_deltas(&mask).unwrap();
            let via_analytic = row_change_multiply_with_deltas(&deltas, &u1, &u2, &v);
            let via_scan = row_change_multiply(&mask, &u1, &u2, &v);
            assert_close(&via_analytic, &via_scan);
        }
    }

    #[test]
    fn delta_sizes_match_row_change_bounds() {
        let mask = Mask::sliding_window(32, 6, 1);
        let deltas = analytic_deltas(&mask).unwrap();
        let bounds = mask.row_change_bounds();
        for (i, (add, del)) in deltas.iter().enumerate() {
            assert_eq!(add.len() + del.len(), bounds[i], "row {i}");
        }
    }

    #[test]
    fn segtree_and_prefix_match_dense() {
        let n = 29;
        let (u1, u2, v) = setup(n, 6, 144);
        let s: Vec<usize> = (0..n).map(|i| i / 2).collect();
        let t: Vec<usize> = (0..n).map(|i| (i / 2 + 9).min(n - 1)).collect();
        let mask = Mask::continuous_row(s.clone(), t.clone());
        let want = dense_multiply(&mask, &u1, &u2, &v);
        assert_close(&continuous_row_multiply_segtree(&u1, &u2, &v, &s, &t), &want);
        assert_close(&continuous_row_multiply_prefix(&u1, &u2, &v, &s, &t), &want);
    }

    #[test]
    fn distinct_rows_matches_dense() {
        let n = 21;
        let (u1, u2, v) = setup(n, 4, 145);
        let mut patterns = vec![vec![false; n]; 3];
        for j in 0..n {
            patterns[0][j] = j % 2 == 0;
            patterns[1][j] = j < 10;
            patterns[2][j] = j % 3 == 1;
        }
        let assign: Vec<usize> = (0..n).map(|i| (i * 7) % 3).collect();
        let mask = Mask::distinct_rows(assign.clone(), patterns.clone());
        assert_close(
            &distinct_rows_multiply(&u1, &u2, &v, &assign, &patterns),
            &dense_multiply(&mask, &u1, &u2, &v),
        );
    }

    #[test]
    fn distinct_cols_matches_dense() {
        let n = 21;
        let (u1, u2, v) = setup(n, 4, 146);
        let mut patterns = vec![vec![false; n]; 3];
        for j in 0..n {
            patterns[0][j] = j % 2 == 1;
            patterns[1][j] = j > 5;
            patterns[2][j] = j % 4 == 0;
        }
        let assign: Vec<usize> = (0..n).map(|i| (i * 5) % 3).collect();
        let mask = Mask::distinct_cols(assign.clone(), patterns.clone());
        assert_close(
            &distinct_cols_multiply(&u1, &u2, &v, &assign, &patterns),
            &dense_multiply(&mask, &u1, &u2, &v),
        );
    }

    #[test]
    fn empty_support_rows_give_zero() {
        let n = 8;
        let (u1, u2, v) = setup(n, 3, 147);
        // Pattern with an all-false row.
        let patterns = vec![vec![false; n], vec![true; n]];
        let assign = vec![0, 1, 0, 1, 0, 1, 0, 1];
        let y = distinct_rows_multiply(&u1, &u2, &v, &assign, &patterns);
        assert_eq!(y[0], 0.0);
        assert_ne!(y[1], 0.0);
    }
}
