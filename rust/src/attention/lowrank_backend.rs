//! Low-rank prefill adapter: the [`crate::lowrank`] masked kernels
//! (Theorem 6.5) shaped like an engine prefill operator.
//!
//! The `lowrank` module has carried the paper's masked low-rank
//! approximation — `Ỹ = D̃⁻¹ (W ∘ U₁U₂ᵀ) V` with the causal
//! prefix-sum kernel (Algorithm 4) — since the Theorem 6.5 PR, but
//! only as a standalone library. This adapter is the thin seam that
//! lets a [`BatchedBackend::LowRank`](super::batched::BatchedBackend)
//! or routed job execute it as an `AttnJob`-shaped causal prefill:
//! same `(q, k, v, mask) → y` signature as the exact and conv
//! operators, same float-op order as calling
//! [`LowRankAttention::new`] + [`LowRankAttention::forward`] directly
//! (it delegates — routed low-rank output is therefore bit-identical
//! to a direct `BatchedBackend::LowRank` job).
//!
//! # What a low-rank route can and cannot do
//!
//! * **Prefill**: `O(n·k·d)` with feature rank `k = C(d+g, g)` —
//!   a win exactly when `k < n` ([`lowrank_viable`] is the router's
//!   guard; past it, low-rank is strictly more work than exact).
//! * **Decode**: a low-rank route **cannot seed a
//!   [`DecodeState`](super::decode::DecodeState)**
//!   ([`CAN_SEED_DECODE`] is `false`): the decode path appends rows to
//!   a recovered *conv basis*, and `U₁U₂ᵀ` has no conv structure to
//!   append to. The router therefore pins decode-bound sessions to
//!   exact/conv (`AttentionBackend::Routed` maps `to_decode()` to the
//!   exact last-row kernel), counting the refusals in
//!   `Metrics::router_decode_pins` — the seed-hit invariants of the
//!   generation path survive routing untouched.

use super::Mask;
use crate::lowrank::{LowRankAttention, LowRankConfig};
use crate::tensor::Matrix;

/// Low-rank routes cannot seed a conv [`DecodeState`]
/// (see the module docs); the router pins decode to exact/conv.
///
/// [`DecodeState`]: super::decode::DecodeState
pub const CAN_SEED_DECODE: bool = false;

/// Is a low-rank route a win at this shape? Rank `k = C(d+g, g)` must
/// be strictly below `n`, otherwise the `O(n·k·d)` feature path costs
/// at least the `O(n²·d)` exact kernel. [`LowRankConfig::rank`]
/// saturates on overflow, so absurd `(d, g)` pairs fail this check
/// instead of wrapping into a spuriously tiny rank.
pub fn lowrank_viable(cfg: &LowRankConfig, n: usize, d: usize) -> bool {
    cfg.rank(d) < n
}

/// One (sequence, head) causal low-rank prefill, `AttnJob`-shaped:
/// build the polynomial factors once, then
/// `Ỹ = D̃⁻¹ (W ∘ U₁U₂ᵀ) V` through the mask's fast kernel (causal →
/// Algorithm 4 prefix sums, `O(n·k)` per column). Bit-identical to
/// `LowRankAttention::new(q, k, mask, cfg).forward(v)` — this adapter
/// only shapes the call, it never reorders a float op.
pub fn lowrank_prefill(
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    mask: Mask,
    cfg: &LowRankConfig,
) -> Matrix {
    LowRankAttention::new(q, k, mask, cfg).forward(v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{max_abs_diff, Rng};

    #[test]
    fn adapter_is_bit_identical_to_direct_lowrank() {
        let mut rng = Rng::seeded(42);
        let (n, d) = (24, 4);
        let q = Matrix::rand_uniform(n, d, 0.8, &mut rng);
        let k = Matrix::rand_uniform(n, d, 0.8, &mut rng);
        let v = Matrix::randn(n, d, &mut rng);
        let cfg = LowRankConfig::new(2, d as f64);
        let mask = Mask::causal(n);
        let direct = LowRankAttention::new(&q, &k, mask.clone(), &cfg).forward(&v);
        let adapted = lowrank_prefill(&q, &k, &v, mask, &cfg);
        assert_eq!(max_abs_diff(&direct, &adapted), 0.0);
    }

    #[test]
    fn viability_is_rank_below_n() {
        let cfg = LowRankConfig::new(2, 4.0);
        // C(4+2, 2) = 15: viable at n = 64, a loss at n = 15.
        assert!(lowrank_viable(&cfg, 64, 4));
        assert!(!lowrank_viable(&cfg, 15, 4));
        assert!(!lowrank_viable(&cfg, 8, 4));
        // Saturated ranks (overflowed binomials) are never viable.
        let absurd = LowRankConfig::new(35, 1.0);
        assert!(!lowrank_viable(&absurd, 1 << 20, 35));
    }

    #[test]
    fn decode_seeding_is_declared_impossible() {
        assert!(!CAN_SEED_DECODE);
    }
}
