//! Rotary Position Embedding (RoPE) and the paper's structured-QK
//! constructions (Appendix A case study + Appendix B.5).
//!
//! Lemma B.25 / B.30: unit vectors built from rotations at frequencies
//! `θ_k` have `⟨z_i, z_j⟩ = g(i − j)`, so `QKᵀ = ZZᵀ` is **exactly
//! Toeplitz** — the cleanest instance of the conv-like structure the
//! paper observes in Llama3 (Figure 1b), and our stand-in for those
//! proprietary attention matrices.

use crate::tensor::{Matrix, Rng};

/// Rotary position embedding with the standard geometric frequency
/// schedule `θ_k = base^{−2k/d}`.
#[derive(Clone, Debug)]
pub struct Rope {
    d: usize,
    freqs: Vec<f64>,
}

impl Rope {
    /// `d` must be even (RoPE rotates coordinate pairs).
    pub fn new(d: usize, base: f64) -> Self {
        assert!(d % 2 == 0, "RoPE requires even head dim");
        let freqs = (0..d / 2).map(|k| base.powf(-2.0 * k as f64 / d as f64)).collect();
        Rope { d, freqs }
    }

    /// Apply the position-`pos` rotation to one row (in place).
    pub fn rotate_row(&self, row: &mut [f64], pos: usize) {
        assert_eq!(row.len(), self.d);
        for (k, &f) in self.freqs.iter().enumerate() {
            let theta = pos as f64 * f;
            let (s, c) = theta.sin_cos();
            let (a, b) = (row[2 * k], row[2 * k + 1]);
            row[2 * k] = a * c - b * s;
            row[2 * k + 1] = a * s + b * c;
        }
    }

    /// Apply to every row of an `n×d` matrix: row `i` gets rotation
    /// `R^{(i)}` — Appendix A: `Q' = R·Q, K' = R·K` in `O(nd)` time,
    /// after which Theorem 4.4 applies unchanged to `Q', K'`.
    pub fn apply(&self, x: &Matrix) -> Matrix {
        let mut out = x.clone();
        for i in 0..out.rows() {
            self.rotate_row(out.row_mut(i), i);
        }
        out
    }
}

/// Lemma B.25 construction: generate `Q, K ∈ R^{n×d}` such that
/// `QKᵀ` is exactly Toeplitz, i.e. `(QKᵀ)[i][j] = g(i−j)` — a matrix
/// with small conv-basis k after masking.
///
/// `Z` rows are `z_i = H·u_i` with `u_{i,2k} = a_k cos(iθ_k)`,
/// `u_{i,2k+1} = a_k sin(iθ_k)`, `Σ a_k² = 1`; we return `Q = K = Z·c`
/// (scaled by `c = scale`) so `QKᵀ = c²·ZZᵀ` with
/// `(ZZᵀ)[i][j] = Σ_k a_k² cos((i−j)θ_k)`.
///
/// `n_freqs ≤ d/2` controls how many rotation planes are active.
pub fn rope_structured_qk(n: usize, d: usize, n_freqs: usize, rng: &mut Rng) -> (Matrix, Matrix) {
    // Lemma B.25 covers both parities: when d is odd the last coordinate
    // is a constant a_l (it contributes a_l² to every inner product,
    // which is still a function of i−j).
    let planes = d / 2;
    let n_freqs = n_freqs.clamp(1, planes.max(1));
    let odd = d % 2 == 1;
    assert!(d >= 2, "need d ≥ 2");
    // Random amplitudes on the simplex (Σ a_k² [+ const²] = 1).
    let n_amp = n_freqs + usize::from(odd);
    let mut amps: Vec<f64> = (0..n_amp).map(|_| rng.uniform() + 0.1).collect();
    let norm: f64 = amps.iter().map(|a| a * a).sum::<f64>().sqrt();
    for a in amps.iter_mut() {
        *a /= norm;
    }
    let thetas: Vec<f64> = (0..n_freqs)
        .map(|k| 0.3 * (k as f64 + 1.0) / n_freqs as f64 + 0.05 * rng.uniform())
        .collect();

    // Random orthonormal H via Gram–Schmidt on a Gaussian matrix
    // (Lemma B.25 allows any orthonormal H; it cancels in ZZᵀ but makes
    // Q, K look generic to downstream code).
    let h = random_orthonormal(d, rng);

    let mut u = Matrix::zeros(n, d);
    for i in 0..n {
        for k in 0..n_freqs {
            let theta = i as f64 * thetas[k];
            u[(i, 2 * k)] = amps[k] * theta.cos();
            u[(i, 2 * k + 1)] = amps[k] * theta.sin();
        }
        if odd {
            u[(i, d - 1)] = amps[n_freqs];
        }
    }
    let z = u.matmul(&h);
    (z.clone(), z)
}

/// Random orthonormal `d×d` matrix (Gram–Schmidt on Gaussian columns).
pub fn random_orthonormal(d: usize, rng: &mut Rng) -> Matrix {
    let mut m = Matrix::randn(d, d, rng);
    // Orthonormalize rows.
    for i in 0..d {
        for j in 0..i {
            let proj = crate::tensor::dot(m.row(i), m.row(j));
            let (head, tail) = m.data_mut().split_at_mut(i * d);
            let row_j = &head[j * d..(j + 1) * d];
            let row_i = &mut tail[..d];
            for (x, y) in row_i.iter_mut().zip(row_j) {
                *x -= proj * y;
            }
        }
        let nrm = crate::tensor::dot(m.row(i), m.row(i)).sqrt();
        for x in m.row_mut(i) {
            *x /= nrm;
        }
    }
    m
}


/// Fraction of lower-triangular Frobenius energy captured by the best
/// Toeplitz (conv-structured) approximation — diagonal means. 1.0 ⇔
/// exactly conv-structured; trained attention heads land high but < 1
/// (the Figure 1b observation made quantitative).
pub fn toeplitz_energy_fraction(h: &Matrix) -> f64 {
    let n = h.rows();
    let mut total = 0.0;
    let mut captured = 0.0;
    for off in 0..n {
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        let count = (n - off) as f64;
        for i in off..n {
            let v = h[(i, i - off)];
            sum += v;
            sumsq += v * v;
        }
        total += sumsq;
        captured += sum * sum / count; // ‖mean·1‖² on this diagonal
    }
    if total == 0.0 {
        1.0
    } else {
        captured / total
    }
}

/// Measure how Toeplitz a matrix is: max over diagonals of the spread
/// (max − min) of entries on that diagonal, lower triangle only. Zero ⇔
/// exactly conv-structured (Figure 1b's qualitative claim made
/// quantitative).
pub fn toeplitzness(h: &Matrix) -> f64 {
    let n = h.rows();
    let mut worst: f64 = 0.0;
    for off in 0..n {
        let mut mn = f64::INFINITY;
        let mut mx = f64::NEG_INFINITY;
        for i in off..n {
            let v = h[(i, i - off)];
            mn = mn.min(v);
            mx = mx.max(v);
        }
        worst = worst.max(mx - mn);
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rope_preserves_norm() {
        let mut rng = Rng::seeded(91);
        let rope = Rope::new(8, 10_000.0);
        let mut row = rng.randn_vec(8);
        let before: f64 = row.iter().map(|x| x * x).sum();
        rope.rotate_row(&mut row, 17);
        let after: f64 = row.iter().map(|x| x * x).sum();
        assert!((before - after).abs() < 1e-10);
    }

    #[test]
    fn rope_relative_position_property() {
        // (R^(i) q)·(R^(j) k) depends only on i − j:
        // check ⟨rot(q,i), rot(k,j)⟩ == ⟨rot(q,i+5), rot(k,j+5)⟩.
        let mut rng = Rng::seeded(92);
        let rope = Rope::new(16, 10_000.0);
        let q0 = rng.randn_vec(16);
        let k0 = rng.randn_vec(16);
        let dotp = |i: usize, j: usize| {
            let mut q = q0.clone();
            let mut k = k0.clone();
            rope.rotate_row(&mut q, i);
            rope.rotate_row(&mut k, j);
            crate::tensor::dot(&q, &k)
        };
        assert!((dotp(7, 3) - dotp(12, 8)).abs() < 1e-9);
        assert!((dotp(0, 0) - dotp(25, 25)).abs() < 1e-9);
    }

    #[test]
    fn structured_qk_is_exactly_toeplitz() {
        let mut rng = Rng::seeded(93);
        let (q, k) = rope_structured_qk(32, 8, 3, &mut rng);
        let h = q.matmul(&k.transpose());
        assert!(toeplitzness(&h) < 1e-9, "spread = {}", toeplitzness(&h));
    }

    #[test]
    fn structured_qk_rows_unit_norm() {
        let mut rng = Rng::seeded(94);
        let (q, _) = rope_structured_qk(16, 6, 2, &mut rng);
        for i in 0..16 {
            let nrm: f64 = q.row(i).iter().map(|x| x * x).sum();
            assert!((nrm - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn random_orthonormal_is_orthonormal() {
        let mut rng = Rng::seeded(95);
        let h = random_orthonormal(6, &mut rng);
        let gram = h.matmul(&h.transpose());
        let eye = Matrix::eye(6);
        assert!(crate::tensor::max_abs_diff(&gram, &eye) < 1e-9);
    }

    #[test]
    fn toeplitz_energy_fraction_bounds() {
        let mut rng = Rng::seeded(97);
        let (q, _) = rope_structured_qk(20, 6, 2, &mut rng);
        let toep = q.matmul(&q.transpose());
        assert!((toeplitz_energy_fraction(&toep) - 1.0).abs() < 1e-9);
        let generic = Matrix::randn(20, 20, &mut rng);
        let frac = toeplitz_energy_fraction(&generic);
        assert!(frac > 0.0 && frac < 0.5, "frac = {frac}");
    }

    #[test]
    fn generic_qk_is_not_toeplitz() {
        let mut rng = Rng::seeded(96);
        let q = Matrix::randn(16, 4, &mut rng);
        let h = q.matmul(&q.transpose());
        assert!(toeplitzness(&h) > 0.1);
    }
}
