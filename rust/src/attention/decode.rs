//! Incremental (decode-time) conv-basis attention.
//!
//! The paper motivates long-context *inference*; in autoregressive
//! serving the dominant operation is attending the **newest** token
//! against the prefix. With a cached conv basis this is a banded dot
//! product, not an FFT:
//!
//! row `n−1` of `Σ_r conv(b̃_r, m_r)` is `Σ_r b̃_r[n−1−j]` over covered
//! columns, so `y_last = (Σ_j A[n−1, j]·v_j) / D[n−1]` costs `O(k·n)`
//! for the weights + `O(n·d)` for the weighted sum — no `n×n` matrix,
//! no transform. This module also maintains the basis under sequence
//! *growth*: appending a token extends every `b̃_r` by one tail entry
//! probed from the new K row (exact when the underlying structure is
//! conv; the serving layer re-recovers on drift).
//!
//! # Worked example
//!
//! Recover a basis once (prefill), then decode a grown sequence one
//! token at a time. [`DecodeState::append_token`] reports a *drift*
//! score — ~0 while the grown matrix keeps its conv structure, large
//! when it breaks (the batched engine re-recovers past a threshold):
//!
//! ```
//! use conv_basis::attention::conv_attention_strided;
//! use conv_basis::attention::decode::{exact_attend_last, DecodeState};
//! use conv_basis::attention::rope::rope_structured_qk;
//! use conv_basis::tensor::{dot, Matrix, Rng};
//!
//! let mut rng = Rng::seeded(7);
//! let (n, d) = (16, 4);
//! // Toeplitz-structured Q, K at the *grown* length n+1; prefill on
//! // the length-n prefix.
//! let (q_full, k_full) = rope_structured_qk(n + 1, d, 2, &mut rng);
//! let (q, k) = (q_full.slice(0, n, 0, d), k_full.slice(0, n, 0, d));
//! let out = conv_attention_strided(&q, &k, &Matrix::zeros(n, d), 1).unwrap();
//! let mut state = DecodeState::new(out.post_basis, out.d_tilde);
//!
//! // One decode step: the new pre-exp logits row q_new · k_j, j ≤ n.
//! let new_row: Vec<f64> =
//!     (0..=n).map(|j| dot(q_full.row(n), k_full.row(j))).collect();
//! let drift = state.append_token(&new_row);
//! assert!(drift < 1e-8, "conv growth is drift-free");
//!
//! // Attend the newest token in O(k·n + n·d) — no n×n matrix.
//! let v = Matrix::randn(n + 1, d, &mut rng);
//! let fast = state.attend_last(&v);
//! let want = exact_attend_last(&q_full, &k_full, &v);
//! for (a, b) in fast.iter().zip(&want) {
//!     assert!((a - b).abs() < 1e-9);
//! }
//! ```
//!
//! Three last-row kernels live here; pick by caller:
//!
//! * [`DecodeState::attend_last`] — `O(k·n + n·d)` from a cached basis
//!   (the conv decode path);
//! * [`exact_decode_last_row`] — exact, from a precomputed pre-exp
//!   logits row, with the **same floating-point operation order** as
//!   [`exact_attention`](crate::attention::exact_attention)'s last row
//!   (both stabilized by the same ascending max-fold), so a decode
//!   step bit-matches a full prefill (the engine's
//!   [`DecodeOp::Exact`](crate::attention::batched::DecodeOp) path and
//!   the `tests/decode.rs` bit-match property rely on this);
//! * [`exact_attend_last_row_only`] — exact stabilized softmax with
//!   divide-by-denominator accumulation, the fair standalone KV-cache
//!   baseline for benches (close to but not bit-compatible with the
//!   full forward, which multiplies by the reciprocal).
//!
//! A fourth exact decode kernel,
//! [`blocked_decode_last_row`](crate::attention::blocked), lives with
//! the blocked family: it bit-matches *blocked* prefill instead.

use super::Mask;
use crate::basis::{ConvBasis, KConvBasis};
use crate::tensor::Matrix;

/// Decode-time attention state for one (layer, head): the cached
/// post-exp basis and normalizer over the current prefix.
#[derive(Clone, Debug)]
pub struct DecodeState {
    post_basis: KConvBasis,
    d_tilde: Vec<f64>,
}

impl DecodeState {
    pub fn new(post_basis: KConvBasis, d_tilde: Vec<f64>) -> Self {
        assert_eq!(post_basis.n(), d_tilde.len());
        DecodeState { post_basis, d_tilde }
    }

    pub fn n(&self) -> usize {
        self.post_basis.n()
    }

    pub fn basis(&self) -> &KConvBasis {
        &self.post_basis
    }

    /// Normalizer diagonal `D̃` over the current prefix.
    pub fn d_tilde(&self) -> &[f64] {
        &self.d_tilde
    }

    /// Floats resident in this state (`Σ_r |b̃_r| + |D̃|`) — the decode
    /// path's contribution to KV-cache memory accounting
    /// (`Metrics::decode_resident_bytes`).
    pub fn memory_floats(&self) -> usize {
        self.post_basis.memory_floats() + self.d_tilde.len()
    }

    /// Basis-implied attention weights of the **last** row (post-exp,
    /// pre-normalization): entry `j` is `Σ_r b̃_r[n−1−j]` over the
    /// windows covering column `j`.
    pub fn last_weight_row(&self) -> Vec<f64> {
        let n = self.n();
        let mut weight_row = vec![0.0; n];
        for t in self.post_basis.terms() {
            let off = n - t.m;
            // Columns off..n are covered; weight at column j is b[n−1−j].
            for j in off..n {
                weight_row[j] += t.b[n - 1 - j];
            }
        }
        weight_row
    }

    /// Attention output for the **last** row only — `O(k·n + n·d)`.
    pub fn attend_last(&self, v: &Matrix) -> Vec<f64> {
        let n = self.n();
        assert_eq!(v.rows(), n);
        let d = v.cols();
        let mut y = vec![0.0; d];
        let weight_row = self.last_weight_row();
        for (j, &w) in weight_row.iter().enumerate() {
            if w != 0.0 {
                crate::tensor::axpy(w, v.row(j), &mut y);
            }
        }
        let inv = 1.0 / self.d_tilde[n - 1];
        for x in y.iter_mut() {
            *x *= inv;
        }
        y
    }

    /// Append one token: extend each basis vector with the probed tail
    /// value and update the normalizer. `new_row_of_h` is the new last
    /// row of `M ∘ (QKᵀ)` *pre-exp* (length `n+1`, i.e. `q_new · k_j`
    /// for `j ≤ n`).
    ///
    /// Exactness: if the grown matrix still has the same onsets, this
    /// reproduces recover-from-scratch; under drift the serving layer's
    /// fingerprint check forces re-recovery. For the common k = 1
    /// (Toeplitz) case the update is exact whenever the new row extends
    /// the same generator.
    ///
    /// Returns the **drift** of the grown state: the maximum deviation
    /// between the basis-implied last-row weights and the exact
    /// `exp(new_row_of_h)` weights, normalized by the exact softmax
    /// denominator. ~0 (float noise) while the structure holds; `O(1)`
    /// when it breaks. The batched engine re-recovers when this exceeds
    /// the job's tolerance ([`DecodeOp::Conv`]'s `drift_tol`, tracked
    /// per-state and surfaced through `coordinator::metrics`).
    ///
    /// [`DecodeOp::Conv`]: crate::attention::batched::DecodeOp
    pub fn append_token(&mut self, new_row_of_h: &[f64]) -> f64 {
        let n = self.n();
        assert_eq!(new_row_of_h.len(), n + 1);
        // Pre-exp cumulative generator value at each diagonal offset is
        // implied by the post-exp telescoping; for the append we need
        // the new diagonal offset t = n (the farthest entry, column 0)
        // and to extend every b̃_r by one slot. The exp of the new
        // row's value at column 0 equals the cumulative Σ b̃_r[n], so
        // the *first* basis (largest window, covering column 0) absorbs
        // the tail; other windows keep their (shorter) reach.
        let mut terms: Vec<ConvBasis> = Vec::with_capacity(self.post_basis.k());
        for (r, t) in self.post_basis.terms().iter().enumerate() {
            let mut b = t.b.clone();
            // Extend vector length to n+1.
            b.push(0.0);
            if r == 0 {
                // New farthest offset: exp(H[n, 0]) (column 0 is covered
                // only by the first window).
                b[n] = new_row_of_h[0].exp();
            }
            terms.push(ConvBasis { b, m: t.m + 1 });
        }
        // Windows grew by one uniformly — still strictly decreasing.
        let grown = KConvBasis::new(n + 1, terms);
        // New normalizer entry: row n of the grown matrix = exp of the
        // new pre-exp row (exact softmax denominator for the new token).
        let mut d = self.d_tilde.clone();
        let new_d: f64 = new_row_of_h.iter().map(|&h| h.exp()).sum();
        d.push(new_d);
        self.post_basis = grown;
        self.d_tilde = d;
        // Drift: basis-implied last-row weights vs the exact exp row.
        let weight_row = self.last_weight_row();
        let mut dev: f64 = 0.0;
        for (w, &h) in weight_row.iter().zip(new_row_of_h) {
            dev = dev.max((w - h.exp()).abs());
        }
        dev / new_d
    }

    /// Roll the state back to a length-`n` prefix — the exact inverse
    /// of [`Self::append_token`] for the dropped rows. The speculative
    /// decoder drafts ahead with `append_token` and truncates back to
    /// the verifier-accepted prefix with this: each basis vector drops
    /// its appended tail slots (`b̃_r[n..]` — the retained entries are
    /// untouched bytes, so truncate ∘ append is bitwise identity) and
    /// every window shrinks by the same `delta`, preserving the
    /// strictly-decreasing window invariant.
    ///
    /// Returns `false` without modifying the state when the rollback is
    /// infeasible: a state re-recovered from scratch mid-draft (drift
    /// fallback) may hold windows shorter than `delta`, and a window
    /// cannot shrink below one column. Callers then re-seed from the
    /// truncated K/Q instead (`BatchedEngine::seed_decode`).
    pub fn truncate_to(&mut self, n: usize) -> bool {
        let n_old = self.n();
        assert!(n >= 1 && n <= n_old, "truncate_to out of range");
        if n == n_old {
            return true;
        }
        let delta = n_old - n;
        if self.post_basis.terms().iter().any(|t| t.m <= delta) {
            return false;
        }
        let terms: Vec<ConvBasis> = self
            .post_basis
            .terms()
            .iter()
            .map(|t| {
                let mut b = t.b.clone();
                b.truncate(n);
                ConvBasis { b, m: t.m - delta }
            })
            .collect();
        // Windows shrank by one uniformly per dropped row — still
        // strictly decreasing, and ≥ 1 by the feasibility check above.
        self.post_basis = KConvBasis::new(n, terms);
        self.d_tilde.truncate(n);
        true
    }
}

/// Exact last-row attention from a precomputed pre-exp logits row
/// (`new_row_of_h[j] = q_last · k_j`, causal, length `n`), replicating
/// [`exact_attention`](crate::attention::exact_attention)'s exact
/// floating-point operation order on its last row — ascending max
/// fold, stabilized `exp`, ascending-`j` accumulation,
/// multiply-by-reciprocal — so an exact decode step **bit-matches** a
/// fresh full prefill. This is the kernel behind the batched engine's
/// row-stream [`DecodeOp::Exact`](crate::attention::batched::DecodeOp)
/// and the fallback for degenerate conv decode states.
pub fn exact_decode_last_row(new_row_of_h: &[f64], v: &Matrix) -> Vec<f64> {
    let n = new_row_of_h.len();
    assert_eq!(v.rows(), n);
    let d = v.cols();
    // Mirrors `exact_attention`: the row max via the same ascending
    // f64::max fold over the causal support …
    let mut mx = f64::NEG_INFINITY;
    for &h in new_row_of_h {
        mx = mx.max(h);
    }
    // … A[n−1, j] = exp(H[n−1, j] − max) …
    let w: Vec<f64> = new_row_of_h.iter().map(|&h| (h - mx).exp()).collect();
    // … D[n−1] via `Matrix::row_sums` (sequential iterator sum) …
    let den: f64 = w.iter().sum();
    // … (A·V)[n−1] via `Matrix::matmul`'s i-k-j accumulation (skip on
    // exact zeros included) …
    let mut y = vec![0.0; d];
    for (j, &wj) in w.iter().enumerate() {
        if wj == 0.0 {
            continue;
        }
        let vr = v.row(j);
        for (c, yv) in y.iter_mut().enumerate() {
            *yv += wj * vr[c];
        }
    }
    // … and `scale_rows` by the reciprocal (not a division).
    let inv = 1.0 / den;
    for x in y.iter_mut() {
        *x *= inv;
    }
    y
}


/// Fair exact last-row baseline: computes only row `n−1` of the
/// attention — `O(n·d)` (dot per column + softmax + weighted sum).
/// This is what a KV-cache serving stack actually does per decode step.
pub fn exact_attend_last_row_only(q: &Matrix, k: &Matrix, v: &Matrix) -> Vec<f64> {
    let n = q.rows();
    let d = v.cols();
    let qn = q.row(n - 1);
    // Stabilized softmax over the causal row.
    let mut logits = Vec::with_capacity(n);
    let mut mx = f64::NEG_INFINITY;
    for j in 0..n {
        let l = crate::tensor::dot(qn, k.row(j));
        mx = mx.max(l);
        logits.push(l);
    }
    let mut den = 0.0;
    let mut y = vec![0.0; d];
    for j in 0..n {
        let w = (logits[j] - mx).exp();
        den += w;
        crate::tensor::axpy(w, v.row(j), &mut y);
    }
    for x in y.iter_mut() {
        *x /= den;
    }
    y
}

/// Exact last-row attention oracle (for tests): softmax row `n−1` of
/// `M ∘ exp(QKᵀ)` applied to V.
pub fn exact_attend_last(q: &Matrix, k: &Matrix, v: &Matrix) -> Vec<f64> {
    let n = q.rows();
    let mask = Mask::causal(n);
    let y = super::exact_attention(q, k, v, &mask);
    y.row(n - 1).to_vec()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::conv_attention_strided;
    use crate::attention::rope::rope_structured_qk;
    use crate::tensor::Rng;

    #[test]
    fn fast_exact_last_row_matches_full() {
        let mut rng = Rng::seeded(505);
        let (n, d) = (24, 5);
        let q = Matrix::randn(n, d, &mut rng).scale(0.4);
        let k = Matrix::randn(n, d, &mut rng).scale(0.4);
        let v = Matrix::randn(n, d, &mut rng);
        let fast = exact_attend_last_row_only(&q, &k, &v);
        let full = exact_attend_last(&q, &k, &v);
        for (a, b) in fast.iter().zip(&full) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn exact_decode_last_row_bitmatches_exact_attention() {
        // The decode kernel must replicate `exact_attention`'s float op
        // order exactly — equality below is bitwise, not approximate.
        let mut rng = Rng::seeded(506);
        let (n, d) = (20, 6);
        let q = Matrix::randn(n, d, &mut rng).scale(0.4);
        let k = Matrix::randn(n, d, &mut rng).scale(0.4);
        let v = Matrix::randn(n, d, &mut rng);
        // Pre-exp logits row in matmul's i-k-j accumulation order.
        let mut new_row = vec![0.0; n];
        for (c, &qc) in q.row(n - 1).iter().enumerate() {
            if qc == 0.0 {
                continue;
            }
            for (j, slot) in new_row.iter_mut().enumerate() {
                *slot += qc * k[(j, c)];
            }
        }
        let fast = exact_decode_last_row(&new_row, &v);
        let full = crate::attention::exact_attention(&q, &k, &v, &Mask::causal(n));
        for (a, b) in fast.iter().zip(full.row(n - 1)) {
            assert_eq!(*a, *b, "decode last row must be bit-identical");
        }
    }

    #[test]
    fn append_token_drift_is_tiny_on_structured_growth() {
        let mut rng = Rng::seeded(507);
        let (n, d) = (24, 6);
        let (q_full, k_full) = rope_structured_qk(n + 1, d, 2, &mut rng);
        let q = q_full.slice(0, n, 0, d);
        let k = k_full.slice(0, n, 0, d);
        let out = conv_attention_strided(&q, &k, &Matrix::zeros(n, d), 1).unwrap();
        let mut state = DecodeState::new(out.post_basis, out.d_tilde);
        let qn = q_full.row(n);
        let new_row: Vec<f64> =
            (0..=n).map(|j| crate::tensor::dot(qn, k_full.row(j))).collect();
        let drift = state.append_token(&new_row);
        assert!(drift < 1e-10, "structured growth must not drift: {drift}");
    }

    #[test]
    fn append_token_drift_is_large_on_broken_structure() {
        let mut rng = Rng::seeded(508);
        let (n, d) = (24, 6);
        let (q, k) = rope_structured_qk(n, d, 2, &mut rng);
        let out = conv_attention_strided(&q, &k, &Matrix::zeros(n, d), 1).unwrap();
        let mut state = DecodeState::new(out.post_basis, out.d_tilde);
        // A random (non-Toeplitz-extending) new row breaks the
        // generator; the append must report it.
        let new_row: Vec<f64> = (0..=n).map(|_| rng.randn()).collect();
        let drift = state.append_token(&new_row);
        assert!(drift > 1e-3, "broken structure must register drift: {drift}");
    }

    #[test]
    fn attend_last_matches_full_forward() {
        let mut rng = Rng::seeded(501);
        let (n, d) = (48, 8);
        let (q, k) = rope_structured_qk(n, d, 3, &mut rng);
        let v = Matrix::randn(n, d, &mut rng);
        let out = conv_attention_strided(&q, &k, &v, 4).unwrap();
        let state = DecodeState::new(out.post_basis.clone(), out.d_tilde.clone());
        let last = state.attend_last(&v);
        for (a, b) in last.iter().zip(out.y.row(n - 1)) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn attend_last_matches_exact_oracle_on_structured() {
        let mut rng = Rng::seeded(502);
        let (n, d) = (64, 8);
        let (q, k) = rope_structured_qk(n, d, 3, &mut rng);
        let v = Matrix::randn(n, d, &mut rng);
        let out = conv_attention_strided(&q, &k, &v, 1).unwrap();
        let state = DecodeState::new(out.post_basis, out.d_tilde);
        let fast = state.attend_last(&v);
        let want = exact_attend_last(&q, &k, &v);
        for (a, b) in fast.iter().zip(&want) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn append_token_exact_on_toeplitz_growth() {
        // Grow a Toeplitz-structured sequence by one token; incremental
        // state must match recover-from-scratch on the longer prefix.
        let mut rng = Rng::seeded(503);
        let (n, d) = (32, 8);
        let (q_full, k_full) = rope_structured_qk(n + 1, d, 3, &mut rng);
        let q = q_full.slice(0, n, 0, d);
        let k = k_full.slice(0, n, 0, d);
        let out = conv_attention_strided(&q, &k, &Matrix::zeros(n, d), 1).unwrap();
        let mut state = DecodeState::new(out.post_basis, out.d_tilde);

        // New pre-exp row: q_new · k_j for j ≤ n.
        let qn = q_full.row(n);
        let new_row: Vec<f64> = (0..=n)
            .map(|j| crate::tensor::dot(qn, k_full.row(j)))
            .collect();
        state.append_token(&new_row);

        let v_full = Matrix::randn(n + 1, d, &mut rng);
        let fast = state.attend_last(&v_full);
        let want = exact_attend_last(&q_full, &k_full, &v_full);
        for (a, b) in fast.iter().zip(&want) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
    }

    #[test]
    fn truncate_to_is_bitwise_append_inverse() {
        // Draft γ tokens ahead, then roll all of them back: the state
        // must be bit-identical to never having appended (the
        // speculative-decode rollback invariant).
        let mut rng = Rng::seeded(509);
        let (n, gamma, d) = (24, 4, 6);
        let (q_full, k_full) = rope_structured_qk(n + gamma, d, 2, &mut rng);
        let q = q_full.slice(0, n, 0, d);
        let k = k_full.slice(0, n, 0, d);
        let out = conv_attention_strided(&q, &k, &Matrix::zeros(n, d), 1).unwrap();
        let base = DecodeState::new(out.post_basis, out.d_tilde);
        let mut state = base.clone();
        for step in 0..gamma {
            let n_cur = n + step;
            let qn = q_full.row(n_cur);
            let new_row: Vec<f64> =
                (0..=n_cur).map(|j| crate::tensor::dot(qn, k_full.row(j))).collect();
            state.append_token(&new_row);
        }
        assert_eq!(state.n(), n + gamma);
        // Partial rollback (keep 2 of the 4 drafted rows), then full.
        assert!(state.truncate_to(n + 2));
        assert_eq!(state.n(), n + 2);
        assert!(state.truncate_to(n));
        assert_eq!(state.basis().to_dense().data(), base.basis().to_dense().data());
        assert_eq!(state.d_tilde(), base.d_tilde(), "normalizer must roll back bitwise");
        // Truncating to the current length is the identity.
        assert!(state.truncate_to(n));
        assert_eq!(state.n(), n);
    }

    #[test]
    fn truncate_to_refuses_window_underflow() {
        // A state recovered from scratch (not grown by append_token) may
        // hold a window shorter than the rollback distance; truncating
        // below one column is infeasible and must be refused, leaving
        // the state untouched.
        let mut rng = Rng::seeded(510);
        let (n, d) = (16, 4);
        let (q, k) = rope_structured_qk(n, d, 2, &mut rng);
        let out = conv_attention_strided(&q, &k, &Matrix::zeros(n, d), 4).unwrap();
        let mut state = DecodeState::new(out.post_basis, out.d_tilde);
        let m_min = state.basis().terms().iter().map(|t| t.m).min().unwrap();
        if m_min < n {
            let before = state.clone();
            assert!(
                !state.truncate_to(n - m_min),
                "rollback past the shortest window must be refused"
            );
            assert_eq!(state.n(), before.n());
            assert_eq!(state.d_tilde(), before.d_tilde());
        }
        // A one-row rollback of a freshly recovered state is feasible
        // whenever every window exceeds one column.
        if m_min > 1 {
            assert!(state.truncate_to(n - 1));
            assert_eq!(state.n(), n - 1);
        }
    }

    #[test]
    fn decode_loop_stays_exact_over_many_appends() {
        let mut rng = Rng::seeded(504);
        let (n0, grow, d) = (16, 12, 6);
        let n_final = n0 + grow;
        let (q_full, k_full) = rope_structured_qk(n_final, d, 2, &mut rng);
        let q0 = q_full.slice(0, n0, 0, d);
        let k0 = k_full.slice(0, n0, 0, d);
        let out = conv_attention_strided(&q0, &k0, &Matrix::zeros(n0, d), 1).unwrap();
        let mut state = DecodeState::new(out.post_basis, out.d_tilde);
        for step in 0..grow {
            let n_cur = n0 + step;
            let qn = q_full.row(n_cur);
            let new_row: Vec<f64> =
                (0..=n_cur).map(|j| crate::tensor::dot(qn, k_full.row(j))).collect();
            state.append_token(&new_row);
        }
        assert_eq!(state.n(), n_final);
        let v = Matrix::randn(n_final, d, &mut rng);
        let fast = state.attend_last(&v);
        let want = exact_attend_last(&q_full, &k_full, &v);
        for (a, b) in fast.iter().zip(&want) {
            assert!((a - b).abs() < 1e-8);
        }
    }
}
