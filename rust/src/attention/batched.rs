//! Batched multi-head conv-attention engine.
//!
//! The paper's `O(k·n·d·log n)` bound only pays off in serving when its
//! fixed costs are amortized: FFT plan tables, recovered conv bases, and
//! thread startup. The seed code evaluated one head of one sequence at a
//! time, re-planning and re-recovering per call. This engine evaluates
//! **all heads of a batch of sequences in one call**:
//!
//! * one [`SharedFftPlanner`] plan cache for the whole engine — a plan
//!   per transform length is built once (off-lock) and shared by every
//!   worker; each job gets a cheap local view whose repeat lookups are
//!   lock-free ([`FftPlanner::with_shared`]);
//! * a per-(model, layer, head, seq_len) recovered-basis cache
//!   ([`BasisCache`], keyed by [`CacheKey`] with a (Q, K, backend)
//!   content fingerprint) — *recover once, apply per V*, now shared
//!   across heads, sequences and callers;
//! * a fixed [`WorkerPool`] of `std::thread` workers fanning the
//!   (sequence, head) jobs out with **deterministic result ordering**:
//!   jobs are pure and results are re-ordered by input index, so thread
//!   counts 1/2/8 produce bit-identical outputs (pinned by
//!   `tests/properties.rs`).
//!
//! Cache-hit/miss counts surface through [`Metrics`]
//! (`cache_hits`/`cache_misses`, plus `batched_calls`/`batched_jobs`).
//! The coordinator's server routes whole batches through one engine
//! ([`BatchedEngine::with_shared`] over the server's cache and metrics),
//! and the model layer batches all heads of a forward pass through
//! `Transformer::forward_batch`.

use super::{
    apply_cached_basis, conv_attention_masked_with, conv_attention_strided_with, exact_attention,
    Mask, MaskKind,
};
use crate::basis::RecoverConfig;
use crate::coordinator::{fingerprint, BasisCache, CacheKey, CachedBasis, Metrics};
use crate::fft::{FftPlanner, SharedFftPlanner};
use crate::lowrank::{LowRankAttention, LowRankConfig};
use crate::runtime::pool::WorkerPool;
use crate::tensor::Matrix;
use std::sync::Arc;

/// Per-job attention operator (the engine-side mirror of the model
/// layer's `AttentionBackend`; jobs in one batch may mix operators).
#[derive(Clone, Debug)]
pub enum BatchedBackend {
    /// Exact `O(n²d)` attention.
    Exact,
    /// Algorithm 1 with adaptive binary-search recovery; falls back to
    /// exact on recovery failure.
    Conv(RecoverConfig),
    /// Algorithm 1 with strided recovery at k uniform onsets (causal
    /// mask only; non-causal jobs fall back to exact).
    Strided(usize),
    /// Theorem 6.5 masked low-rank attention.
    LowRank(LowRankConfig),
}

/// One (sequence, head) unit of attention work.
#[derive(Clone, Debug)]
pub struct AttnJob {
    /// Layer index (cache key component).
    pub layer: u32,
    /// Head index within the layer (cache key component).
    pub head: u32,
    pub q: Matrix,
    pub k: Matrix,
    pub v: Matrix,
    /// `None` means causal.
    pub mask: Option<Mask>,
    pub backend: BatchedBackend,
}

impl AttnJob {
    /// A causal-mask job.
    pub fn causal(
        layer: u32,
        head: u32,
        q: Matrix,
        k: Matrix,
        v: Matrix,
        backend: BatchedBackend,
    ) -> Self {
        AttnJob { layer, head, q, k, v, mask: None, backend }
    }
}

/// Result of one job, with the provenance the serving layer reports.
#[derive(Clone, Debug)]
pub struct JobOutput {
    /// `Ỹ ≈ D⁻¹AV` for this (sequence, head).
    pub y: Matrix,
    /// Basis size used (0 for exact / low-rank).
    pub basis_k: usize,
    /// Whether a conv path fell back to exact attention.
    pub fell_back: bool,
    /// Whether the basis came from the cache (conv paths only).
    pub cache_hit: bool,
    /// Wall time this job spent executing on its worker (per-job, so
    /// latency percentiles stay meaningful under batching).
    pub exec: std::time::Duration,
}

/// Engine sizing.
#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// Worker threads in the pool (clamped to ≥ 1).
    pub workers: usize,
    /// Recovered-basis cache capacity (entries).
    pub cache_capacity: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            workers: std::thread::available_parallelism().map(|p| p.get()).unwrap_or(2),
            cache_capacity: 256,
        }
    }
}

/// The batched multi-head conv-attention engine. Cheap to share
/// (`Arc`): all methods take `&self` and internal state is synchronized.
pub struct BatchedEngine {
    pool: WorkerPool,
    planner: Arc<SharedFftPlanner>,
    cache: Arc<BasisCache>,
    metrics: Arc<Metrics>,
    model_id: u64,
}

impl BatchedEngine {
    /// A self-contained engine with its own cache and metrics.
    pub fn new(cfg: EngineConfig) -> Self {
        Self::with_shared(
            cfg.workers,
            Arc::new(BasisCache::new(cfg.cache_capacity.max(1))),
            Arc::new(Metrics::new()),
        )
    }

    /// An engine over an externally owned cache and metrics sink (the
    /// coordinator's server plugs its own in, so serving dashboards and
    /// tests observe engine cache hits directly).
    pub fn with_shared(workers: usize, cache: Arc<BasisCache>, metrics: Arc<Metrics>) -> Self {
        BatchedEngine {
            pool: WorkerPool::new(workers),
            planner: Arc::new(SharedFftPlanner::new()),
            cache,
            metrics,
            model_id: 0,
        }
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    pub fn cache(&self) -> &Arc<BasisCache> {
        &self.cache
    }

    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// Plans resident in the shared FFT plan cache.
    pub fn cached_plans(&self) -> usize {
        self.planner.cached_plans()
    }

    /// Evaluate every job; results come back in job order. Blocks until
    /// the whole batch is done. Safe to call concurrently from several
    /// threads (the server's workers share one engine).
    pub fn attend_batch(&self, jobs: Vec<AttnJob>) -> Vec<JobOutput> {
        Metrics::incr(&self.metrics.batched_calls);
        Metrics::add(&self.metrics.batched_jobs, jobs.len() as u64);
        let planner = Arc::clone(&self.planner);
        let cache = Arc::clone(&self.cache);
        let metrics = Arc::clone(&self.metrics);
        let model_id = self.model_id;
        self.pool
            .map(jobs, move |_, job| execute_job(job, &planner, &cache, &metrics, model_id))
    }
}

fn execute_job(
    job: AttnJob,
    planner: &Arc<SharedFftPlanner>,
    cache: &BasisCache,
    metrics: &Metrics,
    model_id: u64,
) -> JobOutput {
    let t0 = std::time::Instant::now();
    let mut out = execute_job_inner(job, planner, cache, metrics, model_id);
    out.exec = t0.elapsed();
    out
}

fn execute_job_inner(
    job: AttnJob,
    planner: &Arc<SharedFftPlanner>,
    cache: &BasisCache,
    metrics: &Metrics,
    model_id: u64,
) -> JobOutput {
    let AttnJob { layer, head, q, k, v, mask, backend } = job;
    let n = q.rows();
    let mask = mask.unwrap_or_else(|| Mask::causal(n));
    // Local planner view over the engine-wide plan cache.
    let mut local = FftPlanner::with_shared(Arc::clone(planner));
    match backend {
        BatchedBackend::Exact => {
            Metrics::incr(&metrics.exact_requests);
            JobOutput {
                y: exact_attention(&q, &k, &v, &mask),
                basis_k: 0,
                fell_back: false,
                cache_hit: false,
                exec: std::time::Duration::ZERO,
            }
        }
        BatchedBackend::LowRank(cfg) => {
            Metrics::incr(&metrics.lowrank_requests);
            let lr = LowRankAttention::new(&q, &k, mask, &cfg);
            JobOutput {
                y: lr.forward(&v),
                basis_k: 0,
                fell_back: false,
                cache_hit: false,
                exec: std::time::Duration::ZERO,
            }
        }
        BatchedBackend::Conv(cfg) => {
            Metrics::incr(&metrics.conv_requests);
            let key = CacheKey {
                model_id,
                layer,
                head,
                seq_len: n,
                qk_fingerprint: conv_fingerprint(&q, &k, &mask) ^ recover_cfg_tag(&cfg),
            };
            if let Some(hit) = cache.get(&key) {
                Metrics::incr(&metrics.cache_hits);
                let basis_k = hit.post_basis.k();
                let y = apply_cached_basis(&mut local, &hit.post_basis, &hit.d_tilde, &v);
                return JobOutput {
                    y,
                    basis_k,
                    fell_back: false,
                    cache_hit: true,
                    exec: std::time::Duration::ZERO,
                };
            }
            Metrics::incr(&metrics.cache_misses);
            match conv_attention_masked_with(&mut local, &q, &k, &v, &mask, &cfg) {
                Ok(out) => {
                    cache.put(
                        key,
                        CachedBasis {
                            post_basis: out.post_basis.clone(),
                            d_tilde: out.d_tilde.clone(),
                        },
                    );
                    JobOutput {
                        y: out.y,
                        basis_k: out.post_basis.k(),
                        fell_back: false,
                        cache_hit: false,
                        exec: std::time::Duration::ZERO,
                    }
                }
                Err(_) => {
                    Metrics::incr(&metrics.fallbacks);
                    JobOutput {
                        y: exact_attention(&q, &k, &v, &mask),
                        basis_k: 0,
                        fell_back: true,
                        cache_hit: false,
                        exec: std::time::Duration::ZERO,
                    }
                }
            }
        }
        BatchedBackend::Strided(k_bases) => {
            Metrics::incr(&metrics.conv_requests);
            if !matches!(mask.kind(), MaskKind::Causal) {
                // Strided recovery assumes the causal mask.
                Metrics::incr(&metrics.fallbacks);
                return JobOutput {
                    y: exact_attention(&q, &k, &v, &mask),
                    basis_k: 0,
                    fell_back: true,
                    cache_hit: false,
                    exec: std::time::Duration::ZERO,
                };
            }
            let key = CacheKey {
                model_id,
                layer,
                head,
                seq_len: n,
                qk_fingerprint: conv_fingerprint(&q, &k, &mask) ^ strided_tag(k_bases),
            };
            if let Some(hit) = cache.get(&key) {
                Metrics::incr(&metrics.cache_hits);
                let basis_k = hit.post_basis.k();
                let y = apply_cached_basis(&mut local, &hit.post_basis, &hit.d_tilde, &v);
                return JobOutput {
                    y,
                    basis_k,
                    fell_back: false,
                    cache_hit: true,
                    exec: std::time::Duration::ZERO,
                };
            }
            Metrics::incr(&metrics.cache_misses);
            match conv_attention_strided_with(&mut local, &q, &k, &v, k_bases) {
                Ok(out) => {
                    cache.put(
                        key,
                        CachedBasis {
                            post_basis: out.post_basis.clone(),
                            d_tilde: out.d_tilde.clone(),
                        },
                    );
                    JobOutput {
                        y: out.y,
                        basis_k: out.post_basis.k(),
                        fell_back: false,
                        cache_hit: false,
                        exec: std::time::Duration::ZERO,
                    }
                }
                Err(_) => {
                    Metrics::incr(&metrics.fallbacks);
                    JobOutput {
                        y: exact_attention(&q, &k, &v, &mask),
                        basis_k: 0,
                        fell_back: true,
                        cache_hit: false,
                        exec: std::time::Duration::ZERO,
                    }
                }
            }
        }
    }
}

/// FNV-1a step over one u64.
fn fnv_u64(mut h: u64, x: u64) -> u64 {
    for b in x.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

const FNV_SEED: u64 = 0xcbf29ce484222325;

/// Content fingerprint of a (Q, K, mask) triple. A cached basis is only
/// valid for identical content *and* an identical recovery schedule, so
/// callers xor in a backend tag as well.
fn conv_fingerprint(q: &Matrix, k: &Matrix, mask: &Mask) -> u64 {
    fingerprint(q.data()) ^ fingerprint(k.data()).rotate_left(1) ^ mask_tag(mask).rotate_left(2)
}

fn mask_tag(mask: &Mask) -> u64 {
    match mask.kind() {
        MaskKind::Causal => 0,
        MaskKind::SlidingWindow { w, sink } => {
            fnv_u64(fnv_u64(fnv_u64(FNV_SEED, 1), *w as u64), *sink as u64)
        }
        _ => {
            // Generic masks: hash the support (O(n²), only paid by the
            // rare non-structured masks).
            let mut h = fnv_u64(FNV_SEED, 2);
            for i in 0..mask.n() {
                for j in mask.row_support(i) {
                    h = fnv_u64(h, ((i as u64) << 32) | j as u64);
                }
            }
            h
        }
    }
}

fn recover_cfg_tag(cfg: &RecoverConfig) -> u64 {
    let mut h = fnv_u64(FNV_SEED, 3);
    h = fnv_u64(h, cfg.k_max as u64);
    h = fnv_u64(h, cfg.t as u64);
    h = fnv_u64(h, cfg.delta.to_bits());
    fnv_u64(h, cfg.eps.to_bits())
}

fn strided_tag(k_bases: usize) -> u64 {
    fnv_u64(fnv_u64(FNV_SEED, 4), k_bases as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::rope::rope_structured_qk;
    use crate::attention::{conv_attention_strided, exact_attention};
    use crate::tensor::{max_abs_diff, Rng};

    fn engine(workers: usize) -> BatchedEngine {
        BatchedEngine::new(EngineConfig { workers, cache_capacity: 64 })
    }

    fn structured_job(layer: u32, head: u32, n: usize, d: usize, seed: u64) -> AttnJob {
        let mut rng = Rng::seeded(seed);
        let (q, k) = rope_structured_qk(n, d, 3, &mut rng);
        let v = Matrix::randn(n, d, &mut rng);
        AttnJob::causal(layer, head, q, k, v, BatchedBackend::Strided(4))
    }

    #[test]
    fn exact_jobs_match_oracle_in_order() {
        let e = engine(3);
        let mut rng = Rng::seeded(601);
        let (n, d) = (24, 4);
        let mut jobs = Vec::new();
        let mut want = Vec::new();
        for h in 0..6u32 {
            let q = Matrix::randn(n, d, &mut rng).scale(0.3);
            let k = Matrix::randn(n, d, &mut rng).scale(0.3);
            let v = Matrix::randn(n, d, &mut rng);
            want.push(exact_attention(&q, &k, &v, &Mask::causal(n)));
            jobs.push(AttnJob::causal(0, h, q, k, v, BatchedBackend::Exact));
        }
        let outs = e.attend_batch(jobs);
        assert_eq!(outs.len(), 6);
        for (out, w) in outs.iter().zip(&want) {
            assert_eq!(max_abs_diff(&out.y, w), 0.0);
            assert_eq!(out.basis_k, 0);
            assert!(!out.fell_back);
        }
    }

    #[test]
    fn strided_jobs_match_single_path() {
        let e = engine(2);
        let jobs: Vec<AttnJob> =
            (0..4).map(|h| structured_job(1, h, 48, 8, 700 + h as u64)).collect();
        let singles: Vec<Matrix> = jobs
            .iter()
            .map(|j| conv_attention_strided(&j.q, &j.k, &j.v, 4).unwrap().y)
            .collect();
        let outs = e.attend_batch(jobs);
        for (out, w) in outs.iter().zip(&singles) {
            assert!(!out.fell_back);
            assert!(out.basis_k >= 1);
            assert_eq!(max_abs_diff(&out.y, w), 0.0, "batched must be bit-identical");
        }
    }

    #[test]
    fn second_call_hits_basis_cache() {
        let e = engine(2);
        let jobs: Vec<AttnJob> =
            (0..3).map(|h| structured_job(2, h, 32, 4, 800 + h as u64)).collect();
        let first = e.attend_batch(jobs.clone());
        let second = e.attend_batch(jobs);
        let snap = e.metrics().snapshot();
        assert!(snap.cache_hits >= 3, "hits = {}", snap.cache_hits);
        for (a, b) in first.iter().zip(&second) {
            assert!(b.cache_hit, "second call must be served from the cache");
            assert_eq!(max_abs_diff(&a.y, &b.y), 0.0);
        }
    }

    #[test]
    fn different_backend_tags_do_not_collide_in_cache() {
        // Same (layer, head, seq_len, Q, K) under different strided k
        // must not reuse each other's basis.
        let e = engine(1);
        let j4 = structured_job(0, 0, 40, 8, 900);
        let mut j2 = j4.clone();
        j2.backend = BatchedBackend::Strided(2);
        let out4 = e.attend_batch(vec![j4]);
        let out2 = e.attend_batch(vec![j2]);
        assert!(!out2[0].cache_hit, "k=2 must not hit the k=4 entry");
        assert!(out4[0].basis_k >= out2[0].basis_k);
    }

    #[test]
    fn fallback_on_degenerate_conv_is_finite() {
        let e = engine(2);
        let mut rng = Rng::seeded(901);
        let (n, d) = (12, 4);
        let q = Matrix::randn(n, d, &mut rng).scale(5.0);
        let k = Matrix::randn(n, d, &mut rng).scale(5.0);
        let v = Matrix::randn(n, d, &mut rng);
        let jobs = vec![AttnJob::causal(0, 0, q, k, v, BatchedBackend::Strided(2))];
        let outs = e.attend_batch(jobs);
        assert!(outs[0].y.is_finite());
    }

    #[test]
    fn shared_plan_cache_fills_once() {
        let e = engine(4);
        let jobs: Vec<AttnJob> =
            (0..8).map(|h| structured_job(0, h, 64, 8, 1000 + h as u64)).collect();
        let _ = e.attend_batch(jobs);
        // All jobs have the same n ⇒ a handful of distinct transform
        // lengths, not 8× duplicates.
        assert!(e.cached_plans() >= 1);
        assert!(e.cached_plans() <= 8, "plans = {}", e.cached_plans());
    }
}
