//! Batched multi-head conv-attention engine — **one typed door** for
//! prefill, decode, gradient and LM-backward work.
//!
//! The paper's `O(k·n·d·log n)` bound only pays off in serving when its
//! fixed costs are amortized: FFT plan tables, recovered conv bases, and
//! thread startup. The seed code evaluated one head of one sequence at a
//! time, re-planning and re-recovering per call. This engine executes
//! **a whole batch of typed jobs in one call** —
//! [`BatchedEngine::submit`] takes `Vec<EngineJob>` where each job is a
//! caller key plus an [`EngineOp`]:
//!
//! * [`EngineOp::Prefill`] — one (sequence, head) whole-prefix
//!   attention job ([`AttnJob`]); its **training-forward** flavor
//!   ([`AttnJob::for_training`]) additionally returns the backward's
//!   artifact — softmax rows (exact) or the recovered basis as a
//!   step-scoped handle (conv) — and keeps training out of the serving
//!   `BasisCache` entirely;
//! * [`EngineOp::Decode`] — one (sequence, layer, head) autoregressive
//!   decode step ([`DecodeJob`]);
//! * [`EngineOp::Gradient`] — one (layer, head) Definition 5.1 backward
//!   pass ([`GradJob`](crate::gradient::batched::GradJob));
//! * [`EngineOp::AttnBackward`] — one (sequence, layer, head) LM
//!   attention backward producing `(dQ, dK, dV)`
//!   ([`AttnBackwardJob`](crate::gradient::batched::AttnBackwardJob)),
//!   the lane `Transformer::backward_batch_with_engine` fans the full
//!   transformer backward through.
//!
//! Lanes mix freely in one batch (the server's generation scheduler
//! merges non-generation attention arrivals into in-flight decode
//! submits; `model::train` steps every head's gradient in one call).
//! All four share:
//!
//! * one [`SharedFftPlanner`] plan cache for the whole engine — a plan
//!   per transform length is built once (off-lock) and shared by every
//!   worker; each job gets a cheap local view whose repeat lookups are
//!   lock-free ([`FftPlanner::with_shared`]);
//! * a per-(model, layer, head, seq_len) recovered-basis cache
//!   ([`BasisCache`], keyed by [`CacheKey`] with a (Q, K, backend)
//!   content fingerprint, **lock-striped by (layer, head)** so hot
//!   heads don't serialize on one mutex) — *recover once, apply per V*,
//!   shared across heads, sequences, callers, and now across the
//!   forward/backward boundary: a causal gradient job's operator is
//!   keyed identically to the matching `Conv` prefill job;
//! * a fixed [`WorkerPool`] of `std::thread` workers fanning jobs out
//!   with **deterministic result ordering**: jobs are pure and results
//!   are re-ordered by input index, so thread counts 1/2/8 produce
//!   bit-identical outputs (pinned by `tests/properties.rs` for every
//!   lane, mixed batches included).
//!
//! Cache-hit/miss counts surface through [`Metrics`]
//! (`cache_hits`/`cache_misses`, plus per-lane call/job counters).
//! The coordinator's server routes whole batches through one engine
//! ([`BatchedEngine::with_shared`] over the server's cache and metrics),
//! and the model layer batches all heads of a forward pass through
//! `Transformer::forward_batch` — and all heads of a backward pass
//! through `Transformer::backward_batch_with_engine`.
//!
//! # Decode path (autoregressive serving)
//!
//! The decode lifecycle:
//!
//! 1. **Prefill** recovers bases through [`EngineOp::Prefill`] jobs
//!    (strided conv jobs cache their post-exp basis in the
//!    [`BasisCache`]);
//! 2. [`BatchedEngine::seed_decode`] turns a cached basis into a
//!    [`DecodeState`] — a cache *hit* means decode starts without any
//!    recovery work (`decode_seed_hits`);
//! 3. each [`DecodeOp::Conv`] step appends one token in
//!    `O(k·n + n·d)` — no FFT, no `n×n` matrix — and reports a drift
//!    score; past `drift_tol` the engine re-recovers from the full
//!    per-head Q/K and re-caches (`decode_rerecoveries`);
//! 4. [`DecodeOp::Exact`] steps run the bit-stable exact last-row
//!    kernel (`O(n·d)`, the KV-cache cost), bit-matching a fresh full
//!    prefill — `tests/decode.rs` pins that property end-to-end
//!    through `Transformer::decode_step`.
//!
//! # Determinism & cache-key invariants
//!
//! * Jobs — prefill, decode, gradient and LM-backward — are **pure**:
//!   outputs depend only on job inputs, never on worker identity,
//!   timing, or what other ops share the batch. Results are re-ordered
//!   by input index, so any worker count is bit-identical
//!   (`tests/properties.rs` pins 1/2/8 for all lanes).
//! * A [`CacheKey`] commits to (model, layer, head, seq_len) *and* a
//!   bitwise content fingerprint of (Q, K, mask) *and* a backend tag
//!   (recovery schedule) — two jobs share a basis **iff** they would
//!   recover the identical basis. `seed_decode` reuses the exact key a
//!   strided prefill job wrote (decode seeding is free right after
//!   prefill), and a causal gradient job reuses the key of the
//!   equivalent `Conv` prefill job (backward starts recovery-free after
//!   a forward).
//!
//! # Routing (the fifth mode)
//!
//! [`BatchedBackend::Routed`] wraps a [`RouterPolicy`] — a frozen,
//! deterministic per-(layer, head) table choosing exact / conv(k) /
//! low-rank. Resolution happens *inside* job execution (a pure function
//! of the table and the job's shape, never of wall clock or worker
//! identity), then recurses into the identical operator arms, so a
//! routed job is bit-identical to submitting its resolved backend
//! directly and shares `BasisCache` entries with direct conv jobs.
//! Policies come from an explicit static table or from measured
//! [`HeadProfile`]s via [`RouterPolicy::from_profile`] with pinned
//! [`ProfilePolicyConfig`] thresholds; only order-independent profile
//! aggregates feed decisions. Low-rank routes cannot seed a
//! [`DecodeState`], so decode-bound sessions pin to exact/conv
//! (`router_decode_pins`); low-rank is also refused per job when the
//! feature rank reaches the sequence length (`router_rank_refusals`).
//! `tests/router.rs` pins the equivalence oracle and decision
//! determinism across runs and worker counts.
//!
//! # Worked example
//!
//! ```
//! use conv_basis::attention::batched::{
//!     AttnJob, BatchedBackend, BatchedEngine, DecodeJob, DecodeOp, EngineConfig, EngineJob,
//! };
//! use conv_basis::attention::rope::rope_structured_qk;
//! use conv_basis::tensor::{dot, Matrix, Rng};
//!
//! let engine = BatchedEngine::new(EngineConfig { workers: 2, cache_capacity: 16 });
//! let mut rng = Rng::seeded(3);
//! let (n, d) = (24, 4);
//! let (q_full, k_full) = rope_structured_qk(n + 1, d, 2, &mut rng);
//! let (q, k) = (q_full.slice(0, n, 0, d), k_full.slice(0, n, 0, d));
//! let v = Matrix::randn(n, d, &mut rng);
//!
//! // Prefill: recover + cache the basis for (layer 0, head 0).
//! let out = engine.submit(vec![EngineJob::prefill(
//!     0,
//!     AttnJob::causal(0, 0, q.clone(), k.clone(), v.clone(), BatchedBackend::Strided(2)),
//! )]);
//! assert!(!out[0].result.clone().into_prefill().fell_back);
//!
//! // Decode: seed from the cache (free), append one token.
//! let (state, hit) = engine.seed_decode(0, 0, &q, &k, 2);
//! assert!(hit, "prefill already recovered this basis");
//! let new_row: Vec<f64> =
//!     (0..=n).map(|j| dot(q_full.row(n), k_full.row(j))).collect();
//! let mut v_grown = v.clone();
//! v_grown.push_row(&vec![0.5; d]);
//! let outs = engine.submit(vec![EngineJob::decode(
//!     1,
//!     DecodeJob {
//!         layer: 0,
//!         head: 0,
//!         state: Some(state),
//!         new_row,
//!         v: v_grown,
//!         q: Some(q_full.clone()),
//!         k: Some(k_full.clone()),
//!         op: DecodeOp::conv(2),
//!     },
//! )]);
//! let step = outs[0].result.clone().into_decode();
//! assert_eq!(step.y_last.len(), d);
//! assert!(!step.rerecovered, "structured growth stays drift-free");
//! ```

use super::blocked::{
    blocked_attention_causal, blocked_decode_last_row, blocked_train_forward, ExactKernel,
};
use super::decode::{exact_decode_last_row, DecodeState};
use super::lowrank_backend::{lowrank_prefill, lowrank_viable};
use super::{
    apply_cached_basis, conv_attention_masked_with, conv_attention_strided_with, exact_attention,
    Mask, MaskKind,
};
use crate::basis::{exp_transform, recover_strided, QkColumnOracle, RecoverConfig};
use crate::coordinator::{
    fingerprint, BasisCache, CacheKey, CachedBasis, HeadProfile, Metrics, RouteKind, StepBasis,
};
use crate::fft::{FftPlanner, SharedFftPlanner};
use crate::gradient::batched::{
    execute_attn_backward_job, execute_grad_job, AttnBackwardJob, AttnBackwardOutput, GradJob,
    GradOutput,
};
use crate::lowrank::LowRankConfig;
use crate::runtime::pool::WorkerPool;
use crate::tensor::Matrix;
use std::collections::BTreeMap;
use std::sync::Arc;

/// Per-job attention operator (the engine-side mirror of the model
/// layer's `AttentionBackend`; jobs in one batch may mix operators).
#[derive(Clone, Debug)]
pub enum BatchedBackend {
    /// Exact `O(n²d)` attention, served by the selected
    /// [`ExactKernel`] family (row-streamed oracle or blocked
    /// streaming-softmax; blocked is causal-only and falls back to
    /// row-stream under non-causal masks).
    Exact(ExactKernel),
    /// Algorithm 1 with adaptive binary-search recovery; falls back to
    /// exact on recovery failure.
    Conv(RecoverConfig),
    /// Algorithm 1 with strided recovery at k uniform onsets (causal
    /// mask only; non-causal jobs fall back to exact).
    Strided(usize),
    /// Theorem 6.5 masked low-rank attention.
    LowRank(LowRankConfig),
    /// The fifth mode — **not a fifth operator**: a deterministic
    /// per-(layer, head) [`RouterPolicy`] that resolves to one of the
    /// four operators above *inside job execution* (so pool fan-out
    /// stays bit-identical for any worker count) and then runs the
    /// identical code path — same kernels, same float-op order, same
    /// cache keys. A routed job's output is therefore bit-identical to
    /// submitting its resolved backend directly, and routed conv jobs
    /// share `BasisCache` entries with direct conv jobs.
    /// Serving-only: training-forward jobs reject `Routed` like every
    /// other non-Exact/Conv backend.
    Routed(Arc<RouterPolicy>),
}

/// One (layer, head) entry of a [`RouterPolicy`] table: which operator
/// family serves that head, with its configuration.
#[derive(Clone, Debug, PartialEq)]
pub enum HeadRoute {
    /// Exact `O(n²d)` attention.
    Exact,
    /// Adaptive binary-search conv recovery.
    Conv(RecoverConfig),
    /// Strided conv recovery at `k` uniform onsets.
    Strided(usize),
    /// Theorem 6.5 low-rank attention — guarded at job time: refused
    /// (rerouted to the policy's conv fallback) when the feature rank
    /// `C(d+g, g)` is not strictly below the sequence length, and
    /// pinned to exact for decode seeding (low-rank cannot seed a
    /// `DecodeState` — see [`super::lowrank_backend`]).
    LowRank(LowRankConfig),
}

impl HeadRoute {
    /// The operator family this route resolves to (decision-counter /
    /// profile bucket).
    pub fn kind(&self) -> RouteKind {
        match self {
            HeadRoute::Exact => RouteKind::Exact,
            HeadRoute::Conv(_) | HeadRoute::Strided(_) => RouteKind::Conv,
            HeadRoute::LowRank(_) => RouteKind::LowRank,
        }
    }
}

/// Pinned thresholds for building a [`RouterPolicy`] from measured
/// [`HeadProfile`]s. Every field is data the caller fixes up front —
/// nothing here (and nothing in the build) reads a clock, so two
/// identical profiles always produce identical tables.
#[derive(Clone, Debug, PartialEq)]
pub struct ProfilePolicyConfig {
    /// Heads whose conv fallback rate exceeds this go `Exact`
    /// (recovery is unreliable for their structure; paying recovery +
    /// fallback is strictly worse than exact).
    pub max_fallback_rate: f64,
    /// Heads at or below this mean recovery error keep the conv route
    /// (the structure is there and conv wins).
    pub max_recovery_err: f64,
    /// The conv route assigned to conv-friendly heads.
    pub conv: HeadRoute,
    /// The low-rank configuration assigned to heads whose recovery
    /// error is too high for conv but that still want subquadratic
    /// serving (bounded-entry regime). Guarded at job time by the
    /// rank-vs-n check.
    pub lowrank: LowRankConfig,
}

impl Default for ProfilePolicyConfig {
    fn default() -> Self {
        ProfilePolicyConfig {
            max_fallback_rate: 0.5,
            max_recovery_err: 1e-3,
            conv: HeadRoute::Strided(8),
            lowrank: LowRankConfig::new(2, 1.0),
        }
    }
}

/// Deterministic per-(layer, head) routing policy — the data behind
/// [`BatchedBackend::Routed`].
///
/// A policy is a **frozen decision table**: an explicit
/// `(layer, head) → HeadRoute` map (a `BTreeMap`, per the hash-iter
/// determinism lint) plus a default route for unlisted heads. It is
/// built either directly ([`RouterPolicy::new`] / [`RouterPolicy::set`])
/// or from measured per-head profiles
/// ([`RouterPolicy::from_profile`] with [`ProfilePolicyConfig`]
/// thresholds). Either way the table is pinned before any job runs:
/// resolution at execution time is a pure function of
/// `(table, layer, head, n, d)` — never of wall clock (the PR-8 lint
/// forbids `Instant` in kernel paths), worker identity, or batch
/// composition — so routing decisions are bit-reproducible across
/// runs, worker counts, and lane mixes (`tests/router.rs`).
///
/// The one job-time adjustment is the **rank guard**: a `LowRank`
/// route whose feature rank `C(d+g, g)` is not strictly below the
/// job's sequence length is a strict loss, so it reroutes to
/// [`RouterPolicy::lowrank_fallback`] (and counts
/// `router_rank_refusals`). The guard depends only on job shape, so it
/// is exactly as deterministic as the table itself.
#[derive(Clone, Debug, PartialEq)]
pub struct RouterPolicy {
    table: BTreeMap<(u32, u32), HeadRoute>,
    default: HeadRoute,
    /// Where refused low-rank routes go (rank ≥ n). Never `LowRank`
    /// itself (constructor-enforced), so resolution terminates.
    lowrank_fallback: HeadRoute,
}

impl RouterPolicy {
    /// A policy routing every head the same way.
    pub fn new(default: HeadRoute) -> Self {
        RouterPolicy {
            table: BTreeMap::new(),
            default,
            lowrank_fallback: HeadRoute::Strided(8),
        }
    }

    /// Builder: pin one (layer, head) to a route.
    pub fn set(mut self, layer: u32, head: u32, route: HeadRoute) -> Self {
        self.table.insert((layer, head), route);
        self
    }

    /// Builder: the route refused low-rank jobs take (must not itself
    /// be `LowRank`).
    pub fn with_lowrank_fallback(mut self, route: HeadRoute) -> Self {
        assert!(
            !matches!(route, HeadRoute::LowRank(_)),
            "the low-rank fallback must resolve to a non-low-rank operator"
        );
        self.lowrank_fallback = route;
        self
    }

    /// Build a policy from measured per-head profiles with pinned
    /// thresholds. The decision table (documented in ARCHITECTURE.md
    /// §router):
    ///
    /// 1. `fallback_rate > max_fallback_rate` → `Exact` — conv
    ///    recovery keeps failing for this head, so the conv attempt is
    ///    pure overhead;
    /// 2. else `mean_recovery_err ≤ max_recovery_err` → the `conv`
    ///    route — the head's structure rewards a conv basis;
    /// 3. else → `LowRank` — structure too noisy for conv, entries
    ///    bounded enough for polynomial features (guarded at job time
    ///    by rank < n).
    ///
    /// Only the **order-independent** profile aggregates feed the
    /// decisions (integer fallback counters, integer-quantized error
    /// mean) — never the EMA (order-sensitive) or the latency buckets
    /// (wall-clock) — so any worker count collecting the profile
    /// yields the same table, and two identical runs route
    /// identically. Unprofiled heads take the `conv` route (the
    /// optimistic default: recovery has its own exact fallback).
    pub fn from_profile(
        profiles: &BTreeMap<(u32, u32), HeadProfile>,
        cfg: &ProfilePolicyConfig,
    ) -> Self {
        let mut policy = RouterPolicy::new(cfg.conv.clone());
        for (&(layer, head), p) in profiles {
            let route = if p.fallback_rate() > cfg.max_fallback_rate {
                HeadRoute::Exact
            } else if p.mean_recovery_err() <= cfg.max_recovery_err {
                cfg.conv.clone()
            } else {
                HeadRoute::LowRank(cfg.lowrank)
            };
            policy.table.insert((layer, head), route);
        }
        policy
    }

    /// The table route for one head (before job-time guards).
    pub fn route(&self, layer: u32, head: u32) -> &HeadRoute {
        self.table.get(&(layer, head)).unwrap_or(&self.default)
    }

    /// Resolve one job's route: table lookup plus the rank guard.
    /// Returns the final route and whether a low-rank route was
    /// refused (rank ≥ n). Pure in `(self, layer, head, n, d)`.
    pub fn resolve(&self, layer: u32, head: u32, n: usize, d: usize) -> (&HeadRoute, bool) {
        match self.route(layer, head) {
            HeadRoute::LowRank(cfg) if !lowrank_viable(cfg, n, d) => (&self.lowrank_fallback, true),
            route => (route, false),
        }
    }

    /// Table rows in deterministic (layer, head) order (bench /
    /// report printing — a silent all-exact table can't hide).
    pub fn decisions(&self) -> impl Iterator<Item = ((u32, u32), &HeadRoute)> {
        self.table.iter().map(|(&lh, r)| (lh, r))
    }

    /// The default route for heads not in the table.
    pub fn default_route(&self) -> &HeadRoute {
        &self.default
    }

    /// How many (layer, head) slots of a `layers × heads` grid this
    /// policy routes to low-rank — the count `prefill_batch` pins to
    /// exact for decode-bound sessions (`router_decode_pins`).
    pub fn lowrank_route_count(&self, layers: u32, heads: u32) -> u64 {
        let mut count = 0u64;
        for layer in 0..layers {
            for head in 0..heads {
                if matches!(self.route(layer, head), HeadRoute::LowRank(_)) {
                    count += 1;
                }
            }
        }
        count
    }
}

/// One (sequence, head) unit of attention work.
#[derive(Clone, Debug)]
pub struct AttnJob {
    /// Layer index (cache key component).
    pub layer: u32,
    /// Head index within the layer (cache key component).
    pub head: u32,
    pub q: Matrix,
    pub k: Matrix,
    pub v: Matrix,
    /// `None` means causal.
    pub mask: Option<Mask>,
    pub backend: BatchedBackend,
    /// **Training-forward** job (`false` for serving jobs, the
    /// default): the job keeps what the backward needs — the exact
    /// kernel's softmax rows, or the conv kernel's recovered basis as
    /// a step-scoped handle — in the [`JobOutput`], and conv recovery
    /// **never touches the serving [`BasisCache`]** (training bases are
    /// dead after one optimizer step; a shard write could only evict
    /// live serving entries). Supported backends: `Exact` and `Conv`,
    /// causal mask only.
    pub training: bool,
}

impl AttnJob {
    /// A causal-mask job.
    pub fn causal(
        layer: u32,
        head: u32,
        q: Matrix,
        k: Matrix,
        v: Matrix,
        backend: BatchedBackend,
    ) -> Self {
        AttnJob { layer, head, q, k, v, mask: None, backend, training: false }
    }

    /// Mark this job as a training-forward job (see
    /// [`AttnJob::training`]). `Transformer::forward_train_batch` is
    /// the canonical submitter.
    pub fn for_training(mut self) -> Self {
        self.training = true;
        self
    }

    /// A speculative-**verify** job: causal, always the exact operator
    /// regardless of the serving backend. The speculative decoder
    /// drafts tokens through the cheap conv decode lane and verifies
    /// all drafted positions in one prefill-lane submit of these jobs
    /// (`Transformer::forward_batch` with the exact backend builds
    /// them); row `i` of an exact causal prefill is bit-identical to
    /// the last row of the length-`i+1` prefix's prefill (rows are
    /// independent under the causal mask), so one verify job yields
    /// the greedy-oracle logits for every drafted position at once.
    /// Pinned to the row-stream kernel: verify is the oracle side of
    /// speculation, and the row-per-prefix bit-identity above is the
    /// row-stream family's contract.
    pub fn verify(layer: u32, head: u32, q: Matrix, k: Matrix, v: Matrix) -> Self {
        AttnJob::causal(layer, head, q, k, v, BatchedBackend::Exact(ExactKernel::RowStream))
    }
}

/// Result of one job, with the provenance the serving layer reports.
#[derive(Clone, Debug)]
pub struct JobOutput {
    /// `Ỹ ≈ D⁻¹AV` for this (sequence, head).
    pub y: Matrix,
    /// Basis size used (0 for exact / low-rank).
    pub basis_k: usize,
    /// Whether a conv path fell back to exact attention.
    pub fell_back: bool,
    /// Whether the basis came from the cache (conv paths only).
    pub cache_hit: bool,
    /// Training-forward artifact: the recovered conv basis as a
    /// step-scoped handle (conv training jobs whose recovery
    /// succeeded). The backward consumes it via
    /// `AttnBackwardJob::basis` — one recovery per (record, layer,
    /// head) per step, shared forward→backward. `None` for serving
    /// jobs.
    pub basis: Option<StepBasis>,
    /// Training-forward artifact: the softmax rows (exact training
    /// jobs, and conv training jobs that fell back) — what the exact
    /// backward mode and the fast backward's dense fallback consume.
    /// `None` for serving jobs.
    pub probs: Option<Arc<Matrix>>,
    /// Wall time this job spent executing on its worker (per-job, so
    /// latency percentiles stay meaningful under batching).
    pub exec: std::time::Duration,
}

/// A serving-path [`JobOutput`] (no training artifacts, exec stamped
/// by the caller).
fn serving_output(y: Matrix, basis_k: usize, fell_back: bool, cache_hit: bool) -> JobOutput {
    JobOutput {
        y,
        basis_k,
        fell_back,
        cache_hit,
        basis: None,
        probs: None,
        exec: std::time::Duration::ZERO,
    }
}

/// One typed unit of engine work: a caller-chosen correlation key plus
/// the operation. [`BatchedEngine::submit`] echoes the key back in the
/// matching [`EngineOutput`] (results are input-ordered regardless, so
/// the key is for the caller's bookkeeping, not for matching).
#[derive(Clone, Debug)]
pub struct EngineJob {
    /// Caller-assigned key, echoed in [`EngineOutput::key`].
    pub key: u64,
    pub op: EngineOp,
}

impl EngineJob {
    /// A prefill-lane job.
    pub fn prefill(key: u64, job: AttnJob) -> Self {
        EngineJob { key, op: EngineOp::Prefill(job) }
    }

    /// A decode-lane job.
    pub fn decode(key: u64, job: DecodeJob) -> Self {
        EngineJob { key, op: EngineOp::Decode(job) }
    }

    /// A gradient-lane job.
    pub fn gradient(key: u64, job: GradJob) -> Self {
        EngineJob { key, op: EngineOp::Gradient(job) }
    }

    /// An LM-backward-lane job.
    pub fn attn_backward(key: u64, job: AttnBackwardJob) -> Self {
        EngineJob { key, op: EngineOp::AttnBackward(job) }
    }
}

/// The four operation lanes the engine executes through one door.
/// Lanes mix freely within a batch; every job is pure, so a mixed
/// batch's outputs are bit-identical to running each lane alone.
///
/// ```
/// use conv_basis::attention::batched::{
///     AttnJob, BatchedBackend, BatchedEngine, EngineConfig, EngineJob,
/// };
/// use conv_basis::attention::ExactKernel;
/// use conv_basis::gradient::batched::{FastGradConfig, GradJob};
/// use conv_basis::gradient::AttentionLossProblem;
/// use conv_basis::tensor::{Matrix, Rng};
/// use std::sync::Arc;
///
/// let engine = BatchedEngine::new(EngineConfig { workers: 2, cache_capacity: 16 });
/// let mut rng = Rng::seeded(5);
/// let (n, d) = (16, 3);
/// // One mixed batch: an exact prefill job and a gradient job.
/// let q = Matrix::randn(n, d, &mut rng).scale(0.3);
/// let k = Matrix::randn(n, d, &mut rng).scale(0.3);
/// let v = Matrix::randn(n, d, &mut rng);
/// let problem = Arc::new(AttentionLossProblem::random_structured(n, d, &mut rng));
/// let exact = BatchedBackend::Exact(ExactKernel::RowStream);
/// let outs = engine.submit(vec![
///     EngineJob::prefill(10, AttnJob::causal(0, 0, q, k, v, exact)),
///     EngineJob::gradient(
///         11,
///         GradJob {
///             layer: 0,
///             head: 0,
///             problem,
///             x: Matrix::zeros(d, d),
///             cfg: FastGradConfig::exact(n),
///         },
///     ),
/// ]);
/// // Input-ordered, key-echoed, typed results.
/// assert_eq!([outs[0].key, outs[1].key], [10, 11]);
/// assert_eq!(outs[0].result.clone().into_prefill().y.shape(), (n, d));
/// assert_eq!(outs[1].result.clone().into_gradient().grad.shape(), (d, d));
/// ```
#[derive(Clone, Debug)]
pub enum EngineOp {
    /// Whole-prefix attention for one (sequence, head).
    Prefill(AttnJob),
    /// One autoregressive decode step for one (sequence, layer, head).
    Decode(DecodeJob),
    /// One Definition 5.1 backward pass for one (layer, head).
    Gradient(GradJob),
    /// One per-head LM attention backward for one (sequence, layer,
    /// head), producing `(dQ, dK, dV)`.
    AttnBackward(AttnBackwardJob),
}

impl EngineOp {
    /// The lane's name (diagnostics / mismatch panics).
    pub fn lane(&self) -> &'static str {
        match self {
            EngineOp::Prefill(_) => "prefill",
            EngineOp::Decode(_) => "decode",
            EngineOp::Gradient(_) => "gradient",
            EngineOp::AttnBackward(_) => "lm-backward",
        }
    }
}

/// One result from [`BatchedEngine::submit`], in input order.
#[derive(Clone, Debug)]
pub struct EngineOutput {
    /// The submitting job's key, echoed.
    pub key: u64,
    pub result: EngineResult,
}

/// Typed result, mirroring [`EngineOp`].
#[derive(Clone, Debug)]
pub enum EngineResult {
    Prefill(JobOutput),
    Decode(DecodeOutput),
    Gradient(GradOutput),
    AttnBackward(AttnBackwardOutput),
}

impl EngineResult {
    /// The lane's name (diagnostics / mismatch panics).
    pub fn lane(&self) -> &'static str {
        match self {
            EngineResult::Prefill(_) => "prefill",
            EngineResult::Decode(_) => "decode",
            EngineResult::Gradient(_) => "gradient",
            EngineResult::AttnBackward(_) => "lm-backward",
        }
    }

    /// Unwrap a prefill result; panics if this job ran another lane.
    pub fn into_prefill(self) -> JobOutput {
        match self {
            EngineResult::Prefill(o) => o,
            other => panic!("expected a prefill result, got {}", other.lane()),
        }
    }

    /// Unwrap a decode result; panics if this job ran another lane.
    pub fn into_decode(self) -> DecodeOutput {
        match self {
            EngineResult::Decode(o) => o,
            other => panic!("expected a decode result, got {}", other.lane()),
        }
    }

    /// Unwrap a gradient result; panics if this job ran another lane.
    pub fn into_gradient(self) -> GradOutput {
        match self {
            EngineResult::Gradient(o) => o,
            other => panic!("expected a gradient result, got {}", other.lane()),
        }
    }

    /// Unwrap an LM-backward result; panics if this job ran another
    /// lane.
    pub fn into_attn_backward(self) -> AttnBackwardOutput {
        match self {
            EngineResult::AttnBackward(o) => o,
            other => panic!("expected an lm-backward result, got {}", other.lane()),
        }
    }
}

/// Engine sizing.
#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// Worker threads in the pool (clamped to ≥ 1).
    pub workers: usize,
    /// Recovered-basis cache capacity — entries **per shard** of the
    /// lock-striped [`BasisCache`] (entries of one (layer, head) always
    /// share a shard, so this bounds each slot's working set).
    pub cache_capacity: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            workers: std::thread::available_parallelism().map(|p| p.get()).unwrap_or(2),
            cache_capacity: 256,
        }
    }
}

/// The batched multi-head conv-attention engine. Cheap to share
/// (`Arc`): all methods take `&self` and internal state is synchronized.
pub struct BatchedEngine {
    pool: WorkerPool,
    planner: Arc<SharedFftPlanner>,
    cache: Arc<BasisCache>,
    metrics: Arc<Metrics>,
    model_id: u64,
}

impl BatchedEngine {
    /// A self-contained engine with its own cache and metrics.
    pub fn new(cfg: EngineConfig) -> Self {
        Self::with_shared(
            cfg.workers,
            Arc::new(BasisCache::new(cfg.cache_capacity.max(1))),
            Arc::new(Metrics::new()),
        )
    }

    /// An engine over an externally owned cache and metrics sink (the
    /// coordinator's server plugs its own in, so serving dashboards and
    /// tests observe engine cache hits directly).
    pub fn with_shared(workers: usize, cache: Arc<BasisCache>, metrics: Arc<Metrics>) -> Self {
        BatchedEngine {
            pool: WorkerPool::new(workers),
            planner: Arc::new(SharedFftPlanner::new()),
            cache,
            metrics,
            model_id: 0,
        }
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    pub fn cache(&self) -> &Arc<BasisCache> {
        &self.cache
    }

    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// Plans resident in the shared FFT plan cache.
    pub fn cached_plans(&self) -> usize {
        self.planner.cached_plans()
    }

    /// Execute every job — prefill, decode and gradient ops mixed
    /// freely — over the worker pool; results come back **in job
    /// order** with each job's key echoed. Blocks until the whole batch
    /// is done. Safe to call concurrently from several threads (the
    /// server's workers and its generation scheduler share one engine).
    ///
    /// Jobs are pure, so the outputs are bit-identical for any worker
    /// count and any batch composition: a decode step returns the same
    /// bits whether it ran alone or with prefill/gradient jobs riding
    /// along (`tests/properties.rs` pins this for 1/2/8 workers).
    ///
    /// Per-lane counters land in [`Metrics`]: a call increments
    /// `submit_calls` once, plus `batched_calls`/`decode_calls`/
    /// `grad_calls`/`lm_backward_calls` for each lane that is
    /// non-empty, plus the per-job `batched_jobs`/`decode_steps`/
    /// `grad_jobs`/`lm_backward_jobs` totals.
    pub fn submit(&self, jobs: Vec<EngineJob>) -> Vec<EngineOutput> {
        Metrics::incr(&self.metrics.submit_calls);
        let (mut n_prefill, mut n_decode, mut n_grad, mut n_bwd) = (0u64, 0u64, 0u64, 0u64);
        let mut n_train_conv = 0u64;
        for job in &jobs {
            match &job.op {
                EngineOp::Prefill(j) => {
                    n_prefill += 1;
                    if j.training && matches!(j.backend, BatchedBackend::Conv(_)) {
                        n_train_conv += 1;
                    }
                }
                EngineOp::Decode(_) => n_decode += 1,
                EngineOp::Gradient(_) => n_grad += 1,
                EngineOp::AttnBackward(_) => n_bwd += 1,
            }
        }
        if n_prefill > 0 {
            Metrics::incr(&self.metrics.batched_calls);
            Metrics::add(&self.metrics.batched_jobs, n_prefill);
        }
        if n_train_conv > 0 {
            Metrics::incr(&self.metrics.train_fwd_conv_calls);
            Metrics::add(&self.metrics.train_fwd_conv_jobs, n_train_conv);
        }
        if n_decode > 0 {
            Metrics::incr(&self.metrics.decode_calls);
            Metrics::add(&self.metrics.decode_steps, n_decode);
        }
        if n_grad > 0 {
            Metrics::incr(&self.metrics.grad_calls);
            Metrics::add(&self.metrics.grad_jobs, n_grad);
        }
        if n_bwd > 0 {
            Metrics::incr(&self.metrics.lm_backward_calls);
            Metrics::add(&self.metrics.lm_backward_jobs, n_bwd);
        }
        let planner = Arc::clone(&self.planner);
        let cache = Arc::clone(&self.cache);
        let metrics = Arc::clone(&self.metrics);
        let model_id = self.model_id;
        self.pool.map(jobs, move |_, job| {
            let EngineJob { key, op } = job;
            let result = match op {
                EngineOp::Prefill(j) => {
                    EngineResult::Prefill(execute_job(j, &planner, &cache, &metrics, model_id))
                }
                EngineOp::Decode(j) => {
                    EngineResult::Decode(execute_decode_job(j, &cache, &metrics, model_id))
                }
                EngineOp::Gradient(j) => {
                    EngineResult::Gradient(execute_grad_job(j, &planner, &cache, &metrics, model_id))
                }
                EngineOp::AttnBackward(j) => EngineResult::AttnBackward(
                    execute_attn_backward_job(j, &planner, &cache, &metrics, model_id),
                ),
            };
            EngineOutput { key, result }
        })
    }

    /// Seed a [`DecodeState`] for one (layer, head) from the engine's
    /// [`BasisCache`] — *the prefill already recovered this basis*: a
    /// strided prefill job caches its post-exp basis under the
    /// (layer, head, seq_len, QK-fingerprint ⊕ k-tag) key, and this
    /// lookup turns that entry into decode-ready state for free. On a
    /// miss (evicted, or the prefill ran a different operator) the
    /// basis is recovered here and cached for the next session.
    ///
    /// `q` must be the **pre-scaled** per-head query block and `k` the
    /// per-head key block, exactly as the prefill job carried them —
    /// the content fingerprint is bitwise, so any deviation misses.
    /// Returns the state and whether it was served from the cache
    /// (also counted in `Metrics::decode_seed_hits/_misses`).
    pub fn seed_decode(
        &self,
        layer: u32,
        head: u32,
        q: &Matrix,
        k: &Matrix,
        k_bases: usize,
    ) -> (DecodeState, bool) {
        let (state, hit) = seed_or_recover(
            &self.cache,
            self.model_id,
            (layer, head),
            q,
            k,
            k_bases,
        );
        if hit {
            Metrics::incr(&self.metrics.decode_seed_hits);
        } else {
            Metrics::incr(&self.metrics.decode_seed_misses);
        }
        (state, hit)
    }
}

fn execute_job(
    job: AttnJob,
    planner: &Arc<SharedFftPlanner>,
    cache: &BasisCache,
    metrics: &Metrics,
    model_id: u64,
) -> JobOutput {
    // Derive the head-profile bucket before execution consumes the job.
    // For `Routed` jobs the bucket is the *resolved* operator —
    // re-resolved here through the same pure policy function the inner
    // arm uses, so the profile observes the route that actually ran.
    let profile = if job.training {
        None
    } else {
        let kind = match &job.backend {
            BatchedBackend::Exact(_) => RouteKind::Exact,
            BatchedBackend::Conv(_) | BatchedBackend::Strided(_) => RouteKind::Conv,
            BatchedBackend::LowRank(_) => RouteKind::LowRank,
            BatchedBackend::Routed(policy) => {
                policy.resolve(job.layer, job.head, job.q.rows(), job.q.cols()).0.kind()
            }
        };
        Some((job.layer, job.head, kind))
    };
    let t0 = std::time::Instant::now();
    let mut out = execute_job_inner(job, planner, cache, metrics, model_id);
    out.exec = t0.elapsed();
    if let Some((layer, head, kind)) = profile {
        metrics.record_head_job(layer, head, kind, out.fell_back, out.exec);
    }
    out
}

fn execute_job_inner(
    job: AttnJob,
    planner: &Arc<SharedFftPlanner>,
    cache: &BasisCache,
    metrics: &Metrics,
    model_id: u64,
) -> JobOutput {
    if job.training {
        // Training jobs never touch the serving cache — separate path.
        return execute_training_job(job, planner, metrics);
    }
    let AttnJob { layer, head, q, k, v, mask, backend, .. } = job;
    let n = q.rows();
    let mask = mask.unwrap_or_else(|| Mask::causal(n));
    // Local planner view over the engine-wide plan cache.
    let mut local = FftPlanner::with_shared(Arc::clone(planner));
    match backend {
        BatchedBackend::Exact(kernel) => {
            Metrics::incr(&metrics.exact_requests);
            let y = match kernel {
                // Blocked is causal-only; non-causal exact jobs keep
                // the row-streamed oracle.
                ExactKernel::Blocked if matches!(mask.kind(), MaskKind::Causal) => {
                    blocked_attention_causal(&q, &k, &v)
                }
                _ => exact_attention(&q, &k, &v, &mask),
            };
            serving_output(y, 0, false, false)
        }
        BatchedBackend::LowRank(cfg) => {
            Metrics::incr(&metrics.lowrank_requests);
            serving_output(lowrank_prefill(&q, &k, &v, mask, &cfg), 0, false, false)
        }
        BatchedBackend::Routed(policy) => {
            // Resolve the route *inside* job execution so pool fan-out
            // never sees routing: every worker count executes the same
            // resolved job, and the recursion below re-enters the
            // identical operator arms (same kernels, same cache keys)
            // a direct-backend submit would hit.
            Metrics::incr(&metrics.routed_jobs);
            let (route, refused) = policy.resolve(layer, head, n, q.cols());
            if refused {
                Metrics::incr(&metrics.router_rank_refusals);
            }
            match route.kind() {
                RouteKind::Exact => Metrics::incr(&metrics.router_exact_routes),
                RouteKind::Conv => Metrics::incr(&metrics.router_conv_routes),
                RouteKind::LowRank => Metrics::incr(&metrics.router_lowrank_routes),
            }
            let resolved = match route {
                HeadRoute::Exact => BatchedBackend::Exact(ExactKernel::RowStream),
                HeadRoute::Conv(cfg) => BatchedBackend::Conv(*cfg),
                HeadRoute::Strided(k_bases) => BatchedBackend::Strided(*k_bases),
                HeadRoute::LowRank(cfg) => BatchedBackend::LowRank(*cfg),
            };
            execute_job_inner(
                AttnJob {
                    layer,
                    head,
                    q,
                    k,
                    v,
                    mask: Some(mask),
                    backend: resolved,
                    training: false,
                },
                planner,
                cache,
                metrics,
                model_id,
            )
        }
        BatchedBackend::Conv(cfg) => {
            Metrics::incr(&metrics.conv_requests);
            let key = CacheKey {
                model_id,
                layer,
                head,
                seq_len: n,
                qk_fingerprint: conv_fingerprint(&q, &k, &mask) ^ recover_cfg_tag(&cfg),
            };
            if let Some(hit) = cache.get(&key) {
                Metrics::incr(&metrics.cache_hits);
                let basis_k = hit.post_basis.k();
                let y = apply_cached_basis(&mut local, &hit.post_basis, &hit.d_tilde, &v);
                return serving_output(y, basis_k, false, true);
            }
            Metrics::incr(&metrics.cache_misses);
            match conv_attention_masked_with(&mut local, &q, &k, &v, &mask, &cfg) {
                Ok(out) => {
                    cache.put(
                        key,
                        CachedBasis {
                            post_basis: out.post_basis.clone(),
                            d_tilde: out.d_tilde.clone(),
                        },
                    );
                    serving_output(out.y, out.post_basis.k(), false, false)
                }
                Err(_) => {
                    Metrics::incr(&metrics.fallbacks);
                    serving_output(exact_attention(&q, &k, &v, &mask), 0, true, false)
                }
            }
        }
        BatchedBackend::Strided(k_bases) => {
            Metrics::incr(&metrics.conv_requests);
            if !matches!(mask.kind(), MaskKind::Causal) {
                // Strided recovery assumes the causal mask.
                Metrics::incr(&metrics.fallbacks);
                return serving_output(exact_attention(&q, &k, &v, &mask), 0, true, false);
            }
            let key = CacheKey {
                model_id,
                layer,
                head,
                seq_len: n,
                qk_fingerprint: conv_fingerprint(&q, &k, &mask) ^ strided_tag(k_bases),
            };
            if let Some(hit) = cache.get(&key) {
                Metrics::incr(&metrics.cache_hits);
                let basis_k = hit.post_basis.k();
                let y = apply_cached_basis(&mut local, &hit.post_basis, &hit.d_tilde, &v);
                return serving_output(y, basis_k, false, true);
            }
            Metrics::incr(&metrics.cache_misses);
            match conv_attention_strided_with(&mut local, &q, &k, &v, k_bases) {
                Ok(out) => {
                    cache.put(
                        key,
                        CachedBasis {
                            post_basis: out.post_basis.clone(),
                            d_tilde: out.d_tilde.clone(),
                        },
                    );
                    serving_output(out.y, out.post_basis.k(), false, false)
                }
                Err(_) => {
                    Metrics::incr(&metrics.fallbacks);
                    serving_output(exact_attention(&q, &k, &v, &mask), 0, true, false)
                }
            }
        }
    }
}

/// Execute one **training-forward** job (see [`AttnJob::training`]):
/// the job's output carries the artifact the matching backward
/// consumes, and the serving [`BasisCache`] is never consulted or
/// written.
///
/// * `Exact` — softmax rows via the training-forward helper
///   (`dense_causal_probs`, the same float-op order as
///   `AttentionBackend::attend(keep_probs)`), `y = P·V`; the rows ride
///   the output for the exact backward.
/// * `Conv` — recover once via the identical float-op path a serving
///   conv job uses, return the basis as a step-scoped handle
///   ([`StepBasis`], counted in `Metrics::step_recoveries`). Recovery
///   failure (or a non-finite normalizer) falls back to the exact
///   kernel above — **bit-equal** to the exact training forward, so a
///   failed recovery degrades cost, never the loss curve (counted in
///   `fallbacks` *and* `train_fwd_fallbacks`).
fn execute_training_job(
    job: AttnJob,
    planner: &Arc<SharedFftPlanner>,
    metrics: &Metrics,
) -> JobOutput {
    let AttnJob { q, k, v, mask, backend, .. } = job;
    let n = q.rows();
    assert!(
        mask.as_ref().is_none_or(|m| matches!(m.kind(), MaskKind::Causal)),
        "training-forward jobs are causal"
    );
    let exact_train = |q: &Matrix, k: &Matrix, v: &Matrix, fell_back: bool| {
        // One source of truth for training softmax rows: bit-identical
        // to `AttentionBackend::attend(keep_probs)` and to the fast
        // backward's dense fallback.
        let probs = crate::gradient::batched::dense_causal_probs(q, k);
        let y = probs.matmul(v);
        JobOutput {
            y,
            basis_k: 0,
            fell_back,
            cache_hit: false,
            basis: None,
            probs: Some(Arc::new(probs)),
            exec: std::time::Duration::ZERO,
        }
    };
    match backend {
        BatchedBackend::Exact(kernel) => {
            Metrics::incr(&metrics.exact_requests);
            match kernel {
                ExactKernel::RowStream => exact_train(&q, &k, &v, false),
                ExactKernel::Blocked => {
                    let (y, probs) = blocked_train_forward(&q, &k, &v);
                    JobOutput {
                        y,
                        basis_k: 0,
                        fell_back: false,
                        cache_hit: false,
                        basis: None,
                        probs: Some(Arc::new(probs)),
                        exec: std::time::Duration::ZERO,
                    }
                }
            }
        }
        BatchedBackend::Conv(cfg) => {
            Metrics::incr(&metrics.conv_requests);
            let mut local = FftPlanner::with_shared(Arc::clone(planner));
            let mask = Mask::causal(n);
            match conv_attention_masked_with(&mut local, &q, &k, &v, &mask, &cfg) {
                // Same soundness guard as every serving cache writer:
                // only finite, positive normalizers may be handed to
                // the backward's `FOperator::from_cached`.
                Ok(out) if out.d_tilde.iter().all(|&x| x > 0.0 && x.is_finite()) => {
                    Metrics::incr(&metrics.step_recoveries);
                    let basis_k = out.post_basis.k();
                    let handle: StepBasis =
                        Arc::new(CachedBasis { post_basis: out.post_basis, d_tilde: out.d_tilde });
                    JobOutput {
                        y: out.y,
                        basis_k,
                        fell_back: false,
                        cache_hit: false,
                        basis: Some(handle),
                        probs: None,
                        exec: std::time::Duration::ZERO,
                    }
                }
                _ => {
                    Metrics::incr(&metrics.fallbacks);
                    Metrics::incr(&metrics.train_fwd_fallbacks);
                    exact_train(&q, &k, &v, true)
                }
            }
        }
        other => panic!(
            "training-forward jobs support the Exact and Conv backends, got {other:?}"
        ),
    }
}

/// Per-job decode operator (the decode-time mirror of
/// [`BatchedBackend`]; jobs in one decode batch may mix operators).
#[derive(Clone, Debug)]
pub enum DecodeOp {
    /// Exact last-row attention from the precomputed pre-exp logits
    /// row (`O(n·d)` — what a KV-cache stack pays per step), with the
    /// same float-op order as a full-prefill forward **of the same
    /// [`ExactKernel`] family**, so exact decode bit-matches
    /// re-prefill kernel-for-kernel (row-stream decode replays the
    /// row-streamed forward; blocked decode replays the blocked tile
    /// walk). `AttentionBackend::to_decode` pins the decode kernel to
    /// the prefill flavor for exactly this reason.
    Exact(ExactKernel),
    /// Cached-basis banded dot product (`O(k·n + n·d)`), growing the
    /// state per token and re-recovering a fresh strided basis (at
    /// `k_bases` onsets) from the full per-head Q/K when the append's
    /// drift exceeds `drift_tol`.
    Conv { k_bases: usize, drift_tol: f64 },
}

impl DecodeOp {
    /// Default drift tolerance: far above float noise (~1e-15 on exact
    /// conv growth), far below a structural break (≥1e-3 observed).
    pub const DEFAULT_DRIFT_TOL: f64 = 1e-8;

    /// A conv decode op with the default drift tolerance.
    pub fn conv(k_bases: usize) -> Self {
        DecodeOp::Conv { k_bases: k_bases.max(1), drift_tol: Self::DEFAULT_DRIFT_TOL }
    }
}

/// One (sequence, layer, head) decode step: append one token, attend
/// it against the prefix.
#[derive(Clone, Debug)]
pub struct DecodeJob {
    /// Layer index (cache key component for re-recovery).
    pub layer: u32,
    /// Head index within the layer (cache key component).
    pub head: u32,
    /// The state grown so far — required for [`DecodeOp::Conv`]
    /// (seeded via [`BatchedEngine::seed_decode`]), ignored by
    /// [`DecodeOp::Exact`]. Moved in; handed back in [`DecodeOutput`].
    pub state: Option<DecodeState>,
    /// Pre-exp logits row of the new token: `q_new · k_j` for `j ≤ n`
    /// (pre-scaled q), length `n+1`.
    pub new_row: Vec<f64>,
    /// Per-head V cache *including* the new token's row (`(n+1) × d_h`).
    pub v: Matrix,
    /// Full per-head pre-scaled Q cache including the new row — only
    /// consulted for drift re-recovery, so conv jobs must supply it.
    pub q: Option<Matrix>,
    /// Full per-head K cache including the new row (conv jobs only).
    pub k: Option<Matrix>,
    pub op: DecodeOp,
}

/// Result of one decode step.
#[derive(Clone, Debug)]
pub struct DecodeOutput {
    /// Attention output for the appended token (`d_h` values).
    pub y_last: Vec<f64>,
    /// The grown (possibly re-recovered) state, handed back for the
    /// next step. `None` for exact jobs.
    pub state: Option<DecodeState>,
    /// Drift reported by the append (0 for exact jobs).
    pub drift: f64,
    /// Whether drift forced a basis re-recovery this step.
    pub rerecovered: bool,
    /// Whether the conv path fell back to the exact last-row kernel
    /// (degenerate normalizer even after re-recovery).
    pub fell_back: bool,
    /// Wall time this step spent executing on its worker.
    pub exec: std::time::Duration,
}

/// Strided-recovery decode seeding: cache lookup first, recover on
/// miss, always leave the basis cached. Returns (state, was_hit).
/// Shared by prefill-time seeding and drift re-recovery — both go
/// through the same `BasisCache` key the prefill jobs use.
fn seed_or_recover(
    cache: &BasisCache,
    model_id: u64,
    (layer, head): (u32, u32),
    q: &Matrix,
    k: &Matrix,
    k_bases: usize,
) -> (DecodeState, bool) {
    let n = q.rows();
    let mask = Mask::causal(n);
    let key = CacheKey {
        model_id,
        layer,
        head,
        seq_len: n,
        qk_fingerprint: conv_fingerprint(q, k, &mask) ^ strided_tag(k_bases),
    };
    if let Some(hit) = cache.get(&key) {
        // The decode state grows its basis in place, so it needs owned
        // copies — cloned out of the shared entry here (same cost as
        // the old deep-copying `get`; the zero-copy win is the apply
        // and backward paths, which read through the `Arc`).
        return (DecodeState::new(hit.post_basis.clone(), hit.d_tilde.clone()), true);
    }
    let oracle = QkColumnOracle::new(q, k, &mask);
    let (pre_basis, _stats) = recover_strided(&oracle, k_bases);
    let post_basis = exp_transform(&pre_basis, true);
    let d_tilde = post_basis.row_sums();
    // Cache only sound bases: the prefill path refuses to cache when
    // the normalizer degenerates (exp over/underflow), and a poisoned
    // entry here would be served to future *prefill* cache hits, which
    // have no finiteness check. The decode job itself still gets the
    // state — its attend_last output is finiteness-checked and falls
    // back to the exact row.
    if d_tilde.iter().all(|&x| x > 0.0 && x.is_finite()) {
        cache.put(key, CachedBasis { post_basis: post_basis.clone(), d_tilde: d_tilde.clone() });
    }
    (DecodeState::new(post_basis, d_tilde), false)
}

fn execute_decode_job(
    job: DecodeJob,
    cache: &BasisCache,
    metrics: &Metrics,
    model_id: u64,
) -> DecodeOutput {
    let t0 = std::time::Instant::now();
    let DecodeJob { layer, head, state, new_row, v, q, k, op } = job;
    let mut out = match op {
        DecodeOp::Exact(kernel) => DecodeOutput {
            y_last: match kernel {
                ExactKernel::RowStream => exact_decode_last_row(&new_row, &v),
                ExactKernel::Blocked => blocked_decode_last_row(&new_row, &v),
            },
            state: None,
            drift: 0.0,
            rerecovered: false,
            fell_back: false,
            exec: std::time::Duration::ZERO,
        },
        DecodeOp::Conv { k_bases, drift_tol } => {
            let mut state = state.expect("conv decode job requires a seeded DecodeState");
            let drift = state.append_token(&new_row);
            let mut rerecovered = false;
            let mut drifted_blind = false;
            if drift > drift_tol {
                if let (Some(q), Some(k)) = (q.as_ref(), k.as_ref()) {
                    Metrics::incr(&metrics.decode_rerecoveries);
                    let (fresh, _hit) =
                        seed_or_recover(cache, model_id, (layer, head), q, k, k_bases);
                    state = fresh;
                    rerecovered = true;
                } else {
                    // The job carried no Q/K to re-recover from: don't
                    // serve the structurally broken basis — fall back
                    // to the exact row (new_row is always available).
                    drifted_blind = true;
                }
            }
            let mut y_last = state.attend_last(&v);
            let mut fell_back = false;
            if drifted_blind || !y_last.iter().all(|x| x.is_finite()) {
                // Degenerate normalizer (recovery too inaccurate for a
                // stable softmax): serve the exact last row instead.
                Metrics::incr(&metrics.decode_fallbacks);
                y_last = exact_decode_last_row(&new_row, &v);
                fell_back = true;
            }
            DecodeOutput {
                y_last,
                state: Some(state),
                drift,
                rerecovered,
                fell_back,
                exec: std::time::Duration::ZERO,
            }
        }
    };
    out.exec = t0.elapsed();
    metrics.record_decode(out.exec);
    out
}

/// FNV-1a step over one u64.
pub(crate) fn fnv_u64(mut h: u64, x: u64) -> u64 {
    for b in x.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

pub(crate) const FNV_SEED: u64 = 0xcbf29ce484222325;

/// Content fingerprint of a (Q, K, mask) triple. A cached basis is only
/// valid for identical content *and* an identical recovery schedule, so
/// callers xor in a backend tag as well. `pub(crate)`: the gradient
/// lane keys its `f`-operator with the same fingerprint over
/// `(A₁X, A₂, mask)`, which is what lets forward and backward share
/// recovered bases.
pub(crate) fn conv_fingerprint(q: &Matrix, k: &Matrix, mask: &Mask) -> u64 {
    fingerprint(q.data()) ^ fingerprint(k.data()).rotate_left(1) ^ mask_tag(mask).rotate_left(2)
}

fn mask_tag(mask: &Mask) -> u64 {
    match mask.kind() {
        MaskKind::Causal => 0,
        MaskKind::SlidingWindow { w, sink } => {
            fnv_u64(fnv_u64(fnv_u64(FNV_SEED, 1), *w as u64), *sink as u64)
        }
        _ => {
            // Generic masks: hash the support (O(n²), only paid by the
            // rare non-structured masks).
            let mut h = fnv_u64(FNV_SEED, 2);
            for i in 0..mask.n() {
                for j in mask.row_support(i) {
                    h = fnv_u64(h, ((i as u64) << 32) | j as u64);
                }
            }
            h
        }
    }
}

pub(crate) fn recover_cfg_tag(cfg: &RecoverConfig) -> u64 {
    let mut h = fnv_u64(FNV_SEED, 3);
    h = fnv_u64(h, cfg.k_max as u64);
    h = fnv_u64(h, cfg.t as u64);
    h = fnv_u64(h, cfg.delta.to_bits());
    fnv_u64(h, cfg.eps.to_bits())
}

fn strided_tag(k_bases: usize) -> u64 {
    fnv_u64(fnv_u64(FNV_SEED, 4), k_bases as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::rope::rope_structured_qk;
    use crate::attention::{conv_attention_strided, exact_attention};
    use crate::tensor::{max_abs_diff, Rng};

    fn engine(workers: usize) -> BatchedEngine {
        BatchedEngine::new(EngineConfig { workers, cache_capacity: 64 })
    }

    /// Prefill-lane submit helper.
    fn attend(e: &BatchedEngine, jobs: Vec<AttnJob>) -> Vec<JobOutput> {
        e.submit(jobs.into_iter().enumerate().map(|(i, j)| EngineJob::prefill(i as u64, j)).collect())
            .into_iter()
            .map(|o| o.result.into_prefill())
            .collect()
    }

    /// Decode-lane submit helper.
    fn decode(e: &BatchedEngine, jobs: Vec<DecodeJob>) -> Vec<DecodeOutput> {
        e.submit(jobs.into_iter().enumerate().map(|(i, j)| EngineJob::decode(i as u64, j)).collect())
            .into_iter()
            .map(|o| o.result.into_decode())
            .collect()
    }

    fn structured_job(layer: u32, head: u32, n: usize, d: usize, seed: u64) -> AttnJob {
        let mut rng = Rng::seeded(seed);
        let (q, k) = rope_structured_qk(n, d, 3, &mut rng);
        let v = Matrix::randn(n, d, &mut rng);
        AttnJob::causal(layer, head, q, k, v, BatchedBackend::Strided(4))
    }

    #[test]
    fn exact_jobs_match_oracle_in_order() {
        let e = engine(3);
        let mut rng = Rng::seeded(601);
        let (n, d) = (24, 4);
        let mut jobs = Vec::new();
        let mut want = Vec::new();
        for h in 0..6u32 {
            let q = Matrix::randn(n, d, &mut rng).scale(0.3);
            let k = Matrix::randn(n, d, &mut rng).scale(0.3);
            let v = Matrix::randn(n, d, &mut rng);
            want.push(exact_attention(&q, &k, &v, &Mask::causal(n)));
            let backend = BatchedBackend::Exact(ExactKernel::RowStream);
            jobs.push(AttnJob::causal(0, h, q, k, v, backend));
        }
        let outs = attend(&e, jobs);
        assert_eq!(outs.len(), 6);
        for (out, w) in outs.iter().zip(&want) {
            assert_eq!(max_abs_diff(&out.y, w), 0.0);
            assert_eq!(out.basis_k, 0);
            assert!(!out.fell_back);
        }
    }

    #[test]
    fn strided_jobs_match_single_path() {
        let e = engine(2);
        let jobs: Vec<AttnJob> =
            (0..4).map(|h| structured_job(1, h, 48, 8, 700 + h as u64)).collect();
        let singles: Vec<Matrix> = jobs
            .iter()
            .map(|j| conv_attention_strided(&j.q, &j.k, &j.v, 4).unwrap().y)
            .collect();
        let outs = attend(&e, jobs);
        for (out, w) in outs.iter().zip(&singles) {
            assert!(!out.fell_back);
            assert!(out.basis_k >= 1);
            assert_eq!(max_abs_diff(&out.y, w), 0.0, "batched must be bit-identical");
        }
    }

    #[test]
    fn second_call_hits_basis_cache() {
        let e = engine(2);
        let jobs: Vec<AttnJob> =
            (0..3).map(|h| structured_job(2, h, 32, 4, 800 + h as u64)).collect();
        let first = attend(&e, jobs.clone());
        let second = attend(&e, jobs);
        let snap = e.metrics().snapshot();
        assert!(snap.cache_hits >= 3, "hits = {}", snap.cache_hits);
        for (a, b) in first.iter().zip(&second) {
            assert!(b.cache_hit, "second call must be served from the cache");
            assert_eq!(max_abs_diff(&a.y, &b.y), 0.0);
        }
    }

    #[test]
    fn different_backend_tags_do_not_collide_in_cache() {
        // Same (layer, head, seq_len, Q, K) under different strided k
        // must not reuse each other's basis.
        let e = engine(1);
        let j4 = structured_job(0, 0, 40, 8, 900);
        let mut j2 = j4.clone();
        j2.backend = BatchedBackend::Strided(2);
        let out4 = attend(&e, vec![j4]);
        let out2 = attend(&e, vec![j2]);
        assert!(!out2[0].cache_hit, "k=2 must not hit the k=4 entry");
        assert!(out4[0].basis_k >= out2[0].basis_k);
    }

    #[test]
    fn fallback_on_degenerate_conv_is_finite() {
        let e = engine(2);
        let mut rng = Rng::seeded(901);
        let (n, d) = (12, 4);
        let q = Matrix::randn(n, d, &mut rng).scale(5.0);
        let k = Matrix::randn(n, d, &mut rng).scale(5.0);
        let v = Matrix::randn(n, d, &mut rng);
        let jobs = vec![AttnJob::causal(0, 0, q, k, v, BatchedBackend::Strided(2))];
        let outs = attend(&e, jobs);
        assert!(outs[0].y.is_finite());
    }

    #[test]
    fn decode_exact_bitmatches_full_attention_row() {
        // One exact decode step must equal the last row of the full
        // exact attention at the grown length — bitwise.
        let e = engine(2);
        let mut rng = Rng::seeded(1100);
        let (n, d) = (24, 4);
        let q = Matrix::randn(n + 1, d, &mut rng).scale(0.3);
        let k = Matrix::randn(n + 1, d, &mut rng).scale(0.3);
        let v = Matrix::randn(n + 1, d, &mut rng);
        // Pre-exp logits row in matmul accumulation order.
        let mut new_row = vec![0.0; n + 1];
        for (c, &qc) in q.row(n).iter().enumerate() {
            if qc == 0.0 {
                continue;
            }
            for (j, slot) in new_row.iter_mut().enumerate() {
                *slot += qc * k[(j, c)];
            }
        }
        let outs = decode(&e, vec![DecodeJob {
            layer: 0,
            head: 0,
            state: None,
            new_row,
            v: v.clone(),
            q: None,
            k: None,
            op: DecodeOp::Exact(ExactKernel::RowStream),
        }]);
        let full = exact_attention(&q, &k, &v, &Mask::causal(n + 1));
        for (a, b) in outs[0].y_last.iter().zip(full.row(n)) {
            assert_eq!(*a, *b, "exact decode must be bit-identical to re-prefill");
        }
        let snap = e.metrics().snapshot();
        assert_eq!(snap.decode_calls, 1);
        assert_eq!(snap.decode_steps, 1);
    }

    #[test]
    fn seed_decode_hits_cache_after_strided_prefill() {
        let e = engine(2);
        let job = structured_job(3, 1, 40, 8, 1200);
        let (q, k) = (job.q.clone(), job.k.clone());
        let _ = attend(&e, vec![job]);
        let (state, hit) = e.seed_decode(3, 1, &q, &k, 4);
        assert!(hit, "prefill must have cached the basis");
        assert_eq!(state.n(), 40);
        let snap = e.metrics().snapshot();
        assert_eq!(snap.decode_seed_hits, 1);
        assert_eq!(snap.decode_seed_misses, 0);
        // A never-prefetched (layer, head) misses and recovers.
        let (_, hit2) = e.seed_decode(9, 0, &q, &k, 4);
        assert!(!hit2);
        assert_eq!(e.metrics().snapshot().decode_seed_misses, 1);
    }

    #[test]
    fn drift_triggers_rerecovery_and_matches_scratch() {
        // Grow a structured prefix with a structure-breaking token: the
        // append must report drift, the engine must re-recover, and the
        // result must equal strided-recovery-from-scratch at the grown
        // length (that is exactly what re-recovery computes).
        let e = engine(1);
        let mut rng = Rng::seeded(1300);
        let (n, d, kb) = (32, 8, 4);
        let (q, k) = rope_structured_qk(n, d, 3, &mut rng);
        let (state, _) = e.seed_decode(0, 0, &q, &k, kb);
        // Grown Q/K: random new rows (breaks the Toeplitz generator).
        let mut q_full = q.clone();
        let mut k_full = k.clone();
        q_full.push_row(&rng.randn_vec(d));
        k_full.push_row(&rng.randn_vec(d));
        let new_row: Vec<f64> = (0..=n)
            .map(|j| crate::tensor::dot(q_full.row(n), k_full.row(j)))
            .collect();
        let v = Matrix::randn(n + 1, d, &mut rng);
        let outs = decode(&e, vec![DecodeJob {
            layer: 0,
            head: 0,
            state: Some(state),
            new_row,
            v: v.clone(),
            q: Some(q_full.clone()),
            k: Some(k_full.clone()),
            op: DecodeOp::conv(kb),
        }]);
        let out = &outs[0];
        assert!(out.drift > DecodeOp::DEFAULT_DRIFT_TOL, "drift = {}", out.drift);
        assert!(out.rerecovered);
        assert!(e.metrics().snapshot().decode_rerecoveries >= 1);
        // Re-recovered state ≡ scratch recovery at n+1 ⇒ attend_last
        // agrees with the scratch strided forward's last row.
        let scratch = conv_attention_strided(&q_full, &k_full, &v, kb).unwrap();
        for (a, b) in out.y_last.iter().zip(scratch.y.row(n)) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
    }

    #[test]
    fn decode_batch_is_deterministic_across_worker_counts() {
        let mk_jobs = || -> Vec<DecodeJob> {
            let mut rng = Rng::seeded(1400);
            let (n, d) = (28, 4);
            (0..6u32)
                .map(|h| {
                    let (q_full, k_full) = rope_structured_qk(n + 1, d, 2, &mut rng);
                    let q = q_full.slice(0, n, 0, d);
                    let k = k_full.slice(0, n, 0, d);
                    let out = conv_attention_strided(&q, &k, &Matrix::zeros(n, d), 1).unwrap();
                    let state =
                        crate::attention::decode::DecodeState::new(out.post_basis, out.d_tilde);
                    let new_row: Vec<f64> = (0..=n)
                        .map(|j| crate::tensor::dot(q_full.row(n), k_full.row(j)))
                        .collect();
                    DecodeJob {
                        layer: 0,
                        head: h,
                        state: Some(state),
                        new_row,
                        v: Matrix::randn(n + 1, d, &mut rng),
                        q: Some(q_full),
                        k: Some(k_full),
                        op: DecodeOp::conv(1),
                    }
                })
                .collect()
        };
        let base = decode(&engine(1), mk_jobs());
        for workers in [2usize, 8] {
            let outs = decode(&engine(workers), mk_jobs());
            for (a, b) in outs.iter().zip(&base) {
                assert_eq!(a.y_last, b.y_last, "decode must not depend on worker count");
            }
        }
    }

    #[test]
    fn shared_plan_cache_fills_once() {
        let e = engine(4);
        let jobs: Vec<AttnJob> =
            (0..8).map(|h| structured_job(0, h, 64, 8, 1000 + h as u64)).collect();
        let _ = attend(&e, jobs);
        // All jobs have the same n ⇒ a handful of distinct transform
        // lengths, not 8× duplicates.
        assert!(e.cached_plans() >= 1);
        assert!(e.cached_plans() <= 8, "plans = {}", e.cached_plans());
    }

    #[test]
    fn submit_mixed_lanes_echoes_keys_in_input_order() {
        use crate::gradient::batched::{
            dense_causal_probs, AttnBackwardJob, AttnBackwardMode, FastGradConfig, GradJob,
        };
        use crate::gradient::AttentionLossProblem;
        let e = engine(3);
        let mut rng = Rng::seeded(1500);
        let (n, d) = (20, 4);
        let pre = structured_job(0, 0, 32, 4, 1501);
        let (q_full, k_full) = rope_structured_qk(n + 1, d, 2, &mut rng);
        let new_row: Vec<f64> = (0..=n)
            .map(|j| crate::tensor::dot(q_full.row(n), k_full.row(j)))
            .collect();
        let dec = DecodeJob {
            layer: 0,
            head: 1,
            state: None,
            new_row,
            v: Matrix::randn(n + 1, d, &mut rng),
            q: None,
            k: None,
            op: DecodeOp::Exact(ExactKernel::RowStream),
        };
        let problem = Arc::new(AttentionLossProblem::random_structured(16, 3, &mut rng));
        let grad = GradJob {
            layer: 1,
            head: 0,
            problem,
            x: Matrix::zeros(3, 3),
            cfg: FastGradConfig::exact(16),
        };
        let bq = Matrix::randn(12, 3, &mut rng).scale(0.3);
        let bk = Matrix::randn(12, 3, &mut rng).scale(0.3);
        let probs = Arc::new(dense_causal_probs(&bq, &bk));
        let bwd = AttnBackwardJob {
            layer: 1,
            head: 1,
            q: bq,
            k: bk,
            v: Matrix::randn(12, 3, &mut rng),
            dout: Matrix::randn(12, 3, &mut rng),
            probs: Some(probs),
            basis: None,
            mode: AttnBackwardMode::Exact(ExactKernel::RowStream),
        };
        let outs = e.submit(vec![
            EngineJob::gradient(70, grad),
            EngineJob::prefill(71, pre),
            EngineJob::decode(72, dec),
            EngineJob::attn_backward(73, bwd),
        ]);
        assert_eq!(outs.len(), 4);
        assert_eq!(
            outs.iter().map(|o| o.key).collect::<Vec<_>>(),
            vec![70, 71, 72, 73],
            "results must be input-ordered with keys echoed"
        );
        assert_eq!(outs[0].result.lane(), "gradient");
        assert_eq!(outs[1].result.lane(), "prefill");
        assert_eq!(outs[2].result.lane(), "decode");
        assert_eq!(outs[3].result.lane(), "lm-backward");
        let snap = e.metrics().snapshot();
        assert_eq!(snap.submit_calls, 1);
        assert_eq!(
            (snap.batched_calls, snap.decode_calls, snap.grad_calls, snap.lm_backward_calls),
            (1, 1, 1, 1),
            "each non-empty lane counts one call"
        );
        assert_eq!(
            (snap.batched_jobs, snap.decode_steps, snap.grad_jobs, snap.lm_backward_jobs),
            (1, 1, 1, 1)
        );
    }

    #[test]
    fn training_forward_jobs_return_artifacts_and_skip_serving_cache() {
        use crate::basis::RecoverConfig;
        let e = engine(2);
        let mut rng = Rng::seeded(1800);
        let (n, d) = (20, 4);
        let q = Matrix::randn(n, d, &mut rng).scale(0.3);
        let k = Matrix::randn(n, d, &mut rng).scale(0.3);
        let v = Matrix::randn(n, d, &mut rng);

        // Exact training job: probs ride the output, bit-identical to
        // the model layer's training forward helper.
        let backend = BatchedBackend::Exact(ExactKernel::RowStream);
        let outs = e.submit(vec![EngineJob::prefill(
            0,
            AttnJob::causal(0, 0, q.clone(), k.clone(), v.clone(), backend).for_training(),
        )]);
        let out = outs[0].result.clone().into_prefill();
        let want_probs = crate::gradient::batched::dense_causal_probs(&q, &k);
        let probs = out.probs.expect("exact training job returns probs");
        assert_eq!(max_abs_diff(&probs, &want_probs), 0.0);
        assert_eq!(max_abs_diff(&out.y, &want_probs.matmul(&v)), 0.0);
        assert!(out.basis.is_none());

        // Conv training job: the basis rides the output as a
        // step-scoped handle, y matches the serving conv path bitwise,
        // and the serving cache sees zero traffic.
        let cfg = RecoverConfig::exact(n);
        let outs = e.submit(vec![EngineJob::prefill(
            1,
            AttnJob::causal(0, 1, q.clone(), k.clone(), v.clone(), BatchedBackend::Conv(cfg))
                .for_training(),
        )]);
        let out = outs[0].result.clone().into_prefill();
        assert!(!out.fell_back);
        let handle = out.basis.expect("conv training job returns its basis");
        assert!(handle.post_basis.k() >= 1);
        let want = crate::attention::conv_attention(&q, &k, &v, &cfg).unwrap();
        assert_eq!(max_abs_diff(&out.y, &want.y), 0.0);
        assert_eq!(handle.d_tilde, want.d_tilde, "handle carries the recovered normalizer");
        let snap = e.metrics().snapshot();
        assert_eq!((snap.cache_hits, snap.cache_misses), (0, 0));
        assert_eq!(e.cache().stats(), (0, 0, 0), "no serving-shard traffic");
        assert_eq!(snap.step_recoveries, 1);
        assert_eq!((snap.train_fwd_conv_calls, snap.train_fwd_conv_jobs), (1, 1));
        assert_eq!(snap.train_fwd_fallbacks, 0);

        // Hostile budget: the conv training job falls back to the exact
        // kernel — same bits as the exact training job — and is counted.
        let bad = RecoverConfig { k_max: 0, t: 1, delta: 1.0, eps: 0.0 };
        let outs = e.submit(vec![EngineJob::prefill(
            2,
            AttnJob::causal(0, 2, q.clone(), k.clone(), v.clone(), BatchedBackend::Conv(bad))
                .for_training(),
        )]);
        let out = outs[0].result.clone().into_prefill();
        assert!(out.fell_back);
        assert!(out.basis.is_none());
        let probs = out.probs.expect("fallback returns probs for the exact backward");
        assert_eq!(max_abs_diff(&probs, &want_probs), 0.0);
        assert_eq!(max_abs_diff(&out.y, &want_probs.matmul(&v)), 0.0);
        let snap = e.metrics().snapshot();
        assert_eq!(snap.train_fwd_fallbacks, 1);
        assert_eq!(e.cache().stats(), (0, 0, 0));
    }

    #[test]
    fn attn_backward_lane_routes_through_submit() {
        // An LM-backward job through the door: exact mode must equal
        // the row-streamed kernel run directly, and the lane counters
        // must tick.
        use crate::gradient::batched::{AttnBackwardJob, AttnBackwardMode};
        let e = engine(2);
        let mut rng = Rng::seeded(1700);
        let (n, d) = (20, 4);
        let q = Matrix::randn(n, d, &mut rng).scale(0.3);
        let k = Matrix::randn(n, d, &mut rng).scale(0.3);
        let v = Matrix::randn(n, d, &mut rng);
        let dout = Matrix::randn(n, d, &mut rng);
        let probs = Arc::new(crate::gradient::batched::dense_causal_probs(&q, &k));
        let outs = e.submit(vec![EngineJob::attn_backward(
            42,
            AttnBackwardJob {
                layer: 0,
                head: 0,
                q: q.clone(),
                k: k.clone(),
                v: v.clone(),
                dout: dout.clone(),
                probs: Some(Arc::clone(&probs)),
                basis: None,
                mode: AttnBackwardMode::Exact(ExactKernel::RowStream),
            },
        )]);
        assert_eq!(outs[0].key, 42);
        assert_eq!(outs[0].result.lane(), "lm-backward");
        let got = outs[0].result.clone().into_attn_backward();
        let (dq, dk, dv) = crate::gradient::batched::attn_backward_exact(&probs, &q, &k, &v, &dout);
        assert_eq!(max_abs_diff(&got.dq, &dq), 0.0);
        assert_eq!(max_abs_diff(&got.dk, &dk), 0.0);
        assert_eq!(max_abs_diff(&got.dv, &dv), 0.0);
        let snap = e.metrics().snapshot();
        assert_eq!((snap.lm_backward_calls, snap.lm_backward_jobs), (1, 1));
        assert_eq!(snap.lm_backward.count, 1, "per-job latency recorded");
    }

    // ---- Routed mode (the adaptive approximation router) ----

    /// A mixed policy over a 1-layer × 3-head grid: head 0 exact,
    /// head 1 strided conv, head 2 low-rank.
    fn mixed_policy() -> Arc<RouterPolicy> {
        Arc::new(
            RouterPolicy::new(HeadRoute::Exact)
                .set(0, 1, HeadRoute::Strided(4))
                .set(0, 2, HeadRoute::LowRank(LowRankConfig::new(1, 4.0))),
        )
    }

    /// Inputs every routed operator handles without fallback: RoPE
    /// structure for conv recovery, bounded entries for low-rank.
    fn routed_inputs(n: usize, d: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
        let mut rng = Rng::seeded(seed);
        let (q, k) = rope_structured_qk(n, d, 3, &mut rng);
        let v = Matrix::randn(n, d, &mut rng);
        (q, k, v)
    }

    #[test]
    fn routed_jobs_bit_match_their_resolved_backends() {
        // One routed submit across the mixed table must be bit-identical
        // to running each head's resolved backend directly, and the
        // decision counters must agree with the table.
        let policy = mixed_policy();
        let (n, d) = (48, 4);
        let heads: Vec<(Matrix, Matrix, Matrix)> =
            (0..3).map(|h| routed_inputs(n, d, 2000 + h)).collect();

        let routed_e = engine(2);
        let routed = attend(
            &routed_e,
            heads
                .iter()
                .enumerate()
                .map(|(h, (q, k, v))| {
                    AttnJob::causal(
                        0,
                        h as u32,
                        q.clone(),
                        k.clone(),
                        v.clone(),
                        BatchedBackend::Routed(Arc::clone(&policy)),
                    )
                })
                .collect(),
        );

        let direct_e = engine(2);
        let directs = [
            BatchedBackend::Exact(ExactKernel::RowStream),
            BatchedBackend::Strided(4),
            BatchedBackend::LowRank(LowRankConfig::new(1, 4.0)),
        ];
        let direct = attend(
            &direct_e,
            heads
                .iter()
                .zip(directs.iter())
                .enumerate()
                .map(|(h, ((q, k, v), b))| {
                    AttnJob::causal(0, h as u32, q.clone(), k.clone(), v.clone(), b.clone())
                })
                .collect(),
        );

        for (h, (r, w)) in routed.iter().zip(&direct).enumerate() {
            assert_eq!(
                max_abs_diff(&r.y, &w.y),
                0.0,
                "head {h}: routed output must be bit-identical to the direct backend"
            );
            assert_eq!(r.fell_back, w.fell_back, "head {h}");
        }
        let snap = routed_e.metrics().snapshot();
        assert_eq!(snap.routed_jobs, 3);
        assert_eq!(
            (snap.router_exact_routes, snap.router_conv_routes, snap.router_lowrank_routes),
            (1, 1, 1)
        );
        assert_eq!(snap.router_rank_refusals, 0);
    }

    #[test]
    fn routed_conv_shares_cache_with_direct_conv() {
        // A routed conv job and the matching direct Strided job build
        // the same CacheKey: the second submit is a cache hit.
        let policy = Arc::new(RouterPolicy::new(HeadRoute::Strided(4)));
        let e = engine(1);
        let (q, k, v) = routed_inputs(40, 8, 2100);
        let direct = attend(
            &e,
            vec![AttnJob::causal(0, 0, q.clone(), k.clone(), v.clone(), BatchedBackend::Strided(4))],
        );
        assert!(!direct[0].cache_hit);
        let routed = attend(
            &e,
            vec![AttnJob::causal(0, 0, q, k, v, BatchedBackend::Routed(policy))],
        );
        assert!(routed[0].cache_hit, "routed conv must hit the direct conv's basis");
        assert_eq!(max_abs_diff(&routed[0].y, &direct[0].y), 0.0);
    }

    #[test]
    fn rank_guard_reroutes_unviable_lowrank() {
        // Degree 2 at d = 4 has rank C(6, 2) = 15 ≥ n = 12: the policy's
        // low-rank route must reroute to the fallback and be counted.
        let policy = Arc::new(
            RouterPolicy::new(HeadRoute::LowRank(LowRankConfig::new(2, 4.0)))
                .with_lowrank_fallback(HeadRoute::Exact),
        );
        let e = engine(1);
        let (n, d) = (12, 4);
        let mut rng = Rng::seeded(2200);
        let q = Matrix::randn(n, d, &mut rng).scale(0.3);
        let k = Matrix::randn(n, d, &mut rng).scale(0.3);
        let v = Matrix::randn(n, d, &mut rng);
        let want = exact_attention(&q, &k, &v, &Mask::causal(n));
        let outs =
            attend(&e, vec![AttnJob::causal(0, 0, q, k, v, BatchedBackend::Routed(policy))]);
        assert_eq!(max_abs_diff(&outs[0].y, &want), 0.0, "refused low-rank runs the fallback");
        let snap = e.metrics().snapshot();
        assert_eq!(snap.router_rank_refusals, 1);
        assert_eq!(snap.router_lowrank_routes, 0, "a refused route is not a low-rank route");
        assert_eq!(snap.router_exact_routes, 1);
    }

    #[test]
    fn profile_driven_policy_is_deterministic_and_follows_the_table() {
        // Build profiles exercising all three decision rows, convert
        // twice: identical tables, and each head lands where the
        // documented decision table says.
        let metrics = Metrics::new();
        // Head (0,0): fallback rate 1.0 > 0.5 → Exact.
        metrics.record_head_job(0, 0, RouteKind::Conv, true, std::time::Duration::ZERO);
        // Head (0,1): no fallbacks, tiny error → conv.
        metrics.record_head_job(0, 1, RouteKind::Conv, false, std::time::Duration::ZERO);
        metrics.record_head_recovery_err(0, 1, 1e-6);
        // Head (0,2): no fallbacks, large error → low-rank.
        metrics.record_head_job(0, 2, RouteKind::Conv, false, std::time::Duration::ZERO);
        metrics.record_head_recovery_err(0, 2, 0.25);
        let profiles = metrics.head_profiles();
        let cfg = ProfilePolicyConfig::default();
        let a = RouterPolicy::from_profile(&profiles, &cfg);
        let b = RouterPolicy::from_profile(&profiles, &cfg);
        assert_eq!(a, b, "same profile + same thresholds → same table");
        assert_eq!(*a.route(0, 0), HeadRoute::Exact);
        assert_eq!(*a.route(0, 1), cfg.conv);
        assert_eq!(*a.route(0, 2), HeadRoute::LowRank(cfg.lowrank));
        // Unprofiled heads take the optimistic conv default.
        assert_eq!(*a.route(7, 7), cfg.conv);
    }

    #[test]
    #[should_panic(expected = "a pool job panicked")]
    fn routed_training_jobs_are_rejected() {
        // The training path rejects Routed like every non-Exact/Conv
        // backend; the pool contains the job panic and resurfaces it in
        // the submitting caller.
        let e = engine(1);
        let (q, k, v) = routed_inputs(16, 4, 2300);
        let job = AttnJob::causal(0, 0, q, k, v, BatchedBackend::Routed(mixed_policy()))
            .for_training();
        let _ = e.submit(vec![EngineJob::prefill(0, job)]);
    }

    #[test]
    fn head_profiles_record_resolved_route_kinds() {
        let policy = mixed_policy();
        let e = engine(2);
        let jobs: Vec<AttnJob> = (0..3)
            .map(|h| {
                let (q, k, v) = routed_inputs(48, 4, 2400 + h as u64);
                AttnJob::causal(0, h, q, k, v, BatchedBackend::Routed(Arc::clone(&policy)))
            })
            .collect();
        let _ = attend(&e, jobs);
        let profiles = e.metrics().head_profiles();
        assert_eq!(profiles.len(), 3);
        assert_eq!(profiles[&(0, 0)].exact_jobs, 1);
        assert_eq!(profiles[&(0, 1)].conv_jobs, 1);
        assert_eq!(profiles[&(0, 2)].lowrank_jobs, 1);
    }
}
