//! Attention masks: the causal mask (Definition 3.2) plus the Section 6
//! mask families (Figure 3) and the LongLora sparse mask (Appendix A).

use crate::tensor::Matrix;

/// An `n×n` boolean attention mask.
#[derive(Clone, Debug, PartialEq)]
pub struct Mask {
    n: usize,
    kind: MaskKind,
}

/// Mask families used by the paper.
#[derive(Clone, Debug, PartialEq)]
pub enum MaskKind {
    /// Causal (Definition 3.2): `M[i][j] = 1 ⇔ i ≥ j`.
    Causal,
    /// LongLora-style shifted sparse mask (Appendix A, Figure 3 left):
    /// causal *and* within a sliding window of `w` tokens, plus sink
    /// attention to the first `sink` tokens. Row support changes by an
    /// amortized constant → Definition 6.1 with `B_j = O(1)`.
    SlidingWindow { w: usize, sink: usize },
    /// Continuous-row mask (Definition 6.2): row `i` attends to
    /// `s[i] ..= t[i]`.
    ContinuousRow { s: Vec<usize>, t: Vec<usize> },
    /// Distinct-r rows mask (Definition 6.4): row `i` uses pattern
    /// `patterns[assign[i]]`.
    DistinctRows { assign: Vec<usize>, patterns: Vec<Vec<bool>> },
    /// Distinct-r columns mask (Definition 6.3).
    DistinctCols { assign: Vec<usize>, patterns: Vec<Vec<bool>> },
    /// Arbitrary dense mask (row-major bits).
    Dense(Vec<bool>),
}

impl Mask {
    /// Causal mask (Definition 3.2).
    pub fn causal(n: usize) -> Self {
        Mask { n, kind: MaskKind::Causal }
    }

    /// LongLora-style causal sliding-window mask.
    pub fn sliding_window(n: usize, w: usize, sink: usize) -> Self {
        assert!(w >= 1);
        Mask { n, kind: MaskKind::SlidingWindow { w, sink } }
    }

    /// Continuous-row mask (Definition 6.2); `s[i] ≤ t[i]`, 0-indexed
    /// inclusive.
    pub fn continuous_row(s: Vec<usize>, t: Vec<usize>) -> Self {
        assert_eq!(s.len(), t.len());
        let n = s.len();
        for i in 0..n {
            assert!(s[i] <= t[i] && t[i] < n, "row {i}: bad interval");
        }
        Mask { n, kind: MaskKind::ContinuousRow { s, t } }
    }

    /// Distinct-r rows mask (Definition 6.4).
    pub fn distinct_rows(assign: Vec<usize>, patterns: Vec<Vec<bool>>) -> Self {
        let n = assign.len();
        for &a in &assign {
            assert!(a < patterns.len());
        }
        for p in &patterns {
            assert_eq!(p.len(), n);
        }
        Mask { n, kind: MaskKind::DistinctRows { assign, patterns } }
    }

    /// Distinct-r columns mask (Definition 6.3).
    pub fn distinct_cols(assign: Vec<usize>, patterns: Vec<Vec<bool>>) -> Self {
        let n = assign.len();
        for &a in &assign {
            assert!(a < patterns.len());
        }
        for p in &patterns {
            assert_eq!(p.len(), n);
        }
        Mask { n, kind: MaskKind::DistinctCols { assign, patterns } }
    }

    /// Arbitrary dense mask from a boolean matrix (row-major).
    pub fn dense(n: usize, bits: Vec<bool>) -> Self {
        assert_eq!(bits.len(), n * n);
        Mask { n, kind: MaskKind::Dense(bits) }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn kind(&self) -> &MaskKind {
        &self.kind
    }

    /// `M[i][j]`.
    #[inline]
    pub fn entry(&self, i: usize, j: usize) -> bool {
        debug_assert!(i < self.n && j < self.n);
        match &self.kind {
            MaskKind::Causal => i >= j,
            MaskKind::SlidingWindow { w, sink } => i >= j && (i - j < *w || j < *sink),
            MaskKind::ContinuousRow { s, t } => j >= s[i] && j <= t[i],
            MaskKind::DistinctRows { assign, patterns } => patterns[assign[i]][j],
            MaskKind::DistinctCols { assign, patterns } => patterns[assign[j]][i],
            MaskKind::Dense(bits) => bits[i * self.n + j],
        }
    }

    /// Whether the mask is lower-triangular (required by the conv-basis
    /// decomposition; the Section 6 low-rank path accepts any mask).
    pub fn is_lower_triangular(&self) -> bool {
        match &self.kind {
            MaskKind::Causal | MaskKind::SlidingWindow { .. } => true,
            _ => {
                for i in 0..self.n {
                    for j in i + 1..self.n {
                        if self.entry(i, j) {
                            return false;
                        }
                    }
                }
                true
            }
        }
    }

    /// `M ∘ X` — Hadamard with the 0/1 mask.
    pub fn apply(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.shape(), (self.n, self.n));
        Matrix::from_fn(self.n, self.n, |i, j| if self.entry(i, j) { x[(i, j)] } else { 0.0 })
    }

    /// Dense 0/1 materialization.
    pub fn to_dense(&self) -> Matrix {
        Matrix::from_fn(self.n, self.n, |i, j| if self.entry(i, j) { 1.0 } else { 0.0 })
    }

    /// Support set of row `i` (sorted column indices).
    pub fn row_support(&self, i: usize) -> Vec<usize> {
        (0..self.n).filter(|&j| self.entry(i, j)).collect()
    }

    /// Row-change bounds `B_j = |S_j Δ S_{j−1}|` (Definition 6.1, with
    /// `S_0 = ∅`). LongLora-style masks have `B_j = O(1)` (Claim D.7:
    /// causal has `B_j = 1`).
    pub fn row_change_bounds(&self) -> Vec<usize> {
        let mut prev: Vec<bool> = vec![false; self.n];
        let mut out = Vec::with_capacity(self.n);
        for i in 0..self.n {
            let mut b = 0;
            for j in 0..self.n {
                let cur = self.entry(i, j);
                if cur != prev[j] {
                    b += 1;
                }
                prev[j] = cur;
            }
            out.push(b);
        }
        out
    }

    /// Number of set entries (observability / density reporting).
    pub fn nnz(&self) -> usize {
        let mut c = 0;
        for i in 0..self.n {
            for j in 0..self.n {
                if self.entry(i, j) {
                    c += 1;
                }
            }
        }
        c
    }

    /// ASCII rendering (Figure 3 style: `█` = 1, `·` = 0).
    pub fn render(&self) -> String {
        let mut s = String::with_capacity(self.n * (self.n + 1));
        for i in 0..self.n {
            for j in 0..self.n {
                s.push(if self.entry(i, j) { '█' } else { '·' });
            }
            s.push('\n');
        }
        s
    }
}

/// The Figure 3 gallery: the paper's three illustrative 16×16 masks.
pub fn figure3_masks() -> Vec<(&'static str, Mask)> {
    let n = 16;
    // Left: row change by amortized constant — LongLora-style shifted
    // sparse window.
    let left = Mask::sliding_window(n, 5, 1);
    // Middle: continuous row mask with drifting intervals.
    let s: Vec<usize> = (0..n).map(|i| i.saturating_sub(6)).collect();
    let t: Vec<usize> = (0..n).map(|i| (i + 2).min(n - 1)).collect();
    let middle = Mask::continuous_row(s, t);
    // Right: distinct 3 rows.
    let mut patterns = vec![vec![false; n]; 3];
    for j in 0..n {
        patterns[0][j] = j < 8;
        patterns[1][j] = (4..12).contains(&j);
        patterns[2][j] = j % 2 == 0;
    }
    let assign: Vec<usize> = (0..n).map(|i| i % 3).collect();
    let right = Mask::distinct_rows(assign, patterns);
    vec![
        ("row change by amortized constant (Def 6.1)", left),
        ("continuous row (Def 6.2)", middle),
        ("distinct 3 rows (Def 6.4)", right),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn causal_matches_definition_3_2() {
        let m = Mask::causal(4);
        for i in 0..4 {
            for j in 0..4 {
                assert_eq!(m.entry(i, j), i >= j);
            }
        }
        assert!(m.is_lower_triangular());
        assert_eq!(m.nnz(), 10);
    }

    #[test]
    fn causal_row_change_is_one() {
        // Claim D.7: causal mask has B_j = 1 for all j.
        let m = Mask::causal(8);
        assert_eq!(m.row_change_bounds(), vec![1; 8]);
    }

    #[test]
    fn sliding_window_is_causal_subset() {
        let m = Mask::sliding_window(12, 4, 2);
        assert!(m.is_lower_triangular());
        for i in 0..12 {
            for j in 0..12 {
                if m.entry(i, j) {
                    assert!(i >= j);
                    assert!(i - j < 4 || j < 2);
                }
            }
        }
    }

    #[test]
    fn sliding_window_row_change_amortized_constant() {
        let m = Mask::sliding_window(32, 6, 0);
        let bounds = m.row_change_bounds();
        // Window slides one step per row: B_j ≤ 2.
        assert!(bounds.iter().all(|&b| b <= 2), "{bounds:?}");
    }

    #[test]
    fn continuous_row_entries() {
        let m = Mask::continuous_row(vec![1, 0, 2], vec![2, 1, 2]);
        assert!(!m.entry(0, 0) && m.entry(0, 1) && m.entry(0, 2));
        assert!(m.entry(1, 0) && m.entry(1, 1) && !m.entry(1, 2));
        assert!(!m.entry(2, 0) && !m.entry(2, 1) && m.entry(2, 2));
    }

    #[test]
    fn distinct_rows_share_patterns() {
        let patterns = vec![vec![true, false, true], vec![false, true, false]];
        let m = Mask::distinct_rows(vec![0, 1, 0], patterns);
        assert_eq!(m.row_support(0), m.row_support(2));
        assert_ne!(m.row_support(0), m.row_support(1));
    }

    #[test]
    fn distinct_cols_transpose_of_rows() {
        let patterns = vec![vec![true, false, true], vec![false, true, false]];
        let rows = Mask::distinct_rows(vec![0, 1, 0], patterns.clone());
        let cols = Mask::distinct_cols(vec![0, 1, 0], patterns);
        let rd = rows.to_dense();
        let cd = cols.to_dense();
        assert_eq!(rd.transpose(), cd);
    }

    #[test]
    fn apply_zeroes_masked_entries() {
        let m = Mask::causal(3);
        let x = Matrix::ones(3, 3);
        let y = m.apply(&x);
        assert_eq!(y[(0, 1)], 0.0);
        assert_eq!(y[(1, 0)], 1.0);
    }

    #[test]
    fn figure3_gallery_shapes() {
        let gallery = figure3_masks();
        assert_eq!(gallery.len(), 3);
        for (_, m) in &gallery {
            assert_eq!(m.n(), 16);
            assert!(m.nnz() > 0);
        }
        // The continuous-row render has 16 lines.
        assert_eq!(gallery[1].1.render().lines().count(), 16);
    }

    #[test]
    fn dense_roundtrip() {
        let bits = vec![true, false, false, true];
        let m = Mask::dense(2, bits);
        assert!(m.entry(0, 0) && m.entry(1, 1));
        assert!(!m.entry(0, 1) && !m.entry(1, 0));
    }
}
