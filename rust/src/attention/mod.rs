//! Attention computation: the exact quadratic oracle (Definition 3.3),
//! the conv-basis fast path (Algorithm 1, Theorem 4.4), the masked
//! variants (Appendix A), and the full (bidirectional) self-attention
//! split (Appendix A “Extend to full self-attention”).
//!
//! Serving entry points: [`batched`] (the multi-head engine — one
//! typed `submit` door fanning prefill, decode *and* gradient jobs
//! over a shared worker pool) and [`decode`] (the incremental
//! per-token state the decode jobs grow).

pub mod batched;
pub mod blocked;
pub mod decode;
pub mod lowrank_backend;
pub mod mask;
pub mod rope;

pub use blocked::ExactKernel;
pub use mask::{figure3_masks, Mask, MaskKind};

use crate::basis::{
    exp_transform, recover, ConvBasis, KConvBasis, RecoverConfig, RecoverError, RecoverStats,
};
use crate::fft::FftPlanner;
use crate::tensor::Matrix;

/// Exact masked attention (Definition 3.3):
/// `Att(M,Q,K,V) = D⁻¹·A·V`, `A = M ∘ exp(QKᵀ)`, `D = diag(A·1)`.
/// `O(n²d)` time, `O(n²)` memory — the baseline of every benchmark.
///
/// The softmax is **stabilized**: each row subtracts its masked
/// maximum before `exp`, so large-magnitude logits no longer overflow
/// to `inf`/NaN. Subtracting a per-row constant inside `exp` and
/// dividing by the matching row sum is mathematically the identity;
/// the decode kernel
/// ([`decode::exact_decode_last_row`]) applies the *same* max-fold,
/// `exp`, sum and reciprocal in the same order, preserving the
/// decode-bitmatches-prefill contract.
pub fn exact_attention(q: &Matrix, k: &Matrix, v: &Matrix, mask: &Mask) -> Matrix {
    let n = q.rows();
    assert_eq!(k.rows(), n);
    assert_eq!(v.rows(), n);
    let logits = q.matmul(&k.transpose());
    // Masked per-row max, ascending-j f64::max fold — the exact fold
    // the decode kernel replays over its `new_row`.
    let mut row_max = vec![f64::NEG_INFINITY; n];
    for (i, mx) in row_max.iter_mut().enumerate() {
        for j in 0..n {
            if mask.entry(i, j) {
                *mx = mx.max(logits[(i, j)]);
            }
        }
    }
    let a = Matrix::from_fn(n, n, |i, j| {
        if mask.entry(i, j) {
            (logits[(i, j)] - row_max[i]).exp()
        } else {
            0.0
        }
    });
    let d = a.row_sums();
    let av = a.matmul(v);
    let inv: Vec<f64> = d.iter().map(|&x| 1.0 / x).collect();
    av.scale_rows(&inv)
}

/// Exact *unmasked* (full bidirectional) softmax attention — the
/// Appendix A extension target. Stabilized like [`exact_attention`]
/// (per-row max subtraction over the full row).
pub fn exact_attention_unmasked(q: &Matrix, k: &Matrix, v: &Matrix) -> Matrix {
    let n = q.rows();
    let logits = q.matmul(&k.transpose());
    let mut row_max = vec![f64::NEG_INFINITY; n];
    for (i, mx) in row_max.iter_mut().enumerate() {
        for &l in logits.row(i) {
            *mx = mx.max(l);
        }
    }
    let a = Matrix::from_fn(n, logits.cols(), |i, j| (logits[(i, j)] - row_max[i]).exp());
    let d = a.row_sums();
    let av = a.matmul(v);
    let inv: Vec<f64> = d.iter().map(|&x| 1.0 / x).collect();
    av.scale_rows(&inv)
}

/// Output of the conv-basis fast path, with everything needed for
/// re-use: the recovered pre-softmax basis, the exp-transformed basis
/// (cacheable: `recover` once, `apply` per V), and recovery stats.
#[derive(Clone, Debug)]
pub struct ConvAttentionOutput {
    /// `Ỹ ≈ D⁻¹AV`.
    pub y: Matrix,
    /// Pre-softmax basis of `M ∘ (QKᵀ)`.
    pub pre_basis: KConvBasis,
    /// Post-`exp` basis of `M ∘ exp(QKᵀ)` (what `apply` uses).
    pub post_basis: KConvBasis,
    /// Normalizer diagonal `D̃`.
    pub d_tilde: Vec<f64>,
    /// Recovery statistics.
    pub stats: RecoverStats,
}

/// Attention-path failures.
#[derive(Clone, Debug, PartialEq)]
pub enum AttentionError {
    Recover(RecoverError),
    /// The approximate normalizer `D̃` had a non-positive entry — the
    /// recovered basis is too inaccurate for a stable softmax.
    DegenerateNormalizer { row: usize, value: f64 },
    /// Conv-basis attention requires a lower-triangular mask.
    MaskNotLowerTriangular,
}

impl std::fmt::Display for AttentionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AttentionError::Recover(e) => write!(f, "recover failed: {e}"),
            AttentionError::DegenerateNormalizer { row, value } => {
                write!(f, "degenerate normalizer at row {row}: {value}")
            }
            AttentionError::MaskNotLowerTriangular => {
                write!(f, "conv-basis attention requires a lower-triangular mask")
            }
        }
    }
}

impl std::error::Error for AttentionError {}

impl From<RecoverError> for AttentionError {
    fn from(e: RecoverError) -> Self {
        AttentionError::Recover(e)
    }
}

/// Algorithm 1 (`convForward`) with the causal mask: recover the k-conv
/// basis of `M ∘ (QKᵀ)`, exp-transform it (Lemma B.16), and evaluate
/// `Ỹ = D̃⁻¹ (Σ_r conv(b̃_r, m_r)) V` via FFT. `O(k·n·d·log n)`.
pub fn conv_attention(
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    cfg: &RecoverConfig,
) -> Result<ConvAttentionOutput, AttentionError> {
    conv_attention_masked(q, k, v, &Mask::causal(q.rows()), cfg)
}

/// Algorithm 1 under a general **lower-triangular** mask (Appendix A:
/// “we can directly apply our Algorithm 1 by replacing the causal
/// attention mask with their sparse mask”).
///
/// The exp-transform completion assumes every causal position is either
/// covered by the basis or carries `exp(0) = 1`; positions that are
/// causal but *outside* the mask must be re-zeroed. For masks with
/// structured complements (sliding window) the correction is itself a
/// 1-conv term; for arbitrary masks we decompose the complement exactly.
pub fn conv_attention_masked(
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    mask: &Mask,
    cfg: &RecoverConfig,
) -> Result<ConvAttentionOutput, AttentionError> {
    conv_attention_masked_with(&mut FftPlanner::new(), q, k, v, mask, cfg)
}

/// [`conv_attention_masked`] with a caller-owned planner, so the FFT
/// plan cache amortizes across calls (the batched engine threads one
/// shared plan cache through every worker this way).
pub fn conv_attention_masked_with(
    planner: &mut FftPlanner,
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    mask: &Mask,
    cfg: &RecoverConfig,
) -> Result<ConvAttentionOutput, AttentionError> {
    if !mask.is_lower_triangular() {
        return Err(AttentionError::MaskNotLowerTriangular);
    }
    let (pre_basis, stats) = recover(q, k, mask, cfg)?;
    let mut post = exp_transform(&pre_basis, true);

    // Mask-complement correction: subtract 1 at causal positions not in
    // the mask (there, H̃ = 0 ⇒ the completed transform put exp(0) = 1).
    if let Some(correction) = mask_complement_basis(mask) {
        post = merge_bases(&post, &correction);
    }

    let d_tilde = post.row_sums();
    for (row, &val) in d_tilde.iter().enumerate() {
        if !(val > 0.0) {
            return Err(AttentionError::DegenerateNormalizer { row, value: val });
        }
    }
    let y_num = post.apply_matrix(planner, v);
    let inv: Vec<f64> = d_tilde.iter().map(|&x| 1.0 / x).collect();
    let y = y_num.scale_rows(&inv);
    Ok(ConvAttentionOutput { y, pre_basis, post_basis: post, d_tilde, stats })
}


/// Algorithm 1 with **strided** (non-adaptive) recovery: onsets at k
/// uniformly spaced columns (see [`crate::basis::recover_strided`]).
/// This is the Section 7 experimental protocol — k is the accuracy
/// knob, k = n reproduces the exact output — and the variant the
/// serving backends use on real (approximately conv-like) attention
/// matrices where no usable non-degeneracy gap δ exists.
pub fn conv_attention_strided(
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    k_bases: usize,
) -> Result<ConvAttentionOutput, AttentionError> {
    conv_attention_strided_with(&mut FftPlanner::new(), q, k, v, k_bases)
}

/// [`conv_attention_strided`] with a caller-owned planner (see
/// [`conv_attention_masked_with`]).
pub fn conv_attention_strided_with(
    planner: &mut FftPlanner,
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    k_bases: usize,
) -> Result<ConvAttentionOutput, AttentionError> {
    let n = q.rows();
    let mask = Mask::causal(n);
    let oracle = crate::basis::QkColumnOracle::new(q, k, &mask);
    let (pre_basis, stats) = crate::basis::recover_strided(&oracle, k_bases);
    let post = exp_transform(&pre_basis, true);
    let d_tilde = post.row_sums();
    for (row, &val) in d_tilde.iter().enumerate() {
        if !(val > 0.0) {
            return Err(AttentionError::DegenerateNormalizer { row, value: val });
        }
    }
    let y_num = post.apply_matrix(planner, v);
    let inv: Vec<f64> = d_tilde.iter().map(|&x| 1.0 / x).collect();
    let y = y_num.scale_rows(&inv);
    Ok(ConvAttentionOutput { y, pre_basis, post_basis: post, d_tilde, stats })
}

/// Apply a cached post-exp basis to a fresh `V` (the serving hot path:
/// recover once per sequence/layer, apply per request).
pub fn apply_cached_basis(
    planner: &mut FftPlanner,
    post_basis: &KConvBasis,
    d_tilde: &[f64],
    v: &Matrix,
) -> Matrix {
    let y_num = post_basis.apply_matrix(planner, v);
    let inv: Vec<f64> = d_tilde.iter().map(|&x| 1.0 / x).collect();
    y_num.scale_rows(&inv)
}

/// The conv-basis of `(causal − mask)` as a *negative* correction, or
/// `None` when the mask is exactly causal.
fn mask_complement_basis(mask: &Mask) -> Option<KConvBasis> {
    let n = mask.n();
    match mask.kind() {
        MaskKind::Causal => None,
        MaskKind::SlidingWindow { w, sink } => {
            // Complement = {(i,j): i−j ≥ w, j ≥ sink} = conv(c, n−sink)
            // with c[t] = 1 for t ≥ w — a single basis term. Negated.
            if *w >= n {
                return None;
            }
            let m = n - *sink.min(&(n - 1));
            let mut c = vec![0.0; n];
            for (t, slot) in c.iter_mut().enumerate().take(m).skip(*w) {
                let _ = t;
                *slot = -1.0;
            }
            if c.iter().all(|&x| x == 0.0) {
                return None;
            }
            Some(KConvBasis::new(n, vec![ConvBasis { b: c, m }]))
        }
        _ => {
            // Generic lower-triangular mask: exact decomposition of the
            // complement (O(n²); fine for the small-n cases that reach
            // here — structured masks take the closed forms above).
            let comp = Matrix::from_fn(n, n, |i, j| {
                if i >= j && !mask.entry(i, j) {
                    -1.0
                } else {
                    0.0
                }
            });
            let basis = crate::basis::decompose_exact(&comp, 0.0);
            if basis.k() == 0 {
                None
            } else {
                Some(basis)
            }
        }
    }
}

/// Merge two k-conv bases into one (terms with equal window add by
/// Claim 3.8 additivity; windows re-sorted strictly decreasing).
pub fn merge_bases(a: &KConvBasis, b: &KConvBasis) -> KConvBasis {
    assert_eq!(a.n(), b.n());
    let n = a.n();
    let mut by_m: std::collections::BTreeMap<usize, Vec<f64>> = std::collections::BTreeMap::new();
    for t in a.terms().iter().chain(b.terms()) {
        let e = by_m.entry(t.m).or_insert_with(|| vec![0.0; n]);
        for (x, y) in e.iter_mut().zip(&t.b) {
            *x += y;
        }
    }
    let terms: Vec<ConvBasis> = by_m
        .into_iter()
        .rev()
        .map(|(m, b)| ConvBasis { b, m })
        .collect();
    KConvBasis::new(n, terms)
}

/// Theorem 4.4's error bound: `‖Y − Ỹ‖∞ ≤ 2(e^{2ε} − 1)·‖V‖∞`.
pub fn theorem_4_4_bound(eps: f64, v_inf: f64) -> f64 {
    2.0 * ((2.0 * eps).exp() - 1.0) * v_inf
}

/// Output of the full (bidirectional) self-attention split.
#[derive(Clone, Debug)]
pub struct FullAttentionOutput {
    pub y: Matrix,
    /// Basis of the lower-triangular part `M ∘ exp(tril(QKᵀ))`.
    pub lower_basis: KConvBasis,
    /// Basis of the transposed upper part `M ∘ exp(triu(QKᵀ)ᵀ)`.
    pub upper_basis: KConvBasis,
}

/// Appendix A “Extend to full self-attention”: split `G = QKᵀ` into a
/// lower-triangular part `L` (with diagonal) and a strictly-upper part
/// `U`; approximate `M∘exp(L)` and `M∘exp(Uᵀ)` with conv bases; combine
/// `A = M∘exp(L) + (M∘exp(Uᵀ))ᵀ − I` (the transposed term re-adds
/// `exp(0) = 1` on the diagonal, subtracted once), renormalize over the
/// full row.
pub fn conv_attention_full(
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    cfg: &RecoverConfig,
) -> Result<FullAttentionOutput, AttentionError> {
    let n = q.rows();
    let mask = Mask::causal(n);
    // Lower part: basis of M ∘ (QKᵀ).
    let (pre_l, _) = recover(q, k, &mask, cfg)?;
    let post_l = exp_transform(&pre_l, true);
    // Upper part transposed: strict-upper of QKᵀ, transposed, equals the
    // strict-lower of KQᵀ. Recover against K, Q with the causal mask;
    // the diagonal entries of KQᵀ leak in, so zero the recovered b[0]
    // contribution by construction: recover sees H̃[j][j] = ⟨k_j, q_j⟩,
    // but the split demands Uᵀ diag = 0. We handle it by correcting the
    // composed matrix: subtract the recovered diagonal, add exp(0)=1,
    // then subtract the double-counted identity — net: subtract the
    // recovered diag term and the identity cancels with the +1.
    let (pre_u, _) = recover(k, q, &mask, cfg)?;
    // Zero out the diagonal contribution of the pre-basis: the diagonal
    // of Σ conv(b_r, m_r) is Σ_r b_r[0] on covered columns. Setting each
    // b_r[0] = 0 makes the pre-basis match strict-lower(KQᵀ) exactly
    // (up to recovery error).
    let pre_u_strict = KConvBasis::new(
        n,
        pre_u
            .terms()
            .iter()
            .map(|t| {
                let mut b = t.b.clone();
                b[0] = 0.0;
                ConvBasis { b, m: t.m }
            })
            .collect(),
    );
    let post_u = exp_transform(&pre_u_strict, true);

    let mut planner = FftPlanner::new();
    // Row sums of A = rowsums(lower) + colsums(upper-basis) − 1 (the
    // upper basis’ diagonal is exp(0) = 1, not a real attention weight).
    let rs_l = post_l.row_sums();
    let cs_u = col_sums(&post_u);
    let mut d: Vec<f64> = rs_l.iter().zip(&cs_u).map(|(a, b)| a + b - 1.0).collect();
    for (row, val) in d.iter_mut().enumerate() {
        if !(*val > 0.0) {
            return Err(AttentionError::DegenerateNormalizer { row, value: *val });
        }
    }
    // Y numerator = post_l·V + post_uᵀ·V − V (diagonal 1s double count).
    let yl = post_l.apply_matrix(&mut planner, v);
    let yu = apply_matrix_transpose(&mut planner, &post_u, v);
    let mut y = yl.add(&yu).sub(v);
    for i in 0..n {
        let inv = 1.0 / d[i];
        for x in y.row_mut(i) {
            *x *= inv;
        }
    }
    Ok(FullAttentionOutput { y, lower_basis: post_l, upper_basis: post_u })
}

/// Column sums of `Σ_r conv(b_r, m_r)` in closed form: column `n−m+j`
/// of `conv(b, m)` sums `b[0..m−j]`.
pub fn col_sums(basis: &KConvBasis) -> Vec<f64> {
    let n = basis.n();
    let mut out = vec![0.0; n];
    for t in basis.terms() {
        let off = n - t.m;
        // suffix-style prefix: col j gets Σ_{u < m−j} b[u]
        let mut prefix = vec![0.0; t.m + 1];
        for i in 0..t.m {
            prefix[i + 1] = prefix[i] + t.b[i];
        }
        for j in 0..t.m {
            out[off + j] += prefix[t.m - j];
        }
    }
    out
}

/// `(Σ_r conv(b_r, m_r))ᵀ · V` — correlation via FFT (used by the full
/// self-attention split).
pub fn apply_matrix_transpose(
    planner: &mut FftPlanner,
    basis: &KConvBasis,
    v: &Matrix,
) -> Matrix {
    let n = basis.n();
    assert_eq!(v.rows(), n);
    let d = v.cols();
    let mut out = Matrix::zeros(n, d);
    for c in 0..d {
        let x = v.col(c);
        let mut y = vec![0.0; n];
        for t in basis.terms() {
            let m = t.m;
            let off = n - m;
            // y[off+j] += Σ_{i ≥ j} b[i−j]·x[off+i]  (j < m)
            // = linear_conv(reverse(b[..m]), x[off..])[m−1+j]
            let rev: Vec<f64> = t.b[..m].iter().rev().cloned().collect();
            let full = crate::fft::linear_convolution(planner, &rev, &x[off..]);
            for j in 0..m {
                y[off + j] += full[m - 1 + j];
            }
        }
        out.set_col(c, &y);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::rope::rope_structured_qk;
    use crate::tensor::{max_abs_diff, Matrix, Rng};

    #[test]
    fn exact_attention_rows_are_convex_combinations() {
        let mut rng = Rng::seeded(101);
        let (n, d) = (12, 4);
        let q = Matrix::randn(n, d, &mut rng);
        let k = Matrix::randn(n, d, &mut rng);
        let v = Matrix::ones(n, d);
        let y = exact_attention(&q, &k, &v, &Mask::causal(n));
        // With V = 1, attention returns exactly 1 (softmax weights sum to 1).
        for i in 0..n {
            for j in 0..d {
                assert!((y[(i, j)] - 1.0).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn exact_attention_survives_adversarial_logit_scales() {
        // Regression: the pre-stabilization kernels took `exp(logits)`
        // directly, so any logit past ±709 overflowed the row to
        // `inf/inf = NaN`. With V = 1 every row must still come back
        // exactly as a convex combination — for the row-streamed
        // kernel, the unmasked variant, AND the blocked kernel (the
        // harness in tests/blocked_kernels.rs re-checks this contract
        // end to end).
        let mut rng = Rng::seeded(104);
        let (n, d) = (24, 4);
        let q = Matrix::randn(n, d, &mut rng).scale(20.0);
        let k = Matrix::randn(n, d, &mut rng).scale(20.0);
        let v = Matrix::ones(n, d);
        for y in [
            exact_attention(&q, &k, &v, &Mask::causal(n)),
            exact_attention_unmasked(&q, &k, &v),
            blocked::blocked_attention_causal(&q, &k, &v),
        ] {
            assert!(y.is_finite());
            for i in 0..n {
                for j in 0..d {
                    assert!((y[(i, j)] - 1.0).abs() < 1e-9, "y[{i}][{j}] = {}", y[(i, j)]);
                }
            }
        }
    }

    #[test]
    fn stabilized_exact_matches_blocked_on_adversarial_scales() {
        // Same adversarial magnitudes, generic V: both exact families
        // must stay finite and agree within the blocked tolerance.
        let mut rng = Rng::seeded(105);
        let (n, d) = (33, 4);
        let q = Matrix::randn(n, d, &mut rng).scale(20.0);
        let k = Matrix::randn(n, d, &mut rng).scale(20.0);
        let v = Matrix::randn(n, d, &mut rng);
        let row = exact_attention(&q, &k, &v, &Mask::causal(n));
        let blk = blocked::blocked_attention_causal(&q, &k, &v);
        assert!(row.is_finite() && blk.is_finite());
        let tol = blocked::blocked_rtol(n) * crate::tensor::linf_norm_mat(&v).max(1.0);
        assert!(max_abs_diff(&row, &blk) <= tol);
    }

    #[test]
    fn first_row_attends_only_to_itself() {
        let mut rng = Rng::seeded(102);
        let (n, d) = (8, 4);
        let q = Matrix::randn(n, d, &mut rng);
        let k = Matrix::randn(n, d, &mut rng);
        let v = Matrix::randn(n, d, &mut rng);
        let y = exact_attention(&q, &k, &v, &Mask::causal(n));
        for j in 0..d {
            assert!((y[(0, j)] - v[(0, j)]).abs() < 1e-12);
        }
    }

    #[test]
    fn conv_attention_exact_on_structured_qk() {
        // Toeplitz QKᵀ ⇒ small-k basis ⇒ conv attention ≈ exact.
        let mut rng = Rng::seeded(103);
        let (n, d) = (64, 8);
        let (q, k) = rope_structured_qk(n, d, 3, &mut rng);
        let v = Matrix::randn(n, d, &mut rng);
        let exact = exact_attention(&q, &k, &v, &Mask::causal(n));
        let cfg = RecoverConfig { k_max: 4, t: 4, delta: 1e-4, eps: 1e-9 };
        let out = conv_attention(&q, &k, &v, &cfg).unwrap();
        assert_eq!(out.pre_basis.k(), 1, "Toeplitz ⇒ 1-conv basis");
        let err = max_abs_diff(&exact, &out.y);
        assert!(err < 1e-8, "err = {err}");
    }

    #[test]
    fn conv_attention_exact_config_matches_oracle_any_qk() {
        // Corollary 4.5: with k=n, T=1 the output is exact for ANY Q, K.
        let mut rng = Rng::seeded(104);
        let (n, d) = (24, 5);
        let q = Matrix::randn(n, d, &mut rng).scale(0.3);
        let k = Matrix::randn(n, d, &mut rng).scale(0.3);
        let v = Matrix::randn(n, d, &mut rng);
        let exact = exact_attention(&q, &k, &v, &Mask::causal(n));
        let out = conv_attention(&q, &k, &v, &RecoverConfig::exact(n)).unwrap();
        let err = max_abs_diff(&exact, &out.y);
        assert!(err < 1e-8, "err = {err}");
    }

    #[test]
    fn theorem_4_4_error_bound_holds() {
        // Perturb a structured H̃ by ε; the conv output must stay within
        // 2(e^{2ε}−1)·‖V‖∞ of the exact output.
        let mut rng = Rng::seeded(105);
        let (n, d) = (48, 6);
        let (q0, k0) = rope_structured_qk(n, d, 3, &mut rng);
        // ε-perturbation of Q (propagates to ≤ ε·max‖k_row‖ on H̃; rows
        // of K are unit-norm here so ‖·‖∞ perturbation ≤ ε').
        let eps_h = 1e-3;
        let q = Matrix::from_fn(n, d, |i, j| q0[(i, j)] + (rng.uniform() - 0.5) * eps_h / d as f64);
        let v = Matrix::randn(n, d, &mut rng);
        let exact = exact_attention(&q, &k0, &v, &Mask::causal(n));
        let cfg = RecoverConfig { k_max: 6, t: 4, delta: 0.05, eps: eps_h };
        let out = conv_attention(&q, &k0, &v, &cfg).unwrap();
        let err = max_abs_diff(&exact, &out.y);
        let v_inf = crate::tensor::linf_norm_mat(&v);
        let bound = theorem_4_4_bound(2.0 * eps_h, v_inf); // slack ×2 on ε
        assert!(err <= bound, "err {err} > bound {bound}");
    }

    #[test]
    fn sliding_window_mask_conv_attention() {
        let mut rng = Rng::seeded(106);
        let (n, d) = (48, 8);
        let (q, k) = rope_structured_qk(n, d, 3, &mut rng);
        let v = Matrix::randn(n, d, &mut rng);
        let mask = Mask::sliding_window(n, 8, 2);
        let exact = exact_attention(&q, &k, &v, &mask);
        // The probe window T must exceed the band width w: the windowed
        // matrix's second basis (the −tail term at the sink boundary)
        // only differs from the first at diagonal offsets ≥ w, so a
        // probe shorter than w cannot satisfy Definition 4.1's
        // non-degeneracy for it.
        let cfg = RecoverConfig { k_max: 8, t: 10, delta: 1e-6, eps: 1e-12 };
        let out = conv_attention_masked(&q, &k, &v, &mask, &cfg).unwrap();
        let err = max_abs_diff(&exact, &out.y);
        assert!(err < 1e-7, "err = {err}");
    }

    #[test]
    fn generic_lower_triangular_mask_via_complement_decomposition() {
        let mut rng = Rng::seeded(107);
        let (n, d) = (20, 4);
        let (q, k) = rope_structured_qk(n, d, 2, &mut rng);
        let v = Matrix::randn(n, d, &mut rng);
        // Arbitrary lower-triangular mask: causal minus a few random
        // positions.
        let mut bits = vec![false; n * n];
        for i in 0..n {
            for j in 0..=i {
                bits[i * n + j] = !(i == 7 && j == 3 || i == 15 && j % 4 == 0);
            }
        }
        let mask = Mask::dense(n, bits);
        let exact = exact_attention(&q, &k, &v, &mask);
        let out = conv_attention_masked(&q, &k, &v, &mask, &RecoverConfig::exact(n)).unwrap();
        let err = max_abs_diff(&exact, &out.y);
        assert!(err < 1e-7, "err = {err}");
    }

    #[test]
    fn rejects_non_lower_triangular_mask() {
        let mut rng = Rng::seeded(108);
        let (q, k, v) = (
            Matrix::randn(8, 2, &mut rng),
            Matrix::randn(8, 2, &mut rng),
            Matrix::randn(8, 2, &mut rng),
        );
        let mask = Mask::continuous_row(vec![0; 8], vec![7; 8]); // full rows
        let cfg = RecoverConfig::exact(8);
        assert!(matches!(
            conv_attention_masked(&q, &k, &v, &mask, &cfg),
            Err(AttentionError::MaskNotLowerTriangular)
        ));
    }

    #[test]
    fn full_self_attention_split_matches_oracle() {
        let mut rng = Rng::seeded(109);
        let (n, d) = (24, 6);
        let (q, k) = rope_structured_qk(n, d, 3, &mut rng);
        let v = Matrix::randn(n, d, &mut rng);
        let exact = exact_attention_unmasked(&q, &k, &v);
        let out = conv_attention_full(&q, &k, &v, &RecoverConfig::exact(n)).unwrap();
        let err = max_abs_diff(&exact, &out.y);
        assert!(err < 1e-7, "err = {err}");
    }

    #[test]
    fn col_sums_matches_dense() {
        let mut rng = Rng::seeded(110);
        let n = 16;
        let terms = vec![
            ConvBasis { b: rng.randn_vec(n), m: 16 },
            ConvBasis { b: rng.randn_vec(n), m: 7 },
        ];
        let basis = KConvBasis::new(n, terms);
        let dense = basis.to_dense();
        let want: Vec<f64> = (0..n).map(|j| (0..n).map(|i| dense[(i, j)]).sum()).collect();
        let got = col_sums(&basis);
        for (u, w) in got.iter().zip(&want) {
            assert!((u - w).abs() < 1e-10);
        }
    }

    #[test]
    fn apply_transpose_matches_dense() {
        let mut rng = Rng::seeded(111);
        let n = 20;
        let basis = KConvBasis::new(
            n,
            vec![
                ConvBasis { b: rng.randn_vec(n), m: 20 },
                ConvBasis { b: rng.randn_vec(n), m: 9 },
            ],
        );
        let v = Matrix::randn(n, 3, &mut rng);
        let mut planner = FftPlanner::new();
        let fast = apply_matrix_transpose(&mut planner, &basis, &v);
        let dense = basis.to_dense().transpose().matmul(&v);
        assert!(max_abs_diff(&fast, &dense) < 1e-8);
    }

    #[test]
    fn merge_bases_adds_matching_windows() {
        let n = 8;
        let a = KConvBasis::new(n, vec![ConvBasis { b: vec![1.0; n], m: 8 }]);
        let b = KConvBasis::new(
            n,
            vec![ConvBasis { b: vec![2.0; n], m: 8 }, ConvBasis { b: vec![3.0; n], m: 4 }],
        );
        let merged = merge_bases(&a, &b);
        assert_eq!(merged.k(), 2);
        let want = a.to_dense().add(&b.to_dense());
        assert!(max_abs_diff(&merged.to_dense(), &want) < 1e-12);
    }


    #[test]
    fn strided_full_k_is_exact_any_qk() {
        let mut rng = Rng::seeded(113);
        let (n, d) = (24, 4);
        let q = Matrix::randn(n, d, &mut rng).scale(0.4);
        let k = Matrix::randn(n, d, &mut rng).scale(0.4);
        let v = Matrix::randn(n, d, &mut rng);
        let exact = exact_attention(&q, &k, &v, &Mask::causal(n));
        let out = conv_attention_strided(&q, &k, &v, n).unwrap();
        assert!(max_abs_diff(&exact, &out.y) < 1e-9);
    }

    #[test]
    fn strided_error_decreases_with_k_on_generic_qk() {
        let mut rng = Rng::seeded(114);
        let (n, d) = (64, 8);
        let q = Matrix::randn(n, d, &mut rng).scale(0.3);
        let k = Matrix::randn(n, d, &mut rng).scale(0.3);
        let v = Matrix::randn(n, d, &mut rng);
        let exact = exact_attention(&q, &k, &v, &Mask::causal(n));
        let errs: Vec<f64> = [4usize, 16, 64]
            .iter()
            .map(|&kb| {
                let out = conv_attention_strided(&q, &k, &v, kb).unwrap();
                crate::tensor::rel_fro_error(&exact, &out.y)
            })
            .collect();
        assert!(errs[2] < 1e-18, "full k exact: {errs:?}");
        assert!(errs[2] <= errs[1] && errs[1] <= errs[0], "monotone: {errs:?}");
    }

    #[test]
    fn strided_k1_on_toeplitz_is_exact() {
        let mut rng = Rng::seeded(115);
        let (n, d) = (40, 8);
        let (q, k) = rope_structured_qk(n, d, 3, &mut rng);
        let v = Matrix::randn(n, d, &mut rng);
        let exact = exact_attention(&q, &k, &v, &Mask::causal(n));
        let out = conv_attention_strided(&q, &k, &v, 1).unwrap();
        assert!(max_abs_diff(&exact, &out.y) < 1e-9);
    }

    #[test]
    fn cached_basis_apply_matches_fresh() {
        let mut rng = Rng::seeded(112);
        let (n, d) = (32, 4);
        let (q, k) = rope_structured_qk(n, d, 2, &mut rng);
        let v1 = Matrix::randn(n, d, &mut rng);
        let v2 = Matrix::randn(n, d, &mut rng);
        let cfg = RecoverConfig { k_max: 4, t: 4, delta: 1e-4, eps: 1e-9 };
        let out = conv_attention(&q, &k, &v1, &cfg).unwrap();
        let mut planner = FftPlanner::new();
        let y2_cached = apply_cached_basis(&mut planner, &out.post_basis, &out.d_tilde, &v2);
        let y2_fresh = conv_attention(&q, &k, &v2, &cfg).unwrap().y;
        assert!(max_abs_diff(&y2_cached, &y2_fresh) < 1e-10);
    }
}
