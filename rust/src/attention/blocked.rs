//! Flash-style **blocked streaming-softmax** exact kernels.
//!
//! The row-streamed exact kernels ([`exact_attention`], the training
//! probs builder, [`exact_decode_last_row`]) materialize or stream full
//! `n`-length rows: the softmax denominator is only known after the
//! whole row's `exp` has been taken, so the value accumulation makes a
//! second full pass with no L1/L2 tile reuse — the memory-bound shape
//! Flash Attention (Dao et al., arXiv:2205.14135) identifies. The
//! kernels here instead walk each query row's causal prefix in column
//! **tiles** of [`BLOCK`] and renormalize online, so one pass over the
//! logits suffices and every inner loop runs over tile-local scratch:
//!
//! ```text
//!        columns  0        BLOCK      2·BLOCK            i
//!                 ├─ tile 0 ─┤├─ tile 1 ─┤ … ├─ tile i/B ─┤│(masked)
//! row i state:    m  running max   s  running Σexp   acc[d_v]
//!   per tile t:   m' = max(m, max tile)        (tile max, ascending j)
//!                 c  = exp(m − m')             (1.0 when m' == m)
//!                 s  = s·c + Σ_j exp(l_j − m')
//!                 acc= acc·c + Σ_j exp(l_j − m')·v_j
//!   after tiles:  y_i = acc / s   (multiply by reciprocal)
//! ```
//!
//! `x · 1.0 == x` bitwise, so tiles that do not raise the max are exact
//! no-ops on `s` and `acc`; the first tile starts from `m = −∞`, where
//! `c = exp(−∞ − m') = 0` for any finite tile max. The kernels assume
//! **finite logits** (an `exp(−∞ − (−∞)) = NaN` can only arise from
//! non-finite inputs, which already poison every kernel in this crate).
//!
//! # The two-level equivalence contract
//!
//! The row-streamed kernels pin themselves to the dense matrix form
//! *bitwise*. Blocked kernels renormalize mid-row, so their float-op
//! order is genuinely different; the contract becomes two-level
//! (pinned by `tests/blocked_kernels.rs`):
//!
//! 1. **Against the row-streamed oracles**: agreement within the
//!    analytic [`blocked_rtol`] tolerance below — and strictly *more*
//!    robustness: online max subtraction survives logit magnitudes
//!    far beyond `exp`'s overflow threshold (±709), where an
//!    unstabilized kernel returns `inf/NaN`.
//! 2. **Within the blocked family**, the load-bearing bit-identities
//!    are preserved: [`blocked_decode_last_row`] replays the exact
//!    tile walk of the matching [`blocked_attention_causal`] row
//!    (tiles are indexed by *absolute* column position, so prefill row
//!    `i` at length `i+1` and a decode step at length `i+1` execute
//!    the same float ops in the same order), and every kernel is a
//!    pure per-row function, so any engine worker count is
//!    bit-identical.
//!
//! # Tolerance derivation (`BLOCKED_RTOL`)
//!
//! Softmax weights sum to 1, so each output element is a convex
//! combination of a `V` column: `|y| ≤ ‖V‖∞`. Both kernel families
//! compute the same mathematical sums with different association:
//! an `n`-term summation carries `O(n·ε)` relative rounding
//! (`ε = f64::EPSILON`), each `exp` is faithfully rounded (≤ 1 ulp),
//! and the blocked path compounds one extra `exp(m − m')`
//! renormalization per max-raising tile (≤ ⌈n/BLOCK⌉ of them, each
//! ≤ 1 ulp multiplicative on `s` and `acc`). Numerator and
//! denominator errors add through the final reciprocal. A safe
//! engineering bound on the *difference between the two kernels* is
//! therefore `C·n·ε·‖V‖∞` with a modest constant; [`blocked_rtol`]
//! uses `C = 64`, several× the worst observed deviation at `n = 4096`
//! while still ~1e-12 relative at bench sizes.
//!
//! Serving entry: the engine's exact lanes select kernels through
//! [`ExactKernel`], threaded through `AttentionBackend`,
//! `BatchedBackend`, `DecodeOp` and `AttnBackwardMode`.
//!
//! [`exact_attention`]: crate::attention::exact_attention
//! [`exact_decode_last_row`]: crate::attention::decode::exact_decode_last_row

use crate::tensor::Matrix;

/// Column-tile width of the blocked kernels: 16 f64 lanes = two
/// cache lines, wide enough for the compiler to vectorize the
/// fixed-width inner loops (AVX2: 4 f64/lane), small enough that a
/// tile of logits, weights and a `V` tile stay L1-resident.
pub const BLOCK: usize = 16;

/// Which exact-kernel family serves an exact attention lane.
///
/// Threaded through `AttentionBackend::Exact`, `BatchedBackend::Exact`,
/// `DecodeOp::Exact` and `AttnBackwardMode::Exact` so every exact-lane
/// consumer (serving prefill, decode, training forward, LM backward)
/// can opt into the blocked kernels per job. Decode pins to the
/// prefill's kernel flavor: the decode-bitmatches-prefill contract
/// only holds *within* a family.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ExactKernel {
    /// The row-streamed kernels — bit-identical to the dense matrix
    /// form, the oracle everything else is pinned against.
    #[default]
    RowStream,
    /// The blocked streaming-softmax kernels in this module — within
    /// [`blocked_rtol`] of the oracle, numerically robust to
    /// large-magnitude logits, causal-mask only (non-causal exact
    /// jobs fall back to the row-streamed kernel).
    Blocked,
}

/// Absolute tolerance (per unit of `‖V‖∞`) for blocked-vs-row-streamed
/// comparisons — the documented `BLOCKED_RTOL` of the equivalence
/// harness. See the module doc for the derivation.
pub fn blocked_rtol(n: usize) -> f64 {
    64.0 * n as f64 * f64::EPSILON
}

/// Pre-exp causal logits of one query row against `k[..len]`, with
/// exactly `Matrix::matmul`'s per-element accumulation order —
/// ascending feature index, including the skip on exact-zero `q`
/// entries — so a row computed here is bit-identical to the matching
/// row of `q.matmul(&k.transpose())` and to the `new_row` the model's
/// decode step hands the engine. Exposed so tests and benches can
/// build decode rows that bit-match the blocked prefill.
pub fn causal_logits_row(q_row: &[f64], k: &Matrix, len: usize) -> Vec<f64> {
    assert!(len <= k.rows());
    assert_eq!(q_row.len(), k.cols());
    let mut out = vec![0.0; len];
    causal_logits_row_into(q_row, k, &mut out);
    out
}

fn causal_logits_row_into(q_row: &[f64], k: &Matrix, out: &mut [f64]) {
    for (j, slot) in out.iter_mut().enumerate() {
        let krow = k.row(j);
        let mut acc = 0.0;
        for (&qc, &kc) in q_row.iter().zip(krow) {
            if qc == 0.0 {
                continue;
            }
            acc += qc * kc;
        }
        *slot = acc;
    }
}

/// The online-renormalization walk of one row (the recurrence in the
/// module doc): streams `logits` in absolute tiles of [`BLOCK`],
/// writes `y = softmax(logits)·v[..len]` into `out`, and returns
/// `(m, 1/s)` — the row max and reciprocal denominator the training
/// forward reuses to emit probability rows.
///
/// This is the **single** tile walk of the blocked family: prefill,
/// training forward and decode all call it, which is what makes the
/// decode-replays-prefill bit-identity structural rather than
/// maintained-by-hand.
fn stream_softmax_row(logits: &[f64], v: &Matrix, out: &mut [f64]) -> (f64, f64) {
    let len = logits.len();
    debug_assert!(len >= 1);
    debug_assert!(len <= v.rows());
    debug_assert_eq!(out.len(), v.cols());
    for slot in out.iter_mut() {
        *slot = 0.0;
    }
    let mut m = f64::NEG_INFINITY;
    let mut s = 0.0f64;
    let mut p = [0.0f64; BLOCK];
    let mut t0 = 0;
    while t0 < len {
        let w = BLOCK.min(len - t0);
        let tile = &logits[t0..t0 + w];
        let mut tile_max = f64::NEG_INFINITY;
        for &l in tile {
            tile_max = tile_max.max(l);
        }
        let m_new = m.max(tile_max);
        // exp(0) = 1 when the max did not move: the scale below is a
        // bitwise no-op on s and acc. First tile: exp(−∞ − finite) = 0.
        let corr = (m - m_new).exp();
        s *= corr;
        for slot in out.iter_mut() {
            *slot *= corr;
        }
        for (slot, &l) in p[..w].iter_mut().zip(tile) {
            *slot = (l - m_new).exp();
        }
        for &pj in &p[..w] {
            s += pj;
        }
        // The hot loop: acc += p · V-tile. Full tiles take the
        // fixed-width path (compile-time trip count ⇒ vectorized);
        // the ragged last tile runs the same ops over the prefix.
        if w == BLOCK {
            for (jj, &pj) in p.iter().enumerate() {
                let vrow = v.row(t0 + jj);
                for (slot, &x) in out.iter_mut().zip(vrow) {
                    *slot += pj * x;
                }
            }
        } else {
            for (jj, &pj) in p[..w].iter().enumerate() {
                let vrow = v.row(t0 + jj);
                for (slot, &x) in out.iter_mut().zip(vrow) {
                    *slot += pj * x;
                }
            }
        }
        m = m_new;
        t0 += w;
    }
    let inv = 1.0 / s;
    for slot in out.iter_mut() {
        *slot *= inv;
    }
    (m, inv)
}

/// One contiguous block of rows of the blocked causal forward; the
/// thread-split driver hands each worker a disjoint row range. Rows
/// are fully independent, so any split is bit-identical.
fn forward_rows(
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    rows: std::ops::Range<usize>,
    y_out: &mut [f64],
    mut probs_out: Option<&mut [f64]>,
) {
    let n = k.rows();
    let d_v = v.cols();
    let mut logits = vec![0.0; n];
    for (ri, i) in rows.enumerate() {
        let len = i + 1;
        causal_logits_row_into(q.row(i), k, &mut logits[..len]);
        let yrow = &mut y_out[ri * d_v..(ri + 1) * d_v];
        let (m, inv) = stream_softmax_row(&logits[..len], v, yrow);
        if let Some(p) = probs_out.as_deref_mut() {
            // Second per-row pass: the probability row from the same
            // logits scratch, normalized by the walk's (m, 1/s).
            let prow = &mut p[ri * n..ri * n + len];
            for (slot, &l) in prow.iter_mut().zip(&logits[..len]) {
                *slot = (l - m).exp() * inv;
            }
        }
    }
}

/// Shared driver of the two blocked forwards: computes `y` (and the
/// probability rows when `keep_probs`), splitting rows across scoped
/// threads once the causal work volume is large enough to amortize
/// spawn — the same policy `Matrix::matmul` applies.
fn blocked_forward(
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    keep_probs: bool,
) -> (Matrix, Option<Matrix>) {
    let n = q.rows();
    assert_eq!(k.rows(), n);
    assert_eq!(v.rows(), n);
    assert_eq!(q.cols(), k.cols());
    let d_v = v.cols();
    let mut y = Matrix::zeros(n, d_v);
    let mut probs = if keep_probs { Some(Matrix::zeros(n, n)) } else { None };
    // The causal prefix is half the dense volume.
    let work = n * n * (q.cols() + d_v) / 2;
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    if work < 96 * 96 * 96 || threads == 1 || n < 2 * threads {
        forward_rows(q, k, v, 0..n, y.data_mut(), probs.as_mut().map(|p| p.data_mut()));
        return (y, probs);
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|scope| {
        let mut y_rest: &mut [f64] = y.data_mut();
        let mut p_rest: Option<&mut [f64]> = probs.as_mut().map(|p| p.data_mut());
        let mut start = 0usize;
        while start < n {
            let end = (start + chunk).min(n);
            let (y_head, y_tail) = y_rest.split_at_mut((end - start) * d_v);
            y_rest = y_tail;
            let p_head = match p_rest.take() {
                Some(p) => {
                    let (head, tail) = p.split_at_mut((end - start) * n);
                    p_rest = Some(tail);
                    Some(head)
                }
                None => None,
            };
            let range = start..end;
            scope.spawn(move || forward_rows(q, k, v, range, y_head, p_head));
            start = end;
        }
    });
    (y, probs)
}

/// Blocked causal exact attention: `softmax(QKᵀ)·V` under the causal
/// mask via the online tile walk — one pass over the logits, no `n×n`
/// materialization, only the causal prefix computed (the row-streamed
/// [`exact_attention`](crate::attention::exact_attention) computes the
/// full `QKᵀ` product before masking). `q` arrives pre-scaled, exactly
/// as the engine's prefill jobs carry it.
pub fn blocked_attention_causal(q: &Matrix, k: &Matrix, v: &Matrix) -> Matrix {
    blocked_forward(q, k, v, false).0
}

/// Blocked **training** forward: `(y, probs)` where `probs` are the
/// dense causal softmax rows the exact LM backward consumes. Two
/// passes per row — the online walk for `y`, then a probability fill
/// from the same logits scratch — so peak scratch stays `O(n)` beyond
/// the `n×n` probs output itself. `y` is bit-identical to
/// [`blocked_attention_causal`] (same walk); `probs` match the
/// row-streamed `dense_causal_probs` within [`blocked_rtol`].
pub fn blocked_train_forward(q: &Matrix, k: &Matrix, v: &Matrix) -> (Matrix, Matrix) {
    let (y, probs) = blocked_forward(q, k, v, true);
    (y, probs.expect("keep_probs requested"))
}

/// Blocked exact last-row decode from the precomputed pre-exp logits
/// row (`new_row_of_h[j] = q_new · k_j`, causal, length `n`): replays
/// the exact tile walk of [`blocked_attention_causal`]'s row `n−1` at
/// sequence length `n` — same absolute tile grid, same float-op order
/// — so a blocked decode step **bit-matches** a blocked re-prefill
/// whenever the logit bits match (the model computes `new_row` in
/// `Matrix::matmul`'s accumulation order; see [`causal_logits_row`]).
pub fn blocked_decode_last_row(new_row_of_h: &[f64], v: &Matrix) -> Vec<f64> {
    let n = new_row_of_h.len();
    assert_eq!(v.rows(), n);
    let mut y = vec![0.0; v.cols()];
    stream_softmax_row(new_row_of_h, v, &mut y);
    y
}

/// Blocked exact attention backward: `(dQ, dK, dV)` from the forward's
/// probability rows, streaming each row's **causal prefix** in column
/// tiles:
///
/// ```text
/// dP = dout·Vᵀ            (prefix only)
/// dS = P ∘ (dP − rowdot(P, dP))
/// dQ = dS·K,  dK += dSᵀ·Q,  dV += Pᵀ·dout
/// ```
///
/// Two tile passes per row: pass 1 computes the `dP` prefix, the
/// Jacobian row-dot and the `dV` scatter while the tile's `V` rows are
/// hot; pass 2 forms `dS` and scatters into `dQ`/`dK`. Scratch is one
/// `n`-length `dP` row. The row-streamed
/// `attn_backward_exact` walks all `n` columns per row (its zero-skips
/// only short-circuit the scatters); restricting to the causal prefix
/// halves the flops. Matches the row-streamed kernel within
/// [`blocked_rtol`] (the Jacobian row-dot is re-associated).
pub fn attn_backward_blocked(
    probs: &Matrix,
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    dout: &Matrix,
) -> (Matrix, Matrix, Matrix) {
    let n = probs.rows();
    let dh = q.cols();
    assert_eq!(probs.cols(), n);
    assert_eq!(k.rows(), n);
    assert_eq!(v.rows(), n);
    assert_eq!(dout.rows(), n);
    let mut dq = Matrix::zeros(n, dh);
    let mut dk = Matrix::zeros(n, dh);
    let mut dv = Matrix::zeros(n, dh);
    let mut dp = vec![0.0; n];
    for i in 0..n {
        let prow = probs.row(i);
        let dorow = dout.row(i);
        let len = i + 1;
        // Pass 1: dP prefix, Jacobian dot, dV scatter — tile-local.
        let mut dot = 0.0;
        let mut t0 = 0;
        while t0 < len {
            let w = BLOCK.min(len - t0);
            for jj in 0..w {
                let j = t0 + jj;
                let pij = prow[j];
                let vrow = v.row(j);
                let mut acc = 0.0;
                for (&dc, &vc) in dorow.iter().zip(vrow) {
                    acc += dc * vc;
                }
                dp[j] = acc;
                dot += pij * acc;
                if pij != 0.0 {
                    for (slot, &dc) in dv.row_mut(j).iter_mut().zip(dorow) {
                        *slot += pij * dc;
                    }
                }
            }
            t0 += w;
        }
        // Pass 2: dS, scattered into dQ row i and the dK rows.
        let qrow = q.row(i);
        let dqrow = dq.row_mut(i);
        let mut t0 = 0;
        while t0 < len {
            let w = BLOCK.min(len - t0);
            for jj in 0..w {
                let j = t0 + jj;
                let ds = prow[j] * (dp[j] - dot);
                if ds == 0.0 {
                    continue;
                }
                let krow = k.row(j);
                for (slot, &kc) in dqrow.iter_mut().zip(krow) {
                    *slot += ds * kc;
                }
                for (slot, &qc) in dk.row_mut(j).iter_mut().zip(qrow) {
                    *slot += ds * qc;
                }
            }
            t0 += w;
        }
    }
    (dq, dk, dv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::{exact_attention, Mask};
    use crate::gradient::batched::{attn_backward_exact, dense_causal_probs};
    use crate::tensor::{max_abs_diff, Rng};

    fn inputs(n: usize, d: usize, seed: u64, scale: f64) -> (Matrix, Matrix, Matrix) {
        let mut rng = Rng::seeded(seed);
        let q = Matrix::randn(n, d, &mut rng).scale(scale);
        let k = Matrix::randn(n, d, &mut rng).scale(scale);
        let v = Matrix::randn(n, d, &mut rng);
        (q, k, v)
    }

    #[test]
    fn blocked_forward_matches_rowstream_oracle() {
        for &n in &[5usize, 16, 33, 50] {
            let (q, k, v) = inputs(n, 6, 40 + n as u64, 0.4);
            let blocked = blocked_attention_causal(&q, &k, &v);
            let oracle = exact_attention(&q, &k, &v, &Mask::causal(n));
            let v_inf = crate::tensor::linf_norm_mat(&v);
            let err = max_abs_diff(&blocked, &oracle);
            assert!(err <= blocked_rtol(n) * v_inf.max(1.0), "n={n}: err = {err}");
        }
    }

    #[test]
    fn blocked_decode_bitmatches_blocked_prefill_row() {
        let (n, d) = (37, 5);
        let (q, k, v) = inputs(n, d, 41, 0.4);
        let full = blocked_attention_causal(&q, &k, &v);
        for i in [0usize, 15, 16, 31, 32, n - 1] {
            let new_row = causal_logits_row(q.row(i), &k, i + 1);
            let vi = v.slice(0, i + 1, 0, d);
            let y = blocked_decode_last_row(&new_row, &vi);
            for (a, b) in y.iter().zip(full.row(i)) {
                assert_eq!(*a, *b, "row {i}: decode must replay the prefill walk");
            }
        }
    }

    #[test]
    fn blocked_train_forward_is_consistent() {
        let (n, d) = (33, 4);
        let (q, k, v) = inputs(n, d, 42, 0.4);
        let (y, probs) = blocked_train_forward(&q, &k, &v);
        // y is the same walk as the serving forward — bitwise.
        assert_eq!(max_abs_diff(&y, &blocked_attention_causal(&q, &k, &v)), 0.0);
        // probs rows are causal, normalized, and near the row-streamed
        // builder.
        let want = dense_causal_probs(&q, &k);
        let v_inf = 1.0; // probs entries are already ≤ 1
        assert!(max_abs_diff(&probs, &want) <= blocked_rtol(n) * v_inf);
        for i in 0..n {
            let s: f64 = probs.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-12, "row {i} sums to {s}");
            for &x in &probs.row(i)[i + 1..] {
                assert_eq!(x, 0.0, "probs must be causal");
            }
        }
    }

    #[test]
    fn blocked_backward_matches_rowstream_kernel() {
        let (n, d) = (33, 4);
        let (q, k, v) = inputs(n, d, 43, 0.4);
        let mut rng = Rng::seeded(44);
        let dout = Matrix::randn(n, d, &mut rng);
        let probs = dense_causal_probs(&q, &k);
        let (dq, dk, dv) = attn_backward_blocked(&probs, &q, &k, &v, &dout);
        let (dq_w, dk_w, dv_w) = attn_backward_exact(&probs, &q, &k, &v, &dout);
        let tol = blocked_rtol(n) * 16.0; // gradients are not convex combos
        assert!(max_abs_diff(&dq, &dq_w) <= tol);
        assert!(max_abs_diff(&dk, &dk_w) <= tol);
        assert!(max_abs_diff(&dv, &dv_w) <= tol);
    }

    #[test]
    fn blocked_survives_huge_logits() {
        // Logit magnitudes past exp's ±709 overflow threshold: the
        // online max subtraction must keep every row a finite convex
        // combination.
        let n = 24;
        let (q, k, _) = inputs(n, 4, 45, 20.0);
        let v = Matrix::ones(n, 4);
        let y = blocked_attention_causal(&q, &k, &v);
        assert!(y.is_finite());
        for i in 0..n {
            for &x in y.row(i) {
                assert!((x - 1.0).abs() < 1e-9, "row {i}: {x}");
            }
        }
    }

    #[test]
    fn threaded_split_is_bit_identical_to_serial() {
        // Above the work threshold the driver splits rows across
        // threads; rows are independent, so the split must be a
        // bitwise no-op. (n chosen to cross the matmul-style cutoff.)
        let (q, k, v) = inputs(192, 24, 46, 0.2);
        let threaded = blocked_attention_causal(&q, &k, &v);
        let mut serial = Matrix::zeros(192, 24);
        forward_rows(&q, &k, &v, 0..192, serial.data_mut(), None);
        assert_eq!(max_abs_diff(&threaded, &serial), 0.0);
    }
}
