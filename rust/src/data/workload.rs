//! Serving workload traces: Poisson-ish arrivals with mixed sequence
//! lengths — the input to the L3 coordinator benches (the paper's
//! motivating long-context inference scenario; no production trace is
//! public, so we synthesize one — DESIGN.md substitution log).

use crate::tensor::Rng;

/// One inference request in a trace.
#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    pub id: u64,
    /// Arrival time in microseconds from trace start.
    pub arrival_us: u64,
    /// Sequence length of the prompt.
    pub seq_len: usize,
    /// Hidden dim of the attention call (model-dependent; carried so
    /// mixed-model traces are expressible).
    pub d_model: usize,
}

/// Trace generator configuration.
#[derive(Clone, Copy, Debug)]
pub struct WorkloadConfig {
    /// Mean arrival rate, requests per second.
    pub rate_per_s: f64,
    /// Sequence-length buckets (sampled with `len_weights`).
    pub len_buckets: [usize; 4],
    /// Relative weights of the buckets.
    pub len_weights: [f64; 4],
    pub d_model: usize,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            rate_per_s: 200.0,
            len_buckets: [128, 256, 512, 1024],
            len_weights: [0.4, 0.3, 0.2, 0.1],
            d_model: 64,
        }
    }
}

/// A deterministic synthetic request trace.
#[derive(Clone, Debug)]
pub struct WorkloadTrace {
    pub requests: Vec<Request>,
}

impl WorkloadTrace {
    /// Generate `n` requests with exponential inter-arrivals.
    pub fn generate(n: usize, cfg: &WorkloadConfig, seed: u64) -> Self {
        let mut rng = Rng::seeded(seed);
        let mut t_us = 0u64;
        let mean_gap_us = 1e6 / cfg.rate_per_s;
        let total_w: f64 = cfg.len_weights.iter().sum();
        let mut requests = Vec::with_capacity(n);
        for id in 0..n as u64 {
            // Exponential inter-arrival via inverse CDF.
            let u = rng.uniform().max(1e-12);
            t_us += (-u.ln() * mean_gap_us) as u64;
            // Weighted bucket choice.
            let mut pick = rng.uniform() * total_w;
            let mut seq_len = cfg.len_buckets[3];
            for (b, &w) in cfg.len_weights.iter().enumerate() {
                if pick < w {
                    seq_len = cfg.len_buckets[b];
                    break;
                }
                pick -= w;
            }
            requests.push(Request { id, arrival_us: t_us, seq_len, d_model: cfg.d_model });
        }
        WorkloadTrace { requests }
    }

    /// Aggregate statistics (mean len, span).
    pub fn stats(&self) -> (f64, u64) {
        let mean_len = self.requests.iter().map(|r| r.seq_len as f64).sum::<f64>()
            / self.requests.len().max(1) as f64;
        let span = self.requests.last().map(|r| r.arrival_us).unwrap_or(0);
        (mean_len, span)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_deterministic_and_sorted() {
        let cfg = WorkloadConfig::default();
        let a = WorkloadTrace::generate(100, &cfg, 1);
        let b = WorkloadTrace::generate(100, &cfg, 1);
        assert_eq!(a.requests, b.requests);
        assert!(a.requests.windows(2).all(|w| w[0].arrival_us <= w[1].arrival_us));
    }

    #[test]
    fn lengths_come_from_buckets() {
        let cfg = WorkloadConfig::default();
        let t = WorkloadTrace::generate(200, &cfg, 2);
        for r in &t.requests {
            assert!(cfg.len_buckets.contains(&r.seq_len));
        }
    }

    #[test]
    fn rate_is_roughly_respected() {
        let cfg = WorkloadConfig { rate_per_s: 1000.0, ..Default::default() };
        let t = WorkloadTrace::generate(2000, &cfg, 3);
        let (_, span_us) = t.stats();
        let observed_rate = 2000.0 / (span_us as f64 / 1e6);
        assert!((observed_rate - 1000.0).abs() / 1000.0 < 0.2, "rate = {observed_rate}");
    }
}
