//! Data substrate: byte-level tokenizer, deterministic synthetic
//! corpora, the synthetic sentiment task standing in for IMDB
//! (Figure 4 — see DESIGN.md substitution log), and serving workload
//! traces for the coordinator benches.

mod sentiment;
mod tokenizer;
mod workload;

pub use sentiment::{SentimentDataset, SentimentExample};
pub use tokenizer::ByteTokenizer;
pub use workload::{Request, WorkloadTrace, WorkloadConfig};

use crate::tensor::Rng;

/// A deterministic tiny language corpus: templated English-like
/// sentences with long-range repetition (so attention matrices develop
/// the induction-head / conv-like structure the paper banks on).
pub struct SyntheticCorpus {
    text: String,
}

const SUBJECTS: &[&str] = &[
    "the model", "the system", "a transformer", "the kernel", "the scheduler", "our method",
    "the baseline", "the router",
];
const VERBS: &[&str] =
    &["computes", "approximates", "accelerates", "decomposes", "normalizes", "batches", "routes"];
const OBJECTS: &[&str] = &[
    "the attention matrix",
    "a convolution basis",
    "the gradient",
    "long sequences",
    "the softmax",
    "every request",
    "the key cache",
];
const TAILS: &[&str] = &[
    "in almost linear time",
    "with bounded error",
    "via fast fourier transforms",
    "under a causal mask",
    "without retraining",
    "at every layer",
];

impl SyntheticCorpus {
    /// Generate ~`target_bytes` of text, deterministically from `seed`.
    pub fn generate(target_bytes: usize, seed: u64) -> Self {
        let mut rng = Rng::seeded(seed);
        let mut text = String::with_capacity(target_bytes + 128);
        while text.len() < target_bytes {
            let s = *rng.choose(SUBJECTS);
            let v = *rng.choose(VERBS);
            let o = *rng.choose(OBJECTS);
            let t = *rng.choose(TAILS);
            text.push_str(s);
            text.push(' ');
            text.push_str(v);
            text.push(' ');
            text.push_str(o);
            text.push(' ');
            text.push_str(t);
            text.push_str(". ");
            // Occasionally repeat the previous sentence verbatim —
            // induction-head bait.
            if rng.uniform() < 0.25 && text.len() > 120 {
                let tail_start = text.len().saturating_sub(60);
                // Find a sentence boundary to copy from.
                if let Some(pos) = text[..tail_start].rfind(". ") {
                    let copy = text[pos + 2..tail_start].to_string();
                    text.push_str(&copy);
                }
            }
        }
        text.truncate(target_bytes);
        SyntheticCorpus { text }
    }

    pub fn text(&self) -> &str {
        &self.text
    }

    /// Token stream under the byte tokenizer.
    pub fn tokens(&self, tok: &ByteTokenizer) -> Vec<usize> {
        tok.encode(&self.text)
    }

    /// Contiguous (input, target) training windows of length `seq_len`.
    pub fn windows(&self, tok: &ByteTokenizer, seq_len: usize) -> Vec<(Vec<usize>, Vec<usize>)> {
        let toks = self.tokens(tok);
        let mut out = Vec::new();
        let mut start = 0;
        while start + seq_len + 1 <= toks.len() {
            let x = toks[start..start + seq_len].to_vec();
            let y = toks[start + 1..start + seq_len + 1].to_vec();
            out.push((x, y));
            start += seq_len;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_is_deterministic() {
        let a = SyntheticCorpus::generate(1000, 7);
        let b = SyntheticCorpus::generate(1000, 7);
        assert_eq!(a.text(), b.text());
        let c = SyntheticCorpus::generate(1000, 8);
        assert_ne!(a.text(), c.text());
    }

    #[test]
    fn corpus_has_requested_size() {
        let c = SyntheticCorpus::generate(5000, 1);
        assert_eq!(c.text().len(), 5000);
    }

    #[test]
    fn windows_cover_corpus() {
        let c = SyntheticCorpus::generate(2000, 2);
        let tok = ByteTokenizer::new();
        let w = c.windows(&tok, 64);
        assert!(w.len() >= 30);
        for (x, y) in &w {
            assert_eq!(x.len(), 64);
            assert_eq!(y.len(), 64);
            // Targets are inputs shifted by one.
            assert_eq!(&x[1..], &y[..63]);
        }
    }

    #[test]
    fn corpus_contains_repetitions() {
        let c = SyntheticCorpus::generate(20_000, 3);
        // Induction bait: at least one sentence should appear twice.
        let sentences: Vec<&str> = c.text().split(". ").collect();
        let mut seen = std::collections::HashSet::new();
        let mut dup = false;
        for s in sentences {
            if s.len() > 10 && !seen.insert(s) {
                dup = true;
                break;
            }
        }
        assert!(dup, "no repeated sentences found");
    }
}
