//! Byte-level tokenizer: 256 byte tokens + BOS/EOS/PAD/CLS specials.
//! Deterministic, lossless, zero-config — the right substrate for a
//! reproduction where no pretrained vocabulary exists.

/// Byte-level tokenizer with four special tokens.
#[derive(Clone, Debug, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub const PAD: usize = 256;
    pub const BOS: usize = 257;
    pub const EOS: usize = 258;
    /// Classification token appended for sentence-level tasks.
    pub const CLS: usize = 259;

    pub fn new() -> Self {
        ByteTokenizer
    }

    /// Vocabulary size (bytes + specials).
    pub fn vocab_size(&self) -> usize {
        260
    }

    /// Encode UTF-8 text to token ids (raw bytes; no specials added).
    pub fn encode(&self, text: &str) -> Vec<usize> {
        text.bytes().map(|b| b as usize).collect()
    }

    /// Encode with BOS … EOS framing.
    pub fn encode_framed(&self, text: &str) -> Vec<usize> {
        let mut out = Vec::with_capacity(text.len() + 2);
        out.push(Self::BOS);
        out.extend(text.bytes().map(|b| b as usize));
        out.push(Self::EOS);
        out
    }

    /// Encode for classification: BOS … text … CLS, truncated / padded
    /// to exactly `len` (pad inserted before CLS so CLS stays last).
    pub fn encode_for_classification(&self, text: &str, len: usize) -> Vec<usize> {
        assert!(len >= 3);
        let body_budget = len - 2;
        let mut body: Vec<usize> = text.bytes().map(|b| b as usize).collect();
        body.truncate(body_budget);
        let mut out = Vec::with_capacity(len);
        out.push(Self::BOS);
        out.extend_from_slice(&body);
        while out.len() < len - 1 {
            out.push(Self::PAD);
        }
        out.push(Self::CLS);
        out
    }

    /// Decode token ids back to text (specials dropped; invalid bytes
    /// replaced).
    pub fn decode(&self, ids: &[usize]) -> String {
        let bytes: Vec<u8> = ids.iter().filter(|&&t| t < 256).map(|&t| t as u8).collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let tok = ByteTokenizer::new();
        let text = "conv basis attention!";
        assert_eq!(tok.decode(&tok.encode(text)), text);
    }

    #[test]
    fn framed_has_specials() {
        let tok = ByteTokenizer::new();
        let ids = tok.encode_framed("ab");
        assert_eq!(ids, vec![ByteTokenizer::BOS, 97, 98, ByteTokenizer::EOS]);
    }

    #[test]
    fn classification_encoding_is_fixed_length() {
        let tok = ByteTokenizer::new();
        for text in ["short", &"x".repeat(500)] {
            let ids = tok.encode_for_classification(text, 32);
            assert_eq!(ids.len(), 32);
            assert_eq!(ids[0], ByteTokenizer::BOS);
            assert_eq!(*ids.last().unwrap(), ByteTokenizer::CLS);
        }
    }

    #[test]
    fn decode_skips_specials() {
        let tok = ByteTokenizer::new();
        let ids = vec![ByteTokenizer::BOS, 104, 105, ByteTokenizer::PAD, ByteTokenizer::CLS];
        assert_eq!(tok.decode(&ids), "hi");
    }

    #[test]
    fn vocab_covers_all_ids() {
        let tok = ByteTokenizer::new();
        assert!(ByteTokenizer::CLS < tok.vocab_size());
    }
}
