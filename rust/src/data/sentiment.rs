//! Synthetic sentiment task — the stand-in for IMDB in Figure 4
//! (substitution documented in DESIGN.md).
//!
//! Templated movie reviews with unambiguous polarity words, plus
//! distractor clauses so the classifier must actually attend. Labels
//! are balanced and the train/test split is deterministic.

use crate::tensor::Rng;

const POS_OPENERS: &[&str] = &[
    "an absolute triumph",
    "a stunning achievement",
    "a delightful surprise",
    "a masterful film",
    "pure joy from start to finish",
    "a brilliant and moving picture",
];
const NEG_OPENERS: &[&str] = &[
    "a complete disaster",
    "a tedious slog",
    "an incoherent mess",
    "a painful waste of time",
    "utterly forgettable",
    "a dull and lifeless film",
];
const POS_BODIES: &[&str] = &[
    "the acting was superb and the pacing perfect",
    "every scene sparkled with wit and warmth",
    "i was captivated by the gorgeous cinematography",
    "the script crackles and the score soars",
];
const NEG_BODIES: &[&str] = &[
    "the acting was wooden and the pacing glacial",
    "every scene dragged without purpose",
    "i was bored by the muddy cinematography",
    "the script clunks and the score grates",
];
const NEUTRAL: &[&str] = &[
    "the film runs just over two hours",
    "it was shot on location last spring",
    "the cast includes several newcomers",
    "the director previously worked in television",
];

/// One labelled review.
#[derive(Clone, Debug, PartialEq)]
pub struct SentimentExample {
    pub text: String,
    /// `true` = positive.
    pub label: bool,
}

/// A balanced, deterministic sentiment dataset with a train/test split.
#[derive(Clone, Debug)]
pub struct SentimentDataset {
    pub train: Vec<SentimentExample>,
    pub test: Vec<SentimentExample>,
}

impl SentimentDataset {
    /// Generate `n_train + n_test` balanced examples from `seed`.
    pub fn generate(n_train: usize, n_test: usize, seed: u64) -> Self {
        let mut rng = Rng::seeded(seed);
        let total = n_train + n_test;
        let mut examples = Vec::with_capacity(total);
        for i in 0..total {
            let label = i % 2 == 0;
            examples.push(Self::make_example(label, &mut rng));
        }
        rng.shuffle(&mut examples);
        let test = examples.split_off(n_train);
        SentimentDataset { train: examples, test }
    }

    fn make_example(label: bool, rng: &mut Rng) -> SentimentExample {
        let (openers, bodies) = if label {
            (POS_OPENERS, POS_BODIES)
        } else {
            (NEG_OPENERS, NEG_BODIES)
        };
        let mut text = String::new();
        // Distractor-first half the time: polarity evidence is not
        // always in a fixed position.
        if rng.uniform() < 0.5 {
            text.push_str(*rng.choose(NEUTRAL));
            text.push_str(". ");
        }
        text.push_str(*rng.choose(openers));
        text.push_str(". ");
        text.push_str(*rng.choose(bodies));
        text.push_str(". ");
        if rng.uniform() < 0.5 {
            text.push_str(*rng.choose(NEUTRAL));
            text.push('.');
        }
        SentimentExample { text, label }
    }

    /// The paper's evaluation protocol (Section 7): "5 sample groups,
    /// 200 samples per group" — deterministic grouping of the test set.
    pub fn test_groups(&self, groups: usize) -> Vec<&[SentimentExample]> {
        let per = self.test.len() / groups;
        (0..groups).map(|g| &self.test[g * per..(g + 1) * per]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_balanced() {
        let a = SentimentDataset::generate(100, 40, 5);
        let b = SentimentDataset::generate(100, 40, 5);
        assert_eq!(a.train, b.train);
        let pos = a.train.iter().filter(|e| e.label).count()
            + a.test.iter().filter(|e| e.label).count();
        assert_eq!(pos, 70);
    }

    #[test]
    fn polarity_words_match_labels() {
        let ds = SentimentDataset::generate(50, 10, 6);
        for e in ds.train.iter().chain(&ds.test) {
            let has_pos = POS_OPENERS.iter().any(|w| e.text.contains(w));
            let has_neg = NEG_OPENERS.iter().any(|w| e.text.contains(w));
            assert_eq!(has_pos, e.label);
            assert_eq!(has_neg, !e.label);
        }
    }

    #[test]
    fn groups_partition_test_set() {
        let ds = SentimentDataset::generate(10, 100, 7);
        let groups = ds.test_groups(5);
        assert_eq!(groups.len(), 5);
        assert!(groups.iter().all(|g| g.len() == 20));
    }
}
