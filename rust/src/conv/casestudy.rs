//! Appendix B.5 case-study constructions: explicit `Q, K` families
//! whose `exp(QKᵀ)` is exactly circulant (Lemma B.26) or Toeplitz
//! (Lemmas B.27 / B.30), verified constructively. These are the
//! paper's bridge from RoPE-style embeddings to the conv-basis theory —
//! and the generators behind [`crate::attention::rope::rope_structured_qk`].

use super::{Circulant, Toeplitz};
use crate::tensor::{Matrix, Rng};

/// Lemma B.26 setup: build `Q, K ∈ R^{n×d}` (d = n here, via an
/// explicit factorization) such that `(QKᵀ)[i][j] = b[(i−j) mod n]`,
/// i.e. `QKᵀ = Circ(b)`. Returns `(Q, K)`.
///
/// Construction: `Circ(b)` itself factored as `Q = Circ(b)`, `K = I` —
/// the lemma only requires the *pattern*, not minimal d.
pub fn circulant_qk(b: &[f64]) -> (Matrix, Matrix) {
    let n = b.len();
    let q = Circulant::new(b.to_vec()).to_dense();
    (q, Matrix::eye(n))
}

/// Lemma B.26: with `(QKᵀ)[i][j] = b[(i−j) mod n]`,
/// `exp(QKᵀ) = Circ(exp(b))`.
pub fn lemma_b26_exp_is_circulant(b: &[f64]) -> (Matrix, Circulant) {
    let (q, k) = circulant_qk(b);
    let exp_qk = q.matmul(&k.transpose()).map(f64::exp);
    let circ = Circulant::new(b.iter().map(|x| x.exp()).collect());
    (exp_qk, circ)
}

/// Lemma B.27 setup: `(QKᵀ)[i][j] = b[i−j]` for a length-(2n−1)
/// generator (indexed −(n−1)..(n−1)) — `QKᵀ = Toep(b)`.
pub fn toeplitz_qk(n: usize, diag: &[f64]) -> (Matrix, Matrix) {
    assert_eq!(diag.len(), 2 * n - 1);
    let q = Toeplitz::new(n, diag.to_vec()).to_dense();
    (q, Matrix::eye(n))
}

/// Lemma B.27: `exp(QKᵀ) = Toep(exp(b))`.
pub fn lemma_b27_exp_is_toeplitz(n: usize, diag: &[f64]) -> (Matrix, Toeplitz) {
    let (q, k) = toeplitz_qk(n, diag);
    let exp_qk = q.matmul(&k.transpose()).map(f64::exp);
    let toep = Toeplitz::new(n, diag.iter().map(|x| x.exp()).collect());
    (exp_qk, toep)
}

/// Lemma B.30 / Assumption B.28: `W_Q W_Kᵀ` PSD with `Z = X·A` rows
/// satisfying the Lemma B.25 rotation structure ⇒ `QKᵀ = ZZᵀ` Toeplitz.
/// Returns `(Z, generator g)` with `(ZZᵀ)[i][j] = g[i−j + (n−1)]`.
pub fn lemma_b30_psd_construction(n: usize, d: usize, rng: &mut Rng) -> (Matrix, Vec<f64>) {
    let (z, _) = crate::attention::rope::rope_structured_qk(n, d, (d / 2).clamp(1, 3), rng);
    let gram = z.matmul(&z.transpose());
    // Extract the generator from the first column/row.
    let mut g = vec![0.0; 2 * n - 1];
    for i in 0..n {
        g[n - 1 + i] = gram[(i, 0)]; // offsets 0..n−1
        g[n - 1 - i] = gram[(0, i)]; // offsets −(n−1)..0
    }
    (z, g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::max_abs_diff;

    #[test]
    fn lemma_b26_holds() {
        let mut rng = Rng::seeded(601);
        let b = rng.randn_vec(12);
        let (exp_qk, circ) = lemma_b26_exp_is_circulant(&b);
        assert!(max_abs_diff(&exp_qk, &circ.to_dense()) < 1e-10);
    }

    #[test]
    fn lemma_b27_holds() {
        let mut rng = Rng::seeded(602);
        let n = 9;
        let diag: Vec<f64> = rng.randn_vec(2 * n - 1).iter().map(|x| x * 0.5).collect();
        let (exp_qk, toep) = lemma_b27_exp_is_toeplitz(n, &diag);
        assert!(max_abs_diff(&exp_qk, &toep.to_dense()) < 1e-10);
    }

    #[test]
    fn lemma_b30_gram_is_toeplitz() {
        let mut rng = Rng::seeded(603);
        let (z, g) = lemma_b30_psd_construction(16, 6, &mut rng);
        let gram = z.matmul(&z.transpose());
        for i in 0..16 {
            for j in 0..16 {
                let want = g[(i as isize - j as isize + 15) as usize];
                assert!((gram[(i, j)] - want).abs() < 1e-10, "({i},{j})");
            }
        }
    }

    #[test]
    fn b26_circulant_attention_is_1conv_after_masking() {
        // The masked pre-softmax matrix M ∘ Circ(b) decomposes into at
        // most 2 conv bases (the wrap-around splits once).
        let mut rng = Rng::seeded(604);
        let b = rng.randn_vec(10);
        let (q, k) = circulant_qk(&b);
        let masked = crate::attention::Mask::causal(10).apply(&q.matmul(&k.transpose()));
        let basis = crate::basis::decompose_exact(&masked, 1e-10);
        assert!(basis.k() <= 1, "masked circulant is pure conv: k = {}", basis.k());
    }

    #[test]
    fn b27_toeplitz_attention_exact_with_k1() {
        // Theorem 4.4 end-to-end on the Lemma B.27 family.
        let mut rng = Rng::seeded(605);
        let n = 24;
        let diag: Vec<f64> = rng.randn_vec(2 * n - 1).iter().map(|x| x * 0.3).collect();
        let (q, k) = toeplitz_qk(n, &diag);
        let v = Matrix::randn(n, n, &mut rng);
        let exact =
            crate::attention::exact_attention(&q, &k, &v, &crate::attention::Mask::causal(n));
        let out = crate::attention::conv_attention_strided(&q, &k, &v, 1).unwrap();
        assert!(max_abs_diff(&exact, &out.y) < 1e-9);
    }
}
