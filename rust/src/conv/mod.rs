//! Structured matrices of §3 / Appendix B.1: convolution matrices
//! `conv(a)` (Definition 3.5), sub-convolution matrices `conv(a, m)`
//! (Definition 3.9), Toeplitz (Definition B.2) and circulant
//! (Definition B.3) matrices, together with their FFT-backed multiplies
//! (Claims 3.7 / 3.10, Facts B.7 / B.8).
//!
//! A convolution matrix is stored as its defining length-n vector: the
//! paper's memory story (Appendix A: `O(kn + nd)` total) depends on never
//! materializing the `n×n` form on the hot path. Dense materialization
//! exists (`to_dense`) for oracles and tests only.

use crate::fft::{linear_convolution, FftPlanner};
use crate::tensor::Matrix;

pub mod casestudy;
mod toeplitz;

pub use toeplitz::{fact_b7_embedding, Circulant, Resi, Toeplitz};

/// `conv(a)`: lower-triangular convolution matrix of `a ∈ Rⁿ`
/// (Definition 3.5). `conv(a)[i][j] = a[i−j]` for `i ≥ j` (0-indexed).
#[derive(Clone, Debug, PartialEq)]
pub struct ConvMatrix {
    a: Vec<f64>,
}

impl ConvMatrix {
    pub fn new(a: Vec<f64>) -> Self {
        ConvMatrix { a }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.a.len()
    }

    #[inline]
    pub fn vector(&self) -> &[f64] {
        &self.a
    }

    /// Dense `n×n` materialization (tests/oracles only).
    pub fn to_dense(&self) -> Matrix {
        let n = self.n();
        Matrix::from_fn(n, n, |i, j| if i >= j { self.a[i - j] } else { 0.0 })
    }

    /// `conv(a)·x` via FFT — Claim 3.7, `O(n log n)`.
    pub fn apply(&self, planner: &mut FftPlanner, x: &[f64]) -> Vec<f64> {
        conv_apply(planner, &self.a, x)
    }

    /// `conv(a)·x` naively — the `O(n²)` baseline of Figure 1a.
    pub fn apply_naive(&self, x: &[f64]) -> Vec<f64> {
        conv_apply_naive(&self.a, x)
    }

    /// Rank of `conv(e_j)` is `j` (1-indexed) — Claim 3.6. For a general
    /// vector the rank is `n − z` where the first non-zero entry of `a`
    /// is at index `z` (0-indexed); returns `0` for the zero vector.
    pub fn rank(&self) -> usize {
        match self.a.iter().position(|&v| v != 0.0) {
            Some(z) => self.n() - z,
            None => 0,
        }
    }
}

/// `conv(a)·x` via FFT (free-function form used by the hot path).
///
/// `out[i] = Σ_{j ≤ i} a[i−j]·x[j]` — the first n coefficients of the
/// linear convolution `a * x`.
pub fn conv_apply(planner: &mut FftPlanner, a: &[f64], x: &[f64]) -> Vec<f64> {
    assert_eq!(a.len(), x.len());
    let n = a.len();
    if n == 0 {
        return vec![];
    }
    let mut full = linear_convolution(planner, a, x);
    full.truncate(n);
    full
}

/// Naive `O(n²)` `conv(a)·x` — oracle + Figure 1a baseline.
pub fn conv_apply_naive(a: &[f64], x: &[f64]) -> Vec<f64> {
    assert_eq!(a.len(), x.len());
    let n = a.len();
    let mut out = vec![0.0; n];
    for i in 0..n {
        let mut s = 0.0;
        for j in 0..=i {
            s += a[i - j] * x[j];
        }
        out[i] = s;
    }
    out
}

/// Sub-convolution matrix `conv(a, m)` (Definition 3.9): `conv(a_{1:m})`
/// in the bottom-right `m×m` block, zero elsewhere.
#[derive(Clone, Debug, PartialEq)]
pub struct SubConvMatrix {
    /// The defining vector (only the first `m` entries participate).
    a: Vec<f64>,
    /// Window size `m ∈ [n]`.
    m: usize,
}

impl SubConvMatrix {
    pub fn new(a: Vec<f64>, m: usize) -> Self {
        assert!(m >= 1 && m <= a.len(), "m must be in [1, n], got m={m} n={}", a.len());
        SubConvMatrix { a, m }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.a.len()
    }

    #[inline]
    pub fn m(&self) -> usize {
        self.m
    }

    #[inline]
    pub fn vector(&self) -> &[f64] {
        &self.a
    }

    /// Entry `(i, j)` (0-indexed): non-zero iff `j ≥ n−m` and `i ≥ j`,
    /// value `a[i−j]`.
    #[inline]
    pub fn entry(&self, i: usize, j: usize) -> f64 {
        let n = self.n();
        if j >= n - self.m && i >= j {
            self.a[i - j]
        } else {
            0.0
        }
    }

    /// Dense materialization (tests/oracles only).
    pub fn to_dense(&self) -> Matrix {
        let n = self.n();
        Matrix::from_fn(n, n, |i, j| self.entry(i, j))
    }

    /// `conv(a, m)·x` via FFT — Claim 3.10, `O(n log n)` (actually
    /// `O(m log m)`: only the active block convolves).
    pub fn apply(&self, planner: &mut FftPlanner, x: &[f64]) -> Vec<f64> {
        sub_conv_apply(planner, &self.a, self.m, x)
    }

    /// Naive `O(m²)` apply (oracle).
    pub fn apply_naive(&self, x: &[f64]) -> Vec<f64> {
        let n = self.n();
        assert_eq!(x.len(), n);
        let mut out = vec![0.0; n];
        let off = n - self.m;
        for i in 0..self.m {
            let mut s = 0.0;
            for j in 0..=i {
                s += self.a[i - j] * x[off + j];
            }
            out[off + i] = s;
        }
        out
    }
}

/// `conv(a, m)·x` via FFT (free-function form; hot path).
///
/// Convolves `a[0..m]` with `x[n−m..n]` and writes the first `m`
/// coefficients into the last `m` slots of the output.
pub fn sub_conv_apply(planner: &mut FftPlanner, a: &[f64], m: usize, x: &[f64]) -> Vec<f64> {
    let n = x.len();
    assert!(m >= 1 && m <= n && a.len() >= m);
    let mut out = vec![0.0; n];
    sub_conv_apply_into(planner, a, m, x, &mut out);
    out
}

/// Accumulating variant: `out[n−m+i] += (conv(a,m)·x)[n−m+i]`.
///
/// The k-conv apply `Σ_r conv(b_r, m_r)·x` calls this once per basis,
/// reusing one output buffer — no per-basis allocation.
pub fn sub_conv_apply_into(
    planner: &mut FftPlanner,
    a: &[f64],
    m: usize,
    x: &[f64],
    out: &mut [f64],
) {
    let n = x.len();
    assert!(m >= 1 && m <= n && a.len() >= m && out.len() == n);
    let off = n - m;
    let full = linear_convolution(planner, &a[..m], &x[off..]);
    for i in 0..m {
        out[off + i] += full[i];
    }
}

/// `conv(a, m)ᵀ·x` via FFT — the transpose of the sub-convolution
/// matrix, `O(m log m)` like the forward apply.
///
/// `(conv(a,m)ᵀ·x)_j = Σ_{i ≥ j} a[i−j]·x_i` for `j ≥ n−m` (zero
/// elsewhere): a cross-correlation, computed as the **reversed**
/// convolution of `a[0..m]` with the reversed tail of `x`, so it hits
/// the same FFT plan lengths as [`sub_conv_apply`]. The LM attention
/// backward needs this operator (`dV = fᵀ·(…)`, `dK = dSᵀ·Q`) — the
/// conv structure survives transposition, which is what keeps the
/// backward almost-linear.
pub fn sub_conv_transpose_apply(
    planner: &mut FftPlanner,
    a: &[f64],
    m: usize,
    x: &[f64],
) -> Vec<f64> {
    let n = x.len();
    assert!(m >= 1 && m <= n && a.len() >= m);
    let mut out = vec![0.0; n];
    sub_conv_transpose_apply_into(planner, a, m, x, &mut out);
    out
}

/// Accumulating variant: `out[n−m+j] += (conv(a,m)ᵀ·x)[n−m+j]` — the
/// transpose mirror of [`sub_conv_apply_into`], one call per basis term
/// of a k-conv transpose apply.
pub fn sub_conv_transpose_apply_into(
    planner: &mut FftPlanner,
    a: &[f64],
    m: usize,
    x: &[f64],
    out: &mut [f64],
) {
    let n = x.len();
    assert!(m >= 1 && m <= n && a.len() >= m && out.len() == n);
    let off = n - m;
    // rev(conv(a, rev(x_tail)))[j] = Σ_{i ≥ j} a[i−j]·x_tail[i]: the
    // convolution coefficient at index m−1−j collects exactly the
    // correlation terms of output position j.
    let rev_tail: Vec<f64> = x[off..].iter().rev().copied().collect();
    let full = linear_convolution(planner, &a[..m], &rev_tail);
    for j in 0..m {
        out[off + j] += full[m - 1 - j];
    }
}

/// Claim 3.8: conv is additive — `conv(a)x + conv(b)x = conv(a+b)x`.
/// (Provided as a named helper so property tests read like the claim.)
pub fn conv_additivity_lhs(planner: &mut FftPlanner, a: &[f64], b: &[f64], x: &[f64]) -> Vec<f64> {
    let ya = conv_apply(planner, a, x);
    let yb = conv_apply(planner, b, x);
    ya.iter().zip(&yb).map(|(p, q)| p + q).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn conv_matrix_layout_matches_definition_3_5() {
        // Definition 3.5 example for n = 4.
        let c = ConvMatrix::new(vec![1.0, 2.0, 3.0, 4.0]);
        let d = c.to_dense();
        let expect = Matrix::from_vec(
            4,
            4,
            vec![
                1.0, 0.0, 0.0, 0.0, //
                2.0, 1.0, 0.0, 0.0, //
                3.0, 2.0, 1.0, 0.0, //
                4.0, 3.0, 2.0, 1.0,
            ],
        );
        assert_eq!(d, expect);
    }

    #[test]
    fn fft_apply_matches_naive() {
        let mut p = FftPlanner::new();
        let mut rng = Rng::seeded(41);
        for &n in &[1usize, 2, 7, 16, 47, 128] {
            let a = rng.randn_vec(n);
            let x = rng.randn_vec(n);
            let fast = conv_apply(&mut p, &a, &x);
            let naive = conv_apply_naive(&a, &x);
            for (u, v) in fast.iter().zip(&naive) {
                assert!((u - v).abs() < 1e-8, "n={n}");
            }
        }
    }

    #[test]
    fn fft_apply_matches_dense_matvec() {
        let mut p = FftPlanner::new();
        let mut rng = Rng::seeded(42);
        let n = 33;
        let a = rng.randn_vec(n);
        let x = rng.randn_vec(n);
        let c = ConvMatrix::new(a.clone());
        let dense = c.to_dense().matvec(&x);
        let fast = c.apply(&mut p, &x);
        for (u, v) in fast.iter().zip(&dense) {
            assert!((u - v).abs() < 1e-8);
        }
    }

    #[test]
    fn sub_conv_layout_matches_definition_3_9() {
        // n = 5, m = 3: bottom-right 3×3 block is conv(a_{1:3}).
        let s = SubConvMatrix::new(vec![1.0, 2.0, 3.0, 9.0, 9.0], 3);
        let d = s.to_dense();
        for i in 0..5 {
            for j in 0..5 {
                let expect = if j >= 2 && i >= j { [1.0, 2.0, 3.0][i - j] } else { 0.0 };
                assert_eq!(d[(i, j)], expect, "({i},{j})");
            }
        }
    }

    #[test]
    fn sub_conv_apply_matches_dense() {
        let mut p = FftPlanner::new();
        let mut rng = Rng::seeded(43);
        for &(n, m) in &[(5usize, 3usize), (8, 8), (16, 1), (47, 20), (64, 33)] {
            let a = rng.randn_vec(n);
            let x = rng.randn_vec(n);
            let s = SubConvMatrix::new(a, m);
            let dense = s.to_dense().matvec(&x);
            let fast = s.apply(&mut p, &x);
            let naive = s.apply_naive(&x);
            for i in 0..n {
                assert!((fast[i] - dense[i]).abs() < 1e-8, "n={n} m={m} i={i}");
                assert!((naive[i] - dense[i]).abs() < 1e-10, "n={n} m={m} i={i}");
            }
        }
    }

    #[test]
    fn sub_conv_transpose_apply_matches_dense_transpose() {
        let mut p = FftPlanner::new();
        let mut rng = Rng::seeded(46);
        for &(n, m) in &[(5usize, 3usize), (8, 8), (16, 1), (47, 20), (64, 33)] {
            let a = rng.randn_vec(n);
            let x = rng.randn_vec(n);
            let s = SubConvMatrix::new(a.clone(), m);
            let dense = s.to_dense().transpose().matvec(&x);
            let fast = sub_conv_transpose_apply(&mut p, &a, m, &x);
            for i in 0..n {
                assert!((fast[i] - dense[i]).abs() < 1e-8, "n={n} m={m} i={i}");
            }
            // Leading n−m coordinates are structurally zero.
            for (i, v) in fast.iter().enumerate().take(n - m) {
                assert_eq!(*v, 0.0, "leading zero at {i}");
            }
        }
    }

    #[test]
    fn full_window_sub_conv_equals_conv() {
        let mut p = FftPlanner::new();
        let mut rng = Rng::seeded(44);
        let n = 19;
        let a = rng.randn_vec(n);
        let x = rng.randn_vec(n);
        let via_sub = sub_conv_apply(&mut p, &a, n, &x);
        let via_conv = conv_apply(&mut p, &a, &x);
        for (u, v) in via_sub.iter().zip(&via_conv) {
            assert!((u - v).abs() < 1e-9);
        }
    }

    #[test]
    fn additivity_claim_3_8() {
        let mut p = FftPlanner::new();
        let mut rng = Rng::seeded(45);
        let n = 24;
        let a = rng.randn_vec(n);
        let b = rng.randn_vec(n);
        let x = rng.randn_vec(n);
        let lhs = conv_additivity_lhs(&mut p, &a, &b, &x);
        let sum: Vec<f64> = a.iter().zip(&b).map(|(u, v)| u + v).collect();
        let rhs = conv_apply(&mut p, &sum, &x);
        for (u, v) in lhs.iter().zip(&rhs) {
            assert!((u - v).abs() < 1e-8);
        }
    }

    #[test]
    fn rank_claim_3_6() {
        // conv(e_j) has rank j (1-indexed position of the 1).
        let n = 6;
        for j in 0..n {
            let mut e = vec![0.0; n];
            e[j] = 1.0;
            let c = ConvMatrix::new(e);
            // 1-indexed: e_j with j0 = j+1 → rank n − j... the paper's
            // claim counts rank(conv(e_j)) = j for e_j with the 1 in
            // position j **1-indexed from the bottom**: conv(e_1) = I
            // (rank n)… We verify against the actual linear-algebra rank.
            let dense = c.to_dense();
            let expected = n - j;
            assert_eq!(c.rank(), expected);
            assert_eq!(matrix_rank(&dense), expected);
        }
    }

    /// Gaussian-elimination rank (test helper).
    fn matrix_rank(m: &Matrix) -> usize {
        let mut a = m.clone();
        let (rows, cols) = a.shape();
        let mut rank = 0;
        let mut row = 0;
        for col in 0..cols {
            // Find pivot.
            let mut piv = None;
            for r in row..rows {
                if a[(r, col)].abs() > 1e-9 {
                    piv = Some(r);
                    break;
                }
            }
            let Some(p) = piv else { continue };
            // Swap rows.
            if p != row {
                for c in 0..cols {
                    let tmp = a[(row, c)];
                    a[(row, c)] = a[(p, c)];
                    a[(p, c)] = tmp;
                }
            }
            let pivval = a[(row, col)];
            for r in row + 1..rows {
                let f = a[(r, col)] / pivval;
                for c in 0..cols {
                    let v = a[(row, c)];
                    a[(r, c)] -= f * v;
                }
            }
            rank += 1;
            row += 1;
            if row == rows {
                break;
            }
        }
        rank
    }
}
