//! Toeplitz (Definition B.2) and circulant (Definition B.3) matrices,
//! with the embedding facts B.6–B.8 used in the proof of Claim 3.7.

use crate::fft::{circular_convolution, FftPlanner};
use crate::tensor::Matrix;

/// Toeplitz matrix defined by a length-(2n−1) vector `a` indexed
/// `−(n−1) … (n−1)`: `Toep(a)[i][j] = a[i−j]`.
///
/// Storage: `diag[k]` holds `a_{k−(n−1)}`, i.e. `diag` is the paper's
/// vector read left-to-right (`a_{−(n−1)}, …, a_0, …, a_{n−1}`).
#[derive(Clone, Debug, PartialEq)]
pub struct Toeplitz {
    n: usize,
    diag: Vec<f64>,
}

impl Toeplitz {
    /// Build from the paper-ordered vector `a_{−(n−1)} … a_{n−1}`.
    pub fn new(n: usize, diag: Vec<f64>) -> Self {
        assert_eq!(diag.len(), 2 * n - 1);
        Toeplitz { n, diag }
    }

    /// `a_k` for `k ∈ [−(n−1), n−1]`.
    #[inline]
    pub fn coeff(&self, k: isize) -> f64 {
        self.diag[(k + self.n as isize - 1) as usize]
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    pub fn to_dense(&self) -> Matrix {
        Matrix::from_fn(self.n, self.n, |i, j| self.coeff(i as isize - j as isize))
    }

    /// Fact B.7: embed into a length-2n circulant and multiply via FFT.
    pub fn apply(&self, planner: &mut FftPlanner, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.n);
        let n = self.n;
        // a' = [a_0, a_1, …, a_{n−1}, 0, a_{−(n−1)}, …, a_{−1}]  (len 2n)
        let mut a2 = Vec::with_capacity(2 * n);
        for k in 0..n as isize {
            a2.push(self.coeff(k));
        }
        a2.push(0.0);
        for k in -(n as isize - 1)..0 {
            a2.push(self.coeff(k));
        }
        let mut x2 = vec![0.0; 2 * n];
        x2[..n].copy_from_slice(x);
        let y2 = circular_convolution(planner, &a2, &x2);
        y2[..n].to_vec()
    }
}

/// Circulant matrix (Definition B.3): `Circ(a)[i][j] = a[(i−j) mod n]`.
#[derive(Clone, Debug, PartialEq)]
pub struct Circulant {
    a: Vec<f64>,
}

impl Circulant {
    pub fn new(a: Vec<f64>) -> Self {
        Circulant { a }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.a.len()
    }

    pub fn to_dense(&self) -> Matrix {
        let n = self.n();
        Matrix::from_fn(n, n, |i, j| self.a[(i + n - j) % n])
    }

    /// Fact B.8: `Circ(a)·x = F⁻¹ diag(F a) F x` — one FFT-conv.
    pub fn apply(&self, planner: &mut FftPlanner, x: &[f64]) -> Vec<f64> {
        circular_convolution(planner, &self.a, x)
    }
}


/// Residual matrix `Resi(a)` of Fact B.7: the off-diagonal block of the
/// 2n-circulant embedding of `Toep(a)`. `Resi(a)[i][j] = a'[i−j]` where
/// the index wraps through the padded circulant (0 on the diagonal,
/// `a_{n−1}…a_1` above, `a_{−(n−1)}…a_{−1}` below).
#[derive(Clone, Debug, PartialEq)]
pub struct Resi {
    n: usize,
    diag: Vec<f64>,
}

impl Resi {
    /// Build from the same paper-ordered vector as [`Toeplitz::new`].
    pub fn new(n: usize, diag: Vec<f64>) -> Self {
        assert_eq!(diag.len(), 2 * n - 1);
        Resi { n, diag }
    }

    fn coeff(&self, k: isize) -> f64 {
        self.diag[(k + self.n as isize - 1) as usize]
    }

    pub fn to_dense(&self) -> Matrix {
        let n = self.n as isize;
        Matrix::from_fn(self.n, self.n, |i, j| {
            let off = i as isize - j as isize;
            if off == 0 {
                0.0
            } else if off < 0 {
                // Above diagonal: a_{n+off} (wraps from the positive end).
                self.coeff(n + off)
            } else {
                // Below diagonal: a_{off−n}.
                self.coeff(off - n)
            }
        })
    }
}

/// Fact B.7, verified constructively: the length-2n circulant built
/// from `a'' = [a_0..a_{n−1}, 0, a_{−(n−1)}..a_{−1}]` decomposes into
/// the 2×2 block form `[[Toep(a), Resi(a)], [Resi(a), Toep(a)]]`, so
/// `Circ(a'')·[x; 0] = [Toep(a)·x; Resi(a)·x]`.
pub fn fact_b7_embedding(n: usize, diag: &[f64]) -> (Circulant, Toeplitz, Resi) {
    assert_eq!(diag.len(), 2 * n - 1);
    let toep = Toeplitz::new(n, diag.to_vec());
    let resi = Resi::new(n, diag.to_vec());
    let mut a2 = Vec::with_capacity(2 * n);
    for k in 0..n as isize {
        a2.push(toep.coeff(k));
    }
    a2.push(0.0);
    for k in -(n as isize - 1)..0 {
        a2.push(toep.coeff(k));
    }
    (Circulant::new(a2), toep, resi)
}

/// Claim B.6: `conv(a) = Toep([0_{n−1}; a])` — build the Toeplitz view
/// of a convolution matrix.
#[allow(dead_code)]
pub fn conv_as_toeplitz(a: &[f64]) -> Toeplitz {
    let n = a.len();
    let mut diag = vec![0.0; 2 * n - 1];
    diag[n - 1..].copy_from_slice(a); // a_0 .. a_{n-1} = a, negatives 0
    Toeplitz::new(n, diag)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::ConvMatrix;
    use crate::tensor::Rng;

    #[test]
    fn toeplitz_dense_layout() {
        // n=3, diag = a_{-2},a_{-1},a_0,a_1,a_2 = [9, 8, 1, 2, 3]
        let t = Toeplitz::new(3, vec![9.0, 8.0, 1.0, 2.0, 3.0]);
        let d = t.to_dense();
        let expect = Matrix::from_vec(3, 3, vec![1.0, 8.0, 9.0, 2.0, 1.0, 8.0, 3.0, 2.0, 1.0]);
        assert_eq!(d, expect);
    }

    #[test]
    fn toeplitz_apply_matches_dense() {
        let mut p = FftPlanner::new();
        let mut rng = Rng::seeded(51);
        for &n in &[1usize, 2, 5, 16, 31] {
            let diag = rng.randn_vec(2 * n - 1);
            let x = rng.randn_vec(n);
            let t = Toeplitz::new(n, diag);
            let fast = t.apply(&mut p, &x);
            let dense = t.to_dense().matvec(&x);
            for (u, v) in fast.iter().zip(&dense) {
                assert!((u - v).abs() < 1e-8, "n={n}");
            }
        }
    }

    #[test]
    fn circulant_apply_matches_dense() {
        let mut p = FftPlanner::new();
        let mut rng = Rng::seeded(52);
        for &n in &[1usize, 3, 8, 21] {
            let a = rng.randn_vec(n);
            let x = rng.randn_vec(n);
            let c = Circulant::new(a);
            let fast = c.apply(&mut p, &x);
            let dense = c.to_dense().matvec(&x);
            for (u, v) in fast.iter().zip(&dense) {
                assert!((u - v).abs() < 1e-8, "n={n}");
            }
        }
    }


    #[test]
    fn fact_b7_block_structure() {
        let mut rng = Rng::seeded(54);
        let n = 7;
        let diag = rng.randn_vec(2 * n - 1);
        let (circ, toep, resi) = fact_b7_embedding(n, &diag);
        let c = circ.to_dense();
        let t = toep.to_dense();
        let r = resi.to_dense();
        for i in 0..n {
            for j in 0..n {
                assert!((c[(i, j)] - t[(i, j)]).abs() < 1e-12, "TL");
                assert!((c[(i, j + n)] - r[(i, j)]).abs() < 1e-12, "TR");
                assert!((c[(i + n, j)] - r[(i, j)]).abs() < 1e-12, "BL");
                assert!((c[(i + n, j + n)] - t[(i, j)]).abs() < 1e-12, "BR");
            }
        }
    }

    #[test]
    fn fact_b7_multiply_identity() {
        // Circ(a'')·[x; 0] = [Toep(a)·x; Resi(a)·x]
        let mut p = FftPlanner::new();
        let mut rng = Rng::seeded(55);
        let n = 9;
        let diag = rng.randn_vec(2 * n - 1);
        let x = rng.randn_vec(n);
        let (circ, toep, resi) = fact_b7_embedding(n, &diag);
        let mut x2 = vec![0.0; 2 * n];
        x2[..n].copy_from_slice(&x);
        let y2 = circ.apply(&mut p, &x2);
        let yt = toep.to_dense().matvec(&x);
        let yr = resi.to_dense().matvec(&x);
        for i in 0..n {
            assert!((y2[i] - yt[i]).abs() < 1e-8);
            assert!((y2[n + i] - yr[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn claim_b6_conv_equals_masked_toeplitz() {
        let mut rng = Rng::seeded(53);
        let n = 9;
        let a = rng.randn_vec(n);
        let conv_dense = ConvMatrix::new(a.clone()).to_dense();
        let toep_dense = conv_as_toeplitz(&a).to_dense();
        assert_eq!(conv_dense, toep_dense.tril());
        // And the full Toeplitz with zero negative diagonals IS conv(a).
        assert_eq!(conv_dense, toep_dense);
    }
}
