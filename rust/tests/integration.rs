//! Integration tests across modules: PJRT artifacts vs the native Rust
//! path, and the full recover→transform→apply pipeline end-to-end.
//!
//! Artifact-dependent tests skip (with a notice) when `artifacts/` has
//! not been built; `make test` builds them first.

use conv_basis::attention::rope::rope_structured_qk;
use conv_basis::attention::{conv_attention, exact_attention, Mask};
use conv_basis::basis::{ConvBasis, KConvBasis, RecoverConfig};
use conv_basis::runtime::PjrtRuntime;
use conv_basis::tensor::{max_abs_diff, Matrix, Rng};
use std::path::Path;

fn artifacts_root() -> std::path::PathBuf {
    // Tests run from the crate root.
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_root().join("conv_attention.hlo.txt").exists()
}

/// The default AOT variant baked by `make artifacts` (python/compile/aot.py).
const ART_N: usize = 256;
const ART_D: usize = 32;
const ART_K: usize = 4;
const ART_MS: [usize; 4] = [256, 128, 64, 32];

#[test]
fn pjrt_conv_attention_artifact_matches_native() {
    if !have_artifacts() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    }
    let mut rt = PjrtRuntime::cpu().expect("PJRT CPU client");
    let model = rt
        .load(&artifacts_root().join("conv_attention.hlo.txt"))
        .expect("load artifact");

    // Positive basis bank (mirrors exp_transform output) + V.
    let mut rng = Rng::seeded(301);
    let mut bases = Matrix::randn(ART_K, ART_N, &mut rng).map(|x| x.abs() + 0.1);
    // Keep magnitudes f32-friendly.
    bases = bases.scale(0.5);
    let v = Matrix::randn(ART_N, ART_D, &mut rng);

    let out = model
        .run(&[(&bases, (ART_K, ART_N)), (&v, (ART_N, ART_D))], &[(ART_N, ART_D)])
        .expect("execute artifact");
    let y_pjrt = &out[0];

    // Native Rust path with the identical basis bank.
    let terms: Vec<ConvBasis> = (0..ART_K)
        .map(|r| ConvBasis { b: bases.row(r).to_vec(), m: ART_MS[r] })
        .collect();
    let basis = KConvBasis::new(ART_N, terms);
    let mut planner = conv_basis::fft::FftPlanner::new();
    let num = basis.apply_matrix(&mut planner, &v);
    let d = basis.row_sums();
    let inv: Vec<f64> = d.iter().map(|&x| 1.0 / x).collect();
    let y_native = num.scale_rows(&inv);

    let err = max_abs_diff(y_pjrt, &y_native);
    assert!(err < 5e-4, "pjrt vs native err = {err}"); // f32 artifact
}

#[test]
fn pjrt_exact_attention_artifact_matches_native() {
    if !have_artifacts() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    }
    let mut rt = PjrtRuntime::cpu().expect("PJRT CPU client");
    let model = rt
        .load(&artifacts_root().join("exact_attention.hlo.txt"))
        .expect("load artifact");
    let mut rng = Rng::seeded(302);
    let q = Matrix::randn(ART_N, ART_D, &mut rng).scale(0.2);
    let k = Matrix::randn(ART_N, ART_D, &mut rng).scale(0.2);
    let v = Matrix::randn(ART_N, ART_D, &mut rng);
    let out = model
        .run(
            &[(&q, (ART_N, ART_D)), (&k, (ART_N, ART_D)), (&v, (ART_N, ART_D))],
            &[(ART_N, ART_D)],
        )
        .expect("execute artifact");
    let y_native = exact_attention(&q, &k, &v, &Mask::causal(ART_N));
    let err = max_abs_diff(&out[0], &y_native);
    assert!(err < 1e-3, "pjrt vs native err = {err}");
}

#[test]
fn recover_then_pjrt_apply_pipeline() {
    // Full three-layer composition: Rust recovers the basis from
    // structured Q,K (Algorithm 2), then the PJRT artifact (L2+L1,
    // jax+pallas-lowered) applies it; result must match the exact
    // attention oracle.
    if !have_artifacts() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    }
    let mut rng = Rng::seeded(303);
    let (q, k) = rope_structured_qk(ART_N, ART_D, 3, &mut rng);
    let v = Matrix::randn(ART_N, ART_D, &mut rng);
    let t = 4;
    let cfg = RecoverConfig { k_max: ART_K, t, delta: 5.0 * t as f64 * 1e-7, eps: 1e-7 };
    let out = conv_attention(&q, &k, &v, &cfg).expect("conv attention");

    // Pad the recovered basis into the artifact's fixed (k, ms) bank:
    // the artifact windows are (256,128,64,32); any basis with windows
    // not matching must be re-expressed. Toeplitz QKᵀ gives k=1, m=256,
    // which IS the artifact's first slot; remaining slots zero.
    assert!(out.post_basis.k() <= ART_K);
    let mut bases = Matrix::zeros(ART_K, ART_N);
    let mut ok = true;
    for term in out.post_basis.terms() {
        if let Some(slot) = ART_MS.iter().position(|&m| m == term.m) {
            for (j, &x) in term.b.iter().enumerate() {
                bases[(slot, j)] = x;
            }
        } else {
            ok = false;
        }
    }
    if !ok {
        eprintln!("SKIP: recovered windows don't fit the artifact variant");
        return;
    }
    let mut rt = PjrtRuntime::cpu().expect("PJRT CPU client");
    let model = rt
        .load(&artifacts_root().join("conv_attention.hlo.txt"))
        .expect("load artifact");
    let y_pjrt = &model
        .run(&[(&bases, (ART_K, ART_N)), (&v, (ART_N, ART_D))], &[(ART_N, ART_D)])
        .expect("execute")[0];

    let exact = exact_attention(&q, &k, &v, &Mask::causal(ART_N));
    let err = max_abs_diff(y_pjrt, &exact);
    assert!(err < 1e-3, "pipeline err vs oracle = {err}");
}


#[test]
fn pjrt_lowrank_causal_artifact_matches_native() {
    if !have_artifacts() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    }
    let mut rt = PjrtRuntime::cpu().expect("PJRT CPU client");
    let model = rt
        .load(&artifacts_root().join("lowrank_causal.hlo.txt"))
        .expect("load artifact");
    const RANK: usize = 16; // aot.py default
    let mut rng = Rng::seeded(304);
    // Positive factors: valid normalized attention.
    let u1 = Matrix::randn(ART_N, RANK, &mut rng).map(|x| x.abs() + 0.1);
    let u2 = Matrix::randn(ART_N, RANK, &mut rng).map(|x| x.abs() + 0.1);
    let v = Matrix::randn(ART_N, ART_D, &mut rng);
    let out = model
        .run(
            &[(&u1, (ART_N, RANK)), (&u2, (ART_N, RANK)), (&v, (ART_N, ART_D))],
            &[(ART_N, ART_D)],
        )
        .expect("execute artifact");
    // Native Theorem 6.5 path with identical factors (Algorithm 4).
    let lr = conv_basis::lowrank::LowRankAttention::from_factors(
        conv_basis::lowrank::LowRankFactors { u1, u2 },
        Mask::causal(ART_N),
    );
    let y_native = lr.forward(&v);
    let err = max_abs_diff(&out[0], &y_native);
    assert!(err < 1e-3, "pjrt vs native err = {err}");
}

#[test]
fn artifact_shape_mismatch_is_detected() {
    if !have_artifacts() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    }
    let mut rt = PjrtRuntime::cpu().unwrap();
    let model = rt.load(&artifacts_root().join("conv_attention.hlo.txt")).unwrap();
    let bad = Matrix::zeros(2, 2);
    let v = Matrix::zeros(ART_N, ART_D);
    assert!(model
        .run(&[(&bad, (ART_K, ART_N)), (&v, (ART_N, ART_D))], &[(ART_N, ART_D)])
        .is_err());
}

#[test]
fn makefile_artifact_paths_exist_or_skipped() {
    // Keep the default artifact inventory in sync with aot.py.
    if !have_artifacts() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    for name in ["conv_attention", "exact_attention", "lowrank_causal"] {
        assert!(artifacts_root().join(format!("{name}.hlo.txt")).exists());
        assert!(artifacts_root().join(format!("{name}.meta.json")).exists());
    }
    let _ = Path::new("x");
}
