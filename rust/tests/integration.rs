//! Integration tests across modules: the batched engine's cache reuse,
//! PJRT artifacts vs the native Rust path, and the full
//! recover→transform→apply pipeline end-to-end.
//!
//! Artifact-dependent tests skip (with a notice) when `artifacts/` has
//! not been built or the crate was built without the `pjrt` feature;
//! `make test` builds artifacts first.

use conv_basis::attention::batched::{
    AttnJob, BatchedBackend, BatchedEngine, EngineConfig, EngineJob, JobOutput,
};
use conv_basis::attention::rope::rope_structured_qk;
use conv_basis::attention::{conv_attention, exact_attention, Mask};
use conv_basis::basis::{ConvBasis, KConvBasis, RecoverConfig};
use conv_basis::runtime::PjrtRuntime;
use conv_basis::tensor::{max_abs_diff, Matrix, Rng};
use std::path::Path;

fn attend(e: &BatchedEngine, jobs: Vec<AttnJob>) -> Vec<JobOutput> {
    e.submit(jobs.into_iter().enumerate().map(|(i, j)| EngineJob::prefill(i as u64, j)).collect())
        .into_iter()
        .map(|o| o.result.into_prefill())
        .collect()
}

fn artifacts_root() -> std::path::PathBuf {
    // Tests run from the crate root.
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    conv_basis::runtime::pjrt_available()
        && artifacts_root().join("conv_attention.hlo.txt").exists()
}

/// The default AOT variant baked by `make artifacts` (python/compile/aot.py).
const ART_N: usize = 256;
const ART_D: usize = 32;
const ART_K: usize = 4;
const ART_MS: [usize; 4] = [256, 128, 64, 32];

#[test]
fn batched_engine_second_call_hits_basis_cache() {
    // The serving reuse contract: a second batched call with identical
    // (layer, head, seq_len) jobs is served from the basis cache, and
    // the hit counter is visible through coordinator::metrics.
    let engine = BatchedEngine::new(EngineConfig { workers: 2, cache_capacity: 64 });
    let mut rng = Rng::seeded(310);
    let (n, d) = (64, 8);
    let mut jobs = Vec::new();
    for h in 0..4u32 {
        let (q, k) = rope_structured_qk(n, d, 3, &mut rng);
        let v = Matrix::randn(n, d, &mut rng);
        jobs.push(AttnJob::causal(1, h, q, k, v, BatchedBackend::Strided(4)));
    }
    let first = attend(&engine, jobs.clone());
    let snap1 = engine.metrics().snapshot();
    assert!(snap1.cache_misses >= 4, "first call must recover: {snap1:?}");

    let second = attend(&engine, jobs);
    let snap2 = engine.metrics().snapshot();
    assert!(
        snap2.cache_hits >= snap1.cache_hits + 4,
        "second call must hit the cache for every job: {snap2:?}"
    );
    assert_eq!(snap2.cache_misses, snap1.cache_misses, "no re-recovery on the second call");
    for (a, b) in first.iter().zip(&second) {
        assert!(b.cache_hit);
        assert_eq!(max_abs_diff(&a.y, &b.y), 0.0, "cached apply must be bit-identical");
    }
    // And the cached payload is the O(kn) basis, not an n×n matrix.
    let (hits, _, len) = engine.cache().stats();
    assert!(hits >= 4);
    assert_eq!(len, 4, "one cache entry per (layer, head, seq_len, content)");
}

#[test]
fn server_conv_batches_reuse_engine_cache() {
    // Same repeated synthetic payload through the whole coordinator:
    // the engine-backed workers must hit the server's shared cache.
    use conv_basis::coordinator::{
        AttnRequest, BatcherConfig, Payload, RouterConfig, Server, ServerConfig,
    };
    use std::time::Instant;
    let server = Server::start(ServerConfig {
        router: RouterConfig { exact_below: 64, ..Default::default() },
        batcher: BatcherConfig { max_batch: 4, max_wait: std::time::Duration::from_millis(1) },
        workers: 2,
        cache_capacity: 16,
        lowrank_degree: 2,
        gen: None,
    });
    for i in 0..8u64 {
        server.submit(AttnRequest {
            id: i,
            seq_len: 96,
            d_model: 8,
            bounded_entries: false,
            backend: None,
            payload: Payload::Synthetic { seed: 42 },
            submitted_at: Instant::now(),
        });
    }
    let resps = server.collect(8);
    assert_eq!(resps.len(), 8);
    let metrics = server.shutdown();
    let snap = metrics.snapshot();
    assert_eq!(snap.conv_requests, 8);
    assert!(snap.cache_hits >= 1, "repeated payloads must hit: {snap:?}");
    assert!(snap.batched_calls >= 1, "batches must go through the engine");
    assert_eq!(snap.batched_jobs, 8);
}

#[test]
fn pjrt_conv_attention_artifact_matches_native() {
    if !have_artifacts() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    }
    let mut rt = PjrtRuntime::cpu().expect("PJRT CPU client");
    let model = rt
        .load(&artifacts_root().join("conv_attention.hlo.txt"))
        .expect("load artifact");

    // Positive basis bank (mirrors exp_transform output) + V.
    let mut rng = Rng::seeded(301);
    let mut bases = Matrix::randn(ART_K, ART_N, &mut rng).map(|x| x.abs() + 0.1);
    // Keep magnitudes f32-friendly.
    bases = bases.scale(0.5);
    let v = Matrix::randn(ART_N, ART_D, &mut rng);

    let out = model
        .run(&[(&bases, (ART_K, ART_N)), (&v, (ART_N, ART_D))], &[(ART_N, ART_D)])
        .expect("execute artifact");
    let y_pjrt = &out[0];

    // Native Rust path with the identical basis bank.
    let terms: Vec<ConvBasis> = (0..ART_K)
        .map(|r| ConvBasis { b: bases.row(r).to_vec(), m: ART_MS[r] })
        .collect();
    let basis = KConvBasis::new(ART_N, terms);
    let mut planner = conv_basis::fft::FftPlanner::new();
    let num = basis.apply_matrix(&mut planner, &v);
    let d = basis.row_sums();
    let inv: Vec<f64> = d.iter().map(|&x| 1.0 / x).collect();
    let y_native = num.scale_rows(&inv);

    let err = max_abs_diff(y_pjrt, &y_native);
    assert!(err < 5e-4, "pjrt vs native err = {err}"); // f32 artifact
}

#[test]
fn pjrt_exact_attention_artifact_matches_native() {
    if !have_artifacts() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    }
    let mut rt = PjrtRuntime::cpu().expect("PJRT CPU client");
    let model = rt
        .load(&artifacts_root().join("exact_attention.hlo.txt"))
        .expect("load artifact");
    let mut rng = Rng::seeded(302);
    let q = Matrix::randn(ART_N, ART_D, &mut rng).scale(0.2);
    let k = Matrix::randn(ART_N, ART_D, &mut rng).scale(0.2);
    let v = Matrix::randn(ART_N, ART_D, &mut rng);
    let out = model
        .run(
            &[(&q, (ART_N, ART_D)), (&k, (ART_N, ART_D)), (&v, (ART_N, ART_D))],
            &[(ART_N, ART_D)],
        )
        .expect("execute artifact");
    let y_native = exact_attention(&q, &k, &v, &Mask::causal(ART_N));
    let err = max_abs_diff(&out[0], &y_native);
    assert!(err < 1e-3, "pjrt vs native err = {err}");
}

#[test]
fn recover_then_pjrt_apply_pipeline() {
    // Full three-layer composition: Rust recovers the basis from
    // structured Q,K (Algorithm 2), then the PJRT artifact (L2+L1,
    // jax+pallas-lowered) applies it; result must match the exact
    // attention oracle.
    if !have_artifacts() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    }
    let mut rng = Rng::seeded(303);
    let (q, k) = rope_structured_qk(ART_N, ART_D, 3, &mut rng);
    let v = Matrix::randn(ART_N, ART_D, &mut rng);
    let t = 4;
    let cfg = RecoverConfig { k_max: ART_K, t, delta: 5.0 * t as f64 * 1e-7, eps: 1e-7 };
    let out = conv_attention(&q, &k, &v, &cfg).expect("conv attention");

    // Pad the recovered basis into the artifact's fixed (k, ms) bank:
    // the artifact windows are (256,128,64,32); any basis with windows
    // not matching must be re-expressed. Toeplitz QKᵀ gives k=1, m=256,
    // which IS the artifact's first slot; remaining slots zero.
    assert!(out.post_basis.k() <= ART_K);
    let mut bases = Matrix::zeros(ART_K, ART_N);
    let mut ok = true;
    for term in out.post_basis.terms() {
        if let Some(slot) = ART_MS.iter().position(|&m| m == term.m) {
            for (j, &x) in term.b.iter().enumerate() {
                bases[(slot, j)] = x;
            }
        } else {
            ok = false;
        }
    }
    if !ok {
        eprintln!("SKIP: recovered windows don't fit the artifact variant");
        return;
    }
    let mut rt = PjrtRuntime::cpu().expect("PJRT CPU client");
    let model = rt
        .load(&artifacts_root().join("conv_attention.hlo.txt"))
        .expect("load artifact");
    let y_pjrt = &model
        .run(&[(&bases, (ART_K, ART_N)), (&v, (ART_N, ART_D))], &[(ART_N, ART_D)])
        .expect("execute")[0];

    let exact = exact_attention(&q, &k, &v, &Mask::causal(ART_N));
    let err = max_abs_diff(y_pjrt, &exact);
    assert!(err < 1e-3, "pipeline err vs oracle = {err}");
}


#[test]
fn pjrt_lowrank_causal_artifact_matches_native() {
    if !have_artifacts() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    }
    let mut rt = PjrtRuntime::cpu().expect("PJRT CPU client");
    let model = rt
        .load(&artifacts_root().join("lowrank_causal.hlo.txt"))
        .expect("load artifact");
    const RANK: usize = 16; // aot.py default
    let mut rng = Rng::seeded(304);
    // Positive factors: valid normalized attention.
    let u1 = Matrix::randn(ART_N, RANK, &mut rng).map(|x| x.abs() + 0.1);
    let u2 = Matrix::randn(ART_N, RANK, &mut rng).map(|x| x.abs() + 0.1);
    let v = Matrix::randn(ART_N, ART_D, &mut rng);
    let out = model
        .run(
            &[(&u1, (ART_N, RANK)), (&u2, (ART_N, RANK)), (&v, (ART_N, ART_D))],
            &[(ART_N, ART_D)],
        )
        .expect("execute artifact");
    // Native Theorem 6.5 path with identical factors (Algorithm 4).
    let lr = conv_basis::lowrank::LowRankAttention::from_factors(
        conv_basis::lowrank::LowRankFactors { u1, u2 },
        Mask::causal(ART_N),
    );
    let y_native = lr.forward(&v);
    let err = max_abs_diff(&out[0], &y_native);
    assert!(err < 1e-3, "pjrt vs native err = {err}");
}

#[test]
fn artifact_shape_mismatch_is_detected() {
    if !have_artifacts() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    }
    let mut rt = PjrtRuntime::cpu().unwrap();
    let model = rt.load(&artifacts_root().join("conv_attention.hlo.txt")).unwrap();
    let bad = Matrix::zeros(2, 2);
    let v = Matrix::zeros(ART_N, ART_D);
    assert!(model
        .run(&[(&bad, (ART_K, ART_N)), (&v, (ART_N, ART_D))], &[(ART_N, ART_D)])
        .is_err());
}

#[test]
fn makefile_artifact_paths_exist_or_skipped() {
    // Keep the default artifact inventory in sync with aot.py.
    if !have_artifacts() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    for name in ["conv_attention", "exact_attention", "lowrank_causal"] {
        assert!(artifacts_root().join(format!("{name}.hlo.txt")).exists());
        assert!(artifacts_root().join(format!("{name}.meta.json")).exists());
    }
    let _ = Path::new("x");
}
