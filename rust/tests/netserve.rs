//! TCP front-end, end to end over real sockets: concurrent connections
//! mixing streamed generation with attention requests must see exactly
//! the bytes the in-process API would produce — token streams bit-match
//! an in-process oracle server, attention fingerprints match oracle
//! outputs, load shedding answers busy over the wire, and shutdown
//! mid-stream is clean.

use conv_basis::coordinator::{
    fingerprint, AdmissionConfig, AttnRequest, Backend, GenConfig, GenRequest, NetConfig,
    NetServer, Payload, Server, ServerConfig,
};
use conv_basis::model::{AttentionBackend, ModelConfig, Transformer};
use conv_basis::tensor::Rng;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Instant;

fn model() -> Arc<Transformer> {
    let mut rng = Rng::seeded(42);
    Arc::new(Transformer::new(&ModelConfig::tiny(64), &mut rng))
}

fn cfg(model: Arc<Transformer>, admission: AdmissionConfig) -> ServerConfig {
    ServerConfig {
        workers: 2,
        gen: Some(GenConfig {
            model,
            backend: AttentionBackend::ConvStrided(4),
            max_concurrent: 4,
            admission,
        }),
        ..Default::default()
    }
}

/// Minimal flat-JSON field reader for the wire format under test.
fn jfield<'a>(line: &'a str, key: &str) -> &'a str {
    let pat = format!("\"{key}\":");
    let i = line.find(&pat).unwrap_or_else(|| panic!("no {key:?} in {line:?}")) + pat.len();
    let rest = &line[i..];
    let end = rest
        .char_indices()
        .find(|(_, c)| *c == ',' || *c == '}')
        .map(|(j, _)| j)
        .unwrap_or(rest.len());
    rest[..end].trim_matches('"')
}

fn ju(line: &str, key: &str) -> u64 {
    jfield(line, key).parse().unwrap_or_else(|_| panic!("bad uint {key:?} in {line:?}"))
}

/// What one client connection observed for its generation request.
struct ClientView {
    tokens: Vec<usize>,
    done_tokens: Vec<usize>,
    attn_line: String,
}

/// Drive one connection: a generate and an attn request, concurrently
/// outstanding, reading interleaved lines until both terminate.
fn run_client(addr: std::net::SocketAddr, c: usize) -> ClientView {
    let stream = TcpStream::connect(addr).expect("connect");
    stream.set_nodelay(true).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    writeln!(
        writer,
        "{{\"op\":\"generate\",\"id\":{c},\"prompt\":[{},{},{}],\"max_new_tokens\":6}}",
        1 + c,
        2 + c,
        3 + c,
    )
    .unwrap();
    writeln!(writer, "{{\"op\":\"attn\",\"id\":{},\"seq_len\":128,\"d_model\":8,\"seed\":{c}}}", 100 + c)
        .unwrap();

    let mut view =
        ClientView { tokens: Vec::new(), done_tokens: Vec::new(), attn_line: String::new() };
    let (mut done, mut attn_done) = (false, false);
    let mut line = String::new();
    while !(done && attn_done) {
        line.clear();
        assert!(reader.read_line(&mut line).expect("read") > 0, "server closed early");
        let l = line.trim();
        match jfield(l, "ev") {
            "token" => {
                assert_eq!(ju(l, "id") as usize, c, "token routed to the wrong client id");
                assert_eq!(ju(l, "index") as usize, view.tokens.len(), "indices must be consecutive");
                view.tokens.push(ju(l, "token") as usize);
            }
            "done" => {
                assert_eq!(ju(l, "id") as usize, c);
                let arr = &l[l.find("\"tokens\":[").unwrap() + 10..];
                let arr = &arr[..arr.find(']').unwrap()];
                view.done_tokens =
                    arr.split(',').filter(|t| !t.is_empty()).map(|t| t.parse().unwrap()).collect();
                done = true;
            }
            "attn" => {
                assert_eq!(ju(l, "id") as usize, 100 + c);
                view.attn_line = l.to_string();
                attn_done = true;
            }
            other => panic!("unexpected event {other:?}: {l}"),
        }
    }
    view
}

#[test]
fn concurrent_connections_stream_bit_identical_tokens() {
    let model = model();
    let net = NetServer::start(cfg(model.clone(), AdmissionConfig::default()), NetConfig::default())
        .expect("bind");
    let addr = net.addr();

    let clients: Vec<ClientView> = (0..4usize)
        .map(|c| std::thread::spawn(move || run_client(addr, c)))
        .collect::<Vec<_>>()
        .into_iter()
        .map(|j| j.join().expect("client thread"))
        .collect();
    let net_metrics = net.shutdown();

    // Oracle: the same requests through the in-process API on an
    // identically configured server sharing the same model weights.
    let oracle = Server::start(cfg(model, AdmissionConfig::default()));
    for c in 0..4usize {
        oracle.submit_generate(GenRequest::new(c as u64, vec![1 + c, 2 + c, 3 + c], 6));
        oracle.submit(AttnRequest {
            id: 100 + c as u64,
            seq_len: 128,
            d_model: 8,
            bounded_entries: false,
            payload: Payload::Synthetic { seed: c as u64 },
            submitted_at: Instant::now(),
        });
    }
    let mut gens = oracle.collect_generations(4);
    gens.sort_by_key(|g| g.id);
    let mut attns = oracle.collect(4);
    attns.sort_by_key(|r| r.id);
    oracle.shutdown();

    for (c, view) in clients.iter().enumerate() {
        assert_eq!(view.tokens.len(), 6, "client {c} streamed token count");
        assert_eq!(view.done_tokens, view.tokens, "done must repeat the stream");
        assert_eq!(view.tokens, gens[c].tokens, "client {c} tokens vs in-process oracle");

        let want_backend = match attns[c].backend {
            Backend::Exact => "exact",
            Backend::ConvBasis => "conv",
            Backend::LowRank => "lowrank",
        };
        assert_eq!(jfield(&view.attn_line, "backend"), want_backend);
        assert_eq!(ju(&view.attn_line, "basis_k") as usize, attns[c].basis_k);
        let want_fp = format!("{:016x}", fingerprint(attns[c].y.data()));
        assert_eq!(jfield(&view.attn_line, "y_fp"), want_fp, "client {c} attn fingerprint");
    }
    let s = net_metrics.snapshot();
    assert_eq!((s.gen_requests, s.gen_completed, s.gen_rejected), (4, 4, 0));
    assert_eq!(s.requests_submitted, 4);
}

#[test]
fn full_queue_sheds_busy_over_the_wire() {
    let model = model();
    let admission = AdmissionConfig { max_queue: 1, ..Default::default() };
    let mut cfg = cfg(model, admission);
    cfg.gen.as_mut().unwrap().max_concurrent = 1;
    let net = NetServer::start(cfg, NetConfig::default()).expect("bind");

    let stream = TcpStream::connect(net.addr()).expect("connect");
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    // 8 back-to-back submissions: with one decode slot and a queue of
    // one, most of the burst must shed.
    let mut burst = String::new();
    for i in 0..8 {
        burst.push_str(&format!(
            "{{\"op\":\"generate\",\"id\":{i},\"prompt\":[1,2,3],\"max_new_tokens\":8}}\n"
        ));
    }
    writer.write_all(burst.as_bytes()).unwrap();
    writer.flush().unwrap();

    let (mut done, mut busy) = (0usize, 0usize);
    let mut line = String::new();
    while done + busy < 8 {
        line.clear();
        assert!(reader.read_line(&mut line).expect("read") > 0, "server closed early");
        match jfield(line.trim(), "ev") {
            "done" => done += 1,
            "busy" => busy += 1,
            "token" => {}
            other => panic!("unexpected event {other:?}: {line}"),
        }
    }
    let s = net.shutdown().snapshot();
    assert!(busy >= 1, "a burst of 8 through a queue of 1 must shed");
    assert_eq!(busy as u64, s.shed_requests);
    assert_eq!(done as u64, s.gen_completed);
    assert_eq!(s.gen_requests, 8, "every submission is counted at the door");
}

#[test]
fn shutdown_mid_stream_is_clean() {
    let model = model();
    let net =
        NetServer::start(cfg(model, AdmissionConfig::default()), NetConfig::default()).expect("bind");

    let stream = TcpStream::connect(net.addr()).expect("connect");
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    writeln!(writer, "{{\"op\":\"generate\",\"id\":1,\"prompt\":[5,6,7],\"max_new_tokens\":40}}")
        .unwrap();
    // Wait for the stream to actually start, then pull the plug.
    let mut line = String::new();
    assert!(reader.read_line(&mut line).expect("read") > 0);
    assert_eq!(jfield(line.trim(), "ev"), "token");

    let s = net.shutdown().snapshot();
    assert_eq!(s.gen_requests, 1);
    assert!(s.gen_tokens >= 1, "at least the streamed token decoded");
    // The client's socket is closed: reads drain to EOF without hanging.
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) | Err(_) => break,
            Ok(_) => {}
        }
    }
}
